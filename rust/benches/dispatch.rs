//! Bench: dispatcher inference latency (it sits on the collective call
//! path, so it must be negligible — microseconds).

use pccl::backends::CollKind;
use pccl::dispatch::SvmDispatcher;
use pccl::topology::Machine;
use pccl::util::microbench::{section, Bench};

fn main() {
    section("dispatch");
    let dispatcher = SvmDispatcher::train(
        Machine::Frontier,
        &[16, 64, 256, 1024],
        &[32, 128, 512, 2048],
        3,
        9,
    )
    .expect("train dispatcher");
    let mut i = 0usize;
    Bench::new("dispatch/choose").run(|| {
        i = i.wrapping_add(1);
        let msg = (16 + (i % 64)) << 20;
        let ranks = 32 << (i % 7);
        dispatcher.choose(CollKind::AllGather, msg, ranks)
    });
}
