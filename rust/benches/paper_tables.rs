//! Bench: one benchmark per paper table/figure — times the regeneration of
//! each experiment so `cargo bench` exercises the full harness end to end
//! (the actual rows land in `results/` via `pccl figures all`).

use std::time::Duration;

use pccl::bench::figures;
use pccl::topology::Machine;
use pccl::util::microbench::{section, Bench};

fn main() {
    section("paper figure regeneration");
    let quick = Bench::new("fig1_allgather_scaling").budget(Duration::from_millis(800));
    quick.run(|| figures::fig1().unwrap().cells.len());
    Bench::new("fig2_msgsize_distributions").run(|| figures::fig2().len());
    Bench::new("fig3_nic_counters")
        .budget(Duration::from_millis(800))
        .run(|| figures::fig3().unwrap().0.cells.len());
    Bench::new("fig4_reduce_scatter_small_scale")
        .budget(Duration::from_millis(800))
        .run(|| figures::fig4().unwrap().cells.len());
    Bench::new("fig6_rec_vs_ring_heatmap")
        .budget(Duration::from_millis(800))
        .run(|| figures::fig6().unwrap().cells.len());
    Bench::new("fig12_zero3_strong_scaling")
        .budget(Duration::from_millis(800))
        .run(|| figures::fig12().unwrap().cells.len());
    Bench::new("fig13_ddp_strong_scaling")
        .budget(Duration::from_millis(800))
        .run(|| figures::fig13().unwrap().cells.len());

    section("paper (slow: trains SVM dispatchers)");
    Bench::new("fig11_speedup_heatmap_frontier")
        .warmup(Duration::from_millis(0))
        .budget(Duration::from_millis(1))
        .run(|| figures::fig9_or_11(Machine::Frontier).unwrap().cells.len());
}
