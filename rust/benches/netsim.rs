//! Bench: netsim throughput — the figure harness runs thousands of
//! simulations, so a single simulation must stay in the microsecond range.

use pccl::backends::CollKind;
use pccl::netsim::libmodel::{simulate, LibModel};
use pccl::topology::Machine;
use pccl::util::microbench::{section, Bench};

fn main() {
    section("netsim/simulate (10 trials, 2048 ranks)");
    for (label, lib) in [("vendor", LibModel::Vendor), ("pccl_rec", LibModel::PcclRec)] {
        Bench::new(format!("simulate/{label}")).run(|| {
            simulate(
                Machine::Frontier,
                lib,
                CollKind::ReduceScatter,
                256 << 20,
                2048,
                10,
                3,
            )
            .unwrap()
            .stats
            .mean()
        });
    }
}
