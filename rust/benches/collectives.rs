//! Bench: real data-plane collectives, every backend — the end-to-end hot
//! path of the library (used by the §Perf iteration log).
//!
//! Each measurement spawns one world and runs `INNER` back-to-back
//! collectives inside it, so thread spawn/join is amortized and the number
//! reflects the per-collective hot path.

use pccl::backends::{all_gather, all_reduce, reduce_scatter, Backend, CollectiveOptions};
use pccl::comm::CommWorld;
use pccl::topology::Topology;
use pccl::util::microbench::{section, Bench};

const INNER: usize = 32;

fn main() {
    let topo = Topology::new(2, 4, 2).unwrap();
    let elems = 64 * 1024; // 256 KiB/rank
    let bytes = (elems * 4 * INNER) as u64;
    for backend in [Backend::Vendor, Backend::PcclRing, Backend::PcclRec] {
        section(&format!("collectives/{} ({} ops/iter)", backend.label(), INNER));

        let world = CommWorld::<f32>::with_topology(topo);
        Bench::new(format!("all_gather/8rk/{}", backend.label())).run_bytes(bytes, || {
            world.run(move |comm| {
                let input = vec![comm.rank() as f32; elems / comm.size()];
                let opts = CollectiveOptions::default().backend(backend);
                let mut total = 0usize;
                for _ in 0..INNER {
                    total += all_gather(comm, &input, &opts).unwrap().len();
                }
                total
            })
        });

        let world = CommWorld::<f32>::with_topology(topo);
        Bench::new(format!("reduce_scatter/8rk/{}", backend.label())).run_bytes(bytes, || {
            world.run(move |comm| {
                let input = vec![1.0f32; elems];
                let opts = CollectiveOptions::default().backend(backend);
                let mut total = 0usize;
                for _ in 0..INNER {
                    total += reduce_scatter(comm, &input, &opts).unwrap().len();
                }
                total
            })
        });

        let world = CommWorld::<f32>::with_topology(topo);
        Bench::new(format!("all_reduce/8rk/{}", backend.label())).run_bytes(bytes, || {
            world.run(move |comm| {
                let input = vec![1.0f32; elems];
                let opts = CollectiveOptions::default().backend(backend);
                let mut total = 0usize;
                for _ in 0..INNER {
                    total += all_reduce(comm, &input, &opts).unwrap().len();
                }
                total
            })
        });
    }
}
