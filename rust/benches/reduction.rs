//! Bench: local reduction kernels — native host loop vs the XLA-offloaded
//! L1 Pallas kernel (ablation for the "GPU reductions" design point,
//! Observation 1 / Fig. 4).
//!
//! The XLA benches are skipped if `artifacts/` has not been built.

use pccl::reduction::offload::XlaReducer;
use pccl::reduction::reduce_into;
use pccl::runtime::{Artifacts, DeviceService};
use pccl::util::microbench::{section, Bench};

fn main() {
    section("reduction/native");
    for n in [1 << 12, 1 << 16, 1 << 20] {
        let mut acc = vec![1.0f32; n];
        let src = vec![2.0f32; n];
        Bench::new(format!("native/{n}")).run_bytes((n * 8) as u64, || {
            reduce_into(&mut acc, &src);
        });
    }

    section("reduction/xla-pallas");
    let Ok(arts) = Artifacts::load_default() else {
        eprintln!("skipping reduction/xla: run `make artifacts` first");
        return;
    };
    let Ok(service) = DeviceService::spawn(arts.clone()) else {
        eprintln!("skipping reduction/xla: device service failed");
        return;
    };
    let Ok(Some(reducer)) = XlaReducer::from_artifacts(&arts, service.handle(), 0) else {
        eprintln!("skipping reduction/xla: no reduce_sum artifact");
        return;
    };
    for n in [reducer.chunk(), 4 * reducer.chunk()] {
        let mut acc = vec![1.0f32; n];
        let src = vec![2.0f32; n];
        Bench::new(format!("xla-pallas/{n}")).run_bytes((n * 8) as u64, || {
            reducer.reduce_into(&mut acc, &src).unwrap();
        });
    }
}
