//! Lightweight metrics: wall timers and streaming statistics used by the
//! bench harness and the figure generators.

use std::time::{Duration, Instant};

/// Mean / standard deviation over a set of trial timings — the paper
/// reports mean ± stddev over ten independent trials (§III-A).
#[derive(Debug, Clone)]
pub struct Stats {
    n: usize,
    sum: f64,
    sumsq: f64,
    min: f64,
    max: f64,
}

/// `Default` must match [`Stats::new`]: a derived default would start
/// `min`/`max` at `0.0`, so any stats built via `Default` would report a
/// spurious `0.0` minimum no matter what was pushed (a real
/// measurement-corruption bug — benches feed these numbers to the
/// dispatcher).
impl Default for Stats {
    fn default() -> Self {
        Self::new()
    }
}

impl Stats {
    pub fn new() -> Self {
        Self {
            n: 0,
            sum: 0.0,
            sumsq: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
        }
    }

    pub fn push(&mut self, v: f64) {
        self.n += 1;
        self.sum += v;
        self.sumsq += v * v;
        self.min = self.min.min(v);
        self.max = self.max.max(v);
    }

    pub fn from_iter(vals: impl IntoIterator<Item = f64>) -> Self {
        let mut s = Self::new();
        for v in vals {
            s.push(v);
        }
        s
    }

    pub fn count(&self) -> usize {
        self.n
    }

    pub fn mean(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.sum / self.n as f64
        }
    }

    /// Sample standard deviation.
    pub fn stddev(&self) -> f64 {
        if self.n < 2 {
            return 0.0;
        }
        let m = self.mean();
        ((self.sumsq - self.n as f64 * m * m) / (self.n as f64 - 1.0))
            .max(0.0)
            .sqrt()
    }

    pub fn min(&self) -> f64 {
        self.min
    }

    pub fn max(&self) -> f64 {
        self.max
    }
}

/// Scope timer returning elapsed seconds.
pub struct Timer(Instant);

impl Timer {
    pub fn start() -> Self {
        Timer(Instant::now())
    }

    pub fn elapsed(&self) -> Duration {
        self.0.elapsed()
    }

    pub fn secs(&self) -> f64 {
        self.0.elapsed().as_secs_f64()
    }
}

impl Default for Timer {
    fn default() -> Self {
        Self::start()
    }
}

/// Human-readable byte count (powers of two, like the paper's MB axes).
/// Canonical home of byte formatting; `bench::fmt_bytes` delegates here.
pub fn fmt_bytes(b: u64) -> String {
    const MB: u64 = 1024 * 1024;
    if b >= MB && b % MB == 0 {
        format!("{} MB", b / MB)
    } else if b >= 1024 && b % 1024 == 0 {
        format!("{} KB", b / 1024)
    } else {
        format!("{b} B")
    }
}

/// Throughput in GiB/s for `bytes` moved in `secs` (0 when unmeasurable).
pub fn gib_per_s(bytes: u64, secs: f64) -> f64 {
    if secs <= 0.0 {
        0.0
    } else {
        bytes as f64 / (1u64 << 30) as f64 / secs
    }
}

/// Pretty-print seconds with an adaptive unit (the tables use ms mostly).
pub fn fmt_secs(s: f64) -> String {
    if s >= 1.0 {
        format!("{s:.3} s")
    } else if s >= 1e-3 {
        format!("{:.3} ms", s * 1e3)
    } else {
        format!("{:.1} µs", s * 1e6)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_mean_stddev() {
        let s = Stats::from_iter([1.0, 2.0, 3.0, 4.0]);
        assert_eq!(s.count(), 4);
        assert!((s.mean() - 2.5).abs() < 1e-12);
        assert!((s.stddev() - 1.2909944487358056).abs() < 1e-9);
        assert_eq!(s.min(), 1.0);
        assert_eq!(s.max(), 4.0);
    }

    #[test]
    fn default_stats_track_extrema_like_new() {
        // Regression: the derived Default initialized min/max to 0.0, so a
        // default-built Stats reported min == 0.0 for any positive sample.
        let mut s = Stats::default();
        s.push(5.0);
        assert_eq!(s.min(), 5.0);
        assert_eq!(s.max(), 5.0);
        let mut s = Stats::default();
        s.push(-3.0);
        assert_eq!(s.max(), -3.0, "negative-only samples must not report max 0.0");
        // Empty stats expose the identity extrema, same as Stats::new().
        assert_eq!(Stats::default().min(), f64::INFINITY);
        assert_eq!(Stats::default().max(), f64::NEG_INFINITY);
    }

    #[test]
    fn fmt_units() {
        assert_eq!(fmt_secs(2.5), "2.500 s");
        assert_eq!(fmt_secs(0.0025), "2.500 ms");
        assert_eq!(fmt_secs(2.5e-6), "2.5 µs");
    }

    #[test]
    fn bytes_and_throughput() {
        assert_eq!(fmt_bytes(64 << 20), "64 MB");
        assert_eq!(fmt_bytes(2048), "2 KB");
        assert_eq!(fmt_bytes(100), "100 B");
        assert!((gib_per_s(1 << 30, 2.0) - 0.5).abs() < 1e-12);
        assert_eq!(gib_per_s(1024, 0.0), 0.0);
    }
}
