//! `pccl` — the leader CLI: benchmark the real data plane, regenerate the
//! paper's figures/tables from the netsim, train/inspect the adaptive
//! dispatcher, and run end-to-end DDP / ZeRO-3 training over the AOT
//! artifacts.
//!
//! ```text
//! pccl bench    [--collective all-gather|reduce-scatter|all-reduce]
//!               [--backend vendor|cray-mpich|pccl_ring|pccl_rec|pccl_auto]
//!               [--ranks 8] [--nodes 2] [--size-kb 1024] [--trials 10]
//! pccl figures  <fig1|fig2|fig3|fig4|fig6|fig8|fig9|fig10|fig11|fig12|fig13|table1|all>
//!               [--out results]
//! pccl dispatch [--trials 10] [--save results/models]
//! pccl train    <ddp|zero3> [--ranks 4] [--steps 100] [--lr 0.5]
//!               [--backend pccl_rec] [--artifacts DIR]
//! pccl trace    [--collective C] [--backend B] [--ranks 8] [--nodes 2]
//!               [--size-kb 256] [--lanes 1] [--out trace.json]
//! pccl smoke        [--out BENCH_smoke.json]
//! pccl chaos        [--out BENCH_chaos.json]
//! pccl verify-plans
//! pccl info
//! ```

use std::path::{Path, PathBuf};

use pccl::backends::{Backend, CollKind, CollectiveOptions};
use pccl::bench::figures;
use pccl::bench::Table;
use pccl::comm::CommWorld;
use pccl::dispatch::SvmDispatcher;
use pccl::error::Result;
use pccl::metrics::{fmt_secs, Stats, Timer};
use pccl::topology::{Machine, Topology};
use pccl::train::{ddp::run_ddp, zero3::run_zero3, DdpConfig, Zero3Config};
use pccl::util::cli::Args;

const USAGE: &str = "usage: pccl <bench|figures|dispatch|train|trace|smoke|chaos|verify-plans|info> [options]
  pccl bench        [--collective C] [--backend B] [--ranks N] [--nodes N] [--size-kb K] [--trials T]
  pccl figures      <fig1..fig13|table1|all> [--out DIR]
  pccl dispatch     [--trials T] [--save DIR]
  pccl train        <ddp|zero3> [--ranks N] [--steps S] [--lr F] [--backend B] [--artifacts DIR]
  pccl trace        [--collective C] [--backend B] [--ranks N] [--nodes N] [--size-kb K] [--lanes L]
                    [--out FILE]   (op-level trace of one cell; writes chrome://tracing JSON)
  pccl smoke        [--out FILE]   (quick measured bench of every backend; writes JSON)
  pccl chaos        [--out FILE]   (fault-grid sweep: every cell must complete or abort in bound)
  pccl verify-plans (statically verify every dispatch cell's lowered plan)
  pccl info";

fn parse_collective(s: &str) -> Result<CollKind> {
    CollKind::ALL
        .iter()
        .copied()
        .find(|k| k.label() == s)
        .ok_or_else(|| {
            pccl::error::Error::Dispatch(format!(
                "unknown collective {s:?} (all-gather|reduce-scatter|all-reduce)"
            ))
        })
}

fn parse_backend(s: &str) -> Result<Backend> {
    Backend::CONCRETE
        .iter()
        .copied()
        .chain([Backend::Auto])
        .find(|b| b.label() == s)
        .ok_or_else(|| {
            pccl::error::Error::Dispatch(format!(
                "unknown backend {s:?} (vendor|cray-mpich|pccl_ring|pccl_rec|pccl_auto)"
            ))
        })
}

fn write_table(t: &Table, out: &Path, name: &str) -> Result<()> {
    std::fs::create_dir_all(out)?;
    print!("{}", t.render());
    let path = out.join(format!("{name}.csv"));
    t.write_csv(&path)?;
    println!("→ {}\n", path.display());
    Ok(())
}

fn run_figures(which: &str, out: &Path) -> Result<()> {
    let all = which == "all";
    let mut matched = all;
    if all || which == "fig1" {
        matched = true;
        write_table(&figures::fig1()?, out, "fig1")?;
    }
    if all || which == "fig2" {
        matched = true;
        println!("# Fig 2: message-size distributions");
        println!(
            "{:<8} {:<10} {:>6} {:>12} {:>12} {:>12}",
            "fw", "model", "count", "min", "median", "max"
        );
        let mut csv = String::from("framework,model,count,min_bytes,median_bytes,max_bytes\n");
        for (fw, model, count, min, med, max) in figures::fig2() {
            println!(
                "{:<8} {:<10} {:>6} {:>12} {:>12} {:>12}",
                fw,
                model,
                count,
                pccl::bench::fmt_bytes(min),
                pccl::bench::fmt_bytes(med),
                pccl::bench::fmt_bytes(max)
            );
            csv.push_str(&format!("{fw},{model},{count},{min},{med},{max}\n"));
        }
        std::fs::create_dir_all(out)?;
        std::fs::write(out.join("fig2.csv"), csv)?;
        println!();
    }
    if all || which == "fig3" {
        matched = true;
        let (t, counters) = figures::fig3()?;
        write_table(&t, out, "fig3")?;
        println!("# Fig 3 (middle/right): per-NIC packet counters, 256 MB all-gather, 64 GCDs");
        for (lib, c) in counters {
            println!(
                "{lib:<14} posted={:?} non_posted={:?}",
                c.posted_pkts.iter().map(|v| *v as u64).collect::<Vec<_>>(),
                c.non_posted_pkts
                    .iter()
                    .map(|v| *v as u64)
                    .collect::<Vec<_>>()
            );
        }
        println!();
    }
    if all || which == "fig4" {
        matched = true;
        write_table(&figures::fig4()?, out, "fig4")?;
    }
    if all || which == "fig6" {
        matched = true;
        write_table(&figures::fig6()?, out, "fig6")?;
    }
    if all || which == "fig8" {
        matched = true;
        write_table(&figures::fig8_or_10(Machine::Perlmutter)?, out, "fig8")?;
    }
    if all || which == "fig9" {
        matched = true;
        write_table(&figures::fig9_or_11(Machine::Perlmutter)?, out, "fig9")?;
    }
    if all || which == "fig10" {
        matched = true;
        write_table(&figures::fig8_or_10(Machine::Frontier)?, out, "fig10")?;
    }
    if all || which == "fig11" {
        matched = true;
        write_table(&figures::fig9_or_11(Machine::Frontier)?, out, "fig11")?;
    }
    if all || which == "fig12" {
        matched = true;
        write_table(&figures::fig12()?, out, "fig12")?;
    }
    if all || which == "fig13" {
        matched = true;
        write_table(&figures::fig13()?, out, "fig13")?;
    }
    if all || which == "ablations" {
        matched = true;
        write_table(&figures::ablations()?, out, "ablations")?;
    }
    if all || which == "table1" {
        matched = true;
        print_table1(3, out)?;
    }
    if !matched {
        eprintln!("unknown figure {which:?}");
        eprintln!("{USAGE}");
        std::process::exit(2);
    }
    Ok(())
}

fn print_table1(trials: usize, out: &Path) -> Result<()> {
    println!("# Table I: SVM dispatcher performance on the unseen test set");
    println!(
        "{:<12} {:<16} {:>10} {:>10} {:>10}",
        "machine", "collective", "test size", "correct", "accuracy"
    );
    let mut csv = String::from("machine,collective,test_size,correct,accuracy_pct\n");
    for (machine, coll, size, correct, acc) in figures::table1(trials)? {
        println!("{machine:<12} {coll:<16} {size:>10} {correct:>10} {acc:>9.1}%");
        csv.push_str(&format!("{machine},{coll},{size},{correct},{acc:.1}\n"));
    }
    std::fs::create_dir_all(out)?;
    std::fs::write(out.join("table1.csv"), csv)?;
    println!();
    Ok(())
}

fn run_bench(
    collective: CollKind,
    backend: Backend,
    ranks: usize,
    nodes: usize,
    size_kb: usize,
    trials: usize,
) -> Result<()> {
    let topo = if nodes > 1 && ranks % nodes == 0 {
        Topology::new(nodes, ranks / nodes, 1)?
    } else {
        Topology::flat(ranks)
    };
    let elems = size_kb * 1024 / 4;
    let world = CommWorld::<f32>::with_topology(topo);
    let mut stats = Stats::new();
    for _ in 0..trials {
        let t = Timer::start();
        world.run(move |c| {
            let opts = CollectiveOptions::default().backend(backend);
            match collective {
                CollKind::AllGather => {
                    let input = vec![c.rank() as f32; elems / c.size().max(1)];
                    pccl::backends::all_gather(c, &input, &opts).map(|v| v.len())
                }
                CollKind::ReduceScatter => {
                    let n = elems.div_ceil(c.size()) * c.size();
                    let input = vec![1.0f32; n];
                    pccl::backends::reduce_scatter(c, &input, &opts).map(|v| v.len())
                }
                CollKind::AllReduce => {
                    let input = vec![1.0f32; elems];
                    pccl::backends::all_reduce(c, &input, &opts).map(|v| v.len())
                }
            }
            .expect("collective failed")
        });
        stats.push(t.secs());
    }
    println!(
        "{} / {} on {} ranks ({} nodes), {} KiB/rank: mean {} ± {} over {} trials",
        collective.label(),
        backend.label(),
        ranks,
        nodes,
        size_kb,
        fmt_secs(stats.mean()),
        fmt_secs(stats.stddev()),
        trials
    );
    Ok(())
}

/// Trace one (collective, backend, topology, size, lanes) cell: run it
/// once with the op-level tracer installed on every rank, check the
/// recorded spans against the verified plan's phase shapes, print the
/// per-phase observed-vs-predicted timing summary, and write a
/// chrome://tracing JSON document (load it at chrome://tracing or in
/// Perfetto: one process per cell, one thread track per rank).
fn run_trace(
    collective: CollKind,
    backend: Backend,
    ranks: usize,
    nodes: usize,
    size_kb: usize,
    lanes: usize,
    out: &Path,
) -> Result<()> {
    use pccl::runtime::{Launcher, LauncherConfig};

    if backend == Backend::Auto {
        return Err(pccl::error::Error::Dispatch(
            "pccl trace needs a concrete backend (the auto dispatcher picks one per call): \
             use vendor|cray-mpich|pccl_ring|pccl_rec"
                .into(),
        ));
    }
    let topo = if nodes > 1 && ranks % nodes == 0 {
        Topology::new(nodes, ranks / nodes, 1)?
    } else {
        Topology::flat(ranks)
    };
    let elems = (size_kb * 1024 / 4).max(1);
    let lanes = lanes.max(1);
    let launcher = Launcher::new(LauncherConfig {
        topologies: vec![topo],
        elem_counts: vec![elems],
        trials: 1,
        inner_iters: 1,
        warmup_iters: 1,
        persistent: false,
        lane_counts: vec![lanes],
    });
    let cell = launcher.time_cell_lanes(topo, collective, backend, elems, lanes)?;
    let trace = cell
        .trace
        .as_ref()
        .expect("concrete backends always attach a trace");
    println!(
        "{} / {} on {} ranks ({} nodes), {} KiB/rank, {} lane(s): \
         traced ops match the verified plan",
        collective.label(),
        backend.label(),
        ranks,
        nodes,
        size_kb,
        lanes
    );
    print!("{}", pccl::trace::format_summary(trace, &cell.predicted_phase_s));
    let label = format!(
        "{}/{} {}B p{} l{}",
        collective.label(),
        backend.label(),
        cell.msg_bytes,
        cell.ranks,
        cell.lanes
    );
    let doc = pccl::trace::chrome_trace_doc(&[(label, trace)]);
    if let Some(parent) = out.parent() {
        if !parent.as_os_str().is_empty() {
            std::fs::create_dir_all(parent)?;
        }
    }
    std::fs::write(out, doc.to_string())?;
    println!("chrome trace → {} (open at chrome://tracing)", out.display());
    Ok(())
}

/// Quick measured bench of the real data plane (few sizes, few reps):
/// every backend × collective over two small topologies, run in *both*
/// launcher modes. The persistent-world pass is what lands in the JSON
/// artifact (lower noise); the spawn pass doubles as the
/// schedule-equivalence guard — the zero-copy chunked plane must move
/// exactly the same bytes in either mode for **every** collective
/// (all-gather, reduce-scatter, and all-reduce cells are each required to
/// be present, so the reduce path cannot silently drop out of the guard),
/// and the flat-library cells must match the closed-form schedule volume.
///
/// A third pass sweeps transport lanes ∈ {1, 4} at 8 ranks
/// ([`LauncherConfig::lanes_smoke`]): the cross-lane guard fails the run
/// if striping changes a configuration's byte total or result checksum,
/// and the lanes=4 vs lanes=1 wall-clock ratio on the striped PCCL paths
/// is printed for the large size.
/// Statically verify the lowered plan of every dispatch cell the smoke
/// and lane sweeps will time: for each backend × collective × topology ×
/// size × lane count, build all `p` per-rank plans, simulate them in
/// lockstep (deadlock-freedom, exactly-once block coverage), and check
/// the total element volume against the closed-form schedule bytes where
/// one exists. Prints the verified-cell count.
fn run_verify_plans() -> Result<()> {
    use pccl::runtime::{verify_plan_grid, LauncherConfig};
    let t = Timer::start();
    let smoke_cells = verify_plan_grid(&LauncherConfig::smoke())?;
    let lane_cells = verify_plan_grid(&LauncherConfig::lanes_smoke())?;
    println!(
        "verify-plans: {} smoke-grid + {} lane-grid cells verified in {}",
        smoke_cells,
        lane_cells,
        fmt_secs(t.secs())
    );
    Ok(())
}

fn run_smoke(out: &Path) -> Result<()> {
    use pccl::runtime::{expected_schedule_bytes, verify_plan_grid, Launcher, LauncherConfig};
    use pccl::util::json::Value;

    // Preamble: no schedule is timed before its lowered plan has been
    // statically verified — deadlock-free, exactly-once block coverage,
    // byte-exact against the closed-form volumes.
    let verified =
        verify_plan_grid(&LauncherConfig::smoke())? + verify_plan_grid(&LauncherConfig::lanes_smoke())?;
    println!("verify-plans preamble: {verified} cells verified");

    let t = Timer::start();
    let spawn_sweep = Launcher::new(LauncherConfig::smoke()).sweep()?;
    let guard_wall = t.secs();
    let t = Timer::start();
    let sweep = Launcher::new(LauncherConfig::smoke().with_persistent(true)).sweep()?;
    // wall_s covers only the persistent pass the artifact describes; the
    // spawn-mode guard pass is reported separately as guard_wall_s.
    let wall = t.secs();

    // Schedule-equivalence guard: bytes are schedule-determined, so the
    // persistent world must report exactly what the per-trial worlds did.
    if spawn_sweep.cells.len() != sweep.cells.len() {
        return Err(pccl::error::Error::Dispatch(format!(
            "smoke sweeps diverged: {} spawn cells vs {} persistent",
            spawn_sweep.cells.len(),
            sweep.cells.len()
        )));
    }
    // Coverage check first: every collective kind must be in the guarded
    // set with real traffic — a sweep that stopped emitting reduce-scatter
    // or all-reduce cells would otherwise pass the guard vacuously.
    for kind in CollKind::ALL {
        let guarded = sweep
            .cells
            .iter()
            .filter(|c| c.kind == kind && c.bytes_per_op > 0)
            .count();
        if guarded == 0 {
            return Err(pccl::error::Error::Dispatch(format!(
                "smoke sweep has no {} cells with traffic — the byte guard no \
                 longer covers that collective",
                kind.label()
            )));
        }
    }
    for (a, b) in spawn_sweep.cells.iter().zip(&sweep.cells) {
        if a.kind != b.kind || a.backend != b.backend || a.msg_bytes != b.msg_bytes {
            return Err(pccl::error::Error::Dispatch(format!(
                "smoke sweeps diverged: spawn cell {}/{} vs persistent {}/{}",
                a.kind.label(),
                a.backend.label(),
                b.kind.label(),
                b.backend.label()
            )));
        }
        if a.bytes_per_op != b.bytes_per_op {
            return Err(pccl::error::Error::Dispatch(format!(
                "schedule equivalence violated: {}/{} {} B × {} ranks moved {} B \
                 per op in spawn mode but {} B in persistent mode",
                a.kind.label(),
                a.backend.label(),
                a.msg_bytes,
                a.ranks,
                a.bytes_per_op,
                b.bytes_per_op
            )));
        }
    }
    // Posted-receive guard: the whole reduce path (reduce-scatter and
    // all-reduce, every backend) must deliver by reference handover or
    // combine-write only — a single copied byte means a staging copy crept
    // back into the data plane. Checked in both launcher modes.
    for c in spawn_sweep.cells.iter().chain(&sweep.cells) {
        if matches!(c.kind, CollKind::ReduceScatter | CollKind::AllReduce)
            && c.copied_bytes_per_op != 0
        {
            return Err(pccl::error::Error::Dispatch(format!(
                "reduce path is no longer copy-free: {}/{} {} B × {} ranks \
                 copied {} B per op on delivery",
                c.kind.label(),
                c.backend.label(),
                c.msg_bytes,
                c.ranks,
                c.copied_bytes_per_op
            )));
        }
    }
    // Flat-library cells must also match the closed-form schedule volume
    // (ring all-gather / reduce-scatter, and the ring all-reduce
    // composition on the Cray-MPICH backend).
    for c in &sweep.cells {
        // Invert the §III-A shape convention: msg_bytes / 4 reproduces the
        // element count `cell_shape` saw for every collective.
        let elems = c.msg_bytes / 4;
        if let Some(expect) = expected_schedule_bytes(c.kind, c.backend, elems, c.ranks) {
            if c.bytes_per_op != expect {
                return Err(pccl::error::Error::Dispatch(format!(
                    "ring schedule volume mismatch: {}/{} expected {expect} B, measured {} B",
                    c.kind.label(),
                    c.backend.label(),
                    c.bytes_per_op
                )));
            }
        }
    }

    // Lane sweep: lanes ∈ {1, 4} at 8 ranks on persistent worlds. The
    // cross-lane guard fails the whole smoke run on byte-total or result
    // divergence between lane counts of the same configuration.
    let t = Timer::start();
    let lane_sweep = Launcher::new(LauncherConfig::lanes_smoke()).sweep()?;
    let lanes_wall = t.secs();
    lane_sweep.check_lane_equivalence()?;
    for c in &lane_sweep.cells {
        if matches!(c.kind, CollKind::ReduceScatter | CollKind::AllReduce)
            && c.copied_bytes_per_op != 0
        {
            return Err(pccl::error::Error::Dispatch(format!(
                "reduce path is no longer copy-free at lanes={}: {}/{} {} B × {} ranks \
                 copied {} B per op on delivery",
                c.lanes,
                c.kind.label(),
                c.backend.label(),
                c.msg_bytes,
                c.ranks,
                c.copied_bytes_per_op
            )));
        }
    }
    // Lane win report (informational — wall clock on shared CI boxes is
    // too noisy for a hard assert): striped PCCL ring at the large size.
    let max_msg = lane_sweep.cells.iter().map(|c| c.msg_bytes).max().unwrap_or(0);
    for kind in [CollKind::ReduceScatter, CollKind::AllReduce] {
        let cell_at = |lanes: usize| {
            lane_sweep.cells.iter().find(|c| {
                c.kind == kind
                    && c.backend == pccl::backends::Backend::PcclRing
                    && c.msg_bytes == max_msg
                    && c.lanes == lanes
            })
        };
        if let (Some(one), Some(four)) = (cell_at(1), cell_at(4)) {
            println!(
                "lanes: {} pccl_ring {} B × {} ranks: lanes=1 {} vs lanes=4 {} ({:.2}x)",
                kind.label(),
                max_msg,
                one.ranks,
                fmt_secs(one.stats.mean()),
                fmt_secs(four.stats.mean()),
                one.stats.mean() / four.stats.mean().max(1e-12)
            );
        }
    }

    let cell_json = |c: &pccl::runtime::MeasuredCell| -> Result<Value> {
        // Per-phase observed (traced busy time) next to the netsim's
        // predicted cost of the same `phase_shapes` — the schema-6 field.
        // Timings must be real numbers: a NaN here means a broken clock,
        // and `finite_num` fails the smoke run instead of null-encoding.
        let phases = match &c.trace {
            None => Vec::new(), // Backend::Auto resolves per call — untraced
            Some(t) => t
                .phases
                .iter()
                .enumerate()
                .map(|(i, ph)| {
                    Ok(Value::obj(vec![
                        ("scope", Value::Str(ph.scope.to_string())),
                        ("rounds", Value::Num(ph.rounds as f64)),
                        ("ops", Value::Num(ph.ops as f64)),
                        ("sent_bytes", Value::Num(ph.sent_bytes as f64)),
                        ("combine_bytes", Value::Num(ph.combine_bytes as f64)),
                        ("observed_s", Value::finite_num(ph.busy_s)?),
                        (
                            "predicted_s",
                            match c.predicted_phase_s.get(i) {
                                Some(&p) => Value::finite_num(p)?,
                                None => Value::Null,
                            },
                        ),
                    ]))
                })
                .collect::<Result<Vec<_>>>()?,
        };
        Ok(Value::obj(vec![
            ("collective", Value::Str(c.kind.label().to_string())),
            ("backend", Value::Str(c.backend.label().to_string())),
            ("msg_bytes", Value::Num(c.msg_bytes as f64)),
            ("ranks", Value::Num(c.ranks as f64)),
            ("lanes", Value::Num(c.lanes as f64)),
            ("mean_s", Value::Num(c.stats.mean())),
            ("stddev_s", Value::Num(c.stats.stddev())),
            ("trials", Value::Num(c.stats.count() as f64)),
            ("bytes_per_op", Value::Num(c.bytes_per_op as f64)),
            ("copied_bytes", Value::Num(c.copied_bytes_per_op as f64)),
            (
                "moved_bytes_per_lane",
                Value::arr_usize(
                    &c.moved_bytes_per_lane.iter().map(|&b| b as usize).collect::<Vec<_>>(),
                ),
            ),
            ("phases", Value::Arr(phases)),
        ]))
    };
    let cells: Vec<Value> = sweep
        .cells
        .iter()
        .chain(&lane_sweep.cells)
        .map(cell_json)
        .collect::<Result<_>>()?;

    // Chrome-trace export of every traced cell (both sweeps), written next
    // to the bench record. Every traced trial already passed the
    // observed-vs-plan op-count guard inside the launcher.
    let trace_path = out.with_extension("trace.json");
    let labeled: Vec<(String, &pccl::trace::CellTrace)> = sweep
        .cells
        .iter()
        .chain(&lane_sweep.cells)
        .filter_map(|c| {
            c.trace.as_ref().map(|t| {
                (
                    format!(
                        "{}/{} {}B p{} l{}",
                        c.kind.label(),
                        c.backend.label(),
                        c.msg_bytes,
                        c.ranks,
                        c.lanes
                    ),
                    t,
                )
            })
        })
        .collect();
    let trace_doc = pccl::trace::chrome_trace_doc(&labeled);

    let doc = Value::obj(vec![
        ("schema", Value::Num(6.0)),
        ("suite", Value::Str("pccl-smoke".to_string())),
        ("mode", Value::Str("persistent".to_string())),
        ("schedule_equivalent", Value::Bool(true)),
        // The posted-receive guard above: every reduce-scatter and
        // all-reduce cell delivered with copied_bytes == 0.
        ("reduce_copy_free", Value::Bool(true)),
        // Which collectives the spawn-vs-persistent byte guard covered —
        // CI fails above if any of the three is missing.
        (
            "guarded_collectives",
            Value::Arr(
                CollKind::ALL
                    .iter()
                    .map(|k| Value::Str(k.label().to_string()))
                    .collect(),
            ),
        ),
        // The lane sweep's cross-lane guard: byte totals and checksums
        // matched across lane counts for every configuration.
        ("lane_equivalent", Value::Bool(true)),
        ("wall_s", Value::Num(wall)),
        ("guard_wall_s", Value::Num(guard_wall)),
        ("lanes_wall_s", Value::Num(lanes_wall)),
        (
            "trace_file",
            Value::Str(trace_path.file_name().and_then(|n| n.to_str()).unwrap_or("").to_string()),
        ),
        ("cells", Value::Arr(cells)),
    ]);
    if let Some(parent) = out.parent() {
        if !parent.as_os_str().is_empty() {
            std::fs::create_dir_all(parent)?;
        }
    }
    std::fs::write(out, doc.to_string())?;
    std::fs::write(&trace_path, trace_doc.to_string())?;
    for c in sweep.cells.iter().chain(&lane_sweep.cells) {
        println!(
            "{:<16} {:<12} {:>10} B {:>4} ranks {:>2} lanes  {:>12}  {:>8.2} GiB/s moved",
            c.kind.label(),
            c.backend.label(),
            c.msg_bytes,
            c.ranks,
            c.lanes,
            fmt_secs(c.stats.mean()),
            pccl::metrics::gib_per_s(c.bytes_per_op, c.stats.mean())
        );
    }
    println!(
        "{} cells in {:.1}s + lane sweep {} cells in {:.1}s \
         (schedule-equivalence, cross-lane, and traced-op guards OK) → {} \
         (op trace → {})",
        sweep.cells.len(),
        wall,
        lane_sweep.cells.len(),
        lanes_wall,
        out.display(),
        trace_path.display()
    );
    Ok(())
}

/// Sweep the fault grid (see [`pccl::runtime::run_chaos`]): every fault ×
/// backend cell must either complete with the reference checksum or
/// return the typed collective abort within the detection bound, the
/// persistent world must stay usable after every abort, a shrunk
/// survivor world must complete a correct collective, and no lane-worker
/// thread may outlive its world. The per-cell record (with each cell's
/// replayable fault plan) is written as JSON before pass/fail is decided,
/// so CI uploads the evidence either way.
fn run_chaos_cmd(out: &Path) -> Result<()> {
    use pccl::runtime::{run_chaos, ChaosConfig};

    let cfg = ChaosConfig::default();
    let t = Timer::start();
    let report = run_chaos(&cfg)?;
    let wall = t.secs();
    println!(
        "{:<14} {:<12} {:<16} {:>10} {:>9}  detail",
        "fault", "backend", "collective", "outcome", "detect"
    );
    for c in &report.cells {
        println!(
            "{:<14} {:<12} {:<16} {:>10} {:>9}  {}",
            c.fault,
            c.backend.label(),
            c.kind.label(),
            c.outcome.label(),
            fmt_secs(c.detect_s),
            c.detail
        );
    }
    println!(
        "shrink-after-rank-death: {} in {} {}",
        if report.shrink_passed { "ok" } else { "FAILED" },
        fmt_secs(report.shrink_wall_s),
        report.shrink_detail
    );
    if let Some((before, after)) = report.threads {
        println!("threads: {before} before, {after} after teardown");
    }
    let doc = report.to_value(&cfg);
    if let Some(parent) = out.parent() {
        if !parent.as_os_str().is_empty() {
            std::fs::create_dir_all(parent)?;
        }
    }
    std::fs::write(out, doc.to_string())?;
    println!(
        "chaos: {} cells + shrink in {:.1}s → {}",
        report.cells.len(),
        wall,
        out.display()
    );
    report.ensure_passed()
}

fn main() -> Result<()> {
    let args = match Args::from_env() {
        Ok(a) => a,
        Err(e) => {
            eprintln!("{e}\n{USAGE}");
            std::process::exit(2);
        }
    };
    let Some(cmd) = args.positional.first().map(String::as_str) else {
        eprintln!("{USAGE}");
        std::process::exit(2);
    };
    match cmd {
        "bench" => {
            let collective = parse_collective(args.get("collective").unwrap_or("all-gather"))?;
            let backend = parse_backend(args.get("backend").unwrap_or("pccl_rec"))?;
            run_bench(
                collective,
                backend,
                args.get_parse("ranks", 8usize).unwrap(),
                args.get_parse("nodes", 2usize).unwrap(),
                args.get_parse("size-kb", 1024usize).unwrap(),
                args.get_parse("trials", 10usize).unwrap(),
            )?;
        }
        "figures" => {
            let which = args.positional.get(1).cloned().unwrap_or_else(|| {
                eprintln!("figures: missing figure id\n{USAGE}");
                std::process::exit(2);
            });
            let out = PathBuf::from(args.get("out").unwrap_or("results"));
            run_figures(&which, &out)?;
        }
        "dispatch" => {
            let trials = args.get_parse("trials", 10usize).unwrap();
            print_table1(trials, &PathBuf::from("results"))?;
            if let Some(dir) = args.get("save") {
                let dir = PathBuf::from(dir);
                std::fs::create_dir_all(&dir)?;
                for machine in [Machine::Frontier, Machine::Perlmutter] {
                    let d = figures::trained_dispatcher(machine)?;
                    let p = dir.join(format!("dispatcher-{}.json", machine.params().name));
                    d.save(&p)?;
                    println!("saved {}", p.display());
                }
                // Round-trip sanity.
                let _ = SvmDispatcher::load(dir.join("dispatcher-frontier.json"))?;
            }
        }
        "train" => {
            let mode = args.positional.get(1).map(String::as_str).unwrap_or("");
            let ranks = args.get_parse("ranks", 4usize).unwrap();
            let steps = args.get_parse("steps", 100usize).unwrap();
            let lr = args.get_parse("lr", 0.5f32).unwrap();
            let backend = parse_backend(args.get("backend").unwrap_or("pccl_rec"))?;
            let artifacts = args.get("artifacts").map(str::to_string);
            match mode {
                "ddp" => {
                    let report = run_ddp(&DdpConfig {
                        ranks,
                        steps,
                        lr,
                        backend,
                        artifacts,
                        ..Default::default()
                    })?;
                    println!(
                        "DDP: {} params, {} steps: loss {:.4} → {:.4}",
                        report.param_count,
                        steps,
                        report.initial_loss(),
                        report.final_loss()
                    );
                }
                "zero3" => {
                    let report = run_zero3(&Zero3Config {
                        ranks,
                        steps,
                        lr,
                        backend,
                        artifacts,
                        ..Default::default()
                    })?;
                    println!(
                        "ZeRO-3: {} params ({} elems/shard), {} steps: final loss {:.4}",
                        report.param_count,
                        report.shard_elems,
                        steps,
                        report.final_loss()
                    );
                }
                other => {
                    eprintln!("unknown train mode {other:?} (use ddp|zero3)\n{USAGE}");
                    std::process::exit(2);
                }
            }
        }
        "trace" => {
            let collective = parse_collective(args.get("collective").unwrap_or("all-reduce"))?;
            let backend = parse_backend(args.get("backend").unwrap_or("pccl_ring"))?;
            let out = PathBuf::from(args.get("out").unwrap_or("trace.json"));
            run_trace(
                collective,
                backend,
                args.get_parse("ranks", 8usize).unwrap(),
                args.get_parse("nodes", 2usize).unwrap(),
                args.get_parse("size-kb", 256usize).unwrap(),
                args.get_parse("lanes", 1usize).unwrap(),
                &out,
            )?;
        }
        "smoke" => {
            let out = PathBuf::from(args.get("out").unwrap_or("BENCH_smoke.json"));
            run_smoke(&out)?;
        }
        "chaos" => {
            let out = PathBuf::from(args.get("out").unwrap_or("BENCH_chaos.json"));
            run_chaos_cmd(&out)?;
        }
        "verify-plans" => {
            run_verify_plans()?;
        }
        "info" => {
            for m in [Machine::Frontier, Machine::Perlmutter] {
                let p = m.params();
                println!(
                    "{:<12} {} GPUs/node, {} NICs/node @ {:.0} GB/s, vendor={}",
                    p.name,
                    p.gpus_per_node,
                    p.nics_per_node,
                    p.nic_bw / 1e9,
                    m.vendor_name()
                );
            }
        }
        other => {
            eprintln!("unknown command {other:?}\n{USAGE}");
            std::process::exit(2);
        }
    }
    Ok(())
}
