//! Dispatcher training data (§IV-C): for each (message size, GPU count)
//! configuration, run every candidate backend ten times on the netsim and
//! label the configuration with the fastest backend's class id.

use crate::backends::{Backend, CollKind};
use crate::error::{Error, Result};
use crate::netsim::libmodel::{simulate_lanes, LibModel};
use crate::topology::Machine;
use crate::util::rng::Rng;

/// One labeled configuration.
#[derive(Debug, Clone)]
pub struct Sample {
    /// Features: `[log2(message MiB), log2(ranks), log2(lanes),
    /// collective_id]` — the paper's two dominant factors, the
    /// transport-lane count (the striped PCCL paths shift the regime
    /// crossover), and the collective's stable id
    /// ([`CollKind::collective_id`]). The id is constant within one
    /// per-collective model (the scaler zeroes it out there) but keeps
    /// feature vectors self-describing and lets pooled datasets train a
    /// single cross-collective model.
    pub features: Vec<f64>,
    /// Class id = index into [`Backend::CONCRETE`].
    pub label: usize,
    /// Message bytes (for reporting).
    pub msg: usize,
    /// Rank count (for reporting).
    pub ranks: usize,
    /// Transport-lane count of the configuration.
    pub lanes: usize,
}

/// A labeled dataset for one (machine, collective).
#[derive(Debug, Clone, Default)]
pub struct Dataset {
    pub samples: Vec<Sample>,
}

/// Dispatcher feature vector for a call site.
pub fn features(kind: CollKind, msg_bytes: usize, ranks: usize, lanes: usize) -> Vec<f64> {
    let mb = (msg_bytes as f64 / (1024.0 * 1024.0)).max(1e-6);
    vec![
        mb.log2(),
        (ranks as f64).log2(),
        (lanes.max(1) as f64).log2(),
        kind.collective_id() as f64,
    ]
}

impl Dataset {
    /// Build the dataset by sweeping the netsim: `trials` runs per
    /// (backend, size, ranks, lanes); label = argmin of mean time. The
    /// lane sweep covers the single-lane baseline and the machine's full
    /// rail count (one lane per NIC).
    pub fn build(
        machine: Machine,
        kind: CollKind,
        sizes_mb: &[usize],
        ranks: &[usize],
        trials: usize,
        seed: u64,
    ) -> Result<Self> {
        let nics = machine.params().nics_per_node;
        let lane_counts: &[usize] = if nics > 1 { &[1, nics][..] } else { &[1][..] };
        let mut samples = Vec::new();
        for &mb in sizes_mb {
            let msg = mb << 20;
            for &p in ranks {
                for &lanes in lane_counts {
                    let mut best: Option<(f64, usize)> = None;
                    for (class, backend) in Backend::CONCRETE.iter().enumerate() {
                        let lib = LibModel::from_backend(*backend).expect("concrete backend");
                        let out = simulate_lanes(machine, lib, kind, msg, p, lanes, trials, seed)?;
                        let mean = out.stats.mean();
                        if best.map_or(true, |(b, _)| mean < b) {
                            best = Some((mean, class));
                        }
                    }
                    samples.push(Sample {
                        features: features(kind, msg, p, lanes),
                        label: best.expect("non-empty backends").1,
                        msg,
                        ranks: p,
                        lanes,
                    });
                }
            }
        }
        Ok(Self { samples })
    }

    /// Label one configuration from *measured* per-backend mean times —
    /// the data-plane twin of the netsim sweep in [`Dataset::build`]. The
    /// label is the argmin backend's class id.
    pub fn push_measured(
        &mut self,
        kind: CollKind,
        msg: usize,
        ranks: usize,
        lanes: usize,
        times: &[(Backend, f64)],
    ) -> Result<()> {
        let mut best: Option<(f64, usize)> = None;
        for &(backend, t) in times {
            let class = backend.class_id().ok_or_else(|| {
                Error::Dispatch(format!("backend {backend:?} is not dispatchable"))
            })?;
            if best.map_or(true, |(b, _)| t < b) {
                best = Some((t, class));
            }
        }
        let Some((_, label)) = best else {
            return Err(Error::Dispatch(format!(
                "no measurements for configuration msg={msg} ranks={ranks} lanes={lanes}"
            )));
        };
        self.samples.push(Sample {
            features: features(kind, msg, ranks, lanes),
            label,
            msg,
            ranks,
            lanes,
        });
        Ok(())
    }

    pub fn len(&self) -> usize {
        self.samples.len()
    }

    pub fn is_empty(&self) -> bool {
        self.samples.is_empty()
    }

    /// Feature matrix / label vector views.
    pub fn xy(&self) -> (Vec<Vec<f64>>, Vec<usize>) {
        (
            self.samples.iter().map(|s| s.features.clone()).collect(),
            self.samples.iter().map(|s| s.label).collect(),
        )
    }

    /// Stratified train/test split (the paper's 80/20): each class
    /// contributes proportionally to the test set.
    pub fn stratified_split(&self, test_frac: f64, seed: u64) -> (Dataset, Dataset) {
        let mut rng = Rng::seed_from_u64(seed);
        let mut by_class: std::collections::BTreeMap<usize, Vec<&Sample>> = Default::default();
        for s in &self.samples {
            by_class.entry(s.label).or_default().push(s);
        }
        let mut train = Dataset::default();
        let mut test = Dataset::default();
        for (_, mut group) in by_class {
            rng.shuffle(&mut group);
            let n_test = ((group.len() as f64 * test_frac).round() as usize).min(group.len());
            for (i, s) in group.into_iter().enumerate() {
                if i < n_test {
                    test.samples.push(s.clone());
                } else {
                    train.samples.push(s.clone());
                }
            }
        }
        (train, test)
    }

    /// Class histogram (for stratification checks and Table I context).
    pub fn class_counts(&self) -> std::collections::BTreeMap<usize, usize> {
        let mut m = std::collections::BTreeMap::new();
        for s in &self.samples {
            *m.entry(s.label).or_insert(0) += 1;
        }
        m
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn build_labels_regimes_correctly() {
        // Latency-bound corner must prefer a PCCL backend; bandwidth-bound
        // corner must prefer the vendor library (Fig. 9/11 structure).
        let d = Dataset::build(
            Machine::Frontier,
            CollKind::AllGather,
            &[16, 1024],
            &[32, 2048],
            3,
            1,
        )
        .unwrap();
        // 2 sizes × 2 rank counts × 2 lane counts (Frontier has 4 NICs).
        assert_eq!(d.len(), 8);
        assert!(d.samples.iter().all(|s| s.lanes == 1 || s.lanes == 4));
        let find = |msg_mb: usize, p: usize, lanes: usize| {
            d.samples
                .iter()
                .find(|s| s.msg == msg_mb << 20 && s.ranks == p && s.lanes == lanes)
                .unwrap()
                .label
        };
        let vendor = Backend::Vendor.class_id().unwrap();
        let rec = Backend::PcclRec.class_id().unwrap();
        assert_eq!(find(1024, 32, 1), vendor, "bandwidth-bound corner");
        assert_eq!(find(16, 2048, 1), rec, "latency-bound corner");
    }

    #[test]
    fn stratified_split_is_stratified() {
        let mut d = Dataset::default();
        for i in 0..50 {
            d.samples.push(Sample {
                features: vec![i as f64, 0.0],
                label: i % 2,
                msg: 1,
                ranks: 1,
                lanes: 1,
            });
        }
        let (train, test) = d.stratified_split(0.2, 7);
        assert_eq!(train.len() + test.len(), 50);
        assert_eq!(test.len(), 10);
        let counts = test.class_counts();
        assert_eq!(counts[&0], 5);
        assert_eq!(counts[&1], 5);
    }

    #[test]
    fn push_measured_labels_argmin() {
        let mut d = Dataset::default();
        d.push_measured(
            CollKind::AllReduce,
            64 << 20,
            128,
            4,
            &[
                (Backend::Vendor, 3.0e-3),
                (Backend::CrayMpich, 9.0e-3),
                (Backend::PcclRing, 2.5e-3),
                (Backend::PcclRec, 2.0e-3),
            ],
        )
        .unwrap();
        assert_eq!(d.samples[0].label, Backend::PcclRec.class_id().unwrap());
        assert_eq!(d.samples[0].msg, 64 << 20);
        assert_eq!(d.samples[0].lanes, 4);
        assert!(d.push_measured(CollKind::AllGather, 1, 1, 1, &[]).is_err());
        assert!(d
            .push_measured(CollKind::AllGather, 1, 1, 1, &[(Backend::Auto, 1.0)])
            .is_err());
    }

    #[test]
    fn features_are_log_scaled_and_kind_tagged() {
        let f = features(CollKind::AllReduce, 64 << 20, 1024, 4);
        assert_eq!(f.len(), 4);
        assert!((f[0] - 6.0).abs() < 1e-9);
        assert!((f[1] - 10.0).abs() < 1e-9);
        assert!((f[2] - 2.0).abs() < 1e-9);
        assert_eq!(f[3], CollKind::AllReduce.collective_id() as f64);
        // lanes = 0 is treated as single-lane, not -inf.
        assert_eq!(features(CollKind::AllGather, 1 << 20, 2, 0)[2], 0.0);
        // The collective id distinguishes kinds at identical shapes.
        assert_ne!(
            features(CollKind::AllGather, 1 << 20, 2, 1),
            features(CollKind::ReduceScatter, 1 << 20, 2, 1)
        );
    }
}
