//! Learning-based adaptive dispatching (§IV-C): a from-scratch SVM (SMO)
//! trained per (machine, collective) on sweep data to pick the fastest
//! backend at runtime.

pub mod dataset;
pub mod dispatcher;
pub mod svm;

pub use dataset::{Dataset, Sample};
pub use dispatcher::{DispatcherModel, SvmDispatcher};
pub use svm::{KernelKind, MultiClassSvm, SvmParams};
