//! The adaptive dispatcher: per-(machine, collective) SVMs that map
//! `(message size, rank count, lane count, collective)` to the fastest
//! backend at runtime (§IV-C, extended with the transport-lane and
//! collective-id features).

use std::collections::BTreeMap;
use std::path::Path;
use std::sync::Arc;

use crate::backends::{Backend, Chooser, CollKind};
use crate::error::{Error, Result};
use crate::topology::Machine;
use crate::util::json::Value;

use super::dataset::{features, Dataset};
use super::svm::{train_with_cv, MultiClassSvm, Scaler, SvmParams};

/// Persisted dispatcher payload schema. Schema 1 (implicit — the field was
/// absent) carried 2-feature `(size, ranks)` models; schema 2 added the
/// transport-lane feature; schema 3 appends the collective-id feature
/// ([`crate::backends::CollKind::collective_id`]). Loading an older payload
/// into this build would feed the SVM a short feature vector, so any
/// mismatched schema — including a well-formed schema-2 payload — is
/// refused with [`Error::ArtifactSchema`] instead.
pub const DISPATCHER_SCHEMA: u32 = 3;

/// One trained collective model + its evaluation record (a Table-I row).
#[derive(Debug, Clone)]
pub struct DispatcherModel {
    pub scaler: Scaler,
    pub svm: MultiClassSvm,
    pub params: SvmParams,
    /// 5-fold CV accuracy on the training split.
    pub cv_accuracy: f64,
    /// Held-out test accuracy (the paper's Table I column).
    pub test_accuracy: f64,
    pub test_size: usize,
    pub test_correct: usize,
    pub train_size: usize,
}

impl DispatcherModel {
    /// Fit one collective model on a labeled dataset, whatever produced it
    /// (netsim sweep or measured data-plane sweep): stratified 80/20
    /// split, k-fold CV hyperparameter selection with `k = min(5, train)`.
    pub fn fit(data: &Dataset, seed: u64) -> Result<Self> {
        let (train, test) = data.stratified_split(0.2, seed ^ 0xA5A5);
        let (txs_raw, tys) = train.xy();
        if tys.len() < 2 {
            return Err(Error::Dispatch(format!(
                "need ≥ 2 training samples to fit a dispatcher model, got {}",
                tys.len()
            )));
        }
        let scaler = Scaler::fit(&txs_raw);
        let txs = scaler.transform_all(&txs_raw);
        let k = tys.len().min(5);
        let (svm, params, cv_accuracy) = train_with_cv(&txs, &tys, k, seed)?;
        let (vxs_raw, vys) = test.xy();
        let vxs = scaler.transform_all(&vxs_raw);
        let test_correct = vxs
            .iter()
            .zip(&vys)
            .filter(|(x, &y)| svm.predict(x) == y)
            .count();
        // Small (measured) datasets can stratify into an empty test set;
        // report the CV estimate then instead of a misleading 0% — a
        // consumer can tell the difference via `test_size == 0`.
        let test_accuracy = if vys.is_empty() {
            cv_accuracy
        } else {
            test_correct as f64 / vys.len() as f64
        };
        Ok(DispatcherModel {
            scaler,
            svm,
            params,
            cv_accuracy,
            test_accuracy,
            test_size: vys.len(),
            test_correct,
            train_size: tys.len(),
        })
    }

    /// Predicted backend for a raw (message bytes, rank count) call site
    /// on the single-lane transport.
    pub fn predict(&self, kind: CollKind, msg_bytes: usize, ranks: usize) -> Backend {
        self.predict_lanes(kind, msg_bytes, ranks, 1)
    }

    /// Predicted backend for a lane-striped call site.
    pub fn predict_lanes(
        &self,
        kind: CollKind,
        msg_bytes: usize,
        ranks: usize,
        lanes: usize,
    ) -> Backend {
        let x = self.scaler.transform(&features(kind, msg_bytes, ranks, lanes));
        Backend::CONCRETE[self.svm.predict(&x).min(Backend::CONCRETE.len() - 1)]
    }

    /// Serialize for persistence (the dispatcher artifact format).
    pub fn to_json(&self) -> Value {
        Value::obj(vec![
            ("scaler", self.scaler.to_json()),
            ("svm", self.svm.to_json()),
            ("params", self.params.to_json()),
            ("cv_accuracy", Value::Num(self.cv_accuracy)),
            ("test_accuracy", Value::Num(self.test_accuracy)),
            ("test_size", Value::Num(self.test_size as f64)),
            ("test_correct", Value::Num(self.test_correct as f64)),
            ("train_size", Value::Num(self.train_size as f64)),
        ])
    }

    /// Parse a persisted model (inverse of [`DispatcherModel::to_json`]).
    pub fn from_json(v: &Value) -> Result<Self> {
        Ok(Self {
            scaler: Scaler::from_json(v.get("scaler")?)?,
            svm: MultiClassSvm::from_json(v.get("svm")?)?,
            params: SvmParams::from_json(v.get("params")?)?,
            cv_accuracy: v.get("cv_accuracy")?.as_f64()?,
            test_accuracy: v.get("test_accuracy")?.as_f64()?,
            test_size: v.get("test_size")?.as_usize()?,
            test_correct: v.get("test_correct")?.as_usize()?,
            train_size: v.get("train_size")?.as_usize()?,
        })
    }
}

/// Trained dispatcher for one machine.
#[derive(Debug, Clone)]
pub struct SvmDispatcher {
    pub machine: Machine,
    models: BTreeMap<String, DispatcherModel>,
}

fn kind_key(kind: CollKind) -> String {
    kind.label().to_string()
}

impl SvmDispatcher {
    /// Train one SVM per collective on netsim sweep data, following the
    /// paper's protocol: 10 trials per configuration, stratified 80/20
    /// split, 5-fold CV hyperparameter selection.
    pub fn train(
        machine: Machine,
        sizes_mb: &[usize],
        ranks: &[usize],
        trials: usize,
        seed: u64,
    ) -> Result<Self> {
        let mut datasets = Vec::new();
        for kind in CollKind::ALL {
            datasets.push((kind, Dataset::build(machine, kind, sizes_mb, ranks, trials, seed)?));
        }
        Self::from_datasets(machine, datasets, seed)
    }

    /// Train from pre-built labeled datasets — the shared trunk of the
    /// netsim path ([`SvmDispatcher::train`]) and the measured data-plane
    /// path ([`crate::runtime::MeasuredSweep::train_dispatcher`]).
    pub fn from_datasets(
        machine: Machine,
        datasets: impl IntoIterator<Item = (CollKind, Dataset)>,
        seed: u64,
    ) -> Result<Self> {
        let mut models = BTreeMap::new();
        for (kind, data) in datasets {
            models.insert(kind_key(kind), DispatcherModel::fit(&data, seed)?);
        }
        Ok(Self { machine, models })
    }

    /// The model for one collective.
    pub fn model(&self, kind: CollKind) -> Result<&DispatcherModel> {
        self.models
            .get(&kind_key(kind))
            .ok_or_else(|| Error::Dispatch(format!("no model for {}", kind.label())))
    }

    /// Predict the fastest backend for a single-lane call site.
    pub fn choose(&self, kind: CollKind, msg_bytes: usize, ranks: usize) -> Backend {
        self.choose_lanes(kind, msg_bytes, ranks, 1)
    }

    /// Predict the fastest backend for a lane-striped call site.
    pub fn choose_lanes(
        &self,
        kind: CollKind,
        msg_bytes: usize,
        ranks: usize,
        lanes: usize,
    ) -> Backend {
        match self.model(kind) {
            Ok(m) => m.predict_lanes(kind, msg_bytes, ranks, lanes),
            Err(_) => Backend::PcclRec,
        }
    }

    /// Adapt to the [`Chooser`] hook used by
    /// [`crate::backends::CollectiveOptions`].
    pub fn chooser(self: &Arc<Self>) -> Chooser {
        let this = Arc::clone(self);
        Arc::new(move |kind, bytes, ranks, lanes| this.choose_lanes(kind, bytes, ranks, lanes))
    }

    /// Serialize to JSON (model persistence — train once, ship with the
    /// library, like the paper's per-machine models).
    pub fn save(&self, path: impl AsRef<Path>) -> Result<()> {
        std::fs::write(path, self.to_json().to_string())?;
        Ok(())
    }

    pub fn load(path: impl AsRef<Path>) -> Result<Self> {
        let text = std::fs::read_to_string(path)?;
        Self::from_json(&Value::parse(&text)?)
    }

    fn to_json(&self) -> Value {
        Value::obj(vec![
            ("schema", Value::Num(DISPATCHER_SCHEMA as f64)),
            (
                "machine",
                Value::Str(self.machine.params().name.to_string()),
            ),
            (
                "models",
                Value::Obj(
                    self.models
                        .iter()
                        .map(|(k, m)| (k.clone(), m.to_json()))
                        .collect(),
                ),
            ),
        ])
    }

    fn from_json(v: &Value) -> Result<Self> {
        // A payload with no schema field predates the lane feature
        // (schema 1): its models expect 2-feature inputs and would silently
        // mis-scale a 3-feature call, so refuse it with a migration note.
        let got = match v.get("schema") {
            Ok(s) => s.as_usize()? as u32,
            Err(_) => 1,
        };
        if got != DISPATCHER_SCHEMA {
            return Err(Error::ArtifactSchema {
                what: "dispatcher model".to_string(),
                expected: DISPATCHER_SCHEMA,
                got,
            });
        }
        let machine: Machine = v
            .get("machine")?
            .as_str()?
            .parse()
            .map_err(Error::Json)?;
        let mut models = BTreeMap::new();
        for (k, m) in v.get("models")?.as_obj()? {
            models.insert(k.clone(), DispatcherModel::from_json(m)?);
        }
        Ok(Self { machine, models })
    }

    /// Render the Table-I rows for this machine.
    pub fn table1(&self) -> Vec<(String, usize, usize, f64)> {
        CollKind::ALL
            .iter()
            .filter_map(|&k| {
                self.models.get(&kind_key(k)).map(|m| {
                    (
                        k.label().to_string(),
                        m.test_size,
                        m.test_correct,
                        m.test_accuracy * 100.0,
                    )
                })
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn quick_dispatcher() -> SvmDispatcher {
        // Small sweep to keep the test fast; still covers both regimes.
        SvmDispatcher::train(
            Machine::Frontier,
            &[16, 64, 256, 1024],
            &[32, 128, 512, 2048],
            3,
            11,
        )
        .unwrap()
    }

    #[test]
    fn dispatcher_learns_the_regime_split() {
        let d = quick_dispatcher();
        // Bandwidth-bound corner → vendor; latency-bound corner → pccl_rec.
        assert_eq!(
            d.choose(CollKind::AllGather, 1024 << 20, 32),
            Backend::Vendor
        );
        assert_eq!(
            d.choose(CollKind::AllGather, 16 << 20, 2048),
            Backend::PcclRec
        );
    }

    #[test]
    fn accuracy_is_reported_and_reasonable() {
        let d = quick_dispatcher();
        let m = d.model(CollKind::ReduceScatter).unwrap();
        assert!(m.train_size > 0 && m.test_size > 0);
        // The paper reports 75–95% on real (noisy) data; the netsim dataset
        // is cleaner, so demand at least 60% on the tiny test split.
        assert!(
            m.test_accuracy >= 0.6,
            "test accuracy {}",
            m.test_accuracy
        );
    }

    #[test]
    fn save_load_roundtrip() {
        let d = quick_dispatcher();
        let dir = crate::util::tmp::TempDir::new().unwrap();
        let p = dir.path().join("dispatcher.json");
        d.save(&p).unwrap();
        let d2 = SvmDispatcher::load(&p).unwrap();
        for kind in CollKind::ALL {
            for (mb, p_) in [(16usize, 2048usize), (1024, 32), (128, 256)] {
                assert_eq!(
                    d.choose(kind, mb << 20, p_),
                    d2.choose(kind, mb << 20, p_)
                );
            }
        }
    }

    #[test]
    fn dispatcher_model_json_roundtrip_identical_predictions() {
        // to_json → serialize → parse → identical predictions on a
        // held-out grid of (message size, rank count) points that the
        // training sweep never visited.
        let d = quick_dispatcher();
        for kind in CollKind::ALL {
            let m = d.model(kind).unwrap();
            let text = m.to_json().to_string();
            let back = DispatcherModel::from_json(&Value::parse(&text).unwrap()).unwrap();
            for mb in [1usize, 8, 48, 192, 768, 1536, 4096] {
                for p in [16usize, 96, 384, 1536, 4096] {
                    assert_eq!(
                        m.predict(kind, mb << 20, p),
                        back.predict(kind, mb << 20, p),
                        "{} mb={mb} p={p}",
                        kind.label()
                    );
                }
            }
            assert_eq!(m.cv_accuracy, back.cv_accuracy);
            assert_eq!(m.test_accuracy, back.test_accuracy);
            assert_eq!(m.test_size, back.test_size);
            assert_eq!(m.train_size, back.train_size);
        }
    }

    #[test]
    fn chooser_hook_integrates_with_options() {
        let d = Arc::new(quick_dispatcher());
        let opts = crate::backends::CollectiveOptions::<f32>::default()
            .backend(Backend::Auto)
            .chooser(d.chooser());
        let b = opts.resolve(CollKind::AllGather, 16 << 20, 2048, 1);
        assert_eq!(b, Backend::PcclRec);
    }

    #[test]
    fn persisted_payload_carries_schema_and_rejects_stale_models() {
        let d = quick_dispatcher();
        let text = d.to_json().to_string();
        assert!(text.contains("\"schema\""));

        // Strip the schema field to forge a pre-lane (schema 1) payload:
        // loading it must fail with the typed schema error, not a JSON or
        // shape error deep inside the SVM.
        let v = Value::parse(&text).unwrap();
        let mut fields = v.as_obj().unwrap().clone();
        fields.remove("schema");
        match SvmDispatcher::from_json(&Value::Obj(fields.clone())) {
            Err(Error::ArtifactSchema { expected, got, .. }) => {
                assert_eq!(expected, DISPATCHER_SCHEMA);
                assert_eq!(got, 1);
            }
            other => panic!("expected ArtifactSchema, got {other:?}"),
        }

        // A well-formed schema-2 payload (lane feature but no collective-id
        // feature) is refused the same typed way — its 3-feature scalers
        // would silently mis-scale a 4-feature call.
        fields.insert("schema".to_string(), Value::Num(2.0));
        match SvmDispatcher::from_json(&Value::Obj(fields.clone())) {
            Err(Error::ArtifactSchema { expected, got, .. }) => {
                assert_eq!(expected, DISPATCHER_SCHEMA);
                assert_eq!(got, 2);
            }
            other => panic!("expected ArtifactSchema for schema 2, got {other:?}"),
        }

        // A future schema is refused the same way.
        fields.insert("schema".to_string(), Value::Num(99.0));
        assert!(matches!(
            SvmDispatcher::from_json(&Value::Obj(fields)),
            Err(Error::ArtifactSchema { got: 99, .. })
        ));
    }

    #[test]
    fn lane_and_kind_features_reach_the_model() {
        // The lane-aware entry points must flow the lane count into the
        // feature vector (not ignore it): predictions may legitimately
        // coincide, but the feature transform must differ.
        let d = quick_dispatcher();
        let m = d.model(CollKind::ReduceScatter).unwrap();
        let x1 = m.scaler.transform(&features(CollKind::ReduceScatter, 64 << 20, 128, 1));
        let x4 = m.scaler.transform(&features(CollKind::ReduceScatter, 64 << 20, 128, 4));
        assert_eq!(x1.len(), 4);
        assert_ne!(x1[2], x4[2], "lane feature must survive scaling");
        // And the single-lane delegates agree with the lane form.
        assert_eq!(
            d.choose(CollKind::ReduceScatter, 64 << 20, 128),
            d.choose_lanes(CollKind::ReduceScatter, 64 << 20, 128, 1)
        );
    }
}
