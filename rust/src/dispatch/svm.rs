//! Support Vector Machine, from scratch.
//!
//! Binary soft-margin SVM trained with (simplified) SMO [Platt 1998],
//! RBF or linear kernel, extended to multi-class with one-vs-rest — the
//! classifier behind the paper's adaptive dispatcher (§IV-C, Table I).

use crate::error::{Error, Result};
use crate::util::rng::Rng;

/// Kernel choice.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum KernelKind {
    Linear,
    /// `exp(-gamma · ||x-y||²)`.
    Rbf { gamma: f64 },
}

impl KernelKind {
    fn eval(self, a: &[f64], b: &[f64]) -> f64 {
        match self {
            KernelKind::Linear => a.iter().zip(b).map(|(x, y)| x * y).sum(),
            KernelKind::Rbf { gamma } => {
                let d2: f64 = a.iter().zip(b).map(|(x, y)| (x - y) * (x - y)).sum();
                (-gamma * d2).exp()
            }
        }
    }
}

/// SVM hyperparameters (C and kernel picked by cross-validation).
#[derive(Debug, Clone, Copy)]
pub struct SvmParams {
    /// Soft-margin penalty.
    pub c: f64,
    pub kernel: KernelKind,
    /// KKT violation tolerance.
    pub tol: f64,
    /// SMO passes without progress before stopping.
    pub max_passes: usize,
    /// Hard cap on sweep iterations.
    pub max_iters: usize,
    /// RNG seed for the j-choice in SMO.
    pub seed: u64,
}

impl Default for SvmParams {
    fn default() -> Self {
        Self {
            c: 10.0,
            kernel: KernelKind::Rbf { gamma: 0.5 },
            tol: 1e-3,
            max_passes: 8,
            max_iters: 20_000,
            seed: 0x5EED,
        }
    }
}

/// Feature standardizer (zero mean, unit variance per dimension).
#[derive(Debug, Clone)]
pub struct Scaler {
    mean: Vec<f64>,
    std: Vec<f64>,
}

impl Scaler {
    pub fn fit(xs: &[Vec<f64>]) -> Self {
        let d = xs.first().map_or(0, Vec::len);
        let n = xs.len().max(1) as f64;
        let mut mean = vec![0.0; d];
        for x in xs {
            for (m, v) in mean.iter_mut().zip(x) {
                *m += v / n;
            }
        }
        let mut std = vec![0.0; d];
        for x in xs {
            for (s, (v, m)) in std.iter_mut().zip(x.iter().zip(&mean)) {
                *s += (v - m) * (v - m) / n;
            }
        }
        for s in &mut std {
            *s = s.sqrt().max(1e-9);
        }
        Self { mean, std }
    }

    pub fn transform(&self, x: &[f64]) -> Vec<f64> {
        x.iter()
            .zip(self.mean.iter().zip(&self.std))
            .map(|(v, (m, s))| (v - m) / s)
            .collect()
    }

    pub fn transform_all(&self, xs: &[Vec<f64>]) -> Vec<Vec<f64>> {
        xs.iter().map(|x| self.transform(x)).collect()
    }
}

/// A trained binary SVM (support vectors + duals + bias).
#[derive(Debug, Clone)]
pub struct BinarySvm {
    kernel: KernelKind,
    support: Vec<Vec<f64>>,
    /// `alpha_i * y_i` per support vector.
    coef: Vec<f64>,
    bias: f64,
}

impl BinarySvm {
    /// Train with simplified SMO. `ys` must be ±1.
    pub fn train(xs: &[Vec<f64>], ys: &[f64], params: &SvmParams) -> Result<Self> {
        let n = xs.len();
        if n == 0 || ys.len() != n {
            return Err(Error::Dispatch("empty or mismatched training set".into()));
        }
        if ys.iter().any(|&y| y != 1.0 && y != -1.0) {
            return Err(Error::Dispatch("labels must be ±1".into()));
        }
        let k = |i: usize, j: usize| params.kernel.eval(&xs[i], &xs[j]);
        let mut alpha = vec![0.0f64; n];
        let mut b = 0.0f64;
        let mut rng = Rng::seed_from_u64(params.seed);
        let f = |alpha: &[f64], b: f64, i: usize| -> f64 {
            let mut s = b;
            for j in 0..n {
                if alpha[j] != 0.0 {
                    s += alpha[j] * ys[j] * k(j, i);
                }
            }
            s
        };
        let mut passes = 0;
        let mut iters = 0;
        while passes < params.max_passes && iters < params.max_iters {
            let mut changed = 0;
            for i in 0..n {
                iters += 1;
                let ei = f(&alpha, b, i) - ys[i];
                let violates = (ys[i] * ei < -params.tol && alpha[i] < params.c)
                    || (ys[i] * ei > params.tol && alpha[i] > 0.0);
                if !violates {
                    continue;
                }
                // Pick j ≠ i at random (simplified heuristic).
                let mut j = rng.range_usize(0, n - 1);
                if j >= i {
                    j += 1;
                }
                let ej = f(&alpha, b, j) - ys[j];
                let (ai_old, aj_old) = (alpha[i], alpha[j]);
                let (lo, hi) = if ys[i] != ys[j] {
                    (
                        (aj_old - ai_old).max(0.0),
                        (params.c + aj_old - ai_old).min(params.c),
                    )
                } else {
                    (
                        (ai_old + aj_old - params.c).max(0.0),
                        (ai_old + aj_old).min(params.c),
                    )
                };
                if lo >= hi {
                    continue;
                }
                let eta = 2.0 * k(i, j) - k(i, i) - k(j, j);
                if eta >= 0.0 {
                    continue;
                }
                let mut aj = aj_old - ys[j] * (ei - ej) / eta;
                aj = aj.clamp(lo, hi);
                if (aj - aj_old).abs() < 1e-7 {
                    continue;
                }
                let ai = ai_old + ys[i] * ys[j] * (aj_old - aj);
                alpha[i] = ai;
                alpha[j] = aj;
                let b1 = b - ei
                    - ys[i] * (ai - ai_old) * k(i, i)
                    - ys[j] * (aj - aj_old) * k(i, j);
                let b2 = b - ej
                    - ys[i] * (ai - ai_old) * k(i, j)
                    - ys[j] * (aj - aj_old) * k(j, j);
                b = if ai > 0.0 && ai < params.c {
                    b1
                } else if aj > 0.0 && aj < params.c {
                    b2
                } else {
                    (b1 + b2) / 2.0
                };
                changed += 1;
            }
            if changed == 0 {
                passes += 1;
            } else {
                passes = 0;
            }
        }
        let mut support = Vec::new();
        let mut coef = Vec::new();
        for i in 0..n {
            if alpha[i] > 1e-9 {
                support.push(xs[i].clone());
                coef.push(alpha[i] * ys[i]);
            }
        }
        Ok(Self {
            kernel: params.kernel,
            support,
            coef,
            bias: b,
        })
    }

    /// Signed decision value.
    pub fn decision(&self, x: &[f64]) -> f64 {
        let mut s = self.bias;
        for (sv, c) in self.support.iter().zip(&self.coef) {
            s += c * self.kernel.eval(sv, x);
        }
        s
    }

    pub fn predict(&self, x: &[f64]) -> f64 {
        if self.decision(x) >= 0.0 {
            1.0
        } else {
            -1.0
        }
    }

    pub fn n_support(&self) -> usize {
        self.support.len()
    }
}

/// One-vs-rest multi-class SVM.
#[derive(Debug, Clone)]
pub struct MultiClassSvm {
    per_class: Vec<BinarySvm>,
    /// Class ids present at training time (decision index → class id).
    classes: Vec<usize>,
}

impl MultiClassSvm {
    /// Train one binary SVM per distinct class.
    pub fn train(xs: &[Vec<f64>], ys: &[usize], params: &SvmParams) -> Result<Self> {
        let mut classes: Vec<usize> = ys.to_vec();
        classes.sort_unstable();
        classes.dedup();
        if classes.len() < 2 {
            // Degenerate: single class — still a valid (constant) model.
            return Ok(Self {
                per_class: Vec::new(),
                classes,
            });
        }
        let mut per_class = Vec::with_capacity(classes.len());
        for &cl in &classes {
            let bin_ys: Vec<f64> = ys.iter().map(|&y| if y == cl { 1.0 } else { -1.0 }).collect();
            per_class.push(BinarySvm::train(xs, &bin_ys, params)?);
        }
        Ok(Self { per_class, classes })
    }

    /// Predicted class id (argmax of one-vs-rest decision values).
    pub fn predict(&self, x: &[f64]) -> usize {
        if self.per_class.is_empty() {
            return self.classes.first().copied().unwrap_or(0);
        }
        let mut best = (f64::NEG_INFINITY, 0usize);
        for (svm, &cl) in self.per_class.iter().zip(&self.classes) {
            let d = svm.decision(x);
            if d > best.0 {
                best = (d, cl);
            }
        }
        best.1
    }

    /// Accuracy on a labeled set.
    pub fn accuracy(&self, xs: &[Vec<f64>], ys: &[usize]) -> f64 {
        if xs.is_empty() {
            return 0.0;
        }
        let correct = xs
            .iter()
            .zip(ys)
            .filter(|(x, &y)| self.predict(x) == y)
            .count();
        correct as f64 / xs.len() as f64
    }
}

/// Grid-search C/γ by `k`-fold cross-validation (the paper's five-fold
/// protocol) and train on the full training set with the winner.
pub fn train_with_cv(
    xs: &[Vec<f64>],
    ys: &[usize],
    k: usize,
    seed: u64,
) -> Result<(MultiClassSvm, SvmParams, f64)> {
    let cs = [1.0, 10.0, 100.0];
    let gammas = [0.1, 0.5, 2.0];
    let n = xs.len();
    if n < k.max(2) {
        return Err(Error::Dispatch(format!(
            "need ≥ {k} samples for {k}-fold CV, got {n}"
        )));
    }
    // Shuffled fold assignment.
    let mut order: Vec<usize> = (0..n).collect();
    let mut rng = Rng::seed_from_u64(seed);
    rng.shuffle(&mut order);
    let mut best: Option<(f64, SvmParams)> = None;
    for &c in &cs {
        for &gamma in &gammas {
            let params = SvmParams {
                c,
                kernel: KernelKind::Rbf { gamma },
                seed,
                ..Default::default()
            };
            let mut acc_sum = 0.0;
            for fold in 0..k {
                let (mut txs, mut tys, mut vxs, mut vys) =
                    (Vec::new(), Vec::new(), Vec::new(), Vec::new());
                for (pos, &i) in order.iter().enumerate() {
                    if pos % k == fold {
                        vxs.push(xs[i].clone());
                        vys.push(ys[i]);
                    } else {
                        txs.push(xs[i].clone());
                        tys.push(ys[i]);
                    }
                }
                let model = MultiClassSvm::train(&txs, &tys, &params)?;
                acc_sum += model.accuracy(&vxs, &vys);
            }
            let acc = acc_sum / k as f64;
            if best.as_ref().map_or(true, |(b, _)| acc > *b) {
                best = Some((acc, params));
            }
        }
    }
    let (cv_acc, params) = best.expect("non-empty grid");
    let model = MultiClassSvm::train(xs, ys, &params)?;
    Ok((model, params, cv_acc))
}

// --- JSON persistence (offline substrate: util::json) ----------------------

use crate::util::json::Value;

impl KernelKind {
    pub fn to_json(&self) -> Value {
        match self {
            KernelKind::Linear => Value::obj(vec![("kind", Value::Str("linear".into()))]),
            KernelKind::Rbf { gamma } => Value::obj(vec![
                ("kind", Value::Str("rbf".into())),
                ("gamma", Value::Num(*gamma)),
            ]),
        }
    }

    pub fn from_json(v: &Value) -> Result<Self> {
        match v.get("kind")?.as_str()? {
            "linear" => Ok(KernelKind::Linear),
            "rbf" => Ok(KernelKind::Rbf {
                gamma: v.get("gamma")?.as_f64()?,
            }),
            other => Err(Error::Json(format!("unknown kernel {other:?}"))),
        }
    }
}

impl SvmParams {
    pub fn to_json(&self) -> Value {
        Value::obj(vec![
            ("c", Value::Num(self.c)),
            ("kernel", self.kernel.to_json()),
            ("tol", Value::Num(self.tol)),
            ("max_passes", Value::Num(self.max_passes as f64)),
            ("max_iters", Value::Num(self.max_iters as f64)),
            ("seed", Value::Num(self.seed as f64)),
        ])
    }

    pub fn from_json(v: &Value) -> Result<Self> {
        Ok(Self {
            c: v.get("c")?.as_f64()?,
            kernel: KernelKind::from_json(v.get("kernel")?)?,
            tol: v.get("tol")?.as_f64()?,
            max_passes: v.get("max_passes")?.as_usize()?,
            max_iters: v.get("max_iters")?.as_usize()?,
            seed: v.get("seed")?.as_f64()? as u64,
        })
    }
}

impl Scaler {
    pub fn to_json(&self) -> Value {
        Value::obj(vec![
            ("mean", Value::arr_f64(&self.mean)),
            ("std", Value::arr_f64(&self.std)),
        ])
    }

    pub fn from_json(v: &Value) -> Result<Self> {
        Ok(Self {
            mean: v.get("mean")?.vec_f64()?,
            std: v.get("std")?.vec_f64()?,
        })
    }
}

impl BinarySvm {
    pub fn to_json(&self) -> Value {
        Value::obj(vec![
            ("kernel", self.kernel.to_json()),
            (
                "support",
                Value::Arr(self.support.iter().map(|s| Value::arr_f64(s)).collect()),
            ),
            ("coef", Value::arr_f64(&self.coef)),
            ("bias", Value::Num(self.bias)),
        ])
    }

    pub fn from_json(v: &Value) -> Result<Self> {
        Ok(Self {
            kernel: KernelKind::from_json(v.get("kernel")?)?,
            support: v
                .get("support")?
                .as_arr()?
                .iter()
                .map(Value::vec_f64)
                .collect::<Result<_>>()?,
            coef: v.get("coef")?.vec_f64()?,
            bias: v.get("bias")?.as_f64()?,
        })
    }
}

impl MultiClassSvm {
    pub fn to_json(&self) -> Value {
        Value::obj(vec![
            (
                "per_class",
                Value::Arr(self.per_class.iter().map(BinarySvm::to_json).collect()),
            ),
            ("classes", Value::arr_usize(&self.classes)),
        ])
    }

    pub fn from_json(v: &Value) -> Result<Self> {
        Ok(Self {
            per_class: v
                .get("per_class")?
                .as_arr()?
                .iter()
                .map(BinarySvm::from_json)
                .collect::<Result<_>>()?,
            classes: v.get("classes")?.vec_usize()?,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn linearly_separable() -> (Vec<Vec<f64>>, Vec<f64>) {
        let mut xs = Vec::new();
        let mut ys = Vec::new();
        for i in 0..20 {
            let t = i as f64 / 5.0;
            xs.push(vec![t, t + 2.0]);
            ys.push(1.0);
            xs.push(vec![t, t - 2.0]);
            ys.push(-1.0);
        }
        (xs, ys)
    }

    #[test]
    fn binary_separable_is_learned() {
        let (xs, ys) = linearly_separable();
        let svm = BinarySvm::train(&xs, &ys, &SvmParams::default()).unwrap();
        for (x, y) in xs.iter().zip(&ys) {
            assert_eq!(svm.predict(x), *y, "misclassified {x:?}");
        }
        assert!(svm.n_support() >= 2);
    }

    #[test]
    fn rbf_learns_xor() {
        // XOR — not linearly separable; RBF must handle it.
        let xs: Vec<Vec<f64>> = vec![
            vec![0.0, 0.0],
            vec![1.0, 1.0],
            vec![0.0, 1.0],
            vec![1.0, 0.0],
            vec![0.1, 0.1],
            vec![0.9, 0.9],
            vec![0.1, 0.9],
            vec![0.9, 0.1],
        ];
        let ys = vec![-1.0, -1.0, 1.0, 1.0, -1.0, -1.0, 1.0, 1.0];
        let params = SvmParams {
            c: 100.0,
            kernel: KernelKind::Rbf { gamma: 2.0 },
            ..Default::default()
        };
        let svm = BinarySvm::train(&xs, &ys, &params).unwrap();
        for (x, y) in xs.iter().zip(&ys) {
            assert_eq!(svm.predict(x), *y, "xor misclassified at {x:?}");
        }
    }

    #[test]
    fn multiclass_quadrants() {
        // 4 classes = 4 quadrants.
        let mut xs = Vec::new();
        let mut ys = Vec::new();
        for i in 0..6 {
            for j in 0..6 {
                let (x, y) = (0.3 + i as f64 * 0.4, 0.3 + j as f64 * 0.4);
                for (sx, sy, cl) in
                    [(1.0, 1.0, 0usize), (-1.0, 1.0, 1), (-1.0, -1.0, 2), (1.0, -1.0, 3)]
                {
                    xs.push(vec![sx * x, sy * y]);
                    ys.push(cl);
                }
            }
        }
        let model = MultiClassSvm::train(&xs, &ys, &SvmParams::default()).unwrap();
        assert!(model.accuracy(&xs, &ys) > 0.97);
        assert_eq!(model.predict(&[2.0, 2.0]), 0);
        assert_eq!(model.predict(&[-2.0, -2.0]), 2);
    }

    #[test]
    fn scaler_standardizes() {
        let xs = vec![vec![10.0, 0.0], vec![20.0, 1.0], vec![30.0, 2.0]];
        let sc = Scaler::fit(&xs);
        let t = sc.transform_all(&xs);
        let mean0: f64 = t.iter().map(|x| x[0]).sum::<f64>() / 3.0;
        assert!(mean0.abs() < 1e-12);
        assert!(t[0][0] < 0.0 && t[2][0] > 0.0);
    }

    #[test]
    fn cv_picks_reasonable_params() {
        let mut xs = Vec::new();
        let mut ys = Vec::new();
        for i in 0..30 {
            let t = i as f64 / 10.0;
            xs.push(vec![t, 1.0]);
            ys.push(0usize);
            xs.push(vec![t, -1.0]);
            ys.push(1usize);
        }
        let (model, _params, cv_acc) = train_with_cv(&xs, &ys, 5, 42).unwrap();
        assert!(cv_acc > 0.9, "cv accuracy {cv_acc}");
        assert!(model.accuracy(&xs, &ys) > 0.95);
    }

    #[test]
    fn degenerate_single_class() {
        let xs = vec![vec![1.0], vec![2.0]];
        let ys = vec![3usize, 3];
        let m = MultiClassSvm::train(&xs, &ys, &SvmParams::default()).unwrap();
        assert_eq!(m.predict(&[5.0]), 3);
    }
}
