//! `Pccl` — the library facade that closes the selection loop: one object
//! owning the collective options, routing every `all_gather` /
//! `reduce_scatter` / `all_reduce` through the trained adaptive dispatcher
//! (§IV-C) when a model is available, and through the paper's coarse
//! regime heuristic otherwise.
//!
//! Training drivers ([`crate::train::ddp`], [`crate::train::zero3`]) and
//! the examples construct their options through this facade, so a
//! dispatcher persisted by `pccl dispatch --save` / `dispatch_demo` is
//! consulted on every collective call with `Backend::Auto`.

use std::sync::Arc;

use crate::backends::{self, Backend, CollKind, CollectiveOptions};
use crate::comm::{Chunk, Communicator};
use crate::dispatch::SvmDispatcher;
use crate::error::Result;
use crate::reduction::Elem;
use crate::runtime::Artifacts;
use crate::topology::Machine;

/// Facade over the collective entry points with adaptive backend routing.
#[derive(Clone)]
pub struct Pccl<T: Elem> {
    opts: CollectiveOptions<T>,
    dispatcher: Option<Arc<SvmDispatcher>>,
}

impl<T: Elem> Default for Pccl<T> {
    fn default() -> Self {
        Self::new()
    }
}

impl<T: Elem> Pccl<T> {
    /// Adaptive facade with no trained model: `Backend::Auto` resolves via
    /// the built-in regime heuristic (vendor when bandwidth-bound,
    /// hierarchical recursive when latency-bound).
    pub fn new() -> Self {
        Self {
            opts: CollectiveOptions::default().backend(Backend::Auto),
            dispatcher: None,
        }
    }

    /// Fixed-backend facade (`Backend::Auto` behaves like [`Pccl::new`]).
    pub fn with_backend(backend: Backend) -> Self {
        Self {
            opts: CollectiveOptions::default().backend(backend),
            dispatcher: None,
        }
    }

    /// Route `Backend::Auto` through a trained dispatcher.
    pub fn with_dispatcher(dispatcher: Arc<SvmDispatcher>) -> Self {
        let opts = CollectiveOptions::default()
            .backend(Backend::Auto)
            .chooser(dispatcher.chooser());
        Self { opts, dispatcher: Some(dispatcher) }
    }

    /// Load the dispatcher trained for `machine` from the default artifact
    /// directory; heuristic fallback when no artifact exists. A *corrupt*
    /// artifact also falls back, but loudly (stderr) — silently demoting a
    /// trained model to the heuristic would mask real breakage.
    pub fn from_artifacts(machine: Machine) -> Self {
        Self::fallback_on(Artifacts::load_default().and_then(|a| a.load_dispatcher(machine)))
    }

    /// Adaptive facade for a training run: `Backend::Auto` consults
    /// whichever dispatcher artifact is persisted in `artifact_dir` (or the
    /// default directory), falling back to the heuristic; any other
    /// backend is pinned.
    pub fn for_training(backend: Backend, artifact_dir: Option<&str>) -> Self {
        if backend != Backend::Auto {
            return Self::with_backend(backend);
        }
        let arts = match artifact_dir {
            Some(d) => Artifacts::load(d),
            None => Artifacts::load_default(),
        };
        Self::fallback_on(arts.and_then(|a| a.load_any_dispatcher()))
    }

    /// Heuristic fallback that distinguishes "no artifact" (expected,
    /// silent) from "artifact present but unloadable" (warned).
    fn fallback_on(loaded: Result<SvmDispatcher>) -> Self {
        match loaded {
            Ok(d) => Self::with_dispatcher(Arc::new(d)),
            // Missing directory / missing dispatcher file both surface as
            // Artifact (or Io for an absent dir) — the expected cold path.
            Err(crate::error::Error::Artifact(_)) | Err(crate::error::Error::Io(_)) => Self::new(),
            Err(e) => {
                eprintln!(
                    "warning: dispatcher artifact present but unloadable ({e}); \
                     falling back to the regime heuristic"
                );
                Self::new()
            }
        }
    }

    /// Whether a trained model (vs. the heuristic) backs `Backend::Auto`.
    pub fn is_trained(&self) -> bool {
        self.dispatcher.is_some()
    }

    /// The trained dispatcher, when present.
    pub fn dispatcher(&self) -> Option<&Arc<SvmDispatcher>> {
        self.dispatcher.as_ref()
    }

    /// The underlying options (for APIs that take `CollectiveOptions`,
    /// e.g. bucketed all-reduce).
    pub fn options(&self) -> &CollectiveOptions<T> {
        &self.opts
    }

    /// Which backend a call of this shape would take (introspection,
    /// single-lane). See [`Pccl::route_lanes`] for the striped variant.
    pub fn route(&self, kind: CollKind, msg_bytes: usize, ranks: usize) -> Backend {
        self.opts.resolve(kind, msg_bytes, ranks, 1)
    }

    /// Which backend a lane-striped call of this shape would take.
    pub fn route_lanes(
        &self,
        kind: CollKind,
        msg_bytes: usize,
        ranks: usize,
        lanes: usize,
    ) -> Backend {
        self.opts.resolve(kind, msg_bytes, ranks, lanes)
    }

    /// All-gather through the routed backend.
    pub fn all_gather(&self, c: &mut Communicator<T>, input: &[T]) -> Result<Vec<T>> {
        backends::all_gather(c, input, &self.opts)
    }

    /// All-gather through the routed backend, returning zero-copy chunk
    /// views of every rank's block (the allocation-free hot path).
    pub fn all_gather_chunks(
        &self,
        c: &mut Communicator<T>,
        input: Chunk<T>,
    ) -> Result<Vec<Chunk<T>>> {
        backends::all_gather_chunks(c, input, &self.opts)
    }

    /// Reduce-scatter through the routed backend.
    pub fn reduce_scatter(&self, c: &mut Communicator<T>, input: &[T]) -> Result<Vec<T>> {
        backends::reduce_scatter(c, input, &self.opts)
    }

    /// Reduce-scatter through the routed backend, returning this rank's
    /// reduced block as a chunk — on every `p > 1` path the unique
    /// full-range view of transport-delivered storage, so holding it (the
    /// ZeRO-3 shard update) or `into_vec`-ing it costs zero copies.
    pub fn reduce_scatter_chunks(
        &self,
        c: &mut Communicator<T>,
        input: Chunk<T>,
    ) -> Result<Chunk<T>> {
        backends::reduce_scatter_chunks(c, input, &self.opts)
    }

    /// All-reduce through the routed backend.
    pub fn all_reduce(&self, c: &mut Communicator<T>, input: &[T]) -> Result<Vec<T>> {
        backends::all_reduce(c, input, &self.opts)
    }

    /// All-reduce through the routed backend as rank-ordered chunk blocks
    /// (chunk reduce-scatter ∘ chunk all-gather, no intermediate `Vec`).
    pub fn all_reduce_chunks(
        &self,
        c: &mut Communicator<T>,
        input: Chunk<T>,
    ) -> Result<Vec<Chunk<T>>> {
        backends::all_reduce_chunks(c, input, &self.opts)
    }

    /// Lane-striped reduce-scatter: this rank's reduced block as a stripe
    /// list (one stripe per transport lane on the striped PCCL paths; see
    /// [`backends::reduce_scatter_stripes`]).
    pub fn reduce_scatter_stripes(
        &self,
        c: &mut Communicator<T>,
        input: Chunk<T>,
    ) -> Result<Vec<Chunk<T>>> {
        backends::reduce_scatter_stripes(c, input, &self.opts)
    }

    /// Lane-striped all-reduce as an ordered chunk list.
    pub fn all_reduce_lanes_chunks(
        &self,
        c: &mut Communicator<T>,
        input: Chunk<T>,
    ) -> Result<Vec<Chunk<T>>> {
        backends::all_reduce_lanes_chunks(c, input, &self.opts)
    }

    /// Lane-striped all-gather as an ordered chunk list.
    pub fn all_gather_lanes_chunks(
        &self,
        c: &mut Communicator<T>,
        input: Chunk<T>,
    ) -> Result<Vec<Chunk<T>>> {
        backends::all_gather_lanes_chunks(c, input, &self.opts)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::collectives::oracle;
    use crate::comm::CommWorld;
    use crate::topology::Topology;

    #[test]
    fn untrained_facade_uses_regime_heuristic() {
        let pccl = Pccl::<f32>::new();
        assert!(!pccl.is_trained());
        assert_eq!(pccl.route(CollKind::AllGather, 512 << 20, 16), Backend::Vendor);
        assert_eq!(pccl.route(CollKind::AllGather, 16 << 20, 2048), Backend::PcclRec);
    }

    #[test]
    fn trained_facade_routes_through_svm_and_runs() {
        let dispatcher = Arc::new(
            SvmDispatcher::train(
                Machine::Frontier,
                &[16, 64, 256, 1024],
                &[32, 128, 512, 2048],
                3,
                11,
            )
            .unwrap(),
        );
        let pccl = Pccl::<f32>::with_dispatcher(dispatcher);
        assert!(pccl.is_trained());
        // The two regimes resolve to different backends through the SVM.
        let bw = pccl.route(CollKind::AllGather, 1024 << 20, 32);
        let lat = pccl.route(CollKind::AllGather, 16 << 20, 2048);
        assert_ne!(bw, lat, "dispatcher must split the regimes");
        // And real collectives execute correctly through the facade.
        let topo = Topology::new(2, 3, 1).unwrap();
        let p = topo.world_size();
        let world = CommWorld::<f32>::with_topology(topo);
        let pccl2 = pccl.clone();
        let outs = world
            .try_run(move |c| {
                let ag = pccl2.all_gather(c, &[c.rank() as f32; 4])?;
                let ar = pccl2.all_reduce(c, &[1.0; 5])?;
                Ok((ag, ar))
            })
            .unwrap();
        let ins: Vec<Vec<f32>> = (0..p).map(|r| vec![r as f32; 4]).collect();
        for (ag, ar) in outs {
            assert_eq!(ag, oracle::all_gather(&ins));
            assert_eq!(ar, vec![p as f32; 5]);
        }
    }

    #[test]
    fn for_training_pins_fixed_backends() {
        let pccl = Pccl::<f32>::for_training(Backend::PcclRing, None);
        assert!(!pccl.is_trained());
        assert_eq!(pccl.route(CollKind::AllReduce, 1 << 20, 8), Backend::PcclRing);
    }

    #[test]
    fn for_training_auto_without_artifacts_falls_back_to_heuristic() {
        let pccl = Pccl::<f32>::for_training(Backend::Auto, Some("/definitely/not/here"));
        assert!(!pccl.is_trained());
        assert_eq!(pccl.route(CollKind::AllGather, 16 << 20, 2048), Backend::PcclRec);
    }

    #[test]
    fn for_training_auto_falls_back_loudly_on_pre_lane_artifact() {
        // A stale (schema 1) dispatcher artifact must not be silently
        // consumed: the facade warns and demotes to the heuristic.
        let dir = crate::util::tmp::TempDir::new().unwrap();
        let arts = Artifacts::open_or_init(dir.path()).unwrap();
        std::fs::write(
            arts.dispatcher_path(Machine::Frontier),
            r#"{"machine": "frontier", "models": {}}"#,
        )
        .unwrap();
        let pccl = Pccl::<f32>::for_training(Backend::Auto, dir.path().to_str());
        assert!(!pccl.is_trained());
        assert_eq!(pccl.route(CollKind::AllGather, 16 << 20, 2048), Backend::PcclRec);
    }

    #[test]
    fn for_training_auto_loads_persisted_artifact() {
        let dir = crate::util::tmp::TempDir::new().unwrap();
        let arts = Artifacts::open_or_init(dir.path()).unwrap();
        let d = SvmDispatcher::train(Machine::Frontier, &[16, 1024], &[32, 2048], 2, 7).unwrap();
        arts.save_dispatcher(&d).unwrap();
        let pccl = Pccl::<f32>::for_training(Backend::Auto, dir.path().to_str());
        assert!(pccl.is_trained());
    }
}
