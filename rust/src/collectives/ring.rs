//! Flat ring algorithms — bandwidth-optimal, latency linear in `p`
//! (Eq. 1 of the paper). This is what NCCL/RCCL use for all-gather and
//! reduce-scatter (Observation 2), and PCCL's `PCCL_ring` inter-node
//! backend.
//!
//! Since the Plan IR refactor these entry points own no schedule logic:
//! each one validates its input, lowers a [`PlanSpec`] with
//! [`plan::build`], checks it against the statically verified cache
//! ([`plan::verify_cached`]), and hands the blocks to
//! [`engine::run_flat`]. The ring index math lives once, in
//! [`super::plan`]'s builders (which delegate to
//! [`super::schedule::ring`]).

use crate::comm::{Chunk, Comm};
use crate::error::{Error, Result};
use crate::reduction::offload::Combiner;
use crate::reduction::Elem;

use super::engine;
use super::plan::{self, Algo, PlanKind, PlanSpec};
use super::{
    check_all_gather, check_reduce_scatter, pad_chunk, slice_all_reduce, slice_gather,
    slice_reduce, trim_blocks,
};

/// Lower a flat ring spec for this communicator, verify it (memoized),
/// and execute it. All ring entry points funnel through here.
fn run_ring<T: Elem, C: Comm<T>>(
    c: &mut C,
    kind: PlanKind,
    elems: usize,
    lanes: usize,
    inputs: Vec<Chunk<T>>,
    combiner: Option<&Combiner<T>>,
) -> Result<Vec<Chunk<T>>> {
    let spec = PlanSpec::flat(kind, Algo::Ring, c.size(), elems, lanes);
    plan::verify_cached(&spec)?;
    let pl = plan::build(&spec, c.rank())?;
    engine::run_flat(c, &pl, inputs, combiner)
}

/// Ring all-gather over the chunked plane: `p - 1` steps, each rank
/// forwards the *chunk* it received in the previous step to its right
/// neighbor — zero copies at every hop.
///
/// Returns the `p` per-rank blocks in origin-rank order; block `i` is
/// backed by rank `i`'s input storage (the zero-copy tests assert exactly
/// this identity).
pub fn ring_all_gather_chunks<T: Elem, C: Comm<T>>(
    c: &mut C,
    input: Chunk<T>,
) -> Result<Vec<Chunk<T>>> {
    check_all_gather(input.as_slice())?;
    let elems = input.len();
    run_ring(c, PlanKind::AllGather, elems, 1, vec![input], None)
}

/// Ring all-gather, slice API — adapter over [`ring_all_gather_chunks`].
pub fn ring_all_gather<T: Elem, C: Comm<T>>(c: &mut C, input: &[T]) -> Result<Vec<T>> {
    slice_gather(input, |ch| ring_all_gather_chunks(c, ch))
}

/// Ring reduce-scatter over the chunked plane: `p - 1` steps; the partial
/// for each block travels once around the ring, combined at every hop (on
/// the "GPU" — the injected [`Combiner`]).
///
/// Hot-path note (§Perf): every step's lowered `SendRecvCombine` op posts
/// a view of this rank's own contribution as the receive target and folds
/// the incoming partial into it via [`Comm::sendrecv_combine_into`]. At a
/// partial's *first* combine (incoming is still a shared view of the
/// sender's input) the delivery is a one-pass three-address fuse into
/// fresh exact-size storage — one allocation, zero verbatim copies; on
/// every later hop the exclusive traveling partial is taken over and
/// folded in place, so the storage created at the first combine survives
/// every remaining hop. For `p > 1` the returned chunk is the unique
/// full-range view of that storage: `into_vec` on it is a move, never a
/// copy. At `p == 1` the block comes back backed by the input's storage.
pub fn ring_reduce_scatter_chunks<T: Elem, C: Comm<T>>(
    c: &mut C,
    input: Chunk<T>,
    combiner: &Combiner<T>,
) -> Result<Chunk<T>> {
    let p = c.size();
    let b = check_reduce_scatter(input.as_slice(), p)?;
    let blocks = (0..p).map(|i| input.slice(i * b, b)).collect();
    ring_reduce_scatter_blocks_chunks(c, blocks, combiner)
}

/// Validate a block-list collective input: one block per rank, all equal
/// length. Returns the block length.
fn check_blocks<T>(blocks: &[Chunk<T>], p: usize) -> Result<usize> {
    if blocks.len() != p {
        return Err(Error::BadBufferSize {
            len: blocks.len(),
            size: p,
            why: "block-list reduce-scatter needs exactly one block per rank",
        });
    }
    let b = blocks.first().map_or(0, |c| c.len());
    if blocks.iter().any(|c| c.len() != b) {
        return Err(Error::BadBufferSize {
            len: b,
            size: p,
            why: "block-list reduce-scatter blocks must all be the same length",
        });
    }
    Ok(b)
}

/// Ring reduce-scatter over an explicit per-destination block list:
/// `blocks[i]` is this rank's contribution to rank `i`'s result. Same
/// lowered schedule and posted-combine hot path as
/// [`ring_reduce_scatter_chunks`] (which delegates here), but the
/// contributions need not be slices of one contiguous buffer — this is
/// what lets callers hand per-node *views* straight in with no staging
/// copy. Blocks are consumed (moved into the plan's slot table; the
/// engine drops each one as the schedule takes it).
pub fn ring_reduce_scatter_blocks_chunks<T: Elem, C: Comm<T>>(
    c: &mut C,
    blocks: Vec<Chunk<T>>,
    combiner: &Combiner<T>,
) -> Result<Chunk<T>> {
    let p = c.size();
    let b = check_blocks(&blocks, p)?;
    let mut out = run_ring(c, PlanKind::ReduceScatter, p * b, 1, blocks, Some(combiner))?;
    debug_assert_eq!(out.len(), 1, "unstriped reduce-scatter yields one block");
    Ok(out.pop().expect("reduce-scatter plan outputs this rank's block"))
}

/// Ring reduce-scatter, slice API — adapter over
/// [`ring_reduce_scatter_chunks`].
pub fn ring_reduce_scatter<T: Elem, C: Comm<T>>(
    c: &mut C,
    input: &[T],
    combiner: &Combiner<T>,
) -> Result<Vec<T>> {
    slice_reduce(input, |ch| ring_reduce_scatter_chunks(c, ch, combiner))
}

/// Ring all-reduce over chunks = chunk reduce-scatter ∘ chunk all-gather
/// (the bandwidth-optimal Patarasuk–Yuan composition), lowered as a single
/// two-phase plan over one slot table: the reduced shard feeds the gather
/// directly, no intermediate `Vec`. Unaligned inputs are padded once into
/// the chunk the reduce-scatter consumes, and the padding is trimmed off
/// the returned block list as a view adjustment — the blocks concatenate
/// to exactly `input.len()` elements.
///
/// The composition also runs at `p == 1` (both phases degenerate to
/// zero-message ops but still bump the op sequence), so tag numbering
/// advances identically for every communicator size.
pub fn ring_all_reduce_chunks<T: Elem, C: Comm<T>>(
    c: &mut C,
    input: Chunk<T>,
    combiner: &Combiner<T>,
) -> Result<Vec<Chunk<T>>> {
    check_all_gather(input.as_slice())?;
    let p = c.size();
    let n = input.len();
    let padded = n.div_ceil(p) * p;
    // §Perf: pad at most once, straight into the reduce-scatter input.
    let padded_input = if padded == n {
        input
    } else {
        pad_chunk(&input, padded)
    };
    let b = padded / p;
    let blocks = (0..p).map(|i| padded_input.slice(i * b, b)).collect();
    let mut blocks = run_ring(c, PlanKind::AllReduce, padded, 1, blocks, Some(combiner))?;
    trim_blocks(&mut blocks, n);
    Ok(blocks)
}

/// Ring all-reduce, slice API — adapter over [`ring_all_reduce_chunks`].
pub fn ring_all_reduce<T: Elem, C: Comm<T>>(
    c: &mut C,
    input: &[T],
    combiner: &Combiner<T>,
) -> Result<Vec<T>> {
    slice_all_reduce(input, |ch| ring_all_reduce_chunks(c, ch, combiner))
}

/// Clamp a requested lane count to what the communicator can stripe over.
/// `0` means "as many as available".
pub(crate) fn effective_lanes<T: Elem, C: Comm<T>>(c: &C, lanes: usize) -> usize {
    let want = if lanes == 0 { c.lanes() } else { lanes };
    want.min(c.lanes()).max(1)
}

/// Lane-parallel ring reduce-scatter: the same `p - 1`-step block schedule
/// as [`ring_reduce_scatter_chunks`], but lowered with `lanes > 1`, so
/// every traveling block is split into `lanes` contiguous stripe views,
/// stripe `l` riding transport lane `l` (NCCL-channel style). Each step's
/// incoming stripes are folded into posted views of this rank's
/// contribution via one [`Comm::sendrecv_striped_combine_into`] — on a
/// multi-lane transport the per-stripe folds run concurrently on the lane
/// worker threads, dividing the combine's critical path by the lane count.
///
/// `lanes` is clamped to [`Comm::lanes`] (0 = use all); at an effective
/// lane count of 1 this delegates to the unstriped path. Returns this
/// rank's reduced block as its stripe list (in order — stripes concatenate
/// to the block; they are separate storages by construction, since each
/// stripe's accumulator travels its own lane).
pub fn ring_reduce_scatter_lanes_chunks<T: Elem, C: Comm<T>>(
    c: &mut C,
    input: Chunk<T>,
    combiner: &Combiner<T>,
    lanes: usize,
) -> Result<Vec<Chunk<T>>> {
    let k = effective_lanes(c, lanes);
    if k == 1 {
        return Ok(vec![ring_reduce_scatter_chunks(c, input, combiner)?]);
    }
    let p = c.size();
    let b = check_reduce_scatter(input.as_slice(), p)?;
    let blocks = (0..p).map(|i| input.slice(i * b, b)).collect();
    run_ring(c, PlanKind::ReduceScatter, p * b, k, blocks, Some(combiner))
}

/// Lane-parallel block-list ring reduce-scatter — the striped counterpart
/// of [`ring_reduce_scatter_blocks_chunks`], and the function the other
/// striped reduce paths funnel into. Each block is split into `lanes`
/// stripes riding their own transport lanes; returns this rank's reduced
/// block as its stripe list.
pub fn ring_reduce_scatter_blocks_lanes_chunks<T: Elem, C: Comm<T>>(
    c: &mut C,
    blocks: Vec<Chunk<T>>,
    combiner: &Combiner<T>,
    lanes: usize,
) -> Result<Vec<Chunk<T>>> {
    let k = effective_lanes(c, lanes);
    if k == 1 {
        return Ok(vec![ring_reduce_scatter_blocks_chunks(c, blocks, combiner)?]);
    }
    let p = c.size();
    let b = check_blocks(&blocks, p)?;
    run_ring(c, PlanKind::ReduceScatter, p * b, k, blocks, Some(combiner))
}

/// Lane-parallel ring all-gather: [`ring_all_gather_chunks`] lowered with
/// `lanes > 1` — each block split into `lanes` stripes riding their own
/// transport lanes. Returns `p · k` chunks in rank-major, stripe-minor
/// order (`out[i * k + l]` = stripe `l` of rank `i`'s block), which
/// concatenate to the full gathered buffer. At an effective lane count of
/// 1 this is exactly the unstriped block list.
pub fn ring_all_gather_lanes_chunks<T: Elem, C: Comm<T>>(
    c: &mut C,
    input: Chunk<T>,
    lanes: usize,
) -> Result<Vec<Chunk<T>>> {
    let k = effective_lanes(c, lanes);
    if k == 1 {
        return ring_all_gather_chunks(c, input);
    }
    check_all_gather(input.as_slice())?;
    let elems = input.len();
    run_ring(c, PlanKind::AllGather, elems, k, vec![input], None)
}

/// Lane-parallel ring all-reduce: striped reduce-scatter ∘ striped
/// all-gather as one two-phase plan, no intermediate materialization —
/// each reduced stripe feeds the gather directly on its lane. Returns
/// `p · k` chunks in rank-major, stripe-minor order, trimmed of padding
/// (they concatenate to exactly `input.len()` elements).
pub fn ring_all_reduce_lanes_chunks<T: Elem, C: Comm<T>>(
    c: &mut C,
    input: Chunk<T>,
    combiner: &Combiner<T>,
    lanes: usize,
) -> Result<Vec<Chunk<T>>> {
    let k = effective_lanes(c, lanes);
    if k == 1 {
        return ring_all_reduce_chunks(c, input, combiner);
    }
    check_all_gather(input.as_slice())?;
    let p = c.size();
    let n = input.len();
    let padded = n.div_ceil(p) * p;
    let padded_input = if padded == n {
        input
    } else {
        pad_chunk(&input, padded)
    };
    let b = padded / p;
    let blocks = (0..p).map(|i| padded_input.slice(i * b, b)).collect();
    let mut blocks = run_ring(c, PlanKind::AllReduce, padded, k, blocks, Some(combiner))?;
    trim_blocks(&mut blocks, n);
    Ok(blocks)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::collectives::oracle;
    use crate::comm::CommWorld;
    use crate::reduction::offload::native_combine;

    fn inputs(p: usize, m: usize) -> Vec<Vec<f32>> {
        (0..p)
            .map(|r| (0..m).map(|i| (r * 100 + i) as f32).collect())
            .collect()
    }

    #[test]
    fn all_gather_matches_oracle() {
        for p in [1, 2, 3, 5, 8] {
            let m = 7;
            let world = CommWorld::<f32>::new(p);
            let outs = world.run(move |c| {
                let input: Vec<f32> = (0..m).map(|i| (c.rank() * 100 + i) as f32).collect();
                ring_all_gather(c, &input).unwrap()
            });
            let expect = oracle::all_gather(&inputs(p, m));
            for o in outs {
                assert_eq!(o, expect, "p={p}");
            }
        }
    }

    #[test]
    fn all_gather_chunks_preserve_block_order() {
        let p = 5;
        let world = CommWorld::<f32>::new(p);
        let outs = world.run(move |c| {
            let input = Chunk::from_vec(vec![c.rank() as f32; 3]);
            ring_all_gather_chunks(c, input).unwrap()
        });
        for blocks in outs {
            assert_eq!(blocks.len(), p);
            for (q, b) in blocks.iter().enumerate() {
                assert_eq!(b.as_slice(), &[q as f32; 3], "block {q}");
            }
        }
    }

    #[test]
    fn reduce_scatter_matches_oracle() {
        for p in [1, 2, 4, 6] {
            let b = 5;
            let world = CommWorld::<f32>::new(p);
            let outs = world.run(move |c| {
                let input: Vec<f32> = (0..p * b).map(|i| (c.rank() * 10 + i) as f32).collect();
                ring_reduce_scatter(c, &input, &native_combine()).unwrap()
            });
            let ins: Vec<Vec<f32>> = (0..p)
                .map(|r| (0..p * b).map(|i| (r * 10 + i) as f32).collect())
                .collect();
            for (r, o) in outs.iter().enumerate() {
                assert_eq!(o, &oracle::reduce_scatter(&ins, r), "p={p} r={r}");
            }
        }
    }

    #[test]
    fn all_reduce_handles_unaligned_len() {
        // n = 10 not divisible by p = 4 → internal padding.
        let p = 4;
        let n = 10;
        let world = CommWorld::<f32>::new(p);
        let outs = world.run(move |c| {
            let input: Vec<f32> = (0..n).map(|i| (c.rank() + i) as f32).collect();
            ring_all_reduce(c, &input, &native_combine()).unwrap()
        });
        let ins: Vec<Vec<f32>> = (0..p)
            .map(|r| (0..n).map(|i| (r + i) as f32).collect())
            .collect();
        let expect = oracle::all_reduce(&ins);
        for o in outs {
            assert_eq!(o, expect);
        }
    }

    #[test]
    fn lanes_reduce_scatter_matches_oracle_uneven_stripes() {
        // b = 5 with 4 lanes → stripe lens [2, 1, 1, 1]: uneven on purpose.
        for p in [2, 3, 6] {
            let b = 5;
            let world = CommWorld::<f32>::new(p).with_lanes(4);
            let outs = world.run(move |c| {
                let input: Vec<f32> = (0..p * b).map(|i| (c.rank() * 10 + i) as f32).collect();
                let stripes = ring_reduce_scatter_lanes_chunks(
                    c,
                    Chunk::from_vec(input),
                    &native_combine(),
                    4,
                )
                .unwrap();
                assert_eq!(stripes.len(), 4);
                Chunk::concat(&stripes)
            });
            let ins: Vec<Vec<f32>> = (0..p)
                .map(|r| (0..p * b).map(|i| (r * 10 + i) as f32).collect())
                .collect();
            for (r, o) in outs.iter().enumerate() {
                assert_eq!(o, &oracle::reduce_scatter(&ins, r), "p={p} r={r}");
            }
        }
    }

    #[test]
    fn lanes_all_gather_matches_oracle() {
        for p in [2, 3, 5] {
            let m = 7; // 3 lanes over 7 elems → [3, 2, 2]
            let world = CommWorld::<f32>::new(p).with_lanes(3);
            let outs = world.run(move |c| {
                let input: Vec<f32> = (0..m).map(|i| (c.rank() * 100 + i) as f32).collect();
                let blocks =
                    ring_all_gather_lanes_chunks(c, Chunk::from_vec(input), 3).unwrap();
                assert_eq!(blocks.len(), p * 3);
                Chunk::concat(&blocks)
            });
            let expect = oracle::all_gather(&inputs(p, m));
            for o in outs {
                assert_eq!(o, expect, "p={p}");
            }
        }
    }

    #[test]
    fn lanes_all_reduce_matches_oracle_unaligned() {
        // n = 10, p = 4 → padding; 4 lanes stripe the padded 3-elem blocks
        // as [1, 1, 1, 0] — empty stripes must flow through harmlessly.
        let p = 4;
        let n = 10;
        let world = CommWorld::<f32>::new(p).with_lanes(4);
        let outs = world.run(move |c| {
            let input: Vec<f32> = (0..n).map(|i| (c.rank() + i) as f32).collect();
            let blocks =
                ring_all_reduce_lanes_chunks(c, Chunk::from_vec(input), &native_combine(), 4)
                    .unwrap();
            Chunk::concat(&blocks)
        });
        let ins: Vec<Vec<f32>> = (0..p)
            .map(|r| (0..n).map(|i| (r + i) as f32).collect())
            .collect();
        let expect = oracle::all_reduce(&ins);
        for o in outs {
            assert_eq!(o, expect);
        }
    }

    #[test]
    fn lanes_clamp_to_single_lane_transport() {
        // Asking for 4 lanes on a 1-lane world must silently degrade to
        // the unstriped schedule, not fail.
        let p = 3;
        let b = 4;
        let world = CommWorld::<f32>::new(p);
        let outs = world.run(move |c| {
            let input: Vec<f32> = (0..p * b).map(|i| (c.rank() * 10 + i) as f32).collect();
            let stripes = ring_reduce_scatter_lanes_chunks(
                c,
                Chunk::from_vec(input),
                &native_combine(),
                4,
            )
            .unwrap();
            assert_eq!(stripes.len(), 1, "single-lane world must not stripe");
            Chunk::concat(&stripes)
        });
        let ins: Vec<Vec<f32>> = (0..p)
            .map(|r| (0..p * b).map(|i| (r * 10 + i) as f32).collect())
            .collect();
        for (r, o) in outs.iter().enumerate() {
            assert_eq!(o, &oracle::reduce_scatter(&ins, r));
        }
    }

    #[test]
    fn reduce_scatter_rejects_bad_len() {
        let world = CommWorld::<f32>::new(3);
        let errs = world.run(|c| {
            ring_reduce_scatter(c, &[1.0; 7], &native_combine())
                .err()
                .map(|e| e.to_string())
        });
        assert!(errs.iter().all(|e| e.is_some()));
    }
}
