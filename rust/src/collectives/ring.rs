//! Flat ring algorithms — bandwidth-optimal, latency linear in `p`
//! (Eq. 1 of the paper). This is what NCCL/RCCL use for all-gather and
//! reduce-scatter (Observation 2), and PCCL's `PCCL_ring` inter-node
//! backend.

use crate::comm::{Chunk, Comm};
use crate::error::Result;
use crate::reduction::offload::Combiner;
use crate::reduction::Elem;

use super::schedule::ring as idx;
use super::{
    check_all_gather, check_reduce_scatter, pad_chunk, slice_all_reduce, slice_gather,
    slice_reduce, trim_blocks,
};

/// Ring all-gather over the chunked plane: `p - 1` steps, each rank
/// forwards the *chunk* it received in the previous step to its right
/// neighbor — zero copies at every hop.
///
/// Returns the `p` per-rank blocks in origin-rank order; block `i` is
/// backed by rank `i`'s input storage (the zero-copy tests assert exactly
/// this identity).
pub fn ring_all_gather_chunks<T: Elem, C: Comm<T>>(
    c: &mut C,
    input: Chunk<T>,
) -> Result<Vec<Chunk<T>>> {
    check_all_gather(input.as_slice())?;
    c.begin_op();
    let p = c.size();
    let r = c.rank();
    let mut out: Vec<Option<Chunk<T>>> = vec![None; p];
    out[r] = Some(input.clone());
    if p > 1 {
        let right = (r + 1) % p;
        let left = (r + p - 1) % p;
        // Block (r - s) travels: at s = 0 it's our input; afterwards it's
        // the chunk that just arrived from the left, forwarded untouched.
        let mut current = input;
        for s in 0..p - 1 {
            debug_assert_eq!(idx::ag_send_block(r, p, s), (r + p - s) % p);
            let recv_b = idx::ag_recv_block(r, p, s);
            let got = c.sendrecv_chunk(right, current, left, s as u32)?;
            out[recv_b] = Some(got.clone());
            current = got;
        }
    }
    Ok(out
        .into_iter()
        .map(|b| b.expect("ring schedule covers every block"))
        .collect())
}

/// Ring all-gather, slice API — adapter over [`ring_all_gather_chunks`].
pub fn ring_all_gather<T: Elem, C: Comm<T>>(c: &mut C, input: &[T]) -> Result<Vec<T>> {
    slice_gather(input, |ch| ring_all_gather_chunks(c, ch))
}

/// Ring reduce-scatter over the chunked plane: `p - 1` steps; the partial
/// for each block travels once around the ring, combined at every hop (on
/// the "GPU" — the injected [`Combiner`]).
///
/// Hot-path note (§Perf): every step posts a view of this rank's own
/// contribution as the receive target and folds the incoming partial into
/// it via [`Comm::sendrecv_combine_into`]. At a partial's *first* combine
/// (incoming is still a shared view of the sender's input) the delivery is
/// a one-pass three-address fuse into fresh exact-size storage — one
/// allocation, zero verbatim copies; on every later hop the exclusive
/// traveling partial is taken over and folded in place, so the storage
/// created at the first combine survives every remaining hop. For `p > 1`
/// the returned chunk is the unique full-range view of that storage:
/// `into_vec` on it is a move, never a copy. At `p == 1` the input chunk
/// comes straight back.
pub fn ring_reduce_scatter_chunks<T: Elem, C: Comm<T>>(
    c: &mut C,
    input: Chunk<T>,
    combiner: &Combiner<T>,
) -> Result<Chunk<T>> {
    let p = c.size();
    let b = check_reduce_scatter(input.as_slice(), p)?;
    c.begin_op();
    let r = c.rank();
    if p == 1 {
        return Ok(input);
    }
    let right = (r + 1) % p;
    let left = (r + p - 1) % p;
    let first = idx::rs_send_block(r, p, 0);
    let mut current = input.slice(first * b, b);
    for s in 0..p - 1 {
        let recv_b = idx::rs_recv_block(r, p, s);
        // Post our own contribution for the arriving block as the receive
        // target; the incoming partial is folded straight into the
        // accumulator, never staged.
        let mut acc = input.slice(recv_b * b, b);
        c.sendrecv_combine_into(right, current, left, s as u32, &mut acc, combiner)?;
        current = acc;
    }
    debug_assert_eq!(idx::rs_recv_block(r, p, p - 2), r);
    Ok(current)
}

/// Ring reduce-scatter, slice API — adapter over
/// [`ring_reduce_scatter_chunks`].
pub fn ring_reduce_scatter<T: Elem, C: Comm<T>>(
    c: &mut C,
    input: &[T],
    combiner: &Combiner<T>,
) -> Result<Vec<T>> {
    slice_reduce(input, |ch| ring_reduce_scatter_chunks(c, ch, combiner))
}

/// Ring all-reduce over chunks = chunk reduce-scatter ∘ chunk all-gather
/// (the bandwidth-optimal Patarasuk–Yuan composition) with no intermediate
/// `Vec`: the reduced shard chunk feeds the gather directly. Unaligned
/// inputs are padded once into the chunk the reduce-scatter consumes, and
/// the padding is trimmed off the returned block list as a view
/// adjustment — the blocks concatenate to exactly `input.len()` elements.
///
/// The composition also runs at `p == 1` (both phases degenerate to
/// zero-message ops), so op-sequence numbering advances identically for
/// every communicator size.
pub fn ring_all_reduce_chunks<T: Elem, C: Comm<T>>(
    c: &mut C,
    input: Chunk<T>,
    combiner: &Combiner<T>,
) -> Result<Vec<Chunk<T>>> {
    check_all_gather(input.as_slice())?;
    let p = c.size();
    let n = input.len();
    let padded = n.div_ceil(p) * p;
    // §Perf: pad at most once, straight into the reduce-scatter input.
    let padded_input = if padded == n {
        input
    } else {
        pad_chunk(&input, padded)
    };
    let mine = ring_reduce_scatter_chunks(c, padded_input, combiner)?;
    let mut blocks = ring_all_gather_chunks(c, mine)?;
    trim_blocks(&mut blocks, n);
    Ok(blocks)
}

/// Ring all-reduce, slice API — adapter over [`ring_all_reduce_chunks`].
pub fn ring_all_reduce<T: Elem, C: Comm<T>>(
    c: &mut C,
    input: &[T],
    combiner: &Combiner<T>,
) -> Result<Vec<T>> {
    slice_all_reduce(input, |ch| ring_all_reduce_chunks(c, ch, combiner))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::collectives::oracle;
    use crate::comm::CommWorld;
    use crate::reduction::offload::native_combine;

    fn inputs(p: usize, m: usize) -> Vec<Vec<f32>> {
        (0..p)
            .map(|r| (0..m).map(|i| (r * 100 + i) as f32).collect())
            .collect()
    }

    #[test]
    fn all_gather_matches_oracle() {
        for p in [1, 2, 3, 5, 8] {
            let m = 7;
            let world = CommWorld::<f32>::new(p);
            let outs = world.run(move |c| {
                let input: Vec<f32> = (0..m).map(|i| (c.rank() * 100 + i) as f32).collect();
                ring_all_gather(c, &input).unwrap()
            });
            let expect = oracle::all_gather(&inputs(p, m));
            for o in outs {
                assert_eq!(o, expect, "p={p}");
            }
        }
    }

    #[test]
    fn all_gather_chunks_preserve_block_order() {
        let p = 5;
        let world = CommWorld::<f32>::new(p);
        let outs = world.run(move |c| {
            let input = Chunk::from_vec(vec![c.rank() as f32; 3]);
            ring_all_gather_chunks(c, input).unwrap()
        });
        for blocks in outs {
            assert_eq!(blocks.len(), p);
            for (q, b) in blocks.iter().enumerate() {
                assert_eq!(b.as_slice(), &[q as f32; 3], "block {q}");
            }
        }
    }

    #[test]
    fn reduce_scatter_matches_oracle() {
        for p in [1, 2, 4, 6] {
            let b = 5;
            let world = CommWorld::<f32>::new(p);
            let outs = world.run(move |c| {
                let input: Vec<f32> = (0..p * b).map(|i| (c.rank() * 10 + i) as f32).collect();
                ring_reduce_scatter(c, &input, &native_combine()).unwrap()
            });
            let ins: Vec<Vec<f32>> = (0..p)
                .map(|r| (0..p * b).map(|i| (r * 10 + i) as f32).collect())
                .collect();
            for (r, o) in outs.iter().enumerate() {
                assert_eq!(o, &oracle::reduce_scatter(&ins, r), "p={p} r={r}");
            }
        }
    }

    #[test]
    fn all_reduce_handles_unaligned_len() {
        // n = 10 not divisible by p = 4 → internal padding.
        let p = 4;
        let n = 10;
        let world = CommWorld::<f32>::new(p);
        let outs = world.run(move |c| {
            let input: Vec<f32> = (0..n).map(|i| (c.rank() + i) as f32).collect();
            ring_all_reduce(c, &input, &native_combine()).unwrap()
        });
        let ins: Vec<Vec<f32>> = (0..p)
            .map(|r| (0..n).map(|i| (r + i) as f32).collect())
            .collect();
        let expect = oracle::all_reduce(&ins);
        for o in outs {
            assert_eq!(o, expect);
        }
    }

    #[test]
    fn reduce_scatter_rejects_bad_len() {
        let world = CommWorld::<f32>::new(3);
        let errs = world.run(|c| {
            ring_reduce_scatter(c, &[1.0; 7], &native_combine())
                .err()
                .map(|e| e.to_string())
        });
        assert!(errs.iter().all(|e| e.is_some()));
    }
}
