//! Binomial-tree reduce + broadcast all-reduce.
//!
//! NCCL/RCCL implement all-reduce with double binary trees [15], giving
//! log-latency scaling (which is why the paper's all-reduce speedups are
//! much smaller than its all-gather/reduce-scatter ones). The data-plane
//! stand-in here is a binomial reduce-to-root followed by a binomial
//! broadcast — the same `O(log p)` step structure; the netsim library
//! models use the proper double-binary-tree cost.
//!
//! The schedule is lowered by [`super::plan`]'s tree builder and executed
//! by [`super::engine`]. Over the chunked plane the reduce phase *posts*
//! the local accumulator as the receive target for every child's partial
//! (lowered `RecvCombine` ops on [`Comm::recv_combine_into`]): the first
//! delivery into a still-shared accumulator is a one-pass fuse into fresh
//! storage, every later child is folded in place, and a leaf's
//! contribution leaves as a zero-copy moved send — no rank ever
//! materializes a staging vector. The broadcast phase fans the reduced
//! chunk out as zero-copy clones.

use crate::comm::{Chunk, Comm};
use crate::error::Result;
use crate::reduction::offload::Combiner;
use crate::reduction::Elem;

use super::engine;
use super::plan::{self, Algo, PlanKind, PlanSpec};
use super::slice_reduce;

/// Binomial-tree all-reduce over chunks, any communicator size.
///
/// Consumes the input chunk as the reduction accumulator: on ranks that
/// receive (rank 0 and interior nodes) children's partials are delivered
/// straight into it via posted combining receives; on leaf ranks it is
/// sent up the tree as-is. Every rank returns the same reduced chunk; for
/// `p > 1` on rank 0 that is the accumulator itself, elsewhere the
/// broadcast-delivered view (shared with this rank's children until their
/// references drop).
pub fn tree_all_reduce_chunks<T: Elem, C: Comm<T>>(
    c: &mut C,
    input: Chunk<T>,
    combiner: &Combiner<T>,
) -> Result<Chunk<T>> {
    super::check_all_gather(input.as_slice())?;
    let spec = PlanSpec::flat(PlanKind::AllReduce, Algo::Tree, c.size(), input.len(), 1);
    plan::verify_cached(&spec)?;
    let pl = plan::build(&spec, c.rank())?;
    let mut out = engine::run_flat(c, &pl, vec![input], Some(combiner))?;
    debug_assert_eq!(out.len(), 1, "tree all-reduce yields one chunk");
    Ok(out.pop().expect("tree plan outputs the reduced buffer"))
}

/// Binomial-tree all-reduce, slice API — adapter over
/// [`tree_all_reduce_chunks`].
pub fn tree_all_reduce<T: Elem, C: Comm<T>>(
    c: &mut C,
    input: &[T],
    combiner: &Combiner<T>,
) -> Result<Vec<T>> {
    slice_reduce(input, |ch| tree_all_reduce_chunks(c, ch, combiner))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::collectives::oracle;
    use crate::comm::CommWorld;
    use crate::reduction::offload::native_combine;

    #[test]
    fn tree_all_reduce_all_sizes() {
        for p in 1..=9usize {
            let n = 5;
            let world = CommWorld::<f32>::new(p);
            let outs = world.run(move |c| {
                let input: Vec<f32> = (0..n).map(|i| (c.rank() * 10 + i) as f32).collect();
                tree_all_reduce(c, &input, &native_combine()).unwrap()
            });
            let ins: Vec<Vec<f32>> = (0..p)
                .map(|r| (0..n).map(|i| (r * 10 + i) as f32).collect())
                .collect();
            let expect = oracle::all_reduce(&ins);
            for (r, o) in outs.iter().enumerate() {
                assert_eq!(o, &expect, "p={p} r={r}");
            }
        }
    }

    #[test]
    fn tree_chunks_root_keeps_accumulator_storage() {
        // Rank 0's result must be the very storage its accumulator used —
        // the reduce phase folds children in place, never re-materializes.
        let p = 4;
        let world = CommWorld::<f32>::new(p);
        let outs = world.run(move |c| {
            let input = Chunk::from_vec(vec![c.rank() as f32; 3]);
            let own_id = input.storage_id();
            let out = tree_all_reduce_chunks(c, input, &native_combine()).unwrap();
            (c.rank(), own_id, out.storage_id(), out.as_slice().to_vec())
        });
        for (r, own_id, out_id, vals) in outs {
            assert_eq!(vals, vec![6.0; 3], "r={r}");
            if r == 0 {
                assert_eq!(own_id, out_id, "root re-materialized its accumulator");
            }
        }
    }
}
