//! Binomial-tree reduce + broadcast all-reduce.
//!
//! NCCL/RCCL implement all-reduce with double binary trees [15], giving
//! log-latency scaling (which is why the paper's all-reduce speedups are
//! much smaller than its all-gather/reduce-scatter ones). The data-plane
//! stand-in here is a binomial reduce-to-root followed by a binomial
//! broadcast — the same `O(log p)` step structure; the netsim library
//! models use the proper double-binary-tree cost.
//!
//! Over the chunked plane the reduce phase *posts* the local accumulator
//! as the receive target for every child's partial
//! ([`Comm::recv_combine_into`]): the first delivery into a still-shared
//! accumulator is a one-pass fuse into fresh storage, every later child is
//! folded in place, and a leaf's contribution leaves as a zero-copy view —
//! no rank ever materializes a staging vector (the seed path paid a
//! `to_vec` of the input on every rank plus an owned-Vec send per leaf).
//! The broadcast phase fans the reduced chunk out as zero-copy clones.

use crate::comm::{Chunk, Comm};
use crate::error::Result;
use crate::reduction::offload::Combiner;
use crate::reduction::Elem;

use super::slice_reduce;

/// Binomial-tree all-reduce over chunks, any communicator size.
///
/// Consumes the input chunk as the reduction accumulator: on ranks that
/// receive (rank 0 and interior nodes) children's partials are delivered
/// straight into it via [`Comm::recv_combine_into`]; on leaf ranks it is
/// sent up the tree as-is. Every rank returns the same reduced chunk; for
/// `p > 1` on rank 0 that is the accumulator itself, elsewhere the
/// broadcast-delivered view (shared with this rank's children until their
/// references drop).
pub fn tree_all_reduce_chunks<T: Elem, C: Comm<T>>(
    c: &mut C,
    input: Chunk<T>,
    combiner: &Combiner<T>,
) -> Result<Chunk<T>> {
    super::check_all_gather(input.as_slice())?;
    c.begin_op();
    let p = c.size();
    let r = c.rank();
    if p == 1 {
        return Ok(input);
    }
    // `Some` until the accumulator is sent up the tree — i.e. exactly on
    // rank 0 once phase 1 completes.
    let mut acc = Some(input);

    // Phase 1: binomial reduce toward rank 0.
    let mut mask = 1usize;
    let mut recv_mask = p.next_power_of_two(); // where *we* sent (root: never)
    while mask < p {
        let step = mask.trailing_zeros();
        if r & mask != 0 {
            let dst = r & !mask;
            // Move the accumulator up (we receive the final value in
            // phase 2) — a zero-copy post of whatever storage it holds.
            c.send_slice(dst, step, acc.take().expect("accumulator live until sent"))?;
            recv_mask = mask;
            break;
        }
        let src = r | mask;
        if src < p {
            let dest = acc.as_mut().expect("receiving rank still holds accumulator");
            c.recv_combine_into(src, step, dest, combiner)?;
        }
        mask <<= 1;
    }

    // Phase 2: binomial broadcast from rank 0 (mirror of phase 1).
    let result = match acc {
        Some(chunk) => chunk, // rank 0
        None => {
            // Receive the final value from the rank we reduced into.
            let src = r & !(recv_mask);
            let step = 0x100 + recv_mask.trailing_zeros();
            c.recv_chunk(src, step)?
        }
    };
    // Root keeps its initial recv_mask = next_power_of_two(p).
    let mut child_mask = recv_mask >> 1;
    while child_mask > 0 {
        let dst = r | child_mask;
        if dst != r && dst < p {
            let step = 0x100 + child_mask.trailing_zeros();
            c.send_slice(dst, step, result.clone())?;
        }
        child_mask >>= 1;
    }
    Ok(result)
}

/// Binomial-tree all-reduce, slice API — adapter over
/// [`tree_all_reduce_chunks`].
pub fn tree_all_reduce<T: Elem, C: Comm<T>>(
    c: &mut C,
    input: &[T],
    combiner: &Combiner<T>,
) -> Result<Vec<T>> {
    slice_reduce(input, |ch| tree_all_reduce_chunks(c, ch, combiner))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::collectives::oracle;
    use crate::comm::CommWorld;
    use crate::reduction::offload::native_combine;

    #[test]
    fn tree_all_reduce_all_sizes() {
        for p in 1..=9usize {
            let n = 5;
            let world = CommWorld::<f32>::new(p);
            let outs = world.run(move |c| {
                let input: Vec<f32> = (0..n).map(|i| (c.rank() * 10 + i) as f32).collect();
                tree_all_reduce(c, &input, &native_combine()).unwrap()
            });
            let ins: Vec<Vec<f32>> = (0..p)
                .map(|r| (0..n).map(|i| (r * 10 + i) as f32).collect())
                .collect();
            let expect = oracle::all_reduce(&ins);
            for (r, o) in outs.iter().enumerate() {
                assert_eq!(o, &expect, "p={p} r={r}");
            }
        }
    }

    #[test]
    fn tree_chunks_root_keeps_accumulator_storage() {
        // Rank 0's result must be the very storage its accumulator used —
        // the reduce phase folds children in place, never re-materializes.
        let p = 4;
        let world = CommWorld::<f32>::new(p);
        let outs = world.run(move |c| {
            let input = Chunk::from_vec(vec![c.rank() as f32; 3]);
            let own_id = input.storage_id();
            let out = tree_all_reduce_chunks(c, input, &native_combine()).unwrap();
            (c.rank(), own_id, out.storage_id(), out.as_slice().to_vec())
        });
        for (r, own_id, out_id, vals) in outs {
            assert_eq!(vals, vec![6.0; 3], "r={r}");
            if r == 0 {
                assert_eq!(own_id, out_id, "root re-materialized its accumulator");
            }
        }
    }
}
