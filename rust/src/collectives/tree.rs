//! Binomial-tree reduce + broadcast all-reduce.
//!
//! NCCL/RCCL implement all-reduce with double binary trees [15], giving
//! log-latency scaling (which is why the paper's all-reduce speedups are
//! much smaller than its all-gather/reduce-scatter ones). The data-plane
//! stand-in here is a binomial reduce-to-root followed by a binomial
//! broadcast — the same `O(log p)` step structure; the netsim library
//! models use the proper double-binary-tree cost.
//!
//! Over the chunked plane the broadcast phase fans the reduced buffer out
//! as zero-copy chunk clones (the seed path cloned the full vector per
//! child); the reduce phase combines received chunks straight into the
//! local accumulator without materializing them.

use crate::comm::{Chunk, Comm};
use crate::error::Result;
use crate::reduction::offload::CombineFn;
use crate::reduction::Elem;

/// Binomial-tree all-reduce, any communicator size.
pub fn tree_all_reduce<T: Elem, C: Comm<T>>(
    c: &mut C,
    input: &[T],
    combine: &CombineFn<T>,
) -> Result<Vec<T>> {
    super::check_all_gather(input)?;
    c.begin_op();
    let p = c.size();
    let r = c.rank();
    let mut acc = input.to_vec();
    if p == 1 {
        return Ok(acc);
    }

    // Phase 1: binomial reduce toward rank 0.
    let mut mask = 1usize;
    let mut recv_mask = p.next_power_of_two(); // where *we* sent (root: never)
    while mask < p {
        let step = mask.trailing_zeros();
        if r & mask != 0 {
            let dst = r & !mask;
            // Move the accumulator (we receive the final value in phase 2).
            c.send(dst, step, std::mem::take(&mut acc))?;
            recv_mask = mask;
            break;
        }
        let src = r | mask;
        if src < p {
            let got = c.recv_chunk(src, step)?;
            combine(&mut acc, got.as_slice());
        }
        mask <<= 1;
    }

    // Phase 2: binomial broadcast from rank 0 (mirror of phase 1).
    let result = if r == 0 {
        Chunk::from_vec(acc)
    } else {
        // Receive the final value from the rank we reduced into.
        let src = r & !(recv_mask);
        let step = 0x100 + recv_mask.trailing_zeros();
        c.recv_chunk(src, step)?
    };
    // Root keeps its initial recv_mask = next_power_of_two(p).
    let mut child_mask = recv_mask >> 1;
    while child_mask > 0 {
        let dst = r | child_mask;
        if dst != r && dst < p {
            let step = 0x100 + child_mask.trailing_zeros();
            c.send_slice(dst, step, result.clone())?;
        }
        child_mask >>= 1;
    }
    Ok(result.into_vec())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::collectives::oracle;
    use crate::comm::CommWorld;
    use crate::reduction::offload::native_combine;

    #[test]
    fn tree_all_reduce_all_sizes() {
        for p in 1..=9usize {
            let n = 5;
            let world = CommWorld::<f32>::new(p);
            let outs = world.run(move |c| {
                let input: Vec<f32> = (0..n).map(|i| (c.rank() * 10 + i) as f32).collect();
                tree_all_reduce(c, &input, &native_combine()).unwrap()
            });
            let ins: Vec<Vec<f32>> = (0..p)
                .map(|r| (0..n).map(|i| (r * 10 + i) as f32).collect())
                .collect();
            let expect = oracle::all_reduce(&ins);
            for (r, o) in outs.iter().enumerate() {
                assert_eq!(o, &expect, "p={p} r={r}");
            }
        }
    }
}
