//! Naive reference semantics — the single source of truth the property
//! tests compare every algorithm against. Pure functions over the per-rank
//! input vectors (no communication).

use crate::reduction::{Elem, ReduceOp};

/// Expected all-gather output (identical on every rank).
pub fn all_gather<T: Elem>(inputs: &[Vec<T>]) -> Vec<T> {
    let mut out = Vec::with_capacity(inputs.iter().map(Vec::len).sum());
    for inp in inputs {
        out.extend_from_slice(inp);
    }
    out
}

/// Expected reduce-scatter output for `rank` (sum reduction).
pub fn reduce_scatter<T: Elem>(inputs: &[Vec<T>], rank: usize) -> Vec<T> {
    reduce_scatter_op(inputs, rank, ReduceOp::Sum)
}

/// Expected reduce-scatter output for `rank` under `op`.
pub fn reduce_scatter_op<T: Elem>(inputs: &[Vec<T>], rank: usize, op: ReduceOp) -> Vec<T> {
    let p = inputs.len();
    let b = inputs[0].len() / p;
    let mut out = inputs[0][rank * b..(rank + 1) * b].to_vec();
    for inp in &inputs[1..] {
        let block = &inp[rank * b..(rank + 1) * b];
        crate::reduction::reduce_into_op(&mut out, block, op);
    }
    out
}

/// Expected all-reduce output (identical on every rank, sum reduction).
pub fn all_reduce<T: Elem>(inputs: &[Vec<T>]) -> Vec<T> {
    let mut out = inputs[0].clone();
    for inp in &inputs[1..] {
        crate::reduction::reduce_into(&mut out, inp);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shapes() {
        let ins = vec![vec![1.0f32, 2.0], vec![3.0, 4.0]];
        assert_eq!(all_gather(&ins), vec![1.0, 2.0, 3.0, 4.0]);
        assert_eq!(reduce_scatter(&ins, 0), vec![4.0]);
        assert_eq!(reduce_scatter(&ins, 1), vec![6.0]);
        assert_eq!(all_reduce(&ins), vec![4.0, 6.0]);
    }
}
