//! Collective algorithms, organized as a **builder → verifier → engine →
//! tracer** pipeline.
//!
//! Every collective is *lowered*, not hand-coded: a [`plan::PlanSpec`]
//! (kind × algorithm × world shape) is compiled by [`plan::build`] into a
//! declarative per-rank [`plan::Plan`] — a slot table plus a flat op list
//! of sends, posted receives, and posted combining receives — and a
//! single interpreter, [`engine`], executes any plan against the
//! [`crate::comm::Comm`] trait. The public entry points in this module
//! are thin shells: validate the input, build the spec, run it through
//! the statically-memoized verifier ([`plan::verify_cached`] simulates
//! all `p` ranks in lockstep and proves deadlock-freedom, exactly-once
//! block coverage, and byte-exactness against
//! [`crate::runtime::expected_schedule_bytes`]), then hand the lowered
//! plan and the input chunks to the engine. The network simulator costs
//! the *same* plan objects ([`plan::phase_shapes`]), so the schedule that
//! is verified is the schedule that is timed and the schedule that runs.
//!
//! The fourth stage closes the loop at run time: when a thread-local
//! tracer is installed ([`crate::trace::begin`] / [`crate::trace::end`]),
//! the engine records one span per executed op — kind, peer, lanes,
//! sent/received/combined bytes, wall-clock timings — with phase and
//! round indices mirrored from the very `phase_shapes` walk the netsim
//! costs. [`crate::trace::check_phases`] then compares the observed
//! per-round byte movement byte-exactly against the verified plan, so a
//! traced run that executes anything other than its lowered schedule is
//! an error, not a mystery; [`crate::trace::chrome_trace_doc`] exports
//! the spans as chrome://tracing JSON (`pccl trace`, and
//! `BENCH_smoke.trace.json` from `pccl smoke`). With no tracer installed
//! the engine pays one `Option` check per op — the launcher traces only
//! a dedicated extra trial, never the timed loop.
//!
//! Eight algorithm families lower through the IR: flat ring, recursive
//! doubling/halving, the two-level hierarchical forms (ring or recursive
//! inter-node phase — one multi-phase plan each), the binomial tree
//! all-reduce, the rooted pt2pt collectives, the device-local shuffle,
//! and the lane-striped variants of all of the above. The index math
//! they share lives in [`schedule`]; the plan builders consume it, and
//! the property tests replay it independently against the lowered ops.
//!
//! The `*_chunks` functions are the **canonical signatures**: chunk in,
//! chunk(s) out, zero-copy end to end. The borrowed-slice entry points are
//! thin adapters over them, generated through exactly three shared
//! wrappers — [`slice_gather`], [`slice_reduce`], and [`slice_all_reduce`]
//! — so the "wrap input, run, materialize output" boilerplate lives in one
//! place (the backends dispatch layer routes its slice API through the
//! same three).
//!
//! Semantics (MPI-style, out-of-place):
//! * `all_gather`: input `m` elements/rank → output `p·m`, block `i` is
//!   rank `i`'s input.
//! * `reduce_scatter`: input `p·b` elements/rank → output `b`, rank `r`
//!   receives the elementwise reduction of every rank's block `r`.
//! * `all_reduce`: input `n` → output `n`, elementwise reduction across all
//!   ranks (implemented as reduce-scatter ∘ all-gather when `p | n`).
//!
//! ## Chunk ownership model (zero-copy data plane)
//!
//! Messages are [`crate::comm::Chunk`]s: `Arc`-backed storage plus an
//! `(offset, len)` view, with O(1) `clone`/`slice`/`split`. The rules the
//! algorithms follow:
//!
//! * **Forward, don't copy, when data passes through untouched.** Ring and
//!   recursive all-gather re-send the received chunk; the hierarchical
//!   all-gather forwards the inter-phase views through the intra ring and
//!   performs its unshuffle as a pointer permutation; broadcast fans one
//!   chunk down the whole binomial tree. The `*_chunks` entry points
//!   ([`ring_all_gather_chunks`], [`rec_all_gather_chunks`],
//!   [`hier_all_gather_chunks`]) expose this: every returned block is
//!   backed by the origin rank's input storage.
//! * **Reduce through posted receives — never stage.** Reductions write
//!   new data at every hop by definition, so the reduce loops post the
//!   accumulator's storage as the receive target and fold the incoming
//!   partial into it ([`crate::comm::Comm::recv_combine_into`] /
//!   [`crate::comm::Comm::sendrecv_combine_into`]). Delivery picks the
//!   cheapest legal case by storage exclusivity (see
//!   [`crate::comm::Chunk::accept_combine`]): in place into an exclusive
//!   accumulator, take-over of an exclusive incoming partial, or — at a
//!   partial's *first* combine, where both operands are still shared COW
//!   views — a one-pass three-address fuse into fresh exact-size storage
//!   (one allocation, zero verbatim copies; this replaced the
//!   copy-then-fold that `make_mut_exact` used to pay). The
//!   `*_reduce_scatter_chunks` entry points ([`ring_reduce_scatter_chunks`],
//!   [`rec_reduce_scatter_chunks`], [`hier_reduce_scatter_chunks`]) return
//!   that traveling partial directly: for `p > 1` the result is always the
//!   unique full-range view of transport-delivered storage, so
//!   [`crate::comm::Chunk::into_vec`] on it is a move, never a copy (at
//!   `p == 1` the input chunk itself comes back). The slice-API wrappers
//!   pay exactly two copies: wrapping the borrowed input into a chunk and
//!   materializing the output.
//!
//! ### Posted-receive rules
//!
//! * **Only the posting rank writes into a posted buffer.** A `&mut
//!   Chunk<T>` handed to `recv_into`/`recv_combine_into` is written by the
//!   receiving endpoint alone, and only between post and completion (the
//!   calls are blocking, so completion is the return). Senders never gain
//!   write access to remote storage — delivery either *moves the incoming
//!   reference into the posted slot* or writes through the post's own
//!   (COW-resolved) storage.
//! * **COW protects in-flight peer reads.** If the posted chunk's storage
//!   is shared — e.g. it is a view of the rank's live input, or a peer
//!   still holds a reference to a chunk this rank forwarded — the delivery
//!   path never writes that storage in place: `accept` copies into fresh
//!   COW storage and `accept_combine` fuses into a fresh allocation, so a
//!   peer concurrently reading the old storage always observes the
//!   original bytes. In-place writes happen only when the accumulator is
//!   provably exclusive ([`crate::comm::Chunk::is_exclusive`]).
//! * **Shape is checked before delivery.** A posted buffer whose length
//!   differs from the incoming chunk yields a typed
//!   [`Error::RecvShapeMismatch`](crate::error::Error::RecvShapeMismatch)
//!   and the message stays queued — nothing is partially written.
//! * **Combines must be commutative.** The take-over case folds in the
//!   opposite operand order; sum/max/min (including two-operand IEEE-754
//!   addition) all qualify.
//! * **All-reduce composes chunk-native.** `*_all_reduce_chunks` is chunk
//!   reduce-scatter ∘ chunk all-gather with no intermediate `Vec`: the
//!   reduced shard chunk feeds the gather directly, unaligned inputs are
//!   padded **once** into the chunk the reduce-scatter consumes
//!   ([`pad_chunk`]), and the trailing padding is trimmed off the returned
//!   block list as an O(1) view adjustment ([`trim_blocks`] — no
//!   truncation copy). The composition also runs at `p == 1`, so the
//!   op-sequence numbering (and therefore every wire tag) advances
//!   identically for every communicator size.
//! * **Rooted data must be owned per destination.** Scatter materializes
//!   one block per peer (the source lives in the root's borrowed input);
//!   gather copies received blocks into the root's contiguous output.
//!
//! ## Lane/stripe ownership model (multi-lane transport)
//!
//! The `*_lanes_chunks` entry points run `k` **lane-parallel rings over
//! disjoint stripes** of the payload, NCCL-channel style, one ring per
//! transport lane:
//!
//! * **Stripes are views, never copies.** [`crate::comm::Chunk::stripes`]
//!   splits a chunk into `k` contiguous sub-views of the same storage
//!   (uneven lengths allowed: the first `len % k` stripes carry one extra
//!   element, and stripes may be zero-length so every lane keeps the same
//!   schedule). Striping on the send side is O(1); no element moves.
//! * **Each stripe is owned by exactly one lane.** Stripe `l` of every
//!   message travels on lane `l` for the whole collective: it has its own
//!   per-(pair, lane) transport queue, its own wire tag
//!   ([`crate::comm::Communicator::lane_comm`] folds the lane id into the
//!   FNV tag chain), and — for reductions — its own posted
//!   `accept_combine` executed on that lane's worker thread. Lane 0 is
//!   served inline by the posting rank thread, so a 1-lane world is
//!   byte-for-byte the single-queue transport.
//! * **Lane schedules are independent and equivalent.** Each lane runs the
//!   *same* ring schedule over its stripe; correctness of the striped
//!   collective reduces to correctness of the unstriped one per stripe.
//!   The striped reduce-scatter therefore returns `k` stripe chunks (one
//!   per lane — they live in distinct transport-delivered storages by
//!   construction; concatenating them would be the only copy, so the
//!   caller decides). Striped all-gather/all-reduce return `p·k` blocks,
//!   rank-major stripe-minor.
//! * **Striping is a dispatch decision.** The backends auto path stripes
//!   only above a minimum stripe size (tiny messages gain nothing from
//!   extra rails); `lanes = 1` (or `k == 1` after clamping to the
//!   transport's lane count) delegates straight to the unstriped
//!   algorithm, tags and all.
//!
//! ## Failure model (bounded-time collective abort)
//!
//! Collectives are all-or-nothing: either every rank completes its
//! verified schedule, or every *surviving* rank returns the typed
//! [`Error::CollectiveAborted`](crate::error::Error::CollectiveAborted)
//! within a bounded detection window — never a hang, never a silently
//! wrong answer.
//!
//! * **Fault taxonomy.** The transport's deterministic injection harness
//!   ([`crate::comm::FaultPlan`]) models six failures: a *dropped* message
//!   (counted as sent, lost on the wire — detected by the peer's receive
//!   timeout), a *delayed* delivery, a *duplicated* message (harmless by
//!   construction: wire tags are FNV-chained per epoch/op/step/lane, so a
//!   stale copy can never satisfy a later receive), a *corrupted* payload
//!   (length-visible truncation, caught by the posted-receive shape check
//!   as `RecvShapeMismatch` before anything is folded), a *killed rank*
//!   (every subsequent operation on the rank fails and it never announces
//!   its own death — peers must detect it by timeout, like a real dead
//!   host), and a *stalled lane worker* (a slow rail: survivable when it
//!   wakes within the receive timeout, a typed
//!   [`Error::LaneWorkerLost`](crate::error::Error::LaneWorkerLost) when
//!   it misses the configurable shutdown grace).
//! * **Abort protocol.** [`engine::exec`] is the single conversion point:
//!   when any op fails on a communicator armed with an
//!   [`crate::comm::AbortToken`], the engine broadcasts a poison control
//!   message on the reserved ctrl-tag namespace (top 32 tag bits set, the
//!   epoch in the low bits — unreachable by data traffic), trips the
//!   shared token, and returns `CollectiveAborted { origin_rank, op_seq,
//!   cause }`. Peers parked in receives poll the token between short
//!   slices (25 ms default), so they observe the abort at poll
//!   granularity instead of sleeping out their own receive timeout; a
//!   fault only *one* rank can see (a kill) is detected by its neighbors'
//!   timeout and then propagated the same way. Detection is therefore
//!   bounded by `recv_timeout + poll`, not by the 60 s default timeout —
//!   `pccl chaos` asserts the bound with a wall clock.
//! * **Epoch/tag rules.** Every wire tag folds in the communicator's
//!   epoch. Recovery ([`crate::comm::Communicator::bump_epoch`], run on
//!   every rank by [`crate::runtime::PersistentWorld`] after an aborted
//!   trial) advances the epoch, re-derives the tag context, resets the op
//!   sequence, clears armed faults, and drains the queues — so a straggler
//!   message or poison from the aborted epoch is unmatchable garbage that
//!   the pull loops discard on sight, and the next collective starts from
//!   aligned, empty state.
//! * **Shrink guarantees.** [`crate::comm::Communicator::shrink`] rebuilds
//!   a dense survivor world around dead ranks (ascending survivor order,
//!   fresh epoch, drained queues) as a [`crate::comm::SubComm`]; a dead
//!   rank cannot shrink around itself. The survivors' next collective is
//!   correct and isolated from the failed epoch's traffic — `pccl chaos`
//!   and `rust/tests/failure_injection.rs` exercise the full
//!   die → detect → abort → shrink → recompute arc.

pub mod engine;
mod hierarchical;
pub mod oracle;
mod pccl;
mod pipelined;
pub mod plan;
mod pt2pt;
mod recursive;
mod ring;
pub mod schedule;
mod shuffle;
mod tree;

pub use hierarchical::{
    hier_all_gather, hier_all_gather_chunks, hier_all_gather_lanes_chunks, hier_all_reduce,
    hier_all_reduce_chunks, hier_all_reduce_lanes_chunks, hier_reduce_scatter,
    hier_reduce_scatter_chunks, hier_reduce_scatter_lanes_chunks, InterAlgo,
};
pub use pccl::Pccl;
pub use pipelined::{
    pipelined_hier_all_gather, pipelined_hier_all_reduce, pipelined_hier_all_reduce_chunks,
    pipelined_hier_all_reduce_lanes_chunks, pipelined_hier_reduce_scatter,
    pipelined_hier_reduce_scatter_chunks,
};
pub use pt2pt::{broadcast, gather, reduce, scatter};
pub use recursive::{
    rec_all_gather, rec_all_gather_chunks, rec_all_reduce, rec_all_reduce_chunks,
    rec_reduce_scatter, rec_reduce_scatter_chunks,
};
pub use ring::{
    ring_all_gather, ring_all_gather_chunks, ring_all_gather_lanes_chunks, ring_all_reduce,
    ring_all_reduce_chunks, ring_all_reduce_lanes_chunks, ring_reduce_scatter,
    ring_reduce_scatter_blocks_chunks, ring_reduce_scatter_blocks_lanes_chunks,
    ring_reduce_scatter_chunks, ring_reduce_scatter_lanes_chunks,
};
pub use shuffle::{shuffle_gather, transpose_blocks, transpose_chunk_blocks, unshuffle};
pub use tree::{tree_all_reduce, tree_all_reduce_chunks};

use crate::comm::Chunk;
use crate::error::{Error, Result};
use crate::reduction::Elem;

/// Validate an all-gather input (any non-empty block is fine).
pub(crate) fn check_all_gather<T>(input: &[T]) -> Result<()> {
    if input.is_empty() {
        return Err(Error::BadBufferSize {
            len: 0,
            size: 0,
            why: "all-gather input must be non-empty",
        });
    }
    Ok(())
}

/// Validate a reduce-scatter input: length divisible by communicator size.
pub(crate) fn check_reduce_scatter<T>(input: &[T], p: usize) -> Result<usize> {
    if input.is_empty() || input.len() % p != 0 {
        return Err(Error::BadBufferSize {
            len: input.len(),
            size: p,
            why: "reduce-scatter input length must be a positive multiple of communicator size",
        });
    }
    Ok(input.len() / p)
}

/// Slice adapter for gather-style chunk collectives (all-gather): wrap the
/// borrowed input once, run the chunk-native algorithm, concatenate the
/// returned blocks. The wrap and the concat are the only copies on the
/// path — every slice-API collective pays exactly these two.
pub fn slice_gather<T, F>(input: &[T], run: F) -> Result<Vec<T>>
where
    T: Clone,
    F: FnOnce(Chunk<T>) -> Result<Vec<Chunk<T>>>,
{
    Ok(Chunk::concat(&run(Chunk::from_slice(input))?))
}

/// Slice adapter for reduce-style chunk collectives (reduce-scatter): wrap
/// the borrowed input once, run, move the reduced shard out. The output
/// materialization is a move for `p > 1` (the shard is the unique
/// full-range view of transport-delivered storage).
pub fn slice_reduce<T, F>(input: &[T], run: F) -> Result<Vec<T>>
where
    T: Clone,
    F: FnOnce(Chunk<T>) -> Result<Chunk<T>>,
{
    Ok(run(Chunk::from_slice(input))?.into_vec())
}

/// Slice adapter for all-reduce-style chunk collectives (block-list out):
/// wrap once, run, materialize the rank-ordered block list (a move when
/// the algorithm returns a single block).
pub fn slice_all_reduce<T, F>(input: &[T], run: F) -> Result<Vec<T>>
where
    T: Clone,
    F: FnOnce(Chunk<T>) -> Result<Vec<Chunk<T>>>,
{
    Ok(blocks_into_vec(run(Chunk::from_slice(input))?))
}

/// Zero-pad `input` to `padded` elements in a single pass: one allocation
/// at the final size, one copy of the payload (the old padded all-reduce
/// path paid `to_vec` + `resize` — two full copies on every
/// non-multiple-of-`p` input).
pub fn pad_chunk<T: Elem>(input: &Chunk<T>, padded: usize) -> Chunk<T> {
    debug_assert!(padded >= input.len());
    let mut buf = Vec::with_capacity(padded);
    buf.extend_from_slice(input.as_slice());
    buf.resize(padded, T::zero());
    Chunk::from_vec(buf)
}

/// Materialize an all-reduce block list into one contiguous vector: a
/// single block (`p == 1`, or the vendor tree path, where it is the
/// unique full-range view of its storage) moves out with no copy;
/// otherwise one output concat — the only copy the slice wrappers pay.
pub(crate) fn blocks_into_vec<T: Clone>(mut blocks: Vec<Chunk<T>>) -> Vec<T> {
    if blocks.len() == 1 {
        blocks.pop().expect("one block").into_vec()
    } else {
        Chunk::concat(&blocks)
    }
}

/// Trim a rank-ordered block list down to `n` total elements by shrinking
/// views from the tail — O(1) per block, no element is touched. This is
/// how the chunk-native all-reduce drops internal padding.
pub fn trim_blocks<T>(blocks: &mut Vec<Chunk<T>>, n: usize) {
    let mut total: usize = blocks.iter().map(Chunk::len).sum();
    while total > n {
        let over = total - n;
        let last = blocks.last_mut().expect("blocks cover at least n elements");
        if last.len() <= over {
            total -= last.len();
            blocks.pop();
        } else {
            let keep = last.len() - over;
            *last = last.slice(0, keep);
            total = n;
        }
    }
}
