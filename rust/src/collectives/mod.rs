//! Collective algorithms.
//!
//! Every algorithm is written against the [`crate::comm::Comm`] trait, so
//! the same code runs over the real data plane and (via the step/index
//! helpers in [`schedule`]) drives the network simulator's message
//! schedules.
//!
//! Semantics (MPI-style, out-of-place):
//! * `all_gather`: input `m` elements/rank → output `p·m`, block `i` is
//!   rank `i`'s input.
//! * `reduce_scatter`: input `p·b` elements/rank → output `b`, rank `r`
//!   receives the elementwise reduction of every rank's block `r`.
//! * `all_reduce`: input `n` → output `n`, elementwise reduction across all
//!   ranks (implemented as reduce-scatter ∘ all-gather when `p | n`).
//!
//! ## Chunk ownership model (zero-copy data plane)
//!
//! Messages are [`crate::comm::Chunk`]s: `Arc`-backed storage plus an
//! `(offset, len)` view, with O(1) `clone`/`slice`/`split`. The rules the
//! algorithms follow:
//!
//! * **Forward, don't copy, when data passes through untouched.** Ring and
//!   recursive all-gather re-send the received chunk; the hierarchical
//!   all-gather forwards the inter-phase views through the intra ring and
//!   performs its unshuffle as a pointer permutation; broadcast fans one
//!   chunk down the whole binomial tree. The `*_chunks` entry points
//!   ([`ring_all_gather_chunks`], [`rec_all_gather_chunks`],
//!   [`hier_all_gather_chunks`]) expose this: every returned block is
//!   backed by the origin rank's input storage.
//! * **Materialize only when mutating or when the caller needs contiguous
//!   memory.** Reductions write new data at every hop by definition —
//!   they combine through [`crate::comm::Chunk::make_mut`], which mutates
//!   in place when the received partial is uniquely owned (the common
//!   case: the sender moved its reference into the transport) and
//!   copies-on-write only when the storage is still shared (e.g. the first
//!   combine into a view of the local input). The slice-API wrappers pay
//!   exactly two copies: wrapping the borrowed input into a chunk, and
//!   [`crate::comm::Chunk::concat`]-ing the final output.
//! * **Rooted data must be owned per destination.** Scatter materializes
//!   one block per peer (the source lives in the root's borrowed input);
//!   gather copies received blocks into the root's contiguous output.

mod hierarchical;
pub mod oracle;
mod pccl;
mod pipelined;
mod pt2pt;
mod recursive;
mod ring;
pub mod schedule;
mod shuffle;
mod tree;

pub use hierarchical::{
    hier_all_gather, hier_all_gather_chunks, hier_all_reduce, hier_reduce_scatter, InterAlgo,
};
pub use pccl::Pccl;
pub use pipelined::pipelined_hier_all_gather;
pub use pt2pt::{broadcast, gather, reduce, scatter};
pub use recursive::{rec_all_gather, rec_all_gather_chunks, rec_all_reduce, rec_reduce_scatter};
pub use ring::{ring_all_gather, ring_all_gather_chunks, ring_all_reduce, ring_reduce_scatter};
pub use shuffle::{shuffle_gather, transpose_blocks, transpose_chunk_blocks, unshuffle};
pub use tree::tree_all_reduce;

use crate::error::{Error, Result};

/// Validate an all-gather input (any non-empty block is fine).
pub(crate) fn check_all_gather<T>(input: &[T]) -> Result<()> {
    if input.is_empty() {
        return Err(Error::BadBufferSize {
            len: 0,
            size: 0,
            why: "all-gather input must be non-empty",
        });
    }
    Ok(())
}

/// Validate a reduce-scatter input: length divisible by communicator size.
pub(crate) fn check_reduce_scatter<T>(input: &[T], p: usize) -> Result<usize> {
    if input.is_empty() || input.len() % p != 0 {
        return Err(Error::BadBufferSize {
            len: input.len(),
            size: p,
            why: "reduce-scatter input length must be a positive multiple of communicator size",
        });
    }
    Ok(input.len() / p)
}
