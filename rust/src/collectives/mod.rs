//! Collective algorithms.
//!
//! Every algorithm is written against the [`crate::comm::Comm`] trait, so
//! the same code runs over the real data plane and (via the step/index
//! helpers in [`schedule`]) drives the network simulator's message
//! schedules.
//!
//! Semantics (MPI-style, out-of-place):
//! * `all_gather`: input `m` elements/rank → output `p·m`, block `i` is
//!   rank `i`'s input.
//! * `reduce_scatter`: input `p·b` elements/rank → output `b`, rank `r`
//!   receives the elementwise reduction of every rank's block `r`.
//! * `all_reduce`: input `n` → output `n`, elementwise reduction across all
//!   ranks (implemented as reduce-scatter ∘ all-gather when `p | n`).

mod hierarchical;
pub mod oracle;
mod pccl;
mod pipelined;
mod pt2pt;
mod recursive;
mod ring;
pub mod schedule;
mod shuffle;
mod tree;

pub use hierarchical::{hier_all_gather, hier_all_reduce, hier_reduce_scatter, InterAlgo};
pub use pccl::Pccl;
pub use pipelined::pipelined_hier_all_gather;
pub use pt2pt::{broadcast, gather, reduce, scatter};
pub use recursive::{rec_all_gather, rec_all_reduce, rec_reduce_scatter};
pub use ring::{ring_all_gather, ring_all_reduce, ring_reduce_scatter};
pub use shuffle::{shuffle_gather, transpose_blocks, unshuffle};
pub use tree::tree_all_reduce;

use crate::error::{Error, Result};

/// Validate an all-gather input (any non-empty block is fine).
pub(crate) fn check_all_gather<T>(input: &[T]) -> Result<()> {
    if input.is_empty() {
        return Err(Error::BadBufferSize {
            len: 0,
            size: 0,
            why: "all-gather input must be non-empty",
        });
    }
    Ok(())
}

/// Validate a reduce-scatter input: length divisible by communicator size.
pub(crate) fn check_reduce_scatter<T>(input: &[T], p: usize) -> Result<usize> {
    if input.is_empty() || input.len() % p != 0 {
        return Err(Error::BadBufferSize {
            len: input.len(),
            size: p,
            why: "reduce-scatter input length must be a positive multiple of communicator size",
        });
    }
    Ok(input.len() / p)
}
