//! Rooted collectives — broadcast, reduce, gather, scatter.
//!
//! Not headline operations of the paper, but a collective library a DL
//! framework can adopt needs them: ZeRO-3 broadcasts initial parameters,
//! checkpointing gathers shards, schedulers scatter work. Broadcast and
//! reduce use binomial trees (`O(log p)` rounds, any `p`); gather/scatter
//! use direct point-to-point rounds rooted at `root`. All four lower
//! through [`super::plan`]'s rooted builders and run on
//! [`super::engine`].
//!
//! Chunked-plane notes: broadcast forwards one shared chunk down the whole
//! tree (zero-copy fan-out); reduce posts its accumulator as the receive
//! target for every child's partial (lowered `RecvCombine` ops — in-place
//! folds, no staging) and leaves send their contribution as a zero-copy
//! moved post; scatter materializes one block per destination (the source
//! lives in the root's borrowed input, so each destination must own its
//! block); gather copies received blocks into the root's contiguous
//! output (the output materialization).
//!
//! The specs these slice APIs lower need not agree on `elems` across
//! ranks (non-root inputs are ignored); that is sound because the rooted
//! builders' op structure depends only on `(p, root)`, and each spec is
//! verified as an SPMD-uniform world of its own.

use crate::comm::{Chunk, Comm};
use crate::error::{Error, Result};
use crate::reduction::offload::Combiner;
use crate::reduction::Elem;

use super::engine;
use super::plan::{self, Algo, PlanKind, PlanSpec};

fn check_root<T: Send + Sync + 'static, C: Comm<T>>(c: &C, root: usize) -> Result<()> {
    if root >= c.size() {
        return Err(Error::PeerOutOfRange {
            peer: root,
            size: c.size(),
        });
    }
    Ok(())
}

/// Lower a rooted spec for this communicator, verify it (memoized), and
/// execute it.
fn run_rooted<T: Elem, C: Comm<T>>(
    c: &mut C,
    kind: PlanKind,
    algo: Algo,
    elems: usize,
    root: usize,
    inputs: Vec<Chunk<T>>,
    combiner: Option<&Combiner<T>>,
) -> Result<Vec<Chunk<T>>> {
    let spec = PlanSpec::rooted(kind, algo, c.size(), elems, root);
    plan::verify_cached(&spec)?;
    let pl = plan::build(&spec, c.rank())?;
    engine::run_flat(c, &pl, inputs, combiner)
}

/// Binomial-tree broadcast from `root`. Non-root inputs are ignored;
/// every rank returns the root's buffer. The buffer travels the whole
/// tree as clones of one chunk — one materialization at the root, zero
/// per-hop copies.
pub fn broadcast<T: Elem, C: Comm<T>>(c: &mut C, input: &[T], root: usize) -> Result<Vec<T>> {
    check_root(c, root)?;
    let inputs = if c.rank() == root { vec![Chunk::from_slice(input)] } else { Vec::new() };
    let mut out =
        run_rooted(c, PlanKind::Broadcast, Algo::Binomial, input.len(), root, inputs, None)?;
    Ok(out.pop().expect("broadcast delivers the buffer to every rank").into_vec())
}

/// Binomial-tree reduce to `root`: root returns the elementwise combine of
/// every rank's input; other ranks return an empty vec.
///
/// The accumulator starts as a wrap of the borrowed input (the one input
/// copy this slice API pays) and is *posted* as the receive target for
/// every child's partial, so each delivery folds in place — a rank whose
/// child sent a different length gets a typed
/// [`Error::RecvShapeMismatch`] with the message left queued. Leaves send
/// the accumulator itself (zero-copy moved post), and the root's final
/// materialization is a move.
pub fn reduce<T: Elem, C: Comm<T>>(
    c: &mut C,
    input: &[T],
    root: usize,
    combiner: &Combiner<T>,
) -> Result<Vec<T>> {
    check_root(c, root)?;
    let inputs = vec![Chunk::from_slice(input)];
    let mut out = run_rooted(
        c,
        PlanKind::Reduce,
        Algo::Binomial,
        input.len(),
        root,
        inputs,
        Some(combiner),
    )?;
    Ok(out.pop().map_or_else(Vec::new, Chunk::into_vec))
}

/// Gather to `root`: root returns the rank-ordered concatenation; others
/// return an empty vec. Equal-length contributions required.
pub fn gather<T: Elem, C: Comm<T>>(c: &mut C, input: &[T], root: usize) -> Result<Vec<T>> {
    check_root(c, root)?;
    let m = input.len();
    let inputs = vec![Chunk::from_slice(input)];
    let blocks = run_rooted(c, PlanKind::Gather, Algo::Direct, m, root, inputs, None)?;
    if c.rank() != root {
        debug_assert!(blocks.is_empty());
        return Ok(Vec::new());
    }
    let mut out = Vec::with_capacity(m * blocks.len());
    for got in &blocks {
        if got.len() != m {
            return Err(Error::BadBufferSize {
                len: got.len(),
                size: m,
                why: "gather contributions must have equal length",
            });
        }
        out.extend_from_slice(got.as_slice());
    }
    Ok(out)
}

/// Scatter from `root`: root's input (length `p·b`) is split into `p`
/// blocks; every rank returns its block. Non-root inputs are ignored.
pub fn scatter<T: Elem, C: Comm<T>>(c: &mut C, input: &[T], root: usize) -> Result<Vec<T>> {
    check_root(c, root)?;
    let p = c.size();
    let inputs = if c.rank() == root {
        if input.is_empty() || input.len() % p != 0 {
            return Err(Error::BadBufferSize {
                len: input.len(),
                size: p,
                why: "scatter input length must be a positive multiple of communicator size",
            });
        }
        let b = input.len() / p;
        // One owned block per destination: the receiver takes the storage
        // over for free in `into_vec`.
        (0..p).map(|i| Chunk::from_slice(&input[i * b..(i + 1) * b])).collect()
    } else {
        Vec::new()
    };
    let elems = if c.rank() == root { input.len() } else { 0 };
    let mut out = run_rooted(c, PlanKind::Scatter, Algo::Direct, elems, root, inputs, None)?;
    Ok(out.pop().expect("scatter delivers one block to every rank").into_vec())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::CommWorld;
    use crate::reduction::offload::native_combine;

    #[test]
    fn broadcast_any_root_any_size() {
        for p in 1..=6usize {
            for root in 0..p {
                let world = CommWorld::<f32>::new(p);
                let outs = world.run(move |c| {
                    let input: Vec<f32> = if c.rank() == root {
                        vec![root as f32 * 10.0, 42.0]
                    } else {
                        vec![-1.0, -1.0] // ignored
                    };
                    broadcast(c, &input, root).unwrap()
                });
                for (r, o) in outs.iter().enumerate() {
                    assert_eq!(o, &vec![root as f32 * 10.0, 42.0], "p={p} root={root} r={r}");
                }
            }
        }
    }

    #[test]
    fn reduce_any_root() {
        for p in [1usize, 3, 4, 7] {
            for root in [0, p - 1] {
                let world = CommWorld::<f32>::new(p);
                let outs = world.run(move |c| {
                    let input = vec![(c.rank() + 1) as f32; 3];
                    reduce(c, &input, root, &native_combine()).unwrap()
                });
                let total: f32 = (1..=p).map(|x| x as f32).sum();
                for (r, o) in outs.iter().enumerate() {
                    if r == root {
                        assert_eq!(o, &vec![total; 3], "p={p} root={root}");
                    } else {
                        assert!(o.is_empty());
                    }
                }
            }
        }
    }

    #[test]
    fn gather_scatter_roundtrip() {
        let p = 5;
        let root = 2;
        let world = CommWorld::<f32>::new(p);
        let outs = world.run(move |c| {
            let mine = vec![c.rank() as f32; 4];
            let gathered = gather(c, &mine, root).unwrap();
            // Root redistributes; everyone should get their block back.
            let back = scatter(c, &gathered, root).unwrap();
            (gathered, back)
        });
        for (r, (g, back)) in outs.iter().enumerate() {
            assert_eq!(back, &vec![r as f32; 4]);
            if r == root {
                let expect: Vec<f32> = (0..p).flat_map(|q| vec![q as f32; 4]).collect();
                assert_eq!(g, &expect);
            } else {
                assert!(g.is_empty());
            }
        }
    }

    #[test]
    fn errors_bad_root_and_sizes() {
        let world = CommWorld::<f32>::new(3);
        let outs = world.run(|c| broadcast(c, &[1.0], 9).is_err());
        assert!(outs.iter().all(|&e| e));
        let world = CommWorld::<f32>::new(3);
        let outs = world.run(|c| {
            if c.rank() == 0 {
                scatter(c, &[1.0; 7], 0).is_err() // 7 % 3 != 0
            } else {
                // Peers would block on recv; only root validates. Use a
                // short timeout so the test terminates.
                c.set_timeout(std::time::Duration::from_millis(50));
                scatter(c, &[], 0).is_err()
            }
        });
        assert!(outs.iter().all(|&e| e));
    }
}
