//! Rooted collectives — broadcast, reduce, gather, scatter.
//!
//! Not headline operations of the paper, but a collective library a DL
//! framework can adopt needs them: ZeRO-3 broadcasts initial parameters,
//! checkpointing gathers shards, schedulers scatter work. Broadcast and
//! reduce use binomial trees (`O(log p)` rounds, any `p`); gather/scatter
//! use direct point-to-point rounds rooted at `root`.
//!
//! Chunked-plane notes: broadcast forwards one shared chunk down the whole
//! tree (zero-copy fan-out — the seed path cloned the buffer per child);
//! reduce posts its accumulator as the receive target for every child's
//! partial ([`Comm::recv_combine_into`] — in-place folds, no staging) and
//! leaves send their contribution as a zero-copy post; scatter
//! materializes one block per destination (the source lives in the root's
//! borrowed input, so each destination must own its block); gather copies
//! received blocks into the root's contiguous output (the output
//! materialization).

use crate::comm::{Chunk, Comm};
use crate::error::{Error, Result};
use crate::reduction::offload::Combiner;
use crate::reduction::Elem;

fn check_root<T: Send + Sync + 'static, C: Comm<T>>(c: &C, root: usize) -> Result<()> {
    if root >= c.size() {
        return Err(Error::PeerOutOfRange {
            peer: root,
            size: c.size(),
        });
    }
    Ok(())
}

/// Relative rank so the binomial tree can be rooted anywhere.
#[inline]
fn rel(rank: usize, root: usize, p: usize) -> usize {
    (rank + p - root) % p
}

#[inline]
fn unrel(r: usize, root: usize, p: usize) -> usize {
    (r + root) % p
}

/// Binomial-tree broadcast from `root`. Non-root inputs are ignored;
/// every rank returns the root's buffer. The buffer travels the whole
/// tree as clones of one chunk — one materialization at the root, zero
/// per-hop copies.
pub fn broadcast<T: Elem, C: Comm<T>>(c: &mut C, input: &[T], root: usize) -> Result<Vec<T>> {
    check_root(c, root)?;
    c.begin_op();
    let p = c.size();
    let r = rel(c.rank(), root, p);
    if p == 1 {
        return Ok(input.to_vec());
    }
    let buf: Chunk<T>;
    let mut recv_mask = p.next_power_of_two();
    if r == 0 {
        buf = Chunk::from_slice(input);
    } else {
        // Receive from the parent (clear the lowest set bit of r).
        let mut mask = 1usize;
        while r & mask == 0 {
            mask <<= 1;
        }
        recv_mask = mask;
        let src = unrel(r & !mask, root, p);
        buf = c.recv_chunk(src, mask.trailing_zeros())?;
    }
    let mut child_mask = recv_mask >> 1;
    while child_mask > 0 {
        let dst_rel = r | child_mask;
        if dst_rel != r && dst_rel < p {
            c.send_slice(
                unrel(dst_rel, root, p),
                child_mask.trailing_zeros(),
                buf.clone(),
            )?;
        }
        child_mask >>= 1;
    }
    Ok(buf.into_vec())
}

/// Binomial-tree reduce to `root`: root returns the elementwise combine of
/// every rank's input; other ranks return an empty vec.
///
/// The accumulator starts as a wrap of the borrowed input (the one input
/// copy this slice API pays) and is *posted* as the receive target for
/// every child's partial, so each delivery folds in place — a rank whose
/// child sent a different length gets a typed
/// [`Error::RecvShapeMismatch`] with the message left queued. Leaves send
/// the accumulator itself (zero-copy post), and the root's final
/// materialization is a move.
pub fn reduce<T: Elem, C: Comm<T>>(
    c: &mut C,
    input: &[T],
    root: usize,
    combiner: &Combiner<T>,
) -> Result<Vec<T>> {
    check_root(c, root)?;
    c.begin_op();
    let p = c.size();
    let r = rel(c.rank(), root, p);
    let mut acc = Chunk::from_slice(input);
    let mut mask = 1usize;
    while mask < p {
        let step = mask.trailing_zeros();
        if r & mask != 0 {
            let dst = unrel(r & !mask, root, p);
            c.send_slice(dst, step, acc)?;
            return Ok(Vec::new());
        }
        let src_rel = r | mask;
        if src_rel < p {
            c.recv_combine_into(unrel(src_rel, root, p), step, &mut acc, combiner)?;
        }
        mask <<= 1;
    }
    Ok(acc.into_vec())
}

/// Gather to `root`: root returns the rank-ordered concatenation; others
/// return an empty vec. Equal-length contributions required.
pub fn gather<T: Elem, C: Comm<T>>(c: &mut C, input: &[T], root: usize) -> Result<Vec<T>> {
    check_root(c, root)?;
    c.begin_op();
    let p = c.size();
    let rank = c.rank();
    if rank != root {
        c.send_slice(root, 0, Chunk::from_slice(input))?;
        return Ok(Vec::new());
    }
    let m = input.len();
    let mut out = vec![T::zero(); p * m];
    out[root * m..(root + 1) * m].copy_from_slice(input);
    for peer in 0..p {
        if peer == root {
            continue;
        }
        let got = c.recv_chunk(peer, 0)?;
        if got.len() != m {
            return Err(Error::BadBufferSize {
                len: got.len(),
                size: m,
                why: "gather contributions must have equal length",
            });
        }
        out[peer * m..(peer + 1) * m].copy_from_slice(got.as_slice());
    }
    Ok(out)
}

/// Scatter from `root`: root's input (length `p·b`) is split into `p`
/// blocks; every rank returns its block. Non-root inputs are ignored.
pub fn scatter<T: Elem, C: Comm<T>>(c: &mut C, input: &[T], root: usize) -> Result<Vec<T>> {
    check_root(c, root)?;
    c.begin_op();
    let p = c.size();
    let rank = c.rank();
    if rank == root {
        if input.is_empty() || input.len() % p != 0 {
            return Err(Error::BadBufferSize {
                len: input.len(),
                size: p,
                why: "scatter input length must be a positive multiple of communicator size",
            });
        }
        let b = input.len() / p;
        for peer in 0..p {
            if peer != root {
                // One owned block per destination: the receiver takes the
                // storage over for free in `into_vec`.
                c.send_slice(peer, 0, Chunk::from_slice(&input[peer * b..(peer + 1) * b]))?;
            }
        }
        Ok(input[root * b..(root + 1) * b].to_vec())
    } else {
        Ok(c.recv_chunk(root, 0)?.into_vec())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::CommWorld;
    use crate::reduction::offload::native_combine;

    #[test]
    fn broadcast_any_root_any_size() {
        for p in 1..=6usize {
            for root in 0..p {
                let world = CommWorld::<f32>::new(p);
                let outs = world.run(move |c| {
                    let input: Vec<f32> = if c.rank() == root {
                        vec![root as f32 * 10.0, 42.0]
                    } else {
                        vec![-1.0, -1.0] // ignored
                    };
                    broadcast(c, &input, root).unwrap()
                });
                for (r, o) in outs.iter().enumerate() {
                    assert_eq!(o, &vec![root as f32 * 10.0, 42.0], "p={p} root={root} r={r}");
                }
            }
        }
    }

    #[test]
    fn reduce_any_root() {
        for p in [1usize, 3, 4, 7] {
            for root in [0, p - 1] {
                let world = CommWorld::<f32>::new(p);
                let outs = world.run(move |c| {
                    let input = vec![(c.rank() + 1) as f32; 3];
                    reduce(c, &input, root, &native_combine()).unwrap()
                });
                let total: f32 = (1..=p).map(|x| x as f32).sum();
                for (r, o) in outs.iter().enumerate() {
                    if r == root {
                        assert_eq!(o, &vec![total; 3], "p={p} root={root}");
                    } else {
                        assert!(o.is_empty());
                    }
                }
            }
        }
    }

    #[test]
    fn gather_scatter_roundtrip() {
        let p = 5;
        let root = 2;
        let world = CommWorld::<f32>::new(p);
        let outs = world.run(move |c| {
            let mine = vec![c.rank() as f32; 4];
            let gathered = gather(c, &mine, root).unwrap();
            // Root redistributes; everyone should get their block back.
            let back = scatter(c, &gathered, root).unwrap();
            (gathered, back)
        });
        for (r, (g, back)) in outs.iter().enumerate() {
            assert_eq!(back, &vec![r as f32; 4]);
            if r == root {
                let expect: Vec<f32> = (0..p).flat_map(|q| vec![q as f32; 4]).collect();
                assert_eq!(g, &expect);
            } else {
                assert!(g.is_empty());
            }
        }
    }

    #[test]
    fn errors_bad_root_and_sizes() {
        let world = CommWorld::<f32>::new(3);
        let outs = world.run(|c| broadcast(c, &[1.0], 9).is_err());
        assert!(outs.iter().all(|&e| e));
        let world = CommWorld::<f32>::new(3);
        let outs = world.run(|c| {
            if c.rank() == 0 {
                scatter(c, &[1.0; 7], 0).is_err() // 7 % 3 != 0
            } else {
                // Peers would block on recv; only root validates. Use a
                // short timeout so the test terminates.
                c.set_timeout(std::time::Duration::from_millis(50));
                scatter(c, &[], 0).is_err()
            }
        });
        assert!(outs.iter().all(|&e| e));
    }
}
