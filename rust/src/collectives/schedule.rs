//! Step/index arithmetic shared by the data-plane algorithms and the
//! network simulator's schedule generators.
//!
//! Keeping this math in one place is what makes the netsim figures honest:
//! the simulated message pattern *is* the executed message pattern (same
//! peers, same block sizes, same step counts).

/// Ring algorithm indices (flat, `p - 1` steps, send right / recv left).
pub mod ring {
    /// Number of communication steps.
    pub fn steps(p: usize) -> usize {
        p.saturating_sub(1)
    }

    /// Block sent by `rank` at step `s` during all-gather.
    pub fn ag_send_block(rank: usize, p: usize, s: usize) -> usize {
        (rank + p - s % p) % p
    }

    /// Block received by `rank` at step `s` during all-gather.
    pub fn ag_recv_block(rank: usize, p: usize, s: usize) -> usize {
        (rank + p - s % p - 1) % p
    }

    /// Block sent by `rank` at step `s` during reduce-scatter.
    pub fn rs_send_block(rank: usize, p: usize, s: usize) -> usize {
        (rank + 2 * p - s % p - 1) % p
    }

    /// Block received (and combined) by `rank` at step `s` during
    /// reduce-scatter.
    pub fn rs_recv_block(rank: usize, p: usize, s: usize) -> usize {
        (rank + 2 * p - s % p - 2) % p
    }
}

/// Recursive doubling/halving indices (power-of-two `p`, `log2 p` steps).
pub mod recursive {
    /// Number of steps (`p` must be a power of two).
    pub fn steps(p: usize) -> usize {
        p.trailing_zeros() as usize
    }

    /// Exchange partner of `rank` at all-gather step `s` (doubling:
    /// distance `2^s`).
    pub fn ag_partner(rank: usize, s: usize) -> usize {
        rank ^ (1 << s)
    }

    /// Blocks owned by `rank` *before* all-gather step `s`: the
    /// `2^s`-aligned group containing `rank`.
    pub fn ag_owned_range(rank: usize, s: usize) -> (usize, usize) {
        let width = 1 << s;
        let lo = rank & !(width - 1);
        (lo, lo + width)
    }

    /// Exchange partner at reduce-scatter (halving) step `s` out of
    /// `steps(p)`: distance `p / 2^(s+1)`.
    pub fn rs_partner(rank: usize, p: usize, s: usize) -> usize {
        rank ^ (p >> (s + 1))
    }

    /// Volume factor: elements exchanged at halving step `s` as a fraction
    /// of the full buffer is `1 / 2^(s+1)`.
    pub fn rs_fraction_denom(s: usize) -> usize {
        1 << (s + 1)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ring_blocks_cover_everything() {
        // Over p-1 steps, each rank receives exactly the p-1 blocks it does
        // not own (all-gather).
        let p = 7;
        for r in 0..p {
            let mut got: Vec<usize> = (0..ring::steps(p))
                .map(|s| ring::ag_recv_block(r, p, s))
                .collect();
            got.sort_unstable();
            let mut expect: Vec<usize> = (0..p).filter(|&b| b != r).collect();
            expect.sort_unstable();
            assert_eq!(got, expect);
        }
    }

    #[test]
    fn ring_send_matches_left_neighbor_recv() {
        // What rank r sends at step s is what rank r+1 receives at step s.
        let p = 6;
        for r in 0..p {
            for s in 0..ring::steps(p) {
                assert_eq!(
                    ring::ag_send_block(r, p, s),
                    ring::ag_recv_block((r + 1) % p, p, s)
                );
                assert_eq!(
                    ring::rs_send_block(r, p, s),
                    ring::rs_recv_block((r + 1) % p, p, s)
                );
            }
        }
    }

    #[test]
    fn recursive_partners_are_involutions() {
        let p = 16;
        for r in 0..p {
            for s in 0..recursive::steps(p) {
                assert_eq!(recursive::ag_partner(recursive::ag_partner(r, s), s), r);
                assert_eq!(
                    recursive::rs_partner(recursive::rs_partner(r, p, s), p, s),
                    r
                );
            }
        }
    }

    #[test]
    fn doubling_owned_range_grows_to_world() {
        let p = 8;
        for r in 0..p {
            let (lo, hi) = recursive::ag_owned_range(r, 0);
            assert_eq!((lo, hi), (r, r + 1));
            let (lo, hi) = recursive::ag_owned_range(r, recursive::steps(p));
            assert_eq!((lo, hi), (0, p));
        }
    }
}
