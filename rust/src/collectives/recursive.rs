//! Recursive doubling (all-gather) and recursive halving (reduce-scatter)
//! — the latency-optimal `log2 p`-step algorithms PCCL adds for the
//! inter-node phase (`PCCL_rec`, §IV-B; Eq. 2).
//!
//! These require a power-of-two communicator. Callers (the backends and the
//! hierarchical composition) fall back to the ring when `p` is not a power
//! of two — the paper's target systems are all power-of-two node counts.

use crate::comm::Comm;
use crate::error::{Error, Result};
use crate::reduction::offload::CombineFn;
use crate::reduction::Elem;

use super::schedule::recursive as idx;
use super::{check_all_gather, check_reduce_scatter};

fn require_pow2(p: usize) -> Result<()> {
    if !p.is_power_of_two() {
        return Err(Error::BadBufferSize {
            len: p,
            size: p,
            why: "recursive doubling/halving requires a power-of-two communicator",
        });
    }
    Ok(())
}

/// Recursive-doubling all-gather: `log2 p` exchanges of doubling size.
pub fn rec_all_gather<T: Elem, C: Comm<T>>(c: &mut C, input: &[T]) -> Result<Vec<T>> {
    check_all_gather(input)?;
    let p = c.size();
    require_pow2(p)?;
    c.begin_op();
    let r = c.rank();
    let m = input.len();
    let mut out = vec![T::zero(); p * m];
    out[r * m..(r + 1) * m].copy_from_slice(input);
    for s in 0..idx::steps(p) {
        let partner = idx::ag_partner(r, s);
        let (lo, hi) = idx::ag_owned_range(r, s);
        let (plo, phi) = idx::ag_owned_range(partner, s);
        let payload = out[lo * m..hi * m].to_vec();
        let got = c.sendrecv(partner, payload, partner, s as u32)?;
        out[plo * m..phi * m].copy_from_slice(&got);
    }
    Ok(out)
}

/// Recursive-halving reduce-scatter: each step exchanges and combines half
/// of the remaining segment.
pub fn rec_reduce_scatter<T: Elem, C: Comm<T>>(
    c: &mut C,
    input: &[T],
    combine: &CombineFn<T>,
) -> Result<Vec<T>> {
    let p = c.size();
    let b = check_reduce_scatter(input, p)?;
    require_pow2(p)?;
    c.begin_op();
    let r = c.rank();
    if p == 1 {
        return Ok(input.to_vec());
    }
    let mut acc = input.to_vec();
    // Current segment of *block indices* this rank is still responsible for.
    let mut lo = 0usize;
    let mut hi = p;
    for s in 0..idx::steps(p) {
        let partner = idx::rs_partner(r, p, s);
        let mid = (lo + hi) / 2;
        // If our rank lies in the lower half of the segment, we keep
        // [lo, mid) and send [mid, hi); otherwise the reverse.
        let keep_low = r < mid;
        let (keep_lo, keep_hi, send_lo, send_hi) = if keep_low {
            (lo, mid, mid, hi)
        } else {
            (mid, hi, lo, mid)
        };
        let payload = acc[send_lo * b..send_hi * b].to_vec();
        let got = c.sendrecv(partner, payload, partner, s as u32)?;
        combine(&mut acc[keep_lo * b..keep_hi * b], &got);
        lo = keep_lo;
        hi = keep_hi;
    }
    debug_assert_eq!((lo, hi), (r, r + 1));
    Ok(acc[r * b..(r + 1) * b].to_vec())
}

/// All-reduce = recursive halving reduce-scatter ∘ recursive doubling
/// all-gather (§IV-B: "our all-reduce in PCCL_rec uses recursive halving
/// followed by recursive doubling"). Pads to a multiple of `p`.
pub fn rec_all_reduce<T: Elem, C: Comm<T>>(
    c: &mut C,
    input: &[T],
    combine: &CombineFn<T>,
) -> Result<Vec<T>> {
    check_all_gather(input)?;
    let p = c.size();
    require_pow2(p)?;
    if p == 1 {
        return Ok(input.to_vec());
    }
    let n = input.len();
    let padded = n.div_ceil(p) * p;
    // §Perf: avoid the pad-copy on the (common) aligned path.
    let mine = if padded == n {
        rec_reduce_scatter(c, input, combine)?
    } else {
        let mut buf = input.to_vec();
        buf.resize(padded, T::zero());
        rec_reduce_scatter(c, &buf, combine)?
    };
    let mut out = rec_all_gather(c, &mine)?;
    out.truncate(n);
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::collectives::oracle;
    use crate::comm::CommWorld;
    use crate::reduction::offload::native_combine;

    #[test]
    fn all_gather_pow2() {
        for p in [1usize, 2, 4, 8, 16] {
            let m = 3;
            let world = CommWorld::<f32>::new(p);
            let outs = world.run(move |c| {
                let input: Vec<f32> = (0..m).map(|i| (c.rank() * 100 + i) as f32).collect();
                rec_all_gather(c, &input).unwrap()
            });
            let ins: Vec<Vec<f32>> = (0..p)
                .map(|r| (0..m).map(|i| (r * 100 + i) as f32).collect())
                .collect();
            let expect = oracle::all_gather(&ins);
            for o in outs {
                assert_eq!(o, expect, "p={p}");
            }
        }
    }

    #[test]
    fn reduce_scatter_pow2() {
        for p in [2usize, 4, 8] {
            let b = 4;
            let world = CommWorld::<f32>::new(p);
            let outs = world.run(move |c| {
                let input: Vec<f32> = (0..p * b).map(|i| (c.rank() * 7 + i) as f32).collect();
                rec_reduce_scatter(c, &input, &native_combine()).unwrap()
            });
            let ins: Vec<Vec<f32>> = (0..p)
                .map(|r| (0..p * b).map(|i| (r * 7 + i) as f32).collect())
                .collect();
            for (r, o) in outs.iter().enumerate() {
                assert_eq!(o, &oracle::reduce_scatter(&ins, r), "p={p} r={r}");
            }
        }
    }

    #[test]
    fn all_reduce_pow2_unaligned() {
        let p = 8;
        let n = 13; // forces padding
        let world = CommWorld::<f64>::new(p);
        let outs = world.run(move |c| {
            let input: Vec<f64> = (0..n).map(|i| (c.rank() as f64) + (i as f64) * 0.5).collect();
            rec_all_reduce(c, &input, &native_combine()).unwrap()
        });
        let ins: Vec<Vec<f64>> = (0..p)
            .map(|r| (0..n).map(|i| (r as f64) + (i as f64) * 0.5).collect())
            .collect();
        let expect = oracle::all_reduce(&ins);
        for o in outs {
            assert_eq!(o, expect);
        }
    }

    #[test]
    fn non_pow2_rejected() {
        let world = CommWorld::<f32>::new(3);
        let outs = world.run(|c| rec_all_gather(c, &[1.0]).is_err());
        assert!(outs.iter().all(|&e| e));
    }
}
