//! Recursive doubling (all-gather) and recursive halving (reduce-scatter)
//! — the latency-optimal `log2 p`-step algorithms PCCL adds for the
//! inter-node phase (`PCCL_rec`, §IV-B; Eq. 2).
//!
//! These require a power-of-two communicator. Callers (the backends and the
//! hierarchical composition) fall back to the ring when `p` is not a power
//! of two — the paper's target systems are all power-of-two node counts.
//!
//! Since the Plan IR refactor the doubling/halving step math lives in
//! [`super::plan`]'s builders (which delegate to
//! [`super::schedule::recursive`]); these entry points validate, lower a
//! [`PlanSpec`], verify it against the memoized static checker, and run
//! the plan on [`engine::run_flat`]. Over the chunked plane each *block*
//! is its own message (the step tag encodes `(step, block)`), so the
//! doubling exchange forwards views of the blocks gathered so far instead
//! of re-materializing a contiguous payload every step. Byte volume is
//! unchanged; message count rises from `log2 p` to `p - 1` per rank,
//! matching the ring (sends are non-blocking and free on this transport; a
//! libfabric backend would post them as one iovec).

use crate::comm::{Chunk, Comm};
use crate::error::{Error, Result};
use crate::reduction::offload::Combiner;
use crate::reduction::Elem;

use super::engine;
use super::plan::{self, Algo, PlanKind, PlanSpec};
use super::{
    check_all_gather, check_reduce_scatter, pad_chunk, slice_all_reduce, slice_gather,
    slice_reduce, trim_blocks,
};

fn require_pow2(p: usize) -> Result<()> {
    if !p.is_power_of_two() {
        return Err(Error::BadBufferSize {
            len: p,
            size: p,
            why: "recursive doubling/halving requires a power-of-two communicator",
        });
    }
    Ok(())
}

/// Lower a flat recursive spec for this communicator, verify it
/// (memoized), and execute it. All rec entry points funnel through here.
fn run_rec<T: Elem, C: Comm<T>>(
    c: &mut C,
    kind: PlanKind,
    elems: usize,
    inputs: Vec<Chunk<T>>,
    combiner: Option<&Combiner<T>>,
) -> Result<Vec<Chunk<T>>> {
    let spec = PlanSpec::flat(kind, Algo::Rec, c.size(), elems, 1);
    plan::verify_cached(&spec)?;
    let pl = plan::build(&spec, c.rank())?;
    engine::run_flat(c, &pl, inputs, combiner)
}

/// Recursive-doubling all-gather over chunks: `log2 p` exchanges of
/// doubling size, every block forwarded as a zero-copy view.
///
/// Returns the `p` per-rank blocks in origin-rank order, each backed by
/// the origin rank's input storage.
pub fn rec_all_gather_chunks<T: Elem, C: Comm<T>>(
    c: &mut C,
    input: Chunk<T>,
) -> Result<Vec<Chunk<T>>> {
    check_all_gather(input.as_slice())?;
    require_pow2(c.size())?;
    let elems = input.len();
    run_rec(c, PlanKind::AllGather, elems, vec![input], None)
}

/// Recursive-doubling all-gather, slice API — adapter over
/// [`rec_all_gather_chunks`].
pub fn rec_all_gather<T: Elem, C: Comm<T>>(c: &mut C, input: &[T]) -> Result<Vec<T>> {
    slice_gather(input, |ch| rec_all_gather_chunks(c, ch))
}

/// Recursive-halving reduce-scatter over chunks: each step exchanges and
/// combines half of the remaining segment.
///
/// The `p` blocks start as zero-copy views of the caller's input chunk;
/// the blocks we *send* go out as those views (no payload copies), and
/// each kept block is *posted* as the receive target of its partner's
/// partial (the lowered `RecvCombine` ops land on
/// [`Comm::recv_combine_into`]). At a block's first combine the delivery
/// is a one-pass fuse into fresh exact-size storage (both operands are
/// still input views — one allocation, zero copies); on every later step
/// the now-exclusive accumulator is folded in place, so its storage
/// pointer is stable from the first combine to the final shard. For
/// `p > 1` the returned chunk is the unique full-range view of its
/// storage (`into_vec` is a move); at `p == 1` the block comes back
/// backed by the input's storage.
pub fn rec_reduce_scatter_chunks<T: Elem, C: Comm<T>>(
    c: &mut C,
    input: Chunk<T>,
    combiner: &Combiner<T>,
) -> Result<Chunk<T>> {
    let p = c.size();
    let b = check_reduce_scatter(input.as_slice(), p)?;
    require_pow2(p)?;
    let blocks = (0..p).map(|i| input.slice(i * b, b)).collect();
    let mut out = run_rec(c, PlanKind::ReduceScatter, p * b, blocks, Some(combiner))?;
    debug_assert_eq!(out.len(), 1, "reduce-scatter yields one block");
    Ok(out.pop().expect("reduce-scatter plan outputs this rank's block"))
}

/// Recursive-halving reduce-scatter, slice API — adapter over
/// [`rec_reduce_scatter_chunks`].
pub fn rec_reduce_scatter<T: Elem, C: Comm<T>>(
    c: &mut C,
    input: &[T],
    combiner: &Combiner<T>,
) -> Result<Vec<T>> {
    slice_reduce(input, |ch| rec_reduce_scatter_chunks(c, ch, combiner))
}

/// All-reduce over chunks = recursive halving reduce-scatter ∘ recursive
/// doubling all-gather (§IV-B: "our all-reduce in PCCL_rec uses recursive
/// halving followed by recursive doubling"), lowered as one two-phase plan
/// with no intermediate `Vec`. Pads once into the reduce-scatter input
/// when `p ∤ n` and trims the padding off the returned block list as a
/// view adjustment. Runs the composition at every `p` (including 1),
/// keeping op-sequence numbering size-independent.
pub fn rec_all_reduce_chunks<T: Elem, C: Comm<T>>(
    c: &mut C,
    input: Chunk<T>,
    combiner: &Combiner<T>,
) -> Result<Vec<Chunk<T>>> {
    check_all_gather(input.as_slice())?;
    let p = c.size();
    require_pow2(p)?;
    let n = input.len();
    let padded = n.div_ceil(p) * p;
    // §Perf: pad at most once, straight into the reduce-scatter input.
    let padded_input = if padded == n {
        input
    } else {
        pad_chunk(&input, padded)
    };
    let b = padded / p;
    let blocks = (0..p).map(|i| padded_input.slice(i * b, b)).collect();
    let mut blocks = run_rec(c, PlanKind::AllReduce, padded, blocks, Some(combiner))?;
    trim_blocks(&mut blocks, n);
    Ok(blocks)
}

/// Recursive all-reduce, slice API — adapter over [`rec_all_reduce_chunks`].
pub fn rec_all_reduce<T: Elem, C: Comm<T>>(
    c: &mut C,
    input: &[T],
    combiner: &Combiner<T>,
) -> Result<Vec<T>> {
    slice_all_reduce(input, |ch| rec_all_reduce_chunks(c, ch, combiner))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::collectives::oracle;
    use crate::comm::CommWorld;
    use crate::reduction::offload::native_combine;

    #[test]
    fn all_gather_pow2() {
        for p in [1usize, 2, 4, 8, 16] {
            let m = 3;
            let world = CommWorld::<f32>::new(p);
            let outs = world.run(move |c| {
                let input: Vec<f32> = (0..m).map(|i| (c.rank() * 100 + i) as f32).collect();
                rec_all_gather(c, &input).unwrap()
            });
            let ins: Vec<Vec<f32>> = (0..p)
                .map(|r| (0..m).map(|i| (r * 100 + i) as f32).collect())
                .collect();
            let expect = oracle::all_gather(&ins);
            for o in outs {
                assert_eq!(o, expect, "p={p}");
            }
        }
    }

    #[test]
    fn all_gather_chunks_forward_views() {
        // Every returned block must share storage with some rank's input —
        // the doubling exchange never re-materializes a block.
        let p = 8;
        let world = CommWorld::<f32>::new(p);
        let outs = world.run(move |c| {
            let input = Chunk::from_vec(vec![c.rank() as f32; 2]);
            let own_id = input.storage_id();
            let blocks = rec_all_gather_chunks(c, input).unwrap();
            (own_id, blocks.iter().map(|b| b.storage_id()).collect::<Vec<_>>())
        });
        let ids: Vec<usize> = outs.iter().map(|(id, _)| *id).collect();
        for (r, (_, block_ids)) in outs.iter().enumerate() {
            for (q, bid) in block_ids.iter().enumerate() {
                assert_eq!(bid, &ids[q], "rank {r} re-materialized block {q}");
            }
        }
    }

    #[test]
    fn reduce_scatter_pow2() {
        for p in [2usize, 4, 8] {
            let b = 4;
            let world = CommWorld::<f32>::new(p);
            let outs = world.run(move |c| {
                let input: Vec<f32> = (0..p * b).map(|i| (c.rank() * 7 + i) as f32).collect();
                rec_reduce_scatter(c, &input, &native_combine()).unwrap()
            });
            let ins: Vec<Vec<f32>> = (0..p)
                .map(|r| (0..p * b).map(|i| (r * 7 + i) as f32).collect())
                .collect();
            for (r, o) in outs.iter().enumerate() {
                assert_eq!(o, &oracle::reduce_scatter(&ins, r), "p={p} r={r}");
            }
        }
    }

    #[test]
    fn all_reduce_pow2_unaligned() {
        let p = 8;
        let n = 13; // forces padding
        let world = CommWorld::<f64>::new(p);
        let outs = world.run(move |c| {
            let input: Vec<f64> = (0..n).map(|i| (c.rank() as f64) + (i as f64) * 0.5).collect();
            rec_all_reduce(c, &input, &native_combine()).unwrap()
        });
        let ins: Vec<Vec<f64>> = (0..p)
            .map(|r| (0..n).map(|i| (r as f64) + (i as f64) * 0.5).collect())
            .collect();
        let expect = oracle::all_reduce(&ins);
        for o in outs {
            assert_eq!(o, expect);
        }
    }

    #[test]
    fn non_pow2_rejected() {
        let world = CommWorld::<f32>::new(3);
        let outs = world.run(|c| rec_all_gather(c, &[1.0]).is_err());
        assert!(outs.iter().all(|&e| e));
    }
}
