//! Chunk-pipelined hierarchical all-gather — an extension beyond the
//! paper: split the buffer into K chunks and run the two-level hierarchy
//! per chunk so the inter-node phase of chunk `k+1` overlaps the
//! intra-node phase of chunk `k`.
//!
//! On the in-process data plane sends are asynchronous, so the inter-phase
//! traffic of the next chunk is posted before the intra phase of the
//! current chunk completes — the same schedule a GPU implementation gets
//! from separate streams. The performance model of the overlap lives in
//! [`crate::netsim::libmodel`] (`pccl_pipelined` ablation); peak working
//! memory also drops from `p·m` temporaries to `p·m/K`.
//!
//! Each pipeline stage feeds a zero-copy [`Chunk::slice`] of the input
//! through [`hier_all_gather_chunks`], so the per-stage hierarchy forwards
//! views the whole way; the single copy is the final placement into the
//! caller's contiguous output (the seed path paid a second, per-stage
//! gather copy on top of that).

use crate::comm::{Chunk, Communicator};
use crate::error::{Error, Result};
use crate::reduction::Elem;

use super::hierarchical::{hier_all_gather, hier_all_gather_chunks, InterAlgo};

/// Pipelined two-level all-gather with `chunks` pipeline stages.
///
/// `input.len()` must be divisible by `chunks`; `chunks = 1` degenerates to
/// [`hier_all_gather`]. Output is identical to the unpipelined algorithm.
pub fn pipelined_hier_all_gather<T: Elem>(
    c: &mut Communicator<T>,
    input: &[T],
    inter: InterAlgo,
    chunks: usize,
) -> Result<Vec<T>> {
    if chunks == 0 || input.len() % chunks != 0 {
        return Err(Error::BadBufferSize {
            len: input.len(),
            size: chunks,
            why: "pipelined all-gather needs chunks > 0 dividing the input length",
        });
    }
    if chunks == 1 {
        return hier_all_gather(c, input, inter);
    }
    let p = c.size();
    let m = input.len();
    let cb = m / chunks;
    let whole = Chunk::from_slice(input);
    let mut out = vec![T::zero(); p * m];
    for k in 0..chunks {
        let piece = whole.slice(k * cb, cb);
        let gathered = hier_all_gather_chunks(c, piece, inter)?;
        debug_assert_eq!(gathered.len(), p);
        // Chunk k of rank r lands at out[r·m + k·cb ..].
        for (r, blk) in gathered.iter().enumerate() {
            debug_assert_eq!(blk.len(), cb);
            out[r * m + k * cb..r * m + (k + 1) * cb].copy_from_slice(blk.as_slice());
        }
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::collectives::oracle;
    use crate::comm::CommWorld;
    use crate::topology::Topology;

    #[test]
    fn pipelined_matches_oracle_all_chunk_counts() {
        let topo = Topology::new(2, 3, 1).unwrap();
        let p = topo.world_size();
        let m = 12;
        for chunks in [1usize, 2, 3, 4, 6, 12] {
            for algo in [InterAlgo::Ring, InterAlgo::Rec] {
                let world = CommWorld::<f32>::with_topology(topo);
                let outs = world.run(move |c| {
                    let input: Vec<f32> =
                        (0..m).map(|i| (c.rank() * 1000 + i) as f32).collect();
                    pipelined_hier_all_gather(c, &input, algo, chunks).unwrap()
                });
                let ins: Vec<Vec<f32>> = (0..p)
                    .map(|r| (0..m).map(|i| (r * 1000 + i) as f32).collect())
                    .collect();
                let expect = oracle::all_gather(&ins);
                for (r, o) in outs.iter().enumerate() {
                    assert_eq!(o, &expect, "chunks={chunks} algo={algo:?} r={r}");
                }
            }
        }
    }

    #[test]
    fn bad_chunking_rejected() {
        let world = CommWorld::<f32>::with_topology(Topology::new(2, 2, 1).unwrap());
        let outs = world.run(|c| {
            pipelined_hier_all_gather(c, &[1.0; 10], InterAlgo::Rec, 3).is_err()
                && pipelined_hier_all_gather(c, &[1.0; 10], InterAlgo::Rec, 0).is_err()
        });
        assert!(outs.iter().all(|&e| e));
    }
}
