//! Chunk-pipelined hierarchical all-gather — an extension beyond the
//! paper: split the buffer into K chunks and run the two-level hierarchy
//! per chunk so the inter-node phase of chunk `k+1` overlaps the
//! intra-node phase of chunk `k`.
//!
//! On the in-process data plane sends are asynchronous, so the inter-phase
//! traffic of the next chunk is posted before the intra phase of the
//! current chunk completes — the same schedule a GPU implementation gets
//! from separate streams. The performance model of the overlap lives in
//! [`crate::netsim::libmodel`] (`pccl_pipelined` ablation); peak working
//! memory also drops from `p·m` temporaries to `p·m/K`.
//!
//! Each pipeline stage feeds a zero-copy [`Chunk::slice`] of the input
//! through [`hier_all_gather_chunks`], so the per-stage hierarchy forwards
//! views the whole way; the single copy is the final placement into the
//! caller's contiguous output (the seed path paid a second, per-stage
//! gather copy on top of that). Since the Plan IR refactor every stage is
//! itself a lowered, verified hierarchical plan — all stages of one call
//! share a [`super::plan::PlanSpec`], so verification is paid once (the
//! verifier cache) and the stage loop replays the same per-rank schedule
//! with fresh chunk views.
//!
//! The reduce path is pipelined the same way. All-reduce is elementwise,
//! so contiguous input slices compose directly ([`Chunk::slice`] per
//! stage, zero staging copies). Reduce-scatter splits along the *block*
//! dimension — stage `k` reduces sub-block `k` of every rank block — so
//! each stage pays one strided gather of `p·b/K` elements to stage its
//! input (the sub-blocks are not contiguous), and the per-stage outputs
//! are transport-delivered chunks reassembled once at the end. That
//! staging gather is *schedule-required*, not a data-plane shortcoming:
//! the stage's contribution has no contiguous view to post as a receive
//! buffer, so it sits outside the posted-receive `copied_bytes == 0`
//! guarantee by construction (the copy happens rank-locally, before the
//! transport ever sees the stage input; the per-stage reduce underneath
//! is still fully posted-receive).

use crate::comm::{Chunk, Communicator};
use crate::error::{Error, Result};
use crate::reduction::offload::Combiner;
use crate::reduction::Elem;

use super::hierarchical::{
    hier_all_gather, hier_all_gather_chunks, hier_all_reduce_chunks, hier_all_reduce_lanes_chunks,
    hier_reduce_scatter_chunks, InterAlgo,
};
use super::{slice_all_reduce, slice_reduce};

/// Pipelined two-level all-gather with `chunks` pipeline stages.
///
/// `input.len()` must be divisible by `chunks`; `chunks = 1` degenerates to
/// [`hier_all_gather`]. Output is identical to the unpipelined algorithm.
pub fn pipelined_hier_all_gather<T: Elem>(
    c: &mut Communicator<T>,
    input: &[T],
    inter: InterAlgo,
    chunks: usize,
) -> Result<Vec<T>> {
    if chunks == 0 || input.len() % chunks != 0 {
        return Err(Error::BadBufferSize {
            len: input.len(),
            size: chunks,
            why: "pipelined all-gather needs chunks > 0 dividing the input length",
        });
    }
    if chunks == 1 {
        return hier_all_gather(c, input, inter);
    }
    let p = c.size();
    let m = input.len();
    let cb = m / chunks;
    let whole = Chunk::from_slice(input);
    let mut out = vec![T::zero(); p * m];
    for k in 0..chunks {
        let piece = whole.slice(k * cb, cb);
        let gathered = hier_all_gather_chunks(c, piece, inter)?;
        debug_assert_eq!(gathered.len(), p);
        // Chunk k of rank r lands at out[r·m + k·cb ..].
        for (r, blk) in gathered.iter().enumerate() {
            debug_assert_eq!(blk.len(), cb);
            out[r * m + k * cb..r * m + (k + 1) * cb].copy_from_slice(blk.as_slice());
        }
    }
    Ok(out)
}

/// Pipelined two-level reduce-scatter with `chunks` stages: stage `k`
/// reduces sub-block `k` of every rank block through
/// [`hier_reduce_scatter_chunks`], so the inter-node phase of stage `k+1`
/// overlaps the intra-node phase of stage `k`.
///
/// `chunks` must divide the per-rank block size (`input.len() / p`);
/// `chunks = 1` degenerates to the unpipelined chunk path and returns its
/// transport-delivered block unmodified. For `chunks > 1` the `K` stage
/// outputs are reassembled into one contiguous chunk (the single output
/// copy of the pipelined path).
pub fn pipelined_hier_reduce_scatter_chunks<T: Elem>(
    c: &mut Communicator<T>,
    input: Chunk<T>,
    combiner: &Combiner<T>,
    inter: InterAlgo,
    chunks: usize,
) -> Result<Chunk<T>> {
    let p = c.size();
    let b = super::check_reduce_scatter(input.as_slice(), p)?;
    if chunks == 0 || b % chunks != 0 {
        return Err(Error::BadBufferSize {
            len: input.len(),
            size: chunks,
            why: "pipelined reduce-scatter needs chunks > 0 dividing the per-rank block size",
        });
    }
    if chunks == 1 {
        return hier_reduce_scatter_chunks(c, input, combiner, inter);
    }
    let cb = b / chunks;
    let mut parts = Vec::with_capacity(chunks);
    for k in 0..chunks {
        // Stage input: sub-block k of every rank block (strided, so this
        // gather is the one copy each stage pays).
        let mut staged = Vec::with_capacity(p * cb);
        for blk in 0..p {
            let src = blk * b + k * cb;
            staged.extend_from_slice(&input.as_slice()[src..src + cb]);
        }
        let piece = hier_reduce_scatter_chunks(c, Chunk::from_vec(staged), combiner, inter)?;
        debug_assert_eq!(piece.len(), cb);
        parts.push(piece);
    }
    Ok(Chunk::from_vec(Chunk::concat(&parts)))
}

/// Pipelined two-level reduce-scatter, slice API — adapter over
/// [`pipelined_hier_reduce_scatter_chunks`].
pub fn pipelined_hier_reduce_scatter<T: Elem>(
    c: &mut Communicator<T>,
    input: &[T],
    combiner: &Combiner<T>,
    inter: InterAlgo,
    chunks: usize,
) -> Result<Vec<T>> {
    slice_reduce(input, |ch| {
        pipelined_hier_reduce_scatter_chunks(c, ch, combiner, inter, chunks)
    })
}

/// Pipelined two-level all-reduce with `chunks` stages. All-reduce is
/// elementwise, so each stage runs [`hier_all_reduce_chunks`] over a
/// zero-copy contiguous [`Chunk::slice`] of the input and the stage block
/// lists concatenate to the full result — no staging copies at all.
///
/// `chunks` must divide `input.len()`; `chunks = 1` degenerates to the
/// unpipelined chunk path.
pub fn pipelined_hier_all_reduce_chunks<T: Elem>(
    c: &mut Communicator<T>,
    input: Chunk<T>,
    combiner: &Combiner<T>,
    inter: InterAlgo,
    chunks: usize,
) -> Result<Vec<Chunk<T>>> {
    if chunks == 0 || input.len() % chunks != 0 {
        return Err(Error::BadBufferSize {
            len: input.len(),
            size: chunks,
            why: "pipelined all-reduce needs chunks > 0 dividing the input length",
        });
    }
    if chunks == 1 {
        return hier_all_reduce_chunks(c, input, combiner, inter);
    }
    let cb = input.len() / chunks;
    let mut out = Vec::new();
    for k in 0..chunks {
        let piece = input.slice(k * cb, cb);
        let mut blocks = hier_all_reduce_chunks(c, piece, combiner, inter)?;
        out.append(&mut blocks);
    }
    Ok(out)
}

/// Lane-parallel pipelined two-level all-reduce: each pipeline stage runs
/// [`hier_all_reduce_lanes_chunks`] over a zero-copy contiguous slice of
/// the input, so within every stage the inter-node phase stripes over the
/// transport lanes while successive stages still overlap. `lanes = 1` (or
/// a single-lane transport) degenerates to
/// [`pipelined_hier_all_reduce_chunks`].
pub fn pipelined_hier_all_reduce_lanes_chunks<T: Elem>(
    c: &mut Communicator<T>,
    input: Chunk<T>,
    combiner: &Combiner<T>,
    inter: InterAlgo,
    chunks: usize,
    lanes: usize,
) -> Result<Vec<Chunk<T>>> {
    if chunks == 0 || input.len() % chunks != 0 {
        return Err(Error::BadBufferSize {
            len: input.len(),
            size: chunks,
            why: "pipelined all-reduce needs chunks > 0 dividing the input length",
        });
    }
    if chunks == 1 {
        return hier_all_reduce_lanes_chunks(c, input, combiner, inter, lanes);
    }
    let cb = input.len() / chunks;
    let mut out = Vec::new();
    for k in 0..chunks {
        let piece = input.slice(k * cb, cb);
        let mut blocks = hier_all_reduce_lanes_chunks(c, piece, combiner, inter, lanes)?;
        out.append(&mut blocks);
    }
    Ok(out)
}

/// Pipelined two-level all-reduce, slice API — adapter over
/// [`pipelined_hier_all_reduce_chunks`].
pub fn pipelined_hier_all_reduce<T: Elem>(
    c: &mut Communicator<T>,
    input: &[T],
    combiner: &Combiner<T>,
    inter: InterAlgo,
    chunks: usize,
) -> Result<Vec<T>> {
    slice_all_reduce(input, |ch| {
        pipelined_hier_all_reduce_chunks(c, ch, combiner, inter, chunks)
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::collectives::oracle;
    use crate::comm::CommWorld;
    use crate::reduction::offload::native_combine;
    use crate::topology::Topology;

    #[test]
    fn pipelined_matches_oracle_all_chunk_counts() {
        let topo = Topology::new(2, 3, 1).unwrap();
        let p = topo.world_size();
        let m = 12;
        for chunks in [1usize, 2, 3, 4, 6, 12] {
            for algo in [InterAlgo::Ring, InterAlgo::Rec] {
                let world = CommWorld::<f32>::with_topology(topo);
                let outs = world.run(move |c| {
                    let input: Vec<f32> =
                        (0..m).map(|i| (c.rank() * 1000 + i) as f32).collect();
                    pipelined_hier_all_gather(c, &input, algo, chunks).unwrap()
                });
                let ins: Vec<Vec<f32>> = (0..p)
                    .map(|r| (0..m).map(|i| (r * 1000 + i) as f32).collect())
                    .collect();
                let expect = oracle::all_gather(&ins);
                for (r, o) in outs.iter().enumerate() {
                    assert_eq!(o, &expect, "chunks={chunks} algo={algo:?} r={r}");
                }
            }
        }
    }

    #[test]
    fn bad_chunking_rejected() {
        let world = CommWorld::<f32>::with_topology(Topology::new(2, 2, 1).unwrap());
        let outs = world.run(|c| {
            pipelined_hier_all_gather(c, &[1.0; 10], InterAlgo::Rec, 3).is_err()
                && pipelined_hier_all_gather(c, &[1.0; 10], InterAlgo::Rec, 0).is_err()
        });
        assert!(outs.iter().all(|&e| e));
    }

    #[test]
    fn pipelined_reduce_scatter_matches_oracle() {
        let topo = Topology::new(2, 3, 1).unwrap();
        let p = topo.world_size();
        let b = 6; // per-rank block; stages split it 1/2/3/6 ways
        for chunks in [1usize, 2, 3, 6] {
            for algo in [InterAlgo::Ring, InterAlgo::Rec] {
                let world = CommWorld::<f32>::with_topology(topo);
                let outs = world.run(move |c| {
                    let m = p * b;
                    let input: Vec<f32> = (0..m).map(|i| (c.rank() * 100 + i) as f32).collect();
                    pipelined_hier_reduce_scatter(c, &input, &native_combine(), algo, chunks)
                        .unwrap()
                });
                let ins: Vec<Vec<f32>> = (0..p)
                    .map(|r| (0..p * b).map(|i| (r * 100 + i) as f32).collect())
                    .collect();
                for (r, o) in outs.iter().enumerate() {
                    assert_eq!(
                        o,
                        &oracle::reduce_scatter(&ins, r),
                        "chunks={chunks} algo={algo:?} r={r}"
                    );
                }
            }
        }
    }

    #[test]
    fn pipelined_all_reduce_matches_oracle_including_padding() {
        let topo = Topology::new(2, 3, 1).unwrap();
        let p = topo.world_size();
        let m = 14; // stages of 7 elements pad internally (7 % 6 != 0)
        for chunks in [1usize, 2, 7] {
            for algo in [InterAlgo::Ring, InterAlgo::Rec] {
                let world = CommWorld::<f32>::with_topology(topo);
                let outs = world.run(move |c| {
                    let input: Vec<f32> = (0..m).map(|i| (c.rank() * 10 + i) as f32).collect();
                    pipelined_hier_all_reduce(c, &input, &native_combine(), algo, chunks).unwrap()
                });
                let ins: Vec<Vec<f32>> = (0..p)
                    .map(|r| (0..m).map(|i| (r * 10 + i) as f32).collect())
                    .collect();
                let expect = oracle::all_reduce(&ins);
                for (r, o) in outs.iter().enumerate() {
                    assert_eq!(o, &expect, "chunks={chunks} algo={algo:?} r={r}");
                }
            }
        }
    }

    #[test]
    fn pipelined_lanes_all_reduce_matches_oracle() {
        use crate::comm::Chunk;
        let topo = Topology::new(2, 3, 1).unwrap();
        let p = topo.world_size();
        let m = 14;
        for chunks in [1usize, 2] {
            let world = CommWorld::<f32>::with_topology(topo).with_lanes(2);
            let outs = world.run(move |c| {
                let input: Vec<f32> = (0..m).map(|i| (c.rank() * 10 + i) as f32).collect();
                let blocks = pipelined_hier_all_reduce_lanes_chunks(
                    c,
                    Chunk::from_vec(input),
                    &native_combine(),
                    InterAlgo::Ring,
                    chunks,
                    2,
                )
                .unwrap();
                Chunk::concat(&blocks)
            });
            let ins: Vec<Vec<f32>> = (0..p)
                .map(|r| (0..m).map(|i| (r * 10 + i) as f32).collect())
                .collect();
            let expect = oracle::all_reduce(&ins);
            for (r, o) in outs.iter().enumerate() {
                assert_eq!(o, &expect, "chunks={chunks} r={r}");
            }
        }
    }

    #[test]
    fn bad_reduce_chunking_rejected() {
        let world = CommWorld::<f32>::with_topology(Topology::new(2, 2, 1).unwrap());
        let outs = world.run(|c| {
            // p = 4, input 8 → block size 2: 3 does not divide it; 0 invalid.
            pipelined_hier_reduce_scatter(c, &[1.0; 8], &native_combine(), InterAlgo::Rec, 3)
                .is_err()
                && pipelined_hier_reduce_scatter(
                    c,
                    &[1.0; 8],
                    &native_combine(),
                    InterAlgo::Rec,
                    0,
                )
                .is_err()
                && pipelined_hier_all_reduce(c, &[1.0; 10], &native_combine(), InterAlgo::Rec, 4)
                    .is_err()
        });
        assert!(outs.iter().all(|&e| e));
    }
}
