//! Two-level hierarchical collectives — the heart of PCCL (§IV-A, Fig. 5).
//!
//! * All-gather: concurrent **inter-node** all-gathers (one per local id,
//!   each bound to its own NIC), then an **intra-node** all-gather, then a
//!   device-local unshuffle.
//! * Reduce-scatter: the mirror image — pre-shuffle, intra-node RS, then
//!   inter-node RS (§IV-A: "starting with the intra-node phase followed by
//!   the inter-node phase").
//! * All-reduce: two-level reduce-scatter ∘ two-level all-gather.
//!
//! The inter-node phase takes either the ring (`PCCL_ring`) or the
//! recursive doubling/halving (`PCCL_rec`) backend; recursive requires a
//! power-of-two node count and otherwise falls back to ring (logged by the
//! caller via [`InterAlgo::effective`]).
//!
//! Since the Plan IR refactor the whole two-level composition is lowered
//! as **one plan** per collective ([`super::plan::build_hier`] internally):
//! slot `j·m + l` is the global block of rank `(node j, local l)`, the
//! inter-node phase runs over this rank's slot column, the intra-node
//! phase rotates/folds rows, and the Step-3 unshuffle is free — the plan's
//! global-rank-ordered `outputs` list *is* the permutation.
//! [`super::engine::run_hier`] segments the ops at scope changes and runs
//! each segment on the matching sub-communicator.
//!
//! Over the chunked plane the all-gather is copy-free end to end: the
//! inter phase yields one chunk per node, the intra ring forwards those
//! *views* (`n` messages per step, zero bytes moved) — each block reaches
//! every rank still backed by its origin rank's input storage. The reduce
//! paths post every combining receive (`RecvCombine` ops on
//! [`Comm::recv_combine_into`]) — including the intra-node strided phase
//! of the `Rec` inter path, which pre-IR gathered a contiguous staging
//! partial per step; the lowered schedule exchanges the per-node blocks
//! individually, so the contribution views fold in place and the last
//! copying reduce path is gone.

use crate::comm::{Chunk, Comm, Communicator};
use crate::error::Result;
use crate::reduction::offload::Combiner;
use crate::reduction::Elem;

use super::engine;
use super::plan::{self, Algo, PlanKind, PlanSpec};
use super::recursive::{rec_all_gather_chunks, rec_all_reduce_chunks, rec_reduce_scatter_chunks};
use super::ring::{
    effective_lanes, ring_all_gather_chunks, ring_all_gather_lanes_chunks,
    ring_all_reduce_chunks, ring_all_reduce_lanes_chunks, ring_reduce_scatter_chunks,
    ring_reduce_scatter_lanes_chunks,
};
use super::{
    check_all_gather, check_reduce_scatter, pad_chunk, slice_all_reduce, slice_gather,
    slice_reduce, trim_blocks,
};

/// Inter-node algorithm choice for the hierarchical collectives.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum InterAlgo {
    /// `PCCL_ring`: bandwidth-optimal, latency ∝ nodes.
    Ring,
    /// `PCCL_rec`: recursive doubling/halving, latency ∝ log2(nodes).
    Rec,
}

impl InterAlgo {
    /// The algorithm actually used for `n` nodes (recursive needs 2^k).
    pub fn effective(self, n: usize) -> InterAlgo {
        match self {
            InterAlgo::Rec if !n.is_power_of_two() => InterAlgo::Ring,
            other => other,
        }
    }
}

/// The hierarchical plan algorithm for an inter choice over `n` nodes
/// (resolved *before* spec construction, so a non-power-of-two `Rec`
/// request lowers as `HierRing`).
fn hier_algo(inter: InterAlgo, n: usize) -> Algo {
    match inter.effective(n) {
        InterAlgo::Ring => Algo::HierRing,
        InterAlgo::Rec => Algo::HierRec,
    }
}

/// Lower a hierarchical spec for this communicator's topology, verify it
/// (memoized), and execute it segment-by-segment on the matching
/// sub-communicators.
fn run_hier_plan<T: Elem>(
    c: &mut Communicator<T>,
    kind: PlanKind,
    inter: InterAlgo,
    elems: usize,
    lanes: usize,
    inputs: Vec<Chunk<T>>,
    combiner: Option<&Combiner<T>>,
) -> Result<Vec<Chunk<T>>> {
    let topo = c.topology();
    let (n, m) = (topo.nodes(), topo.gpus_per_node());
    let spec = PlanSpec::hier(kind, hier_algo(inter, n), n, m, elems, lanes);
    plan::verify_cached(&spec)?;
    let pl = plan::build(&spec, c.rank())?;
    engine::run_hier(c, &pl, inputs, combiner)
}

/// Two-level all-gather over chunks: returns the `p` per-rank blocks in
/// global rank order, each a zero-copy view of the origin rank's input
/// storage. Falls back to the flat algorithm when the topology has a
/// single node (or single GPU per node).
///
/// Hot-path note (§Perf): the intra phase forwards the inter-phase chunk
/// *list* (`n` messages per ring step instead of one concatenated buffer)
/// and the Step-3 unshuffle degenerates to the plan's output ordering —
/// no staging buffer, no transpose copy, no per-hop materialization.
pub fn hier_all_gather_chunks<T: Elem>(
    c: &mut Communicator<T>,
    input: Chunk<T>,
    inter: InterAlgo,
) -> Result<Vec<Chunk<T>>> {
    check_all_gather(input.as_slice())?;
    let topo = c.topology();
    if !topo.supports_hierarchical() {
        // Degenerate hierarchy: one level is the whole world.
        return match inter.effective(c.size()) {
            InterAlgo::Ring => ring_all_gather_chunks(c, input),
            InterAlgo::Rec => rec_all_gather_chunks(c, input),
        };
    }
    let elems = input.len();
    run_hier_plan(c, PlanKind::AllGather, inter, elems, 1, vec![input], None)
}

/// Two-level all-gather, slice API — adapter over
/// [`hier_all_gather_chunks`].
pub fn hier_all_gather<T: Elem>(
    c: &mut Communicator<T>,
    input: &[T],
    inter: InterAlgo,
) -> Result<Vec<T>> {
    slice_gather(input, |ch| hier_all_gather_chunks(c, ch, inter))
}

/// Two-level reduce-scatter over chunks (intra first, then inter).
///
/// Returns rank `r`'s reduced block. For `p > 1` the result is the
/// chunk the inter-node phase's traveling partial landed in — the unique
/// full-range view of transport-delivered storage, so `into_vec` on it is
/// a move (see [`ring_reduce_scatter_chunks`]); a ZeRO-3 shard update can
/// hold it directly with zero copies.
///
/// Both inter algorithms now share the posted intra phase: the virtual
/// pre-shuffle's segment `seg` is the block set `{(node, seg)}`, strided
/// across `input` as a segment but contiguous per block, so the intra
/// ring exchanges `n` block messages per step and posts this rank's own
/// block views straight out of `input` as combine targets — no
/// gather-segment staging copy. The `Rec` inter phase then halves over
/// the same per-node block column (block-granular messages) instead of a
/// materialized contiguous partial.
pub fn hier_reduce_scatter_chunks<T: Elem>(
    c: &mut Communicator<T>,
    input: Chunk<T>,
    combiner: &Combiner<T>,
    inter: InterAlgo,
) -> Result<Chunk<T>> {
    let p = c.size();
    let b = check_reduce_scatter(input.as_slice(), p)?;
    let topo = c.topology();
    if !topo.supports_hierarchical() {
        return match inter.effective(p) {
            InterAlgo::Ring => ring_reduce_scatter_chunks(c, input, combiner),
            InterAlgo::Rec => rec_reduce_scatter_chunks(c, input, combiner),
        };
    }
    let blocks = (0..p).map(|i| input.slice(i * b, b)).collect();
    let mut out =
        run_hier_plan(c, PlanKind::ReduceScatter, inter, p * b, 1, blocks, Some(combiner))?;
    debug_assert_eq!(out.len(), 1, "unstriped reduce-scatter yields one block");
    let out = out.pop().expect("reduce-scatter plan outputs this rank's block");
    debug_assert_eq!(out.len(), b);
    Ok(out)
}

/// Two-level reduce-scatter, slice API — adapter over
/// [`hier_reduce_scatter_chunks`].
pub fn hier_reduce_scatter<T: Elem>(
    c: &mut Communicator<T>,
    input: &[T],
    combiner: &Combiner<T>,
    inter: InterAlgo,
) -> Result<Vec<T>> {
    slice_reduce(input, |ch| hier_reduce_scatter_chunks(c, ch, combiner, inter))
}

/// Two-level all-reduce over chunks = hierarchical RS ∘ hierarchical AG,
/// lowered as **one four-phase plan** (intra RS, inter RS, inter AG,
/// intra AG) over a single slot table — the reduced shard feeds the
/// gather directly, no intermediate `Vec`. Pads once when `p ∤ n` and
/// trims the padding off the returned block list as a view adjustment;
/// the blocks concatenate to exactly `input.len()` elements. Runs the
/// composition at every `p` (including degenerate single-rank
/// topologies), keeping op-sequence numbering size-independent.
pub fn hier_all_reduce_chunks<T: Elem>(
    c: &mut Communicator<T>,
    input: Chunk<T>,
    combiner: &Combiner<T>,
    inter: InterAlgo,
) -> Result<Vec<Chunk<T>>> {
    check_all_gather(input.as_slice())?;
    let p = c.size();
    let n = input.len();
    let padded = n.div_ceil(p) * p;
    // §Perf: pad at most once, straight into the reduce-scatter input.
    let padded_input = if padded == n {
        input
    } else {
        pad_chunk(&input, padded)
    };
    let topo = c.topology();
    if !topo.supports_hierarchical() {
        return match inter.effective(p) {
            InterAlgo::Ring => {
                let mut blocks = ring_all_reduce_chunks(c, padded_input, combiner)?;
                trim_blocks(&mut blocks, n);
                Ok(blocks)
            }
            InterAlgo::Rec => {
                let mut blocks = rec_all_reduce_chunks(c, padded_input, combiner)?;
                trim_blocks(&mut blocks, n);
                Ok(blocks)
            }
        };
    }
    let b = padded / p;
    let blocks = (0..p).map(|i| padded_input.slice(i * b, b)).collect();
    let mut blocks =
        run_hier_plan(c, PlanKind::AllReduce, inter, padded, 1, blocks, Some(combiner))?;
    trim_blocks(&mut blocks, n);
    Ok(blocks)
}

/// Two-level all-reduce, slice API — adapter over
/// [`hier_all_reduce_chunks`].
pub fn hier_all_reduce<T: Elem>(
    c: &mut Communicator<T>,
    input: &[T],
    combiner: &Combiner<T>,
    inter: InterAlgo,
) -> Result<Vec<T>> {
    slice_all_reduce(input, |ch| hier_all_reduce_chunks(c, ch, combiner, inter))
}

/// Lane-parallel two-level reduce-scatter: the intra-node phase runs
/// unstriped (it models NVLink, which one lane already saturates), the
/// NIC-bound inter-node phase stripes every block over `lanes` transport
/// lanes. Returns this rank's reduced block as a stripe list
/// (concatenates to the block).
///
/// Falls back gracefully: an effective lane count of 1 delegates to
/// [`hier_reduce_scatter_chunks`]; a degenerate (non-hierarchical)
/// topology routes to the flat striped ring; a `Rec`-effective inter
/// phase runs unstriped (recursive halving's exchange ranges span
/// multiple blocks — striping it is future work).
pub fn hier_reduce_scatter_lanes_chunks<T: Elem>(
    c: &mut Communicator<T>,
    input: Chunk<T>,
    combiner: &Combiner<T>,
    inter: InterAlgo,
    lanes: usize,
) -> Result<Vec<Chunk<T>>> {
    let k = effective_lanes(c, lanes);
    if k == 1 {
        return Ok(vec![hier_reduce_scatter_chunks(c, input, combiner, inter)?]);
    }
    let p = c.size();
    let b = check_reduce_scatter(input.as_slice(), p)?;
    let topo = c.topology();
    if !topo.supports_hierarchical() {
        return match inter.effective(p) {
            InterAlgo::Ring => ring_reduce_scatter_lanes_chunks(c, input, combiner, k),
            InterAlgo::Rec => Ok(vec![rec_reduce_scatter_chunks(c, input, combiner)?]),
        };
    }
    if inter.effective(topo.nodes()) == InterAlgo::Rec {
        return Ok(vec![hier_reduce_scatter_chunks(c, input, combiner, inter)?]);
    }
    let blocks = (0..p).map(|i| input.slice(i * b, b)).collect();
    run_hier_plan(c, PlanKind::ReduceScatter, inter, p * b, k, blocks, Some(combiner))
}

/// Lane-parallel two-level all-gather: each rank's block is split into
/// `lanes` stripes; the inter phase gathers stripe-parallel, the intra
/// phase forwards the stripe views. Returns chunks that concatenate to the
/// gathered buffer (`p·k` stripes on the striped path, `p` blocks on the
/// fallbacks — callers must treat the output as an ordered chunk list, not
/// assume its arity).
pub fn hier_all_gather_lanes_chunks<T: Elem>(
    c: &mut Communicator<T>,
    input: Chunk<T>,
    inter: InterAlgo,
    lanes: usize,
) -> Result<Vec<Chunk<T>>> {
    let k = effective_lanes(c, lanes);
    if k == 1 {
        return hier_all_gather_chunks(c, input, inter);
    }
    check_all_gather(input.as_slice())?;
    let topo = c.topology();
    if !topo.supports_hierarchical() {
        return match inter.effective(c.size()) {
            InterAlgo::Ring => ring_all_gather_lanes_chunks(c, input, k),
            InterAlgo::Rec => rec_all_gather_chunks(c, input),
        };
    }
    if inter.effective(topo.nodes()) == InterAlgo::Rec {
        return hier_all_gather_chunks(c, input, inter);
    }
    let elems = input.len();
    run_hier_plan(c, PlanKind::AllGather, inter, elems, k, vec![input], None)
}

/// Lane-parallel two-level all-reduce: striped hierarchical RS ∘ striped
/// hierarchical AG as one four-phase plan, the reduced stripes feeding
/// the gather directly on their lanes. Returns chunks that concatenate to
/// exactly `input.len()` elements (stripe-granular on the striped path).
pub fn hier_all_reduce_lanes_chunks<T: Elem>(
    c: &mut Communicator<T>,
    input: Chunk<T>,
    combiner: &Combiner<T>,
    inter: InterAlgo,
    lanes: usize,
) -> Result<Vec<Chunk<T>>> {
    let k = effective_lanes(c, lanes);
    if k == 1 {
        return hier_all_reduce_chunks(c, input, combiner, inter);
    }
    check_all_gather(input.as_slice())?;
    let topo = c.topology();
    if !topo.supports_hierarchical() {
        return match inter.effective(c.size()) {
            InterAlgo::Ring => ring_all_reduce_lanes_chunks(c, input, combiner, k),
            InterAlgo::Rec => hier_all_reduce_chunks(c, input, combiner, inter),
        };
    }
    if inter.effective(topo.nodes()) == InterAlgo::Rec {
        return hier_all_reduce_chunks(c, input, combiner, inter);
    }
    let p = c.size();
    let n = input.len();
    let padded = n.div_ceil(p) * p;
    let padded_input = if padded == n {
        input
    } else {
        pad_chunk(&input, padded)
    };
    let b = padded / p;
    let blocks = (0..p).map(|i| padded_input.slice(i * b, b)).collect();
    let mut blocks =
        run_hier_plan(c, PlanKind::AllReduce, inter, padded, k, blocks, Some(combiner))?;
    trim_blocks(&mut blocks, n);
    Ok(blocks)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::collectives::oracle;
    use crate::comm::CommWorld;
    use crate::reduction::offload::native_combine;
    use crate::topology::Topology;

    fn world(nodes: usize, gpn: usize) -> CommWorld<f32> {
        CommWorld::with_topology(Topology::new(nodes, gpn, 1).unwrap())
    }

    fn rank_input(r: usize, len: usize) -> Vec<f32> {
        (0..len).map(|i| (r * 1000 + i) as f32).collect()
    }

    #[test]
    fn hier_all_gather_both_inter_algos() {
        for (nodes, gpn) in [(2, 2), (4, 2), (2, 4), (3, 2), (4, 3)] {
            for algo in [InterAlgo::Ring, InterAlgo::Rec] {
                let p = nodes * gpn;
                let m = 6;
                let outs = world(nodes, gpn).run(move |c| {
                    let input = rank_input(c.rank(), m);
                    hier_all_gather(c, &input, algo).unwrap()
                });
                let ins: Vec<Vec<f32>> = (0..p).map(|r| rank_input(r, m)).collect();
                let expect = oracle::all_gather(&ins);
                for (r, o) in outs.iter().enumerate() {
                    assert_eq!(o, &expect, "nodes={nodes} gpn={gpn} algo={algo:?} r={r}");
                }
            }
        }
    }

    #[test]
    fn hier_reduce_scatter_both_inter_algos() {
        for (nodes, gpn) in [(2, 2), (4, 2), (2, 4), (3, 2)] {
            for algo in [InterAlgo::Ring, InterAlgo::Rec] {
                let p = nodes * gpn;
                let b = 3;
                let outs = world(nodes, gpn).run(move |c| {
                    let input = rank_input(c.rank(), p * b);
                    hier_reduce_scatter(c, &input, &native_combine(), algo).unwrap()
                });
                let ins: Vec<Vec<f32>> = (0..p).map(|r| rank_input(r, p * b)).collect();
                for (r, o) in outs.iter().enumerate() {
                    assert_eq!(
                        o,
                        &oracle::reduce_scatter(&ins, r),
                        "nodes={nodes} gpn={gpn} algo={algo:?} r={r}"
                    );
                }
            }
        }
    }

    #[test]
    fn hier_all_reduce_matches_oracle() {
        let (nodes, gpn) = (2, 4);
        let p = nodes * gpn;
        let n = 21; // unaligned → padding path
        for algo in [InterAlgo::Ring, InterAlgo::Rec] {
            let outs = world(nodes, gpn).run(move |c| {
                let input = rank_input(c.rank(), n);
                hier_all_reduce(c, &input, &native_combine(), algo).unwrap()
            });
            let ins: Vec<Vec<f32>> = (0..p).map(|r| rank_input(r, n)).collect();
            let expect = oracle::all_reduce(&ins);
            for o in outs {
                assert_eq!(o, expect, "algo={algo:?}");
            }
        }
    }

    #[test]
    fn degenerate_topology_falls_back_to_flat() {
        let outs = CommWorld::<f32>::new(4).run(|c| {
            let input = rank_input(c.rank(), 2);
            hier_all_gather(c, &input, InterAlgo::Rec).unwrap()
        });
        let ins: Vec<Vec<f32>> = (0..4).map(|r| rank_input(r, 2)).collect();
        assert_eq!(outs[0], oracle::all_gather(&ins));
    }

    fn lane_world(nodes: usize, gpn: usize, lanes: usize) -> CommWorld<f32> {
        CommWorld::with_topology(Topology::new(nodes, gpn, 1).unwrap()).with_lanes(lanes)
    }

    #[test]
    fn hier_lanes_reduce_scatter_matches_oracle() {
        // b = 3 with 4 lanes → uneven stripes [1, 1, 1, 0] on the inter
        // phase; also a config where stripes are even (b = 8, 4 lanes).
        for (nodes, gpn, b) in [(2, 2, 3), (3, 2, 8), (2, 4, 5)] {
            let p = nodes * gpn;
            let outs = lane_world(nodes, gpn, 4).run(move |c| {
                let input = rank_input(c.rank(), p * b);
                let stripes = hier_reduce_scatter_lanes_chunks(
                    c,
                    Chunk::from_vec(input),
                    &native_combine(),
                    InterAlgo::Ring,
                    4,
                )
                .unwrap();
                Chunk::concat(&stripes)
            });
            let ins: Vec<Vec<f32>> = (0..p).map(|r| rank_input(r, p * b)).collect();
            for (r, o) in outs.iter().enumerate() {
                assert_eq!(
                    o,
                    &oracle::reduce_scatter(&ins, r),
                    "nodes={nodes} gpn={gpn} b={b} r={r}"
                );
            }
        }
    }

    #[test]
    fn hier_lanes_all_gather_matches_oracle() {
        let (nodes, gpn) = (3, 2);
        let p = nodes * gpn;
        let m = 7;
        let outs = lane_world(nodes, gpn, 2).run(move |c| {
            let input = rank_input(c.rank(), m);
            let blocks =
                hier_all_gather_lanes_chunks(c, Chunk::from_vec(input), InterAlgo::Ring, 2)
                    .unwrap();
            Chunk::concat(&blocks)
        });
        let ins: Vec<Vec<f32>> = (0..p).map(|r| rank_input(r, m)).collect();
        let expect = oracle::all_gather(&ins);
        for o in outs {
            assert_eq!(o, expect);
        }
    }

    #[test]
    fn hier_lanes_all_reduce_matches_oracle_unaligned() {
        let (nodes, gpn) = (2, 2);
        let p = nodes * gpn;
        let n = 21; // unaligned → padding + uneven stripes
        let outs = lane_world(nodes, gpn, 4).run(move |c| {
            let input = rank_input(c.rank(), n);
            let blocks = hier_all_reduce_lanes_chunks(
                c,
                Chunk::from_vec(input),
                &native_combine(),
                InterAlgo::Ring,
                4,
            )
            .unwrap();
            Chunk::concat(&blocks)
        });
        let ins: Vec<Vec<f32>> = (0..p).map(|r| rank_input(r, n)).collect();
        let expect = oracle::all_reduce(&ins);
        for o in outs {
            assert_eq!(o, expect);
        }
    }

    #[test]
    fn hier_lanes_rec_inter_falls_back_unstriped() {
        // Rec-effective inter phase runs unstriped but must stay correct.
        let (nodes, gpn) = (4, 2);
        let p = nodes * gpn;
        let b = 3;
        let outs = lane_world(nodes, gpn, 4).run(move |c| {
            let input = rank_input(c.rank(), p * b);
            let stripes = hier_reduce_scatter_lanes_chunks(
                c,
                Chunk::from_vec(input),
                &native_combine(),
                InterAlgo::Rec,
                4,
            )
            .unwrap();
            assert_eq!(stripes.len(), 1, "rec inter must not stripe");
            Chunk::concat(&stripes)
        });
        let ins: Vec<Vec<f32>> = (0..p).map(|r| rank_input(r, p * b)).collect();
        for (r, o) in outs.iter().enumerate() {
            assert_eq!(o, &oracle::reduce_scatter(&ins, r));
        }
    }

    #[test]
    fn hier_reduce_path_is_copy_free() {
        // Every combining receive in the hierarchical reduce path is
        // posted — including the intra phase feeding the Rec inter phase
        // (pre-IR the last copying path: it staged a contiguous partial
        // per step). Zero copied bytes for both inter algorithms.
        for (nodes, gpn, algo) in
            [(3, 2, InterAlgo::Ring), (4, 2, InterAlgo::Rec), (2, 4, InterAlgo::Rec)]
        {
            let p = nodes * gpn;
            let b = 4;
            let oks = lane_world(nodes, gpn, 2).run(move |c| {
                let input = rank_input(c.rank(), p * b);
                let before = c.traffic().copied_bytes;
                let _ = hier_reduce_scatter_lanes_chunks(
                    c,
                    Chunk::from_vec(input),
                    &native_combine(),
                    algo,
                    2,
                )
                .unwrap();
                c.traffic().copied_bytes == before
            });
            assert!(
                oks.into_iter().all(|ok| ok),
                "reduce path copied bytes (nodes={nodes} gpn={gpn} algo={algo:?})"
            );
        }
    }

    #[test]
    fn rec_falls_back_to_ring_on_non_pow2_nodes() {
        assert_eq!(InterAlgo::Rec.effective(3), InterAlgo::Ring);
        assert_eq!(InterAlgo::Rec.effective(4), InterAlgo::Rec);
        assert_eq!(InterAlgo::Ring.effective(3), InterAlgo::Ring);
    }
}
