//! Two-level hierarchical collectives — the heart of PCCL (§IV-A, Fig. 5).
//!
//! * All-gather: concurrent **inter-node** all-gathers (one per local id,
//!   each bound to its own NIC), then an **intra-node** all-gather, then a
//!   device-local unshuffle.
//! * Reduce-scatter: the mirror image — pre-shuffle, intra-node RS, then
//!   inter-node RS (§IV-A: "starting with the intra-node phase followed by
//!   the inter-node phase").
//! * All-reduce: two-level reduce-scatter ∘ two-level all-gather.
//!
//! The inter-node phase takes either the ring (`PCCL_ring`) or the
//! recursive doubling/halving (`PCCL_rec`) backend; recursive requires a
//! power-of-two node count and otherwise falls back to ring (logged by the
//! caller via [`InterAlgo::effective`]).
//!
//! Over the chunked plane the all-gather is copy-free end to end: the
//! inter phase yields one chunk per node, the intra ring forwards those
//! *views* (`n` messages per step, zero bytes moved), and the unshuffle is
//! a pointer permutation of the output list — each block reaches every
//! rank still backed by its origin rank's input storage. The seed path
//! re-materialized `p·m` elements at this layer.

use crate::comm::{Chunk, Comm, Communicator};
use crate::error::Result;
use crate::reduction::offload::Combiner;
use crate::reduction::Elem;

use super::recursive::{rec_all_gather_chunks, rec_reduce_scatter_chunks};
use super::ring::{
    effective_lanes, ring_all_gather_chunks, ring_all_gather_lanes_chunks, ring_all_gather_striped,
    ring_all_reduce_lanes_chunks, ring_reduce_scatter_blocks_chunks,
    ring_reduce_scatter_blocks_lanes_chunks, ring_reduce_scatter_chunks,
    ring_reduce_scatter_lanes_chunks,
};
use super::{
    check_all_gather, check_reduce_scatter, pad_chunk, slice_all_reduce, slice_gather,
    slice_reduce, trim_blocks,
};

/// Inter-node algorithm choice for the hierarchical collectives.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum InterAlgo {
    /// `PCCL_ring`: bandwidth-optimal, latency ∝ nodes.
    Ring,
    /// `PCCL_rec`: recursive doubling/halving, latency ∝ log2(nodes).
    Rec,
}

impl InterAlgo {
    /// The algorithm actually used for `n` nodes (recursive needs 2^k).
    pub fn effective(self, n: usize) -> InterAlgo {
        match self {
            InterAlgo::Rec if !n.is_power_of_two() => InterAlgo::Ring,
            other => other,
        }
    }
}

fn inter_all_gather_chunks<T: Elem>(
    c: &mut Communicator<T>,
    input: Chunk<T>,
    algo: InterAlgo,
) -> Result<Vec<Chunk<T>>> {
    let n = c.topology().nodes();
    let mut inter = c.inter_node()?;
    match algo.effective(n) {
        InterAlgo::Ring => ring_all_gather_chunks(&mut inter, input),
        InterAlgo::Rec => rec_all_gather_chunks(&mut inter, input),
    }
}

/// Two-level all-gather over chunks: returns the `p` per-rank blocks in
/// global rank order, each a zero-copy view of the origin rank's input
/// storage. Falls back to the flat algorithm when the topology has a
/// single node (or single GPU per node).
///
/// Hot-path note (§Perf): the intra phase forwards the inter-phase chunk
/// *list* (`n` messages per ring step instead of one concatenated buffer)
/// and the Step-3 unshuffle degenerates to placing views at their final
/// `(node, local)` positions — no staging buffer, no transpose copy, no
/// per-hop materialization.
pub fn hier_all_gather_chunks<T: Elem>(
    c: &mut Communicator<T>,
    input: Chunk<T>,
    inter: InterAlgo,
) -> Result<Vec<Chunk<T>>> {
    check_all_gather(input.as_slice())?;
    let topo = c.topology();
    if !topo.supports_hierarchical() {
        // Degenerate hierarchy: one level is the whole world.
        return match inter.effective(c.size()) {
            InterAlgo::Ring => ring_all_gather_chunks(c, input),
            InterAlgo::Rec => rec_all_gather_chunks(c, input),
        };
    }
    let n = topo.nodes();
    let m_local = topo.gpus_per_node();
    let p = n * m_local;
    // Step 1: concurrent inter-node all-gathers (one per local id). Chunk
    // `node` holds the input of global rank (node·M + our local id).
    let node_chunks = inter_all_gather_chunks(c, input, inter)?;
    debug_assert_eq!(node_chunks.len(), n);
    // Steps 2+3 fused: the intra-node ring forwards the chunk views; each
    // arrival is placed straight at its final (node, local) slot.
    let mut out: Vec<Option<Chunk<T>>> = vec![None; p];
    let mut intra = c.intra_node()?;
    let l = intra.rank();
    for (node, ch) in node_chunks.iter().enumerate() {
        out[node * m_local + l] = Some(ch.clone());
    }
    if m_local > 1 {
        intra.begin_op();
        let right = (l + 1) % m_local;
        let left = (l + m_local - 1) % m_local;
        let mut current = node_chunks;
        for s in 0..m_local - 1 {
            let recv_l = super::schedule::ring::ag_recv_block(l, m_local, s);
            for (j, ch) in current.iter().enumerate() {
                intra.send_slice(right, (s * n + j) as u32, ch.clone())?;
            }
            let mut got = Vec::with_capacity(n);
            for j in 0..n {
                got.push(intra.recv_chunk(left, (s * n + j) as u32)?);
            }
            for (j, ch) in got.iter().enumerate() {
                out[j * m_local + recv_l] = Some(ch.clone());
            }
            current = got;
        }
    }
    Ok(out
        .into_iter()
        .map(|b| b.expect("hierarchical schedule covers every rank"))
        .collect())
}

/// Two-level all-gather, slice API — adapter over
/// [`hier_all_gather_chunks`].
pub fn hier_all_gather<T: Elem>(
    c: &mut Communicator<T>,
    input: &[T],
    inter: InterAlgo,
) -> Result<Vec<T>> {
    slice_gather(input, |ch| hier_all_gather_chunks(c, ch, inter))
}

/// Two-level reduce-scatter over chunks (intra first, then inter).
///
/// Returns rank `r`'s reduced block. For `p > 1` the result is the
/// chunk the inter-node phase's traveling partial landed in — the unique
/// full-range view of transport-delivered storage, so `into_vec` on it is
/// a move (see [`ring_reduce_scatter_chunks`]); a ZeRO-3 shard update can
/// hold it directly with zero copies.
pub fn hier_reduce_scatter_chunks<T: Elem>(
    c: &mut Communicator<T>,
    input: Chunk<T>,
    combiner: &Combiner<T>,
    inter: InterAlgo,
) -> Result<Chunk<T>> {
    let p = c.size();
    let b = check_reduce_scatter(input.as_slice(), p)?;
    let topo = c.topology();
    if !topo.supports_hierarchical() {
        return match inter.effective(p) {
            InterAlgo::Ring => ring_reduce_scatter_chunks(c, input, combiner),
            InterAlgo::Rec => rec_reduce_scatter_chunks(c, input, combiner),
        };
    }
    let n = topo.nodes();
    let out = match inter.effective(n) {
        InterAlgo::Ring => {
            // Posted intra phase + block-list inter ring: zero staging
            // copies end to end (see `intra_reduce_blocks`).
            let blocks = intra_reduce_blocks(c, &input, combiner, b)?;
            let mut inter_c = c.inter_node()?;
            ring_reduce_scatter_blocks_chunks(&mut inter_c, blocks, combiner)?
        }
        InterAlgo::Rec => {
            // Documented fallback for true strides: recursive halving's
            // exchange ranges span multiple per-node blocks, so the inter
            // phase needs one contiguous n·b partial. The intra loop
            // therefore does NOT post a receive buffer — this rank's
            // contribution to a segment is *strided* across `input`
            // (blocks {(node, seg)}), and materializing a contiguous view
            // to post would reintroduce exactly the staging copy the
            // posted-receive plane removed. Instead the traveling partial
            // arrives exclusive (the sender moved its only reference into
            // the transport), `make_mut_exact` resolves in place, and the
            // strided contribution is folded in with no allocation at all.
            let m_local = topo.gpus_per_node();
            let gather_segment = |seg: usize| -> Vec<T> {
                let mut v = Vec::with_capacity(n * b);
                for node in 0..n {
                    let src = (node * m_local + seg) * b;
                    v.extend_from_slice(&input.as_slice()[src..src + b]);
                }
                v
            };
            let add_segment = |acc: &mut [T], seg: usize| {
                for node in 0..n {
                    let src = (node * m_local + seg) * b;
                    combiner
                        .fold(&mut acc[node * b..(node + 1) * b], &input.as_slice()[src..src + b]);
                }
            };
            let partial = {
                let mut intra = c.intra_node()?;
                let l = intra.rank();
                if m_local == 1 {
                    Chunk::from_vec(gather_segment(0))
                } else {
                    intra.begin_op();
                    let right = (l + 1) % m_local;
                    let left = (l + m_local - 1) % m_local;
                    use super::schedule::ring as idx;
                    let mut current =
                        Chunk::from_vec(gather_segment(idx::rs_send_block(l, m_local, 0)));
                    for s in 0..m_local - 1 {
                        let recv_seg = idx::rs_recv_block(l, m_local, s);
                        let mut got = intra.sendrecv_chunk(right, current, left, s as u32)?;
                        add_segment(got.make_mut_exact(), recv_seg);
                        current = got;
                    }
                    current
                }
            };
            debug_assert_eq!(partial.len(), n * b);
            let mut inter_c = c.inter_node()?;
            rec_reduce_scatter_chunks(&mut inter_c, partial, combiner)?
        }
    };
    debug_assert_eq!(out.len(), b);
    Ok(out)
}

/// Intra-node reduce phase with **posted contiguous-block receives**: the
/// virtual pre-shuffle's segment `seg` is the block set
/// `{(node, seg) : node ∈ 0..N}`, and while the *segment* is strided
/// across `input`, each per-node block at offset `(node·M + seg)·b` is
/// contiguous on its own. The intra ring therefore exchanges `n` block
/// messages per step and posts this rank's own block views straight out of
/// `input` as combine targets ([`Comm::recv_combine_into`]) — no
/// gather-segment staging copy, no `make_mut_exact` resolution; the first
/// fold of each block fuses into fresh exact storage and every later hop
/// folds in place. Returns the `n` reduced per-node blocks of this rank's
/// segment, ready for a block-list inter-node reduce-scatter.
fn intra_reduce_blocks<T: Elem>(
    c: &mut Communicator<T>,
    input: &Chunk<T>,
    combiner: &Combiner<T>,
    b: usize,
) -> Result<Vec<Chunk<T>>> {
    let topo = c.topology();
    let n = topo.nodes();
    let m_local = topo.gpus_per_node();
    let seg_blocks = |seg: usize| -> Vec<Chunk<T>> {
        (0..n)
            .map(|node| input.slice((node * m_local + seg) * b, b))
            .collect()
    };
    let mut intra = c.intra_node()?;
    let l = intra.rank();
    if m_local == 1 {
        return Ok(seg_blocks(0));
    }
    intra.begin_op();
    let right = (l + 1) % m_local;
    let left = (l + m_local - 1) % m_local;
    use super::schedule::ring as idx;
    let mut current = seg_blocks(idx::rs_send_block(l, m_local, 0));
    for s in 0..m_local - 1 {
        let recv_seg = idx::rs_recv_block(l, m_local, s);
        let mut accs = seg_blocks(recv_seg);
        for (j, ch) in current.into_iter().enumerate() {
            intra.send_slice(right, (s * n + j) as u32, ch)?;
        }
        for (j, acc) in accs.iter_mut().enumerate() {
            intra.recv_combine_into(left, (s * n + j) as u32, acc, combiner)?;
        }
        current = accs;
    }
    debug_assert_eq!(idx::rs_recv_block(l, m_local, m_local - 2), l);
    Ok(current)
}

/// Two-level reduce-scatter, slice API — adapter over
/// [`hier_reduce_scatter_chunks`].
pub fn hier_reduce_scatter<T: Elem>(
    c: &mut Communicator<T>,
    input: &[T],
    combiner: &Combiner<T>,
    inter: InterAlgo,
) -> Result<Vec<T>> {
    slice_reduce(input, |ch| hier_reduce_scatter_chunks(c, ch, combiner, inter))
}

/// Two-level all-reduce over chunks = hierarchical RS ∘ hierarchical AG
/// with no intermediate `Vec`: the reduced shard chunk feeds the gather
/// directly. Pads once when `p ∤ n` and trims the padding off the
/// returned block list as a view adjustment; the blocks concatenate to
/// exactly `input.len()` elements. Runs the composition at every `p`
/// (including degenerate single-rank topologies), keeping op-sequence
/// numbering size-independent.
pub fn hier_all_reduce_chunks<T: Elem>(
    c: &mut Communicator<T>,
    input: Chunk<T>,
    combiner: &Combiner<T>,
    inter: InterAlgo,
) -> Result<Vec<Chunk<T>>> {
    check_all_gather(input.as_slice())?;
    let p = c.size();
    let n = input.len();
    let padded = n.div_ceil(p) * p;
    // §Perf: pad at most once, straight into the reduce-scatter input.
    let padded_input = if padded == n {
        input
    } else {
        pad_chunk(&input, padded)
    };
    let mine = hier_reduce_scatter_chunks(c, padded_input, combiner, inter)?;
    let mut blocks = hier_all_gather_chunks(c, mine, inter)?;
    trim_blocks(&mut blocks, n);
    Ok(blocks)
}

/// Two-level all-reduce, slice API — adapter over
/// [`hier_all_reduce_chunks`].
pub fn hier_all_reduce<T: Elem>(
    c: &mut Communicator<T>,
    input: &[T],
    combiner: &Combiner<T>,
    inter: InterAlgo,
) -> Result<Vec<T>> {
    slice_all_reduce(input, |ch| hier_all_reduce_chunks(c, ch, combiner, inter))
}

/// Lane-parallel two-level reduce-scatter: the intra-node phase runs
/// unstriped (it models NVLink, which one lane already saturates), the
/// NIC-bound inter-node phase stripes every block over `lanes` transport
/// lanes ([`ring_reduce_scatter_blocks_lanes_chunks`]). Returns this
/// rank's reduced block as a stripe list (concatenates to the block).
///
/// Falls back gracefully: an effective lane count of 1 delegates to
/// [`hier_reduce_scatter_chunks`]; a degenerate (non-hierarchical)
/// topology routes to the flat striped ring; a `Rec`-effective inter
/// phase runs unstriped (recursive halving's exchange ranges span
/// multiple blocks — striping it is future work).
pub fn hier_reduce_scatter_lanes_chunks<T: Elem>(
    c: &mut Communicator<T>,
    input: Chunk<T>,
    combiner: &Combiner<T>,
    inter: InterAlgo,
    lanes: usize,
) -> Result<Vec<Chunk<T>>> {
    let k = effective_lanes(c, lanes);
    if k == 1 {
        return Ok(vec![hier_reduce_scatter_chunks(c, input, combiner, inter)?]);
    }
    let p = c.size();
    let b = check_reduce_scatter(input.as_slice(), p)?;
    let topo = c.topology();
    if !topo.supports_hierarchical() {
        return match inter.effective(p) {
            InterAlgo::Ring => ring_reduce_scatter_lanes_chunks(c, input, combiner, k),
            InterAlgo::Rec => Ok(vec![rec_reduce_scatter_chunks(c, input, combiner)?]),
        };
    }
    if inter.effective(topo.nodes()) == InterAlgo::Rec {
        return Ok(vec![hier_reduce_scatter_chunks(c, input, combiner, inter)?]);
    }
    let blocks = intra_reduce_blocks(c, &input, combiner, b)?;
    let mut inter_c = c.inter_node()?;
    ring_reduce_scatter_blocks_lanes_chunks(&mut inter_c, blocks, combiner, k)
}

/// Striped two-level all-gather core over an already-striped block: the
/// inter phase gathers the stripe lists lane-parallel, the intra ring then
/// forwards the `n·k` stripe views (zero-copy, as in the unstriped path).
/// Returns `p·k` chunks in global-rank-major, stripe-minor order.
fn hier_all_gather_striped_core<T: Elem>(
    c: &mut Communicator<T>,
    stripes: Vec<Chunk<T>>,
) -> Result<Vec<Chunk<T>>> {
    let topo = c.topology();
    let n = topo.nodes();
    let m_local = topo.gpus_per_node();
    let k = stripes.len();
    let node_stripes: Vec<Chunk<T>> = {
        let mut inter_c = c.inter_node()?;
        ring_all_gather_striped(&mut inter_c, stripes)?
            .into_iter()
            .flatten()
            .collect()
    };
    debug_assert_eq!(node_stripes.len(), n * k);
    let p = n * m_local;
    let mut out: Vec<Option<Chunk<T>>> = vec![None; p * k];
    let place = |out: &mut Vec<Option<Chunk<T>>>, who_l: usize, list: &[Chunk<T>]| {
        for (j, ch) in list.iter().enumerate() {
            let (node, stripe) = (j / k, j % k);
            out[(node * m_local + who_l) * k + stripe] = Some(ch.clone());
        }
    };
    let mut intra = c.intra_node()?;
    let l = intra.rank();
    place(&mut out, l, &node_stripes);
    if m_local > 1 {
        intra.begin_op();
        let right = (l + 1) % m_local;
        let left = (l + m_local - 1) % m_local;
        let nk = n * k;
        let mut current = node_stripes;
        for s in 0..m_local - 1 {
            let recv_l = super::schedule::ring::ag_recv_block(l, m_local, s);
            for (j, ch) in current.iter().enumerate() {
                intra.send_slice(right, (s * nk + j) as u32, ch.clone())?;
            }
            let mut got = Vec::with_capacity(nk);
            for j in 0..nk {
                got.push(intra.recv_chunk(left, (s * nk + j) as u32)?);
            }
            place(&mut out, recv_l, &got);
            current = got;
        }
    }
    Ok(out
        .into_iter()
        .map(|b| b.expect("striped hierarchical schedule covers every stripe"))
        .collect())
}

/// Lane-parallel two-level all-gather: each rank's block is split into
/// `lanes` stripes; the inter phase gathers stripe-parallel, the intra
/// phase forwards the stripe views. Returns chunks that concatenate to the
/// gathered buffer (`p·k` stripes on the striped path, `p` blocks on the
/// fallbacks — callers must treat the output as an ordered chunk list, not
/// assume its arity).
pub fn hier_all_gather_lanes_chunks<T: Elem>(
    c: &mut Communicator<T>,
    input: Chunk<T>,
    inter: InterAlgo,
    lanes: usize,
) -> Result<Vec<Chunk<T>>> {
    let k = effective_lanes(c, lanes);
    if k == 1 {
        return hier_all_gather_chunks(c, input, inter);
    }
    check_all_gather(input.as_slice())?;
    let topo = c.topology();
    if !topo.supports_hierarchical() {
        return match inter.effective(c.size()) {
            InterAlgo::Ring => ring_all_gather_lanes_chunks(c, input, k),
            InterAlgo::Rec => rec_all_gather_chunks(c, input),
        };
    }
    if inter.effective(topo.nodes()) == InterAlgo::Rec {
        return hier_all_gather_chunks(c, input, inter);
    }
    hier_all_gather_striped_core(c, input.stripes(k))
}

/// Lane-parallel two-level all-reduce: striped hierarchical RS ∘ striped
/// hierarchical AG, the reduced stripes feeding the gather directly on
/// their lanes. Returns chunks that concatenate to exactly `input.len()`
/// elements (stripe-granular on the striped path).
pub fn hier_all_reduce_lanes_chunks<T: Elem>(
    c: &mut Communicator<T>,
    input: Chunk<T>,
    combiner: &Combiner<T>,
    inter: InterAlgo,
    lanes: usize,
) -> Result<Vec<Chunk<T>>> {
    let k = effective_lanes(c, lanes);
    if k == 1 {
        return hier_all_reduce_chunks(c, input, combiner, inter);
    }
    check_all_gather(input.as_slice())?;
    let topo = c.topology();
    if !topo.supports_hierarchical() {
        return match inter.effective(c.size()) {
            InterAlgo::Ring => ring_all_reduce_lanes_chunks(c, input, combiner, k),
            InterAlgo::Rec => hier_all_reduce_chunks(c, input, combiner, inter),
        };
    }
    if inter.effective(topo.nodes()) == InterAlgo::Rec {
        return hier_all_reduce_chunks(c, input, combiner, inter);
    }
    let p = c.size();
    let n = input.len();
    let padded = n.div_ceil(p) * p;
    let padded_input = if padded == n {
        input
    } else {
        pad_chunk(&input, padded)
    };
    let stripes = hier_reduce_scatter_lanes_chunks(c, padded_input, combiner, inter, k)?;
    let mut blocks = hier_all_gather_striped_core(c, stripes)?;
    trim_blocks(&mut blocks, n);
    Ok(blocks)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::collectives::oracle;
    use crate::comm::CommWorld;
    use crate::reduction::offload::native_combine;
    use crate::topology::Topology;

    fn world(nodes: usize, gpn: usize) -> CommWorld<f32> {
        CommWorld::with_topology(Topology::new(nodes, gpn, 1).unwrap())
    }

    fn rank_input(r: usize, len: usize) -> Vec<f32> {
        (0..len).map(|i| (r * 1000 + i) as f32).collect()
    }

    #[test]
    fn hier_all_gather_both_inter_algos() {
        for (nodes, gpn) in [(2, 2), (4, 2), (2, 4), (3, 2), (4, 3)] {
            for algo in [InterAlgo::Ring, InterAlgo::Rec] {
                let p = nodes * gpn;
                let m = 6;
                let outs = world(nodes, gpn).run(move |c| {
                    let input = rank_input(c.rank(), m);
                    hier_all_gather(c, &input, algo).unwrap()
                });
                let ins: Vec<Vec<f32>> = (0..p).map(|r| rank_input(r, m)).collect();
                let expect = oracle::all_gather(&ins);
                for (r, o) in outs.iter().enumerate() {
                    assert_eq!(o, &expect, "nodes={nodes} gpn={gpn} algo={algo:?} r={r}");
                }
            }
        }
    }

    #[test]
    fn hier_reduce_scatter_both_inter_algos() {
        for (nodes, gpn) in [(2, 2), (4, 2), (2, 4), (3, 2)] {
            for algo in [InterAlgo::Ring, InterAlgo::Rec] {
                let p = nodes * gpn;
                let b = 3;
                let outs = world(nodes, gpn).run(move |c| {
                    let input = rank_input(c.rank(), p * b);
                    hier_reduce_scatter(c, &input, &native_combine(), algo).unwrap()
                });
                let ins: Vec<Vec<f32>> = (0..p).map(|r| rank_input(r, p * b)).collect();
                for (r, o) in outs.iter().enumerate() {
                    assert_eq!(
                        o,
                        &oracle::reduce_scatter(&ins, r),
                        "nodes={nodes} gpn={gpn} algo={algo:?} r={r}"
                    );
                }
            }
        }
    }

    #[test]
    fn hier_all_reduce_matches_oracle() {
        let (nodes, gpn) = (2, 4);
        let p = nodes * gpn;
        let n = 21; // unaligned → padding path
        for algo in [InterAlgo::Ring, InterAlgo::Rec] {
            let outs = world(nodes, gpn).run(move |c| {
                let input = rank_input(c.rank(), n);
                hier_all_reduce(c, &input, &native_combine(), algo).unwrap()
            });
            let ins: Vec<Vec<f32>> = (0..p).map(|r| rank_input(r, n)).collect();
            let expect = oracle::all_reduce(&ins);
            for o in outs {
                assert_eq!(o, expect, "algo={algo:?}");
            }
        }
    }

    #[test]
    fn degenerate_topology_falls_back_to_flat() {
        let outs = CommWorld::<f32>::new(4).run(|c| {
            let input = rank_input(c.rank(), 2);
            hier_all_gather(c, &input, InterAlgo::Rec).unwrap()
        });
        let ins: Vec<Vec<f32>> = (0..4).map(|r| rank_input(r, 2)).collect();
        assert_eq!(outs[0], oracle::all_gather(&ins));
    }

    fn lane_world(nodes: usize, gpn: usize, lanes: usize) -> CommWorld<f32> {
        CommWorld::with_topology(Topology::new(nodes, gpn, 1).unwrap()).with_lanes(lanes)
    }

    #[test]
    fn hier_lanes_reduce_scatter_matches_oracle() {
        // b = 3 with 4 lanes → uneven stripes [1, 1, 1, 0] on the inter
        // phase; also a config where stripes are even (b = 8, 4 lanes).
        for (nodes, gpn, b) in [(2, 2, 3), (3, 2, 8), (2, 4, 5)] {
            let p = nodes * gpn;
            let outs = lane_world(nodes, gpn, 4).run(move |c| {
                let input = rank_input(c.rank(), p * b);
                let stripes = hier_reduce_scatter_lanes_chunks(
                    c,
                    Chunk::from_vec(input),
                    &native_combine(),
                    InterAlgo::Ring,
                    4,
                )
                .unwrap();
                Chunk::concat(&stripes)
            });
            let ins: Vec<Vec<f32>> = (0..p).map(|r| rank_input(r, p * b)).collect();
            for (r, o) in outs.iter().enumerate() {
                assert_eq!(
                    o,
                    &oracle::reduce_scatter(&ins, r),
                    "nodes={nodes} gpn={gpn} b={b} r={r}"
                );
            }
        }
    }

    #[test]
    fn hier_lanes_all_gather_matches_oracle() {
        let (nodes, gpn) = (3, 2);
        let p = nodes * gpn;
        let m = 7;
        let outs = lane_world(nodes, gpn, 2).run(move |c| {
            let input = rank_input(c.rank(), m);
            let blocks =
                hier_all_gather_lanes_chunks(c, Chunk::from_vec(input), InterAlgo::Ring, 2)
                    .unwrap();
            Chunk::concat(&blocks)
        });
        let ins: Vec<Vec<f32>> = (0..p).map(|r| rank_input(r, m)).collect();
        let expect = oracle::all_gather(&ins);
        for o in outs {
            assert_eq!(o, expect);
        }
    }

    #[test]
    fn hier_lanes_all_reduce_matches_oracle_unaligned() {
        let (nodes, gpn) = (2, 2);
        let p = nodes * gpn;
        let n = 21; // unaligned → padding + uneven stripes
        let outs = lane_world(nodes, gpn, 4).run(move |c| {
            let input = rank_input(c.rank(), n);
            let blocks = hier_all_reduce_lanes_chunks(
                c,
                Chunk::from_vec(input),
                &native_combine(),
                InterAlgo::Ring,
                4,
            )
            .unwrap();
            Chunk::concat(&blocks)
        });
        let ins: Vec<Vec<f32>> = (0..p).map(|r| rank_input(r, n)).collect();
        let expect = oracle::all_reduce(&ins);
        for o in outs {
            assert_eq!(o, expect);
        }
    }

    #[test]
    fn hier_lanes_rec_inter_falls_back_unstriped() {
        // Rec-effective inter phase runs unstriped but must stay correct.
        let (nodes, gpn) = (4, 2);
        let p = nodes * gpn;
        let b = 3;
        let outs = lane_world(nodes, gpn, 4).run(move |c| {
            let input = rank_input(c.rank(), p * b);
            let stripes = hier_reduce_scatter_lanes_chunks(
                c,
                Chunk::from_vec(input),
                &native_combine(),
                InterAlgo::Rec,
                4,
            )
            .unwrap();
            assert_eq!(stripes.len(), 1, "rec inter must not stripe");
            Chunk::concat(&stripes)
        });
        let ins: Vec<Vec<f32>> = (0..p).map(|r| rank_input(r, p * b)).collect();
        for (r, o) in outs.iter().enumerate() {
            assert_eq!(o, &oracle::reduce_scatter(&ins, r));
        }
    }

    #[test]
    fn hier_reduce_path_is_copy_free() {
        // The posted intra phase (contiguous per-node block receives) must
        // keep the whole hierarchical reduce path at zero copied bytes.
        let (nodes, gpn) = (3, 2);
        let p = nodes * gpn;
        let b = 4;
        let oks = lane_world(nodes, gpn, 2).run(move |c| {
            let input = rank_input(c.rank(), p * b);
            let before = c.traffic().copied_bytes;
            let _ = hier_reduce_scatter_lanes_chunks(
                c,
                Chunk::from_vec(input),
                &native_combine(),
                InterAlgo::Ring,
                2,
            )
            .unwrap();
            c.traffic().copied_bytes == before
        });
        assert!(oks.into_iter().all(|ok| ok), "reduce path copied bytes");
    }

    #[test]
    fn rec_falls_back_to_ring_on_non_pow2_nodes() {
        assert_eq!(InterAlgo::Rec.effective(3), InterAlgo::Ring);
        assert_eq!(InterAlgo::Rec.effective(4), InterAlgo::Rec);
        assert_eq!(InterAlgo::Ring.effective(3), InterAlgo::Ring);
    }
}
