//! Two-level hierarchical collectives — the heart of PCCL (§IV-A, Fig. 5).
//!
//! * All-gather: concurrent **inter-node** all-gathers (one per local id,
//!   each bound to its own NIC), then an **intra-node** all-gather, then a
//!   device-local unshuffle.
//! * Reduce-scatter: the mirror image — pre-shuffle, intra-node RS, then
//!   inter-node RS (§IV-A: "starting with the intra-node phase followed by
//!   the inter-node phase").
//! * All-reduce: two-level reduce-scatter ∘ two-level all-gather.
//!
//! The inter-node phase takes either the ring (`PCCL_ring`) or the
//! recursive doubling/halving (`PCCL_rec`) backend; recursive requires a
//! power-of-two node count and otherwise falls back to ring (logged by the
//! caller via [`InterAlgo::effective`]).
//!
//! Over the chunked plane the all-gather is copy-free end to end: the
//! inter phase yields one chunk per node, the intra ring forwards those
//! *views* (`n` messages per step, zero bytes moved), and the unshuffle is
//! a pointer permutation of the output list — each block reaches every
//! rank still backed by its origin rank's input storage. The seed path
//! re-materialized `p·m` elements at this layer.

use crate::comm::{Chunk, Comm, Communicator};
use crate::error::Result;
use crate::reduction::offload::Combiner;
use crate::reduction::Elem;

use super::recursive::{rec_all_gather_chunks, rec_reduce_scatter_chunks};
use super::ring::{ring_all_gather_chunks, ring_reduce_scatter_chunks};
use super::{
    check_all_gather, check_reduce_scatter, pad_chunk, slice_all_reduce, slice_gather,
    slice_reduce, trim_blocks,
};

/// Inter-node algorithm choice for the hierarchical collectives.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum InterAlgo {
    /// `PCCL_ring`: bandwidth-optimal, latency ∝ nodes.
    Ring,
    /// `PCCL_rec`: recursive doubling/halving, latency ∝ log2(nodes).
    Rec,
}

impl InterAlgo {
    /// The algorithm actually used for `n` nodes (recursive needs 2^k).
    pub fn effective(self, n: usize) -> InterAlgo {
        match self {
            InterAlgo::Rec if !n.is_power_of_two() => InterAlgo::Ring,
            other => other,
        }
    }
}

fn inter_all_gather_chunks<T: Elem>(
    c: &mut Communicator<T>,
    input: Chunk<T>,
    algo: InterAlgo,
) -> Result<Vec<Chunk<T>>> {
    let n = c.topology().nodes();
    let mut inter = c.inter_node()?;
    match algo.effective(n) {
        InterAlgo::Ring => ring_all_gather_chunks(&mut inter, input),
        InterAlgo::Rec => rec_all_gather_chunks(&mut inter, input),
    }
}

fn inter_reduce_scatter_chunks<T: Elem>(
    c: &mut Communicator<T>,
    input: Chunk<T>,
    combiner: &Combiner<T>,
    algo: InterAlgo,
) -> Result<Chunk<T>> {
    let n = c.topology().nodes();
    let mut inter = c.inter_node()?;
    match algo.effective(n) {
        InterAlgo::Ring => ring_reduce_scatter_chunks(&mut inter, input, combiner),
        InterAlgo::Rec => rec_reduce_scatter_chunks(&mut inter, input, combiner),
    }
}

/// Two-level all-gather over chunks: returns the `p` per-rank blocks in
/// global rank order, each a zero-copy view of the origin rank's input
/// storage. Falls back to the flat algorithm when the topology has a
/// single node (or single GPU per node).
///
/// Hot-path note (§Perf): the intra phase forwards the inter-phase chunk
/// *list* (`n` messages per ring step instead of one concatenated buffer)
/// and the Step-3 unshuffle degenerates to placing views at their final
/// `(node, local)` positions — no staging buffer, no transpose copy, no
/// per-hop materialization.
pub fn hier_all_gather_chunks<T: Elem>(
    c: &mut Communicator<T>,
    input: Chunk<T>,
    inter: InterAlgo,
) -> Result<Vec<Chunk<T>>> {
    check_all_gather(input.as_slice())?;
    let topo = c.topology();
    if !topo.supports_hierarchical() {
        // Degenerate hierarchy: one level is the whole world.
        return match inter.effective(c.size()) {
            InterAlgo::Ring => ring_all_gather_chunks(c, input),
            InterAlgo::Rec => rec_all_gather_chunks(c, input),
        };
    }
    let n = topo.nodes();
    let m_local = topo.gpus_per_node();
    let p = n * m_local;
    // Step 1: concurrent inter-node all-gathers (one per local id). Chunk
    // `node` holds the input of global rank (node·M + our local id).
    let node_chunks = inter_all_gather_chunks(c, input, inter)?;
    debug_assert_eq!(node_chunks.len(), n);
    // Steps 2+3 fused: the intra-node ring forwards the chunk views; each
    // arrival is placed straight at its final (node, local) slot.
    let mut out: Vec<Option<Chunk<T>>> = vec![None; p];
    let mut intra = c.intra_node()?;
    let l = intra.rank();
    for (node, ch) in node_chunks.iter().enumerate() {
        out[node * m_local + l] = Some(ch.clone());
    }
    if m_local > 1 {
        intra.begin_op();
        let right = (l + 1) % m_local;
        let left = (l + m_local - 1) % m_local;
        let mut current = node_chunks;
        for s in 0..m_local - 1 {
            let recv_l = super::schedule::ring::ag_recv_block(l, m_local, s);
            for (j, ch) in current.iter().enumerate() {
                intra.send_slice(right, (s * n + j) as u32, ch.clone())?;
            }
            let mut got = Vec::with_capacity(n);
            for j in 0..n {
                got.push(intra.recv_chunk(left, (s * n + j) as u32)?);
            }
            for (j, ch) in got.iter().enumerate() {
                out[j * m_local + recv_l] = Some(ch.clone());
            }
            current = got;
        }
    }
    Ok(out
        .into_iter()
        .map(|b| b.expect("hierarchical schedule covers every rank"))
        .collect())
}

/// Two-level all-gather, slice API — adapter over
/// [`hier_all_gather_chunks`].
pub fn hier_all_gather<T: Elem>(
    c: &mut Communicator<T>,
    input: &[T],
    inter: InterAlgo,
) -> Result<Vec<T>> {
    slice_gather(input, |ch| hier_all_gather_chunks(c, ch, inter))
}

/// Two-level reduce-scatter over chunks (intra first, then inter).
///
/// Returns rank `r`'s reduced block. For `p > 1` the result is the
/// chunk the inter-node phase's traveling partial landed in — the unique
/// full-range view of transport-delivered storage, so `into_vec` on it is
/// a move (see [`ring_reduce_scatter_chunks`]); a ZeRO-3 shard update can
/// hold it directly with zero copies.
pub fn hier_reduce_scatter_chunks<T: Elem>(
    c: &mut Communicator<T>,
    input: Chunk<T>,
    combiner: &Combiner<T>,
    inter: InterAlgo,
) -> Result<Chunk<T>> {
    let p = c.size();
    let b = check_reduce_scatter(input.as_slice(), p)?;
    let topo = c.topology();
    if !topo.supports_hierarchical() {
        return match inter.effective(p) {
            InterAlgo::Ring => ring_reduce_scatter_chunks(c, input, combiner),
            InterAlgo::Rec => rec_reduce_scatter_chunks(c, input, combiner),
        };
    }
    let n = topo.nodes();
    let m_local = topo.gpus_per_node();
    // Hot path (§Perf): the pre-shuffle is *virtual* — instead of
    // materializing the (local_id, node)-ordered copy of the whole input,
    // the intra-node ring gathers each segment's strided blocks on demand
    // and combines contributions straight out of `input`. A reduction
    // writes new data at every hop by definition, so (unlike all-gather)
    // the partials themselves must be materialized — but each received
    // partial is uniquely owned exact storage, so the in-place combine
    // never copies.
    //
    // This intra loop deliberately does NOT post a receive buffer
    // (`sendrecv_combine_into`): this rank's contribution to a segment is
    // *strided* across `input` (blocks {(node, seg)}), so there is no
    // contiguous view to post — materializing one would reintroduce
    // exactly the staging copy the posted-receive plane removed. Instead
    // the traveling partial arrives exclusive (the sender moved its only
    // reference into the transport), `make_mut_exact` resolves in place,
    // and the strided contribution is folded in with no allocation at all.
    //
    // Segment `l` = blocks {(node, l) : node ∈ 0..N} = the data destined
    // for local id `l`'s inter-node phase.
    let gather_segment = |seg: usize| -> Vec<T> {
        let mut v = Vec::with_capacity(n * b);
        for node in 0..n {
            let src = (node * m_local + seg) * b;
            v.extend_from_slice(&input.as_slice()[src..src + b]);
        }
        v
    };
    let add_segment = |acc: &mut [T], seg: usize| {
        for node in 0..n {
            let src = (node * m_local + seg) * b;
            combiner.fold(&mut acc[node * b..(node + 1) * b], &input.as_slice()[src..src + b]);
        }
    };
    let partial = {
        let mut intra = c.intra_node()?;
        let l = intra.rank();
        if m_local == 1 {
            Chunk::from_vec(gather_segment(0))
        } else {
            intra.begin_op();
            let right = (l + 1) % m_local;
            let left = (l + m_local - 1) % m_local;
            use super::schedule::ring as idx;
            let mut current = Chunk::from_vec(gather_segment(idx::rs_send_block(l, m_local, 0)));
            for s in 0..m_local - 1 {
                let recv_seg = idx::rs_recv_block(l, m_local, s);
                let mut got = intra.sendrecv_chunk(right, current, left, s as u32)?;
                add_segment(got.make_mut_exact(), recv_seg);
                current = got;
            }
            current
        }
    };
    debug_assert_eq!(partial.len(), n * b);
    // Inter-node reduce-scatter over blocks of b elements — the partial
    // chunk feeds it directly, no slice round-trip.
    let out = inter_reduce_scatter_chunks(c, partial, combiner, inter)?;
    debug_assert_eq!(out.len(), b);
    Ok(out)
}

/// Two-level reduce-scatter, slice API — adapter over
/// [`hier_reduce_scatter_chunks`].
pub fn hier_reduce_scatter<T: Elem>(
    c: &mut Communicator<T>,
    input: &[T],
    combiner: &Combiner<T>,
    inter: InterAlgo,
) -> Result<Vec<T>> {
    slice_reduce(input, |ch| hier_reduce_scatter_chunks(c, ch, combiner, inter))
}

/// Two-level all-reduce over chunks = hierarchical RS ∘ hierarchical AG
/// with no intermediate `Vec`: the reduced shard chunk feeds the gather
/// directly. Pads once when `p ∤ n` and trims the padding off the
/// returned block list as a view adjustment; the blocks concatenate to
/// exactly `input.len()` elements. Runs the composition at every `p`
/// (including degenerate single-rank topologies), keeping op-sequence
/// numbering size-independent.
pub fn hier_all_reduce_chunks<T: Elem>(
    c: &mut Communicator<T>,
    input: Chunk<T>,
    combiner: &Combiner<T>,
    inter: InterAlgo,
) -> Result<Vec<Chunk<T>>> {
    check_all_gather(input.as_slice())?;
    let p = c.size();
    let n = input.len();
    let padded = n.div_ceil(p) * p;
    // §Perf: pad at most once, straight into the reduce-scatter input.
    let padded_input = if padded == n {
        input
    } else {
        pad_chunk(&input, padded)
    };
    let mine = hier_reduce_scatter_chunks(c, padded_input, combiner, inter)?;
    let mut blocks = hier_all_gather_chunks(c, mine, inter)?;
    trim_blocks(&mut blocks, n);
    Ok(blocks)
}

/// Two-level all-reduce, slice API — adapter over
/// [`hier_all_reduce_chunks`].
pub fn hier_all_reduce<T: Elem>(
    c: &mut Communicator<T>,
    input: &[T],
    combiner: &Combiner<T>,
    inter: InterAlgo,
) -> Result<Vec<T>> {
    slice_all_reduce(input, |ch| hier_all_reduce_chunks(c, ch, combiner, inter))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::collectives::oracle;
    use crate::comm::CommWorld;
    use crate::reduction::offload::native_combine;
    use crate::topology::Topology;

    fn world(nodes: usize, gpn: usize) -> CommWorld<f32> {
        CommWorld::with_topology(Topology::new(nodes, gpn, 1).unwrap())
    }

    fn rank_input(r: usize, len: usize) -> Vec<f32> {
        (0..len).map(|i| (r * 1000 + i) as f32).collect()
    }

    #[test]
    fn hier_all_gather_both_inter_algos() {
        for (nodes, gpn) in [(2, 2), (4, 2), (2, 4), (3, 2), (4, 3)] {
            for algo in [InterAlgo::Ring, InterAlgo::Rec] {
                let p = nodes * gpn;
                let m = 6;
                let outs = world(nodes, gpn).run(move |c| {
                    let input = rank_input(c.rank(), m);
                    hier_all_gather(c, &input, algo).unwrap()
                });
                let ins: Vec<Vec<f32>> = (0..p).map(|r| rank_input(r, m)).collect();
                let expect = oracle::all_gather(&ins);
                for (r, o) in outs.iter().enumerate() {
                    assert_eq!(o, &expect, "nodes={nodes} gpn={gpn} algo={algo:?} r={r}");
                }
            }
        }
    }

    #[test]
    fn hier_reduce_scatter_both_inter_algos() {
        for (nodes, gpn) in [(2, 2), (4, 2), (2, 4), (3, 2)] {
            for algo in [InterAlgo::Ring, InterAlgo::Rec] {
                let p = nodes * gpn;
                let b = 3;
                let outs = world(nodes, gpn).run(move |c| {
                    let input = rank_input(c.rank(), p * b);
                    hier_reduce_scatter(c, &input, &native_combine(), algo).unwrap()
                });
                let ins: Vec<Vec<f32>> = (0..p).map(|r| rank_input(r, p * b)).collect();
                for (r, o) in outs.iter().enumerate() {
                    assert_eq!(
                        o,
                        &oracle::reduce_scatter(&ins, r),
                        "nodes={nodes} gpn={gpn} algo={algo:?} r={r}"
                    );
                }
            }
        }
    }

    #[test]
    fn hier_all_reduce_matches_oracle() {
        let (nodes, gpn) = (2, 4);
        let p = nodes * gpn;
        let n = 21; // unaligned → padding path
        for algo in [InterAlgo::Ring, InterAlgo::Rec] {
            let outs = world(nodes, gpn).run(move |c| {
                let input = rank_input(c.rank(), n);
                hier_all_reduce(c, &input, &native_combine(), algo).unwrap()
            });
            let ins: Vec<Vec<f32>> = (0..p).map(|r| rank_input(r, n)).collect();
            let expect = oracle::all_reduce(&ins);
            for o in outs {
                assert_eq!(o, expect, "algo={algo:?}");
            }
        }
    }

    #[test]
    fn degenerate_topology_falls_back_to_flat() {
        let outs = CommWorld::<f32>::new(4).run(|c| {
            let input = rank_input(c.rank(), 2);
            hier_all_gather(c, &input, InterAlgo::Rec).unwrap()
        });
        let ins: Vec<Vec<f32>> = (0..4).map(|r| rank_input(r, 2)).collect();
        assert_eq!(outs[0], oracle::all_gather(&ins));
    }

    #[test]
    fn rec_falls_back_to_ring_on_non_pow2_nodes() {
        assert_eq!(InterAlgo::Rec.effective(3), InterAlgo::Ring);
        assert_eq!(InterAlgo::Rec.effective(4), InterAlgo::Rec);
        assert_eq!(InterAlgo::Ring.effective(3), InterAlgo::Ring);
    }
}
