//! Device-local shuffle — Step 3 of the paper's hierarchical all-gather
//! (Fig. 5): after the inter- then intra-node gathers, each GPU holds the
//! full output in `(local_id, node)` block order and must transpose it to
//! global `(node, local_id)` rank order. Reduce-scatter applies the inverse
//! permutation *before* communicating.
//!
//! On the real system this is the L1 Pallas `shuffle` kernel; the native
//! implementation here is its host-side twin (and test oracle).

/// Transpose an `(outer, inner)` grid of `block`-element chunks:
/// `out[(j·outer + i)·block ..] = buf[(i·inner + j)·block ..]`.
pub fn transpose_blocks<T: Copy>(buf: &[T], outer: usize, inner: usize, block: usize) -> Vec<T> {
    assert_eq!(
        buf.len(),
        outer * inner * block,
        "transpose_blocks: buffer len {} != {outer}×{inner}×{block}",
        buf.len()
    );
    let mut out = Vec::with_capacity(buf.len());
    for j in 0..inner {
        for i in 0..outer {
            let src = (i * inner + j) * block;
            out.extend_from_slice(&buf[src..src + block]);
        }
    }
    out
}

/// All-gather unshuffle: `(local_id ∈ M, node ∈ N)` → `(node, local_id)`.
pub fn unshuffle<T: Copy>(buf: &[T], n_nodes: usize, m_local: usize, block: usize) -> Vec<T> {
    transpose_blocks(buf, m_local, n_nodes, block)
}

/// Reduce-scatter pre-shuffle: `(node ∈ N, local_id ∈ M)` global-rank order
/// → `(local_id, node)` hierarchical order.
pub fn shuffle_gather<T: Copy>(buf: &[T], n_nodes: usize, m_local: usize, block: usize) -> Vec<T> {
    transpose_blocks(buf, n_nodes, m_local, block)
}

/// Zero-copy twin of [`transpose_blocks`] for the chunked plane: permutes
/// the block *list* (O(outer·inner) pointer clones) without touching a
/// byte. This is why the fused hierarchical all-gather needs no transpose
/// kernel at all — the unshuffle is free once blocks are views.
///
/// Lowered as a communication-free [`super::plan`] shuffle (the plan's
/// `outputs` list *is* the permutation) and applied by
/// [`super::engine::run_local`], so the same verified object the netsim
/// costs is what reorders the blocks here.
pub fn transpose_chunk_blocks<T>(
    blocks: &[crate::comm::Chunk<T>],
    outer: usize,
    inner: usize,
) -> Vec<crate::comm::Chunk<T>> {
    assert_eq!(
        blocks.len(),
        outer * inner,
        "transpose_chunk_blocks: {} blocks != {outer}×{inner}",
        blocks.len()
    );
    if blocks.is_empty() {
        return Vec::new();
    }
    let spec = super::plan::PlanSpec::shuffle(outer, inner);
    super::plan::verify_cached(&spec).expect("shuffle plans are statically valid");
    let pl = super::plan::build(&spec, 0).expect("shuffle plans lower for any grid");
    super::engine::run_local(&pl, blocks.to_vec()).expect("local plans cannot fail")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn transpose_2x3() {
        // blocks labeled by (i, j)
        let buf: Vec<i32> = vec![
            00, 00, // (0,0)
            01, 01, // (0,1)
            02, 02, // (0,2)
            10, 10, // (1,0)
            11, 11, // (1,1)
            12, 12, // (1,2)
        ];
        let t = transpose_blocks(&buf, 2, 3, 2);
        assert_eq!(t, vec![00, 00, 10, 10, 01, 01, 11, 11, 02, 02, 12, 12]);
    }

    #[test]
    fn shuffle_roundtrip() {
        let n = 4;
        let m = 3;
        let block = 5;
        let buf: Vec<u32> = (0..(n * m * block) as u32).collect();
        let once = unshuffle(&buf, n, m, block);
        let back = shuffle_gather(&once, n, m, block);
        assert_eq!(back, buf);
    }

    #[test]
    fn chunk_transpose_matches_element_transpose() {
        use crate::comm::Chunk;
        let n = 3;
        let m = 2;
        let block = 2;
        let buf: Vec<i32> = (0..(n * m * block) as i32).collect();
        let whole = Chunk::from_vec(buf.clone());
        let blocks: Vec<Chunk<i32>> = (0..n * m).map(|i| whole.slice(i * block, block)).collect();
        let permuted = transpose_chunk_blocks(&blocks, n, m);
        // Same permutation as the element-wise kernel, zero bytes moved.
        assert_eq!(Chunk::concat(&permuted), transpose_blocks(&buf, n, m, block));
        assert!(permuted.iter().all(|c| c.storage_id() == whole.storage_id()));
    }

    #[test]
    fn unshuffle_produces_global_rank_order() {
        // M=2 locals, N=2 nodes; value = global rank of origin.
        // Hierarchical buffer order is (l, n): l0n0=rank0, l0n1=rank2,
        // l1n0=rank1, l1n1=rank3 (rank = n*M + l).
        let buf = vec![0, 2, 1, 3];
        let out = unshuffle(&buf, 2, 2, 1);
        assert_eq!(out, vec![0, 1, 2, 3]);
    }
}
