//! The plan engine: one executor for every lowered collective.
//!
//! [`run_flat`] executes a single-scope plan against any [`Comm`];
//! [`run_hier`] segments a hierarchical plan at scope changes and runs
//! each segment on the matching sub-communicator of a world
//! [`Communicator`]; [`run_local`] applies a communication-free plan
//! (shuffle). The engine is deliberately dumb: all schedule intelligence
//! lives in [`super::plan`], and the ops map one-to-one onto the comm
//! primitives, preserving the zero-copy ownership discipline of the
//! imperative data plane they replaced:
//!
//! - slot chunks are *moved* in from the caller ([`SlotInit::Take`]), and
//!   the engine drops the leftover input list before executing, so a
//!   whole-input slot regains storage exclusivity (in-place accumulators,
//!   identity-preserving pass-through at `p == 1`);
//! - `Send { take: false }` posts an O(1) shared view, `take: true`
//!   transfers ownership (the moved sends of the reduce paths);
//! - combining receives are posted ([`Comm::recv_combine_into`] /
//!   [`Comm::sendrecv_combine_into`] and their striped forms), so folds
//!   land in receiver-designated storage with zero staging copies;
//! - striped exchanges stripe the slot *at take time*
//!   ([`Chunk::stripes`] on demand), matching the lane data plane's
//!   stripe-at-take semantics.

use std::time::Instant;

use crate::comm::{Chunk, Comm, Communicator};
use crate::error::{Error, Result};
use crate::reduction::offload::Combiner;
use crate::reduction::Elem;
use crate::trace::{self, RankTrace};

use super::plan::{Op, Plan, Scope, SlotInit};

/// Seed the slot table from the caller's chunks. Inputs are moved, never
/// copied; any chunk not claimed by a slot is dropped here, which is what
/// restores exclusivity on the claimed views.
fn materialize<T>(slots: &[SlotInit], inputs: Vec<Chunk<T>>) -> Result<Vec<Vec<Chunk<T>>>> {
    let mut pool: Vec<Option<Chunk<T>>> = inputs.into_iter().map(Some).collect();
    let mut take = |i: usize| -> Result<Chunk<T>> {
        pool.get_mut(i)
            .and_then(Option::take)
            .ok_or_else(|| Error::Plan(format!("plan input {i} missing or claimed twice")))
    };
    slots
        .iter()
        .map(|init| match *init {
            SlotInit::Empty { parts } => Ok((0..parts).map(|_| Chunk::empty()).collect()),
            SlotInit::Take(i) => Ok(vec![take(i)?]),
            SlotInit::TakeStripes { input, k } => Ok(take(input)?.stripes(k)),
        })
        .collect()
}

fn take_part<T>(slots: &mut [Vec<Chunk<T>>], slot: usize, part: usize) -> Chunk<T> {
    std::mem::replace(&mut slots[slot][part], Chunk::empty())
}

fn put_part<T>(slots: &mut [Vec<Chunk<T>>], slot: usize, part: usize, chunk: Chunk<T>) {
    let parts = &mut slots[slot];
    if parts.len() <= part {
        parts.resize_with(part + 1, Chunk::empty);
    }
    parts[part] = chunk;
}

/// A slot's parts as the stripe list of a striped exchange: already at
/// stripe arity, or striped on demand from a single whole-block part. Any
/// other arity means the plan and the slot table disagree — a typed error
/// beats the index panic it used to be.
fn stripe_parts<T: Elem>(parts: Vec<Chunk<T>>, k: usize) -> Result<Vec<Chunk<T>>> {
    match parts.len() {
        n if n == k => Ok(parts),
        1 => {
            let whole = parts.into_iter().next().expect("length checked above");
            Ok(whole.stripes(k))
        }
        n => Err(Error::Plan(format!(
            "slot arity {n} cannot stripe to {k} lanes (must be 1 or the stripe count)"
        ))),
    }
}

fn need_combiner<'a, T>(combiner: Option<&'a Combiner<T>>) -> Result<&'a Combiner<T>> {
    combiner.ok_or_else(|| Error::Plan("combining op in a plan run without a combiner".into()))
}

/// What one executed op moved, for the tracer: kind label, peer, stripe
/// count, and (sent, received, combined) byte totals.
type SpanInfo = (&'static str, usize, u32, u64, u64, u64);

fn chunk_bytes<T>(len: usize) -> u64 {
    (len * std::mem::size_of::<T>()) as u64
}

fn stripe_bytes<T>(stripes: &[Chunk<T>]) -> u64 {
    stripes.iter().map(|s| chunk_bytes::<T>(s.len())).sum()
}

/// Execute a run of ops against one communicator, converting any failure
/// into a world abort when an abort token is armed. All ops must target
/// the communicator `c` represents; scope changes are the caller's job.
///
/// This is the crate's single execution chokepoint, so it is also the
/// single abort-conversion point: a local failure (timeout, shape
/// mismatch, injected fault) broadcasts poison to every peer and returns
/// as [`Error::CollectiveAborted`] attributed to this rank; an incoming
/// [`Error::CollectiveAborted`] (a peer's poison, or a fault-killed rank)
/// passes through unchanged so the origin attribution survives. Either
/// way an `"abort"` span is recorded when tracing, with the segment-start
/// → detection latency as its duration.
fn exec<T: Elem, C: Comm<T>>(
    c: &mut C,
    ops: &[Op],
    slots: &mut [Vec<Chunk<T>>],
    combiner: Option<&Combiner<T>>,
    mut tracer: Option<&mut RankTrace>,
) -> Result<()> {
    let seg_started = tracer.as_ref().map(|_| Instant::now());
    match exec_inner(c, ops, slots, combiner, tracer.as_deref_mut()) {
        Ok(()) => Ok(()),
        Err(e) => {
            let err = match e {
                Error::CollectiveAborted { .. } => e,
                other if c.abort_armed() => {
                    let cause = other.to_string();
                    c.broadcast_abort(&cause);
                    Error::CollectiveAborted {
                        origin_rank: c.rank(),
                        op_seq: c.current_op_seq(),
                        cause,
                    }
                }
                other => other,
            };
            if let Some(t) = tracer.as_deref_mut() {
                let started =
                    seg_started.expect("span timing starts whenever a tracer is present");
                let scope = ops.iter().find_map(Op::scope).unwrap_or(Scope::World);
                t.record("abort", scope, c.rank(), 0, 0, 0, 0, started, 0.0, 0.0);
            }
            Err(err)
        }
    }
}

/// The op loop proper. When `tracer` is present, one span is recorded per
/// executed comm op (with the endpoint op clock differenced around it for
/// the queueing-vs-service split); the phase/round markers update its
/// counters instead. When absent the only overhead is an `Option` check
/// per op — no clocks are read.
fn exec_inner<T: Elem, C: Comm<T>>(
    c: &mut C,
    ops: &[Op],
    slots: &mut [Vec<Chunk<T>>],
    combiner: Option<&Combiner<T>>,
    mut tracer: Option<&mut RankTrace>,
) -> Result<()> {
    for op in ops {
        let started = tracer.as_ref().map(|_| (Instant::now(), c.op_clock()));
        let span: Option<SpanInfo> = match *op {
            Op::BeginOp { .. } => {
                if let Some(t) = tracer.as_deref_mut() {
                    t.on_begin_op();
                }
                c.begin_op();
                None
            }
            Op::Round => {
                if let Some(t) = tracer.as_deref_mut() {
                    t.on_round();
                }
                None
            }
            Op::Send { peer, step, slot, part, take, .. } => {
                let chunk =
                    if take { take_part(slots, slot, part) } else { slots[slot][part].clone() };
                let sent = chunk_bytes::<T>(chunk.len());
                c.send_slice(peer, step, chunk)?;
                Some(("send", peer, 0, sent, 0, 0))
            }
            Op::Recv { peer, step, slot, part, .. } => {
                let got = c.recv_chunk(peer, step)?;
                let recvd = chunk_bytes::<T>(got.len());
                put_part(slots, slot, part, got);
                Some(("recv", peer, 0, 0, recvd, 0))
            }
            Op::RecvCombine { peer, step, slot, part, .. } => {
                let comb = need_combiner(combiner)?;
                c.recv_combine_into(peer, step, &mut slots[slot][part], comb)?;
                let folded = chunk_bytes::<T>(slots[slot][part].len());
                Some(("recv_combine", peer, 0, 0, folded, folded))
            }
            Op::SendRecv { send_peer, recv_peer, step, send_slot, recv_slot, lanes, .. } => {
                if lanes == 0 {
                    let out = slots[send_slot][0].clone();
                    let sent = chunk_bytes::<T>(out.len());
                    let got = c.sendrecv_chunk(send_peer, out, recv_peer, step)?;
                    let recvd = chunk_bytes::<T>(got.len());
                    slots[recv_slot] = vec![got];
                    Some(("sendrecv", send_peer, 0, sent, recvd, 0))
                } else {
                    let out = stripe_parts(slots[send_slot].clone(), lanes)?;
                    let sent = stripe_bytes(&out);
                    let got = c.sendrecv_striped(send_peer, out, recv_peer, step, lanes)?;
                    let recvd = stripe_bytes(&got);
                    slots[recv_slot] = got;
                    Some(("sendrecv", send_peer, lanes as u32, sent, recvd, 0))
                }
            }
            Op::SendRecvCombine {
                send_peer,
                recv_peer,
                step,
                send_slot,
                recv_slot,
                lanes,
                ..
            } => {
                let comb = need_combiner(combiner)?;
                if lanes == 0 {
                    let out = take_part(slots, send_slot, 0);
                    let mut acc = take_part(slots, recv_slot, 0);
                    let sent = chunk_bytes::<T>(out.len());
                    let folded = chunk_bytes::<T>(acc.len());
                    c.sendrecv_combine_into(send_peer, out, recv_peer, step, &mut acc, comb)?;
                    slots[recv_slot][0] = acc;
                    Some(("sendrecv_combine", send_peer, 0, sent, folded, folded))
                } else {
                    let out = stripe_parts(std::mem::take(&mut slots[send_slot]), lanes)?;
                    let mut accs = stripe_parts(std::mem::take(&mut slots[recv_slot]), lanes)?;
                    let sent = stripe_bytes(&out);
                    let folded = stripe_bytes(&accs);
                    c.sendrecv_striped_combine_into(
                        send_peer, out, recv_peer, step, &mut accs, comb,
                    )?;
                    slots[recv_slot] = accs;
                    Some(("sendrecv_combine", send_peer, lanes as u32, sent, folded, folded))
                }
            }
        };
        if let (Some(t), Some((kind, peer, lanes, sent, recvd, folded))) =
            (tracer.as_deref_mut(), span)
        {
            let (started, (wait0, serve0)) =
                started.expect("span timing starts whenever a tracer is present");
            let (wait1, serve1) = c.op_clock();
            t.record(
                kind,
                op.scope().unwrap_or(Scope::World),
                peer,
                lanes,
                sent,
                recvd,
                folded,
                started,
                wait1.saturating_sub(wait0) as f64 / 1e9,
                serve1.saturating_sub(serve0) as f64 / 1e9,
            );
        }
    }
    Ok(())
}

/// Flatten the output slots' parts in plan order.
fn collect_outputs<T>(plan: &Plan, mut slots: Vec<Vec<Chunk<T>>>) -> Vec<Chunk<T>> {
    let mut out = Vec::with_capacity(plan.outputs.len());
    for &slot in &plan.outputs {
        out.extend(std::mem::take(&mut slots[slot]));
    }
    out
}

/// Execute a single-scope (world) plan against any communicator.
pub fn run_flat<T: Elem, C: Comm<T>>(
    c: &mut C,
    plan: &Plan,
    inputs: Vec<Chunk<T>>,
    combiner: Option<&Combiner<T>>,
) -> Result<Vec<Chunk<T>>> {
    debug_assert!(
        plan.ops.iter().all(|op| op.scope().map(|s| s == Scope::World).unwrap_or(true)),
        "flat runs take world-scope plans; use run_hier"
    );
    let mut slots = materialize(&plan.slots, inputs)?;
    // Detach the thread's tracer (if any) for the op loop and put it back
    // before surfacing any error, so a failed traced trial still leaves
    // the partial spans collectable via `trace::end`.
    let mut tracer = trace::take();
    let run = exec(c, &plan.ops, &mut slots, combiner, tracer.as_deref_mut());
    if let Some(t) = tracer {
        trace::restore(t);
    }
    run?;
    Ok(collect_outputs(plan, slots))
}

/// Execute a (possibly hierarchical) plan against the world communicator:
/// ops are segmented at scope changes and each contiguous segment runs on
/// one sub-communicator instance. Adjacent phases on the same scope share
/// the instance — its op sequence keeps the tags fresh across them.
pub fn run_hier<T: Elem>(
    c: &mut Communicator<T>,
    plan: &Plan,
    inputs: Vec<Chunk<T>>,
    combiner: Option<&Combiner<T>>,
) -> Result<Vec<Chunk<T>>> {
    let mut slots = materialize(&plan.slots, inputs)?;
    // One take/restore brackets all segments, so a mid-plan error still
    // re-installs the tracer with the spans recorded so far.
    let mut tracer = trace::take();
    let run = exec_segments(c, &plan.ops, &mut slots, combiner, &mut tracer);
    if let Some(t) = tracer {
        trace::restore(t);
    }
    run?;
    Ok(collect_outputs(plan, slots))
}

fn exec_segments<T: Elem>(
    c: &mut Communicator<T>,
    ops: &[Op],
    slots: &mut [Vec<Chunk<T>>],
    combiner: Option<&Combiner<T>>,
    tracer: &mut Option<Box<RankTrace>>,
) -> Result<()> {
    let mut start = 0;
    while start < ops.len() {
        let scope = ops[start..]
            .iter()
            .find_map(Op::scope)
            .unwrap_or(Scope::World);
        let mut end = start + 1;
        while end < ops.len() {
            match ops[end].scope() {
                Some(s) if s != scope => break,
                _ => end += 1,
            }
        }
        let seg = &ops[start..end];
        match scope {
            Scope::World => exec(c, seg, slots, combiner, tracer.as_deref_mut())?,
            Scope::Inter => {
                let mut sub = c.inter_node()?;
                exec(&mut sub, seg, slots, combiner, tracer.as_deref_mut())?;
            }
            Scope::Intra => {
                let mut sub = c.intra_node()?;
                exec(&mut sub, seg, slots, combiner, tracer.as_deref_mut())?;
            }
        }
        start = end;
    }
    Ok(())
}

/// Execute a communication-free plan (shuffle): pure slot permutation.
pub fn run_local<T>(plan: &Plan, inputs: Vec<Chunk<T>>) -> Result<Vec<Chunk<T>>> {
    debug_assert!(plan.ops.is_empty(), "local plans carry no ops");
    if let Some(mut t) = trace::take() {
        // No comm ops to span; just count the op-free execution.
        t.on_local_run();
        trace::restore(t);
    }
    let slots = materialize(&plan.slots, inputs)?;
    Ok(collect_outputs(plan, slots))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::collectives::plan::{self, PlanSpec};

    #[test]
    fn local_shuffle_plan_permutes_without_copying() {
        let (outer, inner) = (3, 2);
        let spec = PlanSpec::shuffle(outer, inner);
        let p = plan::build(&spec, 0).unwrap();
        let blocks: Vec<Chunk<i32>> =
            (0..outer * inner).map(|i| Chunk::from_vec(vec![i as i32; 2])).collect();
        let ids: Vec<usize> = blocks.iter().map(Chunk::storage_id).collect();
        let out = run_local(&p, blocks).unwrap();
        // (j, i) order: block i * inner + j, same storage, no copies.
        let mut expect = Vec::new();
        for j in 0..inner {
            for i in 0..outer {
                expect.push(i * inner + j);
            }
        }
        for (o, &src) in out.iter().zip(&expect) {
            assert_eq!(o.as_slice(), vec![src as i32; 2].as_slice());
            assert_eq!(o.storage_id(), ids[src], "moved, not copied");
        }
    }

    #[test]
    fn engine_converts_local_failures_into_world_aborts() {
        use crate::comm::CommWorld;
        use std::time::Duration;
        // Rank 1 sits out the collective entirely: rank 0's recv times
        // out, and with an abort token armed the engine must surface that
        // as a CollectiveAborted attributed to rank 0 — on *both* ranks'
        // terms (rank 1 does nothing, so only rank 0 reports).
        let spec = PlanSpec::flat(plan::PlanKind::AllGather, plan::Algo::Ring, 2, 4, 1);
        let outs = CommWorld::<f32>::new(2)
            .with_abort()
            .with_recv_timeout(Duration::from_millis(60))
            .run(move |c| {
                if c.rank() == 1 {
                    return None;
                }
                let pl = plan::build(&spec, c.rank()).unwrap();
                let inputs = vec![Chunk::from_vec(vec![1.0; 4])];
                Some(match run_flat(c, &pl, inputs, None) {
                    Err(Error::CollectiveAborted { origin_rank, .. }) => origin_rank,
                    other => panic!("expected CollectiveAborted, got {other:?}"),
                })
            });
        assert_eq!(outs[0], Some(0));
    }

    #[test]
    fn missing_combiner_is_a_typed_plan_error() {
        use crate::comm::CommWorld;
        let spec = PlanSpec::flat(
            plan::PlanKind::ReduceScatter,
            plan::Algo::Ring,
            2,
            4,
            1,
        );
        let outs = CommWorld::<f32>::new(2).try_run(move |c| {
            let pl = plan::build(&spec, c.rank()).unwrap();
            let blocks = vec![Chunk::from_vec(vec![1.0; 2]), Chunk::from_vec(vec![2.0; 2])];
            match run_flat(c, &pl, blocks, None) {
                Err(Error::Plan(_)) => Ok(()),
                other => panic!("expected Plan error, got {other:?}"),
            }
        });
        // Ranks may time out waiting on the failed peer; the error path
        // itself is what this test pins down.
        let _ = outs;
    }
}
