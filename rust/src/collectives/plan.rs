//! The Plan IR: every collective lowered to a verifiable per-rank schedule.
//!
//! A [`PlanSpec`] names a collective shape — kind, algorithm, world size,
//! element count, stripe lanes, node geometry, root. [`build`] compiles the
//! spec into a [`Plan`] for one rank: a slot table ([`SlotInit`]) describing
//! how caller-provided chunks seed the block map, a flat op sequence
//! ([`Op`]) over those slots, and the slot order of the delivered outputs.
//! The ops are exactly the posted-receive / striped-lane primitives of
//! [`crate::comm::Comm`], so [`super::engine`] can execute any plan without
//! knowing which algorithm produced it — and the network simulator can
//! cost the *same* op sequence via [`phase_shapes`] instead of re-deriving
//! index math on the side.
//!
//! [`verify`] statically checks a spec before any rank executes it: it
//! builds the plans of *all* `p` ranks and runs them in a lockstep
//! simulation where payloads are symbolic block fragments. That proves
//! deadlock-freedom (every receive has a matching send; no rank blocks
//! forever), coverage (all-gather delivers every block everywhere;
//! reduce-scatter folds every contribution exactly once, alignment
//! included), and yields the exact wire byte total for comparison against
//! `runtime::expected_schedule_bytes`. [`verify_cached`] memoizes per spec
//! so the data plane pays the simulation once per shape, not per call.
//!
//! Index math is shared with the legacy closed forms in
//! [`super::schedule`]; the property tests in `tests/plan_properties.rs`
//! pin the lowered plans to that math step by step.
//!
//! **Abort semantics.** A lowered plan carries no failure handling of its
//! own — ops assume every peer executes its verified schedule. Failure is
//! the engine's job: when any op errors mid-plan on an abort-armed
//! communicator, [`super::engine::exec`] broadcasts poison and converts
//! the error to [`Error::CollectiveAborted`], leaving the plan abandoned
//! partway. Slots then hold an undefined mix of delivered and undelivered
//! blocks, so an aborted plan's outputs must never be read; recovery is
//! an epoch bump ([`crate::comm::Communicator::bump_epoch`]) that drains
//! the wire and retags it, after which the *same* spec can be re-lowered
//! and re-run from scratch on the fresh epoch.

use std::collections::{HashMap, HashSet, VecDeque};
use std::sync::{Mutex, OnceLock};

use crate::comm::stripe_lens;
use crate::error::{Error, Result};

use super::schedule::{recursive, ring};

/// Which collective a plan computes.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum PlanKind {
    AllGather,
    ReduceScatter,
    AllReduce,
    Broadcast,
    Reduce,
    Gather,
    Scatter,
    Shuffle,
}

/// Which algorithm family lowers the spec.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Algo {
    /// Flat ring over the world.
    Ring,
    /// Flat recursive doubling/halving (power-of-two world).
    Rec,
    /// Hierarchical: ring inter-node phase, ring intra-node phase.
    HierRing,
    /// Hierarchical: recursive inter-node phase (power-of-two node count),
    /// ring intra-node phase.
    HierRec,
    /// Binomial-tree reduce + broadcast fan-out (all-reduce).
    Tree,
    /// Binomial tree rooted at `root` (broadcast / reduce).
    Binomial,
    /// Direct root exchange (gather / scatter).
    Direct,
    /// No communication — a local pointer permutation (shuffle).
    Local,
}

/// Which communicator an op runs on. `Inter`/`Intra` peers are ranks
/// *within* that sub-communicator (node index / local id).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Scope {
    World,
    Inter,
    Intra,
}

/// A collective shape: everything `build` needs to lower one rank's
/// schedule, and everything `verify` needs to simulate all of them.
///
/// `elems` semantics per kind: all-gather — the per-rank block length;
/// reduce-scatter / all-reduce — the full (padded) input length, a
/// multiple of `p`; broadcast / reduce / gather — the per-rank input
/// length; scatter — the root's input length (`0` on non-root ranks,
/// whose schedule does not depend on it); shuffle — the symbolic block
/// length used by verification (the runtime permutation is length-blind).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct PlanSpec {
    pub kind: PlanKind,
    pub algo: Algo,
    /// World size (`nodes * gpn` for hierarchical algorithms).
    pub p: usize,
    pub elems: usize,
    /// Stripe lanes (1 = unstriped; >1 only on ring paths).
    pub lanes: usize,
    /// Node count (1 for flat specs; shuffle `outer`).
    pub nodes: usize,
    /// GPUs per node (`p` for flat specs; shuffle `inner`).
    pub gpn: usize,
    /// Root rank for rooted collectives (0 otherwise).
    pub root: usize,
}

impl PlanSpec {
    /// A flat (single-scope) spec: ring / rec / tree over the world.
    pub fn flat(kind: PlanKind, algo: Algo, p: usize, elems: usize, lanes: usize) -> Self {
        Self { kind, algo, p, elems, lanes, nodes: 1, gpn: p, root: 0 }
    }

    /// A hierarchical spec over `nodes * gpn` ranks.
    pub fn hier(
        kind: PlanKind,
        algo: Algo,
        nodes: usize,
        gpn: usize,
        elems: usize,
        lanes: usize,
    ) -> Self {
        Self { kind, algo, p: nodes * gpn, elems, lanes, nodes, gpn, root: 0 }
    }

    /// A rooted spec (broadcast / reduce / gather / scatter).
    pub fn rooted(kind: PlanKind, algo: Algo, p: usize, elems: usize, root: usize) -> Self {
        Self { kind, algo, p, elems, lanes: 1, nodes: 1, gpn: p, root }
    }

    /// The local shuffle (block transpose) spec over an `outer x inner`
    /// grid; `elems` is symbolic (1) — the permutation is length-blind.
    pub fn shuffle(outer: usize, inner: usize) -> Self {
        Self {
            kind: PlanKind::Shuffle,
            algo: Algo::Local,
            p: outer * inner,
            elems: 1,
            lanes: 1,
            nodes: outer,
            gpn: inner,
            root: 0,
        }
    }
}

/// How a slot of the block map is seeded before the first op runs.
///
/// All input slicing happens at the entry point (O(1) chunk views); the
/// plan only *moves* caller chunks into slots, so whole-input slots regain
/// storage exclusivity once the engine drops the leftover input list.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SlotInit {
    /// No initial payload; `parts` placeholder parts (stripe arity).
    Empty { parts: usize },
    /// Move caller input `0` at index `i` into the slot (one part).
    Take(usize),
    /// Move caller input `input` in and split it into `k` stripes.
    TakeStripes { input: usize, k: usize },
}

/// One engine primitive. `step` is the wire tag step; `part` selects a
/// stripe of the slot; `lanes` on the fused exchanges is `0` for the
/// plain (single-chunk) protocol and the stripe count `k` for the striped
/// one — they are distinct wire protocols, never mixed.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Op {
    /// Bump the scope communicator's op sequence (tag freshness). Every
    /// phase opens with one, which is also what segments a hierarchical
    /// plan into per-scope runs.
    BeginOp { scope: Scope },
    /// Cost-model round boundary; the engine ignores it.
    Round,
    /// Post one part to `peer`. `take: true` moves the part out of the
    /// slot (ownership transferred); `false` sends a clone (slot keeps a
    /// shared view).
    Send { scope: Scope, peer: usize, step: u32, slot: usize, part: usize, take: bool },
    /// Blocking matched receive into a slot part (replaces it).
    Recv { scope: Scope, peer: usize, step: u32, slot: usize, part: usize },
    /// Posted combining receive: fold the matched message into the slot
    /// part in place (`Comm::recv_combine_into`).
    RecvCombine { scope: Scope, peer: usize, step: u32, slot: usize, part: usize },
    /// Fused exchange: send `send_slot` (cloned), receive into
    /// `recv_slot` (replaced). The ring all-gather step.
    SendRecv {
        scope: Scope,
        send_peer: usize,
        recv_peer: usize,
        step: u32,
        send_slot: usize,
        recv_slot: usize,
        lanes: usize,
    },
    /// Fused exchange with combining delivery: send `send_slot` (moved
    /// out), fold the incoming message into `recv_slot`. The ring
    /// reduce-scatter step.
    SendRecvCombine {
        scope: Scope,
        send_peer: usize,
        recv_peer: usize,
        step: u32,
        send_slot: usize,
        recv_slot: usize,
        lanes: usize,
    },
}

impl Op {
    /// The communicator scope this op runs on (`None` for round markers).
    pub fn scope(&self) -> Option<Scope> {
        match *self {
            Op::Round => None,
            Op::BeginOp { scope }
            | Op::Send { scope, .. }
            | Op::Recv { scope, .. }
            | Op::RecvCombine { scope, .. }
            | Op::SendRecv { scope, .. }
            | Op::SendRecvCombine { scope, .. } => Some(scope),
        }
    }

    /// Whether the op carries a combining delivery (needs a combiner).
    pub fn combines(&self) -> bool {
        matches!(self, Op::RecvCombine { .. } | Op::SendRecvCombine { .. })
    }
}

/// One rank's compiled schedule.
#[derive(Clone, Debug)]
pub struct Plan {
    pub spec: PlanSpec,
    pub rank: usize,
    pub slots: Vec<SlotInit>,
    pub ops: Vec<Op>,
    /// Slots whose parts, flattened in order, are the collective's result.
    pub outputs: Vec<usize>,
}

fn perr(m: String) -> Error {
    Error::Plan(m)
}

/// Compile `spec` into rank `rank`'s plan.
pub fn build(spec: &PlanSpec, rank: usize) -> Result<Plan> {
    let p = spec.p;
    if p == 0 || rank >= p {
        return Err(perr(format!("rank {rank} out of range for p={p}")));
    }
    if spec.lanes == 0 {
        return Err(perr("lanes must be >= 1".into()));
    }
    if spec.nodes * spec.gpn != p {
        return Err(perr(format!(
            "node geometry {}x{} inconsistent with p={p}",
            spec.nodes, spec.gpn
        )));
    }
    let k = spec.lanes;
    use Algo::*;
    use PlanKind::*;
    match (spec.kind, spec.algo) {
        (AllGather, Ring) => Ok(build_flat_ag(spec, rank, false)),
        (AllGather, Rec) => {
            require_unstriped(spec)?;
            require_pow2(p, "recursive doubling")?;
            Ok(build_flat_ag(spec, rank, true))
        }
        (ReduceScatter, Ring) => {
            require_divisible(spec)?;
            Ok(build_flat_rs(spec, rank, false))
        }
        (ReduceScatter, Rec) => {
            require_unstriped(spec)?;
            require_pow2(p, "recursive halving")?;
            require_divisible(spec)?;
            Ok(build_flat_rs(spec, rank, true))
        }
        (AllReduce, Ring) => {
            require_divisible(spec)?;
            Ok(build_flat_ar(spec, rank, false))
        }
        (AllReduce, Rec) => {
            require_unstriped(spec)?;
            require_pow2(p, "recursive all-reduce")?;
            require_divisible(spec)?;
            Ok(build_flat_ar(spec, rank, true))
        }
        (AllGather | ReduceScatter | AllReduce, HierRing | HierRec) => {
            if spec.algo == HierRec {
                require_unstriped(spec)?;
                require_pow2(spec.nodes, "recursive inter-node phase")?;
            }
            if spec.kind != AllGather {
                require_divisible(spec)?;
            }
            build_hier(spec, rank)
        }
        (AllReduce, Tree) => {
            require_unstriped(spec)?;
            Ok(build_tree_ar(spec, rank))
        }
        (Broadcast, Binomial) => {
            require_root(spec)?;
            Ok(build_broadcast(spec, rank))
        }
        (Reduce, Binomial) => {
            require_root(spec)?;
            Ok(build_reduce(spec, rank))
        }
        (Gather, Direct) => {
            require_root(spec)?;
            Ok(build_gather(spec, rank))
        }
        (Scatter, Direct) => {
            require_root(spec)?;
            Ok(build_scatter(spec, rank))
        }
        (Shuffle, Local) => Ok(build_shuffle(spec, rank)),
        (kind, algo) => Err(perr(format!("no lowering for {kind:?} via {algo:?} (lanes {k})"))),
    }
}

fn require_pow2(n: usize, what: &str) -> Result<()> {
    if n.is_power_of_two() {
        Ok(())
    } else {
        Err(perr(format!("{what} requires a power-of-two rank count, got {n}")))
    }
}

fn require_unstriped(spec: &PlanSpec) -> Result<()> {
    if spec.lanes == 1 {
        Ok(())
    } else {
        Err(perr(format!("{:?}/{:?} has no striped lowering", spec.kind, spec.algo)))
    }
}

fn require_divisible(spec: &PlanSpec) -> Result<()> {
    if spec.elems % spec.p == 0 {
        Ok(())
    } else {
        Err(perr(format!(
            "{:?} input of {} elems not divisible by p={}",
            spec.kind, spec.elems, spec.p
        )))
    }
}

fn require_root(spec: &PlanSpec) -> Result<()> {
    if spec.root < spec.p {
        Ok(())
    } else {
        Err(perr(format!("root {} out of range for p={}", spec.root, spec.p)))
    }
}

// ---------------------------------------------------------------------------
// Op emitters (composable phases shared by flat and hierarchical builders)
// ---------------------------------------------------------------------------

/// Ring all-gather phase over ranks `0..p` of `scope`; `lanes` is the
/// striped-exchange stripe count (0 = plain protocol).
fn ring_ag_ops(
    ops: &mut Vec<Op>,
    scope: Scope,
    r: usize,
    p: usize,
    slot_of: &dyn Fn(usize) -> usize,
    lanes: usize,
) {
    ops.push(Op::BeginOp { scope });
    if p <= 1 {
        return;
    }
    let right = (r + 1) % p;
    let left = (r + p - 1) % p;
    for s in 0..ring::steps(p) {
        ops.push(Op::Round);
        ops.push(Op::SendRecv {
            scope,
            send_peer: right,
            recv_peer: left,
            step: s as u32,
            send_slot: slot_of(ring::ag_send_block(r, p, s)),
            recv_slot: slot_of(ring::ag_recv_block(r, p, s)),
            lanes,
        });
    }
}

/// Ring reduce-scatter phase: the traveling-partial schedule. After the
/// phase, `slot_of(r)` holds the fully reduced block of rank `r`.
fn ring_rs_ops(
    ops: &mut Vec<Op>,
    scope: Scope,
    r: usize,
    p: usize,
    slot_of: &dyn Fn(usize) -> usize,
    lanes: usize,
) {
    ops.push(Op::BeginOp { scope });
    if p <= 1 {
        return;
    }
    let right = (r + 1) % p;
    let left = (r + p - 1) % p;
    for s in 0..ring::steps(p) {
        ops.push(Op::Round);
        ops.push(Op::SendRecvCombine {
            scope,
            send_peer: right,
            recv_peer: left,
            step: s as u32,
            send_slot: slot_of(ring::rs_send_block(r, p, s)),
            recv_slot: slot_of(ring::rs_recv_block(r, p, s)),
            lanes,
        });
    }
}

/// Recursive-doubling all-gather phase (power-of-two `p`, plain protocol).
fn rec_ag_ops(ops: &mut Vec<Op>, scope: Scope, r: usize, p: usize, slot_of: &dyn Fn(usize) -> usize) {
    ops.push(Op::BeginOp { scope });
    for s in 0..recursive::steps(p) {
        ops.push(Op::Round);
        let partner = recursive::ag_partner(r, s);
        let (lo, hi) = recursive::ag_owned_range(r, s);
        let (plo, phi) = recursive::ag_owned_range(partner, s);
        for i in lo..hi {
            ops.push(Op::Send {
                scope,
                peer: partner,
                step: (s * p + i) as u32,
                slot: slot_of(i),
                part: 0,
                take: false,
            });
        }
        for i in plo..phi {
            ops.push(Op::Recv {
                scope,
                peer: partner,
                step: (s * p + i) as u32,
                slot: slot_of(i),
                part: 0,
            });
        }
    }
}

/// Recursive-halving reduce-scatter phase (power-of-two `p`, plain
/// protocol). After the phase, `slot_of(r)` holds the reduced block.
fn rec_rs_ops(ops: &mut Vec<Op>, scope: Scope, r: usize, p: usize, slot_of: &dyn Fn(usize) -> usize) {
    ops.push(Op::BeginOp { scope });
    let (mut lo, mut hi) = (0usize, p);
    for s in 0..recursive::steps(p) {
        ops.push(Op::Round);
        let partner = recursive::rs_partner(r, p, s);
        let mid = (lo + hi) / 2;
        let (keep, send) = if r < mid { ((lo, mid), (mid, hi)) } else { ((mid, hi), (lo, mid)) };
        for i in send.0..send.1 {
            ops.push(Op::Send {
                scope,
                peer: partner,
                step: (s * p + i) as u32,
                slot: slot_of(i),
                part: 0,
                take: false,
            });
        }
        for i in keep.0..keep.1 {
            ops.push(Op::RecvCombine {
                scope,
                peer: partner,
                step: (s * p + i) as u32,
                slot: slot_of(i),
                part: 0,
            });
        }
        lo = keep.0;
        hi = keep.1;
    }
    debug_assert!(recursive::steps(p) == 0 || (lo, hi) == (r, r + 1));
}

/// Intra-node ring all-gather phase of a hierarchical plan: rotate every
/// node-column's blocks around the local ring, one plain send per
/// `(node block, stripe)` pair. Slot `j * m + l` is node-block `j` of
/// local rank `l`; `k` is the stripe arity of each slot (1 = unstriped).
fn intra_ag_ops(ops: &mut Vec<Op>, l: usize, m: usize, n: usize, k: usize) {
    ops.push(Op::BeginOp { scope: Scope::Intra });
    if m <= 1 {
        return;
    }
    let right = (l + 1) % m;
    let left = (l + m - 1) % m;
    let nk = n * k;
    for s in 0..ring::steps(m) {
        ops.push(Op::Round);
        let send_l = ring::ag_send_block(l, m, s);
        let recv_l = ring::ag_recv_block(l, m, s);
        for j in 0..n {
            for t in 0..k {
                ops.push(Op::Send {
                    scope: Scope::Intra,
                    peer: right,
                    step: (s * nk + j * k + t) as u32,
                    slot: j * m + send_l,
                    part: t,
                    take: false,
                });
            }
        }
        for j in 0..n {
            for t in 0..k {
                ops.push(Op::Recv {
                    scope: Scope::Intra,
                    peer: left,
                    step: (s * nk + j * k + t) as u32,
                    slot: j * m + recv_l,
                    part: t,
                });
            }
        }
    }
}

/// Intra-node ring reduce-scatter phase of a hierarchical plan: for every
/// node block `j`, combine local segment `l` across the node's ranks via
/// the traveling-partial schedule (posted combining receives, moved
/// sends). After the phase, slot `j * m + l` holds this rank's partial of
/// global block `j * m + l`.
fn intra_rs_ops(ops: &mut Vec<Op>, l: usize, m: usize, n: usize) {
    ops.push(Op::BeginOp { scope: Scope::Intra });
    if m <= 1 {
        return;
    }
    let right = (l + 1) % m;
    let left = (l + m - 1) % m;
    for s in 0..ring::steps(m) {
        ops.push(Op::Round);
        let send_seg = ring::rs_send_block(l, m, s);
        let recv_seg = ring::rs_recv_block(l, m, s);
        for j in 0..n {
            ops.push(Op::Send {
                scope: Scope::Intra,
                peer: right,
                step: (s * n + j) as u32,
                slot: j * m + send_seg,
                part: 0,
                take: true,
            });
        }
        for j in 0..n {
            ops.push(Op::RecvCombine {
                scope: Scope::Intra,
                peer: left,
                step: (s * n + j) as u32,
                slot: j * m + recv_seg,
                part: 0,
            });
        }
    }
}

// ---------------------------------------------------------------------------
// Builders
// ---------------------------------------------------------------------------

fn striped(k: usize) -> usize {
    if k > 1 { k } else { 0 }
}

fn build_flat_ag(spec: &PlanSpec, r: usize, rec: bool) -> Plan {
    let (p, k) = (spec.p, spec.lanes);
    let slots = (0..p)
        .map(|i| {
            if i == r {
                if k > 1 { SlotInit::TakeStripes { input: 0, k } } else { SlotInit::Take(0) }
            } else {
                SlotInit::Empty { parts: k }
            }
        })
        .collect();
    let mut ops = Vec::new();
    if rec {
        rec_ag_ops(&mut ops, Scope::World, r, p, &|i| i);
    } else {
        ring_ag_ops(&mut ops, Scope::World, r, p, &|i| i, striped(k));
    }
    Plan { spec: *spec, rank: r, slots, ops, outputs: (0..p).collect() }
}

/// Reduce-scatter / all-reduce slot table: every caller block is moved in;
/// this rank's own block is pre-striped when lanes are in play (it is the
/// final accumulator, and at `p == 1` the untouched output).
fn rs_slots(r: usize, p: usize, k: usize) -> Vec<SlotInit> {
    (0..p)
        .map(|i| {
            if i == r && k > 1 {
                SlotInit::TakeStripes { input: i, k }
            } else {
                SlotInit::Take(i)
            }
        })
        .collect()
}

fn build_flat_rs(spec: &PlanSpec, r: usize, rec: bool) -> Plan {
    let (p, k) = (spec.p, spec.lanes);
    let mut ops = Vec::new();
    if rec {
        rec_rs_ops(&mut ops, Scope::World, r, p, &|i| i);
    } else {
        ring_rs_ops(&mut ops, Scope::World, r, p, &|i| i, striped(k));
    }
    Plan { spec: *spec, rank: r, slots: rs_slots(r, p, k), ops, outputs: vec![r] }
}

/// All-reduce = reduce-scatter then all-gather over the *same* slot
/// table: after the RS phase only slot `r` holds payload (the reduced
/// block), which is exactly the all-gather phase's initial condition.
fn build_flat_ar(spec: &PlanSpec, r: usize, rec: bool) -> Plan {
    let (p, k) = (spec.p, spec.lanes);
    let mut ops = Vec::new();
    if rec {
        rec_rs_ops(&mut ops, Scope::World, r, p, &|i| i);
        rec_ag_ops(&mut ops, Scope::World, r, p, &|i| i);
    } else {
        ring_rs_ops(&mut ops, Scope::World, r, p, &|i| i, striped(k));
        ring_ag_ops(&mut ops, Scope::World, r, p, &|i| i, striped(k));
    }
    Plan { spec: *spec, rank: r, slots: rs_slots(r, p, k), ops, outputs: (0..p).collect() }
}

/// Hierarchical lowering. Slot `j * m + l` is global block of rank
/// `(node j, local l)`; the inter-node phase runs over this rank's column
/// `{ j * m + l : j }`, the intra-node phase rotates/folds rows.
fn build_hier(spec: &PlanSpec, rank: usize) -> Result<Plan> {
    let (n, m, k) = (spec.nodes, spec.gpn, spec.lanes);
    let p = spec.p;
    let (nd, l) = (rank / m, rank % m);
    let rec = spec.algo == Algo::HierRec;
    let col = |j: usize| j * m + l;
    let mut ops = Vec::new();
    let (slots, outputs) = match spec.kind {
        PlanKind::AllGather => {
            // Inter: gather the column's blocks across nodes; intra:
            // rotate every node's column around the local ring.
            if rec {
                rec_ag_ops(&mut ops, Scope::Inter, nd, n, &col);
            } else {
                ring_ag_ops(&mut ops, Scope::Inter, nd, n, &col, striped(k));
            }
            intra_ag_ops(&mut ops, l, m, n, k);
            let slots = (0..p)
                .map(|i| {
                    if i == rank {
                        if k > 1 {
                            SlotInit::TakeStripes { input: 0, k }
                        } else {
                            SlotInit::Take(0)
                        }
                    } else {
                        SlotInit::Empty { parts: k }
                    }
                })
                .collect();
            (slots, (0..p).collect())
        }
        PlanKind::ReduceScatter => {
            // Intra: fold local segment l of every node block; inter:
            // reduce-scatter the column of partials across nodes.
            intra_rs_ops(&mut ops, l, m, n);
            if rec {
                rec_rs_ops(&mut ops, Scope::Inter, nd, n, &col);
            } else {
                ring_rs_ops(&mut ops, Scope::Inter, nd, n, &col, striped(k));
            }
            ((0..p).map(SlotInit::Take).collect(), vec![rank])
        }
        PlanKind::AllReduce => {
            intra_rs_ops(&mut ops, l, m, n);
            if rec {
                rec_rs_ops(&mut ops, Scope::Inter, nd, n, &col);
                rec_ag_ops(&mut ops, Scope::Inter, nd, n, &col);
            } else {
                ring_rs_ops(&mut ops, Scope::Inter, nd, n, &col, striped(k));
                ring_ag_ops(&mut ops, Scope::Inter, nd, n, &col, striped(k));
            }
            intra_ag_ops(&mut ops, l, m, n, k);
            ((0..p).map(SlotInit::Take).collect(), (0..p).collect())
        }
        kind => return Err(perr(format!("no hierarchical lowering for {kind:?}"))),
    };
    Ok(Plan { spec: *spec, rank, slots, ops, outputs })
}

/// Binomial-tree all-reduce rooted at rank 0: reduce up the tree (moved
/// leaf sends, posted combining receives), then broadcast the result back
/// down the same tree.
fn build_tree_ar(spec: &PlanSpec, r: usize) -> Plan {
    let p = spec.p;
    let mut ops = vec![Op::BeginOp { scope: Scope::World }];
    let mut recv_mask = p.next_power_of_two();
    let mut mask = 1usize;
    while mask < p {
        let step = mask.trailing_zeros();
        if r & mask != 0 {
            ops.push(Op::Round);
            ops.push(Op::Send {
                scope: Scope::World,
                peer: r & !mask,
                step,
                slot: 0,
                part: 0,
                take: true,
            });
            recv_mask = mask;
            break;
        }
        let src = r | mask;
        if src < p {
            ops.push(Op::Round);
            ops.push(Op::RecvCombine { scope: Scope::World, peer: src, step, slot: 0, part: 0 });
        }
        mask <<= 1;
    }
    if r != 0 {
        ops.push(Op::Round);
        ops.push(Op::Recv {
            scope: Scope::World,
            peer: r & !recv_mask,
            step: 0x100 + recv_mask.trailing_zeros(),
            slot: 0,
            part: 0,
        });
    }
    let mut child_mask = recv_mask >> 1;
    while child_mask > 0 {
        let dst = r | child_mask;
        if dst != r && dst < p {
            ops.push(Op::Round);
            ops.push(Op::Send {
                scope: Scope::World,
                peer: dst,
                step: 0x100 + child_mask.trailing_zeros(),
                slot: 0,
                part: 0,
                take: false,
            });
        }
        child_mask >>= 1;
    }
    Plan { spec: *spec, rank: r, slots: vec![SlotInit::Take(0)], ops, outputs: vec![0] }
}

fn rel(rank: usize, root: usize, p: usize) -> usize {
    (rank + p - root) % p
}

fn unrel(r: usize, root: usize, p: usize) -> usize {
    (r + root) % p
}

/// Binomial broadcast from `root`: receive from the parent in
/// root-relative rank space, fan out to children highest-bit-first.
fn build_broadcast(spec: &PlanSpec, rank: usize) -> Plan {
    let (p, root) = (spec.p, spec.root);
    let r = rel(rank, root, p);
    let mut ops = vec![Op::BeginOp { scope: Scope::World }];
    let mut recv_mask = p.next_power_of_two();
    if r != 0 {
        let mut mask = 1usize;
        while r & mask == 0 {
            mask <<= 1;
        }
        recv_mask = mask;
        ops.push(Op::Round);
        ops.push(Op::Recv {
            scope: Scope::World,
            peer: unrel(r & !mask, root, p),
            step: mask.trailing_zeros(),
            slot: 0,
            part: 0,
        });
    }
    let mut child_mask = recv_mask >> 1;
    while child_mask > 0 {
        let dst_rel = r | child_mask;
        if dst_rel != r && dst_rel < p {
            ops.push(Op::Round);
            ops.push(Op::Send {
                scope: Scope::World,
                peer: unrel(dst_rel, root, p),
                step: child_mask.trailing_zeros(),
                slot: 0,
                part: 0,
                take: false,
            });
        }
        child_mask >>= 1;
    }
    let slots = if r == 0 { vec![SlotInit::Take(0)] } else { vec![SlotInit::Empty { parts: 1 }] };
    Plan { spec: *spec, rank, slots, ops, outputs: vec![0] }
}

/// Binomial reduce to `root`: fold children's partials into the local
/// accumulator, then move it to the parent. Only the root keeps output.
fn build_reduce(spec: &PlanSpec, rank: usize) -> Plan {
    let (p, root) = (spec.p, spec.root);
    let r = rel(rank, root, p);
    let mut ops = vec![Op::BeginOp { scope: Scope::World }];
    let mut mask = 1usize;
    while mask < p {
        let step = mask.trailing_zeros();
        if r & mask != 0 {
            ops.push(Op::Round);
            ops.push(Op::Send {
                scope: Scope::World,
                peer: unrel(r & !mask, root, p),
                step,
                slot: 0,
                part: 0,
                take: true,
            });
            break;
        }
        let src_rel = r | mask;
        if src_rel < p {
            ops.push(Op::Round);
            ops.push(Op::RecvCombine {
                scope: Scope::World,
                peer: unrel(src_rel, root, p),
                step,
                slot: 0,
                part: 0,
            });
        }
        mask <<= 1;
    }
    let outputs = if r == 0 { vec![0] } else { Vec::new() };
    Plan { spec: *spec, rank, slots: vec![SlotInit::Take(0)], ops, outputs }
}

/// Direct gather to `root`: every non-root rank moves its input to the
/// root; the root receives one block per peer into its block map.
fn build_gather(spec: &PlanSpec, rank: usize) -> Plan {
    let (p, root) = (spec.p, spec.root);
    let mut ops = vec![Op::BeginOp { scope: Scope::World }];
    if rank == root {
        let slots = (0..p)
            .map(|i| if i == root { SlotInit::Take(0) } else { SlotInit::Empty { parts: 1 } })
            .collect();
        for peer in 0..p {
            if peer != root {
                ops.push(Op::Round);
                ops.push(Op::Recv { scope: Scope::World, peer, step: 0, slot: peer, part: 0 });
            }
        }
        Plan { spec: *spec, rank, slots, ops, outputs: (0..p).collect() }
    } else {
        ops.push(Op::Round);
        ops.push(Op::Send { scope: Scope::World, peer: root, step: 0, slot: 0, part: 0, take: true });
        Plan { spec: *spec, rank, slots: vec![SlotInit::Take(0)], ops, outputs: Vec::new() }
    }
}

/// Direct scatter from `root`: the root moves block `i` to rank `i` and
/// keeps its own; non-roots receive theirs.
fn build_scatter(spec: &PlanSpec, rank: usize) -> Plan {
    let (p, root) = (spec.p, spec.root);
    let mut ops = vec![Op::BeginOp { scope: Scope::World }];
    if rank == root {
        for peer in 0..p {
            if peer != root {
                ops.push(Op::Round);
                ops.push(Op::Send {
                    scope: Scope::World,
                    peer,
                    step: 0,
                    slot: peer,
                    part: 0,
                    take: true,
                });
            }
        }
        let slots = (0..p).map(SlotInit::Take).collect();
        Plan { spec: *spec, rank, slots, ops, outputs: vec![root] }
    } else {
        ops.push(Op::Round);
        ops.push(Op::Recv { scope: Scope::World, peer: root, step: 0, slot: 0, part: 0 });
        Plan { spec: *spec, rank, slots: vec![SlotInit::Empty { parts: 1 }], ops, outputs: vec![0] }
    }
}

/// Local block transpose: no ops, outputs are a permutation of the moved
/// inputs (blocks `i * inner + j` emitted in `(j, i)` order).
fn build_shuffle(spec: &PlanSpec, rank: usize) -> Plan {
    let (outer, inner) = (spec.nodes, spec.gpn);
    let mut outputs = Vec::with_capacity(outer * inner);
    for j in 0..inner {
        for i in 0..outer {
            outputs.push(i * inner + j);
        }
    }
    Plan {
        spec: *spec,
        rank,
        slots: (0..outer * inner).map(SlotInit::Take).collect(),
        ops: Vec::new(),
        outputs,
    }
}

// ---------------------------------------------------------------------------
// Cost-model shapes: the netsim reads round structure off the lowered plan
// ---------------------------------------------------------------------------

/// Element counts of one cost-model round (rank-0 perspective: what one
/// rank sends and combines between two round markers).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct RoundShape {
    /// Elements this rank posts to the wire during the round.
    pub sent_elems: u64,
    /// Elements folded through the combiner during the round.
    pub combine_elems: u64,
}

/// One phase (BeginOp-delimited op segment) of a lowered plan.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct PhaseShape {
    pub scope: Scope,
    pub rounds: Vec<RoundShape>,
}

/// Uniform block length of the spec (what one slot part sums to).
pub fn block_elems(spec: &PlanSpec) -> usize {
    // The tree all-reduce is unblocked: the whole buffer travels as one
    // unit (no reduce-scatter decomposition), so the block is the input.
    if spec.algo == Algo::Tree {
        return spec.elems;
    }
    match spec.kind {
        PlanKind::AllGather | PlanKind::Broadcast | PlanKind::Reduce | PlanKind::Gather => {
            spec.elems
        }
        PlanKind::ReduceScatter | PlanKind::AllReduce | PlanKind::Scatter => {
            spec.elems / spec.p.max(1)
        }
        PlanKind::Shuffle => spec.elems,
    }
}

/// Walk rank 0's lowered plan and report its per-phase, per-round element
/// counts — the structure the network simulator costs. Collectives are
/// SPMD-symmetric, so rank 0 is representative of every rank's per-round
/// volume.
pub fn phase_shapes(spec: &PlanSpec) -> Result<Vec<PhaseShape>> {
    let plan = build(spec, 0)?;
    let b = block_elems(spec) as u64;
    // Stripe arity per slot, tracked so per-part sends cost stripe lengths.
    let mut arity: Vec<usize> = plan
        .slots
        .iter()
        .map(|s| match *s {
            SlotInit::Empty { parts } => parts,
            SlotInit::Take(_) => 1,
            SlotInit::TakeStripes { k, .. } => k,
        })
        .collect();
    let part_len = |arity: usize, part: usize| -> u64 {
        if arity <= 1 { b } else { stripe_lens(b as usize, arity)[part] as u64 }
    };
    let mut phases: Vec<PhaseShape> = Vec::new();
    for op in &plan.ops {
        match *op {
            Op::BeginOp { scope } => phases.push(PhaseShape { scope, rounds: Vec::new() }),
            Op::Round => {
                let ph = phases.last_mut().ok_or_else(|| perr("round before any phase".into()))?;
                ph.rounds.push(RoundShape { sent_elems: 0, combine_elems: 0 });
            }
            _ => {
                let ph = phases.last_mut().ok_or_else(|| perr("op before any phase".into()))?;
                if ph.rounds.is_empty() {
                    ph.rounds.push(RoundShape { sent_elems: 0, combine_elems: 0 });
                }
                let round = ph.rounds.last_mut().expect("round present");
                match *op {
                    Op::Send { slot, part, .. } => {
                        round.sent_elems += part_len(arity[slot], part);
                    }
                    Op::Recv { slot, part, .. } => {
                        arity[slot] = arity[slot].max(part + 1);
                    }
                    Op::RecvCombine { slot, part, .. } => {
                        round.combine_elems += part_len(arity[slot], part);
                    }
                    Op::SendRecv { recv_slot, lanes, .. } => {
                        round.sent_elems += b;
                        arity[recv_slot] = lanes.max(1);
                    }
                    Op::SendRecvCombine { recv_slot, lanes, .. } => {
                        round.sent_elems += b;
                        round.combine_elems += b;
                        arity[recv_slot] = lanes.max(1);
                    }
                    Op::BeginOp { .. } | Op::Round => unreachable!(),
                }
            }
        }
    }
    Ok(phases)
}

// ---------------------------------------------------------------------------
// Static verification: all-rank lockstep simulation over symbolic payloads
// ---------------------------------------------------------------------------

/// What the verifier proves beyond pass/fail.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct VerifyStats {
    /// Total elements posted to the wire across all ranks — multiply by
    /// the element width for the schedule's exact byte total.
    pub total_sent_elems: u64,
}

/// A contiguous fragment of origin rank `origin`'s input block `block`:
/// source elements `[lo, lo + len)`.
#[derive(Clone, Copy, Debug)]
struct Atom {
    origin: usize,
    block: usize,
    lo: usize,
    len: usize,
}

/// A symbolic payload: `layers` are summands (one per folded
/// contribution), each an ordered atom list covering the value's length.
#[derive(Clone, Debug)]
struct Val {
    len: usize,
    layers: Vec<Vec<Atom>>,
}

impl Val {
    fn solid(origin: usize, block: usize, len: usize) -> Self {
        let layer = if len == 0 { Vec::new() } else { vec![Atom { origin, block, lo: 0, len }] };
        Val { len, layers: vec![layer] }
    }

    fn combine(&mut self, other: Val, at: &str) -> Result<()> {
        if self.len != other.len {
            return Err(perr(format!(
                "{at}: combine of {}-elem value into {}-elem accumulator",
                other.len, self.len
            )));
        }
        self.layers.extend(other.layers);
        Ok(())
    }
}

/// Split a value at the stripe boundaries of its length.
fn split_val(v: &Val, k: usize) -> Vec<Val> {
    let lens = stripe_lens(v.len, k);
    let mut outs: Vec<Val> =
        lens.iter().map(|&l| Val { len: l, layers: Vec::new() }).collect();
    for layer in &v.layers {
        let mut iter = layer.iter().copied();
        let mut cur = iter.next();
        for (si, &sl) in lens.iter().enumerate() {
            let mut need = sl;
            let mut seg = Vec::new();
            while need > 0 {
                let a = cur.expect("layer shorter than value length");
                if a.len <= need {
                    need -= a.len;
                    seg.push(a);
                    cur = iter.next();
                } else {
                    seg.push(Atom { len: need, ..a });
                    cur = Some(Atom { lo: a.lo + need, len: a.len - need, ..a });
                    need = 0;
                }
            }
            outs[si].layers.push(seg);
        }
        debug_assert!(cur.is_none(), "layer longer than value length");
    }
    outs
}

/// The symbolic inputs rank `rank` contributes under `spec` (mirrors the
/// entry-point slicing: one value per caller chunk).
fn input_vals(spec: &PlanSpec, rank: usize) -> Vec<Val> {
    let b = block_elems(spec);
    // Tree all-reduce: every rank contributes its whole buffer as the
    // single block 0 (no per-destination decomposition).
    if spec.algo == Algo::Tree {
        return vec![Val::solid(rank, 0, b)];
    }
    match spec.kind {
        PlanKind::AllGather | PlanKind::Reduce | PlanKind::Gather => {
            vec![Val::solid(rank, 0, b)]
        }
        PlanKind::Broadcast => {
            if rank == spec.root { vec![Val::solid(rank, 0, b)] } else { Vec::new() }
        }
        PlanKind::ReduceScatter | PlanKind::AllReduce => {
            (0..spec.p).map(|i| Val::solid(rank, i, b)).collect()
        }
        PlanKind::Scatter => {
            if rank == spec.root {
                (0..spec.p).map(|i| Val::solid(rank, i, b)).collect()
            } else {
                Vec::new()
            }
        }
        PlanKind::Shuffle => (0..spec.p).map(|i| Val::solid(rank, i, b)).collect(),
    }
}

/// The (origins, block, length) an output position must cover exactly.
fn expected_output(spec: &PlanSpec, rank: usize, oi: usize) -> (Vec<usize>, usize, usize) {
    let b = block_elems(spec);
    let p = spec.p;
    // Tree all-reduce: one output, the whole buffer folded across ranks.
    if spec.algo == Algo::Tree {
        return ((0..p).collect(), 0, b);
    }
    match spec.kind {
        PlanKind::AllGather | PlanKind::Gather => (vec![oi], 0, b),
        PlanKind::ReduceScatter => ((0..p).collect(), rank, b),
        PlanKind::AllReduce => ((0..p).collect(), oi, b),
        PlanKind::Broadcast => (vec![spec.root], 0, b),
        PlanKind::Reduce => ((0..p).collect(), 0, b),
        PlanKind::Scatter => (vec![spec.root], rank, b),
        PlanKind::Shuffle => {
            let outer = spec.nodes;
            let (j, i) = (oi / outer, oi % outer);
            (vec![rank], i * spec.gpn + j, b)
        }
    }
}

#[derive(Clone, Copy, PartialEq, Eq, Hash, Debug)]
struct ChanKey {
    src: usize,
    dst: usize,
    scope: u8,
    epoch: u32,
    step: u32,
    striped: bool,
}

fn scope_disc(s: Scope) -> u8 {
    match s {
        Scope::World => 0,
        Scope::Inter => 1,
        Scope::Intra => 2,
    }
}

/// Map a scope-local peer index to a global rank.
fn global_peer(spec: &PlanSpec, rank: usize, scope: Scope, peer: usize) -> usize {
    match scope {
        Scope::World => peer,
        Scope::Inter => peer * spec.gpn + rank % spec.gpn,
        Scope::Intra => (rank / spec.gpn) * spec.gpn + peer,
    }
}

struct RankSim {
    plan: Plan,
    slots: Vec<Vec<Option<Val>>>,
    cursor: usize,
    /// BeginOps executed so far: the tag-freshness dimension of the
    /// channel key. Plans are SPMD-uniform in their BeginOp structure, so
    /// matching epochs is faithful to (or stricter than) the transport's
    /// FIFO-per-`(src, tag)` matching.
    epoch: u32,
    /// Send half of a fused exchange already posted (recv still pending).
    sent_half: bool,
}

type Chans = HashMap<ChanKey, VecDeque<Vec<Val>>>;

impl RankSim {
    fn new(plan: Plan, spec: &PlanSpec) -> Result<Self> {
        let mut inputs: Vec<Option<Val>> =
            input_vals(spec, plan.rank).into_iter().map(Some).collect();
        let mut slots = Vec::with_capacity(plan.slots.len());
        for init in &plan.slots {
            slots.push(match *init {
                SlotInit::Empty { parts } => vec![None; parts],
                SlotInit::Take(i) => {
                    vec![Some(take_input(&mut inputs, i, plan.rank)?)]
                }
                SlotInit::TakeStripes { input, k } => {
                    let v = take_input(&mut inputs, input, plan.rank)?;
                    split_val(&v, k).into_iter().map(Some).collect()
                }
            });
        }
        Ok(RankSim { plan, slots, cursor: 0, epoch: 0, sent_half: false })
    }

    fn done(&self) -> bool {
        self.cursor >= self.plan.ops.len()
    }

    fn key(&self, spec: &PlanSpec, scope: Scope, peer: usize, step: u32, striped: bool, incoming: bool) -> ChanKey {
        let me = self.plan.rank;
        let other = global_peer(spec, me, scope, peer);
        let (src, dst) = if incoming { (other, me) } else { (me, other) };
        ChanKey { src, dst, scope: scope_disc(scope), epoch: self.epoch, step, striped }
    }

    fn part(&mut self, slot: usize, part: usize, take: bool, at: &str) -> Result<Val> {
        let parts = self
            .slots
            .get_mut(slot)
            .ok_or_else(|| perr(format!("{at}: slot {slot} out of range")))?;
        let cell = parts
            .get_mut(part)
            .ok_or_else(|| perr(format!("{at}: part {part} out of range for slot {slot}")))?;
        let v = if take { cell.take() } else { cell.clone() };
        v.ok_or_else(|| perr(format!("{at}: slot {slot} part {part} is empty")))
    }

    fn put(&mut self, slot: usize, part: usize, v: Val) {
        let parts = &mut self.slots[slot];
        if parts.len() <= part {
            parts.resize(part + 1, None);
        }
        parts[part] = Some(v);
    }

    /// The parts posted by a fused exchange: the whole slot, striped on
    /// demand when the protocol is striped but the slot is still one part
    /// (the stripe-at-take semantics of the lane data plane).
    fn exchange_parts(&mut self, slot: usize, lanes: usize, take: bool, at: &str) -> Result<Vec<Val>> {
        if lanes == 0 {
            return Ok(vec![self.part(slot, 0, take, at)?]);
        }
        let arity = self.slots.get(slot).map(Vec::len).unwrap_or(0);
        if arity == lanes {
            (0..lanes).map(|t| self.part(slot, t, take, at)).collect()
        } else if arity == 1 {
            Ok(split_val(&self.part(slot, 0, take, at)?, lanes))
        } else {
            Err(perr(format!("{at}: slot {slot} arity {arity} vs {lanes} stripes")))
        }
    }

    /// Run ops until blocked on a receive or finished. Returns whether
    /// any progress was made.
    fn drain(&mut self, spec: &PlanSpec, chans: &mut Chans, total: &mut u64) -> Result<bool> {
        let mut progressed = false;
        while self.cursor < self.plan.ops.len() {
            let op = self.plan.ops[self.cursor];
            match op {
                Op::BeginOp { .. } => self.epoch += 1,
                Op::Round => {}
                Op::Send { scope, peer, step, slot, part, take } => {
                    let v = self.part(slot, part, take, "send")?;
                    *total += v.len as u64;
                    let key = self.key(spec, scope, peer, step, false, false);
                    chans.entry(key).or_default().push_back(vec![v]);
                }
                Op::Recv { scope, peer, step, slot, part } => {
                    let key = self.key(spec, scope, peer, step, false, true);
                    let Some(mut msg) = pop_chan(chans, &key) else {
                        return Ok(progressed);
                    };
                    debug_assert_eq!(msg.len(), 1);
                    self.put(slot, part, msg.pop().expect("plain message"));
                }
                Op::RecvCombine { scope, peer, step, slot, part } => {
                    let key = self.key(spec, scope, peer, step, false, true);
                    let Some(mut msg) = pop_chan(chans, &key) else {
                        return Ok(progressed);
                    };
                    let incoming = msg.pop().expect("plain message");
                    let mut acc = self.part(slot, part, true, "recv-combine")?;
                    acc.combine(incoming, "recv-combine")?;
                    self.put(slot, part, acc);
                }
                Op::SendRecv { scope, send_peer, recv_peer, step, send_slot, recv_slot, lanes } => {
                    if !self.sent_half {
                        let parts = self.exchange_parts(send_slot, lanes, false, "sendrecv")?;
                        *total += parts.iter().map(|v| v.len as u64).sum::<u64>();
                        let key = self.key(spec, scope, send_peer, step, lanes > 0, false);
                        chans.entry(key).or_default().push_back(parts);
                        self.sent_half = true;
                        progressed = true;
                    }
                    let key = self.key(spec, scope, recv_peer, step, lanes > 0, true);
                    let Some(msg) = pop_chan(chans, &key) else {
                        return Ok(progressed);
                    };
                    self.slots[recv_slot] = msg.into_iter().map(Some).collect();
                    self.sent_half = false;
                }
                Op::SendRecvCombine {
                    scope,
                    send_peer,
                    recv_peer,
                    step,
                    send_slot,
                    recv_slot,
                    lanes,
                } => {
                    if !self.sent_half {
                        let parts =
                            self.exchange_parts(send_slot, lanes, true, "sendrecv-combine")?;
                        *total += parts.iter().map(|v| v.len as u64).sum::<u64>();
                        let key = self.key(spec, scope, send_peer, step, lanes > 0, false);
                        chans.entry(key).or_default().push_back(parts);
                        self.sent_half = true;
                        progressed = true;
                    }
                    let key = self.key(spec, scope, recv_peer, step, lanes > 0, true);
                    let Some(msg) = pop_chan(chans, &key) else {
                        return Ok(progressed);
                    };
                    let mut accs =
                        self.exchange_parts(recv_slot, lanes, true, "sendrecv-combine")?;
                    if accs.len() != msg.len() {
                        return Err(perr(format!(
                            "sendrecv-combine: {} accumulators vs {} incoming stripes",
                            accs.len(),
                            msg.len()
                        )));
                    }
                    for (acc, v) in accs.iter_mut().zip(msg) {
                        acc.combine(v, "sendrecv-combine")?;
                    }
                    self.slots[recv_slot] = accs.into_iter().map(Some).collect();
                    self.sent_half = false;
                }
            }
            self.cursor += 1;
            progressed = true;
        }
        Ok(progressed)
    }

    fn check_outputs(&self, spec: &PlanSpec) -> Result<()> {
        for (oi, &slot) in self.plan.outputs.iter().enumerate() {
            let parts = self
                .slots
                .get(slot)
                .ok_or_else(|| perr(format!("output slot {slot} out of range")))?;
            let vals: Vec<&Val> = parts
                .iter()
                .map(|c| {
                    c.as_ref().ok_or_else(|| {
                        perr(format!(
                            "rank {}: output slot {slot} has an undelivered part",
                            self.plan.rank
                        ))
                    })
                })
                .collect::<Result<_>>()?;
            let (origins, block, b) = expected_output(spec, self.plan.rank, oi);
            check_cover(&vals, &origins, block, b).map_err(|e| {
                perr(format!("rank {} output {oi} (slot {slot}): {e}", self.plan.rank))
            })?;
        }
        Ok(())
    }
}

fn take_input(inputs: &mut [Option<Val>], i: usize, rank: usize) -> Result<Val> {
    inputs
        .get_mut(i)
        .and_then(Option::take)
        .ok_or_else(|| perr(format!("rank {rank}: input {i} missing or taken twice")))
}

fn pop_chan(chans: &mut Chans, key: &ChanKey) -> Option<Vec<Val>> {
    let q = chans.get_mut(key)?;
    let msg = q.pop_front();
    if q.is_empty() {
        chans.remove(key);
    }
    msg
}

/// Check that `parts` cover exactly `[0, b)` of block `block` from every
/// origin in `origins`, contiguously, alignment-preserving, exactly once,
/// with no foreign contributions.
fn check_cover(parts: &[&Val], origins: &[usize], block: usize, b: usize) -> Result<()> {
    let mut per: HashMap<(usize, usize), Vec<(usize, usize, usize)>> = HashMap::new();
    let mut base = 0usize;
    for v in parts {
        for layer in &v.layers {
            let mut pos = base;
            for a in layer {
                per.entry((a.origin, a.block)).or_default().push((pos, a.lo, a.len));
                pos += a.len;
            }
            if pos - base != v.len {
                return Err(perr(format!(
                    "layer covers {} of a {}-elem value",
                    pos - base,
                    v.len
                )));
            }
        }
        base += v.len;
    }
    if base != b {
        return Err(perr(format!("output holds {base} elems, expected {b}")));
    }
    for &o in origins {
        let Some(mut ivs) = per.remove(&(o, block)) else {
            if b == 0 {
                continue;
            }
            return Err(perr(format!("missing contribution of rank {o} block {block}")));
        };
        ivs.sort_unstable();
        let mut pos = 0usize;
        for (dst, lo, len) in ivs {
            if dst != pos {
                return Err(perr(format!(
                    "rank {o} block {block}: gap or double-fold at element {pos}"
                )));
            }
            if lo != dst {
                return Err(perr(format!(
                    "rank {o} block {block}: element {lo} misaligned to position {dst}"
                )));
            }
            pos += len;
        }
        if pos != b {
            return Err(perr(format!(
                "rank {o} block {block}: only {pos} of {b} elems delivered"
            )));
        }
    }
    if let Some(((o, blk), _)) = per.iter().next() {
        return Err(perr(format!("stray contribution of rank {o} block {blk}")));
    }
    Ok(())
}

/// Verify externally supplied plans (one per rank, in rank order) against
/// `spec`. Used by `verify` and by the property tests that forge broken
/// plans to prove the checker rejects them.
pub fn verify_plans(spec: &PlanSpec, plans: Vec<Plan>) -> Result<VerifyStats> {
    if plans.len() != spec.p {
        return Err(perr(format!("{} plans for p={}", plans.len(), spec.p)));
    }
    let mut sims = plans
        .into_iter()
        .map(|pl| RankSim::new(pl, spec))
        .collect::<Result<Vec<_>>>()?;
    let mut chans: Chans = HashMap::new();
    let mut total = 0u64;
    loop {
        let mut progressed = false;
        let mut done = true;
        for sim in sims.iter_mut() {
            progressed |= sim.drain(spec, &mut chans, &mut total)?;
            done &= sim.done();
        }
        if done {
            break;
        }
        if !progressed {
            let stuck = sims.iter().find(|s| !s.done()).expect("some rank is stuck");
            return Err(perr(format!(
                "deadlock: rank {} blocked at op {} ({:?}) with no matching send",
                stuck.plan.rank, stuck.cursor, stuck.plan.ops[stuck.cursor]
            )));
        }
    }
    if let Some((key, _)) = chans.iter().find(|(_, q)| !q.is_empty()) {
        return Err(perr(format!("message sent but never received: {key:?}")));
    }
    for sim in &sims {
        sim.check_outputs(spec)?;
    }
    Ok(VerifyStats { total_sent_elems: total })
}

/// Build every rank's plan for `spec` and statically verify the ensemble:
/// deadlock-freedom, exact block coverage, and the wire byte total.
pub fn verify(spec: &PlanSpec) -> Result<VerifyStats> {
    let plans = (0..spec.p).map(|r| build(spec, r)).collect::<Result<Vec<_>>>()?;
    verify_plans(spec, plans)
}

/// Memoized [`verify`]: each distinct spec is simulated once per process;
/// the data-plane entry points call this before executing, so the cost is
/// paid at first dispatch, not per collective call.
pub fn verify_cached(spec: &PlanSpec) -> Result<()> {
    static VERIFIED: OnceLock<Mutex<HashSet<PlanSpec>>> = OnceLock::new();
    let cache = VERIFIED.get_or_init(|| Mutex::new(HashSet::new()));
    let mut seen = cache.lock().unwrap_or_else(|poisoned| poisoned.into_inner());
    if seen.contains(spec) {
        return Ok(());
    }
    verify(spec)?;
    seen.insert(*spec);
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn flat(kind: PlanKind, algo: Algo, p: usize, elems: usize, lanes: usize) -> PlanSpec {
        PlanSpec::flat(kind, algo, p, elems, lanes)
    }

    #[test]
    fn flat_specs_verify_across_sizes() {
        for p in [1, 2, 3, 5, 8] {
            for spec in [
                flat(PlanKind::AllGather, Algo::Ring, p, 6, 1),
                flat(PlanKind::ReduceScatter, Algo::Ring, p, 6 * p, 1),
                flat(PlanKind::AllReduce, Algo::Ring, p, 6 * p, 1),
                flat(PlanKind::AllReduce, Algo::Tree, p, 7, 1),
            ] {
                verify(&spec).unwrap_or_else(|e| panic!("{spec:?}: {e}"));
            }
        }
        for p in [1, 2, 4, 8] {
            for spec in [
                flat(PlanKind::AllGather, Algo::Rec, p, 5, 1),
                flat(PlanKind::ReduceScatter, Algo::Rec, p, 5 * p, 1),
                flat(PlanKind::AllReduce, Algo::Rec, p, 5 * p, 1),
            ] {
                verify(&spec).unwrap_or_else(|e| panic!("{spec:?}: {e}"));
            }
        }
    }

    #[test]
    fn striped_specs_verify_with_uneven_stripes() {
        for (p, k) in [(3, 2), (5, 4), (8, 3)] {
            for spec in [
                flat(PlanKind::AllGather, Algo::Ring, p, 5, k),
                flat(PlanKind::ReduceScatter, Algo::Ring, p, 5 * p, k),
                flat(PlanKind::AllReduce, Algo::Ring, p, 5 * p, k),
            ] {
                verify(&spec).unwrap_or_else(|e| panic!("{spec:?}: {e}"));
            }
        }
    }

    #[test]
    fn hier_specs_verify_both_algos_and_stripes() {
        for (n, m) in [(2, 2), (3, 2), (2, 4), (4, 3)] {
            let p = n * m;
            for kind in [PlanKind::AllGather, PlanKind::ReduceScatter, PlanKind::AllReduce] {
                let spec = PlanSpec::hier(kind, Algo::HierRing, n, m, elems_for(kind, p), 1);
                verify(&spec).unwrap_or_else(|e| panic!("{spec:?}: {e}"));
                let spec = PlanSpec::hier(kind, Algo::HierRing, n, m, elems_for(kind, p), 3);
                verify(&spec).unwrap_or_else(|e| panic!("{spec:?}: {e}"));
                if n.is_power_of_two() {
                    let spec = PlanSpec::hier(kind, Algo::HierRec, n, m, elems_for(kind, p), 1);
                    verify(&spec).unwrap_or_else(|e| panic!("{spec:?}: {e}"));
                }
            }
        }
    }

    fn elems_for(kind: PlanKind, p: usize) -> usize {
        match kind {
            PlanKind::AllGather => 6,
            _ => 6 * p,
        }
    }

    #[test]
    fn rooted_and_shuffle_specs_verify() {
        for p in [1, 2, 3, 5, 8] {
            for root in [0, p - 1] {
                verify(&PlanSpec::rooted(PlanKind::Broadcast, Algo::Binomial, p, 4, root))
                    .unwrap();
                verify(&PlanSpec::rooted(PlanKind::Reduce, Algo::Binomial, p, 4, root)).unwrap();
                verify(&PlanSpec::rooted(PlanKind::Gather, Algo::Direct, p, 4, root)).unwrap();
                verify(&PlanSpec::rooted(PlanKind::Scatter, Algo::Direct, p, 4 * p, root))
                    .unwrap();
            }
        }
        verify(&PlanSpec::shuffle(3, 4)).unwrap();
        verify(&PlanSpec::shuffle(1, 5)).unwrap();
    }

    #[test]
    fn ring_byte_totals_match_closed_form() {
        // Flat ring all-gather: every rank posts (p - 1) blocks of b.
        let (p, b) = (6, 7);
        let stats = verify(&flat(PlanKind::AllGather, Algo::Ring, p, b, 1)).unwrap();
        assert_eq!(stats.total_sent_elems, (p * (p - 1) * b) as u64);
        // Striping does not change the wire volume.
        let striped = verify(&flat(PlanKind::AllGather, Algo::Ring, p, b, 4)).unwrap();
        assert_eq!(striped.total_sent_elems, stats.total_sent_elems);
        // Ring all-reduce: RS + AG, each (p - 1) blocks per rank.
        let stats = verify(&flat(PlanKind::AllReduce, Algo::Ring, p, b * p, 1)).unwrap();
        assert_eq!(stats.total_sent_elems, (2 * p * (p - 1) * b) as u64);
    }

    #[test]
    fn rec_volume_halves_per_step() {
        // Recursive halving posts p*b/2 + p*b/4 + ... + b per rank.
        let (p, b) = (8, 3);
        let stats = verify(&flat(PlanKind::ReduceScatter, Algo::Rec, p, b * p, 1)).unwrap();
        assert_eq!(stats.total_sent_elems, (p * (p - 1) * b) as u64);
    }

    #[test]
    fn non_pow2_rec_is_rejected() {
        let err = build(&flat(PlanKind::AllGather, Algo::Rec, 6, 4, 1), 0).unwrap_err();
        assert!(matches!(err, Error::Plan(_)), "{err}");
        assert!(err.to_string().contains("power-of-two"));
    }

    #[test]
    fn forged_plans_are_rejected() {
        let spec = flat(PlanKind::AllGather, Algo::Ring, 3, 4, 1);
        // Drop one rank's final exchange: its left neighbor's send is never
        // received and its own block map stays incomplete.
        let mut plans: Vec<Plan> = (0..3).map(|r| build(&spec, r).unwrap()).collect();
        let last = plans[1].ops.len() - 1;
        plans[1].ops.truncate(last);
        let err = verify_plans(&spec, plans).unwrap_err();
        assert!(matches!(err, Error::Plan(_)), "{err}");

        // Swap two recv slots: coverage check catches the misplaced block.
        let mut plans: Vec<Plan> = (0..3).map(|r| build(&spec, r).unwrap()).collect();
        for op in plans[2].ops.iter_mut() {
            if let Op::SendRecv { recv_slot, .. } = op {
                *recv_slot = (*recv_slot + 1) % 3;
            }
        }
        let err = verify_plans(&spec, plans).unwrap_err();
        assert!(matches!(err, Error::Plan(_)), "{err}");

        // A send with no matching recv anywhere deadlocks the ensemble.
        let mut plans: Vec<Plan> = (0..3).map(|r| build(&spec, r).unwrap()).collect();
        if let Op::SendRecv { step, .. } = &mut plans[0].ops[1] {
            *step += 99;
        }
        let err = verify_plans(&spec, plans).unwrap_err();
        assert!(err.to_string().contains("deadlock"), "{err}");
    }

    #[test]
    fn phase_shapes_report_ring_and_rec_structure() {
        // Flat ring AG at b=1: p-1 rounds of 1 element, no combining.
        let p = 6;
        let shapes = phase_shapes(&flat(PlanKind::AllGather, Algo::Ring, p, 1, 1)).unwrap();
        assert_eq!(shapes.len(), 1);
        assert_eq!(shapes[0].scope, Scope::World);
        assert_eq!(shapes[0].rounds.len(), p - 1);
        assert!(shapes[0]
            .rounds
            .iter()
            .all(|r| r.sent_elems == 1 && r.combine_elems == 0));

        // Flat rec RS at elems=p (b=1): halving volumes p/2, p/4, ..., 1.
        let p = 8;
        let shapes = phase_shapes(&flat(PlanKind::ReduceScatter, Algo::Rec, p, p, 1)).unwrap();
        assert_eq!(shapes[0].rounds.len(), 3);
        let sent: Vec<u64> = shapes[0].rounds.iter().map(|r| r.sent_elems).collect();
        assert_eq!(sent, vec![4, 2, 1]);
        assert!(shapes[0].rounds.iter().all(|r| r.combine_elems == r.sent_elems));

        // Hierarchical AR: intra-RS, inter-RS, inter-AG, intra-AG phases.
        let (n, m) = (4, 3);
        let spec = PlanSpec::hier(PlanKind::AllReduce, Algo::HierRing, n, m, n * m, 1);
        let shapes = phase_shapes(&spec).unwrap();
        let scopes: Vec<Scope> = shapes.iter().map(|s| s.scope).collect();
        assert_eq!(scopes, vec![Scope::Intra, Scope::Inter, Scope::Inter, Scope::Intra]);
        // Intra rounds move n blocks of b=1 each; inter rounds move one.
        assert!(shapes[0].rounds.iter().all(|r| r.sent_elems == n as u64));
        assert_eq!(shapes[1].rounds.len(), n - 1);
        assert!(shapes[1].rounds.iter().all(|r| r.sent_elems == 1));
    }

    #[test]
    fn degenerate_hier_shapes_keep_phase_structure() {
        // The cost model builds hier specs even for single-node / single-
        // GPU geometries; the empty phase must still be present.
        let spec = PlanSpec::hier(PlanKind::AllGather, Algo::HierRing, 1, 4, 1, 1);
        let shapes = phase_shapes(&spec).unwrap();
        assert_eq!(shapes.len(), 2);
        assert!(shapes[0].rounds.is_empty(), "inter phase of n=1 is empty");
        let spec = PlanSpec::hier(PlanKind::AllGather, Algo::HierRing, 4, 1, 1, 1);
        let shapes = phase_shapes(&spec).unwrap();
        assert!(shapes[1].rounds.is_empty(), "intra phase of m=1 is empty");
    }

    #[test]
    fn verify_cached_memoizes() {
        let spec = flat(PlanKind::AllGather, Algo::Ring, 4, 3, 1);
        verify_cached(&spec).unwrap();
        verify_cached(&spec).unwrap();
        let bad = flat(PlanKind::AllGather, Algo::Rec, 6, 3, 1);
        assert!(verify_cached(&bad).is_err());
    }
}
