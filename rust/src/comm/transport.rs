//! In-process transport: per-rank mailboxes with (source, tag) matching.
//!
//! Each rank owns an [`Endpoint`]: an MPSC receiver (its mailbox) plus
//! cloned senders to every peer. Messages are matched MPI-style on
//! `(src, tag)`; out-of-order arrivals are stashed in a pending map. FIFO
//! is preserved per `(src, tag)` pair because the underlying channel is
//! FIFO per sender and stashing appends in arrival order.
//!
//! The message payload is a [`Chunk`] — an Arc-backed shared buffer view —
//! so posting a message moves a reference, never the bytes. A rank that
//! forwards a received chunk (ring/hierarchical all-gather) or sends a
//! sub-view of its input (recursive doubling, scatter) performs zero
//! copies end to end.

use std::collections::{HashMap, VecDeque};
use std::sync::mpsc::{self, Receiver, RecvTimeoutError, Sender};
use std::time::{Duration, Instant};

use crate::error::{Error, Result};

use super::chunk::Chunk;

/// Default receive timeout — generous for tests on loaded machines while
/// still converting deadlocks into typed errors instead of hangs.
pub const DEFAULT_RECV_TIMEOUT: Duration = Duration::from_secs(60);

struct Msg<T> {
    src: usize,
    tag: u64,
    data: Chunk<T>,
}

/// Monotonic per-endpoint traffic counters (messages, elements, bytes).
///
/// Bytes are exact: `elements × size_of::<T>()`, which for the data-plane
/// element types equals [`crate::reduction::Elem::SIZE`]. The bench harness
/// and the launcher's schedule-equivalence guard consume these.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct Traffic {
    /// Messages posted by this endpoint.
    pub sent_msgs: u64,
    /// Elements posted by this endpoint.
    pub sent_elems: u64,
    /// Bytes posted by this endpoint.
    pub sent_bytes: u64,
    /// Messages received (matched) by this endpoint.
    pub recvd_msgs: u64,
    /// Bytes received (matched) by this endpoint.
    pub recvd_bytes: u64,
}

/// Cloneable handle with senders to every rank's mailbox.
pub struct TransportHub<T> {
    senders: Vec<Sender<Msg<T>>>,
}

impl<T> Clone for TransportHub<T> {
    fn clone(&self) -> Self {
        Self {
            senders: self.senders.clone(),
        }
    }
}

impl<T: Send + Sync + 'static> TransportHub<T> {
    /// Build a hub + one endpoint per rank.
    pub fn new(size: usize) -> (Self, Vec<Endpoint<T>>) {
        let mut senders = Vec::with_capacity(size);
        let mut receivers = Vec::with_capacity(size);
        for _ in 0..size {
            let (tx, rx) = mpsc::channel();
            senders.push(tx);
            receivers.push(rx);
        }
        let hub = Self { senders };
        let endpoints = receivers
            .into_iter()
            .enumerate()
            .map(|(rank, rx)| Endpoint {
                rank,
                hub: hub.clone(),
                rx,
                pending: HashMap::new(),
                timeout: DEFAULT_RECV_TIMEOUT,
                traffic: Traffic::default(),
            })
            .collect();
        (hub, endpoints)
    }

    fn size(&self) -> usize {
        self.senders.len()
    }
}

/// One rank's connection to the transport. Not `Clone`: exactly one owner
/// (the rank thread) may receive.
pub struct Endpoint<T> {
    rank: usize,
    hub: TransportHub<T>,
    rx: Receiver<Msg<T>>,
    pending: HashMap<(usize, u64), VecDeque<Chunk<T>>>,
    timeout: Duration,
    traffic: Traffic,
}

impl<T: Send + Sync + 'static> Endpoint<T> {
    pub fn rank(&self) -> usize {
        self.rank
    }

    pub fn size(&self) -> usize {
        self.hub.size()
    }

    /// Override the receive timeout (failure-injection tests use short ones).
    pub fn set_timeout(&mut self, timeout: Duration) {
        self.timeout = timeout;
    }

    /// Traffic counters so far (monotonic).
    pub fn traffic(&self) -> Traffic {
        self.traffic
    }

    /// Post `chunk` to `to`'s mailbox — a reference move, never a byte
    /// copy. Non-blocking (unbounded channel — the collectives are
    /// self-throttling, at most one outstanding message per peer per step).
    pub fn send_chunk(&mut self, to: usize, tag: u64, chunk: Chunk<T>) -> Result<()> {
        if to >= self.hub.size() {
            return Err(Error::PeerOutOfRange {
                peer: to,
                size: self.hub.size(),
            });
        }
        self.traffic.sent_msgs += 1;
        self.traffic.sent_elems += chunk.len() as u64;
        self.traffic.sent_bytes += (chunk.len() * std::mem::size_of::<T>()) as u64;
        self.hub.senders[to]
            .send(Msg {
                src: self.rank,
                tag,
                data: chunk,
            })
            .map_err(|_| Error::TransportClosed { rank: self.rank })
    }

    /// Owned-vector send: wraps into a [`Chunk`] (O(1)) and posts it.
    pub fn send(&mut self, to: usize, tag: u64, data: Vec<T>) -> Result<()> {
        self.send_chunk(to, tag, Chunk::from_vec(data))
    }

    /// Blocking matched receive of a chunk from `(from, tag)`.
    pub fn recv_chunk(&mut self, from: usize, tag: u64) -> Result<Chunk<T>> {
        let key = (from, tag);
        if let Some(q) = self.pending.get_mut(&key) {
            if let Some(data) = q.pop_front() {
                self.count_recv(&data);
                return Ok(data);
            }
        }
        let deadline = Instant::now() + self.timeout;
        loop {
            let remaining = deadline.saturating_duration_since(Instant::now());
            match self.rx.recv_timeout(remaining) {
                Ok(msg) => {
                    if msg.src == from && msg.tag == tag {
                        self.count_recv(&msg.data);
                        return Ok(msg.data);
                    }
                    self.pending
                        .entry((msg.src, msg.tag))
                        .or_default()
                        .push_back(msg.data);
                }
                Err(RecvTimeoutError::Timeout) => {
                    return Err(Error::RecvTimeout {
                        src: from,
                        tag,
                        ms: self.timeout.as_millis() as u64,
                    })
                }
                Err(RecvTimeoutError::Disconnected) => {
                    return Err(Error::TransportClosed { rank: self.rank })
                }
            }
        }
    }

    /// Materializing receive (compat shim over [`Endpoint::recv_chunk`]).
    pub fn recv(&mut self, from: usize, tag: u64) -> Result<Vec<T>>
    where
        T: Clone,
    {
        Ok(self.recv_chunk(from, tag)?.into_vec())
    }

    fn count_recv(&mut self, chunk: &Chunk<T>) {
        self.traffic.recvd_msgs += 1;
        self.traffic.recvd_bytes += (chunk.len() * std::mem::size_of::<T>()) as u64;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matched_send_recv() {
        let (_hub, mut eps) = TransportHub::<f32>::new(2);
        let mut e1 = eps.pop().unwrap();
        let mut e0 = eps.pop().unwrap();
        e0.send(1, 7, vec![1.0, 2.0]).unwrap();
        assert_eq!(e1.recv(0, 7).unwrap(), vec![1.0, 2.0]);
    }

    #[test]
    fn out_of_order_tags_are_stashed() {
        let (_hub, mut eps) = TransportHub::<i64>::new(2);
        let mut e1 = eps.pop().unwrap();
        let mut e0 = eps.pop().unwrap();
        e0.send(1, 1, vec![10]).unwrap();
        e0.send(1, 2, vec![20]).unwrap();
        // Receive in reverse tag order.
        assert_eq!(e1.recv(0, 2).unwrap(), vec![20]);
        assert_eq!(e1.recv(0, 1).unwrap(), vec![10]);
    }

    #[test]
    fn fifo_within_same_tag() {
        let (_hub, mut eps) = TransportHub::<u8>::new(2);
        let mut e1 = eps.pop().unwrap();
        let mut e0 = eps.pop().unwrap();
        for v in 0..4u8 {
            e0.send(1, 9, vec![v]).unwrap();
        }
        for v in 0..4u8 {
            assert_eq!(e1.recv(0, 9).unwrap(), vec![v]);
        }
    }

    #[test]
    fn recv_timeout_is_typed_error() {
        let (_hub, mut eps) = TransportHub::<f32>::new(2);
        let mut e1 = eps.remove(1);
        e1.set_timeout(Duration::from_millis(20));
        match e1.recv(0, 5) {
            Err(Error::RecvTimeout { src: 0, tag: 5, .. }) => {}
            other => panic!("expected RecvTimeout, got {other:?}"),
        }
    }

    #[test]
    fn send_to_bad_peer_rejected() {
        let (_hub, mut eps) = TransportHub::<f32>::new(2);
        let mut e0 = eps.remove(0);
        assert!(matches!(
            e0.send(5, 0, vec![]),
            Err(Error::PeerOutOfRange { peer: 5, size: 2 })
        ));
    }

    #[test]
    fn cross_thread_roundtrip() {
        let (_hub, mut eps) = TransportHub::<f64>::new(2);
        let mut e1 = eps.pop().unwrap();
        let mut e0 = eps.pop().unwrap();
        let t = std::thread::spawn(move || {
            let got = e1.recv(0, 3).unwrap();
            e1.send(0, 4, got.iter().map(|x| x * 2.0).collect())
                .unwrap();
        });
        e0.send(1, 3, vec![1.5, 2.5]).unwrap();
        assert_eq!(e0.recv(1, 4).unwrap(), vec![3.0, 5.0]);
        t.join().unwrap();
    }

    #[test]
    fn chunk_messages_are_zero_copy_across_threads() {
        // A sub-view sent to a peer thread arrives backed by the *same*
        // storage: no bytes moved through the transport.
        let (_hub, mut eps) = TransportHub::<f32>::new(2);
        let mut e1 = eps.pop().unwrap();
        let mut e0 = eps.pop().unwrap();
        let big = Chunk::from_vec((0..64).map(|i| i as f32).collect());
        let id = big.storage_id();
        let view = big.slice(16, 8);
        let t = std::thread::spawn(move || {
            let got = e1.recv_chunk(0, 1).unwrap();
            (got.storage_id(), got.to_vec())
        });
        e0.send_chunk(1, 1, view).unwrap();
        let (got_id, data) = t.join().unwrap();
        assert_eq!(got_id, id, "received chunk must share the sender's storage");
        assert_eq!(data, (16..24).map(|i| i as f32).collect::<Vec<_>>());
    }

    #[test]
    fn traffic_counts_bytes_and_messages() {
        let (_hub, mut eps) = TransportHub::<f32>::new(2);
        let mut e1 = eps.pop().unwrap();
        let mut e0 = eps.pop().unwrap();
        e0.send(1, 0, vec![1.0, 2.0, 3.0]).unwrap();
        let t = e0.traffic();
        assert_eq!((t.sent_msgs, t.sent_elems, t.sent_bytes), (1, 3, 12));
        assert_eq!((t.recvd_msgs, t.recvd_bytes), (0, 0));
        let _ = e1.recv(0, 0).unwrap();
        let t = e1.traffic();
        assert_eq!((t.recvd_msgs, t.recvd_bytes), (1, 12));
    }
}
