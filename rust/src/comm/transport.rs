//! In-process transport: per-(rank, lane) mailboxes with (source, tag)
//! matching.
//!
//! Each rank owns an [`Endpoint`]: one MPSC receiver (its mailbox) per
//! **lane** plus cloned senders to every peer lane. Messages are matched
//! MPI-style on `(src, tag)`; out-of-order arrivals are stashed in a
//! pending map. FIFO is preserved per `(src, tag)` pair because the
//! underlying channel is FIFO per sender and stashing appends in arrival
//! order.
//!
//! The message payload is a [`Chunk`] — an Arc-backed shared buffer view —
//! so posting a message moves a reference, never the bytes. A rank that
//! forwards a received chunk (ring/hierarchical all-gather) or sends a
//! sub-view of its input (recursive doubling, scatter) performs zero
//! copies end to end.
//!
//! ## Lanes
//!
//! A hub built with [`TransportHub::new_with_lanes`] gives every rank pair
//! `lanes` independent queues, modeling the multiple NIC rails a node can
//! drive at once (NCCL channels / HiCCL rail striping). Lane 0 is served
//! inline by the owning rank thread — `lanes = 1` is byte-for-byte the old
//! single-queue transport. Each lane ≥ 1 is served by a dedicated **lane
//! worker thread** owned by the endpoint: the striped receive family
//! ([`Endpoint::recv_striped_combine_into`] and friends) fans one posted
//! buffer per lane out to the workers, so the per-stripe `accept` /
//! `accept_combine` (the memcpy/fold work of a collective step) runs on
//! `lanes` threads concurrently while lane 0's stripe is handled on the
//! calling thread. Workers are long-lived — spawned once per endpoint, fed
//! over a job queue — so the per-step cost is a channel round-trip, not a
//! thread spawn.
//!
//! Traffic accounting is **per lane** ([`Endpoint::traffic_per_lane`]):
//! sends are counted by the posting thread into the destination lane's
//! counters, receives by whichever thread completes the delivery.
//! [`Endpoint::traffic`] returns the lane sum, so single-lane callers see
//! the exact counters they always did.
//!
//! ## Failure semantics
//!
//! Every blocking wait in this module is sliced into
//! [`Endpoint::set_abort_poll`]-sized pieces and re-checks three things
//! between slices: the lane teardown flag, the world [`AbortToken`], and
//! the **live** receive timeout (an [`Endpoint::set_timeout`] issued while
//! a lane job is already parked takes effect within one slice — the job
//! carries a handle to the shared deadline, not a snapshot). A rank that
//! detects a failure calls [`Endpoint::broadcast_abort`], which trips the
//! token and posts a poison message on a reserved control tag
//! ([`CTRL_TAG_PREFIX`] | epoch) to every peer's lane-0 mailbox, so a
//! parked peer wakes immediately instead of at its next poll slice. Stale
//! poison from an already-recovered epoch is discarded by the epoch check.
//! Deterministic chaos testing is driven by a [`FaultPlan`] armed on an
//! endpoint ([`Endpoint::arm_faults`]).

use std::collections::{HashMap, VecDeque};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{self, Receiver, RecvTimeoutError, Sender};
use std::sync::{Arc, Mutex, MutexGuard, PoisonError};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::error::{Error, Result};
use crate::reduction::offload::Combiner;
use crate::util::json::Value;

use super::chunk::Chunk;

/// Default receive timeout — generous for tests on loaded machines while
/// still converting deadlocks into typed errors instead of hangs.
pub const DEFAULT_RECV_TIMEOUT: Duration = Duration::from_secs(60);

/// Default extra wait past the receive timeout before a silent lane worker
/// is declared lost — see [`Endpoint::set_shutdown_grace`].
pub const DEFAULT_SHUTDOWN_GRACE: Duration = Duration::from_secs(30);

/// Default wait-slice length for every blocking pull: the teardown flag,
/// the abort token, and the live timeout are re-checked between slices, so
/// abort detection latency is bounded by this (configurable per endpoint
/// via [`Endpoint::set_abort_poll`]), not by the receive timeout.
const LANE_SHUTDOWN_POLL: Duration = Duration::from_millis(25);

/// Control-message tag namespace: top 32 bits all-ones, the abort epoch in
/// the low 32. Data tags are FNV-1a chain outputs, which land in this
/// namespace with probability 2⁻³² per tag — vanishingly unlikely, and a
/// collision is still caught downstream by the chaos checksums.
pub(crate) const CTRL_TAG_PREFIX: u64 = 0xFFFF_FFFF_0000_0000;

fn ctrl_tag(epoch: u32) -> u64 {
    CTRL_TAG_PREFIX | epoch as u64
}

fn is_ctrl_tag(tag: u64) -> bool {
    tag & CTRL_TAG_PREFIX == CTRL_TAG_PREFIX
}

fn ctrl_epoch(tag: u64) -> u32 {
    (tag & 0xFFFF_FFFF) as u32
}

/// World-wide collective abort flag, shared by every rank of a world (one
/// `Arc` under the clones). The first rank to detect a failure trips it
/// with its identity and cause; every subsequent wait in the world returns
/// the same typed [`Error::CollectiveAborted`] within one poll slice.
/// [`AbortToken::clear`] re-arms it after recovery.
#[derive(Clone, Default)]
pub struct AbortToken {
    inner: Arc<AbortState>,
}

#[derive(Default)]
struct AbortState {
    tripped: AtomicBool,
    detail: Mutex<Option<(usize, u64, String)>>,
}

impl AbortToken {
    pub fn new() -> Self {
        Self::default()
    }

    /// Trip the abort. The first caller wins — later trips are ignored so
    /// the origin attribution stays stable. Returns whether this call was
    /// the one that tripped it.
    pub fn trip(&self, origin_rank: usize, op_seq: u64, cause: &str) -> bool {
        let mut d = self
            .inner
            .detail
            .lock()
            .unwrap_or_else(PoisonError::into_inner);
        if d.is_some() {
            return false;
        }
        *d = Some((origin_rank, op_seq, cause.to_string()));
        // Ordered after the detail write: a reader that observes the flag
        // always finds the detail populated.
        self.inner.tripped.store(true, Ordering::Release);
        true
    }

    pub fn is_tripped(&self) -> bool {
        self.inner.tripped.load(Ordering::Acquire)
    }

    /// The typed abort error, if tripped.
    pub fn error(&self) -> Option<Error> {
        if !self.is_tripped() {
            return None;
        }
        let d = self
            .inner
            .detail
            .lock()
            .unwrap_or_else(PoisonError::into_inner);
        let (origin_rank, op_seq, cause) = d
            .clone()
            .unwrap_or_else(|| (usize::MAX, 0, "aborted".to_string()));
        Some(Error::CollectiveAborted {
            origin_rank,
            op_seq,
            cause,
        })
    }

    /// Reset after recovery so the world can run its next epoch.
    pub fn clear(&self) {
        let mut d = self
            .inner
            .detail
            .lock()
            .unwrap_or_else(PoisonError::into_inner);
        *d = None;
        self.inner.tripped.store(false, Ordering::Release);
    }
}

/// What an injected fault does when it fires. Send-side directives model
/// NIC/link failures at the posting rank; [`FaultAction::StallWorker`]
/// fires on the receiving rank's lane worker.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultAction {
    /// The message is counted as sent, then silently lost on the wire —
    /// peers detect it as a receive timeout.
    Drop,
    /// Delivery is delayed by `ms` on the sending side.
    Delay { ms: u64 },
    /// The message is delivered twice. The duplicate can never match a
    /// later op (tags are FNV-chained per op/step), so correct tag
    /// matching makes it harmless; recovery's queue drain reclaims it.
    Duplicate,
    /// The payload is mangled in a length-visible way (truncated by one
    /// element; an empty payload is dropped instead) — our stand-in for a
    /// CRC-detected corruption, surfaced by the posted-receive shape check
    /// as [`Error::RecvShapeMismatch`] instead of silently folding garbage.
    Corrupt,
    /// The rank dies: this operation and every later send/receive on the
    /// rank fails immediately with [`Error::CollectiveAborted`], and the
    /// dead rank never broadcasts — peers must detect the death by
    /// timeout, exactly as with a real dead host.
    KillRank,
    /// The lane worker serving the matching receive stalls `ms` before
    /// serving (a slow rail). Fires on the receiving rank; worker lanes
    /// (≥ 1) only.
    StallWorker { ms: u64 },
}

impl FaultAction {
    fn kind(&self) -> &'static str {
        match self {
            FaultAction::Drop => "drop",
            FaultAction::Delay { .. } => "delay",
            FaultAction::Duplicate => "duplicate",
            FaultAction::Corrupt => "corrupt",
            FaultAction::KillRank => "kill_rank",
            FaultAction::StallWorker { .. } => "stall_worker",
        }
    }

    fn ms(&self) -> u64 {
        match self {
            FaultAction::Delay { ms } | FaultAction::StallWorker { ms } => *ms,
            _ => 0,
        }
    }

    fn from_parts(kind: &str, ms: u64) -> Result<FaultAction> {
        Ok(match kind {
            "drop" => FaultAction::Drop,
            "delay" => FaultAction::Delay { ms },
            "duplicate" => FaultAction::Duplicate,
            "corrupt" => FaultAction::Corrupt,
            "kill_rank" => FaultAction::KillRank,
            "stall_worker" => FaultAction::StallWorker { ms },
            other => {
                return Err(Error::Json(format!("unknown fault action {other:?}")))
            }
        })
    }
}

/// One injected fault directive: fires on `rank` the first time it touches
/// `(peer, lane)` at or after communicator op `op_seq`, then is spent
/// ([`FaultAction::KillRank`] stays in effect permanently). Send-side
/// actions match `peer` = destination; [`FaultAction::StallWorker`]
/// matches `peer` = source.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct FaultSpec {
    pub rank: usize,
    pub peer: usize,
    pub lane: usize,
    pub op_seq: u64,
    pub action: FaultAction,
}

/// A deterministic, serializable fault schedule for chaos runs. Armed per
/// endpoint via [`Endpoint::arm_faults`]; because each rank's traffic
/// order is deterministic, replaying the same plan against the same
/// program reproduces the same failure exactly.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct FaultPlan {
    seed: u64,
    faults: Vec<FaultSpec>,
}

impl FaultPlan {
    /// An explicit (hand-written) plan.
    pub fn new(faults: Vec<FaultSpec>) -> Self {
        Self { seed: 0, faults }
    }

    /// Deterministic pseudo-random plan: the same `(seed, size, lanes, n)`
    /// always produces the same plan (xorshift64, no global RNG state).
    pub fn seeded(seed: u64, size: usize, lanes: usize, n: usize) -> Self {
        let mut s = seed.wrapping_mul(0x9E37_79B9_7F4A_7C15) | 1;
        let mut next = move || {
            s ^= s << 13;
            s ^= s >> 7;
            s ^= s << 17;
            s
        };
        let size = size.max(1) as u64;
        let lanes = lanes.max(1) as u64;
        let faults = (0..n)
            .map(|_| {
                let rank = (next() % size) as usize;
                let mut peer = (next() % size) as usize;
                if size > 1 && peer == rank {
                    peer = (peer + 1) % size as usize;
                }
                let lane = (next() % lanes) as usize;
                let op_seq = next() % 4;
                let action = match next() % 6 {
                    0 => FaultAction::Drop,
                    1 => FaultAction::Delay { ms: 1 + next() % 20 },
                    2 => FaultAction::Duplicate,
                    3 => FaultAction::Corrupt,
                    4 => FaultAction::KillRank,
                    _ => FaultAction::StallWorker { ms: 1 + next() % 20 },
                };
                FaultSpec { rank, peer, lane, op_seq, action }
            })
            .collect();
        Self { seed, faults }
    }

    pub fn seed(&self) -> u64 {
        self.seed
    }

    pub fn faults(&self) -> &[FaultSpec] {
        &self.faults
    }

    /// Serialize for the chaos record, so a failing cell's exact fault
    /// schedule ships with the artifact and replays bit-for-bit.
    pub fn to_value(&self) -> Value {
        Value::obj(vec![
            // Seed as string: f64 would truncate seeds above 2^53.
            ("seed", Value::Str(self.seed.to_string())),
            (
                "faults",
                Value::Arr(
                    self.faults
                        .iter()
                        .map(|f| {
                            Value::obj(vec![
                                ("rank", Value::Num(f.rank as f64)),
                                ("peer", Value::Num(f.peer as f64)),
                                ("lane", Value::Num(f.lane as f64)),
                                ("op_seq", Value::Num(f.op_seq as f64)),
                                ("action", Value::Str(f.action.kind().to_string())),
                                ("ms", Value::Num(f.action.ms() as f64)),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
    }

    /// Inverse of [`FaultPlan::to_value`].
    pub fn from_value(v: &Value) -> Result<FaultPlan> {
        let seed = v
            .get("seed")?
            .as_str()?
            .parse::<u64>()
            .map_err(|e| Error::Json(format!("bad fault plan seed: {e}")))?;
        let faults = v
            .get("faults")?
            .as_arr()?
            .iter()
            .map(|f| {
                let ms = f.get("ms")?.as_f64()? as u64;
                Ok(FaultSpec {
                    rank: f.get("rank")?.as_usize()?,
                    peer: f.get("peer")?.as_usize()?,
                    lane: f.get("lane")?.as_usize()?,
                    op_seq: f.get("op_seq")?.as_f64()? as u64,
                    action: FaultAction::from_parts(f.get("action")?.as_str()?, ms)?,
                })
            })
            .collect::<Result<Vec<_>>>()?;
        Ok(FaultPlan { seed, faults })
    }
}

/// Armed per-endpoint fault state: the plan plus one-shot spent markers,
/// the current communicator op sequence (fed by `begin_op`), and the
/// kill-rank latch.
struct FaultCtx {
    plan: FaultPlan,
    spent: Vec<bool>,
    op_seq: u64,
    killed: bool,
}

impl FaultCtx {
    fn fire(&mut self, rank: usize, peer: usize, lane: usize, stall: bool) -> Option<FaultAction> {
        for (i, f) in self.plan.faults.iter().enumerate() {
            if self.spent[i]
                || f.rank != rank
                || f.peer != peer
                || f.lane != lane
                || self.op_seq < f.op_seq
            {
                continue;
            }
            if matches!(f.action, FaultAction::StallWorker { .. }) != stall {
                continue;
            }
            self.spent[i] = true;
            return Some(f.action);
        }
        None
    }
}

/// Lock a lane traffic counter, surviving poisoning. The counters are
/// plain numbers: a panicked sibling thread cannot leave them in a state
/// worth cascading the panic for, and the partial counts are still the
/// best available answer during teardown.
fn lock_traffic(t: &Mutex<Traffic>) -> MutexGuard<'_, Traffic> {
    t.lock().unwrap_or_else(PoisonError::into_inner)
}

struct Msg<T> {
    src: usize,
    tag: u64,
    data: Chunk<T>,
}

/// Monotonic per-endpoint traffic counters (messages, elements, bytes).
///
/// Bytes are exact: `elements × size_of::<T>()`, which for the data-plane
/// element types equals [`crate::reduction::Elem::SIZE`]. The bench harness
/// and the launcher's schedule-equivalence guard consume these. With a
/// multi-lane endpoint one `Traffic` exists per lane; see
/// [`Endpoint::traffic_per_lane`].
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct Traffic {
    /// Messages posted by this endpoint.
    pub sent_msgs: u64,
    /// Elements posted by this endpoint.
    pub sent_elems: u64,
    /// Bytes posted by this endpoint.
    pub sent_bytes: u64,
    /// Messages received (matched) by this endpoint.
    pub recvd_msgs: u64,
    /// Bytes received (matched) by this endpoint.
    pub recvd_bytes: u64,
    /// Received bytes delivered by reference move or in-place combine —
    /// no verbatim buffer copy on the receive path.
    pub moved_bytes: u64,
    /// Received bytes that had to be copied into caller storage (a shared
    /// incoming view delivered into a posted buffer). The reduce-path
    /// smoke guard asserts this stays zero. Invariant:
    /// `moved_bytes + copied_bytes == recvd_bytes`.
    pub copied_bytes: u64,
}

impl Traffic {
    /// Field-wise sum — aggregates per-lane counters into endpoint totals.
    pub fn merged(self, o: Traffic) -> Traffic {
        Traffic {
            sent_msgs: self.sent_msgs + o.sent_msgs,
            sent_elems: self.sent_elems + o.sent_elems,
            sent_bytes: self.sent_bytes + o.sent_bytes,
            recvd_msgs: self.recvd_msgs + o.recvd_msgs,
            recvd_bytes: self.recvd_bytes + o.recvd_bytes,
            moved_bytes: self.moved_bytes + o.moved_bytes,
            copied_bytes: self.copied_bytes + o.copied_bytes,
        }
    }

    fn count_send<T>(&mut self, elems: usize) {
        self.sent_msgs += 1;
        self.sent_elems += elems as u64;
        self.sent_bytes += (elems * std::mem::size_of::<T>()) as u64;
    }

    fn count_recv<T>(&mut self, elems: usize, copied_elems: usize) {
        let bytes = |e: usize| (e * std::mem::size_of::<T>()) as u64;
        self.recvd_msgs += 1;
        self.recvd_bytes += bytes(elems);
        self.copied_bytes += bytes(copied_elems);
        self.moved_bytes += bytes(elems - copied_elems);
    }
}

/// One lane's matching state: its mailbox receiver plus the out-of-order
/// stash. Lane 0's mailbox lives inside the endpoint; every other lane's
/// lives inside that lane's worker thread.
struct Mailbox<T> {
    rx: Receiver<Msg<T>>,
    pending: HashMap<(usize, u64), VecDeque<Chunk<T>>>,
}

impl<T> Mailbox<T> {
    fn new(rx: Receiver<Msg<T>>) -> Self {
        Self {
            rx,
            pending: HashMap::new(),
        }
    }

    /// Matched pull without traffic accounting (counting happens once the
    /// delivery is classified as moved or copied). `rank` is only for
    /// error construction.
    ///
    /// The wait is sliced into `watch.poll` pieces; between slices the
    /// teardown flag, the abort token, and the **live** receive timeout
    /// are re-checked (so a timeout shortened mid-wait takes effect within
    /// one slice). A matching-epoch control message aborts the pull
    /// immediately; a stale-epoch one (from an already-recovered abort) is
    /// discarded. Cancellation surfaces as [`Error::TransportClosed`],
    /// aborts as [`Error::CollectiveAborted`].
    fn pull_watched(
        &mut self,
        rank: usize,
        from: usize,
        tag: u64,
        watch: &Watch<'_>,
    ) -> Result<Chunk<T>> {
        let key = (from, tag);
        if let Some(q) = self.pending.get_mut(&key) {
            if let Some(data) = q.pop_front() {
                return Ok(data);
            }
        }
        let start = Instant::now();
        loop {
            if watch.cancel.is_some_and(|c| c.load(Ordering::Relaxed)) {
                return Err(Error::TransportClosed { rank });
            }
            if let Some(e) = watch.abort.and_then(AbortToken::error) {
                return Err(e);
            }
            let timeout = Duration::from_millis(watch.timeout_ms.load(Ordering::Relaxed));
            let deadline = start + timeout;
            let now = Instant::now();
            if now >= deadline {
                return Err(Error::RecvTimeout {
                    src: from,
                    tag,
                    ms: timeout.as_millis() as u64,
                });
            }
            let wait = deadline.saturating_duration_since(now).min(watch.poll);
            match self.rx.recv_timeout(wait) {
                Ok(msg) => {
                    if is_ctrl_tag(msg.tag) {
                        if ctrl_epoch(msg.tag) == watch.epoch {
                            return Err(abort_error(watch.abort, msg.src));
                        }
                        continue; // stale-epoch poison: already recovered from
                    }
                    if msg.src == from && msg.tag == tag {
                        return Ok(msg.data);
                    }
                    self.pending
                        .entry((msg.src, msg.tag))
                        .or_default()
                        .push_back(msg.data);
                }
                Err(RecvTimeoutError::Timeout) => {}
                Err(RecvTimeoutError::Disconnected) => {
                    return Err(Error::TransportClosed { rank })
                }
            }
        }
    }

    /// [`Mailbox::pull_watched`] plus the posted-buffer shape check; on
    /// mismatch the message is requeued at the front (FIFO order preserved
    /// — it was taken from the front) and the error is recoverable.
    fn checked_pull_watched(
        &mut self,
        rank: usize,
        from: usize,
        tag: u64,
        expected: usize,
        watch: &Watch<'_>,
    ) -> Result<Chunk<T>> {
        let data = self.pull_watched(rank, from, tag, watch)?;
        if data.len() != expected {
            let got = data.len();
            self.pending.entry((from, tag)).or_default().push_front(data);
            return Err(Error::RecvShapeMismatch {
                src: from,
                tag,
                expected,
                got,
            });
        }
        Ok(data)
    }
}

/// Everything a blocking pull watches besides its own `(src, tag)` match:
/// the lane teardown flag, the world abort token, the live receive
/// timeout, the current abort epoch (for control-tag filtering), and the
/// wait-slice length bounding detection latency.
struct Watch<'a> {
    cancel: Option<&'a AtomicBool>,
    abort: Option<&'a AbortToken>,
    timeout_ms: &'a AtomicU64,
    epoch: u32,
    poll: Duration,
}

/// The error a poison control message resolves to: the token's detail when
/// armed (origin, op, cause as tripped), else attribution to the sender.
fn abort_error(tok: Option<&AbortToken>, origin: usize) -> Error {
    tok.and_then(AbortToken::error)
        .unwrap_or_else(|| Error::CollectiveAborted {
            origin_rank: origin,
            op_seq: 0,
            cause: "abort signal from peer".to_string(),
        })
}

/// Build a [`Watch`] from an endpoint's fields. A macro (not a method) so
/// the borrow checker sees disjoint field borrows and lets the watch
/// coexist with the `&mut self.lane0` pull it feeds.
macro_rules! watch {
    ($ep:expr) => {
        Watch {
            cancel: None,
            abort: $ep.abort.as_ref(),
            timeout_ms: &*$ep.timeout,
            epoch: $ep.epoch,
            poll: $ep.poll,
        }
    };
}

/// A receive request shipped to a lane worker. `dest: None` is a plain
/// matched pull (the chunk reference comes back); `Some` is a posted
/// receive, folded through `combiner` when one is attached. The timeout is
/// a live handle to the endpoint's shared deadline — not a snapshot — so
/// [`Endpoint::set_timeout`] reaches a job that is already parked.
struct LaneJob<T> {
    from: usize,
    tag: u64,
    timeout_ms: Arc<AtomicU64>,
    abort: Option<AbortToken>,
    epoch: u32,
    poll: Duration,
    /// Injected rail stall (fault harness): sleep this long before serving.
    stall_ms: u64,
    dest: Option<Chunk<T>>,
    combiner: Option<Combiner<T>>,
}

/// What the endpoint asks a lane worker to do.
enum LaneCmd<T> {
    Recv(LaneJob<T>),
    /// Post-abort recovery: discard every queued and stashed message on
    /// this lane (stale-epoch tags can never match again).
    Drain,
}

/// A lane worker's answer: the delivered (or returned-on-error) chunk plus
/// the delivery result. On error a posted `dest` comes back untouched.
/// `wait`/`serve` split the service time into time-in-mailbox vs
/// accept/fold time, feeding the endpoint's op clock.
struct LaneDone<T> {
    chunk: Option<Chunk<T>>,
    wait: Duration,
    serve: Duration,
    result: Result<()>,
}

/// Owner-side handle to one lane worker thread (lanes ≥ 1).
struct LaneWorker<T> {
    job_tx: Sender<LaneCmd<T>>,
    done_rx: Receiver<LaneDone<T>>,
    traffic: Arc<Mutex<Traffic>>,
    /// Shutdown flag shared with the worker thread: set by the endpoint's
    /// `Drop` before the job queue closes so a mid-pull worker bails within
    /// one [`LANE_SHUTDOWN_POLL`] slice and queued jobs drain immediately.
    stop: Arc<AtomicBool>,
    handle: Option<JoinHandle<()>>,
}

/// Cloneable handle with senders to every `(rank, lane)` mailbox.
pub struct TransportHub<T> {
    /// Flattened `[rank * lanes + lane]`.
    senders: Vec<Sender<Msg<T>>>,
    lanes: usize,
}

impl<T> Clone for TransportHub<T> {
    fn clone(&self) -> Self {
        Self {
            senders: self.senders.clone(),
            lanes: self.lanes,
        }
    }
}

impl<T: Send + Sync + 'static> TransportHub<T> {
    /// Build a single-lane hub + one endpoint per rank — byte-for-byte the
    /// pre-lane transport (no worker threads are spawned).
    pub fn new(size: usize) -> (Self, Vec<Endpoint<T>>) {
        let (hub, rxs) = Self::channels(size, 1);
        let endpoints = rxs
            .into_iter()
            .enumerate()
            .map(|(rank, mut lane_rxs)| {
                Endpoint::assemble(rank, hub.clone(), lane_rxs.pop().expect("lane 0"), Vec::new())
            })
            .collect();
        (hub, endpoints)
    }

    fn channels(size: usize, lanes: usize) -> (Self, Vec<Vec<Receiver<Msg<T>>>>) {
        assert!(lanes >= 1, "transport needs at least one lane");
        let mut senders = Vec::with_capacity(size * lanes);
        let mut receivers: Vec<Vec<Receiver<Msg<T>>>> = Vec::with_capacity(size);
        for _ in 0..size {
            let mut lane_rxs = Vec::with_capacity(lanes);
            for _ in 0..lanes {
                let (tx, rx) = mpsc::channel();
                senders.push(tx);
                lane_rxs.push(rx);
            }
            receivers.push(lane_rxs);
        }
        (Self { senders, lanes }, receivers)
    }

    fn size(&self) -> usize {
        self.senders.len() / self.lanes
    }

    fn sender(&self, to: usize, lane: usize) -> &Sender<Msg<T>> {
        &self.senders[to * self.lanes + lane]
    }
}

impl<T: Send + Sync + Clone + 'static> TransportHub<T> {
    /// Build a hub with `lanes` independent queues per rank pair. Each
    /// endpoint owns `lanes - 1` long-lived lane worker threads (lane 0 is
    /// served inline by the rank thread), so striped receives fold their
    /// stripes in parallel.
    pub fn new_with_lanes(size: usize, lanes: usize) -> (Self, Vec<Endpoint<T>>) {
        let (hub, rxs) = Self::channels(size, lanes);
        let endpoints = rxs
            .into_iter()
            .enumerate()
            .map(|(rank, lane_rxs)| {
                let mut it = lane_rxs.into_iter();
                let lane0 = it.next().expect("lane 0");
                let workers = it
                    .enumerate()
                    .map(|(i, rx)| spawn_lane_worker(rank, i + 1, rx))
                    .collect();
                Endpoint::assemble(rank, hub.clone(), lane0, workers)
            })
            .collect();
        (hub, endpoints)
    }
}

/// Spawn the worker thread serving lane `lane` of rank `rank`.
fn spawn_lane_worker<T: Send + Sync + Clone + 'static>(
    rank: usize,
    lane: usize,
    rx: Receiver<Msg<T>>,
) -> LaneWorker<T> {
    let (job_tx, job_rx) = mpsc::channel::<LaneCmd<T>>();
    let (done_tx, done_rx) = mpsc::channel::<LaneDone<T>>();
    let traffic = Arc::new(Mutex::new(Traffic::default()));
    let shared = Arc::clone(&traffic);
    let stop = Arc::new(AtomicBool::new(false));
    let stop_flag = Arc::clone(&stop);
    let handle = std::thread::Builder::new()
        .name(format!("pccl-lane-{rank}.{lane}"))
        .spawn(move || {
            let mut mailbox = Mailbox::new(rx);
            while let Ok(cmd) = job_rx.recv() {
                let done = match cmd {
                    LaneCmd::Drain => {
                        while mailbox.rx.try_recv().is_ok() {}
                        mailbox.pending.clear();
                        LaneDone {
                            chunk: None,
                            wait: Duration::ZERO,
                            serve: Duration::ZERO,
                            result: Ok(()),
                        }
                    }
                    // Once teardown starts, drain queued jobs without
                    // serving them: their pulls would only time out against
                    // a dying transport and stall the endpoint's join.
                    LaneCmd::Recv(job) if stop_flag.load(Ordering::Relaxed) => LaneDone {
                        chunk: job.dest,
                        wait: Duration::ZERO,
                        serve: Duration::ZERO,
                        result: Err(Error::TransportClosed { rank }),
                    },
                    LaneCmd::Recv(job) => {
                        serve_lane_job(&mut mailbox, &shared, rank, &stop_flag, job)
                    }
                };
                if done_tx.send(done).is_err() {
                    return; // endpoint dropped
                }
            }
        })
        .expect("spawn lane worker thread");
    LaneWorker {
        job_tx,
        done_rx,
        traffic,
        stop,
        handle: Some(handle),
    }
}

/// One receive on a worker lane: pull, deliver per the job's mode, count.
/// The pulls watch `stop` so endpoint teardown interrupts a parked wait,
/// and the abort token so a world abort does too.
fn serve_lane_job<T: Send + Sync + Clone + 'static>(
    mailbox: &mut Mailbox<T>,
    traffic: &Mutex<Traffic>,
    rank: usize,
    stop: &AtomicBool,
    job: LaneJob<T>,
) -> LaneDone<T> {
    // Injected rail stall: sleep in poll slices so teardown still
    // interrupts promptly.
    if job.stall_ms > 0 {
        let until = Instant::now() + Duration::from_millis(job.stall_ms);
        loop {
            let remaining = until.saturating_duration_since(Instant::now());
            if remaining.is_zero() {
                break;
            }
            if stop.load(Ordering::Relaxed) {
                return LaneDone {
                    chunk: job.dest,
                    wait: Duration::ZERO,
                    serve: Duration::ZERO,
                    result: Err(Error::TransportClosed { rank }),
                };
            }
            std::thread::sleep(remaining.min(job.poll));
        }
    }
    let watch = Watch {
        cancel: Some(stop),
        abort: job.abort.as_ref(),
        timeout_ms: &job.timeout_ms,
        epoch: job.epoch,
        poll: job.poll,
    };
    let t0 = Instant::now();
    match job.dest {
        None => match mailbox.pull_watched(rank, job.from, job.tag, &watch) {
            Ok(data) => {
                let wait = t0.elapsed();
                lock_traffic(traffic).count_recv::<T>(data.len(), 0);
                LaneDone {
                    chunk: Some(data),
                    wait,
                    serve: Duration::ZERO,
                    result: Ok(()),
                }
            }
            Err(e) => LaneDone {
                chunk: None,
                wait: t0.elapsed(),
                serve: Duration::ZERO,
                result: Err(e),
            },
        },
        Some(mut dest) => {
            match mailbox.checked_pull_watched(rank, job.from, job.tag, dest.len(), &watch) {
                Ok(data) => {
                    let matched = Instant::now();
                    let len = data.len();
                    let copied = match &job.combiner {
                        Some(comb) => {
                            dest.accept_combine(data, comb);
                            0
                        }
                        None => dest.accept(data),
                    };
                    lock_traffic(traffic).count_recv::<T>(len, copied);
                    LaneDone {
                        chunk: Some(dest),
                        wait: matched - t0,
                        serve: matched.elapsed(),
                        result: Ok(()),
                    }
                }
                Err(e) => LaneDone {
                    chunk: Some(dest),
                    wait: t0.elapsed(),
                    serve: Duration::ZERO,
                    result: Err(e),
                },
            }
        }
    }
}

/// One rank's connection to the transport. Not `Clone`: exactly one owner
/// (the rank thread) may receive.
pub struct Endpoint<T> {
    rank: usize,
    hub: TransportHub<T>,
    lane0: Mailbox<T>,
    workers: Vec<LaneWorker<T>>,
    /// Live receive timeout in ms — shared with every dispatched lane job,
    /// so [`Endpoint::set_timeout`] reaches already-parked workers.
    timeout: Arc<AtomicU64>,
    /// Wait-slice length: abort/teardown/timeout-change detection latency.
    poll: Duration,
    /// Extra wait past the receive timeout before a silent lane worker is
    /// declared lost ([`Error::LaneWorkerLost`]).
    shutdown_grace: Duration,
    /// Current abort epoch — folded into control tags so stale poison from
    /// a recovered abort is discarded.
    epoch: u32,
    abort: Option<AbortToken>,
    fault: Option<FaultCtx>,
    /// Cumulative time-in-mailbox across receives (ns) — the op clock's
    /// queueing half.
    wait_ns: u64,
    /// Cumulative accept/fold time across receives (ns) — the service half.
    serve_ns: u64,
    traffic: Traffic,
}

impl<T: Send + Sync + 'static> Endpoint<T> {
    fn assemble(
        rank: usize,
        hub: TransportHub<T>,
        lane0_rx: Receiver<Msg<T>>,
        workers: Vec<LaneWorker<T>>,
    ) -> Self {
        Self {
            rank,
            hub,
            lane0: Mailbox::new(lane0_rx),
            workers,
            timeout: Arc::new(AtomicU64::new(DEFAULT_RECV_TIMEOUT.as_millis() as u64)),
            poll: LANE_SHUTDOWN_POLL,
            shutdown_grace: DEFAULT_SHUTDOWN_GRACE,
            epoch: 0,
            abort: None,
            fault: None,
            wait_ns: 0,
            serve_ns: 0,
            traffic: Traffic::default(),
        }
    }

    pub fn rank(&self) -> usize {
        self.rank
    }

    pub fn size(&self) -> usize {
        self.hub.size()
    }

    /// Number of independent lanes per rank pair (≥ 1; lane 0 always exists).
    pub fn lane_count(&self) -> usize {
        1 + self.workers.len()
    }

    /// Override the receive timeout (failure-injection tests use short
    /// ones). Takes effect immediately, including for lane jobs that are
    /// already parked in a pull — they observe the new deadline within one
    /// poll slice.
    pub fn set_timeout(&mut self, timeout: Duration) {
        self.timeout
            .store(timeout.as_millis() as u64, Ordering::Relaxed);
    }

    fn timeout(&self) -> Duration {
        Duration::from_millis(self.timeout.load(Ordering::Relaxed))
    }

    /// Extra wait past the receive timeout before a silent lane worker is
    /// declared [`Error::LaneWorkerLost`]. Default
    /// [`DEFAULT_SHUTDOWN_GRACE`].
    pub fn set_shutdown_grace(&mut self, grace: Duration) {
        self.shutdown_grace = grace;
    }

    pub fn shutdown_grace(&self) -> Duration {
        self.shutdown_grace
    }

    /// Wait-slice length for every blocking pull — the abort detection
    /// window. Clamped to ≥ 1 ms.
    pub fn set_abort_poll(&mut self, poll: Duration) {
        self.poll = poll.max(Duration::from_millis(1));
    }

    /// Arm this endpoint with the world's shared abort token. Pulls check
    /// it between wait slices; [`Endpoint::broadcast_abort`] trips it.
    pub fn set_abort_token(&mut self, token: AbortToken) {
        self.abort = Some(token);
    }

    pub fn abort_token(&self) -> Option<&AbortToken> {
        self.abort.as_ref()
    }

    /// Set the abort epoch. Control messages carry the sender's epoch;
    /// pulls discard poison whose epoch differs from this one. Recovery
    /// bumps every rank's epoch in lockstep (see `Communicator::bump_epoch`).
    pub fn set_epoch(&mut self, epoch: u32) {
        self.epoch = epoch;
    }

    pub fn epoch(&self) -> u32 {
        self.epoch
    }

    /// Arm a deterministic fault schedule (chaos harness). Replaces any
    /// previously armed plan and resets its spent/killed state.
    pub fn arm_faults(&mut self, plan: FaultPlan) {
        let spent = vec![false; plan.faults.len()];
        self.fault = Some(FaultCtx {
            plan,
            spent,
            op_seq: 0,
            killed: false,
        });
    }

    /// Disarm fault injection (part of epoch-bump recovery).
    pub fn clear_faults(&mut self) {
        self.fault = None;
    }

    /// Feed the communicator's op sequence to the fault harness so
    /// directives can be keyed on it.
    pub fn note_op_seq(&mut self, op_seq: u64) {
        if let Some(f) = &mut self.fault {
            f.op_seq = op_seq;
        }
    }

    fn check_killed(&self) -> Result<()> {
        match &self.fault {
            Some(f) if f.killed => Err(Error::CollectiveAborted {
                origin_rank: self.rank,
                op_seq: f.op_seq,
                cause: "fault injection: rank killed".to_string(),
            }),
            _ => Ok(()),
        }
    }

    fn check_abort(&self) -> Result<()> {
        match self.abort.as_ref().and_then(AbortToken::error) {
            Some(e) => Err(e),
            None => Ok(()),
        }
    }

    /// Cumulative `(time-in-mailbox, accept/fold time)` across this
    /// endpoint's receives, in nanoseconds. The engine differences this
    /// around each op to attribute queueing vs service time per span.
    pub fn op_clock(&self) -> (u64, u64) {
        (self.wait_ns, self.serve_ns)
    }

    /// Trip the world abort (if a token is armed) and post a poison
    /// control message on the reserved tag for the current epoch to every
    /// peer's lane-0 mailbox, waking parked peers immediately. Control
    /// messages bypass traffic accounting — they are not data-plane bytes.
    pub fn broadcast_abort(&mut self, op_seq: u64, cause: &str) {
        if let Some(tok) = &self.abort {
            tok.trip(self.rank, op_seq, cause);
        }
        let tag = ctrl_tag(self.epoch);
        for peer in 0..self.hub.size() {
            if peer == self.rank {
                continue;
            }
            let _ = self.hub.sender(peer, 0).send(Msg {
                src: self.rank,
                tag,
                data: Chunk::empty(),
            });
        }
    }

    /// Discard every queued and stashed message on all lanes — part of
    /// post-abort recovery. Stale messages carry previous-epoch tags that
    /// can never match again; dropping them reclaims the memory.
    pub fn drain(&mut self) -> Result<()> {
        while self.lane0.rx.try_recv().is_ok() {}
        self.lane0.pending.clear();
        for lane in 1..self.lane_count() {
            let w = &self.workers[lane - 1];
            w.job_tx
                .send(LaneCmd::Drain)
                .map_err(|_| Error::TransportClosed { rank: self.rank })?;
            let done = self.collect_lane(lane)?;
            done.result?;
        }
        Ok(())
    }

    /// Traffic counters so far, summed over all lanes (monotonic).
    pub fn traffic(&self) -> Traffic {
        self.traffic_per_lane()
            .into_iter()
            .fold(Traffic::default(), Traffic::merged)
    }

    /// Per-lane traffic counters (index = lane id). Lane 0 is the inline
    /// lane; the rest are worker lanes.
    pub fn traffic_per_lane(&self) -> Vec<Traffic> {
        let mut out = Vec::with_capacity(self.lane_count());
        out.push(self.traffic);
        for w in &self.workers {
            out.push(*lock_traffic(&w.traffic));
        }
        out
    }

    /// Post `chunk` to `to`'s lane-0 mailbox — a reference move, never a
    /// byte copy. Non-blocking (unbounded channel — the collectives are
    /// self-throttling, at most one outstanding message per peer per step).
    pub fn send_chunk(&mut self, to: usize, tag: u64, chunk: Chunk<T>) -> Result<()> {
        self.send_chunk_on(to, 0, tag, chunk)
    }

    /// Post `chunk` to `to`'s mailbox on `lane`. Counting lands in this
    /// endpoint's per-lane send counters. An armed fault directive for
    /// `(self.rank, to, lane)` fires here, before the message is posted —
    /// modeling a sender-side NIC/link fault.
    pub fn send_chunk_on(&mut self, to: usize, lane: usize, tag: u64, chunk: Chunk<T>) -> Result<()> {
        if to >= self.hub.size() {
            return Err(Error::PeerOutOfRange {
                peer: to,
                size: self.hub.size(),
            });
        }
        if lane >= self.lane_count() {
            return Err(Error::PeerOutOfRange {
                peer: lane,
                size: self.lane_count(),
            });
        }
        self.check_killed()?;
        self.check_abort()?;
        let rank = self.rank;
        let action = self
            .fault
            .as_mut()
            .and_then(|ctx| ctx.fire(rank, to, lane, false));
        let mut chunk = chunk;
        let mut copies = 1usize;
        match action {
            None => {}
            Some(FaultAction::Drop) => {
                // Lost on the wire: the NIC already counted it as sent.
                self.count_send_on(lane, chunk.len());
                return Ok(());
            }
            Some(FaultAction::Delay { ms }) => std::thread::sleep(Duration::from_millis(ms)),
            Some(FaultAction::Duplicate) => copies = 2,
            Some(FaultAction::Corrupt) => {
                if chunk.is_empty() {
                    self.count_send_on(lane, 0);
                    return Ok(());
                }
                let len = chunk.len();
                chunk = chunk.slice(0, len - 1);
            }
            Some(FaultAction::KillRank) => {
                let op_seq = match &mut self.fault {
                    Some(ctx) => {
                        ctx.killed = true;
                        ctx.op_seq
                    }
                    None => 0,
                };
                return Err(Error::CollectiveAborted {
                    origin_rank: rank,
                    op_seq,
                    cause: "fault injection: rank killed".to_string(),
                });
            }
            Some(FaultAction::StallWorker { .. }) => {} // receive-side directive
        }
        self.count_send_on(lane, chunk.len());
        for _ in 1..copies {
            self.hub
                .sender(to, lane)
                .send(Msg {
                    src: rank,
                    tag,
                    data: chunk.clone(),
                })
                .map_err(|_| Error::TransportClosed { rank })?;
        }
        self.hub
            .sender(to, lane)
            .send(Msg {
                src: rank,
                tag,
                data: chunk,
            })
            .map_err(|_| Error::TransportClosed { rank })
    }

    fn count_send_on(&mut self, lane: usize, elems: usize) {
        if lane == 0 {
            self.traffic.count_send::<T>(elems);
        } else {
            lock_traffic(&self.workers[lane - 1].traffic).count_send::<T>(elems);
        }
    }

    /// Blocking matched receive of a chunk from `(from, tag)` on lane 0 —
    /// the caller takes the delivered reference, so the whole message
    /// counts as moved.
    pub fn recv_chunk(&mut self, from: usize, tag: u64) -> Result<Chunk<T>> {
        self.check_killed()?;
        let t0 = Instant::now();
        let data = self
            .lane0
            .pull_watched(self.rank, from, tag, &watch!(self))?;
        self.wait_ns += t0.elapsed().as_nanos() as u64;
        self.traffic.count_recv::<T>(data.len(), 0);
        Ok(data)
    }

    /// Blocking matched receive on an explicit lane. Lanes ≥ 1 round-trip
    /// through that lane's worker thread (its mailbox lives there).
    pub fn recv_chunk_on(&mut self, lane: usize, from: usize, tag: u64) -> Result<Chunk<T>> {
        if lane == 0 {
            return self.recv_chunk(from, tag);
        }
        self.dispatch_lane(lane, from, tag, None, None)?;
        let done = self.collect_lane(lane)?;
        done.result?;
        done.chunk.ok_or(Error::TransportClosed { rank: self.rank })
    }

    /// Posted receive: deliver the matched chunk into `dest`, preferring a
    /// reference move over a copy (see [`Chunk::accept`]).
    ///
    /// If the incoming chunk's length differs from `dest.len()` the message
    /// is pushed back onto the front of the pending queue (so a later,
    /// correctly-sized receive can still match it) and a typed
    /// [`Error::RecvShapeMismatch`] is returned.
    pub fn recv_chunk_into(&mut self, from: usize, tag: u64, dest: &mut Chunk<T>) -> Result<()>
    where
        T: Clone,
    {
        self.check_killed()?;
        let t0 = Instant::now();
        let data =
            self.lane0
                .checked_pull_watched(self.rank, from, tag, dest.len(), &watch!(self))?;
        let matched = Instant::now();
        self.wait_ns += (matched - t0).as_nanos() as u64;
        let len = data.len();
        let copied = dest.accept(data);
        self.serve_ns += matched.elapsed().as_nanos() as u64;
        self.traffic.count_recv::<T>(len, copied);
        Ok(())
    }

    /// Posted receive fused with a reduction: after the call `dest` holds
    /// `dest ⊕ incoming` with zero verbatim copies (see
    /// [`Chunk::accept_combine`] for the three delivery cases). Shape
    /// mismatches behave as in [`Endpoint::recv_chunk_into`].
    pub fn recv_chunk_combine_into(
        &mut self,
        from: usize,
        tag: u64,
        dest: &mut Chunk<T>,
        combiner: &Combiner<T>,
    ) -> Result<()>
    where
        T: Clone,
    {
        self.check_killed()?;
        let t0 = Instant::now();
        let data =
            self.lane0
                .checked_pull_watched(self.rank, from, tag, dest.len(), &watch!(self))?;
        let matched = Instant::now();
        self.wait_ns += (matched - t0).as_nanos() as u64;
        let len = data.len();
        dest.accept_combine(data, combiner);
        self.serve_ns += matched.elapsed().as_nanos() as u64;
        self.traffic.count_recv::<T>(len, 0);
        Ok(())
    }

    fn dispatch_lane(
        &mut self,
        lane: usize,
        from: usize,
        tag: u64,
        dest: Option<Chunk<T>>,
        combiner: Option<Combiner<T>>,
    ) -> Result<()> {
        self.check_killed()?;
        let rank = self.rank;
        // A stall directive for (self.rank, from, lane) fires on the
        // receiving side: the worker sleeps before serving this job.
        let stall_ms = match self.fault.as_mut().and_then(|ctx| ctx.fire(rank, from, lane, true)) {
            Some(FaultAction::StallWorker { ms }) => ms,
            _ => 0,
        };
        let job = LaneJob {
            from,
            tag,
            timeout_ms: Arc::clone(&self.timeout),
            abort: self.abort.clone(),
            epoch: self.epoch,
            poll: self.poll,
            stall_ms,
            dest,
            combiner,
        };
        let w = self
            .workers
            .get(lane - 1)
            .ok_or(Error::PeerOutOfRange {
                peer: lane,
                size: self.lane_count(),
            })?;
        w.job_tx
            .send(LaneCmd::Recv(job))
            .map_err(|_| Error::TransportClosed { rank })
    }

    fn collect_lane(&mut self, lane: usize) -> Result<LaneDone<T>> {
        // Workers answer every job exactly once; a worker that stays
        // silent past the job's own receive timeout plus the configured
        // shutdown grace is presumed dead — a typed loss, distinct from an
        // orderly transport teardown.
        let grace = self.shutdown_grace;
        let deadline = self.timeout() + grace;
        let res = self
            .workers
            .get(lane - 1)
            .ok_or(Error::PeerOutOfRange {
                peer: lane,
                size: self.lane_count(),
            })?
            .done_rx
            .recv_timeout(deadline);
        match res {
            Ok(done) => {
                self.wait_ns += done.wait.as_nanos() as u64;
                self.serve_ns += done.serve.as_nanos() as u64;
                Ok(done)
            }
            Err(RecvTimeoutError::Disconnected) => {
                Err(Error::TransportClosed { rank: self.rank })
            }
            Err(RecvTimeoutError::Timeout) => Err(Error::LaneWorkerLost {
                rank: self.rank,
                lane,
                grace_ms: grace.as_millis() as u64,
            }),
        }
    }

    /// Posted receive on an explicit lane (see [`Endpoint::recv_chunk_into`]).
    pub fn recv_chunk_into_on(
        &mut self,
        lane: usize,
        from: usize,
        tag: u64,
        dest: &mut Chunk<T>,
    ) -> Result<()>
    where
        T: Clone,
    {
        if lane == 0 {
            return self.recv_chunk_into(from, tag, dest);
        }
        let posted = std::mem::replace(dest, Chunk::empty());
        self.dispatch_lane(lane, from, tag, Some(posted), None)?;
        let done = self.collect_lane(lane)?;
        if let Some(chunk) = done.chunk {
            *dest = chunk;
        }
        done.result
    }

    /// Posted combining receive on an explicit lane (see
    /// [`Endpoint::recv_chunk_combine_into`]).
    pub fn recv_chunk_combine_into_on(
        &mut self,
        lane: usize,
        from: usize,
        tag: u64,
        dest: &mut Chunk<T>,
        combiner: &Combiner<T>,
    ) -> Result<()>
    where
        T: Clone,
    {
        if lane == 0 {
            return self.recv_chunk_combine_into(from, tag, dest, combiner);
        }
        let posted = std::mem::replace(dest, Chunk::empty());
        self.dispatch_lane(lane, from, tag, Some(posted), Some(combiner.clone()))?;
        let done = self.collect_lane(lane)?;
        if let Some(chunk) = done.chunk {
            *dest = chunk;
        }
        done.result
    }

    /// Striped matched receive: pull stripe `l` from `(from, tags[l])` on
    /// lane `l`. Stripes on worker lanes are pulled concurrently; the
    /// returned chunks are in lane order. `tags.len()` must be ≤
    /// [`Endpoint::lane_count`].
    pub fn recv_striped(&mut self, from: usize, tags: &[u64]) -> Result<Vec<Chunk<T>>> {
        self.check_killed()?;
        let k = self.check_stripes(tags.len())?;
        for (l, &tag) in tags.iter().enumerate().skip(1) {
            self.dispatch_lane(l, from, tag, None, None)?;
        }
        let t0 = Instant::now();
        let lane0 = self
            .lane0
            .pull_watched(self.rank, from, tags[0], &watch!(self));
        self.wait_ns += t0.elapsed().as_nanos() as u64;
        if let Ok(data) = &lane0 {
            self.traffic.count_recv::<T>(data.len(), 0);
        }
        let mut out: Vec<Option<Chunk<T>>> = Vec::with_capacity(k);
        out.push(lane0.as_ref().ok().cloned());
        let mut first_err: Option<Error> = lane0.err();
        for l in 1..k {
            match self.collect_lane(l) {
                Ok(done) => {
                    if let Err(e) = done.result {
                        first_err.get_or_insert(e);
                        out.push(None);
                    } else {
                        out.push(done.chunk);
                    }
                }
                Err(e) => {
                    first_err.get_or_insert(e);
                    out.push(None);
                }
            }
        }
        match first_err {
            Some(e) => Err(e),
            None => Ok(out.into_iter().map(|c| c.expect("stripe delivered")).collect()),
        }
    }

    /// Striped posted receive: deliver stripe `l` from `(from, tags[l])`
    /// on lane `l` into `dests[l]`. Worker-lane stripes are delivered
    /// concurrently with lane 0's. On error, already-delivered stripes
    /// keep their payload and the rest come back untouched (the whole
    /// collective op is abandoned anyway).
    pub fn recv_striped_into(
        &mut self,
        from: usize,
        tags: &[u64],
        dests: &mut [Chunk<T>],
    ) -> Result<()>
    where
        T: Clone,
    {
        self.striped_delivery(from, tags, dests, None)
    }

    /// Striped posted receive fused with a reduction — the lane-parallel
    /// combine primitive. Stripe `l` is folded into `dests[l]` via
    /// [`Chunk::accept_combine`] on lane `l`'s worker thread (lane 0 on the
    /// calling thread), so the fold work of one collective step runs on
    /// `tags.len()` threads at once.
    pub fn recv_striped_combine_into(
        &mut self,
        from: usize,
        tags: &[u64],
        dests: &mut [Chunk<T>],
        combiner: &Combiner<T>,
    ) -> Result<()>
    where
        T: Clone,
    {
        self.striped_delivery(from, tags, dests, Some(combiner))
    }

    fn striped_delivery(
        &mut self,
        from: usize,
        tags: &[u64],
        dests: &mut [Chunk<T>],
        combiner: Option<&Combiner<T>>,
    ) -> Result<()>
    where
        T: Clone,
    {
        self.check_killed()?;
        let k = self.check_stripes(tags.len())?;
        if dests.len() != k {
            return Err(Error::BadBufferSize {
                len: dests.len(),
                size: k,
                why: "striped receive needs one posted buffer per stripe tag",
            });
        }
        // Fan worker-lane stripes out first so they overlap lane 0's work.
        for l in 1..k {
            let dest = std::mem::replace(&mut dests[l], Chunk::empty());
            self.dispatch_lane(l, from, tags[l], Some(dest), combiner.cloned())?;
        }
        let lane0_result = match combiner {
            Some(comb) => self.recv_chunk_combine_into(from, tags[0], &mut dests[0], comb),
            None => self.recv_chunk_into(from, tags[0], &mut dests[0]),
        };
        let mut first_err: Option<Error> = lane0_result.err();
        for l in 1..k {
            match self.collect_lane(l) {
                Ok(done) => {
                    if let Some(chunk) = done.chunk {
                        dests[l] = chunk;
                    }
                    if let Err(e) = done.result {
                        first_err.get_or_insert(e);
                    }
                }
                Err(e) => {
                    first_err.get_or_insert(e);
                }
            }
        }
        match first_err {
            Some(e) => Err(e),
            None => Ok(()),
        }
    }

    fn check_stripes(&self, k: usize) -> Result<usize> {
        if k == 0 || k > self.lane_count() {
            return Err(Error::BadBufferSize {
                len: k,
                size: self.lane_count(),
                why: "stripe count must be 1..=lane_count",
            });
        }
        Ok(k)
    }
}

impl<T> Drop for Endpoint<T> {
    fn drop(&mut self) {
        // Flag every worker first: one mid-pull on a dead transport would
        // otherwise sleep out its full receive timeout before noticing the
        // closed job queue, stalling this join for a minute or more.
        for w in &self.workers {
            w.stop.store(true, Ordering::Relaxed);
        }
        // Closing each worker's job queue ends its loop; join so no lane
        // thread outlives the transport it serves.
        for w in &mut self.workers {
            let (dead_tx, _) = mpsc::channel();
            let _ = std::mem::replace(&mut w.job_tx, dead_tx);
        }
        for w in &mut self.workers {
            if let Some(h) = w.handle.take() {
                let _ = h.join();
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matched_send_recv() {
        let (_hub, mut eps) = TransportHub::<f32>::new(2);
        let mut e1 = eps.pop().unwrap();
        let mut e0 = eps.pop().unwrap();
        e0.send_chunk(1, 7, Chunk::from_vec(vec![1.0, 2.0])).unwrap();
        assert_eq!(e1.recv_chunk(0, 7).unwrap(), vec![1.0, 2.0]);
    }

    #[test]
    fn out_of_order_tags_are_stashed() {
        let (_hub, mut eps) = TransportHub::<i64>::new(2);
        let mut e1 = eps.pop().unwrap();
        let mut e0 = eps.pop().unwrap();
        e0.send_chunk(1, 1, Chunk::from_vec(vec![10])).unwrap();
        e0.send_chunk(1, 2, Chunk::from_vec(vec![20])).unwrap();
        // Receive in reverse tag order.
        assert_eq!(e1.recv_chunk(0, 2).unwrap(), vec![20]);
        assert_eq!(e1.recv_chunk(0, 1).unwrap(), vec![10]);
    }

    #[test]
    fn fifo_within_same_tag() {
        let (_hub, mut eps) = TransportHub::<u8>::new(2);
        let mut e1 = eps.pop().unwrap();
        let mut e0 = eps.pop().unwrap();
        for v in 0..4u8 {
            e0.send_chunk(1, 9, Chunk::from_vec(vec![v])).unwrap();
        }
        for v in 0..4u8 {
            assert_eq!(e1.recv_chunk(0, 9).unwrap(), vec![v]);
        }
    }

    #[test]
    fn recv_timeout_is_typed_error() {
        let (_hub, mut eps) = TransportHub::<f32>::new(2);
        let mut e1 = eps.remove(1);
        e1.set_timeout(Duration::from_millis(20));
        match e1.recv_chunk(0, 5) {
            Err(Error::RecvTimeout { src: 0, tag: 5, .. }) => {}
            other => panic!("expected RecvTimeout, got {other:?}"),
        }
    }

    #[test]
    fn send_to_bad_peer_rejected() {
        let (_hub, mut eps) = TransportHub::<f32>::new(2);
        let mut e0 = eps.remove(0);
        assert!(matches!(
            e0.send_chunk(5, 0, Chunk::from_vec(vec![])),
            Err(Error::PeerOutOfRange { peer: 5, size: 2 })
        ));
    }

    #[test]
    fn cross_thread_roundtrip() {
        let (_hub, mut eps) = TransportHub::<f64>::new(2);
        let mut e1 = eps.pop().unwrap();
        let mut e0 = eps.pop().unwrap();
        let t = std::thread::spawn(move || {
            let got = e1.recv_chunk(0, 3).unwrap();
            let doubled: Vec<f64> = got.iter().map(|x| x * 2.0).collect();
            e1.send_chunk(0, 4, Chunk::from_vec(doubled)).unwrap();
        });
        e0.send_chunk(1, 3, Chunk::from_vec(vec![1.5, 2.5])).unwrap();
        assert_eq!(e0.recv_chunk(1, 4).unwrap(), vec![3.0, 5.0]);
        t.join().unwrap();
    }

    #[test]
    fn posted_receive_moves_exclusive_and_counts_copies() {
        let (_hub, mut eps) = TransportHub::<f32>::new(2);
        let mut e1 = eps.pop().unwrap();
        let mut e0 = eps.pop().unwrap();

        // Exclusive message (sender moved its only reference): delivery is
        // a pointer move into the posted buffer.
        let msg = Chunk::from_vec(vec![1.0, 2.0]);
        let msg_id = msg.storage_id();
        e0.send_chunk(1, 1, msg).unwrap();
        let mut dest = Chunk::from_vec(vec![0.0; 2]);
        e1.recv_chunk_into(0, 1, &mut dest).unwrap();
        assert_eq!(dest.storage_id(), msg_id, "exclusive delivery must move");
        let t = e1.traffic();
        assert_eq!((t.moved_bytes, t.copied_bytes), (8, 0));

        // Shared message (sender keeps a live view): delivery copies into
        // the posted buffer and the copy is accounted.
        let big = Chunk::from_vec(vec![3.0, 4.0, 5.0, 6.0]);
        e0.send_chunk(1, 2, big.slice(1, 2)).unwrap();
        let mut dest = Chunk::from_vec(vec![0.0; 2]);
        let dest_id = dest.storage_id();
        e1.recv_chunk_into(0, 2, &mut dest).unwrap();
        assert_eq!(dest.storage_id(), dest_id, "shared delivery copies in place");
        assert_eq!(dest.as_slice(), &[4.0, 5.0]);
        let t = e1.traffic();
        assert_eq!((t.recvd_bytes, t.moved_bytes, t.copied_bytes), (16, 8, 8));
    }

    #[test]
    fn posted_combine_receive_is_copy_free() {
        let sum = crate::reduction::offload::native_combine::<f32>();
        let (_hub, mut eps) = TransportHub::<f32>::new(2);
        let mut e1 = eps.pop().unwrap();
        let mut e0 = eps.pop().unwrap();

        // Exclusive accumulator: combine folds in place, pointer stable.
        let input = Chunk::from_vec(vec![10.0, 20.0]);
        e0.send_chunk(1, 1, input.slice(0, 2)).unwrap();
        let mut acc = Chunk::from_vec(vec![1.0, 2.0]);
        let acc_id = acc.storage_id();
        e1.recv_chunk_combine_into(0, 1, &mut acc, &sum).unwrap();
        assert_eq!(acc.storage_id(), acc_id, "accumulator must fold in place");
        assert_eq!(acc.as_slice(), &[11.0, 22.0]);
        let t = e1.traffic();
        assert_eq!((t.moved_bytes, t.copied_bytes), (8, 0), "combine never copies");
    }

    #[test]
    fn shape_mismatch_is_typed_and_recoverable() {
        let (_hub, mut eps) = TransportHub::<f32>::new(2);
        let mut e1 = eps.pop().unwrap();
        let mut e0 = eps.pop().unwrap();
        e0.send_chunk(1, 3, Chunk::from_vec(vec![1.0, 2.0, 3.0])).unwrap();

        // Wrong-size posted buffer: typed error, nothing delivered...
        let mut small = Chunk::from_vec(vec![0.0; 2]);
        match e1.recv_chunk_into(0, 3, &mut small) {
            Err(Error::RecvShapeMismatch { src: 0, tag: 3, expected: 2, got: 3 }) => {}
            other => panic!("expected RecvShapeMismatch, got {other:?}"),
        }
        assert_eq!(small.as_slice(), &[0.0, 0.0], "posted buffer untouched");
        let t = e1.traffic();
        assert_eq!((t.recvd_msgs, t.recvd_bytes), (0, 0), "mismatch is not a receive");

        // ...and the message is still matchable by a correctly sized post.
        let mut right = Chunk::from_vec(vec![0.0; 3]);
        e1.recv_chunk_into(0, 3, &mut right).unwrap();
        assert_eq!(right.as_slice(), &[1.0, 2.0, 3.0]);
    }

    #[test]
    fn chunk_messages_are_zero_copy_across_threads() {
        // A sub-view sent to a peer thread arrives backed by the *same*
        // storage: no bytes moved through the transport.
        let (_hub, mut eps) = TransportHub::<f32>::new(2);
        let mut e1 = eps.pop().unwrap();
        let mut e0 = eps.pop().unwrap();
        let big = Chunk::from_vec((0..64).map(|i| i as f32).collect());
        let id = big.storage_id();
        let view = big.slice(16, 8);
        let t = std::thread::spawn(move || {
            let got = e1.recv_chunk(0, 1).unwrap();
            (got.storage_id(), got.to_vec())
        });
        e0.send_chunk(1, 1, view).unwrap();
        let (got_id, data) = t.join().unwrap();
        assert_eq!(got_id, id, "received chunk must share the sender's storage");
        assert_eq!(data, (16..24).map(|i| i as f32).collect::<Vec<_>>());
    }

    #[test]
    fn traffic_counts_bytes_and_messages() {
        let (_hub, mut eps) = TransportHub::<f32>::new(2);
        let mut e1 = eps.pop().unwrap();
        let mut e0 = eps.pop().unwrap();
        e0.send_chunk(1, 0, Chunk::from_vec(vec![1.0, 2.0, 3.0])).unwrap();
        let t = e0.traffic();
        assert_eq!((t.sent_msgs, t.sent_elems, t.sent_bytes), (1, 3, 12));
        assert_eq!((t.recvd_msgs, t.recvd_bytes), (0, 0));
        let _ = e1.recv_chunk(0, 0).unwrap();
        let t = e1.traffic();
        assert_eq!((t.recvd_msgs, t.recvd_bytes), (1, 12));
        // Reference handover to the caller is a move, never a copy.
        assert_eq!((t.moved_bytes, t.copied_bytes), (12, 0));
        assert_eq!(t.moved_bytes + t.copied_bytes, t.recvd_bytes);
    }

    #[test]
    fn lanes_are_independent_queues() {
        let (_hub, mut eps) = TransportHub::<f32>::new_with_lanes(2, 3);
        let mut e1 = eps.pop().unwrap();
        let mut e0 = eps.pop().unwrap();
        assert_eq!(e0.lane_count(), 3);
        // Same tag on every lane: no cross-delivery.
        for lane in 0..3 {
            e0.send_chunk_on(1, lane, 42, Chunk::from_vec(vec![lane as f32]))
                .unwrap();
        }
        for lane in (0..3).rev() {
            assert_eq!(e1.recv_chunk_on(lane, 0, 42).unwrap(), vec![lane as f32]);
        }
    }

    #[test]
    fn striped_combine_folds_every_stripe() {
        let sum = crate::reduction::offload::native_combine::<f32>();
        let (_hub, mut eps) = TransportHub::<f32>::new_with_lanes(2, 4);
        let mut e1 = eps.pop().unwrap();
        let mut e0 = eps.pop().unwrap();
        let tags = [10u64, 11, 12, 13];
        for (l, &tag) in tags.iter().enumerate() {
            e0.send_chunk_on(1, l, tag, Chunk::from_vec(vec![l as f32; 2]))
                .unwrap();
        }
        let mut dests: Vec<Chunk<f32>> =
            (0..4).map(|_| Chunk::from_vec(vec![100.0, 200.0])).collect();
        e1.recv_striped_combine_into(0, &tags, &mut dests, &sum).unwrap();
        for (l, d) in dests.iter().enumerate() {
            assert_eq!(d.as_slice(), &[100.0 + l as f32, 200.0 + l as f32]);
        }
        let t = e1.traffic();
        assert_eq!((t.recvd_msgs, t.copied_bytes), (4, 0), "striped combine never copies");
        let per_lane = e1.traffic_per_lane();
        assert_eq!(per_lane.len(), 4);
        assert!(per_lane.iter().all(|t| t.recvd_msgs == 1 && t.recvd_bytes == 8));
    }

    #[test]
    fn striped_recv_into_returns_lane_order() {
        let (_hub, mut eps) = TransportHub::<i32>::new_with_lanes(2, 2);
        let mut e1 = eps.pop().unwrap();
        let mut e0 = eps.pop().unwrap();
        // Post lane 1 first: delivery order must still follow lane index.
        e0.send_chunk_on(1, 1, 8, Chunk::from_vec(vec![222])).unwrap();
        e0.send_chunk_on(1, 0, 7, Chunk::from_vec(vec![111])).unwrap();
        let mut dests = vec![Chunk::from_vec(vec![0]), Chunk::from_vec(vec![0])];
        e1.recv_striped_into(0, &[7, 8], &mut dests).unwrap();
        assert_eq!(dests[0].as_slice(), &[111]);
        assert_eq!(dests[1].as_slice(), &[222]);
        // Per-lane send counters on the poster's side.
        let sent = e0.traffic_per_lane();
        assert_eq!(sent[0].sent_msgs, 1);
        assert_eq!(sent[1].sent_msgs, 1);
    }

    #[test]
    fn striped_timeout_is_typed_per_lane() {
        let (_hub, mut eps) = TransportHub::<f32>::new_with_lanes(2, 2);
        let mut e1 = eps.pop().unwrap();
        let mut e0 = eps.pop().unwrap();
        e1.set_timeout(Duration::from_millis(20));
        // Only lane 0 gets a message; lane 1 must time out.
        e0.send_chunk_on(1, 0, 5, Chunk::from_vec(vec![1.0])).unwrap();
        let mut dests = vec![Chunk::from_vec(vec![0.0]), Chunk::from_vec(vec![0.0])];
        match e1.recv_striped_into(0, &[5, 5], &mut dests) {
            Err(Error::RecvTimeout { src: 0, tag: 5, .. }) => {}
            other => panic!("expected RecvTimeout, got {other:?}"),
        }
    }

    #[test]
    fn stripe_count_validated() {
        let (_hub, mut eps) = TransportHub::<f32>::new_with_lanes(2, 2);
        let mut e1 = eps.remove(1);
        assert!(e1.recv_striped(0, &[]).is_err());
        assert!(e1.recv_striped(0, &[1, 2, 3]).is_err());
        let mut dests = vec![Chunk::from_vec(vec![0.0])];
        assert!(e1.recv_striped_into(0, &[1, 2], &mut dests).is_err());
    }

    #[test]
    fn single_lane_hub_has_no_workers() {
        let (_hub, eps) = TransportHub::<f32>::new(3);
        assert!(eps.iter().all(|e| e.lane_count() == 1));
        assert_eq!(eps[0].traffic_per_lane().len(), 1);
    }

    #[test]
    fn lock_traffic_survives_poisoned_lock() {
        // A panicking holder poisons the mutex; the counters are plain
        // numbers, so lock_traffic must hand back the partial counts
        // instead of cascading the panic (the PR 9 poison-recovery path).
        let t = Arc::new(Mutex::new(Traffic::default()));
        let t2 = Arc::clone(&t);
        let _ = std::thread::spawn(move || {
            let mut g = t2.lock().unwrap();
            g.sent_msgs = 7;
            panic!("poison the traffic lock while holding it");
        })
        .join();
        assert!(t.is_poisoned());
        assert_eq!(lock_traffic(&t).sent_msgs, 7, "partial counts readable");
    }

    #[test]
    fn set_timeout_reaches_parked_lane_jobs() {
        // Regression: lane jobs used to snapshot the timeout at dispatch,
        // so shortening it later never reached a parked worker.
        let (_hub, mut eps) = TransportHub::<f32>::new_with_lanes(2, 2);
        let _e1 = eps.pop().unwrap();
        let mut e0 = eps.pop().unwrap();
        e0.set_timeout(Duration::from_secs(300));
        e0.dispatch_lane(1, 1, 0xfeed, None, None).unwrap();
        // Let the worker park inside the pull with the long deadline.
        std::thread::sleep(Duration::from_millis(60));
        e0.set_timeout(Duration::from_millis(50));
        let t = Instant::now();
        let done = e0.collect_lane(1).unwrap();
        assert!(
            matches!(done.result, Err(Error::RecvTimeout { .. })),
            "expected RecvTimeout, got {:?}",
            done.result
        );
        assert!(
            t.elapsed() < Duration::from_secs(10),
            "parked job kept its old deadline: {:?}",
            t.elapsed()
        );
    }

    #[test]
    fn lane_worker_grace_miss_is_typed() {
        let (_hub, mut eps) = TransportHub::<f32>::new_with_lanes(2, 2);
        let _e1 = eps.pop().unwrap();
        let mut e0 = eps.pop().unwrap();
        e0.set_timeout(Duration::from_millis(40));
        e0.set_shutdown_grace(Duration::from_millis(80));
        // Stall the worker far past timeout + grace: the collect must give
        // up with a typed loss, not a generic transport teardown.
        e0.arm_faults(FaultPlan::new(vec![FaultSpec {
            rank: 0,
            peer: 1,
            lane: 1,
            op_seq: 0,
            action: FaultAction::StallWorker { ms: 5_000 },
        }]));
        let t = Instant::now();
        match e0.recv_chunk_on(1, 1, 9) {
            Err(Error::LaneWorkerLost { rank: 0, lane: 1, grace_ms: 80 }) => {}
            other => panic!("expected LaneWorkerLost, got {other:?}"),
        }
        assert!(
            t.elapsed() < Duration::from_secs(4),
            "grace window not honored: {:?}",
            t.elapsed()
        );
    }

    #[test]
    fn abort_broadcast_interrupts_parked_recv_immediately() {
        let (_hub, mut eps) = TransportHub::<f32>::new(2);
        let mut e1 = eps.pop().unwrap();
        let mut e0 = eps.pop().unwrap();
        let tok = AbortToken::new();
        e0.set_abort_token(tok.clone());
        e1.set_abort_token(tok.clone());
        // e1 parks with the default 60 s timeout; the poison must wake it
        // long before that sleeps out.
        let t = std::thread::spawn(move || {
            let start = Instant::now();
            (e1.recv_chunk(0, 5), start.elapsed())
        });
        std::thread::sleep(Duration::from_millis(50));
        e0.broadcast_abort(3, "injected failure");
        let (res, waited) = t.join().unwrap();
        match res {
            Err(Error::CollectiveAborted { origin_rank: 0, op_seq: 3, .. }) => {}
            other => panic!("expected CollectiveAborted, got {other:?}"),
        }
        assert!(waited < Duration::from_secs(5), "detection took {waited:?}");
        assert!(tok.is_tripped());
    }

    #[test]
    fn stale_epoch_poison_is_discarded() {
        let (_hub, mut eps) = TransportHub::<f32>::new(2);
        let mut e1 = eps.pop().unwrap();
        let mut e0 = eps.pop().unwrap();
        e0.broadcast_abort(0, "previous-epoch failure"); // epoch-0 poison
        e1.set_epoch(1); // e1 already recovered past it
        e1.set_timeout(Duration::from_millis(50));
        match e1.recv_chunk(0, 5) {
            Err(Error::RecvTimeout { .. }) => {}
            other => panic!("stale poison must be discarded, got {other:?}"),
        }
    }

    #[test]
    fn injected_drop_surfaces_as_peer_timeout() {
        let (_hub, mut eps) = TransportHub::<f32>::new(2);
        let mut e1 = eps.pop().unwrap();
        let mut e0 = eps.pop().unwrap();
        e0.arm_faults(FaultPlan::new(vec![FaultSpec {
            rank: 0,
            peer: 1,
            lane: 0,
            op_seq: 0,
            action: FaultAction::Drop,
        }]));
        e0.send_chunk(1, 7, Chunk::from_vec(vec![1.0])).unwrap();
        assert_eq!(e0.traffic().sent_msgs, 1, "drop is counted as sent");
        e1.set_timeout(Duration::from_millis(40));
        assert!(matches!(e1.recv_chunk(0, 7), Err(Error::RecvTimeout { .. })));
        // One-shot: the next send goes through.
        e0.send_chunk(1, 8, Chunk::from_vec(vec![2.0])).unwrap();
        assert_eq!(e1.recv_chunk(0, 8).unwrap(), vec![2.0]);
    }

    #[test]
    fn injected_corrupt_is_caught_by_shape_check() {
        let (_hub, mut eps) = TransportHub::<f32>::new(2);
        let mut e1 = eps.pop().unwrap();
        let mut e0 = eps.pop().unwrap();
        e0.arm_faults(FaultPlan::new(vec![FaultSpec {
            rank: 0,
            peer: 1,
            lane: 0,
            op_seq: 0,
            action: FaultAction::Corrupt,
        }]));
        e0.send_chunk(1, 7, Chunk::from_vec(vec![1.0, 2.0, 3.0])).unwrap();
        let mut dest = Chunk::from_vec(vec![0.0; 3]);
        match e1.recv_chunk_into(0, 7, &mut dest) {
            Err(Error::RecvShapeMismatch { expected: 3, got: 2, .. }) => {}
            other => panic!("expected RecvShapeMismatch, got {other:?}"),
        }
    }

    #[test]
    fn injected_duplicate_never_matches_a_later_tag() {
        let (_hub, mut eps) = TransportHub::<i32>::new(2);
        let mut e1 = eps.pop().unwrap();
        let mut e0 = eps.pop().unwrap();
        e0.arm_faults(FaultPlan::new(vec![FaultSpec {
            rank: 0,
            peer: 1,
            lane: 0,
            op_seq: 0,
            action: FaultAction::Duplicate,
        }]));
        e0.send_chunk(1, 7, Chunk::from_vec(vec![11])).unwrap();
        assert_eq!(e1.recv_chunk(0, 7).unwrap(), vec![11]);
        // The duplicate is stashed under its own (src, tag) and can never
        // match a different tag...
        e1.set_timeout(Duration::from_millis(40));
        assert!(matches!(e1.recv_chunk(0, 8), Err(Error::RecvTimeout { .. })));
        // ...and recovery's drain reclaims it.
        e1.drain().unwrap();
        assert!(e1.lane0.pending.is_empty());
    }

    #[test]
    fn injected_kill_rank_latches() {
        let (_hub, mut eps) = TransportHub::<f32>::new(2);
        let _e1 = eps.pop().unwrap();
        let mut e0 = eps.pop().unwrap();
        e0.arm_faults(FaultPlan::new(vec![FaultSpec {
            rank: 0,
            peer: 1,
            lane: 0,
            op_seq: 0,
            action: FaultAction::KillRank,
        }]));
        match e0.send_chunk(1, 7, Chunk::from_vec(vec![1.0])) {
            Err(Error::CollectiveAborted { origin_rank: 0, .. }) => {}
            other => panic!("expected CollectiveAborted, got {other:?}"),
        }
        // Dead is dead: receives fail too, without touching the mailbox.
        assert!(matches!(e0.recv_chunk(1, 9), Err(Error::CollectiveAborted { .. })));
        assert_eq!(e0.traffic().sent_msgs, 0, "a killed rank posts nothing");
    }

    #[test]
    fn drain_clears_all_lanes() {
        let (_hub, mut eps) = TransportHub::<f32>::new_with_lanes(2, 2);
        let mut e1 = eps.pop().unwrap();
        let mut e0 = eps.pop().unwrap();
        e0.send_chunk_on(1, 0, 1, Chunk::from_vec(vec![1.0])).unwrap();
        e0.send_chunk_on(1, 1, 2, Chunk::from_vec(vec![2.0])).unwrap();
        e1.drain().unwrap();
        e1.set_timeout(Duration::from_millis(40));
        assert!(matches!(e1.recv_chunk(0, 1), Err(Error::RecvTimeout { .. })));
        assert!(matches!(
            e1.recv_chunk_on(1, 0, 2),
            Err(Error::RecvTimeout { .. })
        ));
    }

    #[test]
    fn fault_plan_json_round_trip_and_determinism() {
        let plan = FaultPlan::seeded(42, 8, 4, 12);
        assert_eq!(plan, FaultPlan::seeded(42, 8, 4, 12), "seeded plans replay");
        assert_ne!(plan, FaultPlan::seeded(43, 8, 4, 12));
        let v = plan.to_value();
        assert_eq!(FaultPlan::from_value(&v).unwrap(), plan);
    }

    #[test]
    fn endpoint_teardown_is_prompt_with_stuck_lane_jobs() {
        // Two lane jobs that will never match a message: one parks the
        // worker mid-pull, one sits queued behind it. Teardown must not
        // wait out the 60 s receive timeout (let alone the padded collect
        // wait) — the stop flag interrupts the pull within one poll slice
        // and drains the queue.
        let (_hub, mut eps) = TransportHub::<f32>::new_with_lanes(2, 2);
        let e1 = eps.pop().unwrap();
        let mut e0 = eps.pop().unwrap();
        e0.dispatch_lane(1, 1, 0xdead, None, None).unwrap();
        e0.dispatch_lane(1, 1, 0xbeef, None, None).unwrap();
        // Let the worker actually park inside the first pull.
        std::thread::sleep(Duration::from_millis(50));
        let t = Instant::now();
        drop(e0);
        assert!(
            t.elapsed() < Duration::from_secs(10),
            "teardown took {:?} with stuck lane jobs",
            t.elapsed()
        );
        drop(e1);
    }
}
