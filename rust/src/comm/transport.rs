//! In-process transport: per-(rank, lane) mailboxes with (source, tag)
//! matching.
//!
//! Each rank owns an [`Endpoint`]: one MPSC receiver (its mailbox) per
//! **lane** plus cloned senders to every peer lane. Messages are matched
//! MPI-style on `(src, tag)`; out-of-order arrivals are stashed in a
//! pending map. FIFO is preserved per `(src, tag)` pair because the
//! underlying channel is FIFO per sender and stashing appends in arrival
//! order.
//!
//! The message payload is a [`Chunk`] — an Arc-backed shared buffer view —
//! so posting a message moves a reference, never the bytes. A rank that
//! forwards a received chunk (ring/hierarchical all-gather) or sends a
//! sub-view of its input (recursive doubling, scatter) performs zero
//! copies end to end.
//!
//! ## Lanes
//!
//! A hub built with [`TransportHub::new_with_lanes`] gives every rank pair
//! `lanes` independent queues, modeling the multiple NIC rails a node can
//! drive at once (NCCL channels / HiCCL rail striping). Lane 0 is served
//! inline by the owning rank thread — `lanes = 1` is byte-for-byte the old
//! single-queue transport. Each lane ≥ 1 is served by a dedicated **lane
//! worker thread** owned by the endpoint: the striped receive family
//! ([`Endpoint::recv_striped_combine_into`] and friends) fans one posted
//! buffer per lane out to the workers, so the per-stripe `accept` /
//! `accept_combine` (the memcpy/fold work of a collective step) runs on
//! `lanes` threads concurrently while lane 0's stripe is handled on the
//! calling thread. Workers are long-lived — spawned once per endpoint, fed
//! over a job queue — so the per-step cost is a channel round-trip, not a
//! thread spawn.
//!
//! Traffic accounting is **per lane** ([`Endpoint::traffic_per_lane`]):
//! sends are counted by the posting thread into the destination lane's
//! counters, receives by whichever thread completes the delivery.
//! [`Endpoint::traffic`] returns the lane sum, so single-lane callers see
//! the exact counters they always did.

use std::collections::{HashMap, VecDeque};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{self, Receiver, RecvTimeoutError, Sender};
use std::sync::{Arc, Mutex, MutexGuard, PoisonError};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::error::{Error, Result};
use crate::reduction::offload::Combiner;

use super::chunk::Chunk;

/// Default receive timeout — generous for tests on loaded machines while
/// still converting deadlocks into typed errors instead of hangs.
pub const DEFAULT_RECV_TIMEOUT: Duration = Duration::from_secs(60);

/// How long a lane worker sleeps per wait slice once a shutdown flag is
/// attached to its pull: endpoint teardown is bounded by this, not by the
/// full receive timeout a parked job still has remaining.
const LANE_SHUTDOWN_POLL: Duration = Duration::from_millis(25);

/// Lock a lane traffic counter, surviving poisoning. The counters are
/// plain numbers: a panicked sibling thread cannot leave them in a state
/// worth cascading the panic for, and the partial counts are still the
/// best available answer during teardown.
fn lock_traffic(t: &Mutex<Traffic>) -> MutexGuard<'_, Traffic> {
    t.lock().unwrap_or_else(PoisonError::into_inner)
}

struct Msg<T> {
    src: usize,
    tag: u64,
    data: Chunk<T>,
}

/// Monotonic per-endpoint traffic counters (messages, elements, bytes).
///
/// Bytes are exact: `elements × size_of::<T>()`, which for the data-plane
/// element types equals [`crate::reduction::Elem::SIZE`]. The bench harness
/// and the launcher's schedule-equivalence guard consume these. With a
/// multi-lane endpoint one `Traffic` exists per lane; see
/// [`Endpoint::traffic_per_lane`].
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct Traffic {
    /// Messages posted by this endpoint.
    pub sent_msgs: u64,
    /// Elements posted by this endpoint.
    pub sent_elems: u64,
    /// Bytes posted by this endpoint.
    pub sent_bytes: u64,
    /// Messages received (matched) by this endpoint.
    pub recvd_msgs: u64,
    /// Bytes received (matched) by this endpoint.
    pub recvd_bytes: u64,
    /// Received bytes delivered by reference move or in-place combine —
    /// no verbatim buffer copy on the receive path.
    pub moved_bytes: u64,
    /// Received bytes that had to be copied into caller storage (a shared
    /// incoming view delivered into a posted buffer). The reduce-path
    /// smoke guard asserts this stays zero. Invariant:
    /// `moved_bytes + copied_bytes == recvd_bytes`.
    pub copied_bytes: u64,
}

impl Traffic {
    /// Field-wise sum — aggregates per-lane counters into endpoint totals.
    pub fn merged(self, o: Traffic) -> Traffic {
        Traffic {
            sent_msgs: self.sent_msgs + o.sent_msgs,
            sent_elems: self.sent_elems + o.sent_elems,
            sent_bytes: self.sent_bytes + o.sent_bytes,
            recvd_msgs: self.recvd_msgs + o.recvd_msgs,
            recvd_bytes: self.recvd_bytes + o.recvd_bytes,
            moved_bytes: self.moved_bytes + o.moved_bytes,
            copied_bytes: self.copied_bytes + o.copied_bytes,
        }
    }

    fn count_send<T>(&mut self, elems: usize) {
        self.sent_msgs += 1;
        self.sent_elems += elems as u64;
        self.sent_bytes += (elems * std::mem::size_of::<T>()) as u64;
    }

    fn count_recv<T>(&mut self, elems: usize, copied_elems: usize) {
        let bytes = |e: usize| (e * std::mem::size_of::<T>()) as u64;
        self.recvd_msgs += 1;
        self.recvd_bytes += bytes(elems);
        self.copied_bytes += bytes(copied_elems);
        self.moved_bytes += bytes(elems - copied_elems);
    }
}

/// One lane's matching state: its mailbox receiver plus the out-of-order
/// stash. Lane 0's mailbox lives inside the endpoint; every other lane's
/// lives inside that lane's worker thread.
struct Mailbox<T> {
    rx: Receiver<Msg<T>>,
    pending: HashMap<(usize, u64), VecDeque<Chunk<T>>>,
}

impl<T> Mailbox<T> {
    fn new(rx: Receiver<Msg<T>>) -> Self {
        Self {
            rx,
            pending: HashMap::new(),
        }
    }

    /// Matched pull without traffic accounting (counting happens once the
    /// delivery is classified as moved or copied). `rank` is only for
    /// error construction.
    fn pull(&mut self, rank: usize, from: usize, tag: u64, timeout: Duration) -> Result<Chunk<T>> {
        self.pull_with_cancel(rank, from, tag, timeout, None)
    }

    /// [`Mailbox::pull`] that a shutdown flag can interrupt: with `cancel`
    /// attached the wait is sliced into [`LANE_SHUTDOWN_POLL`] pieces and
    /// the flag is checked between slices, so a parked lane worker notices
    /// endpoint teardown within one slice instead of sleeping out the
    /// remaining receive timeout. Cancellation surfaces as
    /// [`Error::TransportClosed`]. With `cancel == None` the behavior is
    /// byte-for-byte the plain pull.
    fn pull_with_cancel(
        &mut self,
        rank: usize,
        from: usize,
        tag: u64,
        timeout: Duration,
        cancel: Option<&AtomicBool>,
    ) -> Result<Chunk<T>> {
        let key = (from, tag);
        if let Some(q) = self.pending.get_mut(&key) {
            if let Some(data) = q.pop_front() {
                return Ok(data);
            }
        }
        let deadline = Instant::now() + timeout;
        loop {
            if cancel.is_some_and(|c| c.load(Ordering::Relaxed)) {
                return Err(Error::TransportClosed { rank });
            }
            let remaining = deadline.saturating_duration_since(Instant::now());
            let wait = if cancel.is_some() {
                remaining.min(LANE_SHUTDOWN_POLL)
            } else {
                remaining
            };
            match self.rx.recv_timeout(wait) {
                Ok(msg) => {
                    if msg.src == from && msg.tag == tag {
                        return Ok(msg.data);
                    }
                    self.pending
                        .entry((msg.src, msg.tag))
                        .or_default()
                        .push_back(msg.data);
                }
                Err(RecvTimeoutError::Timeout) => {
                    if Instant::now() >= deadline {
                        return Err(Error::RecvTimeout {
                            src: from,
                            tag,
                            ms: timeout.as_millis() as u64,
                        });
                    }
                }
                Err(RecvTimeoutError::Disconnected) => {
                    return Err(Error::TransportClosed { rank })
                }
            }
        }
    }

    /// [`Mailbox::pull`] plus the posted-buffer shape check; on mismatch
    /// the message is requeued at the front (FIFO order preserved — it was
    /// taken from the front) and the error is recoverable.
    fn checked_pull(
        &mut self,
        rank: usize,
        from: usize,
        tag: u64,
        expected: usize,
        timeout: Duration,
    ) -> Result<Chunk<T>> {
        self.checked_pull_with_cancel(rank, from, tag, expected, timeout, None)
    }

    /// [`Mailbox::checked_pull`] over the cancellable pull — see
    /// [`Mailbox::pull_with_cancel`].
    fn checked_pull_with_cancel(
        &mut self,
        rank: usize,
        from: usize,
        tag: u64,
        expected: usize,
        timeout: Duration,
        cancel: Option<&AtomicBool>,
    ) -> Result<Chunk<T>> {
        let data = self.pull_with_cancel(rank, from, tag, timeout, cancel)?;
        if data.len() != expected {
            let got = data.len();
            self.pending.entry((from, tag)).or_default().push_front(data);
            return Err(Error::RecvShapeMismatch {
                src: from,
                tag,
                expected,
                got,
            });
        }
        Ok(data)
    }
}

/// A receive request shipped to a lane worker. `dest: None` is a plain
/// matched pull (the chunk reference comes back); `Some` is a posted
/// receive, folded through `combiner` when one is attached.
struct LaneJob<T> {
    from: usize,
    tag: u64,
    timeout: Duration,
    dest: Option<Chunk<T>>,
    combiner: Option<Combiner<T>>,
}

/// A lane worker's answer: the delivered (or returned-on-error) chunk plus
/// the delivery result. On error a posted `dest` comes back untouched.
struct LaneDone<T> {
    chunk: Option<Chunk<T>>,
    result: Result<()>,
}

/// Owner-side handle to one lane worker thread (lanes ≥ 1).
struct LaneWorker<T> {
    job_tx: Sender<LaneJob<T>>,
    done_rx: Receiver<LaneDone<T>>,
    traffic: Arc<Mutex<Traffic>>,
    /// Shutdown flag shared with the worker thread: set by the endpoint's
    /// `Drop` before the job queue closes so a mid-pull worker bails within
    /// one [`LANE_SHUTDOWN_POLL`] slice and queued jobs drain immediately.
    stop: Arc<AtomicBool>,
    handle: Option<JoinHandle<()>>,
}

/// Cloneable handle with senders to every `(rank, lane)` mailbox.
pub struct TransportHub<T> {
    /// Flattened `[rank * lanes + lane]`.
    senders: Vec<Sender<Msg<T>>>,
    lanes: usize,
}

impl<T> Clone for TransportHub<T> {
    fn clone(&self) -> Self {
        Self {
            senders: self.senders.clone(),
            lanes: self.lanes,
        }
    }
}

impl<T: Send + Sync + 'static> TransportHub<T> {
    /// Build a single-lane hub + one endpoint per rank — byte-for-byte the
    /// pre-lane transport (no worker threads are spawned).
    pub fn new(size: usize) -> (Self, Vec<Endpoint<T>>) {
        let (hub, rxs) = Self::channels(size, 1);
        let endpoints = rxs
            .into_iter()
            .enumerate()
            .map(|(rank, mut lane_rxs)| {
                Endpoint::assemble(rank, hub.clone(), lane_rxs.pop().expect("lane 0"), Vec::new())
            })
            .collect();
        (hub, endpoints)
    }

    fn channels(size: usize, lanes: usize) -> (Self, Vec<Vec<Receiver<Msg<T>>>>) {
        assert!(lanes >= 1, "transport needs at least one lane");
        let mut senders = Vec::with_capacity(size * lanes);
        let mut receivers: Vec<Vec<Receiver<Msg<T>>>> = Vec::with_capacity(size);
        for _ in 0..size {
            let mut lane_rxs = Vec::with_capacity(lanes);
            for _ in 0..lanes {
                let (tx, rx) = mpsc::channel();
                senders.push(tx);
                lane_rxs.push(rx);
            }
            receivers.push(lane_rxs);
        }
        (Self { senders, lanes }, receivers)
    }

    fn size(&self) -> usize {
        self.senders.len() / self.lanes
    }

    fn sender(&self, to: usize, lane: usize) -> &Sender<Msg<T>> {
        &self.senders[to * self.lanes + lane]
    }
}

impl<T: Send + Sync + Clone + 'static> TransportHub<T> {
    /// Build a hub with `lanes` independent queues per rank pair. Each
    /// endpoint owns `lanes - 1` long-lived lane worker threads (lane 0 is
    /// served inline by the rank thread), so striped receives fold their
    /// stripes in parallel.
    pub fn new_with_lanes(size: usize, lanes: usize) -> (Self, Vec<Endpoint<T>>) {
        let (hub, rxs) = Self::channels(size, lanes);
        let endpoints = rxs
            .into_iter()
            .enumerate()
            .map(|(rank, lane_rxs)| {
                let mut it = lane_rxs.into_iter();
                let lane0 = it.next().expect("lane 0");
                let workers = it
                    .enumerate()
                    .map(|(i, rx)| spawn_lane_worker(rank, i + 1, rx))
                    .collect();
                Endpoint::assemble(rank, hub.clone(), lane0, workers)
            })
            .collect();
        (hub, endpoints)
    }
}

/// Spawn the worker thread serving lane `lane` of rank `rank`.
fn spawn_lane_worker<T: Send + Sync + Clone + 'static>(
    rank: usize,
    lane: usize,
    rx: Receiver<Msg<T>>,
) -> LaneWorker<T> {
    let (job_tx, job_rx) = mpsc::channel::<LaneJob<T>>();
    let (done_tx, done_rx) = mpsc::channel::<LaneDone<T>>();
    let traffic = Arc::new(Mutex::new(Traffic::default()));
    let shared = Arc::clone(&traffic);
    let stop = Arc::new(AtomicBool::new(false));
    let stop_flag = Arc::clone(&stop);
    let handle = std::thread::Builder::new()
        .name(format!("pccl-lane-{rank}.{lane}"))
        .spawn(move || {
            let mut mailbox = Mailbox::new(rx);
            while let Ok(job) = job_rx.recv() {
                // Once teardown starts, drain queued jobs without serving
                // them: their pulls would only time out against a dying
                // transport and stall the endpoint's join.
                let done = if stop_flag.load(Ordering::Relaxed) {
                    LaneDone {
                        chunk: job.dest,
                        result: Err(Error::TransportClosed { rank }),
                    }
                } else {
                    serve_lane_job(&mut mailbox, &shared, rank, &stop_flag, job)
                };
                if done_tx.send(done).is_err() {
                    return; // endpoint dropped
                }
            }
        })
        .expect("spawn lane worker thread");
    LaneWorker {
        job_tx,
        done_rx,
        traffic,
        stop,
        handle: Some(handle),
    }
}

/// One receive on a worker lane: pull, deliver per the job's mode, count.
/// The pulls watch `stop` so endpoint teardown interrupts a parked wait.
fn serve_lane_job<T: Send + Sync + Clone + 'static>(
    mailbox: &mut Mailbox<T>,
    traffic: &Mutex<Traffic>,
    rank: usize,
    stop: &AtomicBool,
    job: LaneJob<T>,
) -> LaneDone<T> {
    match job.dest {
        None => match mailbox.pull_with_cancel(rank, job.from, job.tag, job.timeout, Some(stop)) {
            Ok(data) => {
                lock_traffic(traffic).count_recv::<T>(data.len(), 0);
                LaneDone {
                    chunk: Some(data),
                    result: Ok(()),
                }
            }
            Err(e) => LaneDone {
                chunk: None,
                result: Err(e),
            },
        },
        Some(mut dest) => {
            match mailbox.checked_pull_with_cancel(
                rank,
                job.from,
                job.tag,
                dest.len(),
                job.timeout,
                Some(stop),
            ) {
                Ok(data) => {
                    let len = data.len();
                    let copied = match &job.combiner {
                        Some(comb) => {
                            dest.accept_combine(data, comb);
                            0
                        }
                        None => dest.accept(data),
                    };
                    lock_traffic(traffic).count_recv::<T>(len, copied);
                    LaneDone {
                        chunk: Some(dest),
                        result: Ok(()),
                    }
                }
                Err(e) => LaneDone {
                    chunk: Some(dest),
                    result: Err(e),
                },
            }
        }
    }
}

/// One rank's connection to the transport. Not `Clone`: exactly one owner
/// (the rank thread) may receive.
pub struct Endpoint<T> {
    rank: usize,
    hub: TransportHub<T>,
    lane0: Mailbox<T>,
    workers: Vec<LaneWorker<T>>,
    timeout: Duration,
    traffic: Traffic,
}

impl<T: Send + Sync + 'static> Endpoint<T> {
    fn assemble(
        rank: usize,
        hub: TransportHub<T>,
        lane0_rx: Receiver<Msg<T>>,
        workers: Vec<LaneWorker<T>>,
    ) -> Self {
        Self {
            rank,
            hub,
            lane0: Mailbox::new(lane0_rx),
            workers,
            timeout: DEFAULT_RECV_TIMEOUT,
            traffic: Traffic::default(),
        }
    }

    pub fn rank(&self) -> usize {
        self.rank
    }

    pub fn size(&self) -> usize {
        self.hub.size()
    }

    /// Number of independent lanes per rank pair (≥ 1; lane 0 always exists).
    pub fn lane_count(&self) -> usize {
        1 + self.workers.len()
    }

    /// Override the receive timeout (failure-injection tests use short ones).
    pub fn set_timeout(&mut self, timeout: Duration) {
        self.timeout = timeout;
    }

    /// Traffic counters so far, summed over all lanes (monotonic).
    pub fn traffic(&self) -> Traffic {
        self.traffic_per_lane()
            .into_iter()
            .fold(Traffic::default(), Traffic::merged)
    }

    /// Per-lane traffic counters (index = lane id). Lane 0 is the inline
    /// lane; the rest are worker lanes.
    pub fn traffic_per_lane(&self) -> Vec<Traffic> {
        let mut out = Vec::with_capacity(self.lane_count());
        out.push(self.traffic);
        for w in &self.workers {
            out.push(*lock_traffic(&w.traffic));
        }
        out
    }

    /// Post `chunk` to `to`'s lane-0 mailbox — a reference move, never a
    /// byte copy. Non-blocking (unbounded channel — the collectives are
    /// self-throttling, at most one outstanding message per peer per step).
    pub fn send_chunk(&mut self, to: usize, tag: u64, chunk: Chunk<T>) -> Result<()> {
        self.send_chunk_on(to, 0, tag, chunk)
    }

    /// Post `chunk` to `to`'s mailbox on `lane`. Counting lands in this
    /// endpoint's per-lane send counters.
    pub fn send_chunk_on(&mut self, to: usize, lane: usize, tag: u64, chunk: Chunk<T>) -> Result<()> {
        if to >= self.hub.size() {
            return Err(Error::PeerOutOfRange {
                peer: to,
                size: self.hub.size(),
            });
        }
        if lane >= self.lane_count() {
            return Err(Error::PeerOutOfRange {
                peer: lane,
                size: self.lane_count(),
            });
        }
        if lane == 0 {
            self.traffic.count_send::<T>(chunk.len());
        } else {
            lock_traffic(&self.workers[lane - 1].traffic).count_send::<T>(chunk.len());
        }
        self.hub
            .sender(to, lane)
            .send(Msg {
                src: self.rank,
                tag,
                data: chunk,
            })
            .map_err(|_| Error::TransportClosed { rank: self.rank })
    }

    /// Blocking matched receive of a chunk from `(from, tag)` on lane 0 —
    /// the caller takes the delivered reference, so the whole message
    /// counts as moved.
    pub fn recv_chunk(&mut self, from: usize, tag: u64) -> Result<Chunk<T>> {
        let data = self.lane0.pull(self.rank, from, tag, self.timeout)?;
        self.traffic.count_recv::<T>(data.len(), 0);
        Ok(data)
    }

    /// Blocking matched receive on an explicit lane. Lanes ≥ 1 round-trip
    /// through that lane's worker thread (its mailbox lives there).
    pub fn recv_chunk_on(&mut self, lane: usize, from: usize, tag: u64) -> Result<Chunk<T>> {
        if lane == 0 {
            return self.recv_chunk(from, tag);
        }
        self.dispatch_lane(lane, from, tag, None, None)?;
        let done = self.collect_lane(lane)?;
        done.result?;
        done.chunk.ok_or(Error::TransportClosed { rank: self.rank })
    }

    /// Posted receive: deliver the matched chunk into `dest`, preferring a
    /// reference move over a copy (see [`Chunk::accept`]).
    ///
    /// If the incoming chunk's length differs from `dest.len()` the message
    /// is pushed back onto the front of the pending queue (so a later,
    /// correctly-sized receive can still match it) and a typed
    /// [`Error::RecvShapeMismatch`] is returned.
    pub fn recv_chunk_into(&mut self, from: usize, tag: u64, dest: &mut Chunk<T>) -> Result<()>
    where
        T: Clone,
    {
        let data = self
            .lane0
            .checked_pull(self.rank, from, tag, dest.len(), self.timeout)?;
        let len = data.len();
        let copied = dest.accept(data);
        self.traffic.count_recv::<T>(len, copied);
        Ok(())
    }

    /// Posted receive fused with a reduction: after the call `dest` holds
    /// `dest ⊕ incoming` with zero verbatim copies (see
    /// [`Chunk::accept_combine`] for the three delivery cases). Shape
    /// mismatches behave as in [`Endpoint::recv_chunk_into`].
    pub fn recv_chunk_combine_into(
        &mut self,
        from: usize,
        tag: u64,
        dest: &mut Chunk<T>,
        combiner: &Combiner<T>,
    ) -> Result<()>
    where
        T: Clone,
    {
        let data = self
            .lane0
            .checked_pull(self.rank, from, tag, dest.len(), self.timeout)?;
        let len = data.len();
        dest.accept_combine(data, combiner);
        self.traffic.count_recv::<T>(len, 0);
        Ok(())
    }

    fn dispatch_lane(
        &mut self,
        lane: usize,
        from: usize,
        tag: u64,
        dest: Option<Chunk<T>>,
        combiner: Option<Combiner<T>>,
    ) -> Result<()> {
        let w = self
            .workers
            .get(lane - 1)
            .ok_or(Error::PeerOutOfRange {
                peer: lane,
                size: self.lane_count(),
            })?;
        w.job_tx
            .send(LaneJob {
                from,
                tag,
                timeout: self.timeout,
                dest,
                combiner,
            })
            .map_err(|_| Error::TransportClosed { rank: self.rank })
    }

    fn collect_lane(&mut self, lane: usize) -> Result<LaneDone<T>> {
        // Workers answer every job exactly once; a generous wait beyond the
        // job's own recv timeout means a missing answer is a dead worker.
        self.workers
            .get(lane - 1)
            .ok_or(Error::PeerOutOfRange {
                peer: lane,
                size: self.lane_count(),
            })?
            .done_rx
            .recv_timeout(self.timeout + Duration::from_secs(30))
            .map_err(|_| Error::TransportClosed { rank: self.rank })
    }

    /// Posted receive on an explicit lane (see [`Endpoint::recv_chunk_into`]).
    pub fn recv_chunk_into_on(
        &mut self,
        lane: usize,
        from: usize,
        tag: u64,
        dest: &mut Chunk<T>,
    ) -> Result<()>
    where
        T: Clone,
    {
        if lane == 0 {
            return self.recv_chunk_into(from, tag, dest);
        }
        let posted = std::mem::replace(dest, Chunk::empty());
        self.dispatch_lane(lane, from, tag, Some(posted), None)?;
        let done = self.collect_lane(lane)?;
        if let Some(chunk) = done.chunk {
            *dest = chunk;
        }
        done.result
    }

    /// Posted combining receive on an explicit lane (see
    /// [`Endpoint::recv_chunk_combine_into`]).
    pub fn recv_chunk_combine_into_on(
        &mut self,
        lane: usize,
        from: usize,
        tag: u64,
        dest: &mut Chunk<T>,
        combiner: &Combiner<T>,
    ) -> Result<()>
    where
        T: Clone,
    {
        if lane == 0 {
            return self.recv_chunk_combine_into(from, tag, dest, combiner);
        }
        let posted = std::mem::replace(dest, Chunk::empty());
        self.dispatch_lane(lane, from, tag, Some(posted), Some(combiner.clone()))?;
        let done = self.collect_lane(lane)?;
        if let Some(chunk) = done.chunk {
            *dest = chunk;
        }
        done.result
    }

    /// Striped matched receive: pull stripe `l` from `(from, tags[l])` on
    /// lane `l`. Stripes on worker lanes are pulled concurrently; the
    /// returned chunks are in lane order. `tags.len()` must be ≤
    /// [`Endpoint::lane_count`].
    pub fn recv_striped(&mut self, from: usize, tags: &[u64]) -> Result<Vec<Chunk<T>>> {
        let k = self.check_stripes(tags.len())?;
        for (l, &tag) in tags.iter().enumerate().skip(1) {
            self.dispatch_lane(l, from, tag, None, None)?;
        }
        let lane0 = self.lane0.pull(self.rank, from, tags[0], self.timeout);
        if let Ok(data) = &lane0 {
            self.traffic.count_recv::<T>(data.len(), 0);
        }
        let mut out: Vec<Option<Chunk<T>>> = Vec::with_capacity(k);
        out.push(lane0.as_ref().ok().cloned());
        let mut first_err: Option<Error> = lane0.err();
        for l in 1..k {
            match self.collect_lane(l) {
                Ok(done) => {
                    if let Err(e) = done.result {
                        first_err.get_or_insert(e);
                        out.push(None);
                    } else {
                        out.push(done.chunk);
                    }
                }
                Err(e) => {
                    first_err.get_or_insert(e);
                    out.push(None);
                }
            }
        }
        match first_err {
            Some(e) => Err(e),
            None => Ok(out.into_iter().map(|c| c.expect("stripe delivered")).collect()),
        }
    }

    /// Striped posted receive: deliver stripe `l` from `(from, tags[l])`
    /// on lane `l` into `dests[l]`. Worker-lane stripes are delivered
    /// concurrently with lane 0's. On error, already-delivered stripes
    /// keep their payload and the rest come back untouched (the whole
    /// collective op is abandoned anyway).
    pub fn recv_striped_into(
        &mut self,
        from: usize,
        tags: &[u64],
        dests: &mut [Chunk<T>],
    ) -> Result<()>
    where
        T: Clone,
    {
        self.striped_delivery(from, tags, dests, None)
    }

    /// Striped posted receive fused with a reduction — the lane-parallel
    /// combine primitive. Stripe `l` is folded into `dests[l]` via
    /// [`Chunk::accept_combine`] on lane `l`'s worker thread (lane 0 on the
    /// calling thread), so the fold work of one collective step runs on
    /// `tags.len()` threads at once.
    pub fn recv_striped_combine_into(
        &mut self,
        from: usize,
        tags: &[u64],
        dests: &mut [Chunk<T>],
        combiner: &Combiner<T>,
    ) -> Result<()>
    where
        T: Clone,
    {
        self.striped_delivery(from, tags, dests, Some(combiner))
    }

    fn striped_delivery(
        &mut self,
        from: usize,
        tags: &[u64],
        dests: &mut [Chunk<T>],
        combiner: Option<&Combiner<T>>,
    ) -> Result<()>
    where
        T: Clone,
    {
        let k = self.check_stripes(tags.len())?;
        if dests.len() != k {
            return Err(Error::BadBufferSize {
                len: dests.len(),
                size: k,
                why: "striped receive needs one posted buffer per stripe tag",
            });
        }
        // Fan worker-lane stripes out first so they overlap lane 0's work.
        for l in 1..k {
            let dest = std::mem::replace(&mut dests[l], Chunk::empty());
            self.dispatch_lane(l, from, tags[l], Some(dest), combiner.cloned())?;
        }
        let lane0_result = match combiner {
            Some(comb) => self.recv_chunk_combine_into(from, tags[0], &mut dests[0], comb),
            None => self.recv_chunk_into(from, tags[0], &mut dests[0]),
        };
        let mut first_err: Option<Error> = lane0_result.err();
        for l in 1..k {
            match self.collect_lane(l) {
                Ok(done) => {
                    if let Some(chunk) = done.chunk {
                        dests[l] = chunk;
                    }
                    if let Err(e) = done.result {
                        first_err.get_or_insert(e);
                    }
                }
                Err(e) => {
                    first_err.get_or_insert(e);
                }
            }
        }
        match first_err {
            Some(e) => Err(e),
            None => Ok(()),
        }
    }

    fn check_stripes(&self, k: usize) -> Result<usize> {
        if k == 0 || k > self.lane_count() {
            return Err(Error::BadBufferSize {
                len: k,
                size: self.lane_count(),
                why: "stripe count must be 1..=lane_count",
            });
        }
        Ok(k)
    }
}

impl<T> Drop for Endpoint<T> {
    fn drop(&mut self) {
        // Flag every worker first: one mid-pull on a dead transport would
        // otherwise sleep out its full receive timeout before noticing the
        // closed job queue, stalling this join for a minute or more.
        for w in &self.workers {
            w.stop.store(true, Ordering::Relaxed);
        }
        // Closing each worker's job queue ends its loop; join so no lane
        // thread outlives the transport it serves.
        for w in &mut self.workers {
            let (dead_tx, _) = mpsc::channel();
            let _ = std::mem::replace(&mut w.job_tx, dead_tx);
        }
        for w in &mut self.workers {
            if let Some(h) = w.handle.take() {
                let _ = h.join();
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matched_send_recv() {
        let (_hub, mut eps) = TransportHub::<f32>::new(2);
        let mut e1 = eps.pop().unwrap();
        let mut e0 = eps.pop().unwrap();
        e0.send_chunk(1, 7, Chunk::from_vec(vec![1.0, 2.0])).unwrap();
        assert_eq!(e1.recv_chunk(0, 7).unwrap(), vec![1.0, 2.0]);
    }

    #[test]
    fn out_of_order_tags_are_stashed() {
        let (_hub, mut eps) = TransportHub::<i64>::new(2);
        let mut e1 = eps.pop().unwrap();
        let mut e0 = eps.pop().unwrap();
        e0.send_chunk(1, 1, Chunk::from_vec(vec![10])).unwrap();
        e0.send_chunk(1, 2, Chunk::from_vec(vec![20])).unwrap();
        // Receive in reverse tag order.
        assert_eq!(e1.recv_chunk(0, 2).unwrap(), vec![20]);
        assert_eq!(e1.recv_chunk(0, 1).unwrap(), vec![10]);
    }

    #[test]
    fn fifo_within_same_tag() {
        let (_hub, mut eps) = TransportHub::<u8>::new(2);
        let mut e1 = eps.pop().unwrap();
        let mut e0 = eps.pop().unwrap();
        for v in 0..4u8 {
            e0.send_chunk(1, 9, Chunk::from_vec(vec![v])).unwrap();
        }
        for v in 0..4u8 {
            assert_eq!(e1.recv_chunk(0, 9).unwrap(), vec![v]);
        }
    }

    #[test]
    fn recv_timeout_is_typed_error() {
        let (_hub, mut eps) = TransportHub::<f32>::new(2);
        let mut e1 = eps.remove(1);
        e1.set_timeout(Duration::from_millis(20));
        match e1.recv_chunk(0, 5) {
            Err(Error::RecvTimeout { src: 0, tag: 5, .. }) => {}
            other => panic!("expected RecvTimeout, got {other:?}"),
        }
    }

    #[test]
    fn send_to_bad_peer_rejected() {
        let (_hub, mut eps) = TransportHub::<f32>::new(2);
        let mut e0 = eps.remove(0);
        assert!(matches!(
            e0.send_chunk(5, 0, Chunk::from_vec(vec![])),
            Err(Error::PeerOutOfRange { peer: 5, size: 2 })
        ));
    }

    #[test]
    fn cross_thread_roundtrip() {
        let (_hub, mut eps) = TransportHub::<f64>::new(2);
        let mut e1 = eps.pop().unwrap();
        let mut e0 = eps.pop().unwrap();
        let t = std::thread::spawn(move || {
            let got = e1.recv_chunk(0, 3).unwrap();
            let doubled: Vec<f64> = got.iter().map(|x| x * 2.0).collect();
            e1.send_chunk(0, 4, Chunk::from_vec(doubled)).unwrap();
        });
        e0.send_chunk(1, 3, Chunk::from_vec(vec![1.5, 2.5])).unwrap();
        assert_eq!(e0.recv_chunk(1, 4).unwrap(), vec![3.0, 5.0]);
        t.join().unwrap();
    }

    #[test]
    fn posted_receive_moves_exclusive_and_counts_copies() {
        let (_hub, mut eps) = TransportHub::<f32>::new(2);
        let mut e1 = eps.pop().unwrap();
        let mut e0 = eps.pop().unwrap();

        // Exclusive message (sender moved its only reference): delivery is
        // a pointer move into the posted buffer.
        let msg = Chunk::from_vec(vec![1.0, 2.0]);
        let msg_id = msg.storage_id();
        e0.send_chunk(1, 1, msg).unwrap();
        let mut dest = Chunk::from_vec(vec![0.0; 2]);
        e1.recv_chunk_into(0, 1, &mut dest).unwrap();
        assert_eq!(dest.storage_id(), msg_id, "exclusive delivery must move");
        let t = e1.traffic();
        assert_eq!((t.moved_bytes, t.copied_bytes), (8, 0));

        // Shared message (sender keeps a live view): delivery copies into
        // the posted buffer and the copy is accounted.
        let big = Chunk::from_vec(vec![3.0, 4.0, 5.0, 6.0]);
        e0.send_chunk(1, 2, big.slice(1, 2)).unwrap();
        let mut dest = Chunk::from_vec(vec![0.0; 2]);
        let dest_id = dest.storage_id();
        e1.recv_chunk_into(0, 2, &mut dest).unwrap();
        assert_eq!(dest.storage_id(), dest_id, "shared delivery copies in place");
        assert_eq!(dest.as_slice(), &[4.0, 5.0]);
        let t = e1.traffic();
        assert_eq!((t.recvd_bytes, t.moved_bytes, t.copied_bytes), (16, 8, 8));
    }

    #[test]
    fn posted_combine_receive_is_copy_free() {
        let sum = crate::reduction::offload::native_combine::<f32>();
        let (_hub, mut eps) = TransportHub::<f32>::new(2);
        let mut e1 = eps.pop().unwrap();
        let mut e0 = eps.pop().unwrap();

        // Exclusive accumulator: combine folds in place, pointer stable.
        let input = Chunk::from_vec(vec![10.0, 20.0]);
        e0.send_chunk(1, 1, input.slice(0, 2)).unwrap();
        let mut acc = Chunk::from_vec(vec![1.0, 2.0]);
        let acc_id = acc.storage_id();
        e1.recv_chunk_combine_into(0, 1, &mut acc, &sum).unwrap();
        assert_eq!(acc.storage_id(), acc_id, "accumulator must fold in place");
        assert_eq!(acc.as_slice(), &[11.0, 22.0]);
        let t = e1.traffic();
        assert_eq!((t.moved_bytes, t.copied_bytes), (8, 0), "combine never copies");
    }

    #[test]
    fn shape_mismatch_is_typed_and_recoverable() {
        let (_hub, mut eps) = TransportHub::<f32>::new(2);
        let mut e1 = eps.pop().unwrap();
        let mut e0 = eps.pop().unwrap();
        e0.send_chunk(1, 3, Chunk::from_vec(vec![1.0, 2.0, 3.0])).unwrap();

        // Wrong-size posted buffer: typed error, nothing delivered...
        let mut small = Chunk::from_vec(vec![0.0; 2]);
        match e1.recv_chunk_into(0, 3, &mut small) {
            Err(Error::RecvShapeMismatch { src: 0, tag: 3, expected: 2, got: 3 }) => {}
            other => panic!("expected RecvShapeMismatch, got {other:?}"),
        }
        assert_eq!(small.as_slice(), &[0.0, 0.0], "posted buffer untouched");
        let t = e1.traffic();
        assert_eq!((t.recvd_msgs, t.recvd_bytes), (0, 0), "mismatch is not a receive");

        // ...and the message is still matchable by a correctly sized post.
        let mut right = Chunk::from_vec(vec![0.0; 3]);
        e1.recv_chunk_into(0, 3, &mut right).unwrap();
        assert_eq!(right.as_slice(), &[1.0, 2.0, 3.0]);
    }

    #[test]
    fn chunk_messages_are_zero_copy_across_threads() {
        // A sub-view sent to a peer thread arrives backed by the *same*
        // storage: no bytes moved through the transport.
        let (_hub, mut eps) = TransportHub::<f32>::new(2);
        let mut e1 = eps.pop().unwrap();
        let mut e0 = eps.pop().unwrap();
        let big = Chunk::from_vec((0..64).map(|i| i as f32).collect());
        let id = big.storage_id();
        let view = big.slice(16, 8);
        let t = std::thread::spawn(move || {
            let got = e1.recv_chunk(0, 1).unwrap();
            (got.storage_id(), got.to_vec())
        });
        e0.send_chunk(1, 1, view).unwrap();
        let (got_id, data) = t.join().unwrap();
        assert_eq!(got_id, id, "received chunk must share the sender's storage");
        assert_eq!(data, (16..24).map(|i| i as f32).collect::<Vec<_>>());
    }

    #[test]
    fn traffic_counts_bytes_and_messages() {
        let (_hub, mut eps) = TransportHub::<f32>::new(2);
        let mut e1 = eps.pop().unwrap();
        let mut e0 = eps.pop().unwrap();
        e0.send_chunk(1, 0, Chunk::from_vec(vec![1.0, 2.0, 3.0])).unwrap();
        let t = e0.traffic();
        assert_eq!((t.sent_msgs, t.sent_elems, t.sent_bytes), (1, 3, 12));
        assert_eq!((t.recvd_msgs, t.recvd_bytes), (0, 0));
        let _ = e1.recv_chunk(0, 0).unwrap();
        let t = e1.traffic();
        assert_eq!((t.recvd_msgs, t.recvd_bytes), (1, 12));
        // Reference handover to the caller is a move, never a copy.
        assert_eq!((t.moved_bytes, t.copied_bytes), (12, 0));
        assert_eq!(t.moved_bytes + t.copied_bytes, t.recvd_bytes);
    }

    #[test]
    fn lanes_are_independent_queues() {
        let (_hub, mut eps) = TransportHub::<f32>::new_with_lanes(2, 3);
        let mut e1 = eps.pop().unwrap();
        let mut e0 = eps.pop().unwrap();
        assert_eq!(e0.lane_count(), 3);
        // Same tag on every lane: no cross-delivery.
        for lane in 0..3 {
            e0.send_chunk_on(1, lane, 42, Chunk::from_vec(vec![lane as f32]))
                .unwrap();
        }
        for lane in (0..3).rev() {
            assert_eq!(e1.recv_chunk_on(lane, 0, 42).unwrap(), vec![lane as f32]);
        }
    }

    #[test]
    fn striped_combine_folds_every_stripe() {
        let sum = crate::reduction::offload::native_combine::<f32>();
        let (_hub, mut eps) = TransportHub::<f32>::new_with_lanes(2, 4);
        let mut e1 = eps.pop().unwrap();
        let mut e0 = eps.pop().unwrap();
        let tags = [10u64, 11, 12, 13];
        for (l, &tag) in tags.iter().enumerate() {
            e0.send_chunk_on(1, l, tag, Chunk::from_vec(vec![l as f32; 2]))
                .unwrap();
        }
        let mut dests: Vec<Chunk<f32>> =
            (0..4).map(|_| Chunk::from_vec(vec![100.0, 200.0])).collect();
        e1.recv_striped_combine_into(0, &tags, &mut dests, &sum).unwrap();
        for (l, d) in dests.iter().enumerate() {
            assert_eq!(d.as_slice(), &[100.0 + l as f32, 200.0 + l as f32]);
        }
        let t = e1.traffic();
        assert_eq!((t.recvd_msgs, t.copied_bytes), (4, 0), "striped combine never copies");
        let per_lane = e1.traffic_per_lane();
        assert_eq!(per_lane.len(), 4);
        assert!(per_lane.iter().all(|t| t.recvd_msgs == 1 && t.recvd_bytes == 8));
    }

    #[test]
    fn striped_recv_into_returns_lane_order() {
        let (_hub, mut eps) = TransportHub::<i32>::new_with_lanes(2, 2);
        let mut e1 = eps.pop().unwrap();
        let mut e0 = eps.pop().unwrap();
        // Post lane 1 first: delivery order must still follow lane index.
        e0.send_chunk_on(1, 1, 8, Chunk::from_vec(vec![222])).unwrap();
        e0.send_chunk_on(1, 0, 7, Chunk::from_vec(vec![111])).unwrap();
        let mut dests = vec![Chunk::from_vec(vec![0]), Chunk::from_vec(vec![0])];
        e1.recv_striped_into(0, &[7, 8], &mut dests).unwrap();
        assert_eq!(dests[0].as_slice(), &[111]);
        assert_eq!(dests[1].as_slice(), &[222]);
        // Per-lane send counters on the poster's side.
        let sent = e0.traffic_per_lane();
        assert_eq!(sent[0].sent_msgs, 1);
        assert_eq!(sent[1].sent_msgs, 1);
    }

    #[test]
    fn striped_timeout_is_typed_per_lane() {
        let (_hub, mut eps) = TransportHub::<f32>::new_with_lanes(2, 2);
        let mut e1 = eps.pop().unwrap();
        let mut e0 = eps.pop().unwrap();
        e1.set_timeout(Duration::from_millis(20));
        // Only lane 0 gets a message; lane 1 must time out.
        e0.send_chunk_on(1, 0, 5, Chunk::from_vec(vec![1.0])).unwrap();
        let mut dests = vec![Chunk::from_vec(vec![0.0]), Chunk::from_vec(vec![0.0])];
        match e1.recv_striped_into(0, &[5, 5], &mut dests) {
            Err(Error::RecvTimeout { src: 0, tag: 5, .. }) => {}
            other => panic!("expected RecvTimeout, got {other:?}"),
        }
    }

    #[test]
    fn stripe_count_validated() {
        let (_hub, mut eps) = TransportHub::<f32>::new_with_lanes(2, 2);
        let mut e1 = eps.remove(1);
        assert!(e1.recv_striped(0, &[]).is_err());
        assert!(e1.recv_striped(0, &[1, 2, 3]).is_err());
        let mut dests = vec![Chunk::from_vec(vec![0.0])];
        assert!(e1.recv_striped_into(0, &[1, 2], &mut dests).is_err());
    }

    #[test]
    fn single_lane_hub_has_no_workers() {
        let (_hub, eps) = TransportHub::<f32>::new(3);
        assert!(eps.iter().all(|e| e.lane_count() == 1));
        assert_eq!(eps[0].traffic_per_lane().len(), 1);
    }

    #[test]
    fn endpoint_teardown_is_prompt_with_stuck_lane_jobs() {
        // Two lane jobs that will never match a message: one parks the
        // worker mid-pull, one sits queued behind it. Teardown must not
        // wait out the 60 s receive timeout (let alone the padded collect
        // wait) — the stop flag interrupts the pull within one poll slice
        // and drains the queue.
        let (_hub, mut eps) = TransportHub::<f32>::new_with_lanes(2, 2);
        let e1 = eps.pop().unwrap();
        let mut e0 = eps.pop().unwrap();
        e0.dispatch_lane(1, 1, 0xdead, None, None).unwrap();
        e0.dispatch_lane(1, 1, 0xbeef, None, None).unwrap();
        // Let the worker actually park inside the first pull.
        std::thread::sleep(Duration::from_millis(50));
        let t = Instant::now();
        drop(e0);
        assert!(
            t.elapsed() < Duration::from_secs(10),
            "teardown took {:?} with stuck lane jobs",
            t.elapsed()
        );
        drop(e1);
    }
}
