//! In-process transport: per-rank mailboxes with (source, tag) matching.
//!
//! Each rank owns an [`Endpoint`]: an MPSC receiver (its mailbox) plus
//! cloned senders to every peer. Messages are matched MPI-style on
//! `(src, tag)`; out-of-order arrivals are stashed in a pending map. FIFO
//! is preserved per `(src, tag)` pair because the underlying channel is
//! FIFO per sender and stashing appends in arrival order.
//!
//! The message payload is a [`Chunk`] — an Arc-backed shared buffer view —
//! so posting a message moves a reference, never the bytes. A rank that
//! forwards a received chunk (ring/hierarchical all-gather) or sends a
//! sub-view of its input (recursive doubling, scatter) performs zero
//! copies end to end.

use std::collections::{HashMap, VecDeque};
use std::sync::mpsc::{self, Receiver, RecvTimeoutError, Sender};
use std::time::{Duration, Instant};

use crate::error::{Error, Result};
use crate::reduction::offload::Combiner;

use super::chunk::Chunk;

/// Default receive timeout — generous for tests on loaded machines while
/// still converting deadlocks into typed errors instead of hangs.
pub const DEFAULT_RECV_TIMEOUT: Duration = Duration::from_secs(60);

struct Msg<T> {
    src: usize,
    tag: u64,
    data: Chunk<T>,
}

/// Monotonic per-endpoint traffic counters (messages, elements, bytes).
///
/// Bytes are exact: `elements × size_of::<T>()`, which for the data-plane
/// element types equals [`crate::reduction::Elem::SIZE`]. The bench harness
/// and the launcher's schedule-equivalence guard consume these.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct Traffic {
    /// Messages posted by this endpoint.
    pub sent_msgs: u64,
    /// Elements posted by this endpoint.
    pub sent_elems: u64,
    /// Bytes posted by this endpoint.
    pub sent_bytes: u64,
    /// Messages received (matched) by this endpoint.
    pub recvd_msgs: u64,
    /// Bytes received (matched) by this endpoint.
    pub recvd_bytes: u64,
    /// Received bytes delivered by reference move or in-place combine —
    /// no verbatim buffer copy on the receive path.
    pub moved_bytes: u64,
    /// Received bytes that had to be copied into caller storage (a shared
    /// incoming view delivered into a posted buffer). The reduce-path
    /// smoke guard asserts this stays zero. Invariant:
    /// `moved_bytes + copied_bytes == recvd_bytes`.
    pub copied_bytes: u64,
}

/// Cloneable handle with senders to every rank's mailbox.
pub struct TransportHub<T> {
    senders: Vec<Sender<Msg<T>>>,
}

impl<T> Clone for TransportHub<T> {
    fn clone(&self) -> Self {
        Self {
            senders: self.senders.clone(),
        }
    }
}

impl<T: Send + Sync + 'static> TransportHub<T> {
    /// Build a hub + one endpoint per rank.
    pub fn new(size: usize) -> (Self, Vec<Endpoint<T>>) {
        let mut senders = Vec::with_capacity(size);
        let mut receivers = Vec::with_capacity(size);
        for _ in 0..size {
            let (tx, rx) = mpsc::channel();
            senders.push(tx);
            receivers.push(rx);
        }
        let hub = Self { senders };
        let endpoints = receivers
            .into_iter()
            .enumerate()
            .map(|(rank, rx)| Endpoint {
                rank,
                hub: hub.clone(),
                rx,
                pending: HashMap::new(),
                timeout: DEFAULT_RECV_TIMEOUT,
                traffic: Traffic::default(),
            })
            .collect();
        (hub, endpoints)
    }

    fn size(&self) -> usize {
        self.senders.len()
    }
}

/// One rank's connection to the transport. Not `Clone`: exactly one owner
/// (the rank thread) may receive.
pub struct Endpoint<T> {
    rank: usize,
    hub: TransportHub<T>,
    rx: Receiver<Msg<T>>,
    pending: HashMap<(usize, u64), VecDeque<Chunk<T>>>,
    timeout: Duration,
    traffic: Traffic,
}

impl<T: Send + Sync + 'static> Endpoint<T> {
    pub fn rank(&self) -> usize {
        self.rank
    }

    pub fn size(&self) -> usize {
        self.hub.size()
    }

    /// Override the receive timeout (failure-injection tests use short ones).
    pub fn set_timeout(&mut self, timeout: Duration) {
        self.timeout = timeout;
    }

    /// Traffic counters so far (monotonic).
    pub fn traffic(&self) -> Traffic {
        self.traffic
    }

    /// Post `chunk` to `to`'s mailbox — a reference move, never a byte
    /// copy. Non-blocking (unbounded channel — the collectives are
    /// self-throttling, at most one outstanding message per peer per step).
    pub fn send_chunk(&mut self, to: usize, tag: u64, chunk: Chunk<T>) -> Result<()> {
        if to >= self.hub.size() {
            return Err(Error::PeerOutOfRange {
                peer: to,
                size: self.hub.size(),
            });
        }
        self.traffic.sent_msgs += 1;
        self.traffic.sent_elems += chunk.len() as u64;
        self.traffic.sent_bytes += (chunk.len() * std::mem::size_of::<T>()) as u64;
        self.hub.senders[to]
            .send(Msg {
                src: self.rank,
                tag,
                data: chunk,
            })
            .map_err(|_| Error::TransportClosed { rank: self.rank })
    }

    /// Owned-vector send: wraps into a [`Chunk`] (O(1)) and posts it.
    #[deprecated(note = "owned-Vec compat shim — use `send_chunk` (O(1) wrap, zero-copy post)")]
    pub fn send(&mut self, to: usize, tag: u64, data: Vec<T>) -> Result<()> {
        self.send_chunk(to, tag, Chunk::from_vec(data))
    }

    /// Blocking matched receive of a chunk from `(from, tag)` — the caller
    /// takes the delivered reference, so the whole message counts as moved.
    pub fn recv_chunk(&mut self, from: usize, tag: u64) -> Result<Chunk<T>> {
        let data = self.pull(from, tag)?;
        self.count_recv(data.len(), 0);
        Ok(data)
    }

    /// Posted receive: deliver the matched chunk into `dest`, preferring a
    /// reference move over a copy (see [`Chunk::accept`]).
    ///
    /// If the incoming chunk's length differs from `dest.len()` the message
    /// is pushed back onto the front of the pending queue (so a later,
    /// correctly-sized receive can still match it) and a typed
    /// [`Error::RecvShapeMismatch`] is returned.
    pub fn recv_chunk_into(&mut self, from: usize, tag: u64, dest: &mut Chunk<T>) -> Result<()>
    where
        T: Clone,
    {
        let data = self.checked_pull(from, tag, dest.len())?;
        let len = data.len();
        let copied = dest.accept(data);
        self.count_recv(len, copied);
        Ok(())
    }

    /// Posted receive fused with a reduction: after the call `dest` holds
    /// `dest ⊕ incoming` with zero verbatim copies (see
    /// [`Chunk::accept_combine`] for the three delivery cases). Shape
    /// mismatches behave as in [`Endpoint::recv_chunk_into`].
    pub fn recv_chunk_combine_into(
        &mut self,
        from: usize,
        tag: u64,
        dest: &mut Chunk<T>,
        combiner: &Combiner<T>,
    ) -> Result<()>
    where
        T: Clone,
    {
        let data = self.checked_pull(from, tag, dest.len())?;
        let len = data.len();
        dest.accept_combine(data, combiner);
        self.count_recv(len, 0);
        Ok(())
    }

    /// Materializing receive (compat shim over [`Endpoint::recv_chunk`]).
    #[deprecated(
        note = "owned-Vec compat shim — use `recv_chunk` (zero-copy) or `recv_chunk_into` \
                (posted receive)"
    )]
    pub fn recv(&mut self, from: usize, tag: u64) -> Result<Vec<T>>
    where
        T: Clone,
    {
        Ok(self.recv_chunk(from, tag)?.into_vec())
    }

    /// Matched pull without traffic accounting (counting happens once the
    /// delivery is classified as moved or copied).
    fn pull(&mut self, from: usize, tag: u64) -> Result<Chunk<T>> {
        let key = (from, tag);
        if let Some(q) = self.pending.get_mut(&key) {
            if let Some(data) = q.pop_front() {
                return Ok(data);
            }
        }
        let deadline = Instant::now() + self.timeout;
        loop {
            let remaining = deadline.saturating_duration_since(Instant::now());
            match self.rx.recv_timeout(remaining) {
                Ok(msg) => {
                    if msg.src == from && msg.tag == tag {
                        return Ok(msg.data);
                    }
                    self.pending
                        .entry((msg.src, msg.tag))
                        .or_default()
                        .push_back(msg.data);
                }
                Err(RecvTimeoutError::Timeout) => {
                    return Err(Error::RecvTimeout {
                        src: from,
                        tag,
                        ms: self.timeout.as_millis() as u64,
                    })
                }
                Err(RecvTimeoutError::Disconnected) => {
                    return Err(Error::TransportClosed { rank: self.rank })
                }
            }
        }
    }

    /// [`Endpoint::pull`] plus the posted-buffer shape check; on mismatch
    /// the message is requeued at the front (FIFO order preserved — it was
    /// taken from the front) and the error is recoverable.
    fn checked_pull(&mut self, from: usize, tag: u64, expected: usize) -> Result<Chunk<T>> {
        let data = self.pull(from, tag)?;
        if data.len() != expected {
            let got = data.len();
            self.pending.entry((from, tag)).or_default().push_front(data);
            return Err(Error::RecvShapeMismatch {
                src: from,
                tag,
                expected,
                got,
            });
        }
        Ok(data)
    }

    fn count_recv(&mut self, elems: usize, copied_elems: usize) {
        let bytes = |e: usize| (e * std::mem::size_of::<T>()) as u64;
        self.traffic.recvd_msgs += 1;
        self.traffic.recvd_bytes += bytes(elems);
        self.traffic.copied_bytes += bytes(copied_elems);
        self.traffic.moved_bytes += bytes(elems - copied_elems);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matched_send_recv() {
        let (_hub, mut eps) = TransportHub::<f32>::new(2);
        let mut e1 = eps.pop().unwrap();
        let mut e0 = eps.pop().unwrap();
        e0.send_chunk(1, 7, Chunk::from_vec(vec![1.0, 2.0])).unwrap();
        assert_eq!(e1.recv_chunk(0, 7).unwrap(), vec![1.0, 2.0]);
    }

    #[test]
    fn out_of_order_tags_are_stashed() {
        let (_hub, mut eps) = TransportHub::<i64>::new(2);
        let mut e1 = eps.pop().unwrap();
        let mut e0 = eps.pop().unwrap();
        e0.send_chunk(1, 1, Chunk::from_vec(vec![10])).unwrap();
        e0.send_chunk(1, 2, Chunk::from_vec(vec![20])).unwrap();
        // Receive in reverse tag order.
        assert_eq!(e1.recv_chunk(0, 2).unwrap(), vec![20]);
        assert_eq!(e1.recv_chunk(0, 1).unwrap(), vec![10]);
    }

    #[test]
    fn fifo_within_same_tag() {
        let (_hub, mut eps) = TransportHub::<u8>::new(2);
        let mut e1 = eps.pop().unwrap();
        let mut e0 = eps.pop().unwrap();
        for v in 0..4u8 {
            e0.send_chunk(1, 9, Chunk::from_vec(vec![v])).unwrap();
        }
        for v in 0..4u8 {
            assert_eq!(e1.recv_chunk(0, 9).unwrap(), vec![v]);
        }
    }

    #[test]
    #[allow(deprecated)]
    fn owned_vec_shims_still_work() {
        // The deprecated compat shims must stay behaviorally identical to
        // the chunk API until they are removed.
        let (_hub, mut eps) = TransportHub::<f32>::new(2);
        let mut e1 = eps.pop().unwrap();
        let mut e0 = eps.pop().unwrap();
        e0.send(1, 7, vec![1.0, 2.0]).unwrap();
        assert_eq!(e1.recv(0, 7).unwrap(), vec![1.0, 2.0]);
    }

    #[test]
    fn recv_timeout_is_typed_error() {
        let (_hub, mut eps) = TransportHub::<f32>::new(2);
        let mut e1 = eps.remove(1);
        e1.set_timeout(Duration::from_millis(20));
        match e1.recv_chunk(0, 5) {
            Err(Error::RecvTimeout { src: 0, tag: 5, .. }) => {}
            other => panic!("expected RecvTimeout, got {other:?}"),
        }
    }

    #[test]
    fn send_to_bad_peer_rejected() {
        let (_hub, mut eps) = TransportHub::<f32>::new(2);
        let mut e0 = eps.remove(0);
        assert!(matches!(
            e0.send_chunk(5, 0, Chunk::from_vec(vec![])),
            Err(Error::PeerOutOfRange { peer: 5, size: 2 })
        ));
    }

    #[test]
    fn cross_thread_roundtrip() {
        let (_hub, mut eps) = TransportHub::<f64>::new(2);
        let mut e1 = eps.pop().unwrap();
        let mut e0 = eps.pop().unwrap();
        let t = std::thread::spawn(move || {
            let got = e1.recv_chunk(0, 3).unwrap();
            let doubled: Vec<f64> = got.iter().map(|x| x * 2.0).collect();
            e1.send_chunk(0, 4, Chunk::from_vec(doubled)).unwrap();
        });
        e0.send_chunk(1, 3, Chunk::from_vec(vec![1.5, 2.5])).unwrap();
        assert_eq!(e0.recv_chunk(1, 4).unwrap(), vec![3.0, 5.0]);
        t.join().unwrap();
    }

    #[test]
    fn posted_receive_moves_exclusive_and_counts_copies() {
        let (_hub, mut eps) = TransportHub::<f32>::new(2);
        let mut e1 = eps.pop().unwrap();
        let mut e0 = eps.pop().unwrap();

        // Exclusive message (sender moved its only reference): delivery is
        // a pointer move into the posted buffer.
        let msg = Chunk::from_vec(vec![1.0, 2.0]);
        let msg_id = msg.storage_id();
        e0.send_chunk(1, 1, msg).unwrap();
        let mut dest = Chunk::from_vec(vec![0.0; 2]);
        e1.recv_chunk_into(0, 1, &mut dest).unwrap();
        assert_eq!(dest.storage_id(), msg_id, "exclusive delivery must move");
        let t = e1.traffic();
        assert_eq!((t.moved_bytes, t.copied_bytes), (8, 0));

        // Shared message (sender keeps a live view): delivery copies into
        // the posted buffer and the copy is accounted.
        let big = Chunk::from_vec(vec![3.0, 4.0, 5.0, 6.0]);
        e0.send_chunk(1, 2, big.slice(1, 2)).unwrap();
        let mut dest = Chunk::from_vec(vec![0.0; 2]);
        let dest_id = dest.storage_id();
        e1.recv_chunk_into(0, 2, &mut dest).unwrap();
        assert_eq!(dest.storage_id(), dest_id, "shared delivery copies in place");
        assert_eq!(dest.as_slice(), &[4.0, 5.0]);
        let t = e1.traffic();
        assert_eq!((t.recvd_bytes, t.moved_bytes, t.copied_bytes), (16, 8, 8));
    }

    #[test]
    fn posted_combine_receive_is_copy_free() {
        let sum = crate::reduction::offload::native_combine::<f32>();
        let (_hub, mut eps) = TransportHub::<f32>::new(2);
        let mut e1 = eps.pop().unwrap();
        let mut e0 = eps.pop().unwrap();

        // Exclusive accumulator: combine folds in place, pointer stable.
        let input = Chunk::from_vec(vec![10.0, 20.0]);
        e0.send_chunk(1, 1, input.slice(0, 2)).unwrap();
        let mut acc = Chunk::from_vec(vec![1.0, 2.0]);
        let acc_id = acc.storage_id();
        e1.recv_chunk_combine_into(0, 1, &mut acc, &sum).unwrap();
        assert_eq!(acc.storage_id(), acc_id, "accumulator must fold in place");
        assert_eq!(acc.as_slice(), &[11.0, 22.0]);
        let t = e1.traffic();
        assert_eq!((t.moved_bytes, t.copied_bytes), (8, 0), "combine never copies");
    }

    #[test]
    fn shape_mismatch_is_typed_and_recoverable() {
        let (_hub, mut eps) = TransportHub::<f32>::new(2);
        let mut e1 = eps.pop().unwrap();
        let mut e0 = eps.pop().unwrap();
        e0.send_chunk(1, 3, Chunk::from_vec(vec![1.0, 2.0, 3.0])).unwrap();

        // Wrong-size posted buffer: typed error, nothing delivered...
        let mut small = Chunk::from_vec(vec![0.0; 2]);
        match e1.recv_chunk_into(0, 3, &mut small) {
            Err(Error::RecvShapeMismatch { src: 0, tag: 3, expected: 2, got: 3 }) => {}
            other => panic!("expected RecvShapeMismatch, got {other:?}"),
        }
        assert_eq!(small.as_slice(), &[0.0, 0.0], "posted buffer untouched");
        let t = e1.traffic();
        assert_eq!((t.recvd_msgs, t.recvd_bytes), (0, 0), "mismatch is not a receive");

        // ...and the message is still matchable by a correctly sized post.
        let mut right = Chunk::from_vec(vec![0.0; 3]);
        e1.recv_chunk_into(0, 3, &mut right).unwrap();
        assert_eq!(right.as_slice(), &[1.0, 2.0, 3.0]);
    }

    #[test]
    fn chunk_messages_are_zero_copy_across_threads() {
        // A sub-view sent to a peer thread arrives backed by the *same*
        // storage: no bytes moved through the transport.
        let (_hub, mut eps) = TransportHub::<f32>::new(2);
        let mut e1 = eps.pop().unwrap();
        let mut e0 = eps.pop().unwrap();
        let big = Chunk::from_vec((0..64).map(|i| i as f32).collect());
        let id = big.storage_id();
        let view = big.slice(16, 8);
        let t = std::thread::spawn(move || {
            let got = e1.recv_chunk(0, 1).unwrap();
            (got.storage_id(), got.to_vec())
        });
        e0.send_chunk(1, 1, view).unwrap();
        let (got_id, data) = t.join().unwrap();
        assert_eq!(got_id, id, "received chunk must share the sender's storage");
        assert_eq!(data, (16..24).map(|i| i as f32).collect::<Vec<_>>());
    }

    #[test]
    fn traffic_counts_bytes_and_messages() {
        let (_hub, mut eps) = TransportHub::<f32>::new(2);
        let mut e1 = eps.pop().unwrap();
        let mut e0 = eps.pop().unwrap();
        e0.send_chunk(1, 0, Chunk::from_vec(vec![1.0, 2.0, 3.0])).unwrap();
        let t = e0.traffic();
        assert_eq!((t.sent_msgs, t.sent_elems, t.sent_bytes), (1, 3, 12));
        assert_eq!((t.recvd_msgs, t.recvd_bytes), (0, 0));
        let _ = e1.recv_chunk(0, 0).unwrap();
        let t = e1.traffic();
        assert_eq!((t.recvd_msgs, t.recvd_bytes), (1, 12));
        // Reference handover to the caller is a move, never a copy.
        assert_eq!((t.moved_bytes, t.copied_bytes), (12, 0));
        assert_eq!(t.moved_bytes + t.copied_bytes, t.recvd_bytes);
    }
}
