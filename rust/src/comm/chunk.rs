//! `Chunk<T>` — the shared, sliceable message buffer of the zero-copy data
//! plane.
//!
//! A chunk is an `Arc`-backed storage plus an `(offset, len)` view:
//! `clone()`, [`Chunk::slice`], and [`Chunk::split`] are O(1) and never
//! touch the elements, so a collective can forward a received block, or
//! send a sub-view of its input, without materializing a fresh buffer.
//! This is what lets multi-level hierarchical/pipelined schedules pass
//! each block through every hop untouched (the copy-free multicast/reduce
//! primitives PCCL and HiCCL compose collectives from).
//!
//! Mutation goes through [`Chunk::make_mut`]: in place when the storage is
//! uniquely owned (the common case for a freshly received reduction
//! partial, since the sender moved its reference into the transport),
//! copy-on-write otherwise. [`Chunk::into_vec`] is likewise free for a
//! unique full-range view and copies only when the storage is still
//! shared.

use std::fmt;
use std::ops::Deref;
use std::sync::Arc;

use crate::reduction::offload::Combiner;

/// Shared, sliceable message buffer: `Arc` storage + `(offset, len)` view.
pub struct Chunk<T> {
    storage: Arc<Vec<T>>,
    off: usize,
    len: usize,
}

impl<T> Chunk<T> {
    /// Wrap an owned vector — O(1), no copy.
    pub fn from_vec(v: Vec<T>) -> Self {
        let len = v.len();
        Self {
            storage: Arc::new(v),
            off: 0,
            len,
        }
    }

    /// The empty chunk (zero-length barrier/token messages).
    pub fn empty() -> Self {
        Self::from_vec(Vec::new())
    }

    /// Elements visible through this view.
    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Borrow the viewed elements.
    pub fn as_slice(&self) -> &[T] {
        &self.storage[self.off..self.off + self.len]
    }

    /// O(1) sub-view of `len` elements starting at `start` — shares storage.
    pub fn slice(&self, start: usize, len: usize) -> Self {
        let end = start.checked_add(len).expect("chunk slice range overflow");
        assert!(
            end <= self.len,
            "chunk slice {start}..{end} out of bounds for view of {}",
            self.len
        );
        Self {
            storage: Arc::clone(&self.storage),
            off: self.off + start,
            len,
        }
    }

    /// O(1) split into `[0, at)` and `[at, len)` views.
    pub fn split(&self, at: usize) -> (Self, Self) {
        (self.slice(0, at), self.slice(at, self.len - at))
    }

    /// O(k) split into `k` contiguous stripe views covering the whole
    /// chunk, in order — the unit of multi-lane striping. All stripes
    /// share this chunk's storage. An uneven length gives the first
    /// `len % k` stripes one extra element ([`stripe_lens`] is the shape
    /// contract both sides of a striped exchange compute independently).
    /// Zero-length stripes are produced when `len < k` so lane schedules
    /// stay aligned across ranks regardless of payload size.
    pub fn stripes(&self, k: usize) -> Vec<Self> {
        stripe_lens(self.len, k)
            .into_iter()
            .scan(0usize, |off, n| {
                let s = self.slice(*off, n);
                *off += n;
                Some(s)
            })
            .collect()
    }

    /// Identity of the backing storage — two chunks with equal ids share
    /// bytes. Used by the zero-copy (no re-materialization) tests.
    pub fn storage_id(&self) -> usize {
        Arc::as_ptr(&self.storage) as usize
    }

    /// Number of live references to the backing storage.
    pub fn storage_refs(&self) -> usize {
        Arc::strong_count(&self.storage)
    }

    /// Whether this view covers the whole backing storage.
    pub fn is_full_view(&self) -> bool {
        self.off == 0 && self.len == self.storage.len()
    }

    /// Whether this chunk may be written in place: it is the unique
    /// full-range view of its storage, so no other view can observe the
    /// write and no foreign bytes share the allocation.
    pub fn is_exclusive(&self) -> bool {
        self.is_full_view() && self.storage_refs() == 1
    }
}

impl<T: Clone> Chunk<T> {
    /// Copy a borrowed slice into fresh storage (the one materialization a
    /// slice-based caller pays; everything downstream is views).
    pub fn from_slice(data: &[T]) -> Self {
        Self::from_vec(data.to_vec())
    }

    /// Copy the viewed elements out.
    pub fn to_vec(&self) -> Vec<T> {
        self.as_slice().to_vec()
    }

    /// Take the elements: moves the storage when this is the unique
    /// full-range view (no copy), otherwise copies the viewed range.
    pub fn into_vec(self) -> Vec<T> {
        let Chunk { storage, off, len } = self;
        if off == 0 && len == storage.len() {
            match Arc::try_unwrap(storage) {
                Ok(v) => v,
                Err(shared) => shared[..len].to_vec(),
            }
        } else {
            storage[off..off + len].to_vec()
        }
    }

    /// Mutable access to the viewed elements: in place when the storage is
    /// uniquely owned, copy-on-write otherwise (so mutation can never be
    /// observed through another view).
    pub fn make_mut(&mut self) -> &mut [T] {
        if Arc::get_mut(&mut self.storage).is_none() {
            let owned = self.as_slice().to_vec();
            self.off = 0;
            self.len = owned.len();
            self.storage = Arc::new(owned);
        }
        let (off, len) = (self.off, self.len);
        let v = Arc::get_mut(&mut self.storage).expect("chunk storage unique after copy-on-write");
        &mut v[off..off + len]
    }

    /// Like [`Chunk::make_mut`], but additionally re-materializes a
    /// sub-view into exact-size storage: afterwards this chunk is always
    /// the unique full-range view of its storage, so a later
    /// [`Chunk::into_vec`] is a free move.
    ///
    /// This is what the reduce-scatter hot loops combine through. A
    /// traveling reduction partial is unique full-range storage from its
    /// first combine on (in place, like `make_mut`); the difference shows
    /// on the *first* combine, where the received chunk is a sub-view of
    /// the sender's input. `make_mut` would copy it only when the sender
    /// still holds a reference — a race — and in the no-copy outcome the
    /// b-element result would pin the sender's whole p·b storage alive.
    /// Copying the exact range unconditionally makes the output shape
    /// deterministic and bounds resident memory, at the cost the COW path
    /// was already paying.
    pub fn make_mut_exact(&mut self) -> &mut [T] {
        if !self.is_full_view() || Arc::get_mut(&mut self.storage).is_none() {
            let owned = self.as_slice().to_vec();
            self.off = 0;
            self.len = owned.len();
            self.storage = Arc::new(owned);
        }
        let (off, len) = (self.off, self.len);
        let v = Arc::get_mut(&mut self.storage).expect("chunk storage unique after exact copy");
        &mut v[off..off + len]
    }

    /// Posted-receive delivery: replace this chunk's contents with
    /// `incoming`'s, preferring a reference move over a copy.
    ///
    /// If `incoming` is [exclusive](Chunk::is_exclusive) the delivery is a
    /// pointer move (`*self = incoming`) and `0` is returned; otherwise the
    /// viewed range is copied into this chunk's (COW-resolved) storage and
    /// the number of elements copied is returned. Lengths must match —
    /// callers enforce that with a typed error before delivery.
    pub fn accept(&mut self, incoming: Chunk<T>) -> usize {
        debug_assert_eq!(self.len, incoming.len(), "accept length mismatch");
        if incoming.is_exclusive() {
            *self = incoming;
            0
        } else {
            let n = incoming.len();
            self.make_mut().clone_from_slice(incoming.as_slice());
            n
        }
    }

    /// Posted-receive delivery fused with a reduction: after the call this
    /// chunk holds `self ⊕ incoming`, without ever copying a buffer verbatim.
    ///
    /// Three cases, in order:
    /// 1. this chunk is [exclusive](Chunk::is_exclusive) → in-place fold into
    ///    its storage (the accumulator pointer is stable across steps);
    /// 2. `incoming` is exclusive → fold this chunk's elements into
    ///    `incoming`'s storage and take it over (the traveling partial the
    ///    sender moved into the transport becomes the accumulator);
    /// 3. both are shared COW views → one-pass three-address fuse into fresh
    ///    exact-size storage (one allocation, zero verbatim copies — this
    ///    replaces the copy-then-fold that `make_mut_exact` paid on the
    ///    first combine).
    ///
    /// Because case 2 swaps the operand order, the combine must be
    /// commutative (sum/max/min are).
    pub fn accept_combine(&mut self, incoming: Chunk<T>, combiner: &Combiner<T>)
    where
        T: 'static,
    {
        debug_assert_eq!(self.len, incoming.len(), "accept_combine length mismatch");
        if self.is_exclusive() {
            combiner.fold(self.make_mut(), incoming.as_slice());
        } else if incoming.is_exclusive() {
            let mut incoming = incoming;
            combiner.fold(incoming.make_mut(), self.as_slice());
            *self = incoming;
        } else {
            *self = Chunk::from_vec(combiner.fuse(incoming.as_slice(), self.as_slice()));
        }
    }

    /// Materialize an ordered list of chunks into one contiguous vector
    /// (the final output copy of the slice-based collective wrappers).
    pub fn concat(chunks: &[Chunk<T>]) -> Vec<T> {
        let total: usize = chunks.iter().map(Chunk::len).sum();
        let mut out = Vec::with_capacity(total);
        for c in chunks {
            out.extend_from_slice(c.as_slice());
        }
        out
    }
}

/// Stripe lengths for splitting `len` elements into `k` contiguous
/// stripes: the first `len % k` stripes get `len / k + 1` elements, the
/// rest `len / k`. Both peers of a striped exchange derive the posted
/// buffer shapes from this, so it is the wire contract for striping.
pub fn stripe_lens(len: usize, k: usize) -> Vec<usize> {
    assert!(k >= 1, "stripe count must be at least 1");
    let (q, r) = (len / k, len % k);
    (0..k).map(|i| q + usize::from(i < r)).collect()
}

impl<T> Clone for Chunk<T> {
    fn clone(&self) -> Self {
        Self {
            storage: Arc::clone(&self.storage),
            off: self.off,
            len: self.len,
        }
    }
}

impl<T> From<Vec<T>> for Chunk<T> {
    fn from(v: Vec<T>) -> Self {
        Self::from_vec(v)
    }
}

impl<T> Deref for Chunk<T> {
    type Target = [T];
    fn deref(&self) -> &[T] {
        self.as_slice()
    }
}

impl<T: fmt::Debug> fmt::Debug for Chunk<T> {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.debug_struct("Chunk")
            .field("off", &self.off)
            .field("len", &self.len)
            .field("data", &self.as_slice())
            .finish()
    }
}

impl<T: PartialEq> PartialEq for Chunk<T> {
    fn eq(&self, other: &Self) -> bool {
        self.as_slice() == other.as_slice()
    }
}

impl<T: PartialEq> PartialEq<Vec<T>> for Chunk<T> {
    fn eq(&self, other: &Vec<T>) -> bool {
        self.as_slice() == other.as_slice()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn slice_and_split_share_storage() {
        let c = Chunk::from_vec(vec![0, 1, 2, 3, 4, 5, 6]);
        let s = c.slice(2, 3);
        assert_eq!(s.as_slice(), &[2, 3, 4]);
        assert_eq!(s.storage_id(), c.storage_id());
        // Uneven split.
        let (a, b) = c.split(3);
        assert_eq!(a.as_slice(), &[0, 1, 2]);
        assert_eq!(b.as_slice(), &[3, 4, 5, 6]);
        assert_eq!(a.storage_id(), b.storage_id());
        assert_eq!(c.storage_refs(), 4); // c, s, a, b
    }

    #[test]
    #[should_panic(expected = "out of bounds")]
    fn slice_out_of_bounds_panics() {
        let c = Chunk::from_vec(vec![1, 2, 3]);
        let _ = c.slice(1, 3);
    }

    #[test]
    fn make_mut_in_place_when_unique() {
        let mut c = Chunk::from_vec(vec![1.0f32, 2.0, 3.0]);
        let id = c.storage_id();
        c.make_mut()[0] = 9.0;
        assert_eq!(c.storage_id(), id, "unique chunk must mutate in place");
        assert_eq!(c.as_slice(), &[9.0, 2.0, 3.0]);
    }

    #[test]
    fn make_mut_copies_on_write_when_shared() {
        let a = Chunk::from_vec(vec![1, 2, 3, 4]);
        let mut b = a.slice(1, 2);
        b.make_mut()[0] = 99;
        assert_ne!(b.storage_id(), a.storage_id(), "shared view must COW");
        assert_eq!(b.as_slice(), &[99, 3]);
        assert_eq!(a.as_slice(), &[1, 2, 3, 4], "original untouched");
    }

    #[test]
    fn make_mut_exact_normalizes_sub_views() {
        // Unique full view: in place, storage identity preserved.
        let mut c = Chunk::from_vec(vec![1.0f32, 2.0]);
        let id = c.storage_id();
        c.make_mut_exact()[1] = 9.0;
        assert_eq!(c.storage_id(), id, "unique full view must stay in place");
        // Unique sub-view: re-materialized to exact-size full-view storage
        // (so into_vec is a move and the parent storage is released).
        let parent = Chunk::from_vec(vec![0, 1, 2, 3, 4, 5]);
        let mut v = parent.slice(2, 2);
        drop(parent);
        assert_eq!(v.storage_refs(), 1, "sub-view is unique after parent drop");
        v.make_mut_exact()[0] = 99;
        assert!(v.is_full_view());
        assert_eq!(v.storage_refs(), 1);
        assert_eq!(v.as_slice(), &[99, 3]);
        let ptr = v.as_slice().as_ptr();
        assert_eq!(v.into_vec().as_ptr(), ptr, "exact chunk must move out");
    }

    #[test]
    fn into_vec_moves_when_unique_copies_when_shared() {
        let v = vec![1u8, 2, 3];
        let data_ptr = v.as_ptr();
        let c = Chunk::from_vec(v);
        let back = c.into_vec();
        assert_eq!(back.as_ptr(), data_ptr, "unique full view must move");

        let c = Chunk::from_vec(vec![1u8, 2, 3]);
        let keep = c.clone();
        let copied = c.into_vec();
        assert_eq!(copied, vec![1, 2, 3]);
        assert_eq!(keep.as_slice(), &[1, 2, 3]);
    }

    #[test]
    fn concat_restores_order() {
        let c = Chunk::from_vec(vec![10, 20, 30, 40]);
        let parts = vec![c.slice(2, 2), c.slice(0, 2)];
        assert_eq!(Chunk::concat(&parts), vec![30, 40, 10, 20]);
    }

    #[test]
    fn accept_moves_exclusive_and_copies_shared() {
        // Exclusive incoming: pointer move, zero copied elements.
        let mut dest = Chunk::from_vec(vec![0.0f32; 3]);
        let incoming = Chunk::from_vec(vec![1.0f32, 2.0, 3.0]);
        let id = incoming.storage_id();
        assert_eq!(dest.accept(incoming), 0);
        assert_eq!(dest.storage_id(), id, "exclusive delivery must be a move");
        assert_eq!(dest.as_slice(), &[1.0, 2.0, 3.0]);

        // Shared incoming (a live sub-view): copied into dest's storage.
        let parent = Chunk::from_vec(vec![7.0f32, 8.0, 9.0, 10.0]);
        let mut dest = Chunk::from_vec(vec![0.0f32; 2]);
        let dest_id = dest.storage_id();
        assert_eq!(dest.accept(parent.slice(1, 2)), 2);
        assert_eq!(dest.storage_id(), dest_id, "copy lands in the posted storage");
        assert_eq!(dest.as_slice(), &[8.0, 9.0]);
    }

    #[test]
    fn accept_combine_three_cases() {
        let sum = crate::reduction::offload::native_combine::<f32>();

        // Case 1: exclusive dest — in-place fold, pointer stable.
        let mut acc = Chunk::from_vec(vec![1.0f32, 2.0]);
        let id = acc.storage_id();
        let parent = Chunk::from_vec(vec![10.0f32, 20.0]);
        acc.accept_combine(parent.clone(), &sum);
        assert_eq!(acc.storage_id(), id, "exclusive accumulator folds in place");
        assert_eq!(acc.as_slice(), &[11.0, 22.0]);

        // Case 2: shared dest, exclusive incoming — take over the partial.
        let base = Chunk::from_vec(vec![1.0f32, 1.0]);
        let mut acc = base.slice(0, 2);
        let incoming = Chunk::from_vec(vec![5.0f32, 6.0]);
        let incoming_id = incoming.storage_id();
        acc.accept_combine(incoming, &sum);
        assert_eq!(acc.storage_id(), incoming_id, "partial's storage is taken over");
        assert_eq!(acc.as_slice(), &[6.0, 7.0]);
        assert_eq!(base.as_slice(), &[1.0, 1.0], "posted view's parent untouched");

        // Case 3: both shared — fused create into fresh exact storage.
        let a = Chunk::from_vec(vec![1.0f32, 2.0, 3.0, 4.0]);
        let mut dest = a.slice(0, 2);
        dest.accept_combine(a.slice(2, 2), &sum);
        assert_ne!(dest.storage_id(), a.storage_id());
        assert_eq!(dest.as_slice(), &[4.0, 6.0]);
        assert!(dest.is_exclusive(), "fused create yields exact exclusive storage");
    }

    #[test]
    fn stripes_cover_unevenly_and_share_storage() {
        let c = Chunk::from_vec((0..7).collect::<Vec<i32>>());
        let s = c.stripes(3);
        assert_eq!(stripe_lens(7, 3), vec![3, 2, 2]);
        assert_eq!(s[0].as_slice(), &[0, 1, 2]);
        assert_eq!(s[1].as_slice(), &[3, 4]);
        assert_eq!(s[2].as_slice(), &[5, 6]);
        assert!(s.iter().all(|x| x.storage_id() == c.storage_id()));
        // len < k pads with empty stripes, never drops lanes.
        let tiny = Chunk::from_vec(vec![1, 2]);
        let s = tiny.stripes(4);
        assert_eq!(
            s.iter().map(Chunk::len).collect::<Vec<_>>(),
            vec![1, 1, 0, 0]
        );
        assert_eq!(Chunk::concat(&s), vec![1, 2]);
    }

    #[test]
    fn empty_chunk_roundtrip() {
        let c = Chunk::<f32>::empty();
        assert!(c.is_empty());
        assert!(c.is_full_view());
        assert!(c.into_vec().is_empty());
    }
}
