//! `Chunk<T>` — the shared, sliceable message buffer of the zero-copy data
//! plane.
//!
//! A chunk is an `Arc`-backed storage plus an `(offset, len)` view:
//! `clone()`, [`Chunk::slice`], and [`Chunk::split`] are O(1) and never
//! touch the elements, so a collective can forward a received block, or
//! send a sub-view of its input, without materializing a fresh buffer.
//! This is what lets multi-level hierarchical/pipelined schedules pass
//! each block through every hop untouched (the copy-free multicast/reduce
//! primitives PCCL and HiCCL compose collectives from).
//!
//! Mutation goes through [`Chunk::make_mut`]: in place when the storage is
//! uniquely owned (the common case for a freshly received reduction
//! partial, since the sender moved its reference into the transport),
//! copy-on-write otherwise. [`Chunk::into_vec`] is likewise free for a
//! unique full-range view and copies only when the storage is still
//! shared.

use std::fmt;
use std::ops::Deref;
use std::sync::Arc;

/// Shared, sliceable message buffer: `Arc` storage + `(offset, len)` view.
pub struct Chunk<T> {
    storage: Arc<Vec<T>>,
    off: usize,
    len: usize,
}

impl<T> Chunk<T> {
    /// Wrap an owned vector — O(1), no copy.
    pub fn from_vec(v: Vec<T>) -> Self {
        let len = v.len();
        Self {
            storage: Arc::new(v),
            off: 0,
            len,
        }
    }

    /// The empty chunk (zero-length barrier/token messages).
    pub fn empty() -> Self {
        Self::from_vec(Vec::new())
    }

    /// Elements visible through this view.
    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Borrow the viewed elements.
    pub fn as_slice(&self) -> &[T] {
        &self.storage[self.off..self.off + self.len]
    }

    /// O(1) sub-view of `len` elements starting at `start` — shares storage.
    pub fn slice(&self, start: usize, len: usize) -> Self {
        let end = start.checked_add(len).expect("chunk slice range overflow");
        assert!(
            end <= self.len,
            "chunk slice {start}..{end} out of bounds for view of {}",
            self.len
        );
        Self {
            storage: Arc::clone(&self.storage),
            off: self.off + start,
            len,
        }
    }

    /// O(1) split into `[0, at)` and `[at, len)` views.
    pub fn split(&self, at: usize) -> (Self, Self) {
        (self.slice(0, at), self.slice(at, self.len - at))
    }

    /// Identity of the backing storage — two chunks with equal ids share
    /// bytes. Used by the zero-copy (no re-materialization) tests.
    pub fn storage_id(&self) -> usize {
        Arc::as_ptr(&self.storage) as usize
    }

    /// Number of live references to the backing storage.
    pub fn storage_refs(&self) -> usize {
        Arc::strong_count(&self.storage)
    }

    /// Whether this view covers the whole backing storage.
    pub fn is_full_view(&self) -> bool {
        self.off == 0 && self.len == self.storage.len()
    }
}

impl<T: Clone> Chunk<T> {
    /// Copy a borrowed slice into fresh storage (the one materialization a
    /// slice-based caller pays; everything downstream is views).
    pub fn from_slice(data: &[T]) -> Self {
        Self::from_vec(data.to_vec())
    }

    /// Copy the viewed elements out.
    pub fn to_vec(&self) -> Vec<T> {
        self.as_slice().to_vec()
    }

    /// Take the elements: moves the storage when this is the unique
    /// full-range view (no copy), otherwise copies the viewed range.
    pub fn into_vec(self) -> Vec<T> {
        let Chunk { storage, off, len } = self;
        if off == 0 && len == storage.len() {
            match Arc::try_unwrap(storage) {
                Ok(v) => v,
                Err(shared) => shared[..len].to_vec(),
            }
        } else {
            storage[off..off + len].to_vec()
        }
    }

    /// Mutable access to the viewed elements: in place when the storage is
    /// uniquely owned, copy-on-write otherwise (so mutation can never be
    /// observed through another view).
    pub fn make_mut(&mut self) -> &mut [T] {
        if Arc::get_mut(&mut self.storage).is_none() {
            let owned = self.as_slice().to_vec();
            self.off = 0;
            self.len = owned.len();
            self.storage = Arc::new(owned);
        }
        let (off, len) = (self.off, self.len);
        let v = Arc::get_mut(&mut self.storage).expect("chunk storage unique after copy-on-write");
        &mut v[off..off + len]
    }

    /// Like [`Chunk::make_mut`], but additionally re-materializes a
    /// sub-view into exact-size storage: afterwards this chunk is always
    /// the unique full-range view of its storage, so a later
    /// [`Chunk::into_vec`] is a free move.
    ///
    /// This is what the reduce-scatter hot loops combine through. A
    /// traveling reduction partial is unique full-range storage from its
    /// first combine on (in place, like `make_mut`); the difference shows
    /// on the *first* combine, where the received chunk is a sub-view of
    /// the sender's input. `make_mut` would copy it only when the sender
    /// still holds a reference — a race — and in the no-copy outcome the
    /// b-element result would pin the sender's whole p·b storage alive.
    /// Copying the exact range unconditionally makes the output shape
    /// deterministic and bounds resident memory, at the cost the COW path
    /// was already paying.
    pub fn make_mut_exact(&mut self) -> &mut [T] {
        if !self.is_full_view() || Arc::get_mut(&mut self.storage).is_none() {
            let owned = self.as_slice().to_vec();
            self.off = 0;
            self.len = owned.len();
            self.storage = Arc::new(owned);
        }
        let (off, len) = (self.off, self.len);
        let v = Arc::get_mut(&mut self.storage).expect("chunk storage unique after exact copy");
        &mut v[off..off + len]
    }

    /// Materialize an ordered list of chunks into one contiguous vector
    /// (the final output copy of the slice-based collective wrappers).
    pub fn concat(chunks: &[Chunk<T>]) -> Vec<T> {
        let total: usize = chunks.iter().map(Chunk::len).sum();
        let mut out = Vec::with_capacity(total);
        for c in chunks {
            out.extend_from_slice(c.as_slice());
        }
        out
    }
}

impl<T> Clone for Chunk<T> {
    fn clone(&self) -> Self {
        Self {
            storage: Arc::clone(&self.storage),
            off: self.off,
            len: self.len,
        }
    }
}

impl<T> From<Vec<T>> for Chunk<T> {
    fn from(v: Vec<T>) -> Self {
        Self::from_vec(v)
    }
}

impl<T> Deref for Chunk<T> {
    type Target = [T];
    fn deref(&self) -> &[T] {
        self.as_slice()
    }
}

impl<T: fmt::Debug> fmt::Debug for Chunk<T> {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.debug_struct("Chunk")
            .field("off", &self.off)
            .field("len", &self.len)
            .field("data", &self.as_slice())
            .finish()
    }
}

impl<T: PartialEq> PartialEq for Chunk<T> {
    fn eq(&self, other: &Self) -> bool {
        self.as_slice() == other.as_slice()
    }
}

impl<T: PartialEq> PartialEq<Vec<T>> for Chunk<T> {
    fn eq(&self, other: &Vec<T>) -> bool {
        self.as_slice() == other.as_slice()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn slice_and_split_share_storage() {
        let c = Chunk::from_vec(vec![0, 1, 2, 3, 4, 5, 6]);
        let s = c.slice(2, 3);
        assert_eq!(s.as_slice(), &[2, 3, 4]);
        assert_eq!(s.storage_id(), c.storage_id());
        // Uneven split.
        let (a, b) = c.split(3);
        assert_eq!(a.as_slice(), &[0, 1, 2]);
        assert_eq!(b.as_slice(), &[3, 4, 5, 6]);
        assert_eq!(a.storage_id(), b.storage_id());
        assert_eq!(c.storage_refs(), 4); // c, s, a, b
    }

    #[test]
    #[should_panic(expected = "out of bounds")]
    fn slice_out_of_bounds_panics() {
        let c = Chunk::from_vec(vec![1, 2, 3]);
        let _ = c.slice(1, 3);
    }

    #[test]
    fn make_mut_in_place_when_unique() {
        let mut c = Chunk::from_vec(vec![1.0f32, 2.0, 3.0]);
        let id = c.storage_id();
        c.make_mut()[0] = 9.0;
        assert_eq!(c.storage_id(), id, "unique chunk must mutate in place");
        assert_eq!(c.as_slice(), &[9.0, 2.0, 3.0]);
    }

    #[test]
    fn make_mut_copies_on_write_when_shared() {
        let a = Chunk::from_vec(vec![1, 2, 3, 4]);
        let mut b = a.slice(1, 2);
        b.make_mut()[0] = 99;
        assert_ne!(b.storage_id(), a.storage_id(), "shared view must COW");
        assert_eq!(b.as_slice(), &[99, 3]);
        assert_eq!(a.as_slice(), &[1, 2, 3, 4], "original untouched");
    }

    #[test]
    fn make_mut_exact_normalizes_sub_views() {
        // Unique full view: in place, storage identity preserved.
        let mut c = Chunk::from_vec(vec![1.0f32, 2.0]);
        let id = c.storage_id();
        c.make_mut_exact()[1] = 9.0;
        assert_eq!(c.storage_id(), id, "unique full view must stay in place");
        // Unique sub-view: re-materialized to exact-size full-view storage
        // (so into_vec is a move and the parent storage is released).
        let parent = Chunk::from_vec(vec![0, 1, 2, 3, 4, 5]);
        let mut v = parent.slice(2, 2);
        drop(parent);
        assert_eq!(v.storage_refs(), 1, "sub-view is unique after parent drop");
        v.make_mut_exact()[0] = 99;
        assert!(v.is_full_view());
        assert_eq!(v.storage_refs(), 1);
        assert_eq!(v.as_slice(), &[99, 3]);
        let ptr = v.as_slice().as_ptr();
        assert_eq!(v.into_vec().as_ptr(), ptr, "exact chunk must move out");
    }

    #[test]
    fn into_vec_moves_when_unique_copies_when_shared() {
        let v = vec![1u8, 2, 3];
        let data_ptr = v.as_ptr();
        let c = Chunk::from_vec(v);
        let back = c.into_vec();
        assert_eq!(back.as_ptr(), data_ptr, "unique full view must move");

        let c = Chunk::from_vec(vec![1u8, 2, 3]);
        let keep = c.clone();
        let copied = c.into_vec();
        assert_eq!(copied, vec![1, 2, 3]);
        assert_eq!(keep.as_slice(), &[1, 2, 3]);
    }

    #[test]
    fn concat_restores_order() {
        let c = Chunk::from_vec(vec![10, 20, 30, 40]);
        let parts = vec![c.slice(2, 2), c.slice(0, 2)];
        assert_eq!(Chunk::concat(&parts), vec![30, 40, 10, 20]);
    }

    #[test]
    fn empty_chunk_roundtrip() {
        let c = Chunk::<f32>::empty();
        assert!(c.is_empty());
        assert!(c.is_full_view());
        assert!(c.into_vec().is_empty());
    }
}
