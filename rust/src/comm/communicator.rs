//! Communicators: the MPI-like object collectives run over.
//!
//! A [`Communicator`] owns a rank's transport [`Endpoint`] and represents
//! the *world*; [`SubComm`] is a borrowed view over a subset of ranks (the
//! inter-node / intra-node sub-communicators of the paper's hierarchical
//! design, Fig. 5). Both implement [`Comm`], the trait the algorithms in
//! [`crate::collectives`] are written against.
//!
//! Tag namespacing: every communicator has a 64-bit context id (an FNV hash
//! of its member list and lineage), combined with a per-instance op sequence
//! number and the algorithm step. FIFO per `(src, tag)` in the transport
//! makes residual aliasing harmless (SPMD collectives send and receive in
//! matched order).

use std::time::Duration;

use crate::error::{Error, Result};
use crate::topology::Topology;

use super::transport::Endpoint;

/// FNV-1a over a stream of u64 words — deterministic context ids.
fn fnv64(words: impl IntoIterator<Item = u64>) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for w in words {
        for b in w.to_le_bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(0x1000_0000_01b3);
        }
    }
    h
}

fn compose_tag(ctx: u64, op_seq: u64, step: u32) -> u64 {
    // ctx is already well-mixed; fold in op_seq and step reversibly enough
    // that distinct (op, step) pairs within a context never collide.
    ctx ^ (op_seq << 16) ^ (step as u64)
}

/// Operations collectives need from a communicator.
pub trait Comm<T: Send + 'static> {
    /// This rank within the communicator (0-based).
    fn rank(&self) -> usize;
    /// Number of ranks in the communicator.
    fn size(&self) -> usize;
    /// Post `data` to `peer` for algorithm step `step` (non-blocking).
    fn send(&mut self, peer: usize, step: u32, data: Vec<T>) -> Result<()>;
    /// Matched receive from `peer` for step `step` (blocking).
    fn recv(&mut self, peer: usize, step: u32) -> Result<Vec<T>>;
    /// Begin a new collective: bumps the op sequence for tag freshness.
    fn begin_op(&mut self);

    /// Combined exchange: send to `to`, then receive from `from`, same step.
    /// Safe against deadlock because sends never block.
    fn sendrecv(&mut self, to: usize, data: Vec<T>, from: usize, step: u32) -> Result<Vec<T>> {
        self.send(to, step, data)?;
        self.recv(from, step)
    }

    /// Dissemination barrier: O(log p) rounds.
    fn barrier(&mut self) -> Result<()>
    where
        T: Default,
    {
        self.begin_op();
        let p = self.size();
        let rank = self.rank();
        let mut k = 0u32;
        let mut dist = 1usize;
        while dist < p {
            let to = (rank + dist) % p;
            let from = (rank + p - dist) % p;
            self.send(to, 0x8000 + k, Vec::new())?;
            self.recv(from, 0x8000 + k)?;
            dist <<= 1;
            k += 1;
        }
        Ok(())
    }
}

/// The world communicator: owns this rank's endpoint.
pub struct Communicator<T> {
    ep: Endpoint<T>,
    topo: Topology,
    ctx: u64,
    op_seq: u64,
}

impl<T: Send + 'static> Communicator<T> {
    /// This rank (inherent mirror of [`Comm::rank`] so callers don't need
    /// the trait in scope).
    pub fn rank(&self) -> usize {
        self.ep.rank()
    }

    /// World size (inherent mirror of [`Comm::size`]).
    pub fn size(&self) -> usize {
        self.ep.size()
    }

    /// Wrap an endpoint; `topo.world_size()` must equal the transport size.
    pub fn new(ep: Endpoint<T>, topo: Topology) -> Result<Self> {
        if topo.world_size() != ep.size() {
            return Err(Error::InvalidTopology(format!(
                "topology world {} != transport size {}",
                topo.world_size(),
                ep.size()
            )));
        }
        let ctx = fnv64([0xC0, ep.size() as u64]);
        Ok(Self {
            ep,
            topo,
            ctx,
            op_seq: 0,
        })
    }

    pub fn topology(&self) -> Topology {
        self.topo
    }

    /// (messages sent, elements sent, messages received) on this endpoint.
    pub fn traffic(&self) -> (u64, u64, u64) {
        self.ep.traffic()
    }

    /// Receive timeout for deadlock detection / failure injection.
    pub fn set_timeout(&mut self, timeout: Duration) {
        self.ep.set_timeout(timeout);
    }

    /// Borrowed sub-communicator over `group` (global ranks, which must
    /// contain this rank). Order of `group` defines sub-ranks.
    pub fn subcomm(&mut self, group: Vec<usize>) -> Result<SubComm<'_, T>> {
        let Some(rank) = group.iter().position(|&g| g == self.ep.rank()) else {
            return Err(Error::InvalidTopology(format!(
                "rank {} not in subgroup {:?}",
                self.ep.rank(),
                group
            )));
        };
        for &g in &group {
            if g >= self.ep.size() {
                return Err(Error::PeerOutOfRange {
                    peer: g,
                    size: self.ep.size(),
                });
            }
        }
        let ctx = fnv64(
            std::iter::once(self.ctx).chain(group.iter().map(|&g| g as u64)),
        );
        Ok(SubComm {
            ep: &mut self.ep,
            group,
            rank,
            ctx,
            op_seq: 0,
        })
    }

    /// This rank's inter-node sub-communicator (same local id across nodes).
    pub fn inter_node(&mut self) -> Result<SubComm<'_, T>> {
        let g = self.topo.inter_node_group(self.ep.rank());
        self.subcomm(g)
    }

    /// This rank's intra-node sub-communicator (all ranks on its node).
    pub fn intra_node(&mut self) -> Result<SubComm<'_, T>> {
        let g = self.topo.intra_node_group(self.ep.rank());
        self.subcomm(g)
    }
}

impl<T: Send + 'static> Comm<T> for Communicator<T> {
    fn rank(&self) -> usize {
        self.ep.rank()
    }

    fn size(&self) -> usize {
        self.ep.size()
    }

    fn send(&mut self, peer: usize, step: u32, data: Vec<T>) -> Result<()> {
        let tag = compose_tag(self.ctx, self.op_seq, step);
        self.ep.send(peer, tag, data)
    }

    fn recv(&mut self, peer: usize, step: u32) -> Result<Vec<T>> {
        let tag = compose_tag(self.ctx, self.op_seq, step);
        self.ep.recv(peer, tag)
    }

    fn begin_op(&mut self) {
        self.op_seq = self.op_seq.wrapping_add(1);
    }
}

/// Borrowed view over a subset of world ranks.
pub struct SubComm<'a, T> {
    ep: &'a mut Endpoint<T>,
    group: Vec<usize>,
    rank: usize,
    ctx: u64,
    op_seq: u64,
}

impl<'a, T: Send + 'static> SubComm<'a, T> {
    /// The global (world) ranks of this subgroup, in sub-rank order.
    pub fn group(&self) -> &[usize] {
        &self.group
    }
}

impl<'a, T: Send + 'static> Comm<T> for SubComm<'a, T> {
    fn rank(&self) -> usize {
        self.rank
    }

    fn size(&self) -> usize {
        self.group.len()
    }

    fn send(&mut self, peer: usize, step: u32, data: Vec<T>) -> Result<()> {
        let global = *self.group.get(peer).ok_or(Error::PeerOutOfRange {
            peer,
            size: self.group.len(),
        })?;
        let tag = compose_tag(self.ctx, self.op_seq, step);
        self.ep.send(global, tag, data)
    }

    fn recv(&mut self, peer: usize, step: u32) -> Result<Vec<T>> {
        let global = *self.group.get(peer).ok_or(Error::PeerOutOfRange {
            peer,
            size: self.group.len(),
        })?;
        let tag = compose_tag(self.ctx, self.op_seq, step);
        self.ep.recv(global, tag)
    }

    fn begin_op(&mut self) {
        self.op_seq = self.op_seq.wrapping_add(1);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::transport::TransportHub;

    fn pair() -> (Communicator<f32>, Communicator<f32>) {
        let (_hub, mut eps) = TransportHub::<f32>::new(2);
        let e1 = eps.pop().unwrap();
        let e0 = eps.pop().unwrap();
        let t = Topology::flat(2);
        (
            Communicator::new(e0, t).unwrap(),
            Communicator::new(e1, t).unwrap(),
        )
    }

    #[test]
    fn world_send_recv() {
        let (mut c0, mut c1) = pair();
        c0.send(1, 0, vec![42.0]).unwrap();
        assert_eq!(c1.recv(0, 0).unwrap(), vec![42.0]);
    }

    #[test]
    fn subcomm_rank_translation() {
        let (_hub, eps) = TransportHub::<i32>::new(4);
        let topo = Topology::new(2, 2, 1).unwrap();
        let mut comms: Vec<Communicator<i32>> = eps
            .into_iter()
            .map(|e| Communicator::new(e, topo).unwrap())
            .collect();
        // rank 1 and rank 3 share local id 1 → inter-node group [1, 3].
        let c3 = comms.pop().unwrap();
        let _c2 = comms.pop().unwrap();
        let c1 = comms.pop().unwrap();
        let mut c1 = c1;
        let mut c3 = c3;
        {
            let mut s1 = c1.inter_node().unwrap();
            assert_eq!(s1.group(), &[1, 3]);
            assert_eq!(s1.rank(), 0);
            assert_eq!(s1.size(), 2);
            s1.send(1, 0, vec![7]).unwrap();
        }
        {
            let mut s3 = c3.inter_node().unwrap();
            assert_eq!(s3.rank(), 1);
            assert_eq!(s3.recv(0, 0).unwrap(), vec![7]);
        }
    }

    #[test]
    fn subcomm_requires_membership() {
        let (mut c0, _c1) = pair();
        assert!(c0.subcomm(vec![1]).is_err());
        assert!(c0.subcomm(vec![0, 9]).is_err());
    }

    #[test]
    fn distinct_contexts_do_not_cross_talk() {
        let (_hub, eps) = TransportHub::<i32>::new(4);
        let topo = Topology::new(2, 2, 1).unwrap();
        let mut comms: Vec<Communicator<i32>> = eps
            .into_iter()
            .map(|e| Communicator::new(e, topo).unwrap())
            .collect();
        // World-send from 0 to 1 and subcomm-send from 0 to 1 with the same
        // step must be distinguishable by tag.
        let mut c1 = comms.remove(1);
        let mut c0 = comms.remove(0);
        c0.send(1, 0, vec![100]).unwrap();
        {
            let mut s0 = c0.subcomm(vec![0, 1]).unwrap();
            s0.send(1, 0, vec![200]).unwrap();
        }
        {
            let mut s1 = c1.subcomm(vec![0, 1]).unwrap();
            assert_eq!(s1.recv(0, 0).unwrap(), vec![200]);
        }
        assert_eq!(c1.recv(0, 0).unwrap(), vec![100]);
    }

    #[test]
    fn barrier_completes() {
        let (_hub, eps) = TransportHub::<f32>::new(8);
        let topo = Topology::flat(8);
        let handles: Vec<_> = eps
            .into_iter()
            .map(|e| {
                std::thread::spawn(move || {
                    let mut c = Communicator::new(e, topo).unwrap();
                    for _ in 0..5 {
                        c.barrier().unwrap();
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
    }
}
