//! Communicators: the MPI-like object collectives run over.
//!
//! A [`Communicator`] owns a rank's transport [`Endpoint`] and represents
//! the *world*; [`SubComm`] is a borrowed view over a subset of ranks (the
//! inter-node / intra-node sub-communicators of the paper's hierarchical
//! design, Fig. 5). Both implement [`Comm`], the trait the algorithms in
//! [`crate::collectives`] are written against.
//!
//! The primitive operations are chunk-based ([`Comm::send_slice`] /
//! [`Comm::recv_chunk`]): payloads are [`Chunk`] views into shared
//! storage, so forwarding and sub-view sends are zero-copy. Posted
//! receives ([`Comm::recv_into`] / [`Comm::recv_combine_into`]) go one
//! step further and deliver — or fold — the incoming chunk directly into
//! receiver-designated storage, which is what keeps the reduce path free
//! of staging copies. There is no owned-`Vec` surface: every payload is a
//! [`Chunk`] (an owned `Vec` wraps in O(1) via [`Chunk::from_vec`]).
//!
//! Tag namespacing: every communicator has a 64-bit context id (an FNV hash
//! of its member list and lineage); the per-instance op sequence number and
//! the algorithm step are folded through the same FNV mix (not XOR-shifted)
//! so that high-frequency ops on long-lived subcomms cannot alias tags.
//! FIFO per `(src, tag)` in the transport makes residual aliasing harmless
//! (SPMD collectives send and receive in matched order).
//!
//! Failure semantics: the world's *epoch* is folded into the context id
//! ahead of everything else, so after an aborted collective
//! [`Communicator::bump_epoch`] retags the entire tag namespace — a stale
//! in-flight message from the previous epoch can never match a
//! post-recovery receive, it can only stash until the recovery drain
//! reclaims it. [`Communicator::shrink`] builds on the same mechanism to
//! resume on a survivor subset after a rank death. See
//! [`crate::collectives`] for the full failure model.

use std::time::Duration;

use crate::error::{Error, Result};
use crate::reduction::offload::Combiner;
use crate::topology::Topology;

use super::chunk::Chunk;
use super::transport::{AbortToken, Endpoint, FaultPlan, Traffic};

/// FNV-1a over a stream of u64 words — deterministic context ids.
fn fnv64(words: impl IntoIterator<Item = u64>) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for w in words {
        h = fnv64_step(h, w);
    }
    h
}

/// Fold one u64 word into an FNV-1a state.
fn fnv64_step(mut h: u64, w: u64) -> u64 {
    for b in w.to_le_bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x1000_0000_01b3);
    }
    h
}

/// Compose the wire tag for `(context, op, step)`. `op_seq` and `step` are
/// folded through the FNV mix seeded by the (already well-mixed) context:
/// unlike the earlier `ctx ^ (op_seq << 16) ^ step` scheme, distinct
/// `(op_seq, step)` pairs cannot cancel linearly, so a subcomm that issues
/// millions of ops never collides a fresh op with an old step.
fn compose_tag(ctx: u64, op_seq: u64, step: u32) -> u64 {
    fnv64_step(fnv64_step(ctx, op_seq), step as u64)
}

/// Compose the wire tag for stripe `lane` of `(context, op, step)`: the
/// lane id is folded through the same FNV mix on top of [`compose_tag`].
/// Lanes already ride separate transport queues, so this is belt and
/// braces — a message that somehow landed on the wrong lane's queue (or a
/// stale-lane replay) can never match, it can only stash and time out.
/// Note lane 0's striped tag differs from the unstriped tag for the same
/// step; striped and unstriped exchanges are distinct wire protocols.
fn compose_tag_lane(ctx: u64, op_seq: u64, step: u32, lane: usize) -> u64 {
    fnv64_step(compose_tag(ctx, op_seq, step), lane as u64)
}

/// Stripe-step encoding used by the *default* (single-queue) striped
/// methods: stripe `l` of step `step` in a `k`-stripe exchange rides tag
/// step `step * k + l`. Collectives that stripe use this encoding for the
/// whole op, so it cannot collide with itself; `begin_op` isolates it
/// from neighboring ops.
fn stripe_step(step: u32, l: usize, k: usize) -> u32 {
    step * k as u32 + l as u32
}

/// Operations collectives need from a communicator.
pub trait Comm<T: Send + Sync + 'static> {
    /// This rank within the communicator (0-based).
    fn rank(&self) -> usize;
    /// Number of ranks in the communicator.
    fn size(&self) -> usize;
    /// Post a shared-buffer `chunk` to `peer` for algorithm step `step`
    /// (non-blocking, zero-copy: a reference moves, not the bytes).
    fn send_slice(&mut self, peer: usize, step: u32, chunk: Chunk<T>) -> Result<()>;
    /// Matched chunk receive from `peer` for step `step` (blocking).
    fn recv_chunk(&mut self, peer: usize, step: u32) -> Result<Chunk<T>>;
    /// Begin a new collective: bumps the op sequence for tag freshness.
    fn begin_op(&mut self);

    /// Whether this communicator participates in a world abort protocol
    /// (an [`AbortToken`] is armed on its endpoint). Defaults `false` for
    /// plain single-queue impls with no failure machinery.
    fn abort_armed(&self) -> bool {
        false
    }

    /// Poison the world: trip the armed abort token and send a control
    /// message to every peer so parked receives wake within one poll
    /// slice. No-op when no token is armed.
    fn broadcast_abort(&mut self, _cause: &str) {}

    /// The communicator's current op sequence number (for abort
    /// attribution). Default 0 for impls without one.
    fn current_op_seq(&self) -> u64 {
        0
    }

    /// Cumulative `(wait_ns, serve_ns)` clock of the underlying endpoint:
    /// time spent waiting for matches vs delivering/folding payloads. The
    /// engine differences this around each op for the queueing-vs-service
    /// split in trace spans. Default `(0, 0)` for impls that don't track
    /// it.
    fn op_clock(&self) -> (u64, u64) {
        (0, 0)
    }

    /// Number of independent transport lanes this communicator can stripe
    /// a message over (≥ 1). The default single-queue implementation
    /// reports 1; endpoint-backed communicators report the transport's
    /// lane count. Collectives clamp their stripe count to this.
    fn lanes(&self) -> usize {
        1
    }

    /// Post the stripes of one striped exchange to `peer`: stripe `l`
    /// travels lane `l` (endpoint-backed impls) with the lane id folded
    /// into its wire tag. The default falls back to the single queue,
    /// encoding the stripe index into the step — functionally identical,
    /// serially delivered.
    fn send_striped(&mut self, peer: usize, step: u32, stripes: Vec<Chunk<T>>) -> Result<()> {
        let k = stripes.len();
        for (l, s) in stripes.into_iter().enumerate() {
            self.send_slice(peer, stripe_step(step, l, k), s)?;
        }
        Ok(())
    }

    /// Matched receive of a `k`-stripe exchange from `peer`, stripes in
    /// lane order.
    fn recv_striped(&mut self, peer: usize, step: u32, k: usize) -> Result<Vec<Chunk<T>>> {
        (0..k)
            .map(|l| self.recv_chunk(peer, stripe_step(step, l, k)))
            .collect()
    }

    /// Posted striped receive: deliver stripe `l` into `dests[l]`.
    /// Endpoint-backed impls deliver worker-lane stripes concurrently.
    fn recv_striped_into(&mut self, peer: usize, step: u32, dests: &mut [Chunk<T>]) -> Result<()>
    where
        T: Clone,
    {
        let k = dests.len();
        for (l, dest) in dests.iter_mut().enumerate() {
            self.recv_into(peer, stripe_step(step, l, k), dest)?;
        }
        Ok(())
    }

    /// Posted striped receive fused with a reduction: stripe `l` is folded
    /// into `dests[l]` — on endpoint-backed impls each worker-lane
    /// stripe's fold runs on its own lane thread, the lane-parallel
    /// combine at the heart of multi-NIC striping.
    fn recv_striped_combine_into(
        &mut self,
        peer: usize,
        step: u32,
        dests: &mut [Chunk<T>],
        combiner: &Combiner<T>,
    ) -> Result<()>
    where
        T: Clone,
    {
        let k = dests.len();
        for (l, dest) in dests.iter_mut().enumerate() {
            self.recv_combine_into(peer, stripe_step(step, l, k), dest, combiner)?;
        }
        Ok(())
    }

    /// Striped exchange: post all stripes to `to`, then receive the
    /// matched stripes from `from` (non-blocking sends make this
    /// deadlock-safe in a ring).
    fn sendrecv_striped(
        &mut self,
        to: usize,
        stripes: Vec<Chunk<T>>,
        from: usize,
        step: u32,
        k: usize,
    ) -> Result<Vec<Chunk<T>>> {
        self.send_striped(to, step, stripes)?;
        self.recv_striped(from, step, k)
    }

    /// Striped exchange with posted delivery into `dests`.
    fn sendrecv_striped_into(
        &mut self,
        to: usize,
        stripes: Vec<Chunk<T>>,
        from: usize,
        step: u32,
        dests: &mut [Chunk<T>],
    ) -> Result<()>
    where
        T: Clone,
    {
        self.send_striped(to, step, stripes)?;
        self.recv_striped_into(from, step, dests)
    }

    /// Striped exchange with posted combining delivery — the hot-loop
    /// primitive of the lane-parallel reduce path: one call per ring step
    /// posts `k` outgoing stripes and folds `k` incoming stripes, the
    /// folds running lane-parallel on endpoint-backed impls.
    fn sendrecv_striped_combine_into(
        &mut self,
        to: usize,
        stripes: Vec<Chunk<T>>,
        from: usize,
        step: u32,
        dests: &mut [Chunk<T>],
        combiner: &Combiner<T>,
    ) -> Result<()>
    where
        T: Clone,
    {
        self.send_striped(to, step, stripes)?;
        self.recv_striped_combine_into(from, step, dests, combiner)
    }

    /// Posted receive: deliver the matched chunk from `peer` directly into
    /// `dest`'s storage — a reference move when the incoming chunk is
    /// exclusive, a copy into the posted buffer otherwise. Returns
    /// [`Error::RecvShapeMismatch`] (leaving the message receivable) when
    /// `dest.len()` differs from the incoming length.
    ///
    /// The default builds on [`Comm::recv_chunk`]; endpoint-backed
    /// implementations override it so the transport requeues mismatched
    /// messages and accounts moved vs copied bytes exactly.
    fn recv_into(&mut self, peer: usize, step: u32, dest: &mut Chunk<T>) -> Result<()>
    where
        T: Clone,
    {
        let got = self.recv_chunk(peer, step)?;
        if got.len() != dest.len() {
            return Err(Error::RecvShapeMismatch {
                src: peer,
                tag: step as u64,
                expected: dest.len(),
                got: got.len(),
            });
        }
        dest.accept(got);
        Ok(())
    }

    /// Posted receive fused with a reduction: after the call `dest` holds
    /// `dest ⊕ incoming` with zero verbatim copies — in place when `dest`
    /// is exclusive, taking over an exclusive incoming partial otherwise,
    /// and a one-pass three-address fuse into fresh storage when both are
    /// shared COW views (see [`Chunk::accept_combine`]). The combine must
    /// be commutative. Shape mismatches behave as in [`Comm::recv_into`].
    fn recv_combine_into(
        &mut self,
        peer: usize,
        step: u32,
        dest: &mut Chunk<T>,
        combiner: &Combiner<T>,
    ) -> Result<()>
    where
        T: Clone,
    {
        let got = self.recv_chunk(peer, step)?;
        if got.len() != dest.len() {
            return Err(Error::RecvShapeMismatch {
                src: peer,
                tag: step as u64,
                expected: dest.len(),
                got: got.len(),
            });
        }
        dest.accept_combine(got, combiner);
        Ok(())
    }

    /// Combined exchange: send `chunk` to `to`, then receive from `from`,
    /// same step. Safe against deadlock because sends never block.
    fn sendrecv_chunk(
        &mut self,
        to: usize,
        chunk: Chunk<T>,
        from: usize,
        step: u32,
    ) -> Result<Chunk<T>> {
        self.send_slice(to, step, chunk)?;
        self.recv_chunk(from, step)
    }

    /// Fused exchange with a posted receive: send `chunk` to `to`, then
    /// deliver the matched message from `from` into `dest`.
    fn sendrecv_into(
        &mut self,
        to: usize,
        chunk: Chunk<T>,
        from: usize,
        step: u32,
        dest: &mut Chunk<T>,
    ) -> Result<()>
    where
        T: Clone,
    {
        self.send_slice(to, step, chunk)?;
        self.recv_into(from, step, dest)
    }

    /// Fused exchange with a posted combining receive: send `chunk` to
    /// `to`, then fold the matched message from `from` into `dest` — the
    /// reduce-scatter hot-loop primitive.
    fn sendrecv_combine_into(
        &mut self,
        to: usize,
        chunk: Chunk<T>,
        from: usize,
        step: u32,
        dest: &mut Chunk<T>,
        combiner: &Combiner<T>,
    ) -> Result<()>
    where
        T: Clone,
    {
        self.send_slice(to, step, chunk)?;
        self.recv_combine_into(from, step, dest, combiner)
    }

    /// Dissemination barrier: O(log p) rounds of empty-chunk tokens.
    fn barrier(&mut self) -> Result<()> {
        self.begin_op();
        let p = self.size();
        let rank = self.rank();
        let mut k = 0u32;
        let mut dist = 1usize;
        while dist < p {
            let to = (rank + dist) % p;
            let from = (rank + p - dist) % p;
            self.send_slice(to, 0x8000 + k, Chunk::empty())?;
            self.recv_chunk(from, 0x8000 + k)?;
            dist <<= 1;
            k += 1;
        }
        Ok(())
    }
}

/// The world communicator: owns this rank's endpoint.
pub struct Communicator<T> {
    ep: Endpoint<T>,
    topo: Topology,
    /// Epoch-independent context seed (hash of kind + world size).
    base_ctx: u64,
    /// Live context id: `fnv64_step(base_ctx, epoch)`.
    ctx: u64,
    /// Recovery epoch — bumped after every aborted collective so stale
    /// messages from the dead epoch can never match fresh tags.
    epoch: u32,
    op_seq: u64,
}

impl<T: Send + Sync + 'static> Communicator<T> {
    /// This rank (inherent mirror of [`Comm::rank`] so callers don't need
    /// the trait in scope).
    pub fn rank(&self) -> usize {
        self.ep.rank()
    }

    /// World size (inherent mirror of [`Comm::size`]).
    pub fn size(&self) -> usize {
        self.ep.size()
    }

    /// Wrap an endpoint; `topo.world_size()` must equal the transport size.
    pub fn new(ep: Endpoint<T>, topo: Topology) -> Result<Self> {
        if topo.world_size() != ep.size() {
            return Err(Error::InvalidTopology(format!(
                "topology world {} != transport size {}",
                topo.world_size(),
                ep.size()
            )));
        }
        let base_ctx = fnv64([0xC0, ep.size() as u64]);
        Ok(Self {
            ep,
            topo,
            base_ctx,
            ctx: fnv64_step(base_ctx, 0),
            epoch: 0,
            op_seq: 0,
        })
    }

    pub fn topology(&self) -> Topology {
        self.topo
    }

    /// Monotonic traffic counters (messages, elements, bytes) on this
    /// endpoint — the launcher reads deltas around timed sections.
    pub fn traffic(&self) -> Traffic {
        self.ep.traffic()
    }

    /// Receive timeout for deadlock detection / failure injection.
    pub fn set_timeout(&mut self, timeout: Duration) {
        self.ep.set_timeout(timeout);
    }

    /// Arm the world abort token: parked receives on this rank watch it
    /// between poll slices, and a local failure broadcast trips it for
    /// every peer sharing the token.
    pub fn arm_abort(&mut self, token: AbortToken) {
        self.ep.set_abort_token(token);
    }

    /// The armed abort token, if any.
    pub fn abort_token(&self) -> Option<&AbortToken> {
        self.ep.abort_token()
    }

    /// How often parked receives re-check teardown / abort / timeout
    /// state — the abort detection granularity.
    pub fn set_abort_poll(&mut self, poll: Duration) {
        self.ep.set_abort_poll(poll);
    }

    /// Grace window a lane worker gets past the receive timeout before
    /// its collect gives up with [`Error::LaneWorkerLost`].
    pub fn set_shutdown_grace(&mut self, grace: Duration) {
        self.ep.set_shutdown_grace(grace);
    }

    /// Arm a deterministic fault plan on this rank's endpoint (chaos
    /// testing). Specs fire against this communicator's op sequence.
    pub fn arm_faults(&mut self, plan: FaultPlan) {
        self.ep.arm_faults(plan);
    }

    /// Disarm fault injection (clears a latched kill too).
    pub fn clear_faults(&mut self) {
        self.ep.clear_faults();
    }

    /// Current recovery epoch.
    pub fn epoch(&self) -> u32 {
        self.epoch
    }

    /// Enter the next recovery epoch after an aborted collective. Drains
    /// every lane queue (reclaiming stale messages and stale poison),
    /// disarms fault injection, re-derives the tag context with the new
    /// epoch folded in, and resets the op sequence — all ranks of the
    /// world must call this the same number of times, like any collective
    /// configuration change.
    pub fn bump_epoch(&mut self) -> Result<()> {
        self.epoch = self.epoch.wrapping_add(1);
        self.ctx = fnv64_step(self.base_ctx, self.epoch as u64);
        self.op_seq = 0;
        self.ep.set_epoch(self.epoch);
        self.ep.clear_faults();
        self.ep.drain()
    }

    /// Rebuild the world without `dead` ranks after an abort: bumps the
    /// recovery epoch (draining stale traffic), then returns the survivor
    /// sub-communicator this rank runs post-recovery collectives on.
    /// Every survivor must call `shrink` with the same dead list; sub-rank
    /// order is ascending global rank. Calling it on a dead rank is an
    /// error — that rank is out of the world by definition.
    pub fn shrink(&mut self, dead: &[usize]) -> Result<SubComm<'_, T>> {
        if dead.contains(&self.ep.rank()) {
            return Err(Error::InvalidTopology(format!(
                "rank {} cannot shrink around its own death",
                self.ep.rank()
            )));
        }
        let survivors: Vec<usize> =
            (0..self.ep.size()).filter(|r| !dead.contains(r)).collect();
        // An empty dead list still enters a fresh epoch: the caller gets
        // the same stale-message guarantees either way.
        self.bump_epoch()?;
        self.subcomm(survivors)
    }

    /// Borrowed sub-communicator over `group` (global ranks, which must
    /// contain this rank). Order of `group` defines sub-ranks.
    pub fn subcomm(&mut self, group: Vec<usize>) -> Result<SubComm<'_, T>> {
        let Some(rank) = group.iter().position(|&g| g == self.ep.rank()) else {
            return Err(Error::InvalidTopology(format!(
                "rank {} not in subgroup {:?}",
                self.ep.rank(),
                group
            )));
        };
        for &g in &group {
            if g >= self.ep.size() {
                return Err(Error::PeerOutOfRange {
                    peer: g,
                    size: self.ep.size(),
                });
            }
        }
        let ctx = fnv64(
            std::iter::once(self.ctx).chain(group.iter().map(|&g| g as u64)),
        );
        Ok(SubComm {
            ep: &mut self.ep,
            group,
            rank,
            ctx,
            op_seq: 0,
        })
    }

    /// This rank's inter-node sub-communicator (same local id across nodes).
    pub fn inter_node(&mut self) -> Result<SubComm<'_, T>> {
        let g = self.topo.inter_node_group(self.ep.rank());
        self.subcomm(g)
    }

    /// This rank's intra-node sub-communicator (all ranks on its node).
    pub fn intra_node(&mut self) -> Result<SubComm<'_, T>> {
        let g = self.topo.intra_node_group(self.ep.rank());
        self.subcomm(g)
    }

    /// Transport lanes available for striping (inherent mirror of
    /// [`Comm::lanes`]).
    pub fn lanes(&self) -> usize {
        self.ep.lane_count()
    }

    /// Per-lane traffic counters on this rank's endpoint.
    pub fn traffic_per_lane(&self) -> Vec<Traffic> {
        self.ep.traffic_per_lane()
    }

    /// A single-lane [`Comm`] view pinned to transport lane `lane`: every
    /// send/receive rides that lane's queue with the lane id folded into
    /// the wire tag. Lane views share this communicator's op sequence, so
    /// interleaving ops on different lanes stays tag-fresh.
    pub fn lane_comm(&mut self, lane: usize) -> Result<LaneComm<'_, T>> {
        if lane >= self.ep.lane_count() {
            return Err(Error::PeerOutOfRange {
                peer: lane,
                size: self.ep.lane_count(),
            });
        }
        Ok(LaneComm { c: self, lane })
    }

    fn stripe_tags(&self, step: u32, k: usize) -> Vec<u64> {
        (0..k)
            .map(|l| compose_tag_lane(self.ctx, self.op_seq, step, l))
            .collect()
    }
}

impl<T: Send + Sync + 'static> Comm<T> for Communicator<T> {
    fn rank(&self) -> usize {
        self.ep.rank()
    }

    fn size(&self) -> usize {
        self.ep.size()
    }

    fn send_slice(&mut self, peer: usize, step: u32, chunk: Chunk<T>) -> Result<()> {
        let tag = compose_tag(self.ctx, self.op_seq, step);
        self.ep.send_chunk(peer, tag, chunk)
    }

    fn recv_chunk(&mut self, peer: usize, step: u32) -> Result<Chunk<T>> {
        let tag = compose_tag(self.ctx, self.op_seq, step);
        self.ep.recv_chunk(peer, tag)
    }

    fn recv_into(&mut self, peer: usize, step: u32, dest: &mut Chunk<T>) -> Result<()>
    where
        T: Clone,
    {
        let tag = compose_tag(self.ctx, self.op_seq, step);
        self.ep.recv_chunk_into(peer, tag, dest)
    }

    fn recv_combine_into(
        &mut self,
        peer: usize,
        step: u32,
        dest: &mut Chunk<T>,
        combiner: &Combiner<T>,
    ) -> Result<()>
    where
        T: Clone,
    {
        let tag = compose_tag(self.ctx, self.op_seq, step);
        self.ep.recv_chunk_combine_into(peer, tag, dest, combiner)
    }

    fn begin_op(&mut self) {
        self.op_seq = self.op_seq.wrapping_add(1);
        self.ep.note_op_seq(self.op_seq);
    }

    fn abort_armed(&self) -> bool {
        self.ep.abort_token().is_some()
    }

    fn broadcast_abort(&mut self, cause: &str) {
        self.ep.broadcast_abort(self.op_seq, cause);
    }

    fn current_op_seq(&self) -> u64 {
        self.op_seq
    }

    fn op_clock(&self) -> (u64, u64) {
        self.ep.op_clock()
    }

    fn lanes(&self) -> usize {
        self.ep.lane_count()
    }

    fn send_striped(&mut self, peer: usize, step: u32, stripes: Vec<Chunk<T>>) -> Result<()> {
        for (l, s) in stripes.into_iter().enumerate() {
            let tag = compose_tag_lane(self.ctx, self.op_seq, step, l);
            self.ep.send_chunk_on(peer, l, tag, s)?;
        }
        Ok(())
    }

    fn recv_striped(&mut self, peer: usize, step: u32, k: usize) -> Result<Vec<Chunk<T>>> {
        let tags = self.stripe_tags(step, k);
        self.ep.recv_striped(peer, &tags)
    }

    fn recv_striped_into(&mut self, peer: usize, step: u32, dests: &mut [Chunk<T>]) -> Result<()>
    where
        T: Clone,
    {
        let tags = self.stripe_tags(step, dests.len());
        self.ep.recv_striped_into(peer, &tags, dests)
    }

    fn recv_striped_combine_into(
        &mut self,
        peer: usize,
        step: u32,
        dests: &mut [Chunk<T>],
        combiner: &Combiner<T>,
    ) -> Result<()>
    where
        T: Clone,
    {
        let tags = self.stripe_tags(step, dests.len());
        self.ep.recv_striped_combine_into(peer, &tags, dests, combiner)
    }
}

/// A [`Comm`] view pinned to one transport lane of a [`Communicator`] —
/// single-lane from the algorithm's point of view ([`Comm::lanes`] = 1),
/// but all traffic rides lane `lane`'s queue with lane-folded tags. Used
/// to run independent single-lane schedules side by side (and by tests to
/// prove lane isolation).
pub struct LaneComm<'a, T> {
    c: &'a mut Communicator<T>,
    lane: usize,
}

impl<'a, T: Send + Sync + 'static> LaneComm<'a, T> {
    /// The transport lane this view is pinned to.
    pub fn lane(&self) -> usize {
        self.lane
    }
}

impl<'a, T: Send + Sync + 'static> Comm<T> for LaneComm<'a, T> {
    fn rank(&self) -> usize {
        self.c.rank()
    }

    fn size(&self) -> usize {
        self.c.size()
    }

    fn send_slice(&mut self, peer: usize, step: u32, chunk: Chunk<T>) -> Result<()> {
        let tag = compose_tag_lane(self.c.ctx, self.c.op_seq, step, self.lane);
        self.c.ep.send_chunk_on(peer, self.lane, tag, chunk)
    }

    fn recv_chunk(&mut self, peer: usize, step: u32) -> Result<Chunk<T>> {
        let tag = compose_tag_lane(self.c.ctx, self.c.op_seq, step, self.lane);
        self.c.ep.recv_chunk_on(self.lane, peer, tag)
    }

    fn recv_into(&mut self, peer: usize, step: u32, dest: &mut Chunk<T>) -> Result<()>
    where
        T: Clone,
    {
        let tag = compose_tag_lane(self.c.ctx, self.c.op_seq, step, self.lane);
        self.c.ep.recv_chunk_into_on(self.lane, peer, tag, dest)
    }

    fn recv_combine_into(
        &mut self,
        peer: usize,
        step: u32,
        dest: &mut Chunk<T>,
        combiner: &Combiner<T>,
    ) -> Result<()>
    where
        T: Clone,
    {
        let tag = compose_tag_lane(self.c.ctx, self.c.op_seq, step, self.lane);
        self.c
            .ep
            .recv_chunk_combine_into_on(self.lane, peer, tag, dest, combiner)
    }

    fn begin_op(&mut self) {
        self.c.op_seq = self.c.op_seq.wrapping_add(1);
        self.c.ep.note_op_seq(self.c.op_seq);
    }

    fn abort_armed(&self) -> bool {
        self.c.ep.abort_token().is_some()
    }

    fn broadcast_abort(&mut self, cause: &str) {
        self.c.ep.broadcast_abort(self.c.op_seq, cause);
    }

    fn current_op_seq(&self) -> u64 {
        self.c.op_seq
    }

    fn op_clock(&self) -> (u64, u64) {
        self.c.ep.op_clock()
    }
}

/// Borrowed view over a subset of world ranks.
pub struct SubComm<'a, T> {
    ep: &'a mut Endpoint<T>,
    group: Vec<usize>,
    rank: usize,
    ctx: u64,
    op_seq: u64,
}

impl<'a, T: Send + Sync + 'static> SubComm<'a, T> {
    /// The global (world) ranks of this subgroup, in sub-rank order.
    pub fn group(&self) -> &[usize] {
        &self.group
    }

    fn global(&self, peer: usize) -> Result<usize> {
        self.group.get(peer).copied().ok_or(Error::PeerOutOfRange {
            peer,
            size: self.group.len(),
        })
    }

    fn stripe_tags(&self, step: u32, k: usize) -> Vec<u64> {
        (0..k)
            .map(|l| compose_tag_lane(self.ctx, self.op_seq, step, l))
            .collect()
    }
}

impl<'a, T: Send + Sync + 'static> Comm<T> for SubComm<'a, T> {
    fn rank(&self) -> usize {
        self.rank
    }

    fn size(&self) -> usize {
        self.group.len()
    }

    fn send_slice(&mut self, peer: usize, step: u32, chunk: Chunk<T>) -> Result<()> {
        let global = *self.group.get(peer).ok_or(Error::PeerOutOfRange {
            peer,
            size: self.group.len(),
        })?;
        let tag = compose_tag(self.ctx, self.op_seq, step);
        self.ep.send_chunk(global, tag, chunk)
    }

    fn recv_chunk(&mut self, peer: usize, step: u32) -> Result<Chunk<T>> {
        let global = *self.group.get(peer).ok_or(Error::PeerOutOfRange {
            peer,
            size: self.group.len(),
        })?;
        let tag = compose_tag(self.ctx, self.op_seq, step);
        self.ep.recv_chunk(global, tag)
    }

    fn recv_into(&mut self, peer: usize, step: u32, dest: &mut Chunk<T>) -> Result<()>
    where
        T: Clone,
    {
        let global = *self.group.get(peer).ok_or(Error::PeerOutOfRange {
            peer,
            size: self.group.len(),
        })?;
        let tag = compose_tag(self.ctx, self.op_seq, step);
        self.ep.recv_chunk_into(global, tag, dest)
    }

    fn recv_combine_into(
        &mut self,
        peer: usize,
        step: u32,
        dest: &mut Chunk<T>,
        combiner: &Combiner<T>,
    ) -> Result<()>
    where
        T: Clone,
    {
        let global = *self.group.get(peer).ok_or(Error::PeerOutOfRange {
            peer,
            size: self.group.len(),
        })?;
        let tag = compose_tag(self.ctx, self.op_seq, step);
        self.ep.recv_chunk_combine_into(global, tag, dest, combiner)
    }

    fn begin_op(&mut self) {
        self.op_seq = self.op_seq.wrapping_add(1);
        self.ep.note_op_seq(self.op_seq);
    }

    fn abort_armed(&self) -> bool {
        self.ep.abort_token().is_some()
    }

    fn broadcast_abort(&mut self, cause: &str) {
        self.ep.broadcast_abort(self.op_seq, cause);
    }

    fn current_op_seq(&self) -> u64 {
        self.op_seq
    }

    fn op_clock(&self) -> (u64, u64) {
        self.ep.op_clock()
    }

    fn lanes(&self) -> usize {
        self.ep.lane_count()
    }

    fn send_striped(&mut self, peer: usize, step: u32, stripes: Vec<Chunk<T>>) -> Result<()> {
        let global = self.global(peer)?;
        for (l, s) in stripes.into_iter().enumerate() {
            let tag = compose_tag_lane(self.ctx, self.op_seq, step, l);
            self.ep.send_chunk_on(global, l, tag, s)?;
        }
        Ok(())
    }

    fn recv_striped(&mut self, peer: usize, step: u32, k: usize) -> Result<Vec<Chunk<T>>> {
        let global = self.global(peer)?;
        let tags = self.stripe_tags(step, k);
        self.ep.recv_striped(global, &tags)
    }

    fn recv_striped_into(&mut self, peer: usize, step: u32, dests: &mut [Chunk<T>]) -> Result<()>
    where
        T: Clone,
    {
        let global = self.global(peer)?;
        let tags = self.stripe_tags(step, dests.len());
        self.ep.recv_striped_into(global, &tags, dests)
    }

    fn recv_striped_combine_into(
        &mut self,
        peer: usize,
        step: u32,
        dests: &mut [Chunk<T>],
        combiner: &Combiner<T>,
    ) -> Result<()>
    where
        T: Clone,
    {
        let global = self.global(peer)?;
        let tags = self.stripe_tags(step, dests.len());
        self.ep.recv_striped_combine_into(global, &tags, dests, combiner)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::transport::TransportHub;

    fn pair() -> (Communicator<f32>, Communicator<f32>) {
        let (_hub, mut eps) = TransportHub::<f32>::new(2);
        let e1 = eps.pop().unwrap();
        let e0 = eps.pop().unwrap();
        let t = Topology::flat(2);
        (
            Communicator::new(e0, t).unwrap(),
            Communicator::new(e1, t).unwrap(),
        )
    }

    #[test]
    fn world_send_recv() {
        let (mut c0, mut c1) = pair();
        c0.send_slice(1, 0, Chunk::from_vec(vec![42.0])).unwrap();
        assert_eq!(c1.recv_chunk(0, 0).unwrap(), vec![42.0]);
    }

    #[test]
    fn posted_receive_through_communicator_counts_moved_bytes() {
        let (mut c0, mut c1) = pair();
        // Exclusive message: posted delivery is a move, counters prove it.
        c0.send_slice(1, 0, Chunk::from_vec(vec![1.0, 2.0])).unwrap();
        let mut dest = Chunk::from_vec(vec![0.0; 2]);
        c1.recv_into(0, 0, &mut dest).unwrap();
        assert_eq!(dest.as_slice(), &[1.0, 2.0]);
        let t = c1.traffic();
        assert_eq!((t.recvd_bytes, t.moved_bytes, t.copied_bytes), (8, 8, 0));

        // Posted combining receive: exclusive accumulator folds in place.
        let sum = crate::reduction::offload::native_combine::<f32>();
        c0.send_slice(1, 1, Chunk::from_vec(vec![10.0, 20.0])).unwrap();
        let id = dest.storage_id();
        c1.recv_combine_into(0, 1, &mut dest, &sum).unwrap();
        assert_eq!(dest.storage_id(), id, "accumulator storage is stable");
        assert_eq!(dest.as_slice(), &[11.0, 22.0]);
        let t = c1.traffic();
        assert_eq!((t.moved_bytes, t.copied_bytes), (16, 0));
    }

    #[test]
    fn posted_receive_shape_mismatch_is_typed_at_comm_level() {
        let (mut c0, mut c1) = pair();
        c0.send_slice(1, 0, Chunk::from_vec(vec![1.0, 2.0, 3.0])).unwrap();
        let mut small = Chunk::from_vec(vec![0.0; 2]);
        match c1.recv_into(0, 0, &mut small) {
            Err(Error::RecvShapeMismatch { src: 0, expected: 2, got: 3, .. }) => {}
            other => panic!("expected RecvShapeMismatch, got {other:?}"),
        }
        // Recoverable: a correctly sized post still matches the message.
        let mut right = Chunk::from_vec(vec![0.0; 3]);
        c1.recv_into(0, 0, &mut right).unwrap();
        assert_eq!(right.as_slice(), &[1.0, 2.0, 3.0]);
    }

    #[test]
    fn chunk_send_recv_shares_storage() {
        let (mut c0, mut c1) = pair();
        let data = Chunk::from_vec(vec![1.0f32, 2.0, 3.0, 4.0]);
        let id = data.storage_id();
        c0.send_slice(1, 0, data.slice(1, 2)).unwrap();
        let got = c1.recv_chunk(0, 0).unwrap();
        assert_eq!(got.as_slice(), &[2.0, 3.0]);
        assert_eq!(got.storage_id(), id);
    }

    #[test]
    fn subcomm_rank_translation() {
        let (_hub, eps) = TransportHub::<i32>::new(4);
        let topo = Topology::new(2, 2, 1).unwrap();
        let mut comms: Vec<Communicator<i32>> = eps
            .into_iter()
            .map(|e| Communicator::new(e, topo).unwrap())
            .collect();
        // rank 1 and rank 3 share local id 1 → inter-node group [1, 3].
        let c3 = comms.pop().unwrap();
        let _c2 = comms.pop().unwrap();
        let c1 = comms.pop().unwrap();
        let mut c1 = c1;
        let mut c3 = c3;
        {
            let mut s1 = c1.inter_node().unwrap();
            assert_eq!(s1.group(), &[1, 3]);
            assert_eq!(s1.rank(), 0);
            assert_eq!(s1.size(), 2);
            s1.send_slice(1, 0, Chunk::from_vec(vec![7])).unwrap();
        }
        {
            let mut s3 = c3.inter_node().unwrap();
            assert_eq!(s3.rank(), 1);
            assert_eq!(s3.recv_chunk(0, 0).unwrap(), vec![7]);
        }
    }

    #[test]
    fn subcomm_requires_membership() {
        let (mut c0, _c1) = pair();
        assert!(c0.subcomm(vec![1]).is_err());
        assert!(c0.subcomm(vec![0, 9]).is_err());
    }

    #[test]
    fn distinct_contexts_do_not_cross_talk() {
        let (_hub, eps) = TransportHub::<i32>::new(4);
        let topo = Topology::new(2, 2, 1).unwrap();
        let mut comms: Vec<Communicator<i32>> = eps
            .into_iter()
            .map(|e| Communicator::new(e, topo).unwrap())
            .collect();
        // World-send from 0 to 1 and subcomm-send from 0 to 1 with the same
        // step must be distinguishable by tag.
        let mut c1 = comms.remove(1);
        let mut c0 = comms.remove(0);
        c0.send_slice(1, 0, Chunk::from_vec(vec![100])).unwrap();
        {
            let mut s0 = c0.subcomm(vec![0, 1]).unwrap();
            s0.send_slice(1, 0, Chunk::from_vec(vec![200])).unwrap();
        }
        {
            let mut s1 = c1.subcomm(vec![0, 1]).unwrap();
            assert_eq!(s1.recv_chunk(0, 0).unwrap(), vec![200]);
        }
        assert_eq!(c1.recv_chunk(0, 0).unwrap(), vec![100]);
    }

    #[test]
    fn barrier_completes() {
        let (_hub, eps) = TransportHub::<f32>::new(8);
        let topo = Topology::flat(8);
        let handles: Vec<_> = eps
            .into_iter()
            .map(|e| {
                std::thread::spawn(move || {
                    let mut c = Communicator::new(e, topo).unwrap();
                    for _ in 0..5 {
                        c.barrier().unwrap();
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
    }

    #[test]
    fn compose_tag_never_aliases_dense_op_step_grids() {
        // Regression for the XOR-shift scheme: high-frequency ops on a
        // long-lived subcomm must never reuse a tag across (op, step).
        let ctx = fnv64([0xC0, 8]);
        let mut seen = std::collections::HashSet::new();
        // Dense band of fresh ops × steps, plus a band deep into a
        // long-lived communicator's op sequence.
        for base in [0u64, 1 << 20, 1 << 40] {
            for op in 0..1024u64 {
                for step in 0..48u32 {
                    assert!(
                        seen.insert(compose_tag(ctx, base + op, step)),
                        "tag alias at op={} step={step}",
                        base + op
                    );
                }
            }
        }
    }

    fn lane_pair(lanes: usize) -> (Communicator<f32>, Communicator<f32>) {
        let (_hub, mut eps) = TransportHub::<f32>::new_with_lanes(2, lanes);
        let e1 = eps.pop().unwrap();
        let e0 = eps.pop().unwrap();
        let t = Topology::flat(2);
        (
            Communicator::new(e0, t).unwrap(),
            Communicator::new(e1, t).unwrap(),
        )
    }

    #[test]
    fn striped_exchange_roundtrip_uneven() {
        let (mut c0, mut c1) = lane_pair(3);
        assert_eq!(Comm::lanes(&c0), 3);
        let data = Chunk::from_vec((0..7).map(|i| i as f32).collect::<Vec<_>>());
        c0.send_striped(1, 0, data.stripes(3)).unwrap();
        let got = c1.recv_striped(0, 0, 3).unwrap();
        assert_eq!(Chunk::concat(&got), data.to_vec());
        // Stripes share the sender's storage end to end — zero-copy views.
        assert!(got.iter().all(|s| s.storage_id() == data.storage_id()));
    }

    #[test]
    fn striped_combine_folds_lane_parallel_stripes() {
        let sum = crate::reduction::offload::native_combine::<f32>();
        let (mut c0, mut c1) = lane_pair(4);
        let incoming = Chunk::from_vec(vec![1.0; 10]);
        c0.send_striped(1, 2, incoming.stripes(4)).unwrap();
        let acc = Chunk::from_vec(vec![5.0; 10]);
        let mut dests = acc.stripes(4);
        c1.recv_striped_combine_into(0, 2, &mut dests, &sum).unwrap();
        assert_eq!(Chunk::concat(&dests), vec![6.0; 10]);
        let t = c1.traffic();
        assert_eq!(t.copied_bytes, 0, "striped combine path must stay copy-free");
        assert_eq!(t.recvd_msgs, 4);
    }

    #[test]
    fn lanes_never_cross_deliver_same_step() {
        // Same (op, step) posted on every lane: each lane view must get
        // its own payload back, never a neighbor lane's.
        let (mut c0, mut c1) = lane_pair(3);
        for l in 0..3 {
            c0.lane_comm(l)
                .unwrap()
                .send_slice(1, 7, Chunk::from_vec(vec![l as f32]))
                .unwrap();
        }
        for l in (0..3).rev() {
            let got = c1.lane_comm(l).unwrap().recv_chunk(0, 7).unwrap();
            assert_eq!(got.as_slice(), &[l as f32], "lane {l} cross-delivered");
        }
    }

    #[test]
    fn stale_lane_tag_never_matches_new_op() {
        // Regression in the spirit of the op-seq wire-tag tests: a stripe
        // posted under op N must not match the same (step, lane) of op
        // N+1, even on the same lane queue.
        let (mut c0, mut c1) = lane_pair(2);
        c0.send_striped(1, 0, Chunk::from_vec(vec![1.0f32, 2.0]).stripes(2))
            .unwrap();
        // Receiver advances its op sequence before looking: the stale
        // stripes must stash, not match, so the receive times out.
        c1.begin_op();
        c1.set_timeout(Duration::from_millis(30));
        match c1.recv_striped(0, 0, 2) {
            Err(Error::RecvTimeout { .. }) => {}
            other => panic!("stale-lane stripes matched a fresh op: {other:?}"),
        }
    }

    #[test]
    fn lane_comm_rejects_out_of_range_lane() {
        let (mut c0, _c1) = lane_pair(2);
        assert!(c0.lane_comm(1).is_ok());
        assert!(matches!(
            c0.lane_comm(2).err(),
            Some(Error::PeerOutOfRange { peer: 2, size: 2 })
        ));
    }

    #[test]
    fn default_striped_methods_work_single_queue() {
        // The trait defaults (stripe-in-step encoding over one queue) must
        // be functionally identical for impls that don't override them.
        let (mut c0, mut c1) = pair();
        assert_eq!(Comm::lanes(&c0), 1);
        let data = Chunk::from_vec(vec![1.0f32, 2.0, 3.0]);
        c0.send_striped(1, 0, data.stripes(1)).unwrap();
        let mut dests = Chunk::from_vec(vec![0.0f32; 3]).stripes(1);
        c1.recv_striped_into(0, 0, &mut dests).unwrap();
        assert_eq!(Chunk::concat(&dests), vec![1.0, 2.0, 3.0]);
    }

    #[test]
    fn subcomm_striping_translates_ranks() {
        let (_hub, eps) = TransportHub::<i32>::new_with_lanes(4, 2);
        let topo = Topology::new(2, 2, 1).unwrap();
        let mut comms: Vec<Communicator<i32>> = eps
            .into_iter()
            .map(|e| Communicator::new(e, topo).unwrap())
            .collect();
        let mut c3 = comms.pop().unwrap();
        let _c2 = comms.pop().unwrap();
        let mut c1 = comms.pop().unwrap();
        {
            let mut s1 = c1.inter_node().unwrap();
            assert_eq!(Comm::lanes(&s1), 2);
            s1.send_striped(1, 0, Chunk::from_vec(vec![7, 8, 9]).stripes(2))
                .unwrap();
        }
        {
            let mut s3 = c3.inter_node().unwrap();
            let got = s3.recv_striped(0, 0, 2).unwrap();
            assert_eq!(Chunk::concat(&got), vec![7, 8, 9]);
        }
    }

    #[test]
    fn bump_epoch_retags_and_drains_stale_traffic() {
        let (mut c0, mut c1) = pair();
        // A message posted in epoch 0...
        c0.send_slice(1, 0, Chunk::from_vec(vec![9.0])).unwrap();
        // ...must never match the same (op, step) after recovery: the
        // epoch is folded into the context ahead of everything else.
        c1.bump_epoch().unwrap();
        c1.set_timeout(Duration::from_millis(30));
        assert!(matches!(c1.recv_chunk(0, 0), Err(Error::RecvTimeout { .. })));
        assert_eq!(c1.epoch(), 1);
        // Once the sender recovers too, the worlds agree again.
        c0.bump_epoch().unwrap();
        c0.send_slice(1, 0, Chunk::from_vec(vec![4.0])).unwrap();
        assert_eq!(c1.recv_chunk(0, 0).unwrap(), vec![4.0]);
    }

    #[test]
    fn shrink_rebuilds_survivor_world() {
        let (_hub, eps) = TransportHub::<i32>::new(4);
        let topo = Topology::flat(4);
        let mut comms: Vec<Communicator<i32>> = eps
            .into_iter()
            .map(|e| Communicator::new(e, topo).unwrap())
            .collect();
        let mut c2 = comms.remove(2);
        let mut c0 = comms.remove(0);
        // Rank 1 and 3 "died"; survivors shrink around them.
        {
            let mut s0 = c0.shrink(&[1, 3]).unwrap();
            assert_eq!(s0.group(), &[0, 2]);
            assert_eq!(s0.rank(), 0);
            s0.send_slice(1, 0, Chunk::from_vec(vec![77])).unwrap();
        }
        {
            let mut s2 = c2.shrink(&[1, 3]).unwrap();
            assert_eq!(s2.rank(), 1);
            assert_eq!(s2.recv_chunk(0, 0).unwrap(), vec![77]);
        }
        // A dead rank cannot shrink around itself.
        let mut c1 = comms.remove(0);
        assert!(c1.shrink(&[1, 3]).is_err());
    }

    #[test]
    fn abort_defaults_and_endpoint_overrides() {
        let (mut c0, _c1) = pair();
        assert!(!c0.abort_armed());
        c0.arm_abort(AbortToken::new());
        assert!(c0.abort_armed());
        c0.begin_op();
        assert_eq!(c0.current_op_seq(), 1);
        assert_eq!(Comm::op_clock(&c0), (0, 0), "no traffic yet");
    }

    #[test]
    fn compose_tag_is_not_linear() {
        // Under the old scheme, (op_seq=1, step=0) and (op_seq=0,
        // step=1<<16) produced the same tag: (1<<16) ^ 0 == 0 ^ (1<<16).
        let ctx = fnv64([0xC0, 4]);
        assert_ne!(compose_tag(ctx, 1, 0), compose_tag(ctx, 0, 1 << 16));
        assert_ne!(compose_tag(ctx, 3, 5), compose_tag(ctx, 5, 3));
    }
}
