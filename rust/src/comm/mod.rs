//! The data plane: a real multi-rank communicator.
//!
//! Ranks are OS threads ("simulated GPUs") exchanging typed buffers through
//! an in-process transport with MPI-style tag matching. Messages are
//! [`Chunk`]s — shared, sliceable buffer views — so the collective hot
//! path forwards and sub-slices without copying. The collective algorithms
//! in [`crate::collectives`] run unmodified over this layer; on a real
//! deployment the [`transport`] would be swapped for RDMA / libfabric
//! endpoints backed by registered memory regions — nothing above it would
//! change (a `Chunk` maps onto an MR offset/length pair).

mod chunk;
mod communicator;
mod transport;
mod world;

pub use chunk::{stripe_lens, Chunk};
pub use communicator::{Comm, Communicator, LaneComm, SubComm};
pub use transport::{
    AbortToken, Endpoint, FaultAction, FaultPlan, FaultSpec, Traffic, TransportHub,
    DEFAULT_RECV_TIMEOUT, DEFAULT_SHUTDOWN_GRACE,
};
pub use world::CommWorld;
