//! The data plane: a real multi-rank communicator.
//!
//! Ranks are OS threads ("simulated GPUs") exchanging typed buffers through
//! an in-process transport with MPI-style tag matching. The collective
//! algorithms in [`crate::collectives`] run unmodified over this layer; on a
//! real deployment the [`transport`] would be swapped for RDMA/ libfabric
//! endpoints — nothing above it would change.

mod communicator;
mod transport;
mod world;

pub use communicator::{Comm, Communicator, SubComm};
pub use transport::{Endpoint, TransportHub, DEFAULT_RECV_TIMEOUT};
pub use world::CommWorld;
