//! `CommWorld` — spawn a world of rank threads and run SPMD closures.
//!
//! This is the top-level entry point for examples, tests, and the training
//! drivers: it owns the transport, spawns one OS thread per rank ("one GPU
//! per process" in the paper's terms), hands each a [`Communicator`], and
//! joins the results.

use std::marker::PhantomData;
use std::time::Duration;

use crate::error::Result;
use crate::topology::Topology;

use super::communicator::Communicator;
use super::transport::{AbortToken, FaultPlan, TransportHub};

/// Factory for SPMD runs over `size` rank threads.
pub struct CommWorld<T> {
    topo: Topology,
    lanes: usize,
    abort: Option<AbortToken>,
    timeout: Option<Duration>,
    faults: Option<FaultPlan>,
    _t: PhantomData<T>,
}

impl<T: Send + Sync + Clone + 'static> CommWorld<T> {
    /// Flat world (one "node" containing all ranks).
    pub fn new(size: usize) -> Self {
        Self {
            topo: Topology::flat(size),
            lanes: 1,
            abort: None,
            timeout: None,
            faults: None,
            _t: PhantomData,
        }
    }

    /// World with an explicit node/GPU/NIC topology.
    pub fn with_topology(topo: Topology) -> Self {
        Self {
            topo,
            lanes: 1,
            abort: None,
            timeout: None,
            faults: None,
            _t: PhantomData,
        }
    }

    /// Give every rank pair `lanes` transport lanes (striped collectives
    /// run lane-parallel; `1` is the plain single-queue transport).
    pub fn with_lanes(mut self, lanes: usize) -> Self {
        assert!(lanes >= 1, "world needs at least one lane");
        self.lanes = lanes;
        self
    }

    /// Arm a shared [`AbortToken`] on every rank of each run: any rank's
    /// failure poisons the world and every peer returns
    /// [`crate::error::Error::CollectiveAborted`] within the detection
    /// window instead of sleeping out its receive timeout. The token is
    /// also readable from outside the run via [`CommWorld::abort_token`].
    pub fn with_abort(mut self) -> Self {
        self.abort = Some(AbortToken::new());
        self
    }

    /// Set every rank's receive timeout (the failure-detection bound for
    /// faults nobody survives to announce, e.g. a killed rank).
    pub fn with_recv_timeout(mut self, timeout: Duration) -> Self {
        self.timeout = Some(timeout);
        self
    }

    /// Arm the same deterministic [`FaultPlan`] on every rank of each run
    /// (each rank's endpoint fires only the specs naming its own rank).
    pub fn with_fault_plan(mut self, plan: FaultPlan) -> Self {
        self.faults = Some(plan);
        self
    }

    /// The armed abort token, if [`CommWorld::with_abort`] was called.
    pub fn abort_token(&self) -> Option<&AbortToken> {
        self.abort.as_ref()
    }

    pub fn size(&self) -> usize {
        self.topo.world_size()
    }

    pub fn topology(&self) -> Topology {
        self.topo
    }

    pub fn lanes(&self) -> usize {
        self.lanes
    }

    /// Run `f` on every rank concurrently; returns per-rank results in rank
    /// order. Panics in a rank thread are propagated.
    pub fn run<R, F>(&self, f: F) -> Vec<R>
    where
        R: Send + 'static,
        F: Fn(&mut Communicator<T>) -> R + Send + Clone + 'static,
    {
        let (_hub, eps) = if self.lanes == 1 {
            TransportHub::<T>::new(self.size())
        } else {
            TransportHub::<T>::new_with_lanes(self.size(), self.lanes)
        };
        let topo = self.topo;
        let handles: Vec<_> = eps
            .into_iter()
            .map(|ep| {
                let f = f.clone();
                let abort = self.abort.clone();
                let timeout = self.timeout;
                let faults = self.faults.clone();
                std::thread::Builder::new()
                    .name(format!("pccl-rank-{}", ep.rank()))
                    .spawn(move || {
                        let mut comm =
                            Communicator::new(ep, topo).expect("topology/transport mismatch");
                        if let Some(tok) = abort {
                            comm.arm_abort(tok);
                        }
                        if let Some(t) = timeout {
                            comm.set_timeout(t);
                        }
                        if let Some(plan) = faults {
                            comm.arm_faults(plan);
                        }
                        f(&mut comm)
                    })
                    .expect("spawn rank thread")
            })
            .collect();
        handles
            .into_iter()
            .map(|h| h.join().expect("rank thread panicked"))
            .collect()
    }

    /// Like [`CommWorld::run`] but fallible: the first rank error is
    /// returned (remaining ranks may see transport-closed errors, which are
    /// discarded).
    pub fn try_run<R, F>(&self, f: F) -> Result<Vec<R>>
    where
        R: Send + 'static,
        F: Fn(&mut Communicator<T>) -> Result<R> + Send + Clone + 'static,
    {
        let results = self.run(f);
        results.into_iter().collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::communicator::Comm;

    #[test]
    fn spmd_ring_pass() {
        // Each rank sends its rank to the right neighbor; sum of received
        // values over all ranks = sum 0..p.
        let world = CommWorld::<f32>::new(6);
        let got = world.run(|c| {
            c.begin_op();
            let p = c.size();
            let r = c.rank();
            use crate::comm::Chunk;
            c.send_slice((r + 1) % p, 0, Chunk::from_vec(vec![r as f32]))
                .unwrap();
            c.recv_chunk((r + p - 1) % p, 0).unwrap()[0]
        });
        let total: f32 = got.iter().sum();
        assert_eq!(total, 15.0);
    }

    #[test]
    fn lane_world_striped_pass() {
        // Striped neighbor exchange across a 4-lane world: every rank's
        // payload survives the stripe/unstripe round trip.
        let world = CommWorld::<f32>::new(4).with_lanes(4);
        assert_eq!(world.lanes(), 4);
        let ok = world.run(|c| {
            c.begin_op();
            let p = c.size();
            let r = c.rank();
            use crate::comm::Chunk;
            let data = Chunk::from_vec((0..10).map(|i| (r * 100 + i) as f32).collect::<Vec<_>>());
            let k = c.lanes();
            c.send_striped((r + 1) % p, 0, data.stripes(k)).unwrap();
            let got = c.recv_striped((r + p - 1) % p, 0, k).unwrap();
            let left = (r + p - 1) % p;
            Chunk::concat(&got) == (0..10).map(|i| (left * 100 + i) as f32).collect::<Vec<_>>()
        });
        assert!(ok.into_iter().all(|b| b));
    }

    #[test]
    fn try_run_propagates_errors() {
        let world = CommWorld::<f32>::new(2);
        let r: Result<Vec<()>> = world.try_run(|c| {
            if c.rank() == 0 {
                Err(crate::error::Error::Dispatch("boom".into()))
            } else {
                Ok(())
            }
        });
        assert!(r.is_err());
    }
}
