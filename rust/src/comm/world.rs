//! `CommWorld` — spawn a world of rank threads and run SPMD closures.
//!
//! This is the top-level entry point for examples, tests, and the training
//! drivers: it owns the transport, spawns one OS thread per rank ("one GPU
//! per process" in the paper's terms), hands each a [`Communicator`], and
//! joins the results.

use std::marker::PhantomData;

use crate::error::Result;
use crate::topology::Topology;

use super::communicator::Communicator;
use super::transport::TransportHub;

/// Factory for SPMD runs over `size` rank threads.
pub struct CommWorld<T> {
    topo: Topology,
    _t: PhantomData<T>,
}

impl<T: Send + Sync + 'static> CommWorld<T> {
    /// Flat world (one "node" containing all ranks).
    pub fn new(size: usize) -> Self {
        Self {
            topo: Topology::flat(size),
            _t: PhantomData,
        }
    }

    /// World with an explicit node/GPU/NIC topology.
    pub fn with_topology(topo: Topology) -> Self {
        Self {
            topo,
            _t: PhantomData,
        }
    }

    pub fn size(&self) -> usize {
        self.topo.world_size()
    }

    pub fn topology(&self) -> Topology {
        self.topo
    }

    /// Run `f` on every rank concurrently; returns per-rank results in rank
    /// order. Panics in a rank thread are propagated.
    pub fn run<R, F>(&self, f: F) -> Vec<R>
    where
        R: Send + 'static,
        F: Fn(&mut Communicator<T>) -> R + Send + Clone + 'static,
    {
        let (_hub, eps) = TransportHub::<T>::new(self.size());
        let topo = self.topo;
        let handles: Vec<_> = eps
            .into_iter()
            .map(|ep| {
                let f = f.clone();
                std::thread::Builder::new()
                    .name(format!("pccl-rank-{}", ep.rank()))
                    .spawn(move || {
                        let mut comm =
                            Communicator::new(ep, topo).expect("topology/transport mismatch");
                        f(&mut comm)
                    })
                    .expect("spawn rank thread")
            })
            .collect();
        handles
            .into_iter()
            .map(|h| h.join().expect("rank thread panicked"))
            .collect()
    }

    /// Like [`CommWorld::run`] but fallible: the first rank error is
    /// returned (remaining ranks may see transport-closed errors, which are
    /// discarded).
    pub fn try_run<R, F>(&self, f: F) -> Result<Vec<R>>
    where
        R: Send + 'static,
        F: Fn(&mut Communicator<T>) -> Result<R> + Send + Clone + 'static,
    {
        let results = self.run(f);
        results.into_iter().collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::communicator::Comm;

    #[test]
    fn spmd_ring_pass() {
        // Each rank sends its rank to the right neighbor; sum of received
        // values over all ranks = sum 0..p.
        let world = CommWorld::<f32>::new(6);
        let got = world.run(|c| {
            c.begin_op();
            let p = c.size();
            let r = c.rank();
            use crate::comm::Chunk;
            c.send_slice((r + 1) % p, 0, Chunk::from_vec(vec![r as f32]))
                .unwrap();
            c.recv_chunk((r + p - 1) % p, 0).unwrap()[0]
        });
        let total: f32 = got.iter().sum();
        assert_eq!(total, 15.0);
    }

    #[test]
    fn try_run_propagates_errors() {
        let world = CommWorld::<f32>::new(2);
        let r: Result<Vec<()>> = world.try_run(|c| {
            if c.rank() == 0 {
                Err(crate::error::Error::Dispatch("boom".into()))
            } else {
                Ok(())
            }
        });
        assert!(r.is_err());
    }
}
