//! Run configuration for the CLI and training drivers (JSON via
//! [`crate::util::json`]).

use std::path::Path;

use crate::backends::Backend;
use crate::error::{Error, Result};
use crate::topology::Machine;
use crate::util::json::Value;

/// Configuration for a benchmark sweep (`pccl bench`, figure harness).
#[derive(Debug, Clone)]
pub struct SweepConfig {
    /// Machine model for netsim runs.
    pub machine: Machine,
    /// Per-rank message sizes in MiB.
    pub sizes_mb: Vec<usize>,
    /// Rank counts (GPUs/GCDs).
    pub ranks: Vec<usize>,
    /// Independent trials per cell.
    pub trials: usize,
    /// RNG seed for jitter reproducibility.
    pub seed: u64,
}

impl Default for SweepConfig {
    fn default() -> Self {
        Self {
            machine: Machine::Frontier,
            sizes_mb: vec![16, 32, 64, 128, 256, 512, 1024],
            ranks: vec![32, 64, 128, 256, 512, 1024, 2048],
            trials: 10,
            seed: 0xC011EC7,
        }
    }
}

impl SweepConfig {
    pub fn to_json(&self) -> Value {
        Value::obj(vec![
            (
                "machine",
                Value::Str(self.machine.params().name.to_string()),
            ),
            ("sizes_mb", Value::arr_usize(&self.sizes_mb)),
            ("ranks", Value::arr_usize(&self.ranks)),
            ("trials", Value::Num(self.trials as f64)),
            ("seed", Value::Num(self.seed as f64)),
        ])
    }

    pub fn from_json(v: &Value) -> Result<Self> {
        Ok(Self {
            machine: v.get("machine")?.as_str()?.parse().map_err(Error::Json)?,
            sizes_mb: v.get("sizes_mb")?.vec_usize()?,
            ranks: v.get("ranks")?.vec_usize()?,
            trials: v.get("trials")?.as_usize()?,
            seed: v.get("seed")?.as_f64()? as u64,
        })
    }

    pub fn save(&self, path: impl AsRef<Path>) -> Result<()> {
        std::fs::write(path, self.to_json().to_string())?;
        Ok(())
    }

    pub fn load(path: impl AsRef<Path>) -> Result<Self> {
        Self::from_json(&Value::parse(&std::fs::read_to_string(path)?)?)
    }
}

fn backend_from_label(s: &str) -> Result<Backend> {
    Backend::CONCRETE
        .iter()
        .copied()
        .chain([Backend::Auto])
        .find(|b| b.label() == s)
        .ok_or_else(|| Error::Json(format!("unknown backend {s:?}")))
}

/// Configuration for the end-to-end training examples.
#[derive(Debug, Clone)]
pub struct TrainConfig {
    /// Number of rank threads.
    pub ranks: usize,
    /// Steps to run.
    pub steps: usize,
    /// Learning rate for the host-side SGD update.
    pub lr: f32,
    /// Collective backend for gradient communication.
    pub backend: Backend,
    /// Artifact directory (defaults to `./artifacts`).
    pub artifacts: Option<String>,
    /// RNG seed for data generation.
    pub seed: u64,
}

impl Default for TrainConfig {
    fn default() -> Self {
        Self {
            ranks: 4,
            steps: 200,
            lr: 0.25,
            backend: Backend::PcclRec,
            artifacts: None,
            seed: 7,
        }
    }
}

impl TrainConfig {
    pub fn to_json(&self) -> Value {
        Value::obj(vec![
            ("ranks", Value::Num(self.ranks as f64)),
            ("steps", Value::Num(self.steps as f64)),
            ("lr", Value::Num(self.lr as f64)),
            ("backend", Value::Str(self.backend.label().to_string())),
            (
                "artifacts",
                match &self.artifacts {
                    Some(a) => Value::Str(a.clone()),
                    None => Value::Null,
                },
            ),
            ("seed", Value::Num(self.seed as f64)),
        ])
    }

    pub fn from_json(v: &Value) -> Result<Self> {
        Ok(Self {
            ranks: v.get("ranks")?.as_usize()?,
            steps: v.get("steps")?.as_usize()?,
            lr: v.get("lr")?.as_f64()? as f32,
            backend: backend_from_label(v.get("backend")?.as_str()?)?,
            artifacts: v
                .get_opt("artifacts")
                .map(|a| a.as_str().map(str::to_string))
                .transpose()?,
            seed: v.get("seed")?.as_f64()? as u64,
        })
    }

    pub fn save(&self, path: impl AsRef<Path>) -> Result<()> {
        std::fs::write(path, self.to_json().to_string())?;
        Ok(())
    }

    pub fn load(path: impl AsRef<Path>) -> Result<Self> {
        Self::from_json(&Value::parse(&std::fs::read_to_string(path)?)?)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::tmp::TempDir;

    #[test]
    fn sweep_roundtrip() {
        let dir = TempDir::new().unwrap();
        let p = dir.path().join("sweep.json");
        let cfg = SweepConfig::default();
        cfg.save(&p).unwrap();
        let back = SweepConfig::load(&p).unwrap();
        assert_eq!(back.sizes_mb, cfg.sizes_mb);
        assert_eq!(back.trials, 10);
        assert_eq!(back.machine, Machine::Frontier);
    }

    #[test]
    fn train_roundtrip_with_optional_fields() {
        let dir = TempDir::new().unwrap();
        let p = dir.path().join("train.json");
        let mut cfg = TrainConfig::default();
        cfg.backend = Backend::Vendor;
        cfg.artifacts = Some("custom/arts".into());
        cfg.save(&p).unwrap();
        let back = TrainConfig::load(&p).unwrap();
        assert_eq!(back.backend, Backend::Vendor);
        assert_eq!(back.artifacts.as_deref(), Some("custom/arts"));

        cfg.artifacts = None;
        cfg.save(&p).unwrap();
        let back = TrainConfig::load(&p).unwrap();
        assert!(back.artifacts.is_none());
    }
}
