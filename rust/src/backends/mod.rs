//! User-facing collective backends — the library surface of PCCL.
//!
//! PCCL's three-pronged design (§IV): (1) call the existing library when it
//! wins ([`Backend::Vendor`], [`Backend::CrayMpich`]); (2) new hierarchical
//! latency-optimized implementations ([`Backend::PcclRing`],
//! [`Backend::PcclRec`]); (3) a learning-based adaptive dispatcher that
//! picks among all of them at runtime ([`Backend::Auto`], backed by
//! [`crate::dispatch`]).
//!
//! On the in-process data plane the "existing libraries" are modeled by
//! their algorithms: vendor (NCCL/RCCL) = flat ring AG/RS + tree all-reduce;
//! Cray-MPICH = flat ring with host (CPU) reductions. Their *performance*
//! models live in [`crate::netsim::libmodel`].

use std::sync::Arc;

use crate::collectives::plan::{Algo, PlanKind, PlanSpec};
use crate::collectives::{
    hier_all_gather, hier_all_gather_chunks, hier_all_gather_lanes_chunks, hier_all_reduce_chunks,
    hier_all_reduce_lanes_chunks, hier_reduce_scatter_chunks, hier_reduce_scatter_lanes_chunks,
    ring_all_gather, ring_all_gather_chunks, ring_all_reduce_chunks, ring_reduce_scatter_chunks,
    slice_all_reduce, slice_reduce, tree_all_reduce_chunks, InterAlgo,
};
use crate::comm::{Chunk, Communicator};
use crate::error::Result;
use crate::reduction::offload::{native_combine, Combiner};
use crate::reduction::{Elem, ReduceOp};

/// Which collective implementation handles a call.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum Backend {
    /// The GPU vendor library (NCCL on Perlmutter, RCCL on Frontier):
    /// flat ring all-gather/reduce-scatter, double-binary-tree all-reduce.
    Vendor,
    /// Cray-MPICH: flat ring with CPU reductions and single-NIC routing
    /// (Observation 1).
    CrayMpich,
    /// PCCL hierarchical collectives with ring inter-node phase.
    PcclRing,
    /// PCCL hierarchical collectives with recursive doubling/halving
    /// inter-node phase.
    PcclRec,
    /// Learning-based adaptive dispatch over all of the above (§IV-C).
    Auto,
}

impl Backend {
    /// All concrete (dispatchable) backends.
    pub const CONCRETE: [Backend; 4] = [
        Backend::Vendor,
        Backend::CrayMpich,
        Backend::PcclRing,
        Backend::PcclRec,
    ];

    /// Stable label used in tables, figures, and model files.
    pub fn label(self) -> &'static str {
        match self {
            Backend::Vendor => "vendor",
            Backend::CrayMpich => "cray-mpich",
            Backend::PcclRing => "pccl_ring",
            Backend::PcclRec => "pccl_rec",
            Backend::Auto => "pccl_auto",
        }
    }

    /// Index into [`Backend::CONCRETE`] (dispatcher class id).
    pub fn class_id(self) -> Option<usize> {
        Backend::CONCRETE.iter().position(|&b| b == self)
    }
}

/// The collective being dispatched (a dispatcher feature).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum CollKind {
    AllGather,
    ReduceScatter,
    AllReduce,
}

impl CollKind {
    pub const ALL: [CollKind; 3] = [
        CollKind::AllGather,
        CollKind::ReduceScatter,
        CollKind::AllReduce,
    ];

    pub fn label(self) -> &'static str {
        match self {
            CollKind::AllGather => "all-gather",
            CollKind::ReduceScatter => "reduce-scatter",
            CollKind::AllReduce => "all-reduce",
        }
    }

    /// Stable ordinal (index into [`CollKind::ALL`]) — the dispatcher's
    /// `collective_id` feature, also recorded in its model files.
    pub fn collective_id(self) -> usize {
        CollKind::ALL
            .iter()
            .position(|&k| k == self)
            .expect("every CollKind is in ALL")
    }
}

/// The plan a `(collective, backend, topology, lanes)` dispatch cell lowers
/// to — the *same* spec the entry points in this module build at run time,
/// fallback gating and all (degenerate topologies route to the flat
/// algorithms, a recursive inter-node phase refuses striping and non-pow2
/// node counts, all-reduce pads to a multiple of `p` first, the vendor
/// all-reduce is the binomial tree). `lanes` is the *effective* stripe
/// count of the call (post [`effective_lane_count`]; `1` = unstriped).
///
/// `pccl verify-plans` statically verifies the spec of every grid cell
/// before the launcher ever times it; the property tests replay the specs
/// against the schedule index math.
pub fn plan_spec_for(
    kind: CollKind,
    backend: Backend,
    topo: crate::topology::Topology,
    elems: usize,
    lanes: usize,
) -> PlanSpec {
    let p = topo.world_size();
    let (n, m) = (topo.nodes(), topo.gpus_per_node());
    let k = lanes.max(1);
    let pk = match kind {
        CollKind::AllGather => PlanKind::AllGather,
        CollKind::ReduceScatter => PlanKind::ReduceScatter,
        CollKind::AllReduce => PlanKind::AllReduce,
    };
    // All-reduce pads its input to a multiple of p before lowering.
    let eff_elems = if kind == CollKind::AllReduce {
        elems.div_ceil(p) * p
    } else {
        elems
    };
    match backend {
        // Vendor all-reduce is the (whole-buffer, unpadded) binomial tree;
        // everything else vendor/Cray is the flat single-lane ring.
        Backend::Vendor if kind == CollKind::AllReduce => {
            PlanSpec::flat(pk, Algo::Tree, p, elems, 1)
        }
        Backend::Vendor | Backend::CrayMpich => PlanSpec::flat(pk, Algo::Ring, p, eff_elems, 1),
        Backend::PcclRing => {
            if topo.supports_hierarchical() {
                PlanSpec::hier(pk, Algo::HierRing, n, m, eff_elems, k)
            } else {
                PlanSpec::flat(pk, Algo::Ring, p, eff_elems, k)
            }
        }
        // PcclRec resolves recursive → ring when the relevant level is not
        // a power of two, and a recursive inter phase runs unstriped.
        Backend::PcclRec | Backend::Auto => {
            if topo.supports_hierarchical() {
                if n.is_power_of_two() {
                    PlanSpec::hier(pk, Algo::HierRec, n, m, eff_elems, 1)
                } else {
                    PlanSpec::hier(pk, Algo::HierRing, n, m, eff_elems, k)
                }
            } else if p.is_power_of_two() {
                PlanSpec::flat(pk, Algo::Rec, p, eff_elems, 1)
            } else {
                PlanSpec::flat(pk, Algo::Ring, p, eff_elems, k)
            }
        }
    }
}

/// A runtime backend chooser:
/// `(collective, message bytes, ranks, lanes) → backend`. Implemented by
/// [`crate::dispatch::SvmDispatcher`]; any closure works. The lane count is
/// a first-class dispatch feature: the striped PCCL paths shift the
/// bandwidth/latency crossover, so the trained model sees it.
pub type Chooser = Arc<dyn Fn(CollKind, usize, usize, usize) -> Backend + Send + Sync>;

/// Minimum per-stripe payload (elements) worth putting on its own lane.
/// Below this the message is latency-bound and extra rails only add
/// per-lane setup cost, so the lane-aware entry points demote to a single
/// stripe. Applied only by the dispatch layer — the `*_lanes_chunks`
/// algorithms themselves stripe whatever they are told to (correctness
/// tests exercise tiny striped inputs deliberately).
pub const MIN_STRIPE_ELEMS: usize = 1024;

/// Per-call configuration for the collective entry points.
#[derive(Clone)]
pub struct CollectiveOptions<T: Elem> {
    /// Requested backend ([`Backend::Auto`] consults `chooser`).
    pub backend: Backend,
    /// Local combine implementation (native host pair by default; wrap the
    /// XLA-offloaded Pallas kernel's
    /// [`crate::reduction::offload::XlaReducer::combine_fn`] via
    /// [`Combiner::from_fold`]).
    pub combine: Combiner<T>,
    /// Adaptive dispatcher for [`Backend::Auto`].
    pub chooser: Option<Chooser>,
    /// Reduction operator (sum by default — gradient averaging).
    pub op: ReduceOp,
    /// Requested stripe/lane count for the lane-aware entry points
    /// (`0` = one stripe per transport lane). Clamped to the
    /// communicator's lane count and subject to [`MIN_STRIPE_ELEMS`];
    /// the plain entry points ignore it.
    pub lanes: usize,
}

impl<T: Elem> Default for CollectiveOptions<T> {
    fn default() -> Self {
        Self {
            backend: Backend::PcclRec,
            combine: native_combine(),
            chooser: None,
            op: ReduceOp::Sum,
            lanes: 0,
        }
    }
}

impl<T: Elem> CollectiveOptions<T> {
    pub fn backend(mut self, b: Backend) -> Self {
        self.backend = b;
        self
    }

    pub fn combine(mut self, c: Combiner<T>) -> Self {
        self.combine = c;
        self
    }

    pub fn chooser(mut self, ch: Chooser) -> Self {
        self.chooser = Some(ch);
        self
    }

    pub fn op(mut self, op: ReduceOp) -> Self {
        self.op = op;
        self
    }

    pub fn lanes(mut self, lanes: usize) -> Self {
        self.lanes = lanes;
        self
    }

    /// The combiner actually used: the injected one for Sum (it may wrap
    /// the XLA-offloaded kernel), the native op pair for Max/Min.
    pub fn effective_combiner(&self) -> Combiner<T> {
        match self.op {
            ReduceOp::Sum => self.combine.clone(),
            op => Combiner::for_op(op),
        }
    }

    /// Resolve [`Backend::Auto`] for a concrete call site. `lanes` is the
    /// effective stripe count of the call (`1` on the unstriped entry
    /// points) — a trained chooser conditions on it.
    pub fn resolve(&self, kind: CollKind, bytes: usize, p: usize, lanes: usize) -> Backend {
        match self.backend {
            Backend::Auto => match &self.chooser {
                Some(ch) => ch(kind, bytes, p, lanes),
                // Untrained fallback: the paper's coarse empirical rule —
                // vendor ring wins in the bandwidth-bound regime (large
                // messages, few ranks), hierarchical recursive wins in the
                // latency-bound regime.
                None => {
                    let mb = bytes as f64 / (1024.0 * 1024.0);
                    if p >= 256 || (p >= 64 && mb <= 64.0) {
                        Backend::PcclRec
                    } else {
                        Backend::Vendor
                    }
                }
            },
            b => b,
        }
    }
}

/// All-gather through the selected backend.
pub fn all_gather<T: Elem>(
    c: &mut Communicator<T>,
    input: &[T],
    opts: &CollectiveOptions<T>,
) -> Result<Vec<T>> {
    let bytes = std::mem::size_of_val(input) * c.size(); // output buffer size
    match opts.resolve(CollKind::AllGather, bytes, c.size(), 1) {
        Backend::Vendor | Backend::CrayMpich => ring_all_gather(c, input),
        Backend::PcclRing => hier_all_gather(c, input, InterAlgo::Ring),
        Backend::PcclRec | Backend::Auto => hier_all_gather(c, input, InterAlgo::Rec),
    }
}

/// All-gather through the selected backend, returning the per-rank blocks
/// as zero-copy chunk views (the allocation-free hot path; see the
/// ownership model in [`crate::collectives`]).
pub fn all_gather_chunks<T: Elem>(
    c: &mut Communicator<T>,
    input: Chunk<T>,
    opts: &CollectiveOptions<T>,
) -> Result<Vec<Chunk<T>>> {
    let bytes = input.len() * std::mem::size_of::<T>() * c.size(); // output buffer size
    match opts.resolve(CollKind::AllGather, bytes, c.size(), 1) {
        Backend::Vendor | Backend::CrayMpich => ring_all_gather_chunks(c, input),
        Backend::PcclRing => hier_all_gather_chunks(c, input, InterAlgo::Ring),
        Backend::PcclRec | Backend::Auto => hier_all_gather_chunks(c, input, InterAlgo::Rec),
    }
}

/// Stripe count a lane-aware entry point actually uses: the requested
/// count (`opts.lanes`, `0` = every transport lane) clamped to the
/// communicator's lanes, then demoted to `1` when the per-stripe payload
/// would fall under [`MIN_STRIPE_ELEMS`].
pub fn effective_lane_count<T: Elem>(
    c: &Communicator<T>,
    opts: &CollectiveOptions<T>,
    elems: usize,
) -> usize {
    let req = if opts.lanes == 0 { c.lanes() } else { opts.lanes };
    let k = req.min(c.lanes()).max(1);
    if k > 1 && elems / k < MIN_STRIPE_ELEMS {
        1
    } else {
        k
    }
}

/// Lane-aware all-gather: the PCCL hierarchical backends stripe the
/// NIC-bound inter-node phase over the transport lanes; the vendor and
/// Cray-MPICH models stay single-lane (single-NIC routing is exactly the
/// libraries' documented behavior — Observation 1). Returns the gathered
/// buffer as an ordered chunk list (`p·k` stripes on the striped paths,
/// `p` blocks otherwise).
pub fn all_gather_lanes_chunks<T: Elem>(
    c: &mut Communicator<T>,
    input: Chunk<T>,
    opts: &CollectiveOptions<T>,
) -> Result<Vec<Chunk<T>>> {
    let k = effective_lane_count(c, opts, input.len());
    let bytes = input.len() * std::mem::size_of::<T>() * c.size();
    match opts.resolve(CollKind::AllGather, bytes, c.size(), k) {
        Backend::Vendor | Backend::CrayMpich => ring_all_gather_chunks(c, input),
        Backend::PcclRing => hier_all_gather_lanes_chunks(c, input, InterAlgo::Ring, k),
        Backend::PcclRec | Backend::Auto => {
            hier_all_gather_lanes_chunks(c, input, InterAlgo::Rec, k)
        }
    }
}

/// Lane-aware reduce-scatter: returns this rank's reduced block as a
/// stripe list (the stripes concatenate to the block; a single chunk on
/// every unstriped path). The striped stripes live in distinct
/// transport-delivered storages by construction, so the list form is the
/// zero-copy one — concatenating is the caller's (single-copy) choice.
pub fn reduce_scatter_stripes<T: Elem>(
    c: &mut Communicator<T>,
    input: Chunk<T>,
    opts: &CollectiveOptions<T>,
) -> Result<Vec<Chunk<T>>> {
    let p = c.size();
    let k = effective_lane_count(c, opts, input.len() / p.max(1));
    let bytes = input.len() * std::mem::size_of::<T>();
    match opts.resolve(CollKind::ReduceScatter, bytes, p, k) {
        Backend::CrayMpich => {
            Ok(vec![ring_reduce_scatter_chunks(c, input, &host_combine(opts.op))?])
        }
        Backend::Vendor => {
            Ok(vec![ring_reduce_scatter_chunks(c, input, &opts.effective_combiner())?])
        }
        Backend::PcclRing => {
            hier_reduce_scatter_lanes_chunks(c, input, &opts.effective_combiner(), InterAlgo::Ring, k)
        }
        Backend::PcclRec | Backend::Auto => {
            hier_reduce_scatter_lanes_chunks(c, input, &opts.effective_combiner(), InterAlgo::Rec, k)
        }
    }
}

/// Lane-aware all-reduce: striped hierarchical RS ∘ AG on the PCCL
/// backends, single-lane vendor tree / Cray ring otherwise. Returns chunks
/// that concatenate to `input.len()` elements.
pub fn all_reduce_lanes_chunks<T: Elem>(
    c: &mut Communicator<T>,
    input: Chunk<T>,
    opts: &CollectiveOptions<T>,
) -> Result<Vec<Chunk<T>>> {
    let k = effective_lane_count(c, opts, input.len() / c.size().max(1));
    let bytes = input.len() * std::mem::size_of::<T>();
    match opts.resolve(CollKind::AllReduce, bytes, c.size(), k) {
        Backend::CrayMpich => ring_all_reduce_chunks(c, input, &host_combine(opts.op)),
        Backend::Vendor => {
            Ok(vec![tree_all_reduce_chunks(c, input, &opts.effective_combiner())?])
        }
        Backend::PcclRing => {
            hier_all_reduce_lanes_chunks(c, input, &opts.effective_combiner(), InterAlgo::Ring, k)
        }
        Backend::PcclRec | Backend::Auto => {
            hier_all_reduce_lanes_chunks(c, input, &opts.effective_combiner(), InterAlgo::Rec, k)
        }
    }
}

/// Host-loop combiner for the backends that reduce on the CPU no matter
/// what the caller injected (Cray-MPICH, Observation 1).
fn host_combine<T: Elem>(op: ReduceOp) -> Combiner<T> {
    Combiner::for_op(op)
}

/// Reduce-scatter through the selected backend, returning rank `r`'s
/// reduced block as a chunk. On every `p > 1` path the result is the
/// unique full-range view of transport-delivered storage (`into_vec` on
/// it is a move) — the zero-copy hot path ZeRO-3 shard updates hold
/// directly; see the ownership model in [`crate::collectives`].
pub fn reduce_scatter_chunks<T: Elem>(
    c: &mut Communicator<T>,
    input: Chunk<T>,
    opts: &CollectiveOptions<T>,
) -> Result<Chunk<T>> {
    let bytes = input.len() * std::mem::size_of::<T>();
    match opts.resolve(CollKind::ReduceScatter, bytes, c.size(), 1) {
        // Cray-MPICH reduces on the host no matter what combine the caller
        // injected (Observation 1) — model that faithfully.
        Backend::CrayMpich => ring_reduce_scatter_chunks(c, input, &host_combine(opts.op)),
        Backend::Vendor => ring_reduce_scatter_chunks(c, input, &opts.effective_combiner()),
        Backend::PcclRing => {
            hier_reduce_scatter_chunks(c, input, &opts.effective_combiner(), InterAlgo::Ring)
        }
        Backend::PcclRec | Backend::Auto => {
            hier_reduce_scatter_chunks(c, input, &opts.effective_combiner(), InterAlgo::Rec)
        }
    }
}

/// Reduce-scatter through the selected backend (slice API — adapter over
/// [`reduce_scatter_chunks`] via [`slice_reduce`]; the output
/// materialization is a move).
pub fn reduce_scatter<T: Elem>(
    c: &mut Communicator<T>,
    input: &[T],
    opts: &CollectiveOptions<T>,
) -> Result<Vec<T>> {
    slice_reduce(input, |ch| reduce_scatter_chunks(c, ch, opts))
}

/// All-reduce through the selected backend, returning the result as
/// rank-ordered chunk blocks that concatenate to `input.len()` elements.
/// The PCCL and ring paths compose chunk reduce-scatter ∘ chunk all-gather
/// with no intermediate `Vec`; the vendor path's binomial tree reduces
/// through posted receives into the input-chunk accumulator and surfaces
/// the reduced buffer as a single chunk.
pub fn all_reduce_chunks<T: Elem>(
    c: &mut Communicator<T>,
    input: Chunk<T>,
    opts: &CollectiveOptions<T>,
) -> Result<Vec<Chunk<T>>> {
    let bytes = input.len() * std::mem::size_of::<T>();
    match opts.resolve(CollKind::AllReduce, bytes, c.size(), 1) {
        Backend::CrayMpich => ring_all_reduce_chunks(c, input, &host_combine(opts.op)),
        // Vendor libraries use double binary trees for all-reduce [15].
        Backend::Vendor => {
            Ok(vec![tree_all_reduce_chunks(c, input, &opts.effective_combiner())?])
        }
        Backend::PcclRing => {
            hier_all_reduce_chunks(c, input, &opts.effective_combiner(), InterAlgo::Ring)
        }
        Backend::PcclRec | Backend::Auto => {
            hier_all_reduce_chunks(c, input, &opts.effective_combiner(), InterAlgo::Rec)
        }
    }
}

/// All-reduce through the selected backend (slice API — adapter over
/// [`all_reduce_chunks`] via [`slice_all_reduce`]). A single-block result
/// (the vendor tree path) moves out of its chunk with no copy; multi-block
/// results pay the one output concat.
pub fn all_reduce<T: Elem>(
    c: &mut Communicator<T>,
    input: &[T],
    opts: &CollectiveOptions<T>,
) -> Result<Vec<T>> {
    slice_all_reduce(input, |ch| all_reduce_chunks(c, ch, opts))
}

/// Broadcast from `root` (binomial tree — backend-independent).
pub fn broadcast<T: Elem>(
    c: &mut Communicator<T>,
    input: &[T],
    root: usize,
) -> Result<Vec<T>> {
    crate::collectives::broadcast(c, input, root)
}

/// Reduce to `root` with the options' operator and combiner.
pub fn reduce<T: Elem>(
    c: &mut Communicator<T>,
    input: &[T],
    root: usize,
    opts: &CollectiveOptions<T>,
) -> Result<Vec<T>> {
    crate::collectives::reduce(c, input, root, &opts.effective_combiner())
}

/// Gather equal-length contributions to `root`.
pub fn gather<T: Elem>(c: &mut Communicator<T>, input: &[T], root: usize) -> Result<Vec<T>> {
    crate::collectives::gather(c, input, root)
}

/// Scatter `root`'s buffer in rank-order blocks.
pub fn scatter<T: Elem>(c: &mut Communicator<T>, input: &[T], root: usize) -> Result<Vec<T>> {
    crate::collectives::scatter(c, input, root)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::collectives::oracle;
    use crate::comm::CommWorld;
    use crate::topology::Topology;

    #[test]
    fn every_backend_every_collective_matches_oracle() {
        let topo = Topology::new(2, 4, 2).unwrap();
        let p = topo.world_size();
        for backend in Backend::CONCRETE {
            let world = CommWorld::<f32>::with_topology(topo);
            let outs = world.run(move |c| {
                let opts = CollectiveOptions::default().backend(backend);
                let r = c.rank();
                let ag_in: Vec<f32> = (0..4).map(|i| (r * 10 + i) as f32).collect();
                let rs_in: Vec<f32> = (0..p * 2).map(|i| (r + i) as f32).collect();
                let ar_in: Vec<f32> = (0..9).map(|i| (r * 2 + i) as f32).collect();
                (
                    all_gather(c, &ag_in, &opts).unwrap(),
                    reduce_scatter(c, &rs_in, &opts).unwrap(),
                    all_reduce(c, &ar_in, &opts).unwrap(),
                )
            });
            let ag_ins: Vec<Vec<f32>> = (0..p)
                .map(|r| (0..4).map(|i| (r * 10 + i) as f32).collect())
                .collect();
            let rs_ins: Vec<Vec<f32>> = (0..p)
                .map(|r| (0..p * 2).map(|i| (r + i) as f32).collect())
                .collect();
            let ar_ins: Vec<Vec<f32>> = (0..p)
                .map(|r| (0..9).map(|i| (r * 2 + i) as f32).collect())
                .collect();
            for (r, (ag, rs, ar)) in outs.iter().enumerate() {
                assert_eq!(ag, &oracle::all_gather(&ag_ins), "{backend:?} ag r={r}");
                assert_eq!(
                    rs,
                    &oracle::reduce_scatter(&rs_ins, r),
                    "{backend:?} rs r={r}"
                );
                assert_eq!(ar, &oracle::all_reduce(&ar_ins), "{backend:?} ar r={r}");
            }
        }
    }

    #[test]
    fn dispatch_cell_specs_all_verify() {
        use crate::collectives::plan;
        // One hierarchical and one degenerate (single-node) topology, with
        // and without striping: every cell's lowered spec must pass static
        // verification — exactly what `pccl verify-plans` enforces.
        for topo in [Topology::new(2, 4, 2).unwrap(), Topology::new(1, 5, 1).unwrap()] {
            let p = topo.world_size();
            for backend in Backend::CONCRETE {
                for kind in CollKind::ALL {
                    for lanes in [1usize, 2] {
                        let elems = match kind {
                            CollKind::AllGather => 6,
                            _ => 6 * p,
                        };
                        let spec = plan_spec_for(kind, backend, topo, elems, lanes);
                        plan::verify_cached(&spec).unwrap_or_else(|e| {
                            panic!("{backend:?} {kind:?} lanes={lanes} p={p}: {e}")
                        });
                    }
                }
            }
        }
        assert_eq!(CollKind::AllGather.collective_id(), 0);
        assert_eq!(CollKind::AllReduce.collective_id(), 2);
    }

    #[test]
    fn auto_resolves_by_regime() {
        let opts = CollectiveOptions::<f32>::default().backend(Backend::Auto);
        // Large message, small p → vendor.
        assert_eq!(
            opts.resolve(CollKind::AllGather, 512 << 20, 16, 1),
            Backend::Vendor
        );
        // Small message, large p → hierarchical recursive.
        assert_eq!(
            opts.resolve(CollKind::AllGather, 16 << 20, 2048, 1),
            Backend::PcclRec
        );
    }

    #[test]
    fn custom_chooser_is_consulted() {
        let opts = CollectiveOptions::<f32>::default()
            .backend(Backend::Auto)
            .chooser(Arc::new(|_, _, _, lanes| {
                if lanes > 1 {
                    Backend::PcclRing
                } else {
                    Backend::Vendor
                }
            }));
        assert_eq!(opts.resolve(CollKind::AllReduce, 1024, 4, 4), Backend::PcclRing);
        assert_eq!(opts.resolve(CollKind::AllReduce, 1024, 4, 1), Backend::Vendor);
    }

    #[test]
    fn lane_aware_entry_points_match_oracle_and_threshold() {
        use crate::comm::Chunk;
        let topo = Topology::new(2, 2, 2).unwrap();
        let p = topo.world_size();
        let b = 2048; // above MIN_STRIPE_ELEMS per stripe at k = 2
        let world = CommWorld::<f32>::with_topology(topo).with_lanes(2);
        let outs = world.run(move |c| {
            let opts = CollectiveOptions::default().backend(Backend::PcclRing);
            assert_eq!(effective_lane_count(c, &opts, 4 * MIN_STRIPE_ELEMS), 2);
            // Tiny payload demotes to a single stripe.
            assert_eq!(effective_lane_count(c, &opts, 8), 1);
            let rs_in: Vec<f32> = (0..p * b).map(|i| (c.rank() + i) as f32).collect();
            let stripes =
                reduce_scatter_stripes(c, Chunk::from_vec(rs_in), &opts).unwrap();
            assert!(stripes.len() > 1, "large payload must stripe");
            let ar_in: Vec<f32> = (0..b).map(|i| (c.rank() * 2 + i) as f32).collect();
            let ar = all_reduce_lanes_chunks(c, Chunk::from_vec(ar_in), &opts).unwrap();
            let ag_in: Vec<f32> = (0..b).map(|i| (c.rank() * 10 + i) as f32).collect();
            let ag = all_gather_lanes_chunks(c, Chunk::from_vec(ag_in), &opts).unwrap();
            (Chunk::concat(&stripes), Chunk::concat(&ar), Chunk::concat(&ag))
        });
        let rs_ins: Vec<Vec<f32>> = (0..p)
            .map(|r| (0..p * b).map(|i| (r + i) as f32).collect())
            .collect();
        let ar_ins: Vec<Vec<f32>> = (0..p)
            .map(|r| (0..b).map(|i| (r * 2 + i) as f32).collect())
            .collect();
        let ag_ins: Vec<Vec<f32>> = (0..p)
            .map(|r| (0..b).map(|i| (r * 10 + i) as f32).collect())
            .collect();
        for (r, (rs, ar, ag)) in outs.iter().enumerate() {
            assert_eq!(rs, &oracle::reduce_scatter(&rs_ins, r), "rs r={r}");
            assert_eq!(ar, &oracle::all_reduce(&ar_ins), "ar r={r}");
            assert_eq!(ag, &oracle::all_gather(&ag_ins), "ag r={r}");
        }
    }
}
