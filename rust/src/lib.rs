//! # PCCL-RS — Performant Collective Communication Library (reproduction)
//!
//! A from-scratch Rust reproduction of *"The Big Send-off: Scalable and
//! Performant Collectives for Deep Learning"* (CS.DC 2025): hierarchical
//! all-gather / reduce-scatter / all-reduce collectives with ring and
//! recursive doubling/halving inter-node backends, an SVM-based adaptive
//! dispatcher, a real multi-rank data plane, and a discrete-event network
//! simulator that regenerates every figure and table of the paper's
//! evaluation at Frontier/Perlmutter scale.
//!
//! ## Layers
//! * **L3** (this crate): communicators, collective algorithms, backends,
//!   adaptive dispatch, network simulation, training drivers.
//! * **L2** (`python/compile/model.py`, build time): JAX GPT `train_step`
//!   AOT-lowered to HLO text, executed from [`runtime`] via PJRT.
//! * **L1** (`python/compile/kernels/`, build time): Pallas reduction and
//!   unshuffle kernels that lower into the same artifacts.
//!
//! ## Quickstart
//! ```no_run
//! use pccl::comm::CommWorld;
//! use pccl::backends::{Backend, CollectiveOptions};
//!
//! let world = CommWorld::<f32>::new(8);
//! let outs = world.try_run(move |comm| {
//!     let mine = vec![comm.rank() as f32; 1024];
//!     let opts = CollectiveOptions::default().backend(Backend::PcclRec);
//!     pccl::backends::all_reduce(comm, &mine, &opts)
//! });
//! ```

// `Option::is_none_or` needs Rust ≥ 1.82; this crate keeps MSRV 1.75 for
// offline toolchains, so silence newer clippy's `map_or(true, ..)`
// suggestion (and tolerate the lint name being unknown to older clippy).
#![allow(unknown_lints)]
#![allow(clippy::unnecessary_map_or)]

pub mod backends;
pub mod bench;
pub mod collectives;
pub mod comm;
pub mod config;
pub mod dispatch;
pub mod error;
pub mod metrics;
pub mod netsim;
pub mod reduction;
pub mod runtime;
pub mod topology;
pub mod trace;
pub mod train;
pub mod util;
pub mod workload;

pub use backends::{Backend, CollectiveOptions};
pub use collectives::Pccl;
pub use comm::{CommWorld, Communicator};
pub use error::{Error, Result};
pub use topology::{Machine, Topology};
