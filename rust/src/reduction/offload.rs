//! XLA-offloaded reduction — the "GPU compute kernel" path.
//!
//! The combine step of reduce-scatter / all-reduce is executed by the L1
//! Pallas reduction kernel, AOT-lowered to `artifacts/reduce_sum_<n>.hlo.txt`
//! and run through the PJRT device service. This reproduces the paper's
//! custom "MPI point-to-point + GPU vector reduction kernel" implementation
//! (§III-B, Fig. 4) in this stack's terms.

use std::sync::Arc;

use crate::error::Result;
use crate::runtime::{Artifacts, DeviceHandle};

use super::native;

/// A combine function used by collectives: `acc += src`.
///
/// Collectives are generic over element type; the combine is injected so the
/// same algorithm code can run with the native host reducer (default) or the
/// XLA-offloaded kernel (f32 only).
pub type CombineFn<T> = Arc<dyn Fn(&mut [T], &[T]) + Send + Sync>;

/// The native (host) combine — works for every [`crate::reduction::Elem`].
pub fn native_combine<T: crate::reduction::Elem>() -> CombineFn<T> {
    Arc::new(|acc, src| native::reduce_into(acc, src))
}

/// XLA-offloaded f32 sum over fixed-size chunks.
///
/// Buffers are processed in `chunk`-element submissions (the artifact's
/// static shape); a trailing partial chunk falls back to the native reducer
/// rather than paying a pad-copy — measured faster for every tail size.
#[derive(Clone)]
pub struct XlaReducer {
    dev: DeviceHandle,
    artifact: String,
    chunk: usize,
}

impl XlaReducer {
    /// Pick the largest compiled `reduce_sum_<n>` artifact not exceeding
    /// `max_chunk` (0 = no limit) and preload it.
    pub fn from_artifacts(
        arts: &Artifacts,
        dev: DeviceHandle,
        max_chunk: usize,
    ) -> Result<Option<Self>> {
        let mut best: Option<(usize, String)> = None;
        for name in arts.names() {
            if let Some(n) = name
                .strip_prefix("reduce_sum_")
                .and_then(|s| s.parse::<usize>().ok())
            {
                if (max_chunk == 0 || n <= max_chunk) && best.as_ref().map_or(true, |(b, _)| n > *b)
                {
                    best = Some((n, name.to_string()));
                }
            }
        }
        match best {
            None => Ok(None),
            Some((chunk, artifact)) => {
                dev.preload(&[&artifact])?;
                Ok(Some(Self {
                    dev,
                    artifact,
                    chunk,
                }))
            }
        }
    }

    /// Chunk size of the compiled kernel.
    pub fn chunk(&self) -> usize {
        self.chunk
    }

    /// `acc[i] += src[i]`, full chunks on the device, tail on the host.
    pub fn reduce_into(&self, acc: &mut [f32], src: &[f32]) -> Result<()> {
        assert_eq!(acc.len(), src.len(), "XlaReducer length mismatch");
        let full = acc.len() / self.chunk * self.chunk;
        let mut off = 0;
        while off < full {
            let end = off + self.chunk;
            let out = self
                .dev
                .execute_f32_pair(&self.artifact, &acc[off..end], &src[off..end])?;
            acc[off..end].copy_from_slice(&out);
            off = end;
        }
        if full < acc.len() {
            native::reduce_into(&mut acc[full..], &src[full..]);
        }
        Ok(())
    }

    /// Wrap as a [`CombineFn`] (errors panic — a failed device submission on
    /// the collective hot path is unrecoverable, like a CUDA error).
    pub fn combine_fn(&self) -> CombineFn<f32> {
        let this = self.clone();
        Arc::new(move |acc, src| {
            this.reduce_into(acc, src)
                .expect("XLA reduction failed on collective hot path")
        })
    }
}
