//! XLA-offloaded reduction — the "GPU compute kernel" path.
//!
//! The combine step of reduce-scatter / all-reduce is executed by the L1
//! Pallas reduction kernel, AOT-lowered to `artifacts/reduce_sum_<n>.hlo.txt`
//! and run through the PJRT device service. This reproduces the paper's
//! custom "MPI point-to-point + GPU vector reduction kernel" implementation
//! (§III-B, Fig. 4) in this stack's terms.

use std::sync::Arc;

use crate::error::Result;
use crate::runtime::{Artifacts, DeviceHandle};

use super::native;

/// A two-address combine function used by collectives: `acc += src`.
///
/// Collectives are generic over element type; the combine is injected so the
/// same algorithm code can run with the native host reducer (default) or the
/// XLA-offloaded kernel (f32 only).
pub type CombineFn<T> = Arc<dyn Fn(&mut [T], &[T]) + Send + Sync>;

/// A three-address fused combine: `out[i] = a[i] ⊕ b[i]` into fresh storage,
/// one pass, each output element written exactly once.
///
/// Used by the posted-receive data plane when *neither* operand's storage may
/// be mutated (both are COW views of live buffers): the fused form replaces
/// copy-then-fold, which pays a full extra write pass for the copy.
pub type FuseFn<T> = Arc<dyn Fn(&[T], &[T]) -> Vec<T> + Send + Sync>;

/// The combine pair injected into reduce-capable collectives: a two-address
/// fold for in-place accumulation plus a three-address fuse for the
/// first combine of a traveling partial.
///
/// The posted-receive delivery path ([`crate::comm::Chunk::accept_combine`])
/// picks between them by storage exclusivity, so the combine must be
/// **commutative** (`a ⊕ b == b ⊕ a`) — true for sum/max/min, including
/// IEEE-754 two-operand addition.
#[derive(Clone)]
pub struct Combiner<T> {
    fold: CombineFn<T>,
    fuse: FuseFn<T>,
}

impl<T: 'static> Combiner<T> {
    /// Bundle an explicit fold/fuse pair.
    pub fn new(fold: CombineFn<T>, fuse: FuseFn<T>) -> Self {
        Self { fold, fuse }
    }

    /// Derive the fuse from a fold as copy-then-fold. Correct for any fold,
    /// but the fuse pays one hidden materialization copy — use only when a
    /// genuine three-address kernel is unavailable (e.g. wrapping
    /// [`XlaReducer::combine_fn`]).
    pub fn from_fold(fold: CombineFn<T>) -> Self
    where
        T: Clone,
    {
        let f = fold.clone();
        let fuse: FuseFn<T> = Arc::new(move |a, b| {
            let mut out = a.to_vec();
            f(&mut out, b);
            out
        });
        Self { fold, fuse }
    }

    /// The native host combiner for `op`, both halves truly one-pass.
    pub fn for_op(op: native::ReduceOp) -> Self
    where
        T: crate::reduction::Elem,
    {
        Self {
            fold: Arc::new(move |acc, src| native::reduce_into_op(acc, src, op)),
            fuse: Arc::new(move |a, b| native::reduce_fused_op(a, b, op)),
        }
    }

    /// Two-address fold: `acc[i] ⊕= src[i]`.
    #[inline]
    pub fn fold(&self, acc: &mut [T], src: &[T]) {
        (self.fold)(acc, src)
    }

    /// Three-address fuse: fresh `out` with `out[i] = a[i] ⊕ b[i]`.
    #[inline]
    pub fn fuse(&self, a: &[T], b: &[T]) -> Vec<T> {
        (self.fuse)(a, b)
    }
}

/// The native (host) sum combiner — works for every [`crate::reduction::Elem`].
pub fn native_combine<T: crate::reduction::Elem>() -> Combiner<T> {
    Combiner {
        fold: Arc::new(|acc, src| native::reduce_into(acc, src)),
        fuse: Arc::new(|a, b| native::reduce_fused(a, b)),
    }
}

/// XLA-offloaded f32 sum over fixed-size chunks.
///
/// Buffers are processed in `chunk`-element submissions (the artifact's
/// static shape); a trailing partial chunk falls back to the native reducer
/// rather than paying a pad-copy — measured faster for every tail size.
#[derive(Clone)]
pub struct XlaReducer {
    dev: DeviceHandle,
    artifact: String,
    chunk: usize,
}

impl XlaReducer {
    /// Pick the largest compiled `reduce_sum_<n>` artifact not exceeding
    /// `max_chunk` (0 = no limit) and preload it.
    pub fn from_artifacts(
        arts: &Artifacts,
        dev: DeviceHandle,
        max_chunk: usize,
    ) -> Result<Option<Self>> {
        let mut best: Option<(usize, String)> = None;
        for name in arts.names() {
            if let Some(n) = name
                .strip_prefix("reduce_sum_")
                .and_then(|s| s.parse::<usize>().ok())
            {
                if (max_chunk == 0 || n <= max_chunk) && best.as_ref().map_or(true, |(b, _)| n > *b)
                {
                    best = Some((n, name.to_string()));
                }
            }
        }
        match best {
            None => Ok(None),
            Some((chunk, artifact)) => {
                dev.preload(&[&artifact])?;
                Ok(Some(Self {
                    dev,
                    artifact,
                    chunk,
                }))
            }
        }
    }

    /// Chunk size of the compiled kernel.
    pub fn chunk(&self) -> usize {
        self.chunk
    }

    /// `acc[i] += src[i]`, full chunks on the device, tail on the host.
    pub fn reduce_into(&self, acc: &mut [f32], src: &[f32]) -> Result<()> {
        assert_eq!(acc.len(), src.len(), "XlaReducer length mismatch");
        let full = acc.len() / self.chunk * self.chunk;
        let mut off = 0;
        while off < full {
            let end = off + self.chunk;
            let out = self
                .dev
                .execute_f32_pair(&self.artifact, &acc[off..end], &src[off..end])?;
            acc[off..end].copy_from_slice(&out);
            off = end;
        }
        if full < acc.len() {
            native::reduce_into(&mut acc[full..], &src[full..]);
        }
        Ok(())
    }

    /// Wrap as a [`CombineFn`] (errors panic — a failed device submission on
    /// the collective hot path is unrecoverable, like a CUDA error).
    pub fn combine_fn(&self) -> CombineFn<f32> {
        let this = self.clone();
        Arc::new(move |acc, src| {
            this.reduce_into(acc, src)
                .expect("XLA reduction failed on collective hot path")
        })
    }
}
