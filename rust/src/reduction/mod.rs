//! Local reduction (combine) kernels — the per-GPU compute inside
//! reduce-scatter and all-reduce.
//!
//! The paper's Observation 1 is that Cray-MPICH performs reductions on the
//! *CPU*, while performant libraries offload them to the GPU. In this
//! reproduction the "GPU" path is the L1 Pallas reduction kernel, AOT-lowered
//! to HLO and executed through PJRT ([`crate::runtime`]); the "CPU" path is
//! the native Rust implementation in this module, which is also the fast path
//! for chunks below the XLA dispatch overhead crossover.

mod elem;
mod native;
pub mod offload;

pub use elem::{DType, Elem};
pub use native::{reduce_fused, reduce_fused_op, reduce_into, reduce_into_op, ReduceOp};

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sum_f32() {
        let mut acc = vec![1.0f32, 2.0, 3.0];
        reduce_into(&mut acc, &[10.0, 20.0, 30.0]);
        assert_eq!(acc, vec![11.0, 22.0, 33.0]);
    }

    #[test]
    fn sum_f64() {
        let mut acc = vec![1.0f64; 17];
        reduce_into(&mut acc, &vec![2.0f64; 17]);
        assert!(acc.iter().all(|&x| x == 3.0));
    }

    #[test]
    fn fused_matches_copy_then_fold() {
        let a = vec![1.0f32, 2.0, 3.0, 4.0];
        let b = vec![10.0f32, 20.0, 30.0, 40.0];
        assert_eq!(reduce_fused(&a, &b), vec![11.0, 22.0, 33.0, 44.0]);
        assert_eq!(reduce_fused_op(&a, &b, ReduceOp::Max), b);
        assert_eq!(reduce_fused_op(&b, &a, ReduceOp::Min), a);
    }

    #[test]
    fn max_min_ops() {
        let mut acc = vec![1.0f32, 5.0];
        reduce_into_op(&mut acc, &[3.0, 2.0], ReduceOp::Max);
        assert_eq!(acc, vec![3.0, 5.0]);
        reduce_into_op(&mut acc, &[0.0, 9.0], ReduceOp::Min);
        assert_eq!(acc, vec![0.0, 5.0]);
    }

    #[test]
    fn bf16_sum_is_exact_for_small_ints() {
        use crate::util::bf16::Bf16;
        let mut acc = vec![Bf16::from_f32(1.0); 8];
        reduce_into(&mut acc, &vec![Bf16::from_f32(2.0); 8]);
        assert!(acc.iter().all(|&x| x.to_f32() == 3.0));
    }
}
