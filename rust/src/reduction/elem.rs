//! Element types supported on the collective data path.

use crate::util::bf16::Bf16;

/// Runtime dtype tag — used for logging, netsim volume accounting, and the
/// artifact registry.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum DType {
    F32,
    F64,
    Bf16,
}

impl DType {
    /// Size of one element in bytes.
    pub fn size_of(self) -> usize {
        match self {
            DType::F32 => 4,
            DType::F64 => 8,
            DType::Bf16 => 2,
        }
    }
}

/// An element type usable in collectives: sendable, reducible, testable.
///
/// This is the trait bound for the whole data plane — `Communicator<T>`,
/// all collective algorithms, and the training drivers are generic over it.
pub trait Elem: Copy + Send + Sync + PartialEq + std::fmt::Debug + 'static {
    /// dtype tag for this element type.
    const DTYPE: DType;
    /// Wire size of one element in bytes (equals `DTYPE.size_of()` and the
    /// `size_of::<T>()` the transport's byte counters use); bench/netsim
    /// volume accounting converts element counts to bytes through this.
    const SIZE: usize = std::mem::size_of::<Self>();
    /// Additive identity.
    fn zero() -> Self;
    /// Elementwise sum — the reduction used by grad averaging.
    fn add(self, other: Self) -> Self;
    /// Elementwise max.
    fn max_(self, other: Self) -> Self;
    /// Elementwise min.
    fn min_(self, other: Self) -> Self;
    /// Lossless-enough conversion for test oracles and XLA interop.
    fn to_f64(self) -> f64;
    /// Inverse of [`Elem::to_f64`] (may round, e.g. bf16).
    fn from_f64(v: f64) -> Self;
}

impl Elem for f32 {
    const DTYPE: DType = DType::F32;
    fn zero() -> Self {
        0.0
    }
    fn add(self, other: Self) -> Self {
        self + other
    }
    fn max_(self, other: Self) -> Self {
        self.max(other)
    }
    fn min_(self, other: Self) -> Self {
        self.min(other)
    }
    fn to_f64(self) -> f64 {
        self as f64
    }
    fn from_f64(v: f64) -> Self {
        v as f32
    }
}

impl Elem for f64 {
    const DTYPE: DType = DType::F64;
    fn zero() -> Self {
        0.0
    }
    fn add(self, other: Self) -> Self {
        self + other
    }
    fn max_(self, other: Self) -> Self {
        self.max(other)
    }
    fn min_(self, other: Self) -> Self {
        self.min(other)
    }
    fn to_f64(self) -> f64 {
        self
    }
    fn from_f64(v: f64) -> Self {
        v
    }
}

impl Elem for Bf16 {
    const DTYPE: DType = DType::Bf16;
    fn zero() -> Self {
        Bf16::from_f32(0.0)
    }
    fn add(self, other: Self) -> Self {
        Bf16::from_f32(self.to_f32() + other.to_f32())
    }
    fn max_(self, other: Self) -> Self {
        if self.to_f32() >= other.to_f32() {
            self
        } else {
            other
        }
    }
    fn min_(self, other: Self) -> Self {
        if self.to_f32() <= other.to_f32() {
            self
        } else {
            other
        }
    }
    fn to_f64(self) -> f64 {
        self.to_f32() as f64
    }
    fn from_f64(v: f64) -> Self {
        Bf16::from_f32(v as f32)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn elem_size_matches_dtype() {
        assert_eq!(f32::SIZE, DType::F32.size_of());
        assert_eq!(f64::SIZE, DType::F64.size_of());
        assert_eq!(Bf16::SIZE, DType::Bf16.size_of());
    }
}
