//! Native (host) vector reduction. Written so LLVM auto-vectorizes the
//! inner loop; this is the sub-crossover fast path and the test oracle for
//! the XLA-offloaded path.

use super::Elem;

/// Reduction operator carried by collective options.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, Default)]
pub enum ReduceOp {
    /// Elementwise sum (gradient averaging) — the only op the paper needs,
    /// and the default.
    #[default]
    Sum,
    Max,
    Min,
}

/// `acc[i] += src[i]` for all i.
///
/// # Panics
/// If lengths differ (an internal invariant of the collectives; user-facing
/// size checks happen at collective entry).
#[inline]
pub fn reduce_into<T: Elem>(acc: &mut [T], src: &[T]) {
    assert_eq!(acc.len(), src.len(), "reduce_into length mismatch");
    // Chunked loop: gives LLVM straight-line vectorizable bodies.
    const LANES: usize = 16;
    let n = acc.len();
    let chunks = n / LANES;
    let (acc_head, acc_tail) = acc.split_at_mut(chunks * LANES);
    let (src_head, src_tail) = src.split_at(chunks * LANES);
    for (a, s) in acc_head
        .chunks_exact_mut(LANES)
        .zip(src_head.chunks_exact(LANES))
    {
        for i in 0..LANES {
            a[i] = a[i].add(s[i]);
        }
    }
    for (a, s) in acc_tail.iter_mut().zip(src_tail) {
        *a = a.add(*s);
    }
}

/// Three-address fused combine: returns a fresh `out` with `out[i] = a[i] + b[i]`.
///
/// One pass over both inputs, writing each output element exactly once — the
/// copy-free way to materialize the *first* combine of a reduction when
/// neither operand's storage may be written (both are COW views of live
/// buffers). Compare with copy-then-[`reduce_into`], which pays a full write
/// pass for the copy before the read-modify-write pass.
#[inline]
pub fn reduce_fused<T: Elem>(a: &[T], b: &[T]) -> Vec<T> {
    assert_eq!(a.len(), b.len(), "reduce_fused length mismatch");
    a.iter().zip(b).map(|(&x, &y)| x.add(y)).collect()
}

/// Three-address fused `op` combine: `out[i] = op(a[i], b[i])`.
#[inline]
pub fn reduce_fused_op<T: Elem>(a: &[T], b: &[T], op: ReduceOp) -> Vec<T> {
    assert_eq!(a.len(), b.len(), "reduce_fused_op length mismatch");
    match op {
        ReduceOp::Sum => reduce_fused(a, b),
        ReduceOp::Max => a.iter().zip(b).map(|(&x, &y)| x.max_(y)).collect(),
        ReduceOp::Min => a.iter().zip(b).map(|(&x, &y)| x.min_(y)).collect(),
    }
}

/// `acc[i] = op(acc[i], src[i])` for all i.
#[inline]
pub fn reduce_into_op<T: Elem>(acc: &mut [T], src: &[T], op: ReduceOp) {
    match op {
        ReduceOp::Sum => reduce_into(acc, src),
        ReduceOp::Max => {
            assert_eq!(acc.len(), src.len(), "reduce_into_op length mismatch");
            for (a, s) in acc.iter_mut().zip(src) {
                *a = a.max_(*s);
            }
        }
        ReduceOp::Min => {
            assert_eq!(acc.len(), src.len(), "reduce_into_op length mismatch");
            for (a, s) in acc.iter_mut().zip(src) {
                *a = a.min_(*s);
            }
        }
    }
}
