//! Native (host) vector reduction. Written so LLVM auto-vectorizes the
//! inner loop; this is the sub-crossover fast path and the test oracle for
//! the XLA-offloaded path.

use super::Elem;

/// Reduction operator carried by collective options.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, Default)]
pub enum ReduceOp {
    /// Elementwise sum (gradient averaging) — the only op the paper needs,
    /// and the default.
    #[default]
    Sum,
    Max,
    Min,
}

/// `acc[i] += src[i]` for all i.
///
/// # Panics
/// If lengths differ (an internal invariant of the collectives; user-facing
/// size checks happen at collective entry).
#[inline]
pub fn reduce_into<T: Elem>(acc: &mut [T], src: &[T]) {
    assert_eq!(acc.len(), src.len(), "reduce_into length mismatch");
    // Chunked loop: gives LLVM straight-line vectorizable bodies.
    const LANES: usize = 16;
    let n = acc.len();
    let chunks = n / LANES;
    let (acc_head, acc_tail) = acc.split_at_mut(chunks * LANES);
    let (src_head, src_tail) = src.split_at(chunks * LANES);
    for (a, s) in acc_head
        .chunks_exact_mut(LANES)
        .zip(src_head.chunks_exact(LANES))
    {
        for i in 0..LANES {
            a[i] = a[i].add(s[i]);
        }
    }
    for (a, s) in acc_tail.iter_mut().zip(src_tail) {
        *a = a.add(*s);
    }
}

/// `acc[i] = op(acc[i], src[i])` for all i.
#[inline]
pub fn reduce_into_op<T: Elem>(acc: &mut [T], src: &[T], op: ReduceOp) {
    match op {
        ReduceOp::Sum => reduce_into(acc, src),
        ReduceOp::Max => {
            assert_eq!(acc.len(), src.len(), "reduce_into_op length mismatch");
            for (a, s) in acc.iter_mut().zip(src) {
                *a = a.max_(*s);
            }
        }
        ReduceOp::Min => {
            assert_eq!(acc.len(), src.len(), "reduce_into_op length mismatch");
            for (a, s) in acc.iter_mut().zip(src) {
                *a = a.min_(*s);
            }
        }
    }
}
