//! Artifact registry — the contract between `python/compile/aot.py` and the
//! Rust runtime.
//!
//! `make artifacts` writes `artifacts/manifest.json` plus one `*.hlo.txt`
//! per compiled computation. The manifest records each computation's input
//! and output tensor specs so the Rust side can validate calls without ever
//! importing Python.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use crate::error::{Error, Result};
use crate::util::json::Value;

/// Shape + dtype of one tensor crossing the AOT boundary.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TensorSpecJson {
    pub shape: Vec<usize>,
    pub dtype: String,
}

impl TensorSpecJson {
    fn from_json(v: &Value) -> Result<Self> {
        Ok(Self {
            shape: v.get("shape")?.vec_usize()?,
            dtype: v.get("dtype")?.as_str()?.to_string(),
        })
    }
}

/// One AOT-compiled computation.
#[derive(Debug, Clone)]
pub struct ArtifactEntry {
    /// HLO text file, relative to the artifact directory.
    pub file: String,
    /// Input tensor specs, in call order.
    pub inputs: Vec<TensorSpecJson>,
    /// Output tensor specs (the lowered function returns a tuple).
    pub outputs: Vec<TensorSpecJson>,
}

impl ArtifactEntry {
    fn from_json(v: &Value) -> Result<Self> {
        let specs = |key: &str| -> Result<Vec<TensorSpecJson>> {
            v.get(key)?
                .as_arr()?
                .iter()
                .map(TensorSpecJson::from_json)
                .collect()
        };
        Ok(Self {
            file: v.get("file")?.as_str()?.to_string(),
            inputs: specs("inputs")?,
            outputs: specs("outputs")?,
        })
    }
}

/// Metadata for the L2 model: how to build/flatten the parameter pytree.
#[derive(Debug, Clone)]
pub struct ModelMeta {
    /// Flattened parameter names, in the order `train_step` expects.
    pub param_names: Vec<String>,
    /// Shapes matching `param_names`.
    pub param_shapes: Vec<Vec<usize>>,
    /// Total parameter count.
    pub param_count: usize,
    /// Tokens per micro-batch row.
    pub seq_len: usize,
    /// Rows per rank per step.
    pub batch_per_rank: usize,
    /// Vocabulary size.
    pub vocab_size: usize,
}

impl ModelMeta {
    fn from_json(v: &Value) -> Result<Self> {
        let param_names = v
            .get("param_names")?
            .as_arr()?
            .iter()
            .map(|n| Ok(n.as_str()?.to_string()))
            .collect::<Result<Vec<_>>>()?;
        let param_shapes = v
            .get("param_shapes")?
            .as_arr()?
            .iter()
            .map(Value::vec_usize)
            .collect::<Result<Vec<_>>>()?;
        Ok(Self {
            param_names,
            param_shapes,
            param_count: v.get("param_count")?.as_usize()?,
            seq_len: v.get("seq_len")?.as_usize()?,
            batch_per_rank: v.get("batch_per_rank")?.as_usize()?,
            vocab_size: v.get("vocab_size")?.as_usize()?,
        })
    }
}

/// `artifacts/manifest.json` root.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub version: usize,
    pub entries: BTreeMap<String, ArtifactEntry>,
    pub model: Option<ModelMeta>,
}

impl Manifest {
    fn from_json(v: &Value) -> Result<Self> {
        let mut entries = BTreeMap::new();
        for (name, e) in v.get("entries")?.as_obj()? {
            entries.insert(name.clone(), ArtifactEntry::from_json(e)?);
        }
        let model = match v.get_opt("model") {
            Some(m) => Some(ModelMeta::from_json(m)?),
            None => None,
        };
        Ok(Self {
            version: v.get("version")?.as_usize()?,
            entries,
            model,
        })
    }
}

/// A loaded artifact directory.
#[derive(Debug, Clone)]
pub struct Artifacts {
    dir: PathBuf,
    manifest: Manifest,
}

impl Artifacts {
    /// Load `<dir>/manifest.json`. Fails with a clear message if
    /// `make artifacts` has not been run.
    pub fn load(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        let mpath = dir.join("manifest.json");
        let text = std::fs::read_to_string(&mpath).map_err(|e| {
            Error::Artifact(format!(
                "cannot read {} ({e}); run `make artifacts` first",
                mpath.display()
            ))
        })?;
        let value = Value::parse(&text)
            .map_err(|e| Error::Artifact(format!("malformed {}: {e}", mpath.display())))?;
        let manifest = Manifest::from_json(&value)
            .map_err(|e| Error::Artifact(format!("bad manifest {}: {e}", mpath.display())))?;
        Ok(Self { dir, manifest })
    }

    /// Default location: `$PCCL_ARTIFACTS` or `./artifacts`.
    pub fn load_default() -> Result<Self> {
        let dir = std::env::var("PCCL_ARTIFACTS").unwrap_or_else(|_| "artifacts".to_string());
        Self::load(dir)
    }

    pub fn dir(&self) -> &Path {
        &self.dir
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// Names of all registered computations.
    pub fn names(&self) -> impl Iterator<Item = &str> {
        self.manifest.entries.keys().map(|s| s.as_str())
    }

    /// Look up one computation.
    pub fn entry(&self, name: &str) -> Result<&ArtifactEntry> {
        self.manifest
            .entries
            .get(name)
            .ok_or_else(|| Error::Artifact(format!("no artifact named {name:?} in manifest")))
    }

    /// Absolute path of the HLO text for `name`, verified to exist.
    pub fn hlo_path(&self, name: &str) -> Result<PathBuf> {
        let entry = self.entry(name)?;
        let p = self.dir.join(&entry.file);
        if !p.is_file() {
            return Err(Error::Artifact(format!(
                "artifact file {} missing (stale manifest? re-run `make artifacts`)",
                p.display()
            )));
        }
        Ok(p)
    }

    /// Model metadata; error if the manifest has no model section.
    pub fn model(&self) -> Result<&ModelMeta> {
        self.manifest
            .model
            .as_ref()
            .ok_or_else(|| Error::Artifact("manifest has no model section".into()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::tmp::TempDir;

    fn sample_manifest() -> &'static str {
        r#"{
          "version": 1,
          "entries": {
            "reduce_sum_1024": {
              "file": "reduce_sum_1024.hlo.txt",
              "inputs": [
                {"shape": [1024], "dtype": "f32"},
                {"shape": [1024], "dtype": "f32"}
              ],
              "outputs": [{"shape": [1024], "dtype": "f32"}]
            }
          },
          "model": {
            "param_names": ["w"],
            "param_shapes": [[4, 2]],
            "param_count": 8,
            "seq_len": 16,
            "batch_per_rank": 2,
            "vocab_size": 64
          }
        }"#
    }

    #[test]
    fn load_and_lookup() {
        let dir = TempDir::new().unwrap();
        std::fs::write(dir.path().join("manifest.json"), sample_manifest()).unwrap();
        std::fs::write(dir.path().join("reduce_sum_1024.hlo.txt"), "HloModule m").unwrap();
        let arts = Artifacts::load(dir.path()).unwrap();
        let e = arts.entry("reduce_sum_1024").unwrap();
        assert_eq!(e.inputs.len(), 2);
        assert_eq!(e.inputs[0].shape, vec![1024]);
        assert!(arts.hlo_path("reduce_sum_1024").is_ok());
        assert!(arts.entry("nope").is_err());
        let m = arts.model().unwrap();
        assert_eq!(m.param_shapes[0], vec![4, 2]);
        assert_eq!(m.vocab_size, 64);
    }

    #[test]
    fn missing_dir_is_actionable() {
        let err = Artifacts::load("/definitely/not/here").unwrap_err();
        let msg = err.to_string();
        assert!(msg.contains("make artifacts"), "got: {msg}");
    }

    #[test]
    fn stale_manifest_detected() {
        let dir = TempDir::new().unwrap();
        std::fs::write(dir.path().join("manifest.json"), sample_manifest()).unwrap();
        let arts = Artifacts::load(dir.path()).unwrap();
        // entry exists but file does not
        let err = arts.hlo_path("reduce_sum_1024").unwrap_err();
        assert!(err.to_string().contains("missing"));
    }

    #[test]
    fn manifest_without_model_is_fine() {
        let dir = TempDir::new().unwrap();
        std::fs::write(
            dir.path().join("manifest.json"),
            r#"{"version": 1, "entries": {}}"#,
        )
        .unwrap();
        let arts = Artifacts::load(dir.path()).unwrap();
        assert!(arts.model().is_err());
    }
}
