//! Artifact registry — the contract between `python/compile/aot.py` and the
//! Rust runtime.
//!
//! `make artifacts` writes `artifacts/manifest.json` plus one `*.hlo.txt`
//! per compiled computation. The manifest records each computation's input
//! and output tensor specs so the Rust side can validate calls without ever
//! importing Python.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use crate::dispatch::SvmDispatcher;
use crate::error::{Error, Result};
use crate::topology::Machine;
use crate::util::json::Value;

/// Shape + dtype of one tensor crossing the AOT boundary.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TensorSpecJson {
    pub shape: Vec<usize>,
    pub dtype: String,
}

impl TensorSpecJson {
    fn from_json(v: &Value) -> Result<Self> {
        Ok(Self {
            shape: v.get("shape")?.vec_usize()?,
            dtype: v.get("dtype")?.as_str()?.to_string(),
        })
    }
}

/// One AOT-compiled computation.
#[derive(Debug, Clone)]
pub struct ArtifactEntry {
    /// HLO text file, relative to the artifact directory.
    pub file: String,
    /// Input tensor specs, in call order.
    pub inputs: Vec<TensorSpecJson>,
    /// Output tensor specs (the lowered function returns a tuple).
    pub outputs: Vec<TensorSpecJson>,
}

impl ArtifactEntry {
    fn from_json(v: &Value) -> Result<Self> {
        let specs = |key: &str| -> Result<Vec<TensorSpecJson>> {
            v.get(key)?
                .as_arr()?
                .iter()
                .map(TensorSpecJson::from_json)
                .collect()
        };
        Ok(Self {
            file: v.get("file")?.as_str()?.to_string(),
            inputs: specs("inputs")?,
            outputs: specs("outputs")?,
        })
    }
}

/// Metadata for the L2 model: how to build/flatten the parameter pytree.
#[derive(Debug, Clone)]
pub struct ModelMeta {
    /// Flattened parameter names, in the order `train_step` expects.
    pub param_names: Vec<String>,
    /// Shapes matching `param_names`.
    pub param_shapes: Vec<Vec<usize>>,
    /// Total parameter count.
    pub param_count: usize,
    /// Tokens per micro-batch row.
    pub seq_len: usize,
    /// Rows per rank per step.
    pub batch_per_rank: usize,
    /// Vocabulary size.
    pub vocab_size: usize,
}

impl ModelMeta {
    fn from_json(v: &Value) -> Result<Self> {
        let param_names = v
            .get("param_names")?
            .as_arr()?
            .iter()
            .map(|n| Ok(n.as_str()?.to_string()))
            .collect::<Result<Vec<_>>>()?;
        let param_shapes = v
            .get("param_shapes")?
            .as_arr()?
            .iter()
            .map(Value::vec_usize)
            .collect::<Result<Vec<_>>>()?;
        Ok(Self {
            param_names,
            param_shapes,
            param_count: v.get("param_count")?.as_usize()?,
            seq_len: v.get("seq_len")?.as_usize()?,
            batch_per_rank: v.get("batch_per_rank")?.as_usize()?,
            vocab_size: v.get("vocab_size")?.as_usize()?,
        })
    }
}

/// `artifacts/manifest.json` root.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub version: usize,
    pub entries: BTreeMap<String, ArtifactEntry>,
    pub model: Option<ModelMeta>,
}

impl Manifest {
    fn from_json(v: &Value) -> Result<Self> {
        let mut entries = BTreeMap::new();
        for (name, e) in v.get("entries")?.as_obj()? {
            entries.insert(name.clone(), ArtifactEntry::from_json(e)?);
        }
        let model = match v.get_opt("model") {
            Some(m) => Some(ModelMeta::from_json(m)?),
            None => None,
        };
        Ok(Self {
            version: v.get("version")?.as_usize()?,
            entries,
            model,
        })
    }
}

/// A loaded artifact directory.
#[derive(Debug, Clone)]
pub struct Artifacts {
    dir: PathBuf,
    manifest: Manifest,
}

impl Artifacts {
    /// Load `<dir>/manifest.json`. Fails with a clear message if
    /// `make artifacts` has not been run.
    pub fn load(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        let mpath = dir.join("manifest.json");
        let text = std::fs::read_to_string(&mpath).map_err(|e| {
            Error::Artifact(format!(
                "cannot read {} ({e}); run `make artifacts` first",
                mpath.display()
            ))
        })?;
        let value = Value::parse(&text)
            .map_err(|e| Error::Artifact(format!("malformed {}: {e}", mpath.display())))?;
        let manifest = Manifest::from_json(&value)
            .map_err(|e| Error::Artifact(format!("bad manifest {}: {e}", mpath.display())))?;
        Ok(Self { dir, manifest })
    }

    /// Default location: `$PCCL_ARTIFACTS` or `./artifacts`.
    pub fn load_default() -> Result<Self> {
        Self::load(Self::default_dir())
    }

    /// The default artifact directory (`$PCCL_ARTIFACTS` or `./artifacts`).
    pub fn default_dir() -> PathBuf {
        PathBuf::from(std::env::var("PCCL_ARTIFACTS").unwrap_or_else(|_| "artifacts".to_string()))
    }

    /// Open an artifact directory, creating it (with an empty manifest)
    /// when missing — used by flows that *produce* artifacts, such as
    /// persisting a trained dispatcher, where `make artifacts` need not
    /// have run.
    pub fn open_or_init(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref();
        if !dir.join("manifest.json").is_file() {
            std::fs::create_dir_all(dir)?;
            std::fs::write(dir.join("manifest.json"), r#"{"version":1,"entries":{}}"#)?;
        }
        Self::load(dir)
    }

    pub fn dir(&self) -> &Path {
        &self.dir
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// Names of all registered computations.
    pub fn names(&self) -> impl Iterator<Item = &str> {
        self.manifest.entries.keys().map(|s| s.as_str())
    }

    /// Look up one computation.
    pub fn entry(&self, name: &str) -> Result<&ArtifactEntry> {
        self.manifest
            .entries
            .get(name)
            .ok_or_else(|| Error::Artifact(format!("no artifact named {name:?} in manifest")))
    }

    /// Absolute path of the HLO text for `name`, verified to exist.
    pub fn hlo_path(&self, name: &str) -> Result<PathBuf> {
        let entry = self.entry(name)?;
        let p = self.dir.join(&entry.file);
        if !p.is_file() {
            return Err(Error::Artifact(format!(
                "artifact file {} missing (stale manifest? re-run `make artifacts`)",
                p.display()
            )));
        }
        Ok(p)
    }

    /// Model metadata; error if the manifest has no model section.
    pub fn model(&self) -> Result<&ModelMeta> {
        self.manifest
            .model
            .as_ref()
            .ok_or_else(|| Error::Artifact("manifest has no model section".into()))
    }

    // --- dispatcher persistence ------------------------------------------
    //
    // Trained dispatcher models are artifacts too: train once (netsim or
    // measured sweep), ship with the library, load at run time — the
    // paper's per-machine model files (§IV-C).

    /// Canonical path of the persisted dispatcher for `machine`.
    pub fn dispatcher_path(&self, machine: Machine) -> PathBuf {
        self.dir.join(format!("dispatcher-{}.json", machine.params().name))
    }

    /// Persist a trained dispatcher next to the compiled computations.
    pub fn save_dispatcher(&self, dispatcher: &SvmDispatcher) -> Result<PathBuf> {
        let path = self.dispatcher_path(dispatcher.machine);
        dispatcher.save(&path)?;
        Ok(path)
    }

    /// Load the persisted dispatcher trained for `machine`.
    pub fn load_dispatcher(&self, machine: Machine) -> Result<SvmDispatcher> {
        let path = self.dispatcher_path(machine);
        if !path.is_file() {
            return Err(Error::Artifact(format!(
                "no dispatcher artifact at {} (train one with `pccl dispatch --save` \
                 or `cargo run --example dispatch_demo`)",
                path.display()
            )));
        }
        SvmDispatcher::load(path)
    }

    /// Load whichever dispatcher artifact is present (machine-agnostic
    /// lookup for run-time selection when the deployment machine is not
    /// pinned). Preference follows `dispatcher-*.json` name order.
    pub fn load_any_dispatcher(&self) -> Result<SvmDispatcher> {
        let mut names: Vec<String> = std::fs::read_dir(&self.dir)?
            .filter_map(|e| e.ok())
            .filter_map(|e| e.file_name().into_string().ok())
            .filter(|n| n.starts_with("dispatcher-") && n.ends_with(".json"))
            .collect();
        names.sort();
        match names.first() {
            Some(name) => SvmDispatcher::load(self.dir.join(name)),
            None => Err(Error::Artifact(format!(
                "no dispatcher-*.json artifact in {}",
                self.dir.display()
            ))),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::tmp::TempDir;

    fn sample_manifest() -> &'static str {
        r#"{
          "version": 1,
          "entries": {
            "reduce_sum_1024": {
              "file": "reduce_sum_1024.hlo.txt",
              "inputs": [
                {"shape": [1024], "dtype": "f32"},
                {"shape": [1024], "dtype": "f32"}
              ],
              "outputs": [{"shape": [1024], "dtype": "f32"}]
            }
          },
          "model": {
            "param_names": ["w"],
            "param_shapes": [[4, 2]],
            "param_count": 8,
            "seq_len": 16,
            "batch_per_rank": 2,
            "vocab_size": 64
          }
        }"#
    }

    #[test]
    fn load_and_lookup() {
        let dir = TempDir::new().unwrap();
        std::fs::write(dir.path().join("manifest.json"), sample_manifest()).unwrap();
        std::fs::write(dir.path().join("reduce_sum_1024.hlo.txt"), "HloModule m").unwrap();
        let arts = Artifacts::load(dir.path()).unwrap();
        let e = arts.entry("reduce_sum_1024").unwrap();
        assert_eq!(e.inputs.len(), 2);
        assert_eq!(e.inputs[0].shape, vec![1024]);
        assert!(arts.hlo_path("reduce_sum_1024").is_ok());
        assert!(arts.entry("nope").is_err());
        let m = arts.model().unwrap();
        assert_eq!(m.param_shapes[0], vec![4, 2]);
        assert_eq!(m.vocab_size, 64);
    }

    #[test]
    fn missing_dir_is_actionable() {
        let err = Artifacts::load("/definitely/not/here").unwrap_err();
        let msg = err.to_string();
        assert!(msg.contains("make artifacts"), "got: {msg}");
    }

    #[test]
    fn stale_manifest_detected() {
        let dir = TempDir::new().unwrap();
        std::fs::write(dir.path().join("manifest.json"), sample_manifest()).unwrap();
        let arts = Artifacts::load(dir.path()).unwrap();
        // entry exists but file does not
        let err = arts.hlo_path("reduce_sum_1024").unwrap_err();
        assert!(err.to_string().contains("missing"));
    }

    #[test]
    fn open_or_init_creates_empty_manifest() {
        let dir = TempDir::new().unwrap();
        let sub = dir.path().join("arts");
        let arts = Artifacts::open_or_init(&sub).unwrap();
        assert_eq!(arts.names().count(), 0);
        // Idempotent: a second open sees the same (empty) registry.
        let again = Artifacts::open_or_init(&sub).unwrap();
        assert_eq!(again.manifest().version, 1);
        // Does not clobber an existing manifest.
        std::fs::write(sub.join("manifest.json"), sample_manifest()).unwrap();
        let full = Artifacts::open_or_init(&sub).unwrap();
        assert_eq!(full.names().count(), 1);
    }

    #[test]
    fn dispatcher_save_load_roundtrip_via_registry() {
        let dir = TempDir::new().unwrap();
        let arts = Artifacts::open_or_init(dir.path()).unwrap();
        assert!(arts.load_dispatcher(Machine::Frontier).is_err());
        assert!(arts.load_any_dispatcher().is_err());
        let d = SvmDispatcher::train(Machine::Frontier, &[16, 1024], &[32, 2048], 2, 5).unwrap();
        let path = arts.save_dispatcher(&d).unwrap();
        assert!(path.ends_with("dispatcher-frontier.json"));
        let back = arts.load_dispatcher(Machine::Frontier).unwrap();
        let any = arts.load_any_dispatcher().unwrap();
        for (mb, p) in [(16usize, 2048usize), (1024, 32)] {
            let kind = crate::backends::CollKind::AllGather;
            assert_eq!(d.choose(kind, mb << 20, p), back.choose(kind, mb << 20, p));
            assert_eq!(d.choose(kind, mb << 20, p), any.choose(kind, mb << 20, p));
        }
    }

    #[test]
    fn pre_lane_dispatcher_artifact_is_refused_with_schema_error() {
        // A dispatcher persisted before the lane feature (schema 1 — no
        // schema field) must surface the typed migration error, not a JSON
        // shape error from deep inside the SVM parser.
        let dir = TempDir::new().unwrap();
        let arts = Artifacts::open_or_init(dir.path()).unwrap();
        std::fs::write(
            arts.dispatcher_path(Machine::Frontier),
            r#"{"machine": "frontier", "models": {}}"#,
        )
        .unwrap();
        let err = arts.load_dispatcher(Machine::Frontier).unwrap_err();
        assert!(
            matches!(err, Error::ArtifactSchema { expected: 2, got: 1, .. }),
            "got: {err:?}"
        );
        assert!(err.to_string().contains("re-train"), "got: {err}");
        let err = arts.load_any_dispatcher().unwrap_err();
        assert!(matches!(err, Error::ArtifactSchema { .. }), "got: {err:?}");
    }

    #[test]
    fn manifest_without_model_is_fine() {
        let dir = TempDir::new().unwrap();
        std::fs::write(
            dir.path().join("manifest.json"),
            r#"{"version": 1, "entries": {}}"#,
        )
        .unwrap();
        let arts = Artifacts::load(dir.path()).unwrap();
        assert!(arts.model().is_err());
    }
}
