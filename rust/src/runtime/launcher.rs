//! Multi-rank in-process launcher: spawn `P` rank threads over the
//! in-memory transport ([`crate::comm::TransportHub`] endpoints) and
//! *measure* every registered backend across a message-size × rank-count
//! sweep.
//!
//! This is the measured counterpart of the netsim sweep that trains the
//! adaptive dispatcher (§IV-C): the netsim path predicts Frontier/
//! Perlmutter-scale timings, while this path times the actual data plane
//! on the machine at hand. Both feed the same
//! [`crate::dispatch::Dataset`] → [`crate::dispatch::SvmDispatcher`]
//! pipeline, so "train on your own measurements" is a first-class flow.
//!
//! Two execution modes:
//! * **spawn** (default): a fresh world per trial — fully isolated, but
//!   thread spawn/join dominates small-message cells.
//! * **persistent** ([`LauncherConfig::persistent`]): one
//!   [`PersistentWorld`] per topology serves the whole sweep from pinned
//!   rank threads, with warmup iterations before the timed section —
//!   lower noise, much larger sweeps feasible.
//!
//! Every cell also records `bytes_per_op` — the bytes the schedule moved,
//! summed over ranks, taken from the endpoints' traffic counters. Byte
//! volume is schedule-determined, so it is identical across modes; the
//! `pccl smoke` job asserts exactly that (the schedule-equivalence guard).

use std::time::Instant;

use crate::backends::{
    all_gather_chunks, all_gather_lanes_chunks, all_reduce_chunks, all_reduce_lanes_chunks,
    plan_spec_for, reduce_scatter_chunks, reduce_scatter_stripes, Backend, CollKind,
    CollectiveOptions, MIN_STRIPE_ELEMS,
};
use crate::collectives::plan;
use crate::comm::{Chunk, Communicator, TransportHub};
use crate::dispatch::{Dataset, SvmDispatcher};
use crate::error::{Error, Result};
use crate::metrics::Stats;
use crate::netsim::predict_phase_times;
use crate::topology::{Machine, Topology};
use crate::trace::{self, CellTrace, OpSpan};

use super::persistent::{PersistentWorld, TrialReport};

/// One measured sweep cell: trial statistics for a backend at a
/// (collective, message size, rank count) configuration.
#[derive(Debug, Clone)]
pub struct MeasuredCell {
    pub kind: CollKind,
    pub backend: Backend,
    /// Message bytes under the paper's §III-A convention (all-gather:
    /// output per GPU; reduce-scatter / all-reduce: input per GPU).
    pub msg_bytes: usize,
    pub ranks: usize,
    pub stats: Stats,
    /// Transport lanes the cell ran on (1 = the pre-lane data plane).
    pub lanes: usize,
    /// Bytes actually sent per collective op, summed over all ranks —
    /// schedule-determined and identical across launcher modes AND across
    /// lane counts (striping partitions the same schedule).
    pub bytes_per_op: u64,
    /// Received bytes delivered by *copying* per collective op, summed over
    /// all ranks ([`crate::comm::Traffic::copied_bytes`] deltas). The
    /// reduce path must report 0 — `pccl smoke` enforces it.
    pub copied_bytes_per_op: u64,
    /// Bytes sent per op on each transport lane, summed over ranks
    /// (`moved_bytes_per_lane.iter().sum() == bytes_per_op`).
    pub moved_bytes_per_lane: Vec<u64>,
    /// Order-independent checksum of every rank's result (sum of output
    /// elements as f64, summed over ranks) — lane-count invariant on the
    /// integer-valued sweep inputs, so `pccl smoke` compares it exactly.
    pub checksum: f64,
    /// Op-level trace of one dedicated traced trial, run *after* the timed
    /// trials (never inside the measured section) and aggregated across
    /// ranks. Before a cell is returned, its trace is checked op-for-op
    /// against the verified plan's [`plan::phase_shapes`] — a disagreement
    /// fails the cell. `None` only for [`Backend::Auto`] cells, whose
    /// backend resolves per call.
    pub trace: Option<CellTrace>,
    /// Netsim-predicted seconds per traced phase (aligned with
    /// `trace.phases`), costed from the same `plan::phase_shapes` the
    /// tracer is checked against, on the [`Machine::Generic`] model.
    pub predicted_phase_s: Vec<f64>,
}

/// Sweep configuration for the launcher.
#[derive(Debug, Clone)]
pub struct LauncherConfig {
    /// Topologies to measure (world size and hierarchy come from each).
    pub topologies: Vec<Topology>,
    /// Message element counts (f32) per configuration, §III-A convention.
    pub elem_counts: Vec<usize>,
    /// Timed repetitions per cell.
    pub trials: usize,
    /// Back-to-back collectives inside one timed trial — amortizes
    /// fixed costs so the sample reflects the per-collective hot path.
    pub inner_iters: usize,
    /// Untimed collectives before the timed section of each trial
    /// (warms allocators, channels, and branch predictors).
    pub warmup_iters: usize,
    /// Serve the sweep from one persistent world per topology instead of
    /// spawning a fresh world per trial.
    pub persistent: bool,
    /// Transport lane counts to sweep (each count gets its own transport;
    /// `[1]` reproduces the pre-lane sweep cell for cell).
    pub lane_counts: Vec<usize>,
}

impl Default for LauncherConfig {
    fn default() -> Self {
        Self {
            topologies: vec![Topology::flat(4), Topology::new(2, 4, 2).expect("static shape")],
            elem_counts: vec![1 << 10, 1 << 14, 1 << 17],
            trials: 3,
            inner_iters: 8,
            warmup_iters: 1,
            persistent: false,
            lane_counts: vec![1],
        }
    }
}

impl LauncherConfig {
    /// CI-sized preset: few sizes, few reps — finishes in seconds.
    pub fn smoke() -> Self {
        Self {
            topologies: vec![Topology::flat(2), Topology::new(2, 2, 1).expect("static shape")],
            elem_counts: vec![1 << 10, 1 << 14],
            trials: 2,
            inner_iters: 4,
            warmup_iters: 1,
            persistent: false,
            lane_counts: vec![1],
        }
    }

    /// Lane-sweep preset for `pccl smoke`: 8 ranks so the striped phases
    /// have real rings to drive, one small and one large size (the large
    /// one is where lanes must win), lanes ∈ {1, 4} for the cross-lane
    /// schedule-equivalence guard, persistent worlds to keep the timings
    /// comparable across lane counts.
    pub fn lanes_smoke() -> Self {
        Self {
            topologies: vec![Topology::flat(8)],
            elem_counts: vec![1 << 14, 1 << 20],
            trials: 2,
            inner_iters: 2,
            warmup_iters: 1,
            persistent: true,
            lane_counts: vec![1, 4],
        }
    }

    /// Builder-style toggle for persistent-world mode.
    pub fn with_persistent(mut self, on: bool) -> Self {
        self.persistent = on;
        self
    }

    /// Builder-style lane-count sweep.
    pub fn with_lane_counts(mut self, lanes: Vec<usize>) -> Self {
        self.lane_counts = if lanes.is_empty() { vec![1] } else { lanes };
        self
    }
}

/// A completed measurement sweep over the real data plane.
#[derive(Debug, Clone, Default)]
pub struct MeasuredSweep {
    pub cells: Vec<MeasuredCell>,
}

impl MeasuredSweep {
    /// Labeled dataset for one collective: each (size, ranks, lanes)
    /// configuration is labeled with its measured-fastest backend.
    pub fn dataset(&self, kind: CollKind) -> Result<Dataset> {
        let mut data = Dataset::default();
        // Group cells by configuration, preserving sweep order.
        let mut configs: Vec<(usize, usize, usize)> = Vec::new();
        for c in self.cells.iter().filter(|c| c.kind == kind) {
            if !configs.contains(&(c.msg_bytes, c.ranks, c.lanes)) {
                configs.push((c.msg_bytes, c.ranks, c.lanes));
            }
        }
        for (msg, ranks, lanes) in configs {
            let times: Vec<(Backend, f64)> = self
                .cells
                .iter()
                .filter(|c| {
                    c.kind == kind && c.msg_bytes == msg && c.ranks == ranks && c.lanes == lanes
                })
                .map(|c| (c.backend, c.stats.mean()))
                .collect();
            data.push_measured(kind, msg, ranks, lanes, &times)?;
        }
        Ok(data)
    }

    /// The cross-lane schedule-equivalence guard: every (collective,
    /// backend, size, ranks) configuration measured at several lane counts
    /// must move the same total bytes and produce the same checksum —
    /// striping partitions the schedule, it must never change it. Errors
    /// name the first diverging configuration.
    pub fn check_lane_equivalence(&self) -> Result<()> {
        let mut seen: Vec<(CollKind, Backend, usize, usize)> = Vec::new();
        for c in &self.cells {
            let key = (c.kind, c.backend, c.msg_bytes, c.ranks);
            if seen.contains(&key) {
                continue;
            }
            seen.push(key);
            let group: Vec<&MeasuredCell> = self
                .cells
                .iter()
                .filter(|x| {
                    x.kind == c.kind
                        && x.backend == c.backend
                        && x.msg_bytes == c.msg_bytes
                        && x.ranks == c.ranks
                })
                .collect();
            for x in &group {
                let lane_sum: u64 = x.moved_bytes_per_lane.iter().sum();
                if lane_sum != x.bytes_per_op {
                    return Err(Error::Dispatch(format!(
                        "per-lane counters disagree with the total: {:?}/{:?} msg={} p={} \
                         lanes={}: {} per-lane vs {} total",
                        x.kind, x.backend, x.msg_bytes, x.ranks,
                        x.lanes, lane_sum, x.bytes_per_op
                    )));
                }
                if x.bytes_per_op != c.bytes_per_op {
                    return Err(Error::Dispatch(format!(
                        "lane schedule divergence: {:?}/{:?} msg={} p={} moved {} bytes at \
                         lanes={} but {} bytes at lanes={}",
                        c.kind, c.backend, c.msg_bytes, c.ranks,
                        c.bytes_per_op, c.lanes, x.bytes_per_op, x.lanes
                    )));
                }
                if x.checksum != c.checksum {
                    return Err(Error::Dispatch(format!(
                        "lane result divergence: {:?}/{:?} msg={} p={} checksum {} at \
                         lanes={} but {} at lanes={}",
                        c.kind, c.backend, c.msg_bytes, c.ranks,
                        c.checksum, c.lanes, x.checksum, x.lanes
                    )));
                }
            }
        }
        Ok(())
    }

    /// One labeled dataset per collective.
    pub fn datasets(&self) -> Result<Vec<(CollKind, Dataset)>> {
        CollKind::ALL
            .iter()
            .map(|&kind| Ok((kind, self.dataset(kind)?)))
            .collect()
    }

    /// Train the adaptive dispatcher on the measured timings — the
    /// measurement-to-selection loop closed end to end.
    pub fn train_dispatcher(&self, machine: Machine, seed: u64) -> Result<SvmDispatcher> {
        SvmDispatcher::from_datasets(machine, self.datasets()?, seed)
    }

    /// Total bytes moved per sweep pass (sum of every cell's per-op bytes).
    pub fn total_bytes_per_op(&self) -> u64 {
        self.cells.iter().map(|c| c.bytes_per_op).sum()
    }
}

/// Spawns rank threads over the in-memory transport and times collectives.
#[derive(Debug, Clone, Default)]
pub struct Launcher {
    cfg: LauncherConfig,
}

/// Realized buffer shape for one cell: (input elements per rank, message
/// bytes under the §III-A convention).
fn cell_shape(kind: CollKind, elems: usize, p: usize) -> (usize, usize) {
    match kind {
        // msg = output bytes per GPU → input block is msg / p.
        CollKind::AllGather => {
            let block = (elems / p).max(1);
            (block, block * p * 4)
        }
        // msg = input bytes per GPU, which must divide by p.
        CollKind::ReduceScatter => {
            let n = elems.div_ceil(p) * p;
            (n, n * 4)
        }
        CollKind::AllReduce => {
            let n = elems.max(1);
            (n, n * 4)
        }
    }
}

/// Analytic bytes-per-op (summed over ranks) for the flat ring algorithms
/// — the closed-form side of the schedule-equivalence guard. `None` for
/// collectives whose flat path is not a plain ring.
///
/// `elems` is a count of **f32** elements (the launcher's sweep dtype —
/// `cell_shape` bakes in the same 4-byte size); other dtypes need their
/// own scaling.
pub fn flat_ring_expected_bytes(kind: CollKind, elems: usize, p: usize) -> Option<u64> {
    let (input_len, _) = cell_shape(kind, elems, p);
    match kind {
        // Each rank forwards p-1 blocks of its input size.
        CollKind::AllGather => Some((p * p.saturating_sub(1) * input_len * 4) as u64),
        // Each rank sends p-1 partials of input_len / p elements.
        CollKind::ReduceScatter => Some((p.saturating_sub(1) * input_len * 4) as u64),
        // Vendor all-reduce is a binomial tree, not a ring.
        CollKind::AllReduce => None,
    }
}

/// Analytic bytes-per-op for every flat-library cell the smoke guard can
/// check in closed form — [`flat_ring_expected_bytes`] extended with the
/// ring all-reduce composition, keyed by backend because Vendor and
/// Cray-MPICH diverge on all-reduce (tree vs. ring RS ∘ AG). `None` for
/// hierarchical backends and for the tree all-reduce (whose volume depends
/// on the non-power-of-two straggler pattern, not a single formula the
/// guard should duplicate).
pub fn expected_schedule_bytes(
    kind: CollKind,
    backend: Backend,
    elems: usize,
    p: usize,
) -> Option<u64> {
    match (backend, kind) {
        (Backend::Vendor | Backend::CrayMpich, CollKind::AllGather | CollKind::ReduceScatter) => {
            flat_ring_expected_bytes(kind, elems, p)
        }
        // Ring all-reduce = reduce-scatter + all-gather over the padded
        // length: each phase moves (p-1)·padded elements summed over ranks.
        (Backend::CrayMpich, CollKind::AllReduce) => {
            let (input_len, _) = cell_shape(kind, elems, p);
            let padded = input_len.div_ceil(p) * p;
            Some((2 * p.saturating_sub(1) * padded * 4) as u64)
        }
        _ => None,
    }
}

/// The stripe count a sweep cell actually runs at: mirrors
/// [`crate::backends::effective_lane_count`] (which needs a live
/// communicator) for a cell whose transport has `lanes` lanes and whose
/// per-rank input is `input_len` elements.
fn effective_cell_lanes(kind: CollKind, input_len: usize, p: usize, lanes: usize) -> usize {
    if lanes <= 1 {
        return 1; // cell_trial routes lanes <= 1 through the unstriped entry points
    }
    let per_block = match kind {
        CollKind::AllGather => input_len,
        CollKind::ReduceScatter | CollKind::AllReduce => input_len / p.max(1),
    };
    if per_block / lanes < MIN_STRIPE_ELEMS {
        1
    } else {
        lanes
    }
}

/// Statically verify the lowered plan of **every cell** in a sweep grid —
/// the `pccl verify-plans` core, also run as the `pccl smoke` preamble so
/// no schedule is ever timed without first proving it deadlock-free,
/// exactly-once covering, and byte-exact.
///
/// For each `(topology, lane count, size, collective, backend)` cell this
/// builds the same [`crate::collectives::plan::PlanSpec`] the dispatch
/// layer lowers at run time ([`plan_spec_for`], including the fallback
/// and lane gating), runs the all-rank lockstep verifier, and — where a
/// closed-form byte total exists ([`expected_schedule_bytes`]) — checks
/// the verifier's wire element total against it (×4: the sweep dtype is
/// f32). Returns the number of verified cells.
pub fn verify_plan_grid(cfg: &LauncherConfig) -> Result<usize> {
    let mut verified = 0usize;
    for &topo in &cfg.topologies {
        let p = topo.world_size();
        for &lanes in &cfg.lane_counts {
            for &elems in &cfg.elem_counts {
                for kind in CollKind::ALL {
                    let (input_len, _) = cell_shape(kind, elems, p);
                    let k = effective_cell_lanes(kind, input_len, p, lanes);
                    for backend in Backend::CONCRETE {
                        let spec = plan_spec_for(kind, backend, topo, input_len, k);
                        let stats = plan::verify(&spec).map_err(|e| {
                            Error::Dispatch(format!(
                                "plan verification failed: {:?}/{:?} elems={elems} p={p} \
                                 lanes={k}: {e}",
                                kind, backend
                            ))
                        })?;
                        if let Some(expect) = expected_schedule_bytes(kind, backend, elems, p) {
                            let got = stats.total_sent_elems * 4;
                            if got != expect {
                                return Err(Error::Dispatch(format!(
                                    "verified plan moves {got} bytes but the analytic schedule \
                                     expects {expect}: {:?}/{:?} elems={elems} p={p} lanes={k}",
                                    kind, backend
                                )));
                            }
                        }
                        verified += 1;
                    }
                }
            }
        }
    }
    Ok(verified)
}

/// Sum a chunk list's elements as f64 — the order-independent result
/// checksum the cross-lane guard compares (exact for the launcher's and
/// the chaos harness's integer-valued f32 inputs).
pub(crate) fn checksum_chunks(chunks: &[Chunk<f32>]) -> f64 {
    chunks
        .iter()
        .flat_map(|c| c.as_slice())
        .map(|&x| x as f64)
        .sum()
}

/// One collective op over the chunk-native entry points, returning the
/// result checksum. The input chunk clone is O(1), so the timed section
/// measures the data plane's actual hot path — not a per-op `Vec → Chunk`
/// staging copy that the real chunk-holding callers (ZeRO-3) never pay.
/// `lanes <= 1` takes the exact pre-lane entry points (byte-for-byte the
/// old schedule); `lanes > 1` takes the lane-aware entry points with
/// `opts.lanes` pre-set by [`cell_trial`].
pub(crate) fn run_collective(
    kind: CollKind,
    lanes: usize,
    comm: &mut Communicator<f32>,
    input: &Chunk<f32>,
    opts: &CollectiveOptions<f32>,
) -> Result<f64> {
    let out = match (kind, lanes > 1) {
        (CollKind::AllGather, false) => all_gather_chunks(comm, input.clone(), opts)?,
        (CollKind::AllGather, true) => all_gather_lanes_chunks(comm, input.clone(), opts)?,
        (CollKind::ReduceScatter, false) => {
            vec![reduce_scatter_chunks(comm, input.clone(), opts)?]
        }
        (CollKind::ReduceScatter, true) => reduce_scatter_stripes(comm, input.clone(), opts)?,
        (CollKind::AllReduce, false) => all_reduce_chunks(comm, input.clone(), opts)?,
        (CollKind::AllReduce, true) => all_reduce_lanes_chunks(comm, input.clone(), opts)?,
    };
    Ok(checksum_chunks(&out))
}

/// The per-rank trial body shared by both launcher modes: warmup, then a
/// timed run of `inner` back-to-back collectives with traffic deltas
/// (total and per lane) and the last op's result checksum.
fn cell_trial(
    kind: CollKind,
    backend: Backend,
    input_len: usize,
    lanes: usize,
    inner: usize,
    warmup: usize,
) -> impl Fn(&mut Communicator<f32>) -> Result<TrialReport> + Send + Sync + Clone + 'static {
    move |comm: &mut Communicator<f32>| {
        let opts = CollectiveOptions::<f32>::default().backend(backend).lanes(lanes.max(1));
        let input = Chunk::from_vec(vec![comm.rank() as f32; input_len]);
        for _ in 0..warmup {
            run_collective(kind, lanes, comm, &input, &opts)?;
        }
        let before = comm.traffic();
        let before_lanes = comm.traffic_per_lane();
        let start = Instant::now();
        let mut checksum = 0.0;
        for _ in 0..inner {
            checksum = run_collective(kind, lanes, comm, &input, &opts)?;
        }
        let secs = start.elapsed().as_secs_f64() / inner as f64;
        let after = comm.traffic();
        let after_lanes = comm.traffic_per_lane();
        let moved_bytes_per_lane = after_lanes
            .iter()
            .zip(&before_lanes)
            .map(|(a, b)| (a.sent_bytes - b.sent_bytes) / inner as u64)
            .collect();
        Ok(TrialReport {
            secs,
            sent_msgs: (after.sent_msgs - before.sent_msgs) / inner as u64,
            sent_bytes: (after.sent_bytes - before.sent_bytes) / inner as u64,
            copied_bytes: (after.copied_bytes - before.copied_bytes) / inner as u64,
            moved_bytes_per_lane,
            checksum,
            trace: Vec::new(),
        })
    }
}

/// The dedicated traced trial: one *untimed* collective per rank with the
/// op-level tracer installed for its duration. Launched after a cell's
/// timed trials, so span recording never overlaps a measured section.
fn traced_cell_trial(
    kind: CollKind,
    backend: Backend,
    input_len: usize,
    lanes: usize,
) -> impl Fn(&mut Communicator<f32>) -> Result<TrialReport> + Send + Sync + Clone + 'static {
    move |comm: &mut Communicator<f32>| {
        let opts = CollectiveOptions::<f32>::default().backend(backend).lanes(lanes.max(1));
        let input = Chunk::from_vec(vec![comm.rank() as f32; input_len]);
        crate::trace::begin(comm.rank());
        let run = run_collective(kind, lanes, comm, &input, &opts);
        // Uninstall before surfacing any error so the rank thread never
        // carries a stale tracer into later (timed) trials.
        let spans = crate::trace::end().map(|t| t.into_spans()).unwrap_or_default();
        let checksum = run?;
        Ok(TrialReport { checksum, trace: spans, ..Default::default() })
    }
}

/// Aggregate a traced trial's per-rank spans into a [`CellTrace`], verify
/// the observed per-phase op structure against the lowered plan, and cost
/// the same phases on the generic machine model. A trace that disagrees
/// with its verified plan fails the cell — this is the observed-vs-planned
/// guard `pccl smoke` (and every sweep) runs.
fn fold_trace(
    kind: CollKind,
    backend: Backend,
    topo: Topology,
    input_len: usize,
    lanes: usize,
    reports: Vec<TrialReport>,
) -> Result<(CellTrace, Vec<f64>)> {
    let p = topo.world_size();
    let spans: Vec<Vec<OpSpan>> = reports.into_iter().map(|r| r.trace).collect();
    let cell_trace = trace::aggregate(spans);
    let k = effective_cell_lanes(kind, input_len, p, lanes);
    let spec = plan_spec_for(kind, backend, topo, input_len, k);
    trace::check_phases(&cell_trace, &spec, 4).map_err(|e| {
        Error::Dispatch(format!(
            "traced {:?}/{:?} run disagrees with its verified plan \
             (elems={input_len} p={p} lanes={k}): {e}",
            kind, backend
        ))
    })?;
    let predicted = predict_phase_times(&spec, Machine::Generic, 4)?;
    Ok((cell_trace, predicted))
}

impl Launcher {
    pub fn new(cfg: LauncherConfig) -> Self {
        Self { cfg }
    }

    pub fn config(&self) -> &LauncherConfig {
        &self.cfg
    }

    /// Run one SPMD closure over `topo`: builds a fresh transport, spawns
    /// one named thread per rank holding its [`crate::comm::Endpoint`], and
    /// joins per-rank results in rank order. Unlike
    /// [`crate::comm::CommWorld::run`], errors (including rank panics) are
    /// returned, not propagated as panics — the sweep must survive a bad
    /// configuration.
    pub fn launch<T, R, F>(&self, topo: Topology, f: F) -> Result<Vec<R>>
    where
        T: Send + Sync + 'static,
        R: Send,
        F: Fn(&mut Communicator<T>) -> Result<R> + Sync,
    {
        let (_hub, eps) = TransportHub::<T>::new(topo.world_size());
        self.launch_on(topo, eps, f)
    }

    /// [`Launcher::launch`] over a multi-lane transport (`lanes == 1` is
    /// identical to `launch`). The extra `Clone` bound is what the lane
    /// workers need to take over stripe storage.
    pub fn launch_lanes<T, R, F>(&self, topo: Topology, lanes: usize, f: F) -> Result<Vec<R>>
    where
        T: Send + Sync + Clone + 'static,
        R: Send,
        F: Fn(&mut Communicator<T>) -> Result<R> + Sync,
    {
        let (_hub, eps) = TransportHub::<T>::new_with_lanes(topo.world_size(), lanes.max(1));
        self.launch_on(topo, eps, f)
    }

    fn launch_on<T, R, F>(
        &self,
        topo: Topology,
        eps: Vec<crate::comm::Endpoint<T>>,
        f: F,
    ) -> Result<Vec<R>>
    where
        T: Send + Sync + 'static,
        R: Send,
        F: Fn(&mut Communicator<T>) -> Result<R> + Sync,
    {
        let results: Vec<Result<R>> = std::thread::scope(|s| {
            let f = &f;
            // Spawn failures become per-rank errors instead of a panic:
            // the spawned ranks run to their own recv timeout and the
            // sweep surfaces the OS error for the rank that never started.
            let handles: Vec<std::io::Result<_>> = eps
                .into_iter()
                .map(|ep| {
                    std::thread::Builder::new()
                        .name(format!("pccl-launch-{}", ep.rank()))
                        .spawn_scoped(s, move || -> Result<R> {
                            let mut comm = Communicator::new(ep, topo)?;
                            f(&mut comm)
                        })
                })
                .collect();
            handles
                .into_iter()
                .enumerate()
                .map(|(rank, h)| match h {
                    // A panicked rank is a dead data-plane endpoint, not a
                    // dispatcher problem — surface it as the transport
                    // failure its peers would observe.
                    Ok(h) => h.join().unwrap_or_else(|_| Err(Error::TransportClosed { rank })),
                    Err(e) => Err(Error::from(e)),
                })
                .collect()
        });
        results.into_iter().collect()
    }

    /// Time one (topology, collective, backend, size) cell in spawn mode:
    /// rank 0's wall time over `inner_iters` back-to-back collectives per
    /// trial (the collectives are globally synchronizing, so every rank
    /// finishes together).
    pub fn time_cell(
        &self,
        topo: Topology,
        kind: CollKind,
        backend: Backend,
        elems: usize,
    ) -> Result<MeasuredCell> {
        self.time_cell_lanes(topo, kind, backend, elems, 1)
    }

    /// [`Launcher::time_cell`] on a `lanes`-lane transport through the
    /// lane-aware entry points.
    pub fn time_cell_lanes(
        &self,
        topo: Topology,
        kind: CollKind,
        backend: Backend,
        elems: usize,
        lanes: usize,
    ) -> Result<MeasuredCell> {
        let p = topo.world_size();
        let (input_len, msg_bytes) = cell_shape(kind, elems, p);
        let trial = cell_trial(
            kind,
            backend,
            input_len,
            lanes,
            self.cfg.inner_iters.max(1),
            self.cfg.warmup_iters,
        );
        let mut stats = Stats::new();
        let mut reports = Vec::new();
        for _ in 0..self.cfg.trials.max(1) {
            reports = self.launch_lanes::<f32, _, _>(topo, lanes, &trial)?;
            stats.push(reports[0].secs);
        }
        // One extra traced (untimed) trial, checked against the plan.
        let (cell_trace, predicted) = if backend == Backend::Auto {
            (None, Vec::new())
        } else {
            let traced = traced_cell_trial(kind, backend, input_len, lanes);
            let trace_reports = self.launch_lanes::<f32, _, _>(topo, lanes, &traced)?;
            let (t, pr) = fold_trace(kind, backend, topo, input_len, lanes, trace_reports)?;
            (Some(t), pr)
        };
        Ok(Self::collect_cell(
            kind, backend, msg_bytes, p, lanes, stats, &reports, cell_trace, predicted,
        ))
    }

    /// Time one cell on a pinned [`PersistentWorld`] (its lane count
    /// decides the entry points, exactly like [`Launcher::time_cell_lanes`]).
    pub fn time_cell_in(
        &self,
        world: &mut PersistentWorld<f32>,
        kind: CollKind,
        backend: Backend,
        elems: usize,
    ) -> Result<MeasuredCell> {
        let p = world.size();
        let lanes = world.lanes();
        let (input_len, msg_bytes) = cell_shape(kind, elems, p);
        let trial = cell_trial(
            kind,
            backend,
            input_len,
            lanes,
            self.cfg.inner_iters.max(1),
            self.cfg.warmup_iters,
        );
        let mut stats = Stats::new();
        let mut reports = Vec::new();
        for _ in 0..self.cfg.trials.max(1) {
            reports = world.run_trial(trial.clone())?;
            stats.push(reports[0].secs);
        }
        // One extra traced (untimed) trial on the same pinned threads; the
        // trial uninstalls its tracer, so later trials stay untraced.
        let (cell_trace, predicted) = if backend == Backend::Auto {
            (None, Vec::new())
        } else {
            let traced = traced_cell_trial(kind, backend, input_len, lanes);
            let trace_reports = world.run_trial(traced)?;
            let (t, pr) =
                fold_trace(kind, backend, world.topology(), input_len, lanes, trace_reports)?;
            (Some(t), pr)
        };
        Ok(Self::collect_cell(
            kind, backend, msg_bytes, p, lanes, stats, &reports, cell_trace, predicted,
        ))
    }

    /// Fold the last trial's per-rank reports into a cell: byte totals,
    /// per-lane byte totals, and the cross-rank checksum sum.
    #[allow(clippy::too_many_arguments)]
    fn collect_cell(
        kind: CollKind,
        backend: Backend,
        msg_bytes: usize,
        ranks: usize,
        lanes: usize,
        stats: Stats,
        reports: &[TrialReport],
        trace: Option<CellTrace>,
        predicted_phase_s: Vec<f64>,
    ) -> MeasuredCell {
        let lane_count = reports
            .iter()
            .map(|t| t.moved_bytes_per_lane.len())
            .max()
            .unwrap_or(0);
        let mut moved_bytes_per_lane = vec![0u64; lane_count];
        for t in reports {
            for (l, &b) in t.moved_bytes_per_lane.iter().enumerate() {
                moved_bytes_per_lane[l] += b;
            }
        }
        MeasuredCell {
            kind,
            backend,
            msg_bytes,
            ranks,
            stats,
            lanes: lanes.max(1),
            bytes_per_op: reports.iter().map(|t| t.sent_bytes).sum(),
            copied_bytes_per_op: reports.iter().map(|t| t.copied_bytes).sum(),
            moved_bytes_per_lane,
            checksum: reports.iter().map(|t| t.checksum).sum(),
            trace,
            predicted_phase_s,
        }
    }

    /// The full sweep: every registered backend × every collective × every
    /// (size, topology) cell of the configuration, in the configured mode.
    pub fn sweep(&self) -> Result<MeasuredSweep> {
        if self.cfg.persistent {
            return self.sweep_persistent();
        }
        let mut cells = Vec::new();
        for &topo in &self.cfg.topologies {
            for &lanes in &self.cfg.lane_counts {
                for &elems in &self.cfg.elem_counts {
                    for kind in CollKind::ALL {
                        for backend in Backend::CONCRETE {
                            cells.push(self.time_cell_lanes(topo, kind, backend, elems, lanes)?);
                        }
                    }
                }
            }
        }
        Ok(MeasuredSweep { cells })
    }

    /// The sweep served from one persistent world per (topology, lane
    /// count): world setup is amortized over all of that world's cells and
    /// trials.
    pub fn sweep_persistent(&self) -> Result<MeasuredSweep> {
        let mut cells = Vec::new();
        for &topo in &self.cfg.topologies {
            for &lanes in &self.cfg.lane_counts {
                let mut world = PersistentWorld::<f32>::new_with_lanes(topo, lanes)?;
                for &elems in &self.cfg.elem_counts {
                    for kind in CollKind::ALL {
                        for backend in Backend::CONCRETE {
                            cells.push(self.time_cell_in(&mut world, kind, backend, elems)?);
                        }
                    }
                }
            }
        }
        Ok(MeasuredSweep { cells })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn launch_runs_spmd_and_orders_results() {
        let launcher = Launcher::default();
        let outs = launcher
            .launch::<f32, _, _>(Topology::flat(5), |c| {
                use crate::comm::Comm;
                c.begin_op();
                let p = c.size();
                let r = c.rank();
                c.send_slice((r + 1) % p, 0, crate::comm::Chunk::from_vec(vec![r as f32]))?;
                Ok(c.recv_chunk((r + p - 1) % p, 0)?[0])
            })
            .unwrap();
        assert_eq!(outs, vec![4.0, 0.0, 1.0, 2.0, 3.0]);
    }

    #[test]
    fn launch_surfaces_rank_errors_instead_of_panicking() {
        let launcher = Launcher::default();
        let err = launcher
            .launch::<f32, _, _>(Topology::flat(2), |c| {
                if c.rank() == 0 {
                    Err(Error::Dispatch("boom".into()))
                } else {
                    Ok(())
                }
            })
            .unwrap_err();
        assert!(err.to_string().contains("boom"));
    }

    #[test]
    fn cell_shapes_follow_paper_convention() {
        // All-gather: elems is the output size; input block = elems / p.
        assert_eq!(cell_shape(CollKind::AllGather, 64, 4), (16, 256));
        // Reduce-scatter: input rounded up to a multiple of p.
        assert_eq!(cell_shape(CollKind::ReduceScatter, 10, 4), (12, 48));
        assert_eq!(cell_shape(CollKind::AllReduce, 10, 4), (10, 40));
    }

    #[test]
    fn sweep_covers_every_backend_and_trains_a_dispatcher() {
        let launcher = Launcher::new(LauncherConfig {
            topologies: vec![Topology::flat(2), Topology::new(2, 2, 1).unwrap()],
            elem_counts: vec![256, 4096],
            trials: 2,
            inner_iters: 2,
            warmup_iters: 1,
            persistent: false,
            lane_counts: vec![1],
        });
        let sweep = launcher.sweep().unwrap();
        // 2 topologies × 2 sizes × 3 collectives × 4 backends.
        assert_eq!(sweep.cells.len(), 2 * 2 * 3 * 4);
        assert!(sweep.cells.iter().all(|c| c.stats.count() == 2));
        assert!(sweep.cells.iter().all(|c| c.stats.mean() > 0.0));
        assert!(sweep.cells.iter().all(|c| c.bytes_per_op > 0));
        // Every concrete cell carries a plan-checked trace with a
        // prediction per observed phase (the traced trial added no sample
        // to `stats` — count stays at `trials`).
        assert!(sweep.cells.iter().all(|c| {
            let t = c.trace.as_ref().expect("traced trial attached");
            !t.phases.is_empty() && c.predicted_phase_s.len() >= t.phases.len()
        }));
        for kind in CollKind::ALL {
            let d = sweep.dataset(kind).unwrap();
            assert_eq!(d.len(), 4, "one labeled sample per configuration");
        }
        // The measurement-to-selection loop closes: a dispatcher trains on
        // the measured data and yields a dispatchable backend everywhere.
        let dispatcher = sweep.train_dispatcher(Machine::Generic, 11).unwrap();
        for kind in CollKind::ALL {
            let b = dispatcher.choose(kind, 4096 * 4, 4);
            assert!(Backend::CONCRETE.contains(&b));
        }
    }

    #[test]
    fn ring_byte_counters_match_the_analytic_schedule() {
        let launcher = Launcher::new(LauncherConfig {
            topologies: vec![Topology::flat(4)],
            elem_counts: vec![512],
            trials: 1,
            inner_iters: 2,
            warmup_iters: 1,
            persistent: false,
            lane_counts: vec![1],
        });
        for kind in [CollKind::AllGather, CollKind::ReduceScatter] {
            let cell = launcher
                .time_cell(Topology::flat(4), kind, Backend::Vendor, 512)
                .unwrap();
            let expect = flat_ring_expected_bytes(kind, 512, 4).unwrap();
            assert_eq!(cell.bytes_per_op, expect, "{kind:?}");
        }
        // The ring all-reduce composition (Cray-MPICH) has a closed form
        // too — including the padded case (513 on 4 ranks pads to 516).
        for elems in [512usize, 513] {
            let cell = launcher
                .time_cell(Topology::flat(4), CollKind::AllReduce, Backend::CrayMpich, elems)
                .unwrap();
            let expect =
                expected_schedule_bytes(CollKind::AllReduce, Backend::CrayMpich, elems, 4)
                    .unwrap();
            assert_eq!(cell.bytes_per_op, expect, "all-reduce elems={elems}");
        }
        // Vendor all-reduce (tree) and hierarchical backends have no
        // closed form here.
        assert!(expected_schedule_bytes(CollKind::AllReduce, Backend::Vendor, 512, 4).is_none());
        assert!(expected_schedule_bytes(CollKind::AllGather, Backend::PcclRec, 512, 4).is_none());
    }

    #[test]
    fn verify_plan_grid_covers_smoke_and_lane_grids() {
        // The exact grids `pccl smoke` runs must verify statically —
        // including the closed-form byte cross-checks for the flat cells.
        let n = verify_plan_grid(&LauncherConfig::smoke()).unwrap();
        // 2 topologies × 1 lane count × 2 sizes × 3 collectives × 4 backends.
        assert_eq!(n, 2 * 2 * 3 * 4);
        // 1 topology × 2 lane counts × 2 sizes × 3 collectives × 4 backends.
        let n = verify_plan_grid(&LauncherConfig::lanes_smoke()).unwrap();
        assert_eq!(n, 2 * 2 * 3 * 4);
        // Lane gating mirrors the dispatch layer: small blocks demote.
        assert_eq!(effective_cell_lanes(CollKind::AllGather, 2048, 8, 4), 1);
        assert_eq!(effective_cell_lanes(CollKind::AllGather, 4 * MIN_STRIPE_ELEMS, 8, 4), 4);
    }

    #[test]
    fn lane_sweep_preserves_schedule_and_results() {
        // 8192 elements on 4 ranks keeps every striped path above
        // MIN_STRIPE_ELEMS at 2 lanes, so the lanes=2 cells genuinely
        // stripe — and must still move the same bytes to the same results.
        let launcher = Launcher::new(LauncherConfig {
            topologies: vec![Topology::flat(4)],
            elem_counts: vec![1 << 13],
            trials: 1,
            inner_iters: 2,
            warmup_iters: 1,
            persistent: true,
            lane_counts: vec![1, 2],
        });
        let sweep = launcher.sweep().unwrap();
        // 2 lane counts × 1 size × 3 collectives × 4 backends.
        assert_eq!(sweep.cells.len(), 2 * 3 * 4);
        sweep.check_lane_equivalence().unwrap();
        // The PCCL ring cells actually used both lanes.
        let striped = sweep
            .cells
            .iter()
            .find(|c| {
                c.kind == CollKind::ReduceScatter && c.backend == Backend::PcclRing && c.lanes == 2
            })
            .unwrap();
        assert_eq!(striped.moved_bytes_per_lane.len(), 2);
        assert!(
            striped.moved_bytes_per_lane.iter().all(|&b| b > 0),
            "both lanes must carry stripe traffic: {:?}",
            striped.moved_bytes_per_lane
        );
        assert_eq!(striped.copied_bytes_per_op, 0, "reduce path must stay copy-free");
        // And the guard actually fires on a forged divergence.
        let mut bad = sweep.clone();
        let idx = bad
            .cells
            .iter()
            .position(|c| c.lanes == 2 && c.backend == Backend::PcclRing)
            .unwrap();
        bad.cells[idx].checksum += 1.0;
        assert!(bad.check_lane_equivalence().is_err());
    }
}
