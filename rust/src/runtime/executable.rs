//! Direct (single-thread) PJRT execution.
//!
//! The `xla` crate's `PjRtClient` wraps an `Rc`, so it is **not** `Send`.
//! [`Runtime`] therefore lives on one thread; multi-rank use goes through
//! [`crate::runtime::service`]'s device-service thread, which mirrors how a
//! real GPU runtime serializes kernel launches onto a stream.
//!
//! This offline build links the in-tree [`super::xla_stub`] instead of the
//! real bindings (see that module's docs); swapping the import below is the
//! only change needed to restore real PJRT execution.

use std::cell::RefCell;
use std::collections::HashMap;
use std::rc::Rc;

use crate::error::{Error, Result};

use super::artifacts::{ArtifactEntry, Artifacts, TensorSpecJson};
use super::xla_stub as xla;

/// Host-side tensor crossing the PJRT boundary.
#[derive(Debug, Clone, PartialEq)]
pub enum HostTensor {
    F32 { data: Vec<f32>, shape: Vec<usize> },
    I32 { data: Vec<i32>, shape: Vec<usize> },
}

impl HostTensor {
    pub fn f32(data: Vec<f32>, shape: Vec<usize>) -> Self {
        HostTensor::F32 { data, shape }
    }

    pub fn i32(data: Vec<i32>, shape: Vec<usize>) -> Self {
        HostTensor::I32 { data, shape }
    }

    pub fn shape(&self) -> &[usize] {
        match self {
            HostTensor::F32 { shape, .. } | HostTensor::I32 { shape, .. } => shape,
        }
    }

    pub fn dtype_str(&self) -> &'static str {
        match self {
            HostTensor::F32 { .. } => "f32",
            HostTensor::I32 { .. } => "i32",
        }
    }

    pub fn len(&self) -> usize {
        match self {
            HostTensor::F32 { data, .. } => data.len(),
            HostTensor::I32 { data, .. } => data.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Unwrap as f32 data, or error.
    pub fn into_f32(self) -> Result<Vec<f32>> {
        match self {
            HostTensor::F32 { data, .. } => Ok(data),
            other => Err(Error::Xla(format!(
                "expected f32 tensor, got {}",
                other.dtype_str()
            ))),
        }
    }

    fn to_literal(&self) -> Result<xla::Literal> {
        let dims: Vec<i64> = self.shape().iter().map(|&d| d as i64).collect();
        let lit = match self {
            HostTensor::F32 { data, .. } => xla::Literal::vec1(data),
            HostTensor::I32 { data, .. } => xla::Literal::vec1(data),
        };
        lit.reshape(&dims)
    }

    fn from_literal(lit: &xla::Literal, spec: &TensorSpecJson) -> Result<Self> {
        let shape = spec.shape.clone();
        match spec.dtype.as_str() {
            "f32" => Ok(HostTensor::F32 {
                data: lit.to_vec::<f32>()?,
                shape,
            }),
            "i32" => Ok(HostTensor::I32 {
                data: lit.to_vec::<i32>()?,
                shape,
            }),
            other => Err(Error::Xla(format!("unsupported artifact dtype {other:?}"))),
        }
    }
}

/// Spec for one tensor, re-exported at the runtime API level.
pub type TensorSpec = TensorSpecJson;

/// A compiled computation plus its manifest entry (for call validation).
#[derive(Clone)]
pub struct Executable {
    exe: Rc<xla::PjRtLoadedExecutable>,
    entry: ArtifactEntry,
    name: String,
}

impl Executable {
    /// Validate `inputs` against the manifest and execute.
    ///
    /// The AOT pipeline lowers with `return_tuple=True`, so the single
    /// output buffer is a tuple that we decompose into one [`HostTensor`]
    /// per manifest output spec.
    pub fn run(&self, inputs: &[HostTensor]) -> Result<Vec<HostTensor>> {
        if inputs.len() != self.entry.inputs.len() {
            return Err(Error::Xla(format!(
                "{}: expected {} inputs, got {}",
                self.name,
                self.entry.inputs.len(),
                inputs.len()
            )));
        }
        for (i, (t, spec)) in inputs.iter().zip(&self.entry.inputs).enumerate() {
            if t.shape() != spec.shape.as_slice() || t.dtype_str() != spec.dtype {
                return Err(Error::Xla(format!(
                    "{}: input {i} mismatch: got {:?}/{}, manifest says {:?}/{}",
                    self.name,
                    t.shape(),
                    t.dtype_str(),
                    spec.shape,
                    spec.dtype
                )));
            }
        }
        let lits: Vec<xla::Literal> = inputs
            .iter()
            .map(|t| t.to_literal())
            .collect::<Result<_>>()?;
        let result = self.exe.execute::<xla::Literal>(&lits)?;
        let out = result
            .first()
            .and_then(|r| r.first())
            .ok_or_else(|| Error::Xla(format!("{}: empty execution result", self.name)))?
            .to_literal_sync()?;
        let parts = out.to_tuple()?;
        if parts.len() != self.entry.outputs.len() {
            return Err(Error::Xla(format!(
                "{}: manifest says {} outputs, executable returned {}",
                self.name,
                self.entry.outputs.len(),
                parts.len()
            )));
        }
        parts
            .iter()
            .zip(&self.entry.outputs)
            .map(|(lit, spec)| HostTensor::from_literal(lit, spec))
            .collect()
    }

    /// Convenience wrapper for all-f32 computations (the reduction kernels).
    pub fn run_f32(&self, inputs: &[&[f32]]) -> Result<Vec<Vec<f32>>> {
        let tensors: Vec<HostTensor> = inputs
            .iter()
            .zip(&self.entry.inputs)
            .map(|(d, spec)| HostTensor::f32(d.to_vec(), spec.shape.clone()))
            .collect();
        self.run(&tensors)?
            .into_iter()
            .map(|t| t.into_f32())
            .collect()
    }

    pub fn name(&self) -> &str {
        &self.name
    }

    pub fn entry(&self) -> &ArtifactEntry {
        &self.entry
    }
}

/// Single-thread PJRT runtime: one client, compiled-executable cache.
pub struct Runtime {
    client: xla::PjRtClient,
    arts: Artifacts,
    cache: RefCell<HashMap<String, Executable>>,
}

impl Runtime {
    /// Create a CPU PJRT client over an artifact directory.
    pub fn new(arts: Artifacts) -> Result<Self> {
        let client = xla::PjRtClient::cpu()?;
        Ok(Self {
            client,
            arts,
            cache: RefCell::new(HashMap::new()),
        })
    }

    pub fn artifacts(&self) -> &Artifacts {
        &self.arts
    }

    /// Load (compile-on-first-use) a named computation.
    pub fn load(&self, name: &str) -> Result<Executable> {
        if let Some(e) = self.cache.borrow().get(name) {
            return Ok(e.clone());
        }
        let path = self.arts.hlo_path(name)?;
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str()
                .ok_or_else(|| Error::Artifact(format!("non-utf8 path {}", path.display())))?,
        )?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp)?;
        let entry = self.arts.entry(name)?.clone();
        let executable = Executable {
            exe: Rc::new(exe),
            entry,
            name: name.to_string(),
        };
        self.cache
            .borrow_mut()
            .insert(name.to_string(), executable.clone());
        Ok(executable)
    }
}
