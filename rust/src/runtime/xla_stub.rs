//! Offline stand-in for the `xla` (PJRT) bindings.
//!
//! The runtime layer was written against the xla-rs PJRT API, but that
//! crate (and its native XLA toolchain) cannot be vendored into this
//! offline, dependency-free build. This module provides the same type
//! surface so the whole crate compiles and the artifact-registry /
//! device-service plumbing stays fully testable; the one operation a stub
//! cannot honestly perform — compiling an HLO module to executable code —
//! returns a typed [`Error::Xla`] instead. Tests and examples that need
//! compiled artifacts already skip when `make artifacts` has not produced
//! them, so a fresh checkout builds and tests green.
//!
//! Re-enabling real PJRT execution is a one-line import swap in
//! `runtime/executable.rs` (`use super::xla_stub as xla;` → `use xla;`)
//! plus the upstream dependency.

use crate::error::{Error, Result};

fn unavailable(what: &str) -> Error {
    Error::Xla(format!(
        "{what}: PJRT backend not available in this build (offline stub); \
         the data plane, netsim, and dispatcher paths are unaffected"
    ))
}

/// Payload of a host literal (the two dtypes crossing the AOT boundary).
#[derive(Debug, Clone, PartialEq)]
pub enum Payload {
    F32(Vec<f32>),
    I32(Vec<i32>),
}

/// Element types a [`Literal`] can hold.
pub trait NativeType: Copy {
    fn wrap(data: &[Self]) -> Payload;
    fn unwrap(lit: &Literal) -> Result<Vec<Self>>;
}

impl NativeType for f32 {
    fn wrap(data: &[Self]) -> Payload {
        Payload::F32(data.to_vec())
    }

    fn unwrap(lit: &Literal) -> Result<Vec<Self>> {
        match &lit.payload {
            Payload::F32(v) => Ok(v.clone()),
            Payload::I32(_) => Err(Error::Xla("literal is i32, expected f32".into())),
        }
    }
}

impl NativeType for i32 {
    fn wrap(data: &[Self]) -> Payload {
        Payload::I32(data.to_vec())
    }

    fn unwrap(lit: &Literal) -> Result<Vec<Self>> {
        match &lit.payload {
            Payload::I32(v) => Ok(v.clone()),
            Payload::F32(_) => Err(Error::Xla("literal is f32, expected i32".into())),
        }
    }
}

/// Host-side literal: rank-1 storage plus logical dimensions.
#[derive(Debug, Clone, PartialEq)]
pub struct Literal {
    payload: Payload,
    dims: Vec<i64>,
}

impl Literal {
    /// Rank-1 literal over a host slice.
    pub fn vec1<T: NativeType>(data: &[T]) -> Literal {
        let n = data.len() as i64;
        Literal { payload: T::wrap(data), dims: vec![n] }
    }

    /// Reinterpret the element buffer under new dimensions.
    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        let want: i64 = dims.iter().product();
        let have = match &self.payload {
            Payload::F32(v) => v.len() as i64,
            Payload::I32(v) => v.len() as i64,
        };
        if want != have {
            return Err(Error::Xla(format!("cannot reshape {have} elements to {dims:?}")));
        }
        Ok(Literal { payload: self.payload.clone(), dims: dims.to_vec() })
    }

    /// Copy out as a host vector.
    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        T::unwrap(self)
    }

    /// Decompose a tuple literal — the stub never produces tuples, so this
    /// only exists for type compatibility with the execution path.
    pub fn to_tuple(&self) -> Result<Vec<Literal>> {
        Err(unavailable("decompose tuple literal"))
    }
}

/// Device buffer handed back by an execution.
#[derive(Debug, Clone)]
pub struct PjRtBuffer {
    _priv: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(unavailable("transfer device buffer to host"))
    }
}

/// Parsed (held, in the stub) HLO text module.
#[derive(Debug, Clone)]
pub struct HloModuleProto {
    text: String,
}

impl HloModuleProto {
    /// Read an HLO-text artifact. I/O errors surface as [`Error::Io`];
    /// compilation is where the stub declines.
    pub fn from_text_file(path: &str) -> Result<HloModuleProto> {
        let text = std::fs::read_to_string(path)?;
        Ok(HloModuleProto { text })
    }
}

/// Computation wrapper around a module proto.
#[derive(Debug, Clone)]
pub struct XlaComputation {
    _text_len: usize,
}

impl XlaComputation {
    pub fn from_proto(proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _text_len: proto.text.len() }
    }
}

/// Compiled executable. Never constructed by the stub (compilation always
/// fails), so its methods are unreachable but type-complete.
#[derive(Debug)]
pub struct PjRtLoadedExecutable {
    _priv: (),
}

impl PjRtLoadedExecutable {
    pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(unavailable("execute computation"))
    }
}

/// PJRT client. Construction succeeds (the registry and device-service
/// plumbing must work without artifacts); compilation reports the stub.
#[derive(Debug)]
pub struct PjRtClient {
    _priv: (),
}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Ok(PjRtClient { _priv: () })
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(unavailable("compile HLO module"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip_and_reshape() {
        let lit = Literal::vec1(&[1.0f32, 2.0, 3.0, 4.0]);
        assert_eq!(lit.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
        assert!(lit.to_vec::<i32>().is_err());
        let r = lit.reshape(&[2, 2]).unwrap();
        assert_eq!(r.to_vec::<f32>().unwrap().len(), 4);
        assert!(lit.reshape(&[3]).is_err());
    }

    #[test]
    fn client_constructs_compile_declines() {
        let client = PjRtClient::cpu().unwrap();
        let proto = HloModuleProto { text: "HloModule m".into() };
        let comp = XlaComputation::from_proto(&proto);
        match client.compile(&comp) {
            Err(Error::Xla(msg)) => assert!(msg.contains("stub"), "{msg}"),
            other => panic!("expected stub Xla error, got {other:?}"),
        }
    }

    #[test]
    fn missing_hlo_file_is_io_error() {
        match HloModuleProto::from_text_file("/no/such/file.hlo.txt") {
            Err(Error::Io(_)) => {}
            other => panic!("expected Io error, got {other:?}"),
        }
    }
}
