//! PJRT runtime — loads the HLO-text artifacts produced by
//! `python/compile/aot.py` (`make artifacts`) and executes them on the CPU
//! PJRT client. This is the only place the crate touches XLA; Python is
//! never on the request path.
//!
//! Interchange format is HLO **text**, not serialized `HloModuleProto`:
//! jax ≥ 0.5 emits protos with 64-bit instruction ids that xla_extension
//! 0.5.1 rejects; the text parser reassigns ids (see
//! `/opt/xla-example/README.md`).
//!
//! Because the `xla` crate's client is `Rc`-based (not `Send`), multi-rank
//! execution goes through a dedicated device-service thread
//! ([`DeviceService`]) that serializes submissions like a GPU stream;
//! single-thread callers can use [`Runtime`] directly.

mod artifacts;
mod executable;
mod service;

pub use artifacts::{ArtifactEntry, Artifacts, Manifest, ModelMeta, TensorSpecJson};
pub use executable::{Executable, HostTensor, Runtime, TensorSpec};
pub use service::{DeviceHandle, DeviceService};
