//! Runtime layer: AOT artifact registry, PJRT execution plumbing, and the
//! multi-rank launcher that measures the data plane.
//!
//! * [`Artifacts`] — loads the HLO-text artifacts produced by
//!   `python/compile/aot.py` (`make artifacts`) and persists trained
//!   dispatcher models next to them.
//! * [`Runtime`] / [`DeviceService`] — execute compiled computations. The
//!   `xla` crate's client is `Rc`-based (not `Send`), so multi-rank
//!   execution goes through a dedicated device-service thread that
//!   serializes submissions like a GPU stream. In this offline build the
//!   bindings are the in-tree stub ([`xla_stub`]) — the plumbing is fully
//!   functional and tested, while HLO *compilation* reports a typed error
//!   until the real bindings are linked (one import swap).
//! * [`Launcher`] — spawns rank threads over the in-memory transport and
//!   times every backend across a message-size × rank-count sweep; the
//!   timings feed the adaptive dispatcher's training pipeline. In
//!   persistent mode a [`PersistentWorld`] pins the rank threads for the
//!   whole sweep (lower noise, larger sweeps) and every cell carries
//!   per-op byte counters.
//! * [`run_chaos`] — the `pccl chaos` fault-grid sweep: every fault kind ×
//!   concrete backend must complete correctly or abort within the
//!   detection bound on a recoverable [`PersistentWorld`], plus a
//!   shrink-after-rank-death cell and a lane-worker leak check.
//!
//! Interchange format is HLO **text**, not serialized `HloModuleProto`:
//! jax ≥ 0.5 emits protos with 64-bit instruction ids that xla_extension
//! 0.5.1 rejects; the text parser reassigns ids.

mod artifacts;
mod chaos;
mod executable;
mod launcher;
mod persistent;
mod service;
pub(crate) mod xla_stub;

pub use artifacts::{ArtifactEntry, Artifacts, Manifest, ModelMeta, TensorSpecJson};
pub use chaos::{run_chaos, CellOutcome, ChaosCell, ChaosConfig, ChaosReport, FAULT_KINDS};
pub use executable::{Executable, HostTensor, Runtime, TensorSpec};
pub use launcher::{
    expected_schedule_bytes, flat_ring_expected_bytes, verify_plan_grid, Launcher, LauncherConfig,
    MeasuredCell, MeasuredSweep,
};
pub use persistent::{PersistentWorld, TrialReport};
pub use service::{DeviceHandle, DeviceService};
