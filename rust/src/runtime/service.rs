//! Device-service thread: multi-rank access to the (non-`Send`) PJRT
//! runtime.
//!
//! The `xla` crate's client and executables are `Rc`-based, so they cannot
//! be shared across rank threads. We model the device the way a GPU driver
//! does: a single submission queue processed in order by a dedicated thread
//! that owns the runtime. Rank threads hold a cloneable [`DeviceHandle`]
//! and block on their own response channel — exactly the semantics of a
//! synchronous kernel launch on a shared stream.

use std::sync::mpsc;
use std::thread::JoinHandle;

use crate::error::{Error, Result};

use super::artifacts::Artifacts;
use super::executable::{HostTensor, Runtime};

enum Req {
    Execute {
        name: String,
        inputs: Vec<HostTensor>,
        resp: mpsc::Sender<Result<Vec<HostTensor>>>,
    },
    /// Compile ahead of time so first-step latency is predictable.
    Preload {
        names: Vec<String>,
        resp: mpsc::Sender<Result<()>>,
    },
    Shutdown,
}

/// Cloneable, `Send` handle to the device-service thread.
#[derive(Clone)]
pub struct DeviceHandle {
    tx: mpsc::Sender<Req>,
}

impl DeviceHandle {
    /// Execute computation `name` with `inputs`; blocks until the device
    /// thread finishes this submission.
    pub fn execute(&self, name: &str, inputs: Vec<HostTensor>) -> Result<Vec<HostTensor>> {
        let (rtx, rrx) = mpsc::channel();
        self.tx
            .send(Req::Execute {
                name: name.to_string(),
                inputs,
                resp: rtx,
            })
            .map_err(|_| Error::TransportClosed { rank: usize::MAX })?;
        rrx.recv()
            .map_err(|_| Error::TransportClosed { rank: usize::MAX })?
    }

    /// Convenience for binary f32 kernels (the reduction artifacts):
    /// submits `f(a, b)` where both operands are rank-1 `[n]` f32 tensors
    /// and returns the single f32 output.
    pub fn execute_f32_pair(&self, name: &str, a: &[f32], b: &[f32]) -> Result<Vec<f32>> {
        let n = a.len();
        let inputs = vec![
            HostTensor::f32(a.to_vec(), vec![n]),
            HostTensor::f32(b.to_vec(), vec![n]),
        ];
        let mut out = self.execute(name, inputs)?;
        if out.len() != 1 {
            return Err(Error::Xla(format!(
                "{name}: expected 1 output, got {}",
                out.len()
            )));
        }
        out.remove(0).into_f32()
    }

    /// Compile `names` now (first use otherwise pays JIT-compile latency).
    pub fn preload(&self, names: &[&str]) -> Result<()> {
        let (rtx, rrx) = mpsc::channel();
        self.tx
            .send(Req::Preload {
                names: names.iter().map(|s| s.to_string()).collect(),
                resp: rtx,
            })
            .map_err(|_| Error::TransportClosed { rank: usize::MAX })?;
        rrx.recv()
            .map_err(|_| Error::TransportClosed { rank: usize::MAX })?
    }
}

/// Owns the device thread; dropping shuts it down.
pub struct DeviceService {
    tx: mpsc::Sender<Req>,
    join: Option<JoinHandle<()>>,
}

impl DeviceService {
    /// Spawn the device thread over an artifact directory.
    pub fn spawn(arts: Artifacts) -> Result<Self> {
        let (tx, rx) = mpsc::channel::<Req>();
        // Runtime construction happens *on* the device thread (the client is
        // not Send); construction errors are reported through the first
        // request instead. To fail fast, do a handshake:
        let (ready_tx, ready_rx) = mpsc::channel::<Result<()>>();
        let join = std::thread::Builder::new()
            .name("pccl-device".into())
            .spawn(move || {
                let rt = match Runtime::new(arts) {
                    Ok(rt) => {
                        let _ = ready_tx.send(Ok(()));
                        rt
                    }
                    Err(e) => {
                        let _ = ready_tx.send(Err(e));
                        return;
                    }
                };
                while let Ok(req) = rx.recv() {
                    match req {
                        Req::Execute { name, inputs, resp } => {
                            let out = rt.load(&name).and_then(|exe| exe.run(&inputs));
                            let _ = resp.send(out);
                        }
                        Req::Preload { names, resp } => {
                            let mut out = Ok(());
                            for n in &names {
                                if let Err(e) = rt.load(n) {
                                    out = Err(e);
                                    break;
                                }
                            }
                            let _ = resp.send(out);
                        }
                        Req::Shutdown => break,
                    }
                }
            })?;
        ready_rx
            .recv()
            .map_err(|_| Error::Xla("device thread died during startup".into()))??;
        Ok(Self {
            tx,
            join: Some(join),
        })
    }

    /// Get a cloneable handle for rank threads.
    pub fn handle(&self) -> DeviceHandle {
        DeviceHandle {
            tx: self.tx.clone(),
        }
    }
}

impl Drop for DeviceService {
    fn drop(&mut self) {
        let _ = self.tx.send(Req::Shutdown);
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}
