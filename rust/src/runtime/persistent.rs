//! Persistent-world execution: one [`TransportHub`] plus pinned rank
//! threads serving a work queue of trials.
//!
//! The measured sweep's original mode spawns (and tears down) a fresh
//! world per trial, which dominates small-message timings with thread
//! spawn/join noise. A [`PersistentWorld`] amortizes world setup across
//! the whole sweep: each rank thread owns its [`Communicator`] for the
//! world's lifetime, pops trial closures off its queue, and reports a
//! [`TrialReport`] (wall seconds + byte counters) back to the driver. The
//! byte counters come from the endpoint's [`crate::comm::Traffic`] deltas,
//! so every trial records exactly what the schedule moved — the
//! schedule-equivalence guard in `pccl smoke` compares them against the
//! fresh-world path.

use std::sync::mpsc::{self, Receiver, Sender};
use std::thread::JoinHandle;
use std::time::Duration;

use crate::comm::{AbortToken, Communicator, TransportHub, DEFAULT_RECV_TIMEOUT};
use crate::error::{Error, Result};
use crate::reduction::Elem;
use crate::topology::Topology;

/// What one rank reports for one trial.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct TrialReport {
    /// Wall seconds of the timed section (per collective op if the trial
    /// divides by its inner iteration count).
    pub secs: f64,
    /// Messages this rank sent inside the timed section.
    pub sent_msgs: u64,
    /// Bytes this rank sent inside the timed section.
    pub sent_bytes: u64,
    /// Received bytes that were delivered by *copying* into this rank's
    /// posted or COW-resolved storage inside the timed section (the
    /// [`crate::comm::Traffic::copied_bytes`] delta). Zero on the whole
    /// reduce path — `pccl smoke` fails the run otherwise.
    pub copied_bytes: u64,
    /// Bytes this rank sent on each transport lane inside the timed
    /// section (`[lane 0, lane 1, ...]`; empty when the trial did not
    /// sample per-lane counters). The cross-lane schedule-equivalence
    /// guard sums these and checks them against the single-lane run.
    pub moved_bytes_per_lane: Vec<u64>,
    /// Order-independent checksum of the trial's result elements (sum of
    /// the output converted to f64). Identical schedules must produce
    /// identical checksums regardless of lane count.
    pub checksum: f64,
    /// Op-level spans recorded by this rank when the trial ran with the
    /// tracer installed (see [`crate::trace`]). Empty for timed trials —
    /// the launcher only traces a dedicated extra trial, never the
    /// measured section.
    pub trace: Vec<crate::trace::OpSpan>,
}

type Job<T> = Box<dyn FnOnce(&mut Communicator<T>) -> Result<TrialReport> + Send>;

/// A long-lived world: pinned rank threads over one shared transport,
/// each serving trial closures from its own queue.
///
/// Every rank is armed with one shared [`AbortToken`], so a trial in
/// which any rank fails aborts *collectively*: the failing rank's engine
/// broadcasts poison and every peer returns
/// [`Error::CollectiveAborted`] within the detection window. Such a trial
/// is **recoverable** — the world clears the token, runs an epoch-resync
/// job on every rank (draining stale traffic and retagging the wire, see
/// [`Communicator::bump_epoch`]), and stays usable for further trials.
/// Only a failure outside the abort protocol (a rank panic, a
/// non-collective error, a failed resync) poisons the world: the ranks'
/// states are no longer known to be aligned, so further trials would
/// exchange garbage — subsequent [`PersistentWorld::run_trial`] calls
/// return an error instead.
pub struct PersistentWorld<T: Elem> {
    topo: Topology,
    lanes: usize,
    job_txs: Vec<Sender<Job<T>>>,
    done_rx: Receiver<(usize, Result<TrialReport>)>,
    handles: Vec<JoinHandle<()>>,
    abort: AbortToken,
    trial_deadline: Duration,
    poisoned: bool,
}

impl<T: Elem> PersistentWorld<T> {
    /// Stand up the transport and pin one worker thread per rank.
    pub fn new(topo: Topology) -> Result<Self> {
        Self::new_with_lanes(topo, 1)
    }

    /// Stand up a multi-lane transport (one stripe queue + lane worker per
    /// extra lane, see [`TransportHub::new_with_lanes`]) and pin one rank
    /// thread per rank. `lanes == 1` is byte-for-byte [`PersistentWorld::new`].
    /// Fails with the OS error if a rank thread cannot be spawned.
    pub fn new_with_lanes(topo: Topology, lanes: usize) -> Result<Self> {
        let size = topo.world_size();
        let (_hub, eps) = TransportHub::<T>::new_with_lanes(size, lanes.max(1));
        let (done_tx, done_rx) = mpsc::channel();
        let abort = AbortToken::new();
        let mut job_txs = Vec::with_capacity(size);
        let mut handles = Vec::with_capacity(size);
        for ep in eps {
            let rank = ep.rank();
            let (jtx, jrx) = mpsc::channel::<Job<T>>();
            let done = done_tx.clone();
            let tok = abort.clone();
            let handle = std::thread::Builder::new()
                .name(format!("pccl-world-{rank}"))
                .spawn(move || {
                    let mut comm = match Communicator::new(ep, topo) {
                        Ok(c) => c,
                        Err(e) => {
                            let _ = done.send((rank, Err(e)));
                            return;
                        }
                    };
                    comm.arm_abort(tok);
                    while let Ok(job) = jrx.recv() {
                        let out = job(&mut comm);
                        if done.send((rank, out)).is_err() {
                            return;
                        }
                    }
                })
                .map_err(Error::from)?;
            job_txs.push(jtx);
            handles.push(handle);
        }
        Ok(Self {
            topo,
            lanes: lanes.max(1),
            job_txs,
            done_rx,
            handles,
            abort,
            trial_deadline: DEFAULT_RECV_TIMEOUT + Duration::from_secs(30),
            poisoned: false,
        })
    }

    pub fn topology(&self) -> Topology {
        self.topo
    }

    /// Transport lanes each pinned rank's endpoint carries.
    pub fn lanes(&self) -> usize {
        self.lanes
    }

    pub fn size(&self) -> usize {
        self.topo.world_size()
    }

    /// Whether a failed trial has invalidated this world.
    pub fn is_poisoned(&self) -> bool {
        self.poisoned
    }

    /// The world's shared abort token (tripped while a collective abort is
    /// in flight; cleared again by the post-abort recovery).
    pub fn abort_token(&self) -> &AbortToken {
        &self.abort
    }

    /// How long the driver waits for each rank's trial report before
    /// declaring a rank dead (unrecoverable). The default leaves room for
    /// every straggler to hit its own receive timeout and report; chaos
    /// tests shorten it together with the ranks' receive timeouts.
    pub fn set_trial_deadline(&mut self, deadline: Duration) {
        self.trial_deadline = deadline;
    }

    /// Run one SPMD trial on every pinned rank thread; returns per-rank
    /// reports in rank order. The first rank error wins (the others
    /// surface as timeouts/closed-transport and are discarded).
    ///
    /// If every failing rank failed with [`Error::CollectiveAborted`]
    /// (the abort protocol worked), the world recovers: the abort token
    /// clears and every rank runs an epoch resync, so the *next*
    /// `run_trial` proceeds on a clean epoch. Any other failure — or a
    /// rank that never reports within the trial deadline — poisons the
    /// world permanently.
    pub fn run_trial<F>(&mut self, f: F) -> Result<Vec<TrialReport>>
    where
        F: Fn(&mut Communicator<T>) -> Result<TrialReport> + Send + Sync + Clone + 'static,
    {
        if self.poisoned {
            return Err(Error::Dispatch(
                "persistent world poisoned by an earlier failed trial".into(),
            ));
        }
        for (rank, tx) in self.job_txs.iter().enumerate() {
            let g = f.clone();
            tx.send(Box::new(move |c: &mut Communicator<T>| g(c)))
                .map_err(|_| Error::TransportClosed { rank })?;
        }
        let p = self.size();
        let mut out = vec![TrialReport::default(); p];
        let mut first_err: Option<Error> = None;
        let mut all_aborts = true;
        for _ in 0..p {
            match self.done_rx.recv_timeout(self.trial_deadline) {
                Ok((rank, Ok(report))) => out[rank] = report,
                Ok((_, Err(e))) => {
                    all_aborts &= matches!(e, Error::CollectiveAborted { .. });
                    if first_err.is_none() {
                        first_err = Some(e);
                    }
                }
                Err(_) => {
                    // A rank died without reporting (panic) — unrecoverable.
                    self.poisoned = true;
                    return Err(Error::RecvTimeout {
                        src: 0,
                        tag: 0,
                        ms: self.trial_deadline.as_millis() as u64,
                    });
                }
            }
        }
        match first_err {
            None => Ok(out),
            Some(e) => {
                if all_aborts {
                    // The abort protocol held: every failure was the typed
                    // collective abort, so rank states are known-aligned
                    // (all idle, op streams cut at the same collective).
                    // Resync and stay usable.
                    self.resync()?;
                } else {
                    self.poisoned = true;
                }
                Err(e)
            }
        }
    }

    /// Post-abort recovery: clear the tripped token, then have every rank
    /// enter the next epoch (drain queues, retag, reset op sequences).
    /// Failure here is unrecoverable and poisons the world.
    fn resync(&mut self) -> Result<()> {
        self.abort.clear();
        let mut dead_queue = None;
        for (rank, tx) in self.job_txs.iter().enumerate() {
            let job: Job<T> = Box::new(|c: &mut Communicator<T>| {
                c.bump_epoch()?;
                Ok(TrialReport::default())
            });
            if tx.send(job).is_err() {
                dead_queue = Some(rank);
                break;
            }
        }
        if let Some(rank) = dead_queue {
            self.poisoned = true;
            return Err(Error::TransportClosed { rank });
        }
        for _ in 0..self.size() {
            match self.done_rx.recv_timeout(self.trial_deadline) {
                Ok((_, Ok(_))) => {}
                Ok((_, Err(e))) => {
                    self.poisoned = true;
                    return Err(e);
                }
                Err(_) => {
                    self.poisoned = true;
                    return Err(Error::RecvTimeout {
                        src: 0,
                        tag: 0,
                        ms: self.trial_deadline.as_millis() as u64,
                    });
                }
            }
        }
        Ok(())
    }
}

impl<T: Elem> Drop for PersistentWorld<T> {
    fn drop(&mut self) {
        // Closing the job queues ends each worker's loop.
        self.job_txs.clear();
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::Comm;

    #[test]
    fn trials_reuse_the_same_world() {
        let mut world = PersistentWorld::<f32>::new(Topology::flat(4)).unwrap();
        for round in 0..3u32 {
            let reports = world
                .run_trial(move |c| {
                    c.begin_op();
                    let p = c.size();
                    let r = c.rank();
                    let before = c.traffic();
                    use crate::comm::Chunk;
                    c.send_slice((r + 1) % p, 0, Chunk::from_vec(vec![round as f32; 2]))?;
                    let got = c.recv_chunk((r + p - 1) % p, 0)?;
                    if got.as_slice() != [round as f32; 2] {
                        return Err(Error::Dispatch(format!("bad payload {got:?}")));
                    }
                    let after = c.traffic();
                    Ok(TrialReport {
                        secs: 0.0,
                        sent_msgs: after.sent_msgs - before.sent_msgs,
                        sent_bytes: after.sent_bytes - before.sent_bytes,
                        copied_bytes: after.copied_bytes - before.copied_bytes,
                        ..Default::default()
                    })
                })
                .unwrap();
            assert_eq!(reports.len(), 4);
            assert!(reports
                .iter()
                .all(|t| t.sent_msgs == 1 && t.sent_bytes == 8 && t.copied_bytes == 0));
        }
    }

    #[test]
    fn lane_world_pins_ranks_on_a_striped_transport() {
        let mut world = PersistentWorld::<f32>::new_with_lanes(Topology::flat(3), 2).unwrap();
        let reports = world
            .run_trial(|c| {
                if c.lanes() != 2 {
                    return Err(Error::Dispatch(format!("expected 2 lanes, got {}", c.lanes())));
                }
                Ok(TrialReport::default())
            })
            .unwrap();
        assert_eq!(reports.len(), 3);
    }

    #[test]
    fn aborted_trial_recovers_and_next_trial_is_correct() {
        use crate::comm::Chunk;
        let mut world = PersistentWorld::<f32>::new(Topology::flat(3)).unwrap();
        // Trial 1: every rank fails with the typed collective abort (as the
        // engine's conversion produces) — the world must resync, not poison.
        let err = world
            .run_trial(|c| {
                c.broadcast_abort("injected");
                Err(Error::CollectiveAborted {
                    origin_rank: c.rank(),
                    op_seq: c.current_op_seq(),
                    cause: "injected".into(),
                })
            })
            .unwrap_err();
        assert!(matches!(err, Error::CollectiveAborted { .. }));
        assert!(!world.is_poisoned(), "typed aborts are recoverable");
        assert!(!world.abort_token().is_tripped(), "recovery clears the token");
        // Trial 2 runs a correct collective on the resynced epoch.
        let reports = world
            .run_trial(|c| {
                c.begin_op();
                let (p, r) = (c.size(), c.rank());
                c.send_slice((r + 1) % p, 0, Chunk::from_vec(vec![r as f32]))?;
                let got = c.recv_chunk((r + p - 1) % p, 0)?;
                Ok(TrialReport { checksum: f64::from(got[0]), ..Default::default() })
            })
            .unwrap();
        let sum: f64 = reports.iter().map(|t| t.checksum).sum();
        assert_eq!(sum, 3.0); // 0 + 1 + 2
    }

    #[test]
    fn rank_panic_poisons_within_the_trial_deadline() {
        // A rank that dies without reporting (panic) is unrecoverable; the
        // driver must notice within the configured deadline, not hang.
        let mut world = PersistentWorld::<f32>::new(Topology::flat(2)).unwrap();
        world.set_trial_deadline(Duration::from_millis(300));
        let t = std::time::Instant::now();
        let err = world
            .run_trial(|c| {
                if c.rank() == 0 {
                    panic!("simulated rank crash");
                }
                Ok(TrialReport::default())
            })
            .unwrap_err();
        assert!(matches!(err, Error::RecvTimeout { .. }));
        assert!(t.elapsed() < Duration::from_secs(10));
        assert!(world.is_poisoned());
        assert!(world.run_trial(|_| Ok(TrialReport::default())).is_err());
    }

    #[test]
    fn failed_trial_poisons_the_world() {
        let mut world = PersistentWorld::<f32>::new(Topology::flat(2)).unwrap();
        let err = world
            .run_trial(|c| {
                if c.rank() == 0 {
                    Err(Error::Dispatch("boom".into()))
                } else {
                    Ok(TrialReport::default())
                }
            })
            .unwrap_err();
        assert!(err.to_string().contains("boom"));
        assert!(world.is_poisoned());
        assert!(world.run_trial(|_| Ok(TrialReport::default())).is_err());
    }
}
