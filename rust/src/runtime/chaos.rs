//! `pccl chaos` — deterministic fault-grid sweep over the plan-IR backends.
//!
//! For every fault kind × concrete backend the harness runs one collective
//! on a [`PersistentWorld`] with a seeded [`FaultPlan`] armed, and demands
//! one of exactly two clean endings:
//!
//! * **completed** — the collective finished and its result checksum
//!   matches a faultless reference run of the same cell (survivable
//!   faults: a bounded delay, a duplicated message, a stalled-but-alive
//!   lane worker), or
//! * **aborted** — every failing rank returned the typed
//!   [`Error::CollectiveAborted`] within the configured detection bound
//!   (wall-clock asserted, far below the 60 s default receive timeout),
//!   the world resynchronized onto a fresh epoch, and the *next* trial on
//!   the same world reproduced the reference checksum.
//!
//! Anything else — a hang past the bound, a silently wrong checksum, an
//! untyped error, a poisoned world, a leaked lane-worker thread — marks
//! the cell `FAILED` and fails the whole run. A separate cell exercises
//! rank-failure recovery by *shrinking*: a world loses a rank, the
//! survivors detect it by timeout, broadcast the abort, and rebuild a
//! smaller communicator (see [`crate::comm::Communicator::shrink`]) that
//! completes a correct collective.
//!
//! Every cell's fault plan is serialized into the JSON report, so a chaos
//! failure can be replayed exactly with [`FaultPlan::from_value`].

use std::sync::{Arc, Barrier};
use std::time::{Duration, Instant};

use crate::backends::{Backend, CollKind, CollectiveOptions};
use crate::comm::{Chunk, Comm, CommWorld, Communicator, FaultAction, FaultPlan, FaultSpec};
use crate::error::{Error, Result};
use crate::topology::Topology;
use crate::util::json::Value;

use super::launcher::run_collective;
use super::persistent::{PersistentWorld, TrialReport};

/// The fault taxonomy the grid sweeps, one cell per kind per backend.
pub const FAULT_KINDS: [&str; 6] =
    ["drop", "delay", "duplicate", "corrupt", "kill_rank", "stall_worker"];

/// Grid shape and failure-detection budget for one chaos run.
#[derive(Debug, Clone)]
pub struct ChaosConfig {
    /// World size (≥ 3 so the shrink cell keeps a non-trivial survivor
    /// ring).
    pub ranks: usize,
    /// Transport lanes per rank pair (≥ 2 so the stall-worker cells have a
    /// worker lane to stall).
    pub lanes: usize,
    /// Elements per rank input — large enough that the striped PCCL paths
    /// keep multiple stripes (see [`crate::backends::MIN_STRIPE_ELEMS`]).
    pub elems: usize,
    /// Per-rank receive timeout: the detection latency for faults nobody
    /// survives to announce (a killed rank), and the clock every abort
    /// cell races against.
    pub recv_timeout: Duration,
    /// Hard wall-clock bound on a faulted trial: complete or abort within
    /// this window or the cell is `FAILED`. Must sit far below the 60 s
    /// default receive timeout to prove the abort protocol, not the
    /// timeout, bounded the trial.
    pub detect_bound: Duration,
    /// Backends to sweep (the concrete set by default).
    pub backends: Vec<Backend>,
    /// Check `/proc/self/status` for leaked threads after teardown. Keep
    /// off inside `cargo test` — concurrent tests spawn threads of their
    /// own and would flake the count.
    pub thread_check: bool,
}

impl Default for ChaosConfig {
    fn default() -> Self {
        Self {
            ranks: 4,
            lanes: 2,
            elems: 16 * 1024,
            recv_timeout: Duration::from_millis(250),
            detect_bound: Duration::from_secs(10),
            backends: Backend::CONCRETE.to_vec(),
            thread_check: true,
        }
    }
}

/// How one fault cell ended.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CellOutcome {
    /// Finished with the reference checksum despite the fault.
    Completed,
    /// Every failing rank returned [`Error::CollectiveAborted`] within the
    /// detection bound and the world recovered.
    Aborted,
    /// Hang, silent corruption, untyped error, or failed recovery.
    Failed,
}

impl CellOutcome {
    pub fn label(&self) -> &'static str {
        match self {
            CellOutcome::Completed => "completed",
            CellOutcome::Aborted => "aborted",
            CellOutcome::Failed => "FAILED",
        }
    }
}

/// One (fault, backend, collective) grid cell's verdict.
#[derive(Debug, Clone)]
pub struct ChaosCell {
    pub fault: &'static str,
    pub backend: Backend,
    pub kind: CollKind,
    /// What the fault taxonomy says must happen (survivable faults must
    /// complete; fatal ones must abort). A mismatch is a `FAILED` cell
    /// even when the ending was individually clean.
    pub expected: CellOutcome,
    pub outcome: CellOutcome,
    /// Wall seconds of the faulted trial — the measured detection window
    /// for aborted cells.
    pub detect_s: f64,
    pub detail: String,
    /// The exact armed plan, serialized into the report for replay.
    pub plan: FaultPlan,
}

impl ChaosCell {
    pub fn passed(&self) -> bool {
        self.outcome != CellOutcome::Failed
    }
}

/// The full chaos run: grid cells, the shrink cell, and the leak check.
#[derive(Debug, Clone)]
pub struct ChaosReport {
    pub cells: Vec<ChaosCell>,
    pub shrink_passed: bool,
    pub shrink_wall_s: f64,
    pub shrink_detail: String,
    /// `(before, after)` OS thread counts when the leak check ran.
    pub threads: Option<(usize, usize)>,
    pub passed: bool,
}

impl ChaosReport {
    /// The `BENCH_chaos.json` document: per-cell outcome plus the replay
    /// plan, the shrink verdict, and the thread-leak numbers.
    pub fn to_value(&self, cfg: &ChaosConfig) -> Value {
        let cells = self
            .cells
            .iter()
            .map(|c| {
                Value::obj(vec![
                    ("fault", Value::Str(c.fault.to_string())),
                    ("backend", Value::Str(c.backend.label().to_string())),
                    ("collective", Value::Str(c.kind.label().to_string())),
                    ("expected", Value::Str(c.expected.label().to_string())),
                    ("outcome", Value::Str(c.outcome.label().to_string())),
                    ("detect_s", Value::Num(c.detect_s)),
                    ("detail", Value::Str(c.detail.clone())),
                    ("plan", c.plan.to_value()),
                ])
            })
            .collect();
        let threads = match self.threads {
            None => Value::Null,
            Some((before, after)) => Value::obj(vec![
                ("before", Value::Num(before as f64)),
                ("after", Value::Num(after as f64)),
                ("leaked", Value::Num(after.saturating_sub(before) as f64)),
            ]),
        };
        Value::obj(vec![
            ("schema", Value::Num(1.0)),
            ("suite", Value::Str("pccl-chaos".to_string())),
            ("ranks", Value::Num(cfg.ranks as f64)),
            ("lanes", Value::Num(cfg.lanes as f64)),
            ("elems", Value::Num(cfg.elems as f64)),
            ("recv_timeout_ms", Value::Num(cfg.recv_timeout.as_millis() as f64)),
            ("detect_bound_ms", Value::Num(cfg.detect_bound.as_millis() as f64)),
            ("cells", Value::Arr(cells)),
            (
                "shrink",
                Value::obj(vec![
                    ("passed", Value::Bool(self.shrink_passed)),
                    ("wall_s", Value::Num(self.shrink_wall_s)),
                    ("detail", Value::Str(self.shrink_detail.clone())),
                ]),
            ),
            ("threads", threads),
            ("passed", Value::Bool(self.passed)),
        ])
    }

    /// Error out with every failed cell named, for CI logs.
    pub fn ensure_passed(&self) -> Result<()> {
        if self.passed {
            return Ok(());
        }
        let mut failed: Vec<String> = self
            .cells
            .iter()
            .filter(|c| !c.passed())
            .map(|c| {
                format!("{}/{}/{}: {}", c.fault, c.backend.label(), c.kind.label(), c.detail)
            })
            .collect();
        if !self.shrink_passed {
            failed.push(format!("shrink: {}", self.shrink_detail));
        }
        if let Some((before, after)) = self.threads {
            if after > before {
                failed.push(format!("thread leak: {before} threads before, {after} after"));
            }
        }
        Err(Error::Dispatch(format!("chaos run failed: {}", failed.join("; "))))
    }
}

/// What the taxonomy demands of each fault kind: faults the transport can
/// ride out must complete correctly; fatal ones must take the typed abort
/// path. A "fatal" fault that completes means the injection never fired —
/// harness rot — so the expectation is enforced both ways.
fn expected_outcome(fault: &str) -> CellOutcome {
    match fault {
        "delay" | "duplicate" | "stall_worker" => CellOutcome::Completed,
        _ => CellOutcome::Aborted,
    }
}

/// The armed plan for one cell: rank 0 is always the faulty party, with
/// one spec per peer so the injection fires on the first matching traffic
/// regardless of which neighbor the backend's schedule touches first.
/// Send-side faults sit on lane 0 (every schedule's stripe 0); the
/// stall sits on worker lane 1 of rank 0's receive side. Delays and
/// stalls stay well under the receive timeout so those cells complete.
fn plan_for(fault: &str, ranks: usize) -> FaultPlan {
    let survivable_ms = 25;
    let spec = |peer: usize, lane: usize, action: FaultAction| FaultSpec {
        rank: 0,
        peer,
        lane,
        op_seq: 0,
        action,
    };
    let faults = (1..ranks)
        .map(|peer| match fault {
            "drop" => spec(peer, 0, FaultAction::Drop),
            "delay" => spec(peer, 0, FaultAction::Delay { ms: survivable_ms }),
            "duplicate" => spec(peer, 0, FaultAction::Duplicate),
            "corrupt" => spec(peer, 0, FaultAction::Corrupt),
            "kill_rank" => spec(peer, 0, FaultAction::KillRank),
            "stall_worker" => spec(peer, 1, FaultAction::StallWorker { ms: survivable_ms }),
            other => unreachable!("unknown fault kind {other:?}"),
        })
        .collect();
    FaultPlan::new(faults)
}

/// One collective trial: every rank runs `kind` on `backend` and reports
/// the result checksum. With `faults`, the plan is armed for exactly this
/// trial (the engine's abort conversion handles whatever it breaks) and
/// disarmed on the way out — an aborted trial's resync clears it too.
fn collective_trial(
    kind: CollKind,
    backend: Backend,
    elems: usize,
    lanes: usize,
    faults: Option<FaultPlan>,
    recv_timeout: Duration,
) -> impl Fn(&mut Communicator<f32>) -> Result<TrialReport> + Send + Sync + Clone + 'static {
    move |c: &mut Communicator<f32>| {
        c.set_timeout(recv_timeout);
        if let Some(plan) = &faults {
            c.arm_faults(plan.clone());
        }
        let opts = CollectiveOptions::<f32>::default().backend(backend).lanes(lanes);
        let input = Chunk::from_vec(vec![c.rank() as f32; elems]);
        let res = run_collective(kind, lanes, c, &input, &opts);
        c.clear_faults();
        Ok(TrialReport { checksum: res?, ..Default::default() })
    }
}

/// World-total checksum: per-rank checksums summed, so all three
/// collective kinds reduce to one reference scalar per cell.
fn total_checksum(reports: &[TrialReport]) -> f64 {
    reports.iter().map(|t| t.checksum).sum()
}

fn failed_cell(
    fault: &'static str,
    backend: Backend,
    kind: CollKind,
    plan: FaultPlan,
    detect_s: f64,
    detail: String,
) -> ChaosCell {
    ChaosCell {
        fault,
        backend,
        kind,
        expected: expected_outcome(fault),
        outcome: CellOutcome::Failed,
        detect_s,
        detail,
        plan,
    }
}

/// Run one grid cell: faultless reference → faulted trial → post-recovery
/// correctness check → epoch reset (drains any surviving duplicates so
/// cells stay isolated).
fn run_cell(
    world: &mut PersistentWorld<f32>,
    cfg: &ChaosConfig,
    fault: &'static str,
    backend: Backend,
    kind: CollKind,
) -> ChaosCell {
    let plan = plan_for(fault, cfg.ranks);
    let expected = expected_outcome(fault);

    let reference = match world.run_trial(collective_trial(
        kind,
        backend,
        cfg.elems,
        cfg.lanes,
        None,
        cfg.recv_timeout,
    )) {
        Ok(reports) => total_checksum(&reports),
        Err(e) => {
            return failed_cell(fault, backend, kind, plan, 0.0, format!("reference trial: {e}"))
        }
    };

    let t0 = Instant::now();
    let res = world.run_trial(collective_trial(
        kind,
        backend,
        cfg.elems,
        cfg.lanes,
        Some(plan.clone()),
        cfg.recv_timeout,
    ));
    let detect_s = t0.elapsed().as_secs_f64();
    let (mut outcome, mut detail) = match res {
        Ok(reports) => {
            let sum = total_checksum(&reports);
            if (sum - reference).abs() > 1e-9 {
                (
                    CellOutcome::Failed,
                    format!("silent corruption: checksum {sum} vs reference {reference}"),
                )
            } else {
                (CellOutcome::Completed, String::new())
            }
        }
        Err(e @ Error::CollectiveAborted { .. }) => {
            if world.is_poisoned() {
                (CellOutcome::Failed, format!("world poisoned by abort: {e}"))
            } else if detect_s > cfg.detect_bound.as_secs_f64() {
                (
                    CellOutcome::Failed,
                    format!("abort took {detect_s:.3}s, over the detection bound: {e}"),
                )
            } else {
                (CellOutcome::Aborted, e.to_string())
            }
        }
        Err(e) => (CellOutcome::Failed, format!("untyped failure: {e}")),
    };
    if outcome != CellOutcome::Failed && outcome != expected {
        detail = format!(
            "expected {} but the cell {} ({})",
            expected.label(),
            outcome.label(),
            if detail.is_empty() { "fault likely never fired" } else { detail.as_str() }
        );
        outcome = CellOutcome::Failed;
    }

    // A clean ending must also leave the world correct: the same cell,
    // faultless, on the (possibly resynced) world must reproduce the
    // reference checksum.
    if outcome != CellOutcome::Failed {
        match world.run_trial(collective_trial(
            kind,
            backend,
            cfg.elems,
            cfg.lanes,
            None,
            cfg.recv_timeout,
        )) {
            Ok(reports) => {
                let sum = total_checksum(&reports);
                if (sum - reference).abs() > 1e-9 {
                    outcome = CellOutcome::Failed;
                    detail =
                        format!("post-recovery checksum {sum} vs reference {reference}");
                }
            }
            Err(e) => {
                outcome = CellOutcome::Failed;
                detail = format!("post-recovery trial: {e}");
            }
        }
    }

    // Enter a fresh epoch between cells: drains anything a fault left in
    // the queues (e.g. the duplicate's second copy) so no cell inherits
    // its predecessor's wreckage.
    if !world.is_poisoned() {
        let reset = world.run_trial(|c: &mut Communicator<f32>| {
            c.bump_epoch()?;
            Ok(TrialReport::default())
        });
        if let Err(e) = reset {
            outcome = CellOutcome::Failed;
            detail = format!("epoch reset between cells: {e}");
        }
    }

    ChaosCell { fault, backend, kind, expected, outcome, detect_s, detail, plan }
}

/// The rank-failure recovery cell: rank 1 of a fresh abort-armed world
/// goes silent mid-ring; a survivor detects it by receive timeout and
/// broadcasts the abort (as the engine would); the survivors then clear
/// the token, shrink around the dead rank, and complete a correct ring
/// pass on the rebuilt communicator. Returns `(passed, wall_s, detail)`.
fn run_shrink_cell(cfg: &ChaosConfig) -> (bool, f64, String) {
    let p = cfg.ranks;
    let dead = 1usize;
    let b_all = Arc::new(Barrier::new(p));
    let b_live = Arc::new(Barrier::new(p - 1));
    let world = CommWorld::<f32>::new(p).with_abort().with_recv_timeout(cfg.recv_timeout);
    let t0 = Instant::now();
    let outs = world.run(move |c: &mut Communicator<f32>| -> Result<f64> {
        let r = c.rank();
        let p = c.size();
        if r == dead {
            // The failed host: never sends, but keeps its endpoint alive
            // until the survivors have finished detecting, so their
            // phase-1 sends don't race its teardown.
            b_all.wait();
            return Ok(0.0);
        }
        c.begin_op();
        c.send_slice((r + 1) % p, 0, Chunk::from_vec(vec![r as f32]))?;
        match c.recv_chunk((r + p - 1) % p, 0) {
            // The dead rank's right neighbor times out and broadcasts the
            // abort exactly as the engine's conversion would; ranks parked
            // behind it observe the poison as the typed abort instead.
            Ok(_) | Err(Error::CollectiveAborted { .. }) => {}
            Err(e) => c.broadcast_abort(&e.to_string()),
        }
        b_all.wait();
        if r == 0 {
            if let Some(tok) = c.abort_token() {
                tok.clear();
            }
        }
        b_live.wait();
        let mut sub = c.shrink(&[dead])?;
        sub.begin_op();
        let (sp, sr) = (sub.size(), sub.rank());
        sub.send_slice((sr + 1) % sp, 0, Chunk::from_vec(vec![r as f32]))?;
        let got = sub.recv_chunk((sr + sp - 1) % sp, 0)?;
        Ok(f64::from(got[0]))
    });
    let wall = t0.elapsed().as_secs_f64();
    if wall > cfg.detect_bound.as_secs_f64() {
        return (false, wall, format!("shrink cell took {wall:.3}s, over the detection bound"));
    }
    let mut sum = 0.0;
    for (r, out) in outs.iter().enumerate() {
        if r == dead {
            continue;
        }
        match out {
            Ok(v) => sum += v,
            Err(e) => return (false, wall, format!("survivor rank {r} failed: {e}")),
        }
    }
    // Each survivor received its left survivor's *original* rank id, so
    // the ring total is the survivor rank sum.
    let expect: f64 = (0..p).filter(|&r| r != dead).map(|r| r as f64).sum();
    if (sum - expect).abs() > 1e-9 {
        return (false, wall, format!("survivor ring moved {sum}, expected {expect}"));
    }
    (true, wall, String::new())
}

/// OS threads of this process, from `/proc/self/status` (Linux only).
fn thread_count() -> Option<usize> {
    let status = std::fs::read_to_string("/proc/self/status").ok()?;
    status
        .lines()
        .find(|l| l.starts_with("Threads:"))?
        .split_whitespace()
        .nth(1)?
        .parse()
        .ok()
}

/// Sweep the full fault grid and the shrink cell. `Err` only on setup
/// failures — per-cell verdicts land in the report; gate CI on
/// [`ChaosReport::ensure_passed`] after writing it out.
pub fn run_chaos(cfg: &ChaosConfig) -> Result<ChaosReport> {
    assert!(cfg.ranks >= 3, "chaos needs >= 3 ranks for a survivor ring");
    assert!(cfg.lanes >= 2, "chaos needs a worker lane to stall");
    let threads_before = if cfg.thread_check { thread_count() } else { None };

    let topo = Topology::flat(cfg.ranks);
    let mut world = PersistentWorld::<f32>::new_with_lanes(topo, cfg.lanes)?;
    world.set_trial_deadline(cfg.detect_bound);
    let mut cells = Vec::with_capacity(FAULT_KINDS.len() * cfg.backends.len());
    let mut kind_i = 0usize;
    for fault in FAULT_KINDS {
        for &backend in &cfg.backends {
            // Rotate the collective kind so the grid covers all three
            // without tripling its size.
            let kind = CollKind::ALL[kind_i % CollKind::ALL.len()];
            kind_i += 1;
            cells.push(run_cell(&mut world, cfg, fault, backend, kind));
            if world.is_poisoned() {
                // A failed cell may strand the world — rebuild so the
                // remaining grid still gets measured.
                world = PersistentWorld::new_with_lanes(topo, cfg.lanes)?;
                world.set_trial_deadline(cfg.detect_bound);
            }
        }
    }
    drop(world);

    let (shrink_passed, shrink_wall_s, shrink_detail) = run_shrink_cell(cfg);

    // Every world above is torn down; any thread still alive is a leaked
    // lane worker. Give detached teardown a moment to settle.
    let threads = match threads_before {
        None => None,
        Some(before) => {
            let deadline = Instant::now() + Duration::from_secs(2);
            let mut after = thread_count().unwrap_or(before);
            while after > before && Instant::now() < deadline {
                std::thread::sleep(Duration::from_millis(20));
                after = thread_count().unwrap_or(before);
            }
            Some((before, after))
        }
    };

    let passed = cells.iter().all(ChaosCell::passed)
        && shrink_passed
        && !threads.is_some_and(|(before, after)| after > before);
    Ok(ChaosReport { cells, shrink_passed, shrink_wall_s, shrink_detail, threads, passed })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quick_chaos_grid_is_clean_and_serializes() {
        // One backend keeps the in-test grid small; the full concrete set
        // runs under `pccl chaos` in CI. Thread counting stays off — other
        // tests' worlds run concurrently with this one.
        let cfg = ChaosConfig {
            backends: vec![Backend::PcclRing],
            recv_timeout: Duration::from_millis(150),
            thread_check: false,
            ..ChaosConfig::default()
        };
        let report = run_chaos(&cfg).unwrap();
        assert_eq!(report.cells.len(), FAULT_KINDS.len());
        for cell in &report.cells {
            assert_eq!(
                cell.outcome, cell.expected,
                "{}/{}: {}",
                cell.fault,
                cell.kind.label(),
                cell.detail
            );
        }
        assert!(report.shrink_passed, "{}", report.shrink_detail);
        assert!(report.passed);
        report.ensure_passed().unwrap();

        let doc = report.to_value(&cfg);
        assert!(doc.get("passed").unwrap().as_bool().unwrap());
        let cells = doc.get("cells").unwrap().as_arr().unwrap();
        assert_eq!(cells.len(), FAULT_KINDS.len());
        // Each cell's armed plan round-trips for replay.
        let plan = FaultPlan::from_value(cells[0].get("plan").unwrap()).unwrap();
        assert_eq!(plan, plan_for(FAULT_KINDS[0], cfg.ranks));
    }

    #[test]
    fn taxonomy_expectations_are_fixed() {
        assert_eq!(expected_outcome("drop"), CellOutcome::Aborted);
        assert_eq!(expected_outcome("corrupt"), CellOutcome::Aborted);
        assert_eq!(expected_outcome("kill_rank"), CellOutcome::Aborted);
        assert_eq!(expected_outcome("delay"), CellOutcome::Completed);
        assert_eq!(expected_outcome("duplicate"), CellOutcome::Completed);
        assert_eq!(expected_outcome("stall_worker"), CellOutcome::Completed);
    }
}
