//! Distributed data parallelism (DDP) driver — the Fig. 13 workload, end
//! to end: every rank holds a full parameter replica, runs the AOT
//! `train_step` on its own micro-batch, all-reduces gradients through a
//! PCCL backend, and applies an identical SGD update.

use std::sync::{Arc, Mutex};

use crate::backends::Backend;
use crate::collectives::Pccl;
use crate::comm::CommWorld;
use crate::error::{Error, Result};
use crate::metrics::Timer;
use crate::runtime::{Artifacts, DeviceService, HostTensor};
use crate::topology::Topology;

use super::data::batch_tokens;
use super::optimizer::Sgd;
use super::params::ParamSet;

/// DDP run configuration.
#[derive(Debug, Clone)]
pub struct DdpConfig {
    /// Rank threads ("GPUs").
    pub ranks: usize,
    /// Optional explicit topology (defaults to flat).
    pub topology: Option<Topology>,
    pub steps: usize,
    pub lr: f32,
    pub momentum: f32,
    pub backend: Backend,
    /// Gradient bucket size in KiB (`None` = one monolithic all-reduce).
    /// PyTorch DDP uses 48–80 MB buckets (§II-A).
    pub bucket_kb: Option<usize>,
    /// Artifact directory (`None` → `$PCCL_ARTIFACTS` or `./artifacts`).
    pub artifacts: Option<String>,
    pub seed: u64,
}

impl Default for DdpConfig {
    fn default() -> Self {
        Self {
            ranks: 4,
            topology: None,
            steps: 100,
            lr: 0.5,
            momentum: 0.0,
            backend: Backend::PcclRec,
            bucket_kb: None,
            artifacts: None,
            seed: 7,
        }
    }
}

/// Result of a DDP run.
#[derive(Debug, Clone)]
pub struct DdpReport {
    /// Rank-averaged loss per step.
    pub losses: Vec<f32>,
    /// Wall time per step (seconds, measured on rank 0).
    pub step_secs: Vec<f64>,
    /// Parameter count of the trained model.
    pub param_count: usize,
}

impl DdpReport {
    pub fn initial_loss(&self) -> f32 {
        self.losses.first().copied().unwrap_or(f32::NAN)
    }

    pub fn final_loss(&self) -> f32 {
        self.losses.last().copied().unwrap_or(f32::NAN)
    }
}

fn load_artifacts(cfg_dir: &Option<String>) -> Result<Artifacts> {
    match cfg_dir {
        Some(d) => Artifacts::load(d),
        None => Artifacts::load_default(),
    }
}

/// Run DDP training; returns the loss curve and per-step timings.
pub fn run_ddp(cfg: &DdpConfig) -> Result<DdpReport> {
    let arts = load_artifacts(&cfg.artifacts)?;
    let meta = arts.model()?.clone();
    let service = DeviceService::spawn(arts)?;
    let handle = service.handle();
    handle.preload(&["init_params", "train_step"])?;

    let topo = cfg.topology.unwrap_or_else(|| Topology::flat(cfg.ranks));
    if topo.world_size() != cfg.ranks {
        return Err(Error::InvalidTopology(format!(
            "topology world {} != ranks {}",
            topo.world_size(),
            cfg.ranks
        )));
    }
    let world = CommWorld::<f32>::with_topology(topo);
    // Backend::Auto routes through the persisted dispatcher artifact when
    // one exists (heuristic fallback otherwise); fixed backends bypass it.
    let pccl = Pccl::<f32>::for_training(cfg.backend, cfg.artifacts.as_deref());
    let cfg = cfg.clone();
    let meta = Arc::new(meta);
    let loss_acc: Arc<Mutex<Vec<Vec<f32>>>> =
        Arc::new(Mutex::new(vec![Vec::new(); cfg.ranks]));
    let times_acc: Arc<Mutex<Vec<f64>>> = Arc::new(Mutex::new(Vec::new()));

    let meta_c = Arc::clone(&meta);
    let loss_c = Arc::clone(&loss_acc);
    let times_c = Arc::clone(&times_acc);
    let results: Result<Vec<()>> = world.try_run(move |comm| {
        let rank = comm.rank();
        let p = comm.size() as f32;
        let mut params = ParamSet::init(&handle, &meta_c, cfg.seed as i32)?;
        let mut opt = Sgd::new(cfg.lr, cfg.momentum);
        for step in 0..cfg.steps {
            let timer = Timer::start();
            let tokens = batch_tokens(
                cfg.seed,
                rank,
                step,
                meta_c.batch_per_rank,
                meta_c.seq_len,
                meta_c.vocab_size,
            );
            let mut inputs = params.tensors.clone();
            inputs.push(HostTensor::i32(
                tokens,
                vec![meta_c.batch_per_rank, meta_c.seq_len + 1],
            ));
            let mut out = handle.execute("train_step", inputs)?;
            // Outputs: [loss, grad_0, ..., grad_{P-1}].
            let loss = out.remove(0).into_f32()?[0];
            let mut summed = params.flatten_grads(&out)?;
            // Gradient all-reduce (the collective under study) + average —
            // bucketed like PyTorch DDP when configured.
            match cfg.bucket_kb {
                Some(kb) => {
                    let bucket_elems = (kb * 1024 / 4).max(1);
                    super::bucket::bucketed_all_reduce(
                        comm,
                        &mut summed,
                        bucket_elems,
                        pccl.options(),
                    )?;
                }
                None => summed = pccl.all_reduce(comm, &summed)?,
            }
            for g in &mut summed {
                *g /= p;
            }
            let mut flat = params.flatten()?;
            opt.step(&mut flat, &summed);
            params.load_flat(&flat)?;
            loss_c.lock().unwrap()[rank].push(loss);
            if rank == 0 {
                times_c.lock().unwrap().push(timer.secs());
            }
        }
        Ok(())
    });
    results?;

    let per_rank = Arc::try_unwrap(loss_acc)
        .map_err(|_| Error::Dispatch("loss accumulator still shared".into()))?
        .into_inner()
        .unwrap();
    let steps = per_rank[0].len();
    let losses: Vec<f32> = (0..steps)
        .map(|s| per_rank.iter().map(|r| r[s]).sum::<f32>() / per_rank.len() as f32)
        .collect();
    let step_secs = Arc::try_unwrap(times_acc).unwrap().into_inner().unwrap();
    Ok(DdpReport {
        losses,
        step_secs,
        param_count: meta.param_count,
    })
}
