//! Gradient bucketing — PyTorch DDP splits the gradient all-reduce into
//! 48–80 MB buckets and launches them as the backward pass produces them
//! (§II-A). The bucket manager reproduces that communication pattern:
//! fixed-size buckets over the flat gradient vector, all-reduced in
//! *reverse* order (gradients materialize output-to-input).

use crate::backends::{all_reduce, CollectiveOptions};
use crate::comm::Communicator;
use crate::error::Result;
use crate::reduction::Elem;

/// Byte ranges of each bucket over a flat gradient vector.
pub fn bucket_ranges(total_elems: usize, bucket_elems: usize) -> Vec<std::ops::Range<usize>> {
    assert!(bucket_elems > 0, "bucket size must be positive");
    let mut out = Vec::new();
    let mut start = 0;
    while start < total_elems {
        let end = (start + bucket_elems).min(total_elems);
        out.push(start..end);
        start = end;
    }
    out
}

/// All-reduce `grads` bucket by bucket (reverse order), in place.
pub fn bucketed_all_reduce<T: Elem>(
    comm: &mut Communicator<T>,
    grads: &mut [T],
    bucket_elems: usize,
    opts: &CollectiveOptions<T>,
) -> Result<()> {
    for range in bucket_ranges(grads.len(), bucket_elems).into_iter().rev() {
        let reduced = all_reduce(comm, &grads[range.clone()], opts)?;
        grads[range].copy_from_slice(&reduced);
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backends::Backend;
    use crate::comm::CommWorld;
    use crate::topology::Topology;

    #[test]
    fn ranges_cover_exactly_once() {
        let ranges = bucket_ranges(100, 32);
        assert_eq!(ranges.len(), 4);
        assert_eq!(ranges[0], 0..32);
        assert_eq!(ranges[3], 96..100);
        let covered: usize = ranges.iter().map(|r| r.len()).sum();
        assert_eq!(covered, 100);
    }

    #[test]
    fn single_bucket_when_larger_than_total() {
        let ranges = bucket_ranges(10, 1000);
        assert_eq!(ranges, vec![0..10]);
    }

    #[test]
    fn bucketed_equals_monolithic() {
        let topo = Topology::new(2, 2, 1).unwrap();
        let p = topo.world_size();
        let n = 77; // not a multiple of the bucket size
        let world = CommWorld::<f32>::with_topology(topo);
        let outs = world.run(move |c| {
            let base: Vec<f32> = (0..n).map(|i| (c.rank() * 100 + i) as f32).collect();
            let opts = CollectiveOptions::default().backend(Backend::PcclRec);
            let mono = all_reduce(c, &base, &opts).unwrap();
            let mut bucketed = base.clone();
            bucketed_all_reduce(c, &mut bucketed, 16, &opts).unwrap();
            (mono, bucketed)
        });
        for (r, (mono, bucketed)) in outs.iter().enumerate() {
            assert_eq!(mono, bucketed, "rank {r} (p={p})");
        }
    }
}
