//! Parameter-set plumbing between the flat vectors the collectives move
//! and the per-tensor `HostTensor` lists the AOT `train_step` consumes.

use crate::error::{Error, Result};
use crate::runtime::{DeviceHandle, HostTensor, ModelMeta};

/// The model's parameters as per-tensor buffers, in `train_step` order.
#[derive(Debug, Clone)]
pub struct ParamSet {
    pub tensors: Vec<HostTensor>,
    shapes: Vec<Vec<usize>>,
}

impl ParamSet {
    /// Initialize via the AOT `init_params(seed)` computation — identical
    /// JAX initialization on every rank, Python-free.
    pub fn init(dev: &DeviceHandle, meta: &ModelMeta, seed: i32) -> Result<Self> {
        let out = dev.execute(
            "init_params",
            vec![HostTensor::i32(vec![seed], vec![])],
        )?;
        if out.len() != meta.param_shapes.len() {
            return Err(Error::Artifact(format!(
                "init_params returned {} tensors, manifest says {}",
                out.len(),
                meta.param_shapes.len()
            )));
        }
        Ok(Self {
            tensors: out,
            shapes: meta.param_shapes.clone(),
        })
    }

    /// Total element count.
    pub fn num_elements(&self) -> usize {
        self.tensors.iter().map(HostTensor::len).sum()
    }

    /// Concatenate all tensors into one flat f32 vector (collective order).
    pub fn flatten(&self) -> Result<Vec<f32>> {
        let mut flat = Vec::with_capacity(self.num_elements());
        for t in &self.tensors {
            match t {
                HostTensor::F32 { data, .. } => flat.extend_from_slice(data),
                other => {
                    return Err(Error::Artifact(format!(
                        "non-f32 parameter tensor ({})",
                        other.dtype_str()
                    )))
                }
            }
        }
        Ok(flat)
    }

    /// Overwrite the tensors from a flat vector (inverse of `flatten`).
    pub fn load_flat(&mut self, flat: &[f32]) -> Result<()> {
        if flat.len() != self.num_elements() {
            return Err(Error::BadBufferSize {
                len: flat.len(),
                size: self.num_elements(),
                why: "flat parameter vector has wrong length",
            });
        }
        let mut off = 0;
        for t in &mut self.tensors {
            let n = t.len();
            if let HostTensor::F32 { data, .. } = t {
                data.copy_from_slice(&flat[off..off + n]);
            }
            off += n;
        }
        Ok(())
    }

    /// Flatten a list of gradient tensors with the same shapes.
    pub fn flatten_grads(&self, grads: &[HostTensor]) -> Result<Vec<f32>> {
        if grads.len() != self.tensors.len() {
            return Err(Error::Artifact(format!(
                "got {} grad tensors, expected {}",
                grads.len(),
                self.tensors.len()
            )));
        }
        let mut flat = Vec::with_capacity(self.num_elements());
        for (g, shape) in grads.iter().zip(&self.shapes) {
            if g.shape() != shape.as_slice() {
                return Err(Error::Artifact(format!(
                    "grad shape {:?} != param shape {:?}",
                    g.shape(),
                    shape
                )));
            }
            match g {
                HostTensor::F32 { data, .. } => flat.extend_from_slice(data),
                other => {
                    return Err(Error::Artifact(format!(
                        "non-f32 gradient ({})",
                        other.dtype_str()
                    )))
                }
            }
        }
        Ok(flat)
    }
}
