//! ZeRO-3-style sharded data parallelism — the Fig. 12 workload: parameters
//! live sharded across ranks; each step all-gathers the full parameter
//! vector (PCCL all-gather), computes on a local micro-batch, reduce-
//! scatters gradients (PCCL reduce-scatter), and updates only the local
//! shard. The communication pattern is exactly DeepSpeed ZeRO-3's (§II-A)
//! with full-model granularity.
//!
//! The optimizer state is chunk-native: the parameter shard is a
//! [`Chunk`], the all-gather sends zero-copy views of it, and the gradient
//! reduce-scatter's transport-delivered result chunk is consumed in place
//! (scaled through `make_mut`, unique → no copy) — the reduce path moves
//! no bytes beyond the schedule. The shard update itself goes through
//! `make_mut` too: if a peer still holds an all-gather view of our shard
//! storage, the optimizer write copy-on-writes instead of racing it.

use std::sync::{Arc, Mutex};

use crate::backends::Backend;
use crate::collectives::Pccl;
use crate::comm::{Chunk, CommWorld};
use crate::error::{Error, Result};
use crate::metrics::Timer;
use crate::runtime::{Artifacts, DeviceService, HostTensor};
use crate::topology::Topology;

use super::data::batch_tokens;
use super::optimizer::Sgd;
use super::params::ParamSet;

/// ZeRO-3 run configuration.
#[derive(Debug, Clone)]
pub struct Zero3Config {
    pub ranks: usize,
    pub topology: Option<Topology>,
    pub steps: usize,
    pub lr: f32,
    pub momentum: f32,
    pub backend: Backend,
    pub artifacts: Option<String>,
    pub seed: u64,
}

impl Default for Zero3Config {
    fn default() -> Self {
        Self {
            ranks: 4,
            topology: None,
            steps: 100,
            lr: 0.5,
            momentum: 0.0,
            backend: Backend::PcclRec,
            artifacts: None,
            seed: 7,
        }
    }
}

/// Result of a ZeRO-3 run.
#[derive(Debug, Clone)]
pub struct Zero3Report {
    pub losses: Vec<f32>,
    pub step_secs: Vec<f64>,
    pub param_count: usize,
    /// Elements held per rank (shard size, incl. padding).
    pub shard_elems: usize,
}

impl Zero3Report {
    pub fn final_loss(&self) -> f32 {
        self.losses.last().copied().unwrap_or(f32::NAN)
    }
}

/// Run ZeRO-3 sharded training.
pub fn run_zero3(cfg: &Zero3Config) -> Result<Zero3Report> {
    let arts = match &cfg.artifacts {
        Some(d) => Artifacts::load(d)?,
        None => Artifacts::load_default()?,
    };
    let meta = arts.model()?.clone();
    let service = DeviceService::spawn(arts)?;
    let handle = service.handle();
    handle.preload(&["init_params", "train_step"])?;

    let topo = cfg.topology.unwrap_or_else(|| Topology::flat(cfg.ranks));
    if topo.world_size() != cfg.ranks {
        return Err(Error::InvalidTopology(format!(
            "topology world {} != ranks {}",
            topo.world_size(),
            cfg.ranks
        )));
    }
    let world = CommWorld::<f32>::with_topology(topo);
    // Backend::Auto routes through the persisted dispatcher artifact when
    // one exists (heuristic fallback otherwise); fixed backends bypass it.
    let pccl = Pccl::<f32>::for_training(cfg.backend, cfg.artifacts.as_deref());
    let cfg = cfg.clone();
    let meta = Arc::new(meta);
    let loss_acc: Arc<Mutex<Vec<Vec<f32>>>> =
        Arc::new(Mutex::new(vec![Vec::new(); cfg.ranks]));
    let times_acc: Arc<Mutex<Vec<f64>>> = Arc::new(Mutex::new(Vec::new()));
    let shard_elems = Arc::new(Mutex::new(0usize));

    let meta_c = Arc::clone(&meta);
    let loss_c = Arc::clone(&loss_acc);
    let times_c = Arc::clone(&times_acc);
    let shard_c = Arc::clone(&shard_elems);
    let results: Result<Vec<()>> = world.try_run(move |comm| {
        let rank = comm.rank();
        let p = comm.size();
        // Materialize full params once (same seed everywhere), keep only
        // this rank's shard of the padded flat vector — as a chunk, so
        // every later collective sends views of it and the reduce-scatter
        // result replaces it without a materialization round-trip.
        let mut params = ParamSet::init(&handle, &meta_c, cfg.seed as i32)?;
        let n = params.num_elements();
        let padded = n.div_ceil(p) * p;
        let shard_len = padded / p;
        let mut shard: Chunk<f32> = {
            let mut flat = params.flatten()?;
            flat.resize(padded, 0.0);
            Chunk::from_vec(flat[rank * shard_len..(rank + 1) * shard_len].to_vec())
        };
        if rank == 0 {
            *shard_c.lock().unwrap() = shard_len;
        }
        let mut opt = Sgd::new(cfg.lr, cfg.momentum);
        for step in 0..cfg.steps {
            let timer = Timer::start();
            // 1. All-gather the full parameter vector from shard views;
            //    the one materialization is the contiguous copy the AOT
            //    executable needs.
            let blocks = pccl.all_gather_chunks(comm, shard.clone())?;
            let mut full = Chunk::concat(&blocks);
            drop(blocks);
            full.truncate(n);
            params.load_flat(&full)?;
            // 2. Local forward/backward via the AOT step.
            let tokens = batch_tokens(
                cfg.seed,
                rank,
                step,
                meta_c.batch_per_rank,
                meta_c.seq_len,
                meta_c.vocab_size,
            );
            let mut inputs = params.tensors.clone();
            inputs.push(HostTensor::i32(
                tokens,
                vec![meta_c.batch_per_rank, meta_c.seq_len + 1],
            ));
            let mut out = handle.execute("train_step", inputs)?;
            let loss = out.remove(0).into_f32()?[0];
            // 3. Reduce-scatter gradients: every rank gets the summed grad
            //    for its own shard, delivered as a chunk that is consumed
            //    in place (pad at most once, straight into the chunk the
            //    collective sends).
            let grad_flat = params.flatten_grads(&out)?;
            let grad_in = if padded == grad_flat.len() {
                Chunk::from_vec(grad_flat)
            } else {
                let mut buf = Vec::with_capacity(padded);
                buf.extend_from_slice(&grad_flat);
                buf.resize(padded, 0.0);
                Chunk::from_vec(buf)
            };
            let mut grad_shard = pccl.reduce_scatter_chunks(comm, grad_in)?;
            let inv = 1.0 / p as f32;
            for g in grad_shard.make_mut() {
                *g *= inv;
            }
            // 4. Update only the local shard (copy-on-write shields any
            //    peer still reading an all-gather view of it).
            opt.step(shard.make_mut(), grad_shard.as_slice());
            loss_c.lock().unwrap()[rank].push(loss);
            if rank == 0 {
                times_c.lock().unwrap().push(timer.secs());
            }
        }
        Ok(())
    });
    results?;

    let per_rank = Arc::try_unwrap(loss_acc)
        .map_err(|_| Error::Dispatch("loss accumulator still shared".into()))?
        .into_inner()
        .unwrap();
    let steps = per_rank[0].len();
    let losses: Vec<f32> = (0..steps)
        .map(|s| per_rank.iter().map(|r| r[s]).sum::<f32>() / per_rank.len() as f32)
        .collect();
    let step_secs = Arc::try_unwrap(times_acc).unwrap().into_inner().unwrap();
    let shard = *shard_elems.lock().unwrap();
    Ok(Zero3Report {
        losses,
        step_secs,
        param_count: meta.param_count,
        shard_elems: shard,
    })
}
