//! Distributed training drivers over the PCCL data plane + PJRT runtime:
//! DDP (all-reduce of gradients, Fig. 13's workload) and ZeRO-3-style
//! sharded data parallelism (all-gather params / reduce-scatter grads,
//! Fig. 12's workload).

pub mod bucket;
pub mod data;
pub mod ddp;
pub mod optimizer;
pub mod params;
pub mod zero3;

pub use ddp::{DdpConfig, DdpReport};
pub use zero3::{Zero3Config, Zero3Report};
