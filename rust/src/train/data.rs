//! Synthetic token stream for the end-to-end training examples.
//!
//! The sequence follows a fixed affine recurrence over the vocabulary with
//! occasional seeded noise, so next-token prediction is genuinely learnable
//! (the map token→next is a function the embedding + head can represent)
//! while remaining fully deterministic per (seed, rank, step).

use crate::util::rng::Rng;

/// Deterministic affine successor over the vocab.
#[inline]
pub fn successor(tok: i32, vocab: i32) -> i32 {
    (tok.wrapping_mul(3).wrapping_add(7)).rem_euclid(vocab)
}

/// One `[batch, seq+1]` token tensor for `(seed, rank, step)`. The extra
/// column gives the shifted next-token targets.
pub fn batch_tokens(
    seed: u64,
    rank: usize,
    step: usize,
    batch: usize,
    seq: usize,
    vocab: usize,
) -> Vec<i32> {
    let mut rng = Rng::seed_from_u64(seed ^ ((rank as u64) << 40) ^ ((step as u64) << 16));
    let v = vocab as i32;
    let mut out = Vec::with_capacity(batch * (seq + 1));
    for _ in 0..batch {
        let mut tok: i32 = rng.range_i32(0, v);
        out.push(tok);
        for _ in 0..seq {
            // 5% noise keeps the entropy floor above zero.
            tok = if rng.ratio(1, 20) {
                rng.range_i32(0, v)
            } else {
                successor(tok, v)
            };
            out.push(tok);
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_per_key() {
        let a = batch_tokens(1, 0, 3, 2, 8, 64);
        let b = batch_tokens(1, 0, 3, 2, 8, 64);
        assert_eq!(a, b);
        let c = batch_tokens(1, 1, 3, 2, 8, 64);
        assert_ne!(a, c, "ranks must see different data");
    }

    #[test]
    fn tokens_in_vocab_and_mostly_successor() {
        let v = 97;
        let toks = batch_tokens(42, 0, 0, 4, 128, v);
        assert_eq!(toks.len(), 4 * 129);
        assert!(toks.iter().all(|&t| (0..v as i32).contains(&t)));
        // ≥ 85% of transitions follow the learnable rule.
        let mut follow = 0;
        let mut total = 0;
        for row in toks.chunks(129) {
            for w in row.windows(2) {
                total += 1;
                if w[1] == successor(w[0], v as i32) {
                    follow += 1;
                }
            }
        }
        assert!(follow as f64 / total as f64 > 0.85);
    }
}
