//! Host-side optimizers applied after gradient communication.
//!
//! The AOT `train_step` returns (loss, grads); the collective layer
//! averages grads across ranks; these optimizers apply the update. They
//! operate on the *flat* parameter vector — the same layout the ZeRO-3
//! driver shards.

/// Plain SGD with optional momentum.
#[derive(Debug, Clone)]
pub struct Sgd {
    pub lr: f32,
    pub momentum: f32,
    velocity: Vec<f32>,
}

impl Sgd {
    pub fn new(lr: f32, momentum: f32) -> Self {
        Self {
            lr,
            momentum,
            velocity: Vec::new(),
        }
    }

    /// `params -= lr · (grad + momentum·v)`; lazily sizes the velocity.
    pub fn step(&mut self, params: &mut [f32], grads: &[f32]) {
        assert_eq!(params.len(), grads.len(), "sgd length mismatch");
        if self.momentum == 0.0 {
            for (p, g) in params.iter_mut().zip(grads) {
                *p -= self.lr * g;
            }
            return;
        }
        if self.velocity.len() != params.len() {
            self.velocity = vec![0.0; params.len()];
        }
        for ((p, g), v) in params.iter_mut().zip(grads).zip(&mut self.velocity) {
            *v = self.momentum * *v + g;
            *p -= self.lr * *v;
        }
    }
}

/// AdamW (decoupled weight decay).
#[derive(Debug, Clone)]
pub struct AdamW {
    pub lr: f32,
    pub beta1: f32,
    pub beta2: f32,
    pub eps: f32,
    pub weight_decay: f32,
    t: i32,
    m: Vec<f32>,
    v: Vec<f32>,
}

impl AdamW {
    pub fn new(lr: f32) -> Self {
        Self {
            lr,
            beta1: 0.9,
            beta2: 0.95,
            eps: 1e-8,
            weight_decay: 0.0,
            t: 0,
            m: Vec::new(),
            v: Vec::new(),
        }
    }

    pub fn step(&mut self, params: &mut [f32], grads: &[f32]) {
        assert_eq!(params.len(), grads.len(), "adamw length mismatch");
        if self.m.len() != params.len() {
            self.m = vec![0.0; params.len()];
            self.v = vec![0.0; params.len()];
        }
        self.t += 1;
        let bc1 = 1.0 - self.beta1.powi(self.t);
        let bc2 = 1.0 - self.beta2.powi(self.t);
        for i in 0..params.len() {
            let g = grads[i];
            self.m[i] = self.beta1 * self.m[i] + (1.0 - self.beta1) * g;
            self.v[i] = self.beta2 * self.v[i] + (1.0 - self.beta2) * g * g;
            let mhat = self.m[i] / bc1;
            let vhat = self.v[i] / bc2;
            params[i] -=
                self.lr * (mhat / (vhat.sqrt() + self.eps) + self.weight_decay * params[i]);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sgd_descends_quadratic() {
        // minimize f(x) = x², grad = 2x
        let mut x = vec![10.0f32];
        let mut opt = Sgd::new(0.1, 0.0);
        for _ in 0..100 {
            let g = vec![2.0 * x[0]];
            opt.step(&mut x, &g);
        }
        assert!(x[0].abs() < 1e-3);
    }

    #[test]
    fn momentum_accelerates() {
        let run = |momentum: f32| {
            let mut x = vec![10.0f32];
            let mut opt = Sgd::new(0.02, momentum);
            for _ in 0..40 {
                let g = vec![2.0 * x[0]];
                opt.step(&mut x, &g);
            }
            x[0].abs()
        };
        assert!(run(0.9) < run(0.0));
    }

    #[test]
    fn adamw_descends_and_decays() {
        let mut x = vec![5.0f32, -5.0];
        let mut opt = AdamW::new(0.1);
        opt.weight_decay = 0.01;
        for _ in 0..300 {
            let g = vec![2.0 * x[0], 2.0 * x[1]];
            opt.step(&mut x, &g);
        }
        assert!(x[0].abs() < 1e-2 && x[1].abs() < 1e-2);
    }
}
