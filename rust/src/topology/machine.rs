//! Machine presets — the two systems of the paper's evaluation plus a
//! generic preset for laptop-scale runs.
//!
//! All bandwidth/latency constants are calibration inputs to
//! [`crate::netsim`]; they are set from public system specs and from the
//! paper's own measurements (e.g. ~25 GB/s per Slingshot-11 NIC, the 4×
//! Cray-MPICH NIC-underutilization gap of Fig. 3). The *shapes* of the
//! reproduced figures are insensitive to ±2× changes in these values; the
//! netsim property tests pin the invariants that matter.

/// Supported machine models.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Machine {
    /// OLCF Frontier: AMD MI250X, 8 GCDs/node, 4 Slingshot-11 NICs/node.
    Frontier,
    /// NERSC Perlmutter: NVIDIA A100, 4 GPUs/node, 4 Slingshot-11 NICs/node.
    Perlmutter,
    /// Small generic box for data-plane testing: 1 node is assumed.
    Generic,
    /// A hypothetical InfiniBand/NVLink cluster (DGX-H100-like) — the
    /// paper's stated future work ("benchmark PCCL on clusters with
    /// InfiniBand interconnects"). No Cassini match-list pathology.
    InfiniBand,
}

/// Calibration constants for one machine.
#[derive(Debug, Clone)]
pub struct MachineParams {
    pub name: &'static str,
    pub gpus_per_node: usize,
    pub nics_per_node: usize,
    /// Per-NIC injection bandwidth, bytes/s (Slingshot-11 ≈ 25 GB/s).
    pub nic_bw: f64,
    /// Per-message inter-node startup latency, seconds (MPI p2p path).
    pub alpha_inter: f64,
    /// Extra per-message startup cost for each *additional* NIC rail a
    /// striped (multi-lane) collective drives: per-lane queue-pair setup,
    /// doorbell and completion handling. Total inter-node alpha for a
    /// `k`-lane step is `alpha_inter + (k − 1)·alpha_lane`.
    pub alpha_lane: f64,
    /// Per-step overhead of the vendor (NCCL/RCCL) inter-node ring,
    /// seconds — kernel launch + proto handshake, higher than raw MPI p2p.
    pub alpha_vendor: f64,
    /// Intra-node GPU↔GPU link bandwidth per direction, bytes/s
    /// (Infinity Fabric / NVLink3).
    pub intra_bw: f64,
    /// Per-message intra-node latency, seconds.
    pub alpha_intra: f64,
    /// Local reduction bandwidth on the GPU, bytes/s (HBM-bound kernel).
    pub gpu_reduce_bw: f64,
    /// Local reduction bandwidth on the CPU, bytes/s — the Cray-MPICH
    /// pathology of Observation 1.
    pub cpu_reduce_bw: f64,
    /// Host-side copy bandwidth for the Cassini "overflow list" software
    /// copy path that RCCL triggers at scale (§VI-B).
    pub overflow_copy_bw: f64,
    /// Device-local shuffle (transpose) bandwidth, bytes/s (Step 3 of the
    /// hierarchical all-gather).
    pub shuffle_bw: f64,
    /// Peak matmul throughput used by the analytic step-time model
    /// (flop/s, bf16): MI250X GCD ≈ 191.5e12, A100 ≈ 312e12.
    pub gpu_flops: f64,
    /// Run-to-run timing jitter (lognormal sigma); vendor all-reduce on
    /// Frontier is notoriously variable (§V-B).
    pub jitter_sigma: f64,
}

impl std::str::FromStr for Machine {
    type Err = String;

    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s.to_ascii_lowercase().as_str() {
            "frontier" => Ok(Machine::Frontier),
            "perlmutter" => Ok(Machine::Perlmutter),
            "generic" => Ok(Machine::Generic),
            "infiniband" | "ib" => Ok(Machine::InfiniBand),
            other => Err(format!("unknown machine {other:?} (frontier|perlmutter|generic)")),
        }
    }
}

impl Machine {
    /// Calibration constants for this machine.
    pub fn params(self) -> MachineParams {
        match self {
            Machine::Frontier => MachineParams {
                name: "frontier",
                gpus_per_node: 8,
                nics_per_node: 4,
                nic_bw: 25.0e9,
                alpha_inter: 4.0e-6,
                alpha_lane: 2.0e-6,
                alpha_vendor: 20.0e-6,
                intra_bw: 100.0e9,
                alpha_intra: 2.0e-6,
                gpu_reduce_bw: 1.0e12,
                cpu_reduce_bw: 12.0e9,
                overflow_copy_bw: 3.0e9,
                shuffle_bw: 600.0e9,
                gpu_flops: 191.5e12,
                jitter_sigma: 0.06,
            },
            Machine::Perlmutter => MachineParams {
                name: "perlmutter",
                gpus_per_node: 4,
                nics_per_node: 4,
                nic_bw: 25.0e9,
                alpha_inter: 3.5e-6,
                alpha_lane: 2.0e-6,
                alpha_vendor: 0.8e-6,
                intra_bw: 200.0e9,
                alpha_intra: 1.5e-6,
                gpu_reduce_bw: 1.3e12,
                cpu_reduce_bw: 15.0e9,
                // NCCL on Perlmutter degrades far less than RCCL on
                // Frontier (5.7× vs 168× peak speedups): the overflow-copy
                // path is much cheaper there.
                overflow_copy_bw: 40.0e9,
                shuffle_bw: 900.0e9,
                gpu_flops: 312.0e12,
                jitter_sigma: 0.04,
            },
            Machine::InfiniBand => MachineParams {
                name: "infiniband",
                gpus_per_node: 8,
                nics_per_node: 8,
                nic_bw: 50.0e9, // NDR 400 Gb/s per HCA
                alpha_inter: 2.5e-6,
                alpha_lane: 2.0e-6,
                alpha_vendor: 1.5e-6,
                intra_bw: 450.0e9, // NVLink4
                alpha_intra: 1.0e-6,
                gpu_reduce_bw: 2.0e12,
                cpu_reduce_bw: 20.0e9,
                // No Slingshot overflow-list: unexpected messages land in
                // pre-posted RDMA buffers at near-wire speed.
                overflow_copy_bw: 1.0e12,
                shuffle_bw: 1.5e12,
                gpu_flops: 989.0e12,
                jitter_sigma: 0.03,
            },
            Machine::Generic => MachineParams {
                name: "generic",
                gpus_per_node: 8,
                nics_per_node: 4,
                nic_bw: 25.0e9,
                alpha_inter: 4.0e-6,
                alpha_lane: 2.0e-6,
                alpha_vendor: 20.0e-6,
                intra_bw: 100.0e9,
                alpha_intra: 2.0e-6,
                gpu_reduce_bw: 1.0e12,
                cpu_reduce_bw: 12.0e9,
                overflow_copy_bw: 3.0e9,
                shuffle_bw: 600.0e9,
                gpu_flops: 191.5e12,
                jitter_sigma: 0.0,
            },
        }
    }

    /// The vendor collective library of this machine (for labels).
    pub fn vendor_name(self) -> &'static str {
        match self {
            Machine::Frontier => "RCCL",
            Machine::Perlmutter | Machine::InfiniBand => "NCCL",
            Machine::Generic => "vendor",
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn params_sane() {
        for m in [
            Machine::Frontier,
            Machine::Perlmutter,
            Machine::Generic,
            Machine::InfiniBand,
        ] {
            let p = m.params();
            assert!(p.gpus_per_node % p.nics_per_node == 0);
            assert!(p.nic_bw > 0.0 && p.intra_bw >= p.nic_bw);
            assert!(p.gpu_reduce_bw > p.cpu_reduce_bw * 10.0);
            assert!(p.alpha_vendor > 0.0 && p.alpha_inter > 0.0);
            assert!(p.alpha_lane > 0.0 && p.alpha_lane <= p.alpha_inter);
        }
    }
}
