//! Cluster topology: nodes × GPUs-per-node × NICs-per-node, plus the
//! GPU→NIC binding and the inter-/intra-node sub-communicator structure the
//! paper's hierarchical collectives are built on (§IV-A, Fig. 5).

mod machine;

pub use machine::{Machine, MachineParams};

use crate::error::{Error, Result};

/// Static shape of the cluster a communicator spans.
///
/// Global rank `r` lives on node `r / gpus_per_node` with local id
/// `r % gpus_per_node` (the "corresponding GPU" numbering of Fig. 5) and is
/// bound to NIC `local_id / (gpus_per_node / nics_per_node)` of its node —
/// on Frontier: GCDs 0,1 → NIC 0, GCDs 2,3 → NIC 1, etc. (§IV-A).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Topology {
    nodes: usize,
    gpus_per_node: usize,
    nics_per_node: usize,
}

impl Topology {
    /// Build a topology; validates divisibility of the NIC binding.
    pub fn new(nodes: usize, gpus_per_node: usize, nics_per_node: usize) -> Result<Self> {
        if nodes == 0 || gpus_per_node == 0 || nics_per_node == 0 {
            return Err(Error::InvalidTopology(format!(
                "all dimensions must be > 0 (got {nodes} nodes × {gpus_per_node} GPUs × {nics_per_node} NICs)"
            )));
        }
        if gpus_per_node % nics_per_node != 0 {
            return Err(Error::InvalidTopology(format!(
                "gpus_per_node ({gpus_per_node}) must be divisible by nics_per_node ({nics_per_node})"
            )));
        }
        Ok(Self {
            nodes,
            gpus_per_node,
            nics_per_node,
        })
    }

    /// Single-node topology for `size` ranks (flat testing).
    pub fn flat(size: usize) -> Self {
        Self {
            nodes: 1,
            gpus_per_node: size,
            nics_per_node: 1,
        }
    }

    /// Topology for `world` ranks on machine `m` (world must divide evenly
    /// into nodes).
    pub fn for_machine(m: Machine, world: usize) -> Result<Self> {
        let p = m.params();
        if world % p.gpus_per_node != 0 {
            return Err(Error::InvalidTopology(format!(
                "world size {world} not a multiple of {} GPUs/node on {}",
                p.gpus_per_node, p.name
            )));
        }
        Self::new(world / p.gpus_per_node, p.gpus_per_node, p.nics_per_node)
    }

    pub fn nodes(&self) -> usize {
        self.nodes
    }

    pub fn gpus_per_node(&self) -> usize {
        self.gpus_per_node
    }

    pub fn nics_per_node(&self) -> usize {
        self.nics_per_node
    }

    /// Total ranks (GPUs/GCDs).
    pub fn world_size(&self) -> usize {
        self.nodes * self.gpus_per_node
    }

    /// Node index of a global rank.
    pub fn node_of(&self, rank: usize) -> usize {
        rank / self.gpus_per_node
    }

    /// Within-node id of a global rank.
    pub fn local_id(&self, rank: usize) -> usize {
        rank % self.gpus_per_node
    }

    /// NIC index (within its node) that `rank` is bound to.
    pub fn nic_of(&self, rank: usize) -> usize {
        self.local_id(rank) / (self.gpus_per_node / self.nics_per_node)
    }

    /// Global rank from (node, local id).
    pub fn rank_of(&self, node: usize, local: usize) -> usize {
        node * self.gpus_per_node + local
    }

    /// The inter-node sub-communicator of `rank`: all ranks across nodes
    /// sharing its local id, in node order (Fig. 5 step 1). Length = nodes.
    pub fn inter_node_group(&self, rank: usize) -> Vec<usize> {
        let local = self.local_id(rank);
        (0..self.nodes).map(|n| self.rank_of(n, local)).collect()
    }

    /// The intra-node sub-communicator of `rank`: all ranks on its node, in
    /// local-id order (Fig. 5 step 2). Length = gpus_per_node.
    pub fn intra_node_group(&self, rank: usize) -> Vec<usize> {
        let node = self.node_of(rank);
        (0..self.gpus_per_node)
            .map(|l| self.rank_of(node, l))
            .collect()
    }

    /// True if the hierarchical algorithms can run (both levels ≥ 1 and the
    /// world splits exactly).
    pub fn supports_hierarchical(&self) -> bool {
        self.nodes >= 2 && self.gpus_per_node >= 2
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn frontier_nic_binding() {
        // Frontier node: 8 GCDs, 4 NICs → pairs share a NIC (§IV-A).
        let t = Topology::new(4, 8, 4).unwrap();
        assert_eq!(t.world_size(), 32);
        let nics: Vec<usize> = (0..8).map(|r| t.nic_of(r)).collect();
        assert_eq!(nics, vec![0, 0, 1, 1, 2, 2, 3, 3]);
        // Same binding on every node.
        assert_eq!(t.nic_of(8 + 5), 2);
    }

    #[test]
    fn groups_are_consistent() {
        let t = Topology::new(3, 4, 2).unwrap();
        // rank 6 = node 1, local 2
        assert_eq!(t.node_of(6), 1);
        assert_eq!(t.local_id(6), 2);
        assert_eq!(t.inter_node_group(6), vec![2, 6, 10]);
        assert_eq!(t.intra_node_group(6), vec![4, 5, 6, 7]);
        // Every rank appears in exactly one inter group per local id and one
        // intra group per node.
        let mut seen = vec![0usize; t.world_size()];
        for local in 0..t.gpus_per_node() {
            for &r in &t.inter_node_group(local) {
                seen[r] += 1;
            }
        }
        assert!(seen.iter().all(|&c| c == 1));
    }

    #[test]
    fn invalid_shapes_rejected() {
        assert!(Topology::new(0, 8, 4).is_err());
        assert!(Topology::new(2, 6, 4).is_err()); // 6 % 4 != 0
        assert!(Topology::for_machine(Machine::Frontier, 12).is_err());
    }

    #[test]
    fn machine_world_split() {
        let t = Topology::for_machine(Machine::Frontier, 64).unwrap();
        assert_eq!(t.nodes(), 8);
        assert_eq!(t.gpus_per_node(), 8);
        let t = Topology::for_machine(Machine::Perlmutter, 64).unwrap();
        assert_eq!(t.nodes(), 16);
        assert_eq!(t.gpus_per_node(), 4);
    }
}
