//! Crate-wide error type. Every fallible public API returns [`Result`].
//!
//! Hand-rolled `Display`/`Error` impls keep the crate dependency-free (the
//! build must work fully offline — no crates.io access).

use std::fmt;

/// Errors surfaced by the PCCL library.
#[derive(Debug)]
pub enum Error {
    /// A collective was invoked with a buffer whose length is incompatible
    /// with the communicator size (e.g. reduce-scatter input not divisible
    /// by `p`).
    BadBufferSize {
        len: usize,
        size: usize,
        why: &'static str,
    },

    /// A rank tried to communicate with a peer outside `0..size`.
    PeerOutOfRange { peer: usize, size: usize },

    /// A receive timed out — the peer rank likely died or deadlocked.
    RecvTimeout { src: usize, tag: u64, ms: u64 },

    /// A posted receive buffer (`recv_into` / `recv_combine_into`) does not
    /// match the shape of the incoming chunk. The message is left queued so
    /// the caller can re-post a correctly sized buffer.
    RecvShapeMismatch {
        src: usize,
        tag: u64,
        expected: usize,
        got: usize,
    },

    /// The transport was shut down while an operation was in flight.
    TransportClosed { rank: usize },

    /// A collective was aborted — either this rank hit a fault/timeout and
    /// poisoned the world, or a peer did and the poison reached us. Every
    /// surviving rank of the world returns this same error (with the
    /// origin's identity) within the configured detection window, instead
    /// of each independently sleeping out its full receive timeout.
    CollectiveAborted {
        /// Rank that first detected the failure and tripped the abort.
        origin_rank: usize,
        /// The origin's communicator op sequence when it aborted.
        op_seq: u64,
        /// Human-readable description of the underlying failure.
        cause: String,
    },

    /// A lane worker thread failed to answer a dispatched job within the
    /// receive timeout plus the endpoint's configured shutdown grace — the
    /// worker is presumed dead or wedged (distinct from an orderly
    /// [`Error::TransportClosed`] teardown).
    LaneWorkerLost {
        rank: usize,
        lane: usize,
        grace_ms: u64,
    },

    /// Topology construction was asked for an impossible shape.
    InvalidTopology(String),

    /// An artifact produced by `make artifacts` is missing or malformed.
    Artifact(String),

    /// A persisted payload (dispatcher model, bench record) carries a
    /// schema version this build cannot consume — e.g. a pre-lane
    /// 2-feature dispatcher model loaded by a 3-feature build. Refusing
    /// loudly beats silently mis-dispatching on garbage features; re-train
    /// with `pccl train` to migrate.
    ArtifactSchema {
        what: String,
        expected: u32,
        got: u32,
    },

    /// The PJRT runtime failed to compile or execute an HLO module (or the
    /// build carries only the offline stub backend).
    Xla(String),

    /// SVM training / dispatcher errors.
    Dispatch(String),

    /// A lowered collective plan failed static verification (deadlock,
    /// coverage, or shape defect) or could not be built for the requested
    /// spec. Plans are verified before any rank executes them, so this
    /// surfaces at dispatch time, not mid-collective.
    Plan(String),

    /// Simulator configuration errors.
    NetSim(String),

    /// Anything I/O.
    Io(std::io::Error),

    /// JSON (manifest, model persistence).
    Json(String),

    /// A measurement that must land in a JSON artifact is NaN or infinite.
    /// JSON has no spelling for those, so encoding would silently corrupt
    /// the document; [`crate::util::json::Value::finite_num`] rejects them
    /// up front with this error instead.
    NonFiniteJson { value: String },
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::BadBufferSize { len, size, why } => {
                write!(f, "buffer size {len} incompatible with communicator size {size}: {why}")
            }
            Error::PeerOutOfRange { peer, size } => {
                write!(f, "peer rank {peer} out of range for communicator of size {size}")
            }
            Error::RecvTimeout { src, tag, ms } => {
                write!(f, "recv from rank {src} (tag {tag:#x}) timed out after {ms} ms")
            }
            Error::RecvShapeMismatch { src, tag, expected, got } => {
                write!(
                    f,
                    "posted receive buffer of {expected} elements cannot accept \
                     {got}-element chunk from rank {src} (tag {tag:#x})"
                )
            }
            Error::TransportClosed { rank } => {
                write!(f, "transport closed while rank {rank} was communicating")
            }
            Error::CollectiveAborted { origin_rank, op_seq, cause } => {
                write!(
                    f,
                    "collective aborted by rank {origin_rank} at op {op_seq}: {cause}"
                )
            }
            Error::LaneWorkerLost { rank, lane, grace_ms } => {
                write!(
                    f,
                    "lane worker {lane} of rank {rank} missed the shutdown grace \
                     ({grace_ms} ms past the receive timeout) — worker presumed dead"
                )
            }
            Error::InvalidTopology(m) => write!(f, "invalid topology: {m}"),
            Error::Artifact(m) => write!(f, "artifact error: {m}"),
            Error::ArtifactSchema { what, expected, got } => {
                write!(
                    f,
                    "artifact schema mismatch for {what}: this build expects schema \
                     {expected}, found {got} — re-train/regenerate to migrate"
                )
            }
            Error::Xla(m) => write!(f, "xla runtime error: {m}"),
            Error::Dispatch(m) => write!(f, "dispatch error: {m}"),
            Error::Plan(m) => write!(f, "plan verification failed: {m}"),
            Error::NetSim(m) => write!(f, "netsim error: {m}"),
            // Transparent: the io error's own message is the message.
            Error::Io(e) => write!(f, "{e}"),
            Error::Json(m) => write!(f, "json error: {m}"),
            Error::NonFiniteJson { value } => {
                write!(f, "non-finite number {value} cannot be encoded as JSON")
            }
        }
    }
}

impl std::error::Error for Error {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            Error::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Self {
        Error::Io(e)
    }
}

/// Crate-wide result alias.
pub type Result<T> = std::result::Result<T, Error>;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_matches_seed_format() {
        let e = Error::BadBufferSize { len: 7, size: 3, why: "nope" };
        assert_eq!(
            e.to_string(),
            "buffer size 7 incompatible with communicator size 3: nope"
        );
        let e = Error::RecvTimeout { src: 2, tag: 0x10, ms: 50 };
        assert!(e.to_string().contains("tag 0x10"));
        let e = Error::RecvShapeMismatch { src: 1, tag: 0x20, expected: 4, got: 8 };
        assert_eq!(
            e.to_string(),
            "posted receive buffer of 4 elements cannot accept 8-element chunk \
             from rank 1 (tag 0x20)"
        );
        let e: Error = std::io::Error::new(std::io::ErrorKind::NotFound, "gone").into();
        assert!(e.to_string().contains("gone"));
        assert!(std::error::Error::source(&e).is_some());
    }

    #[test]
    fn abort_and_worker_loss_are_typed() {
        let e = Error::CollectiveAborted {
            origin_rank: 3,
            op_seq: 7,
            cause: "recv timeout".into(),
        };
        assert_eq!(
            e.to_string(),
            "collective aborted by rank 3 at op 7: recv timeout"
        );
        let e = Error::LaneWorkerLost { rank: 1, lane: 2, grace_ms: 500 };
        assert!(e.to_string().contains("lane worker 2 of rank 1"));
        assert!(e.to_string().contains("500 ms"));
    }
}
