//! Crate-wide error type. Every fallible public API returns [`Result`].

use thiserror::Error;

/// Errors surfaced by the PCCL library.
#[derive(Debug, Error)]
pub enum Error {
    /// A collective was invoked with a buffer whose length is incompatible
    /// with the communicator size (e.g. reduce-scatter input not divisible
    /// by `p`).
    #[error("buffer size {len} incompatible with communicator size {size}: {why}")]
    BadBufferSize {
        len: usize,
        size: usize,
        why: &'static str,
    },

    /// A rank tried to communicate with a peer outside `0..size`.
    #[error("peer rank {peer} out of range for communicator of size {size}")]
    PeerOutOfRange { peer: usize, size: usize },

    /// A receive timed out — the peer rank likely died or deadlocked.
    #[error("recv from rank {src} (tag {tag:#x}) timed out after {ms} ms")]
    RecvTimeout { src: usize, tag: u64, ms: u64 },

    /// The transport was shut down while an operation was in flight.
    #[error("transport closed while rank {rank} was communicating")]
    TransportClosed { rank: usize },

    /// Topology construction was asked for an impossible shape.
    #[error("invalid topology: {0}")]
    InvalidTopology(String),

    /// An artifact produced by `make artifacts` is missing or malformed.
    #[error("artifact error: {0}")]
    Artifact(String),

    /// The PJRT runtime failed to compile or execute an HLO module.
    #[error("xla runtime error: {0}")]
    Xla(String),

    /// SVM training / dispatcher errors.
    #[error("dispatch error: {0}")]
    Dispatch(String),

    /// Simulator configuration errors.
    #[error("netsim error: {0}")]
    NetSim(String),

    /// Anything I/O.
    #[error(transparent)]
    Io(#[from] std::io::Error),

    /// JSON (manifest, model persistence).
    #[error("json error: {0}")]
    Json(String),
}

impl From<xla::Error> for Error {
    fn from(e: xla::Error) -> Self {
        Error::Xla(e.to_string())
    }
}

/// Crate-wide result alias.
pub type Result<T> = std::result::Result<T, Error>;
