//! Micro-benchmark harness for the `cargo bench` targets (offline stand-in
//! for criterion): warmup, timed iterations until a wall budget, mean ±
//! stddev, ns/iter and throughput reporting.

use std::time::{Duration, Instant};

use crate::metrics::Stats;

/// One benchmark group's configuration.
pub struct Bench {
    name: String,
    warmup: Duration,
    budget: Duration,
    min_iters: u32,
}

/// A finished measurement.
#[derive(Debug, Clone)]
pub struct Measurement {
    pub name: String,
    pub iters: u64,
    pub mean_ns: f64,
    pub stddev_ns: f64,
    pub bytes_per_iter: Option<u64>,
}

impl Measurement {
    pub fn report(&self) {
        let thr = self
            .bytes_per_iter
            .map(|b| {
                let gbs = b as f64 / (self.mean_ns * 1e-9) / 1e9;
                format!("  {gbs:>8.2} GB/s")
            })
            .unwrap_or_default();
        println!(
            "{:<52} {:>12.0} ns/iter (± {:>8.0})  {:>8} iters{}",
            self.name, self.mean_ns, self.stddev_ns, self.iters, thr
        );
    }
}

impl Bench {
    pub fn new(name: impl Into<String>) -> Self {
        Self {
            name: name.into(),
            warmup: Duration::from_millis(200),
            budget: Duration::from_millis(1500),
            min_iters: 10,
        }
    }

    pub fn warmup(mut self, d: Duration) -> Self {
        self.warmup = d;
        self
    }

    pub fn budget(mut self, d: Duration) -> Self {
        self.budget = d;
        self
    }

    /// Run `f` repeatedly; returns and prints the measurement.
    pub fn run<R>(&self, mut f: impl FnMut() -> R) -> Measurement {
        self.run_inner(&mut f, None)
    }

    /// Like [`Bench::run`], reporting throughput for `bytes` per iteration.
    pub fn run_bytes<R>(&self, bytes: u64, mut f: impl FnMut() -> R) -> Measurement {
        self.run_inner(&mut f, Some(bytes))
    }

    fn run_inner<R>(&self, f: &mut impl FnMut() -> R, bytes: Option<u64>) -> Measurement {
        // Warmup.
        let start = Instant::now();
        while start.elapsed() < self.warmup {
            std::hint::black_box(f());
        }
        // Measure.
        let mut stats = Stats::new();
        let mut iters = 0u64;
        let start = Instant::now();
        while start.elapsed() < self.budget || iters < self.min_iters as u64 {
            let t = Instant::now();
            std::hint::black_box(f());
            stats.push(t.elapsed().as_nanos() as f64);
            iters += 1;
            if iters > 10_000_000 {
                break;
            }
        }
        let m = Measurement {
            name: self.name.clone(),
            iters,
            mean_ns: stats.mean(),
            stddev_ns: stats.stddev(),
            bytes_per_iter: bytes,
        };
        m.report();
        m
    }
}

/// Print a bench section header.
pub fn section(title: &str) {
    println!("\n== {title} ==");
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something_positive() {
        let m = Bench::new("noop")
            .warmup(Duration::from_millis(1))
            .budget(Duration::from_millis(10))
            .run(|| 1 + 1);
        assert!(m.iters >= 10);
        assert!(m.mean_ns >= 0.0);
    }

    #[test]
    fn throughput_is_reported() {
        let m = Bench::new("copy")
            .warmup(Duration::from_millis(1))
            .budget(Duration::from_millis(10))
            .run_bytes(1024, || vec![0u8; 1024]);
        assert_eq!(m.bytes_per_iter, Some(1024));
    }
}
