//! Deterministic pseudo-random numbers: xoshiro256** seeded via SplitMix64
//! — the generator behind netsim jitter, SMO's partner choice, data
//! shuffling, and the property-test harness. Reproducible by seed across
//! platforms.

/// xoshiro256** (Blackman–Vigna), seeded with SplitMix64.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

impl Rng {
    pub fn seed_from_u64(seed: u64) -> Self {
        let mut sm = seed;
        Self {
            s: [
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
            ],
        }
    }

    /// Next raw u64.
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, 1)`.
    pub fn f64(&mut self) -> f64 {
        // 53 high bits → [0,1).
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in `[0, 1)`.
    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// Uniform integer in `[lo, hi)` (half-open, `hi > lo`).
    pub fn range_usize(&mut self, lo: usize, hi: usize) -> usize {
        assert!(hi > lo, "empty range [{lo},{hi})");
        lo + (self.next_u64() % (hi - lo) as u64) as usize
    }

    /// Uniform integer in `[lo, hi)` for i32.
    pub fn range_i32(&mut self, lo: i32, hi: i32) -> i32 {
        assert!(hi > lo);
        lo + (self.next_u64() % (hi - lo) as u64) as i32
    }

    /// Uniform in `[lo, hi)`.
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.f64() * (hi - lo)
    }

    /// True with probability `num/den`.
    pub fn ratio(&mut self, num: u32, den: u32) -> bool {
        (self.next_u64() % den as u64) < num as u64
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(1e-300);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.range_usize(0, i + 1);
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_by_seed() {
        let mut a = Rng::seed_from_u64(7);
        let mut b = Rng::seed_from_u64(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        let mut c = Rng::seed_from_u64(8);
        assert_ne!(a.next_u64(), c.next_u64());
    }

    #[test]
    fn uniform_bounds_and_mean() {
        let mut r = Rng::seed_from_u64(1);
        let n = 20_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let v = r.f64();
            assert!((0.0..1.0).contains(&v));
            sum += v;
        }
        let mean = sum / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean {mean}");
    }

    #[test]
    fn range_is_inclusive_exclusive() {
        let mut r = Rng::seed_from_u64(2);
        let mut seen_lo = false;
        let mut seen_hi_minus1 = false;
        for _ in 0..1000 {
            let v = r.range_usize(3, 6);
            assert!((3..6).contains(&v));
            seen_lo |= v == 3;
            seen_hi_minus1 |= v == 5;
        }
        assert!(seen_lo && seen_hi_minus1);
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::seed_from_u64(3);
        let n = 20_000;
        let (mut s, mut s2) = (0.0, 0.0);
        for _ in 0..n {
            let v = r.normal();
            s += v;
            s2 += v * v;
        }
        let mean = s / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.03, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::seed_from_u64(4);
        let mut v: Vec<usize> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
        assert_ne!(v, sorted, "shuffle should move things");
    }
}
