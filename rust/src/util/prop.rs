//! Property-testing helper (offline stand-in for proptest): run a
//! predicate over many seeded random cases; on failure report the failing
//! seed so the case can be replayed deterministically.

use super::rng::Rng;

/// Run `cases` random trials of `f`, each with a fresh deterministic RNG.
/// Panics with the failing case index + seed on first failure.
pub fn check(name: &str, cases: usize, base_seed: u64, mut f: impl FnMut(&mut Rng)) {
    for case in 0..cases {
        let seed = base_seed
            .wrapping_mul(0x9E37_79B9_7F4A_7C15)
            .wrapping_add(case as u64);
        let mut rng = Rng::seed_from_u64(seed);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| f(&mut rng)));
        if let Err(panic) = result {
            let msg = panic
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| panic.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "<non-string panic>".into());
            panic!("property {name:?} failed at case {case} (seed {seed:#x}): {msg}");
        }
    }
}

/// Random vector of f32 in [-scale, scale].
pub fn vec_f32(rng: &mut Rng, len: usize, scale: f32) -> Vec<f32> {
    (0..len)
        .map(|_| (rng.f32() * 2.0 - 1.0) * scale)
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = 0;
        check("trivial", 25, 1, |rng| {
            count += 1;
            assert!(rng.f64() < 1.0);
        });
        assert_eq!(count, 25);
    }

    #[test]
    fn failing_property_reports_seed() {
        let result = std::panic::catch_unwind(|| {
            check("fails", 10, 2, |rng| {
                let v = rng.range_usize(0, 100);
                assert!(v < 101); // always true
                assert!(v != v || false == true || v < 1000); // true
                panic!("boom");
            });
        });
        let payload = result.unwrap_err();
        let msg = payload
            .downcast_ref::<String>()
            .cloned()
            .unwrap_or_default();
        assert!(msg.contains("case 0"), "{msg}");
        assert!(msg.contains("seed"), "{msg}");
    }
}
