//! Minimal JSON: a value model, a recursive-descent parser, and a writer.
//! Used for the artifact manifest, dispatcher persistence, and config
//! files. Supports the full JSON grammar except `\u` surrogate pairs
//! beyond the BMP (sufficient for machine-generated files).

use std::collections::BTreeMap;
use std::fmt::Write as _;

use crate::error::{Error, Result};

/// A JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Value>),
    Obj(BTreeMap<String, Value>),
}

impl Value {
    pub fn parse(text: &str) -> Result<Value> {
        let mut p = Parser {
            b: text.as_bytes(),
            i: 0,
        };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    // --- accessors -------------------------------------------------------

    pub fn as_obj(&self) -> Result<&BTreeMap<String, Value>> {
        match self {
            Value::Obj(m) => Ok(m),
            _ => Err(Error::Json(format!("expected object, got {self:?}"))),
        }
    }

    pub fn as_arr(&self) -> Result<&[Value]> {
        match self {
            Value::Arr(v) => Ok(v),
            _ => Err(Error::Json(format!("expected array, got {self:?}"))),
        }
    }

    pub fn as_str(&self) -> Result<&str> {
        match self {
            Value::Str(s) => Ok(s),
            _ => Err(Error::Json(format!("expected string, got {self:?}"))),
        }
    }

    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Value::Num(n) => Ok(*n),
            _ => Err(Error::Json(format!("expected number, got {self:?}"))),
        }
    }

    pub fn as_usize(&self) -> Result<usize> {
        let f = self.as_f64()?;
        if f < 0.0 || f.fract() != 0.0 {
            return Err(Error::Json(format!("expected non-negative integer, got {f}")));
        }
        Ok(f as usize)
    }

    pub fn as_bool(&self) -> Result<bool> {
        match self {
            Value::Bool(b) => Ok(*b),
            _ => Err(Error::Json(format!("expected bool, got {self:?}"))),
        }
    }

    /// Object field lookup.
    pub fn get(&self, key: &str) -> Result<&Value> {
        self.as_obj()?
            .get(key)
            .ok_or_else(|| Error::Json(format!("missing field {key:?}")))
    }

    /// Optional field (None when missing or null).
    pub fn get_opt(&self, key: &str) -> Option<&Value> {
        match self {
            Value::Obj(m) => match m.get(key) {
                Some(Value::Null) | None => None,
                Some(v) => Some(v),
            },
            _ => None,
        }
    }

    // --- builders --------------------------------------------------------

    pub fn obj(pairs: Vec<(&str, Value)>) -> Value {
        Value::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    /// [`Value::Num`] that refuses NaN/infinity with a typed error instead
    /// of letting the writer null-encode it. Use for measurements that a
    /// downstream consumer must be able to trust as numbers (bench records,
    /// predicted/observed timings).
    pub fn finite_num(n: f64) -> Result<Value> {
        if n.is_finite() {
            Ok(Value::Num(n))
        } else {
            Err(Error::NonFiniteJson {
                value: n.to_string(),
            })
        }
    }

    pub fn arr_f64(xs: &[f64]) -> Value {
        Value::Arr(xs.iter().map(|&x| Value::Num(x)).collect())
    }

    pub fn arr_usize(xs: &[usize]) -> Value {
        Value::Arr(xs.iter().map(|&x| Value::Num(x as f64)).collect())
    }

    pub fn vec_f64(&self) -> Result<Vec<f64>> {
        self.as_arr()?.iter().map(Value::as_f64).collect()
    }

    pub fn vec_usize(&self) -> Result<Vec<usize>> {
        self.as_arr()?.iter().map(Value::as_usize).collect()
    }

    // --- writer ----------------------------------------------------------

    fn write(&self, out: &mut String) {
        match self {
            Value::Null => out.push_str("null"),
            Value::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Value::Num(n) => {
                // JSON has no NaN/Infinity tokens; `{n}` would print them
                // literally and corrupt the document. Null-encode instead
                // (the lossy-but-valid fallback; use [`Value::finite_num`]
                // to reject non-finite values up front).
                if !n.is_finite() {
                    out.push_str("null");
                } else if n.fract() == 0.0 && n.abs() < 9.0e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Value::Str(s) => write_escaped(out, s),
            Value::Arr(v) => {
                out.push('[');
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    x.write(out);
                }
                out.push(']');
            }
            Value::Obj(m) => {
                out.push('{');
                for (i, (k, x)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(out, k);
                    out.push(':');
                    x.write(out);
                }
                out.push('}');
            }
        }
    }
}

/// Compact serialization (so `value.to_string()` is the wire format).
impl std::fmt::Display for Value {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let mut s = String::new();
        self.write(&mut s);
        f.write_str(&s)
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for ch in s.chars() {
        match ch {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> Error {
        Error::Json(format!("JSON parse error at byte {}: {msg}", self.i))
    }

    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<()> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected {:?}", c as char)))
        }
    }

    fn lit(&mut self, s: &str, v: Value) -> Result<Value> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected {s}")))
        }
    }

    fn value(&mut self) -> Result<Value> {
        match self.peek().ok_or_else(|| self.err("unexpected end"))? {
            b'n' => self.lit("null", Value::Null),
            b't' => self.lit("true", Value::Bool(true)),
            b'f' => self.lit("false", Value::Bool(false)),
            b'"' => Ok(Value::Str(self.string()?)),
            b'[' => self.array(),
            b'{' => self.object(),
            b'-' | b'0'..=b'9' => self.number(),
            c => Err(self.err(&format!("unexpected {:?}", c as char))),
        }
    }

    fn string(&mut self) -> Result<String> {
        self.eat(b'"')?;
        let mut out = String::new();
        loop {
            let c = self.peek().ok_or_else(|| self.err("unterminated string"))?;
            self.i += 1;
            match c {
                b'"' => return Ok(out),
                b'\\' => {
                    let e = self.peek().ok_or_else(|| self.err("bad escape"))?;
                    self.i += 1;
                    match e {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'n' => out.push('\n'),
                        b't' => out.push('\t'),
                        b'r' => out.push('\r'),
                        b'b' => out.push('\u{0008}'),
                        b'f' => out.push('\u{000C}'),
                        b'u' => {
                            if self.i + 4 > self.b.len() {
                                return Err(self.err("short \\u escape"));
                            }
                            let hex = std::str::from_utf8(&self.b[self.i..self.i + 4])
                                .map_err(|_| self.err("bad \\u escape"))?;
                            let code = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            self.i += 4;
                            out.push(char::from_u32(code).unwrap_or('\u{FFFD}'));
                        }
                        _ => return Err(self.err("unknown escape")),
                    }
                }
                _ => {
                    // Re-sync to char boundary for multi-byte UTF-8.
                    let start = self.i - 1;
                    let mut end = self.i;
                    while end < self.b.len() && (self.b[end] & 0xC0) == 0x80 {
                        end += 1;
                    }
                    let chunk = std::str::from_utf8(&self.b[start..end])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    out.push_str(chunk);
                    self.i = end;
                }
            }
        }
    }

    fn number(&mut self) -> Result<Value> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(
            self.peek(),
            Some(b'0'..=b'9') | Some(b'.') | Some(b'e') | Some(b'E') | Some(b'+') | Some(b'-')
        ) {
            self.i += 1;
        }
        let text = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        text.parse::<f64>()
            .map(Value::Num)
            .map_err(|_| self.err(&format!("bad number {text:?}")))
    }

    fn array(&mut self) -> Result<Value> {
        self.eat(b'[')?;
        let mut v = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Value::Arr(v));
        }
        loop {
            self.ws();
            v.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Value::Arr(v));
                }
                _ => return Err(self.err("expected , or ]")),
            }
        }
    }

    fn object(&mut self) -> Result<Value> {
        self.eat(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Value::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.eat(b':')?;
            self.ws();
            let v = self.value()?;
            m.insert(k, v);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Value::Obj(m));
                }
                _ => return Err(self.err("expected , or }")),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_nested() {
        let text = r#"{"a": [1, 2.5, -3e2], "b": {"c": "hi\nthere", "d": null}, "e": true}"#;
        let v = Value::parse(text).unwrap();
        assert_eq!(v.get("a").unwrap().vec_f64().unwrap(), vec![1.0, 2.5, -300.0]);
        assert_eq!(v.get("b").unwrap().get("c").unwrap().as_str().unwrap(), "hi\nthere");
        assert!(v.get("e").unwrap().as_bool().unwrap());
        // Round-trip through the writer.
        let v2 = Value::parse(&v.to_string()).unwrap();
        assert_eq!(v, v2);
    }

    #[test]
    fn parses_unicode_and_escapes() {
        let v = Value::parse(r#""café ☕""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "café ☕");
        let v2 = Value::parse(&v.to_string()).unwrap();
        assert_eq!(v, v2);
    }

    #[test]
    fn integer_formatting_is_exact() {
        let v = Value::Num(67108864.0);
        assert_eq!(v.to_string(), "67108864");
    }

    #[test]
    fn errors_are_positional() {
        let e = Value::parse("{\"a\": }").unwrap_err().to_string();
        assert!(e.contains("byte 6"), "{e}");
        assert!(Value::parse("[1, 2,]").is_err());
        assert!(Value::parse("[1] trailing").is_err());
    }

    #[test]
    fn accessor_errors() {
        let v = Value::parse("{\"n\": 1.5}").unwrap();
        assert!(v.get("n").unwrap().as_usize().is_err());
        assert!(v.get("missing").is_err());
        assert!(v.get_opt("missing").is_none());
    }

    #[test]
    fn non_finite_numbers_null_encode() {
        // `{n}` on NaN/inf would emit bare `NaN`/`inf` tokens — not JSON.
        assert_eq!(Value::Num(f64::NAN).to_string(), "null");
        assert_eq!(Value::Num(f64::INFINITY).to_string(), "null");
        assert_eq!(Value::Num(f64::NEG_INFINITY).to_string(), "null");
        // A document carrying one stays valid and round-trips; the bad
        // field comes back as Null, so optional lookups see it as absent.
        let doc = Value::obj(vec![("ok", Value::Num(1.5)), ("bad", Value::Num(f64::NAN))]);
        let text = doc.to_string();
        let back = Value::parse(&text).expect(&text);
        assert_eq!(back.get("ok").unwrap().as_f64().unwrap(), 1.5);
        assert_eq!(back.get("bad").unwrap(), &Value::Null);
        assert!(back.get_opt("bad").is_none());
    }

    #[test]
    fn finite_num_rejects_non_finite_with_typed_error() {
        assert_eq!(Value::finite_num(2.5).unwrap(), Value::Num(2.5));
        for bad in [f64::NAN, f64::INFINITY, f64::NEG_INFINITY] {
            match Value::finite_num(bad) {
                Err(Error::NonFiniteJson { value }) => {
                    assert_eq!(value, bad.to_string());
                }
                other => panic!("expected NonFiniteJson, got {other:?}"),
            }
        }
        let msg = Value::finite_num(f64::NAN).unwrap_err().to_string();
        assert!(msg.contains("cannot be encoded as JSON"), "{msg}");
    }
}

#[cfg(test)]
mod prop_tests {
    use super::*;
    use crate::util::prop::check;
    use crate::util::rng::Rng;

    fn random_value(rng: &mut Rng, depth: usize) -> Value {
        match rng.range_usize(0, if depth == 0 { 4 } else { 6 }) {
            0 => Value::Null,
            1 => Value::Bool(rng.ratio(1, 2)),
            2 => Value::Num((rng.f64() * 2e6 - 1e6).round() / 8.0),
            3 => {
                let n = rng.range_usize(0, 12);
                Value::Str(
                    (0..n)
                        .map(|_| {
                            char::from_u32(rng.range_usize(32, 0x250) as u32).unwrap_or('x')
                        })
                        .collect(),
                )
            }
            4 => Value::Arr(
                (0..rng.range_usize(0, 4))
                    .map(|_| random_value(rng, depth - 1))
                    .collect(),
            ),
            _ => Value::Obj(
                (0..rng.range_usize(0, 4))
                    .map(|i| (format!("k{i}"), random_value(rng, depth - 1)))
                    .collect(),
            ),
        }
    }

    #[test]
    fn prop_roundtrip_random_documents() {
        check("json roundtrip", 60, 0x15, |rng| {
            let v = random_value(rng, 3);
            let text = v.to_string();
            let back = Value::parse(&text).expect(&text);
            assert_eq!(v, back, "{text}");
        });
    }
}
