//! Unique temp directories for tests (offline stand-in for tempfile):
//! created under `std::env::temp_dir()`, removed on drop.

use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};

static COUNTER: AtomicU64 = AtomicU64::new(0);

/// A directory deleted when dropped.
pub struct TempDir {
    path: PathBuf,
}

impl TempDir {
    pub fn new() -> std::io::Result<TempDir> {
        let n = COUNTER.fetch_add(1, Ordering::Relaxed);
        let path = std::env::temp_dir().join(format!(
            "pccl-test-{}-{}-{n}",
            std::process::id(),
            std::time::SystemTime::now()
                .duration_since(std::time::UNIX_EPOCH)
                .map(|d| d.subsec_nanos())
                .unwrap_or(0)
        ));
        std::fs::create_dir_all(&path)?;
        Ok(TempDir { path })
    }

    pub fn path(&self) -> &Path {
        &self.path
    }
}

impl Drop for TempDir {
    fn drop(&mut self) {
        let _ = std::fs::remove_dir_all(&self.path);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn create_write_cleanup() {
        let keep;
        {
            let d = TempDir::new().unwrap();
            keep = d.path().to_path_buf();
            std::fs::write(d.path().join("x.txt"), "hello").unwrap();
            assert!(keep.join("x.txt").is_file());
        }
        assert!(!keep.exists(), "dir should be removed on drop");
    }

    #[test]
    fn dirs_are_unique() {
        let a = TempDir::new().unwrap();
        let b = TempDir::new().unwrap();
        assert_ne!(a.path(), b.path());
    }
}
