//! Tiny CLI argument parser: subcommand + `--flag value` / `--flag` pairs
//! with typed accessors and helpful errors. Powers the `pccl` binary.

use std::collections::BTreeMap;

/// Parsed arguments: positionals plus `--key value` options.
#[derive(Debug, Clone, Default)]
pub struct Args {
    pub positional: Vec<String>,
    options: BTreeMap<String, String>,
    flags: Vec<String>,
}

impl Args {
    /// Parse from an iterator of raw args (without argv[0]).
    pub fn parse(raw: impl IntoIterator<Item = String>) -> Result<Args, String> {
        let mut args = Args::default();
        let mut it = raw.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(key) = a.strip_prefix("--") {
                if key.is_empty() {
                    return Err("bare -- is not supported".into());
                }
                if let Some((k, v)) = key.split_once('=') {
                    args.options.insert(k.to_string(), v.to_string());
                } else if it
                    .peek()
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = it.next().unwrap();
                    args.options.insert(key.to_string(), v);
                } else {
                    args.flags.push(key.to_string());
                }
            } else {
                args.positional.push(a);
            }
        }
        Ok(args)
    }

    pub fn from_env() -> Result<Args, String> {
        Self::parse(std::env::args().skip(1))
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.options.get(key).map(String::as_str)
    }

    pub fn has_flag(&self, key: &str) -> bool {
        self.flags.iter().any(|f| f == key)
    }

    /// Typed option with default.
    pub fn get_parse<T: std::str::FromStr>(&self, key: &str, default: T) -> Result<T, String> {
        match self.options.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| format!("invalid value for --{key}: {v:?}")),
        }
    }

    /// Reject unknown options (catch typos).
    pub fn expect_known(&self, known: &[&str]) -> Result<(), String> {
        for k in self.options.keys().chain(self.flags.iter()) {
            if !known.contains(&k.as_str()) {
                return Err(format!(
                    "unknown option --{k} (known: {})",
                    known.join(", ")
                ));
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from)).unwrap()
    }

    #[test]
    fn positionals_options_flags() {
        let a = parse("figures fig1 --out results --trials 5 --verbose");
        assert_eq!(a.positional, vec!["figures", "fig1"]);
        assert_eq!(a.get("out"), Some("results"));
        assert_eq!(a.get_parse("trials", 10usize).unwrap(), 5);
        assert!(a.has_flag("verbose"));
        assert!(!a.has_flag("quiet"));
    }

    #[test]
    fn equals_syntax() {
        let a = parse("bench --ranks=16 --size-kb=64");
        assert_eq!(a.get_parse("ranks", 0usize).unwrap(), 16);
        assert_eq!(a.get_parse("size-kb", 0usize).unwrap(), 64);
    }

    #[test]
    fn defaults_and_bad_values() {
        let a = parse("x");
        assert_eq!(a.get_parse("missing", 42i32).unwrap(), 42);
        let a = parse("x --n abc");
        assert!(a.get_parse("n", 0i32).is_err());
    }

    #[test]
    fn unknown_option_rejected() {
        let a = parse("x --tyop 3");
        assert!(a.expect_known(&["typo"]).is_err());
        assert!(a.expect_known(&["tyop"]).is_ok());
    }
}
