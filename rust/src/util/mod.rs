//! In-tree substrates for a fully offline build: deterministic RNG,
//! JSON, bf16, CLI parsing, a micro-benchmark harness, a property-testing
//! helper, and temp-dir management. (The build environment ships only the
//! `xla` bindings; everything else is built here, per the from-scratch
//! mandate.)

pub mod bf16;
pub mod cli;
pub mod json;
pub mod microbench;
pub mod prop;
pub mod rng;
pub mod tmp;
