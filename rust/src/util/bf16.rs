//! bfloat16 — the training dtype of the paper's workloads, implemented as
//! a truncated-f32 wrapper (round-to-nearest-even on conversion).

/// A bfloat16 value (1 sign, 8 exponent, 7 mantissa bits).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, Default)]
pub struct Bf16(pub u16);

impl Bf16 {
    pub const ZERO: Bf16 = Bf16(0);

    /// Round-to-nearest-even conversion from f32.
    pub fn from_f32(v: f32) -> Self {
        let bits = v.to_bits();
        if v.is_nan() {
            // Preserve NaN, force a quiet mantissa bit.
            return Bf16(((bits >> 16) as u16) | 0x0040);
        }
        let round_bit = 0x0000_8000u32;
        let lsb = (bits >> 16) & 1;
        let rounded = bits.wrapping_add(0x0000_7FFF + lsb);
        let _ = round_bit;
        Bf16((rounded >> 16) as u16)
    }

    pub fn to_f32(self) -> f32 {
        f32::from_bits((self.0 as u32) << 16)
    }
}

impl From<f32> for Bf16 {
    fn from(v: f32) -> Self {
        Bf16::from_f32(v)
    }
}

impl From<Bf16> for f32 {
    fn from(v: Bf16) -> f32 {
        v.to_f32()
    }
}

impl std::fmt::Display for Bf16 {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}", self.to_f32())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exact_small_integers_roundtrip() {
        for i in -256..=256 {
            let v = i as f32;
            assert_eq!(Bf16::from_f32(v).to_f32(), v, "{v}");
        }
    }

    #[test]
    fn rounding_is_nearest_even() {
        // 1.0 + 2^-8 is exactly between bf16(1.0) and the next value
        // 1.0078125; nearest-even rounds down to 1.0.
        let v = 1.0f32 + 2f32.powi(-8);
        assert_eq!(Bf16::from_f32(v).to_f32(), 1.0);
        // Slightly above the midpoint rounds up.
        let v = 1.0f32 + 2f32.powi(-8) + 2f32.powi(-12);
        assert_eq!(Bf16::from_f32(v).to_f32(), 1.0078125);
    }

    #[test]
    fn specials() {
        assert!(Bf16::from_f32(f32::NAN).to_f32().is_nan());
        assert_eq!(Bf16::from_f32(f32::INFINITY).to_f32(), f32::INFINITY);
        assert_eq!(Bf16::from_f32(-0.0).to_f32(), 0.0);
        assert!(Bf16::from_f32(-0.0).to_f32().is_sign_negative());
    }

    #[test]
    fn relative_error_bounded() {
        let mut x = 0.001f32;
        while x < 1e6 {
            let rt = Bf16::from_f32(x).to_f32();
            let rel = ((rt - x) / x).abs();
            assert!(rel <= 0.00391 + 1e-7, "x={x} rt={rt} rel={rel}");
            x *= 1.7;
        }
    }
}
