//! Message-size distributions of sharded/distributed data parallelism
//! (Fig. 2): what sizes do FSDP, DeepSpeed ZeRO-3, AxoNN, and PyTorch DDP
//! actually put on the wire for a given model?

use super::transformer::TransformerConfig;

/// Framework whose communication pattern is modeled.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Framework {
    /// PyTorch FSDP: one all-gather / reduce-scatter per FSDP unit
    /// (= transformer block), bf16.
    Fsdp,
    /// DeepSpeed ZeRO-3: parameter gathers coalesced toward its default
    /// ~0.5 GB prefetch bucket, bf16.
    Zero3,
    /// AxoNN: one collective per *linear layer* — a wide range of sizes.
    Axonn,
    /// PyTorch DDP: gradient all-reduce buckets (48–80 MB observed, §II-A).
    Ddp,
}

impl Framework {
    pub fn label(self) -> &'static str {
        match self {
            Framework::Fsdp => "FSDP",
            Framework::Zero3 => "ZeRO-3",
            Framework::Axonn => "AxoNN",
            Framework::Ddp => "DDP",
        }
    }
}

/// One framework × model message-size distribution.
#[derive(Debug, Clone)]
pub struct MsgDistribution {
    pub framework: &'static str,
    pub model: &'static str,
    /// Per-collective message sizes in bytes (all-gather input / RS output
    /// convention of Fig. 2).
    pub sizes: Vec<usize>,
}

impl MsgDistribution {
    pub fn min(&self) -> usize {
        self.sizes.iter().copied().min().unwrap_or(0)
    }

    pub fn max(&self) -> usize {
        self.sizes.iter().copied().max().unwrap_or(0)
    }

    pub fn median(&self) -> usize {
        if self.sizes.is_empty() {
            return 0;
        }
        let mut v = self.sizes.clone();
        v.sort_unstable();
        v[v.len() / 2]
    }

    pub fn total(&self) -> usize {
        self.sizes.iter().sum()
    }
}

const BF16: usize = 2;
const F32: usize = 4;

/// Model the per-step collective message sizes of `framework` training
/// `config` (Fig. 2).
pub fn message_sizes(framework: Framework, config: &TransformerConfig) -> MsgDistribution {
    let sizes = match framework {
        Framework::Fsdp => {
            // One unit per block + the embedding unit.
            let mut v = vec![config.block_params() * BF16; config.layers];
            v.push(config.vocab * config.hidden * BF16);
            v
        }
        Framework::Zero3 => {
            // ZeRO-3 coalesces consecutive parameters up to its prefetch
            // bucket (default ≈ 5e8 elements ≫ a block, but the allgather
            // bucket size caps at ~2e8 elements in practice). Model:
            // groups of blocks up to 200M params each.
            let cap = 200_000_000usize;
            let mut v = Vec::new();
            let mut acc = 0usize;
            for _ in 0..config.layers {
                acc += config.block_params();
                if acc >= cap {
                    v.push(acc * BF16);
                    acc = 0;
                }
            }
            acc += config.vocab * config.hidden;
            if acc > 0 {
                v.push(acc * BF16);
            }
            v
        }
        Framework::Axonn => {
            // Per linear layer, every block.
            let mut v = Vec::new();
            for _ in 0..config.layers {
                for p in config.linear_layer_params() {
                    v.push(p * BF16);
                }
            }
            v.push(config.vocab * config.hidden * BF16);
            v
        }
        Framework::Ddp => {
            // fp32 gradient buckets; PyTorch DDP rebuilds buckets after the
            // first iteration to ~48–80 MB (§II-A). Use 64 MB buckets.
            let bucket = 64 << 20;
            let total = config.param_count() * F32;
            let n = total.div_ceil(bucket);
            let mut v = vec![bucket; n.saturating_sub(1)];
            v.push(total - bucket * n.saturating_sub(1));
            v
        }
    };
    MsgDistribution {
        framework: framework.label(),
        model: config.name,
        sizes,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::transformer::{GPT_1_3B, GPT_7B};

    const MB: usize = 1 << 20;

    #[test]
    fn fig2_sizes_are_tens_to_hundreds_of_mb() {
        // The paper's observation: DL collective messages are 10s–100s MB.
        for fw in [Framework::Fsdp, Framework::Zero3, Framework::Axonn] {
            let d = message_sizes(fw, &GPT_7B);
            assert!(
                d.median() > 10 * MB,
                "{} median {} too small",
                d.framework,
                d.median()
            );
            assert!(d.max() < 2048 * MB, "{} max too large", d.framework);
        }
    }

    #[test]
    fn axonn_has_wider_range_than_fsdp() {
        let ax = message_sizes(Framework::Axonn, &GPT_7B);
        let fs = message_sizes(Framework::Fsdp, &GPT_7B);
        let spread = |d: &MsgDistribution| d.max() as f64 / d.min() as f64;
        assert!(spread(&ax) > spread(&fs));
    }

    #[test]
    fn ddp_buckets_in_observed_range() {
        let d = message_sizes(Framework::Ddp, &GPT_1_3B);
        // All but the tail bucket are exactly 64 MB; total = 4·params.
        assert!(d.sizes[..d.sizes.len() - 1].iter().all(|&s| s == 64 * MB));
        assert_eq!(d.total(), GPT_1_3B.param_count() * 4);
    }

    #[test]
    fn volume_conservation() {
        // FSDP + embedding covers every parameter exactly once.
        let d = message_sizes(Framework::Fsdp, &GPT_7B);
        let covered: usize = d.total() / BF16;
        let expect = GPT_7B.layers * GPT_7B.block_params() + GPT_7B.vocab * GPT_7B.hidden;
        assert_eq!(covered, expect);
    }
}
