//! GPT-style transformer configurations (Table II) and parameter
//! accounting used by the message-size and step-time models.

/// Architecture hyperparameters of a GPT-style decoder (Table II; the
/// hyperparameters come from Zhang et al. / OPT).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TransformerConfig {
    pub name: &'static str,
    pub layers: usize,
    pub hidden: usize,
    pub heads: usize,
    pub vocab: usize,
    pub seq: usize,
}

/// GPT-7B (ZeRO-3 experiments, Fig. 12).
pub const GPT_7B: TransformerConfig = TransformerConfig {
    name: "GPT-7B",
    layers: 32,
    hidden: 4096,
    heads: 32,
    vocab: 50272,
    seq: 2048,
};

/// GPT-13B (ZeRO-3 experiments, Fig. 12).
pub const GPT_13B: TransformerConfig = TransformerConfig {
    name: "GPT-13B",
    layers: 40,
    hidden: 5120,
    heads: 40,
    vocab: 50272,
    seq: 2048,
};

/// GPT-1.3B (DDP experiments, Fig. 13).
pub const GPT_1_3B: TransformerConfig = TransformerConfig {
    name: "GPT-1.3B",
    layers: 24,
    hidden: 2048,
    heads: 32,
    vocab: 50272,
    seq: 2048,
};

impl TransformerConfig {
    /// Parameters in one transformer block: attention (QKV + output
    /// projection) + 4× MLP + layer norms.
    pub fn block_params(&self) -> usize {
        let h = self.hidden;
        // qkv: 3h², attn out: h², mlp: 4h² + 4h², biases/norms ≈ 13h
        12 * h * h + 13 * h
    }

    /// Total parameters (blocks + embeddings + final norm).
    pub fn param_count(&self) -> usize {
        self.layers * self.block_params() + self.vocab * self.hidden + 2 * self.hidden
    }

    /// The per-linear-layer weight shapes AxoNN communicates separately
    /// (Fig. 2's wide distribution): qkv, attn-proj, mlp-up, mlp-down.
    pub fn linear_layer_params(&self) -> Vec<usize> {
        let h = self.hidden;
        vec![3 * h * h, h * h, 4 * h * h, 4 * h * h]
    }

    /// Approximate training flops per token (the standard 6·P estimate:
    /// forward 2·P, backward 4·P).
    pub fn flops_per_token(&self) -> f64 {
        6.0 * self.param_count() as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn param_counts_match_model_names() {
        // Within 15% of the nominal size.
        let b = 1.0e9;
        let p7 = GPT_7B.param_count() as f64;
        let p13 = GPT_13B.param_count() as f64;
        let p13b = GPT_1_3B.param_count() as f64;
        assert!((p7 / (6.9 * b) - 1.0).abs() < 0.15, "7B → {p7}");
        assert!((p13 / (13.0 * b) - 1.0).abs() < 0.15, "13B → {p13}");
        assert!((p13b / (1.3 * b) - 1.0).abs() < 0.15, "1.3B → {p13b}");
    }

    #[test]
    fn linear_layers_sum_close_to_block() {
        let lin: usize = GPT_7B.linear_layer_params().iter().sum();
        assert!(lin <= GPT_7B.block_params());
        assert!(lin * 10 >= GPT_7B.block_params() * 9);
    }
}
