//! Analytic step-time model for the strong-scaling figures (Figs. 12–13):
//! compute time from the 6·P flop estimate, communication time from the
//! netsim library models, partial overlap between the two.

use crate::backends::CollKind;
use crate::error::Result;
use crate::netsim::libmodel::{simulate, LibModel};
use crate::topology::Machine;

use super::msgsizes::{message_sizes, Framework};
use super::transformer::TransformerConfig;

/// Achievable fraction of peak matmul throughput in mixed-precision
/// training (MFU): the paper's frameworks land in the 30–45% range.
const MFU: f64 = 0.38;
/// Fraction of communication hidden behind compute (prefetch in ZeRO-3,
/// bucketed overlap in DDP).
const OVERLAP: f64 = 0.5;

/// Breakdown of one training step.
#[derive(Debug, Clone)]
pub struct StepTime {
    pub compute_s: f64,
    pub comm_s: f64,
    /// Total with partial overlap: `compute + max(0, comm - OVERLAP·compute)`.
    pub total_s: f64,
}

fn combine(compute_s: f64, comm_s: f64) -> StepTime {
    let exposed = (comm_s - OVERLAP * compute_s).max(0.0);
    StepTime {
        compute_s,
        comm_s,
        total_s: compute_s + exposed,
    }
}

/// Per-GPU compute time for one step at `global_batch_tokens`.
fn compute_time(machine: Machine, cfg: &TransformerConfig, ranks: usize, tokens: usize) -> f64 {
    let mp = machine.params();
    let tokens_per_gpu = tokens as f64 / ranks as f64;
    cfg.flops_per_token() * tokens_per_gpu / (mp.gpu_flops * MFU)
}

/// ZeRO-3 step (Fig. 12): all-gather parameters for forward and backward,
/// reduce-scatter gradients — one collective per ZeRO-3 message-size bucket.
pub fn zero3_step(
    machine: Machine,
    lib: LibModel,
    cfg: &TransformerConfig,
    ranks: usize,
    global_batch_tokens: usize,
) -> Result<StepTime> {
    let compute = compute_time(machine, cfg, ranks, global_batch_tokens);
    let dist = message_sizes(Framework::Zero3, cfg);
    let mut comm = 0.0;
    for &msg in &dist.sizes {
        // Forward all-gather + backward all-gather (paper §II-A: gather the
        // full copy from shards) ...
        let ag = simulate(machine, lib, CollKind::AllGather, msg, ranks, 1, 17)?
            .stats
            .mean();
        // ... + gradient reduce-scatter (fp32 grads = 2× the bf16 bytes).
        let rs = simulate(machine, lib, CollKind::ReduceScatter, msg * 2, ranks, 1, 18)?
            .stats
            .mean();
        comm += 2.0 * ag + rs;
    }
    Ok(combine(compute, comm))
}

/// DDP step (Fig. 13): bucketed gradient all-reduce.
pub fn ddp_step(
    machine: Machine,
    lib: LibModel,
    cfg: &TransformerConfig,
    ranks: usize,
    global_batch_tokens: usize,
) -> Result<StepTime> {
    let compute = compute_time(machine, cfg, ranks, global_batch_tokens);
    let dist = message_sizes(Framework::Ddp, cfg);
    let mut comm = 0.0;
    for &msg in &dist.sizes {
        comm += simulate(machine, lib, CollKind::AllReduce, msg, ranks, 1, 19)?
            .stats
            .mean();
    }
    Ok(combine(compute, comm))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::transformer::{GPT_1_3B, GPT_7B};

    #[test]
    fn fig12_crossover_on_frontier() {
        // At 128 GCDs vendor and PCCL are comparable; at 1024–2048 PCCL
        // wins clearly (paper: 2.5× at 1024, 3.3–4.9× at 2048).
        let tokens = 4_000_000; // 4M-token global batch (§V-B)
        let t = |lib, p| {
            zero3_step(Machine::Frontier, lib, &GPT_7B, p, tokens)
                .unwrap()
                .total_s
        };
        let small_ratio = t(LibModel::Vendor, 128) / t(LibModel::PcclRec, 128);
        let large_ratio = t(LibModel::Vendor, 2048) / t(LibModel::PcclRec, 2048);
        assert!(
            (0.5..2.0).contains(&small_ratio),
            "comparable at 128: {small_ratio:.2}"
        );
        assert!(
            large_ratio > 2.0,
            "pccl must win big at 2048: {large_ratio:.2}"
        );
        assert!(large_ratio > small_ratio);
    }

    #[test]
    fn fig13_ddp_crossover() {
        // Paper: RCCL wins at 128–256 GCDs (0.55×/0.80×), PCCL wins at
        // 1024–2048 (1.8×/2.4×).
        let tokens = 1_000_000;
        let t = |lib, p| {
            ddp_step(Machine::Frontier, lib, &GPT_1_3B, p, tokens)
                .unwrap()
                .total_s
        };
        let at256 = t(LibModel::Vendor, 256) / t(LibModel::PcclRing, 256);
        let at2048 = t(LibModel::Vendor, 2048) / t(LibModel::PcclRec, 2048);
        assert!(at256 < 1.4, "vendor should be competitive at 256: {at256:.2}");
        assert!(at2048 > 1.3, "pccl should win at 2048: {at2048:.2}");
    }

    #[test]
    fn compute_shrinks_with_ranks() {
        let a = compute_time(Machine::Frontier, &GPT_7B, 128, 4_000_000);
        let b = compute_time(Machine::Frontier, &GPT_7B, 256, 4_000_000);
        assert!((a / b - 2.0).abs() < 1e-9);
    }
}
