//! DL workload models: message-size distributions (Fig. 2), transformer
//! configurations (Table II), and the analytic step-time model behind the
//! ZeRO-3 / DDP strong-scaling figures (Figs. 12–13).

pub mod msgsizes;
pub mod steptime;
pub mod transformer;

pub use transformer::{TransformerConfig, GPT_13B, GPT_1_3B, GPT_7B};
