//! Generators for every figure/table of the paper's evaluation
//! (DESIGN.md §4 maps each to its modules). Each returns [`Table`]s whose
//! rows are the series the paper plots; the CLI prints them and writes CSV.

use crate::backends::CollKind;
use crate::dispatch::SvmDispatcher;
use crate::error::Result;
use crate::metrics::Stats;
use crate::netsim::libmodel::{schedule, simulate, LibModel};
use crate::netsim::NicCounters;
use crate::topology::Machine;
use crate::workload::msgsizes::{message_sizes, Framework};
use crate::workload::steptime::{ddp_step, zero3_step};
use crate::workload::transformer::{TransformerConfig, GPT_13B, GPT_1_3B, GPT_7B};

use super::Table;

const MB: usize = 1 << 20;
const TRIALS: usize = 10;
const SEED: u64 = 0xF16;

fn sim_cell(
    table: &mut Table,
    machine: Machine,
    lib: LibModel,
    kind: CollKind,
    msg: usize,
    ranks: usize,
) -> Result<()> {
    let out = simulate(machine, lib, kind, msg, ranks, TRIALS, SEED)?;
    // Record the modeled per-node NIC write volume next to the timing so
    // the CSV artifacts carry bytes moved per collective.
    let moved = out.counters.posted_bytes();
    table.push_with_bytes(lib.label(machine), msg, ranks, out.stats, moved);
    Ok(())
}

/// Fig. 1: all-gather scaling of RCCL (Frontier), Cray-MPICH (Frontier),
/// NCCL (Perlmutter) at 64/128 MB output buffers.
pub fn fig1() -> Result<Table> {
    let mut t = Table::new("Fig 1: all-gather time vs processes (64/128 MB)");
    for &msg in &[64 * MB, 128 * MB] {
        for &p in &[64, 128, 256, 512, 1024, 2048] {
            sim_cell(&mut t, Machine::Frontier, LibModel::Vendor, CollKind::AllGather, msg, p)?;
            sim_cell(&mut t, Machine::Frontier, LibModel::CrayMpich, CollKind::AllGather, msg, p)?;
            sim_cell(&mut t, Machine::Perlmutter, LibModel::Vendor, CollKind::AllGather, msg, p)?;
        }
    }
    Ok(t)
}

/// Fig. 2: message-size distributions per framework and model size.
pub fn fig2() -> Vec<(String, String, usize, usize, usize, usize)> {
    let mut rows = Vec::new();
    let configs: [&TransformerConfig; 3] = [&GPT_1_3B, &GPT_7B, &GPT_13B];
    for cfg in configs {
        for fw in [Framework::Fsdp, Framework::Zero3, Framework::Axonn, Framework::Ddp] {
            let d = message_sizes(fw, cfg);
            rows.push((
                d.framework.to_string(),
                d.model.to_string(),
                d.sizes.len(),
                d.min(),
                d.median(),
                d.max(),
            ));
        }
    }
    rows
}

/// Fig. 3: Cray-MPICH vs RCCL all-gather at small scale (left) plus the
/// per-NIC read/write packet counters (middle, right).
pub fn fig3() -> Result<(Table, Vec<(String, NicCounters)>)> {
    let mut t = Table::new("Fig 3: Cray-MPICH vs RCCL all-gather, 256/512 MB, small scale");
    let mut counters = Vec::new();
    for &msg in &[256 * MB, 512 * MB] {
        for &p in &[8, 16, 32, 64] {
            sim_cell(&mut t, Machine::Frontier, LibModel::CrayMpich, CollKind::AllGather, msg, p)?;
            sim_cell(&mut t, Machine::Frontier, LibModel::Vendor, CollKind::AllGather, msg, p)?;
        }
    }
    for lib in [LibModel::CrayMpich, LibModel::Vendor] {
        let (_, c, _) = schedule(Machine::Frontier, lib, CollKind::AllGather, 256 * MB, 64)?;
        counters.push((lib.label(Machine::Frontier), c));
    }
    Ok((t, counters))
}

/// Fig. 4: reduce-scatter — Cray-MPICH vs RCCL vs the custom
/// MPI-p2p + GPU-kernel implementation.
pub fn fig4() -> Result<Table> {
    let mut t = Table::new("Fig 4: reduce-scatter, Cray-MPICH vs RCCL vs custom p2p+GPU");
    for &msg in &[256 * MB, 512 * MB] {
        for &p in &[8, 16, 32, 64] {
            for lib in [LibModel::CrayMpich, LibModel::Vendor, LibModel::Custom] {
                sim_cell(&mut t, Machine::Frontier, lib, CollKind::ReduceScatter, msg, p)?;
            }
        }
    }
    Ok(t)
}

/// Fig. 6: speedup heatmap of recursive halving over ring for the
/// inter-node phase of reduce-scatter.
pub fn fig6() -> Result<Table> {
    let mut t = Table::new("Fig 6: rec-halving/ring speedup heatmap (reduce-scatter)");
    for &mb in &[1usize, 4, 16, 64, 256, 1024] {
        for &p in &[8usize, 32, 128, 512, 2048] {
            let rs = CollKind::ReduceScatter;
            let ring =
                simulate(Machine::Frontier, LibModel::PcclRing, rs, mb * MB, p, TRIALS, SEED)?;
            let rec = simulate(Machine::Frontier, LibModel::PcclRec, rs, mb * MB, p, TRIALS, SEED)?;
            // Encode the speedup as "mean" of a one-sample stat.
            t.push(
                "rec_over_ring",
                mb * MB,
                p,
                Stats::from_iter([ring.stats.mean() / rec.stats.mean()]),
            );
        }
    }
    Ok(t)
}

/// Table I: SVM dispatcher test accuracy per machine × collective.
pub fn table1(trials: usize) -> Result<Vec<(String, String, usize, usize, f64)>> {
    let sizes: Vec<usize> = vec![1, 2, 4, 8, 16, 32, 64, 128, 256, 512, 1024];
    let ranks: Vec<usize> = vec![4, 8, 16, 32, 64, 128, 256, 512, 1024, 2048];
    let mut rows = Vec::new();
    for machine in [Machine::Frontier, Machine::Perlmutter] {
        // Perlmutter's smallest deployment is 2 nodes × 4 GPUs.
        let ranks: Vec<usize> = ranks
            .iter()
            .copied()
            .filter(|&p| p >= machine.params().gpus_per_node)
            .collect();
        let d = SvmDispatcher::train(machine, &sizes, &ranks, trials, SEED)?;
        for (coll, test_size, correct, acc) in d.table1() {
            rows.push((machine.params().name.to_string(), coll, test_size, correct, acc));
        }
    }
    Ok(rows)
}

/// Figs. 8 & 10: line plots — Cray-MPICH vs vendor vs PCCL-adaptive for
/// all three collectives on one machine.
pub fn fig8_or_10(machine: Machine) -> Result<Table> {
    let name = machine.params().name;
    let mut t = Table::new(format!(
        "Fig {}: collectives vs process count on {name}",
        if machine == Machine::Frontier { 10 } else { 8 }
    ));
    let dispatcher = trained_dispatcher(machine)?;
    for (kind, sizes) in [
        (CollKind::AllGather, [256 * MB, 512 * MB]),
        (CollKind::ReduceScatter, [256 * MB, 512 * MB]),
        (CollKind::AllReduce, [64 * MB, 128 * MB]),
    ] {
        for &msg in &sizes {
            for &p in &[32, 64, 128, 256, 512, 1024, 2048] {
                sim_cell(&mut t, machine, LibModel::CrayMpich, kind, msg, p)?;
                sim_cell(&mut t, machine, LibModel::Vendor, kind, msg, p)?;
                // PCCL with adaptive dispatch.
                let backend = dispatcher.choose(kind, msg, p);
                let lib = LibModel::from_backend(backend).unwrap_or(LibModel::PcclRec);
                let out = simulate(machine, lib, kind, msg, p, TRIALS, SEED)?;
                let mut label = String::from("pccl_auto:");
                label.push_str(&format!("{kind:?}"));
                let _ = label;
                t.push("pccl_auto", msg, p, out.stats);
            }
        }
    }
    Ok(t)
}

/// Figs. 9 & 11: speedup heatmaps of PCCL-adaptive over the vendor
/// library across (message size × process count).
pub fn fig9_or_11(machine: Machine) -> Result<Table> {
    let mut t = Table::new(format!(
        "Fig {}: PCCL/vendor speedup heatmap on {}",
        if machine == Machine::Frontier { 11 } else { 9 },
        machine.params().name
    ));
    let dispatcher = trained_dispatcher(machine)?;
    for kind in CollKind::ALL {
        for &mb in &[16usize, 32, 64, 128, 256, 512, 1024] {
            for &p in &[32usize, 64, 128, 256, 512, 1024, 2048] {
                let vendor = simulate(machine, LibModel::Vendor, kind, mb * MB, p, TRIALS, SEED)?;
                let backend = dispatcher.choose(kind, mb * MB, p);
                let lib = LibModel::from_backend(backend).unwrap_or(LibModel::PcclRec);
                let pccl = simulate(machine, lib, kind, mb * MB, p, TRIALS, SEED)?;
                let series = format!("{}-speedup", kind.label());
                t.push(
                    series,
                    mb * MB,
                    p,
                    Stats::from_iter([vendor.stats.mean() / pccl.stats.mean()]),
                );
            }
        }
    }
    Ok(t)
}

/// Fig. 12: ZeRO-3 strong scaling (GPT-7B/13B) on both machines.
pub fn fig12() -> Result<Table> {
    let mut t = Table::new("Fig 12: ZeRO-3 strong scaling batch time (GPT-7B/13B)");
    let tokens = 4_000_000;
    for (machine, ranks) in [
        (Machine::Frontier, vec![128usize, 256, 512, 1024, 2048]),
        (Machine::Perlmutter, vec![256, 512, 1024, 2048]),
    ] {
        for cfg in [&GPT_7B, &GPT_13B] {
            for &p in &ranks {
                for lib in [LibModel::Vendor, LibModel::PcclRec] {
                    let st = zero3_step(machine, lib, cfg, p, tokens)?;
                    let series = format!(
                        "{}/{}/{}",
                        machine.params().name,
                        cfg.name,
                        lib.label(machine)
                    );
                    t.push(series, cfg.param_count(), p, Stats::from_iter([st.total_s]));
                }
            }
        }
    }
    Ok(t)
}

/// Fig. 13: DDP strong scaling (GPT-1.3B) on Frontier.
pub fn fig13() -> Result<Table> {
    let mut t = Table::new("Fig 13: DDP strong scaling batch time (GPT-1.3B, Frontier)");
    let tokens = 1_000_000;
    for &p in &[128usize, 256, 512, 1024, 2048] {
        for lib in [LibModel::Vendor, LibModel::PcclRec] {
            let st = ddp_step(Machine::Frontier, lib, &GPT_1_3B, p, tokens)?;
            t.push(
                format!("frontier/GPT-1.3B/{}", lib.label(Machine::Frontier)),
                GPT_1_3B.param_count(),
                p,
                Stats::from_iter([st.total_s]),
            );
        }
    }
    Ok(t)
}

/// Train (or reuse a cached) dispatcher for figure generation. Uses a
/// medium sweep — enough for the regime boundary to be learned.
pub fn trained_dispatcher(machine: Machine) -> Result<SvmDispatcher> {
    let sizes: Vec<usize> = vec![16, 32, 64, 128, 256, 512, 1024];
    let ranks: Vec<usize> = vec![32, 64, 128, 256, 512, 1024, 2048];
    SvmDispatcher::train(machine, &sizes, &ranks, 3, SEED)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig1_shape_vendor_linear_pccl_absent() {
        let t = fig1().unwrap();
        // RCCL time at 2048 ≫ at 128 for 64 MB (linear latency growth).
        let r128 = t.mean("rccl", 64 * MB, 128).unwrap();
        let r2048 = t.mean("rccl", 64 * MB, 2048).unwrap();
        assert!(r2048 / r128 > 6.0, "ratio {:.1}", r2048 / r128);
    }

    #[test]
    fn fig2_rows_cover_all_frameworks() {
        let rows = fig2();
        assert_eq!(rows.len(), 12);
        assert!(rows.iter().any(|r| r.0 == "AxoNN" && r.1 == "GPT-13B"));
    }

    #[test]
    fn fig4_ordering_cray_worst_custom_between() {
        let t = fig4().unwrap();
        let cray = t.mean("cray-mpich", 512 * MB, 64).unwrap();
        let rccl = t.mean("rccl", 512 * MB, 64).unwrap();
        let custom = t.mean("custom-p2p-gpu", 512 * MB, 64).unwrap();
        assert!(cray > custom && custom > rccl);
    }

    #[test]
    fn fig6_corners() {
        let t = fig6().unwrap();
        // Latency-bound corner (small msg, many ranks): rec wins (>1).
        assert!(t.mean("rec_over_ring", MB, 2048).unwrap() > 1.5);
        // Bandwidth-bound corner: ring competitive (speedup ≤ ~1).
        assert!(t.mean("rec_over_ring", 1024 * MB, 8).unwrap() < 1.3);
    }
}

/// Ablations beyond the paper (DESIGN.md §5): (a) would NCCL's PAT
/// algorithm close the gap if it supported multi-GPU nodes? (b) how much
/// does chunk-pipelining the hierarchy buy? (c) does PCCL still pay off on
/// an InfiniBand cluster without the Cassini overflow pathology?
pub fn ablations() -> Result<Table> {
    let mut t = Table::new("Ablations: PAT / pipelining / InfiniBand");
    // (a) PAT vs PCCL_rec on Frontier, latency-bound regime.
    for &mb in &[16usize, 64, 256] {
        for &p in &[512usize, 2048] {
            for lib in [LibModel::Vendor, LibModel::VendorPat, LibModel::PcclRec] {
                sim_cell(&mut t, Machine::Frontier, lib, CollKind::AllGather, mb * MB, p)?;
            }
        }
    }
    // (b) pipelined vs plain hierarchy, bandwidth-heavy regime where the
    // intra phase is long enough to hide.
    for &mb in &[128usize, 512, 1024] {
        for &p in &[256usize, 2048] {
            for lib in [LibModel::PcclRec, LibModel::PcclRecPipelined] {
                sim_cell(&mut t, Machine::Frontier, lib, CollKind::AllGather, mb * MB, p)?;
            }
        }
    }
    // (c) InfiniBand: vendor vs PCCL (paper future work).
    for &mb in &[16usize, 256] {
        for &p in &[256usize, 2048] {
            for lib in [LibModel::Vendor, LibModel::PcclRec] {
                sim_cell(&mut t, Machine::InfiniBand, lib, CollKind::AllGather, mb * MB, p)?;
            }
        }
    }
    Ok(t)
}

#[cfg(test)]
mod ablation_tests {
    use super::*;

    #[test]
    fn pat_would_fix_vendor_latency_scaling_but_not_nic_spread() {
        let t = ablations().unwrap();
        let rccl = t.mean("rccl", 16 * MB, 2048).unwrap();
        let pat = t.mean("rccl-pat", 16 * MB, 2048).unwrap();
        let pccl = t.mean("pccl_rec", 16 * MB, 2048).unwrap();
        assert!(pat < rccl / 4.0, "PAT should fix the log-latency gap");
        assert!(pccl < pat * 4.0, "PCCL stays competitive with ideal PAT");
    }

    #[test]
    fn pipelining_helps_when_phases_are_comparable() {
        let t = ablations().unwrap();
        let plain = t.mean("pccl_rec", 1024 * MB, 2048).unwrap();
        let piped = t.mean("pccl_rec_pipe4", 1024 * MB, 2048).unwrap();
        assert!(piped < plain, "pipelined {piped} !< plain {plain}");
        assert!(piped > plain * 0.4, "overlap cannot beat the dominant phase");
    }

    #[test]
    fn infiniband_gains_exist_but_are_smaller_than_frontier() {
        let t = ablations().unwrap();
        let v = t.mean("nccl", 16 * MB, 2048);
        // Label on InfiniBand is also "nccl" — disambiguate via fresh sims.
        let _ = v;
        let ag = CollKind::AllGather;
        let sim = |machine, lib| {
            simulate(machine, lib, ag, 16 * MB, 2048, 5, 3)
                .unwrap()
                .stats
                .mean()
        };
        let v = sim(Machine::InfiniBand, LibModel::Vendor);
        let p = sim(Machine::InfiniBand, LibModel::PcclRec);
        let ib_speedup = v / p;
        let vf = sim(Machine::Frontier, LibModel::Vendor);
        let pf = sim(Machine::Frontier, LibModel::PcclRec);
        assert!(ib_speedup > 1.0, "PCCL should still win at scale on IB: {ib_speedup:.2}");
        assert!(ib_speedup < vf / pf, "IB gap must be smaller than Frontier's");
    }
}
