//! Benchmark harness utilities shared by the CLI figure generators and the
//! criterion benches: sweep drivers, row formatting, CSV output.

pub mod figures;

use std::io::Write as _;
use std::path::Path;

use crate::error::Result;
use crate::metrics::Stats;

/// One measured cell of a sweep table.
#[derive(Debug, Clone)]
pub struct Cell {
    /// Row label (e.g. library name).
    pub series: String,
    /// Per-rank message size in bytes.
    pub bytes: usize,
    /// Rank count.
    pub ranks: usize,
    /// Trial statistics (seconds).
    pub stats: Stats,
    /// Bytes actually moved per op (NIC/transport counters), when known —
    /// the BENCH artifacts record traffic volume next to the timings.
    pub moved_bytes: Option<f64>,
    /// Received bytes delivered by *copying* per op
    /// ([`crate::comm::Traffic::copied_bytes`]), when measured on the real
    /// data plane. Zero on the reduce path — the column makes the
    /// posted-receive guarantee visible in the artifacts. `None` for
    /// simulated cells (the netsim has no copy notion).
    pub copied_bytes: Option<f64>,
}

/// A complete table keyed by (series, bytes, ranks).
#[derive(Debug, Default, Clone)]
pub struct Table {
    pub title: String,
    pub cells: Vec<Cell>,
}

impl Table {
    pub fn new(title: impl Into<String>) -> Self {
        Self {
            title: title.into(),
            cells: Vec::new(),
        }
    }

    pub fn push(&mut self, series: impl Into<String>, bytes: usize, ranks: usize, stats: Stats) {
        self.cells.push(Cell {
            series: series.into(),
            bytes,
            ranks,
            stats,
            moved_bytes: None,
            copied_bytes: None,
        });
    }

    /// Push a cell that also records the bytes moved per op.
    pub fn push_with_bytes(
        &mut self,
        series: impl Into<String>,
        bytes: usize,
        ranks: usize,
        stats: Stats,
        moved_bytes: f64,
    ) {
        self.cells.push(Cell {
            series: series.into(),
            bytes,
            ranks,
            stats,
            moved_bytes: Some(moved_bytes),
            copied_bytes: None,
        });
    }

    /// Push a cell measured on the real data plane: moved *and* copied
    /// traffic counters next to the timings.
    pub fn push_with_traffic(
        &mut self,
        series: impl Into<String>,
        bytes: usize,
        ranks: usize,
        stats: Stats,
        moved_bytes: f64,
        copied_bytes: f64,
    ) {
        self.cells.push(Cell {
            series: series.into(),
            bytes,
            ranks,
            stats,
            moved_bytes: Some(moved_bytes),
            copied_bytes: Some(copied_bytes),
        });
    }

    /// Look up the mean time for a cell.
    pub fn mean(&self, series: &str, bytes: usize, ranks: usize) -> Option<f64> {
        self.cells
            .iter()
            .find(|c| c.series == series && c.bytes == bytes && c.ranks == ranks)
            .map(|c| c.stats.mean())
    }

    /// Render as an aligned text table (the paper's "rows/series").
    pub fn render(&self) -> String {
        let mut out = String::new();
        out.push_str(&format!("# {}\n", self.title));
        out.push_str(&format!(
            "{:<14} {:>10} {:>8} {:>14} {:>12}\n",
            "series", "size", "ranks", "mean", "stddev"
        ));
        for c in &self.cells {
            out.push_str(&format!(
                "{:<14} {:>10} {:>8} {:>14} {:>12}\n",
                c.series,
                fmt_bytes(c.bytes),
                c.ranks,
                crate::metrics::fmt_secs(c.stats.mean()),
                crate::metrics::fmt_secs(c.stats.stddev()),
            ));
        }
        out
    }

    /// Write CSV:
    /// `series,bytes,ranks,mean_s,stddev_s,min_s,max_s,moved_bytes,copied_bytes`
    /// (traffic columns empty when the cell carries no counters).
    pub fn write_csv(&self, path: impl AsRef<Path>) -> Result<()> {
        let mut f = std::fs::File::create(path)?;
        writeln!(
            f,
            "series,bytes,ranks,mean_s,stddev_s,min_s,max_s,moved_bytes,copied_bytes"
        )?;
        for c in &self.cells {
            let moved = c
                .moved_bytes
                .map(|b| format!("{b:.0}"))
                .unwrap_or_default();
            let copied = c
                .copied_bytes
                .map(|b| format!("{b:.0}"))
                .unwrap_or_default();
            writeln!(
                f,
                "{},{},{},{:.9},{:.9},{:.9},{:.9},{},{}",
                c.series,
                c.bytes,
                c.ranks,
                c.stats.mean(),
                c.stats.stddev(),
                c.stats.min(),
                c.stats.max(),
                moved,
                copied
            )?;
        }
        Ok(())
    }
}

/// Human-readable byte size (delegates to [`crate::metrics::fmt_bytes`]).
pub fn fmt_bytes(b: usize) -> String {
    crate::metrics::fmt_bytes(b as u64)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_roundtrip() {
        let mut t = Table::new("fig-x");
        t.push("rccl", 64 << 20, 128, Stats::from_iter([1.0, 2.0]));
        t.push_with_bytes("pccl", 64 << 20, 128, Stats::from_iter([0.5]), 4096.0);
        t.push_with_traffic("pccl-rs", 64 << 20, 128, Stats::from_iter([0.4]), 4096.0, 0.0);
        assert_eq!(t.mean("rccl", 64 << 20, 128), Some(1.5));
        let r = t.render();
        assert!(r.contains("64 MB"));
        let dir = crate::util::tmp::TempDir::new().unwrap();
        let p = dir.path().join("t.csv");
        t.write_csv(&p).unwrap();
        let text = std::fs::read_to_string(p).unwrap();
        assert!(text.lines().count() == 4);
        assert!(text.contains("rccl,67108864,128"));
        assert!(text.contains("moved_bytes,copied_bytes"));
        // Simulated cell: moved only, copied column empty.
        assert!(text.lines().nth(2).unwrap().ends_with(",4096,"));
        // Measured cell: both counters — and the reduce path copies nothing.
        assert!(text.lines().nth(3).unwrap().ends_with(",4096,0"));
    }

    #[test]
    fn byte_formatting() {
        assert_eq!(fmt_bytes(64 << 20), "64 MB");
        assert_eq!(fmt_bytes(2048), "2 KB");
        assert_eq!(fmt_bytes(100), "100 B");
    }
}
