//! Op-level tracing for the plan engine.
//!
//! Every collective in this crate executes as a lowered, statically
//! verified [`crate::collectives::plan::Plan`] run by one engine
//! ([`crate::collectives::engine`]). That gives correctness a single
//! choke point — and this module gives *observability* the same choke
//! point: a per-rank ring-buffer recorder that the engine feeds with one
//! [`OpSpan`] per executed op (kind, peer, lanes, bytes moved, wall-clock
//! start and duration, and the phase/round indices of the plan's cost
//! model).
//!
//! Design constraints, in order:
//!
//! 1. **Zero cost when off.** The engine checks one `Option` per op; no
//!    clocks are read and nothing allocates unless a tracer was installed
//!    on the current thread with [`begin`]. The launcher only installs it
//!    for a dedicated traced trial that runs *after* the timed loop, so
//!    recording never overlaps a measured section.
//! 2. **Phase/round indices match [`plan::phase_shapes`]** exactly: a
//!    `BeginOp` opens a new phase, a `Round` marker opens a new round,
//!    and an op before any explicit round marker lands in the phase's
//!    implicit round 0 — the same rules the cost model uses. That makes
//!    the traced timeline directly comparable (and compared, see
//!    [`check_phases`]) to the verified plan.
//! 3. **Bounded memory.** The recorder is a ring buffer; once full it
//!    overwrites the oldest span and counts the loss, so tracing an
//!    arbitrarily long run cannot OOM a rank thread.
//!
//! The aggregation side folds all ranks' spans into a [`CellTrace`]:
//! raw per-rank spans for the chrome://tracing export
//! ([`chrome_trace_doc`]) plus a [`PhaseSummary`] per plan phase for the
//! compact table ([`format_summary`]) and the smoke-artifact guard.

use std::cell::RefCell;
use std::time::Instant;

use crate::collectives::plan::{self, PlanSpec, Scope};
use crate::error::{Error, Result};
use crate::util::json::Value;

/// Default span capacity of a rank's ring buffer. Covers every plan the
/// sweep grids lower today by orders of magnitude (a p=8 hierarchical
/// all-reduce is a few dozen ops).
pub const DEFAULT_CAPACITY: usize = 1 << 16;

/// Stable label for a plan scope, used in span records and trace exports.
pub fn scope_label(scope: Scope) -> &'static str {
    match scope {
        Scope::World => "world",
        Scope::Inter => "inter",
        Scope::Intra => "intra",
    }
}

/// One executed plan op, as observed on one rank.
#[derive(Debug, Clone, PartialEq)]
pub struct OpSpan {
    /// Phase index, aligned with `plan::phase_shapes(spec)`.
    pub phase: u32,
    /// Round index within the phase, aligned with `PhaseShape::rounds`.
    pub round: u32,
    /// Op kind: `send`, `recv`, `recv_combine`, `sendrecv`,
    /// `sendrecv_combine`.
    pub kind: &'static str,
    /// Scope label (`world`/`inter`/`intra`).
    pub scope: &'static str,
    /// Peer rank (the send peer for fused exchanges).
    pub peer: usize,
    /// Stripe count of a striped exchange (0 = plain protocol).
    pub lanes: u32,
    /// Bytes posted by this op.
    pub sent_bytes: u64,
    /// Bytes received by this op.
    pub recvd_bytes: u64,
    /// Bytes folded by a combining delivery.
    pub combine_bytes: u64,
    /// Seconds since the tracer was installed on this rank.
    pub start_s: f64,
    /// Wall-clock duration of the op (post → delivery).
    pub dur_s: f64,
    /// Queueing share of the op: seconds the transport spent waiting for
    /// matches in the mailbox (differenced from the endpoint's op clock).
    pub wait_s: f64,
    /// Service share of the op: seconds spent delivering/folding payloads
    /// once matched (the combine time on reduce paths).
    pub serve_s: f64,
}

/// Per-rank span recorder: a bounded ring buffer plus the phase/round
/// counters that mirror the plan cost model.
#[derive(Debug)]
pub struct RankTrace {
    rank: usize,
    origin: Instant,
    cap: usize,
    spans: Vec<OpSpan>,
    /// Next overwrite position once the buffer is full (= oldest span).
    head: usize,
    /// Spans overwritten after the buffer filled.
    dropped: u64,
    /// Phases opened so far (`BeginOp` count).
    phases_seen: u32,
    /// Explicit (or implicit first) rounds opened in the current phase.
    rounds_in_phase: u32,
    /// Local (op-free) plan executions observed, e.g. shuffle plans.
    local_runs: u32,
}

impl RankTrace {
    fn new(rank: usize, capacity: usize) -> Self {
        Self {
            rank,
            origin: Instant::now(),
            cap: capacity.max(1),
            spans: Vec::new(),
            head: 0,
            dropped: 0,
            phases_seen: 0,
            rounds_in_phase: 0,
            local_runs: 0,
        }
    }

    pub fn rank(&self) -> usize {
        self.rank
    }

    /// Spans lost to ring-buffer overwrite.
    pub fn dropped(&self) -> u64 {
        self.dropped
    }

    /// Op-free local plan executions seen while this tracer was live.
    pub fn local_runs(&self) -> u32 {
        self.local_runs
    }

    /// The engine saw a `BeginOp`: a new phase opens with no rounds yet.
    pub(crate) fn on_begin_op(&mut self) {
        self.phases_seen += 1;
        self.rounds_in_phase = 0;
    }

    /// The engine saw a `Round` cost-model marker.
    pub(crate) fn on_round(&mut self) {
        self.rounds_in_phase += 1;
    }

    /// The engine ran an op-free local plan (no spans to record).
    pub(crate) fn on_local_run(&mut self) {
        self.local_runs += 1;
    }

    /// Record one executed op. `started` is the instant the engine began
    /// the op; duration is measured to now. `wait_s`/`serve_s` are the
    /// op's queueing-vs-service split, differenced from the endpoint's op
    /// clock around the op (0 when the comm impl doesn't track it).
    #[allow(clippy::too_many_arguments)]
    pub(crate) fn record(
        &mut self,
        kind: &'static str,
        scope: Scope,
        peer: usize,
        lanes: u32,
        sent_bytes: u64,
        recvd_bytes: u64,
        combine_bytes: u64,
        started: Instant,
        wait_s: f64,
        serve_s: f64,
    ) {
        if self.rounds_in_phase == 0 {
            // Mirrors `plan::phase_shapes`: an op before any explicit
            // `Round` marker lands in the phase's implicit round 0.
            self.rounds_in_phase = 1;
        }
        let span = OpSpan {
            phase: self.phases_seen.saturating_sub(1),
            round: self.rounds_in_phase - 1,
            kind,
            scope: scope_label(scope),
            peer,
            lanes,
            sent_bytes,
            recvd_bytes,
            combine_bytes,
            start_s: started.duration_since(self.origin).as_secs_f64(),
            dur_s: started.elapsed().as_secs_f64(),
            wait_s,
            serve_s,
        };
        if self.spans.len() < self.cap {
            self.spans.push(span);
        } else {
            self.spans[self.head] = span;
            self.head = (self.head + 1) % self.cap;
            self.dropped += 1;
        }
    }

    /// Consume the recorder, yielding spans oldest-first.
    pub fn into_spans(self) -> Vec<OpSpan> {
        let mut spans = self.spans;
        if self.dropped > 0 {
            spans.rotate_left(self.head);
        }
        spans
    }
}

thread_local! {
    /// The rank thread's installed tracer, if any. Boxed so the engine's
    /// take/restore handoff moves a pointer, not the buffer.
    static ACTIVE: RefCell<Option<Box<RankTrace>>> = const { RefCell::new(None) };
}

/// Install a tracer on the current (rank) thread with the default span
/// capacity. Replaces any tracer already installed.
pub fn begin(rank: usize) {
    begin_with_capacity(rank, DEFAULT_CAPACITY);
}

/// Install a tracer with an explicit ring-buffer capacity (min 1).
pub fn begin_with_capacity(rank: usize, capacity: usize) {
    ACTIVE.with(|slot| *slot.borrow_mut() = Some(Box::new(RankTrace::new(rank, capacity))));
}

/// Uninstall and return the current thread's tracer, if one is active.
pub fn end() -> Option<RankTrace> {
    ACTIVE.with(|slot| slot.borrow_mut().take()).map(|boxed| *boxed)
}

/// Whether a tracer is installed on the current thread.
pub fn is_active() -> bool {
    ACTIVE.with(|slot| slot.borrow().is_some())
}

/// Engine-side handoff: detach the tracer for the duration of a plan run
/// (so the engine can thread `&mut` through its op loop without fighting
/// the thread-local), to be put back with [`restore`].
pub(crate) fn take() -> Option<Box<RankTrace>> {
    ACTIVE.with(|slot| slot.borrow_mut().take())
}

/// Engine-side handoff: re-install a tracer detached with [`take`].
pub(crate) fn restore(tracer: Box<RankTrace>) {
    ACTIVE.with(|slot| *slot.borrow_mut() = Some(tracer));
}

// ---------------------------------------------------------------------------
// Aggregation
// ---------------------------------------------------------------------------

/// One plan phase of a traced run, folded across ranks.
#[derive(Debug, Clone, PartialEq)]
pub struct PhaseSummary {
    /// Scope label of the phase (from its first observed span).
    pub scope: &'static str,
    /// Comm ops rank 0 executed in the phase.
    pub ops: u64,
    /// Rounds rank 0 observed (max round index + 1).
    pub rounds: u64,
    /// Bytes rank 0 posted in the phase.
    pub sent_bytes: u64,
    /// Bytes rank 0 folded via combining deliveries.
    pub combine_bytes: u64,
    /// Bytes posted by all ranks together.
    pub total_sent_bytes: u64,
    /// Busiest rank's summed span time in the phase (seconds).
    pub busy_s: f64,
    /// Rank 0's summed queueing time in the phase (waiting for matches).
    pub wait_s: f64,
    /// Rank 0's summed service time in the phase (delivery + folds).
    pub serve_s: f64,
}

/// A traced cell: raw per-rank spans plus the per-phase rollup.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct CellTrace {
    /// `per_rank[r]` = rank `r`'s spans, oldest first.
    pub per_rank: Vec<Vec<OpSpan>>,
    /// One summary per observed plan phase, in phase order.
    pub phases: Vec<PhaseSummary>,
}

/// Fold per-rank span streams into a per-phase timeline.
pub fn aggregate(per_rank: Vec<Vec<OpSpan>>) -> CellTrace {
    let nphases = per_rank
        .iter()
        .flat_map(|spans| spans.iter())
        .map(|s| s.phase + 1)
        .max()
        .unwrap_or(0);
    let mut phases = Vec::with_capacity(nphases as usize);
    for ph in 0..nphases {
        let mut scope = None;
        let (mut ops, mut rounds, mut sent, mut combine, mut total) = (0u64, 0u64, 0u64, 0u64, 0u64);
        let mut busy = 0.0f64;
        let (mut wait, mut serve) = (0.0f64, 0.0f64);
        for (rank, spans) in per_rank.iter().enumerate() {
            let mut rank_busy = 0.0f64;
            for s in spans.iter().filter(|s| s.phase == ph) {
                // Rank order means rank 0's first span names the scope.
                scope.get_or_insert(s.scope);
                total += s.sent_bytes;
                rank_busy += s.dur_s;
                if rank == 0 {
                    ops += 1;
                    rounds = rounds.max(u64::from(s.round) + 1);
                    sent += s.sent_bytes;
                    combine += s.combine_bytes;
                    wait += s.wait_s;
                    serve += s.serve_s;
                }
            }
            busy = busy.max(rank_busy);
        }
        phases.push(PhaseSummary {
            scope: scope.unwrap_or("world"),
            ops,
            rounds,
            sent_bytes: sent,
            combine_bytes: combine,
            total_sent_bytes: total,
            busy_s: busy,
            wait_s: wait,
            serve_s: serve,
        });
    }
    CellTrace { per_rank, phases }
}

// ---------------------------------------------------------------------------
// Guard: traced run vs. verified plan
// ---------------------------------------------------------------------------

/// Check a traced run against the plan the spec lowers to: rank 0's
/// observed per-phase/per-round byte movement must equal the
/// [`plan::phase_shapes`] cost model exactly (scope labels included).
///
/// Two deliberate leniencies keep degenerate plans (p = 1, op-free
/// phases) checkable: trailing plan phases the trace never reached are
/// accepted only if they move zero volume, and rounds beyond rank 0's
/// last observed op are accepted only if the model schedules nothing for
/// them — any scheduled volume with no matching span is an error.
pub fn check_phases(trace: &CellTrace, spec: &PlanSpec, elem_bytes: usize) -> Result<()> {
    let shapes = plan::phase_shapes(spec)?;
    let es = elem_bytes as u64;
    let rank0: &[OpSpan] = trace.per_rank.first().map(Vec::as_slice).unwrap_or(&[]);
    let observed_phases = rank0.iter().map(|s| s.phase as usize + 1).max().unwrap_or(0);
    if observed_phases > shapes.len() {
        return Err(Error::Plan(format!(
            "trace records {observed_phases} phases but the lowered plan has {}",
            shapes.len()
        )));
    }
    for (i, shape) in shapes.iter().enumerate().skip(observed_phases) {
        let volume: u64 = shape
            .rounds
            .iter()
            .map(|r| r.sent_elems + r.combine_elems)
            .sum();
        if volume != 0 {
            return Err(Error::Plan(format!(
                "plan phase {i} schedules {volume} elems but the trace never reached it"
            )));
        }
    }
    for (i, shape) in shapes.iter().enumerate().take(observed_phases) {
        let spans: Vec<&OpSpan> = rank0.iter().filter(|s| s.phase as usize == i).collect();
        if let Some(first) = spans.first() {
            let expect = scope_label(shape.scope);
            if first.scope != expect {
                return Err(Error::Plan(format!(
                    "trace phase {i} ran on the {} scope but the plan lowers it to {expect}",
                    first.scope
                )));
            }
        }
        let nrounds = shape.rounds.len();
        let mut sent = vec![0u64; nrounds];
        let mut combine = vec![0u64; nrounds];
        for s in &spans {
            let r = s.round as usize;
            if r >= nrounds {
                return Err(Error::Plan(format!(
                    "trace phase {i} observed round {r} but the plan has {nrounds} rounds"
                )));
            }
            sent[r] += s.sent_bytes;
            combine[r] += s.combine_bytes;
        }
        for (r, round) in shape.rounds.iter().enumerate() {
            let (want_sent, want_combine) = (round.sent_elems * es, round.combine_elems * es);
            if sent[r] != want_sent || combine[r] != want_combine {
                return Err(Error::Plan(format!(
                    "trace phase {i} round {r} moved {} sent / {} combined bytes but the \
                     verified plan schedules {want_sent} / {want_combine}",
                    sent[r], combine[r]
                )));
            }
        }
    }
    Ok(())
}

// ---------------------------------------------------------------------------
// Exports
// ---------------------------------------------------------------------------

/// Build a chrome://tracing (Trace Event Format) document from labeled
/// cell traces: one process per cell, one thread row per rank, one
/// complete (`"ph": "X"`) event per span. Loads in `chrome://tracing`
/// and Perfetto.
pub fn chrome_trace_doc(cells: &[(String, &CellTrace)]) -> Value {
    let mut events = Vec::new();
    for (pid, (label, cell)) in cells.iter().enumerate() {
        events.push(Value::obj(vec![
            ("name", Value::Str("process_name".to_string())),
            ("ph", Value::Str("M".to_string())),
            ("pid", Value::Num(pid as f64)),
            (
                "args",
                Value::obj(vec![("name", Value::Str(label.clone()))]),
            ),
        ]));
        for (rank, spans) in cell.per_rank.iter().enumerate() {
            for s in spans {
                events.push(Value::obj(vec![
                    ("name", Value::Str(format!("{} p{}", s.kind, s.peer))),
                    ("cat", Value::Str(s.scope.to_string())),
                    ("ph", Value::Str("X".to_string())),
                    ("ts", Value::Num(s.start_s * 1e6)),
                    ("dur", Value::Num(s.dur_s * 1e6)),
                    ("pid", Value::Num(pid as f64)),
                    ("tid", Value::Num(rank as f64)),
                    (
                        "args",
                        Value::obj(vec![
                            ("phase", Value::Num(f64::from(s.phase))),
                            ("round", Value::Num(f64::from(s.round))),
                            ("lanes", Value::Num(f64::from(s.lanes))),
                            ("sent_bytes", Value::Num(s.sent_bytes as f64)),
                            ("recvd_bytes", Value::Num(s.recvd_bytes as f64)),
                            ("combine_bytes", Value::Num(s.combine_bytes as f64)),
                            ("wait_us", Value::Num(s.wait_s * 1e6)),
                            ("serve_us", Value::Num(s.serve_s * 1e6)),
                        ]),
                    ),
                ]));
            }
        }
    }
    Value::obj(vec![
        ("traceEvents", Value::Arr(events)),
        ("displayTimeUnit", Value::Str("ms".to_string())),
    ])
}

/// Compact per-phase table of a traced cell, with the netsim-predicted
/// time per phase alongside when available (pass `&[]` to omit). The
/// `wait`/`serve` columns split rank 0's observed time into queueing
/// (parked in the mailbox awaiting a match) vs service (delivering and
/// folding payloads) — a phase dominated by `wait` is skew- or
/// straggler-bound, one dominated by `serve` is combine-bound.
pub fn format_summary(trace: &CellTrace, predicted_s: &[f64]) -> String {
    let mut out = String::new();
    out.push_str(
        "  phase  scope  rounds  ops   rank0-sent    combine       observed         wait        serve     predicted\n",
    );
    for (i, ph) in trace.phases.iter().enumerate() {
        let predicted = predicted_s
            .get(i)
            .map(|p| format!("{:>9.1} us", p * 1e6))
            .unwrap_or_else(|| "          --".to_string());
        out.push_str(&format!(
            "  {:<5}  {:<5}  {:>6}  {:>3}   {:>10} B  {:>10} B  {:>9.1} us  {:>9.1} us  {:>9.1} us  {}\n",
            i,
            ph.scope,
            ph.rounds,
            ph.ops,
            ph.sent_bytes,
            ph.combine_bytes,
            ph.busy_s * 1e6,
            ph.wait_s * 1e6,
            ph.serve_s * 1e6,
            predicted
        ));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::collectives::plan::{Algo, PlanKind};

    fn span(phase: u32, round: u32, sent: u64, combine: u64) -> OpSpan {
        OpSpan {
            phase,
            round,
            kind: "send",
            scope: "world",
            peer: 1,
            lanes: 0,
            sent_bytes: sent,
            recvd_bytes: 0,
            combine_bytes: combine,
            start_s: 0.0,
            dur_s: 1e-6,
            wait_s: 6e-7,
            serve_s: 4e-7,
        }
    }

    #[test]
    fn ring_buffer_overwrites_oldest_and_counts_drops() {
        let mut t = RankTrace::new(0, 2);
        t.on_begin_op();
        for i in 0..5u64 {
            t.record("send", Scope::World, 1, 0, i, 0, 0, Instant::now(), 0.0, 0.0);
        }
        assert_eq!(t.dropped(), 3);
        let spans = t.into_spans();
        assert_eq!(spans.len(), 2);
        // Oldest-first order survives the wraparound.
        assert_eq!(spans[0].sent_bytes, 3);
        assert_eq!(spans[1].sent_bytes, 4);
    }

    #[test]
    fn phase_and_round_counters_mirror_the_cost_model() {
        let mut t = RankTrace::new(0, 16);
        // Phase 0 with an implicit round 0 (op before any Round marker).
        t.on_begin_op();
        t.record("send", Scope::World, 1, 0, 8, 0, 0, Instant::now(), 0.0, 0.0);
        // Phase 1 with two explicit rounds.
        t.on_begin_op();
        t.on_round();
        t.record("send", Scope::Inter, 2, 0, 8, 0, 0, Instant::now(), 0.0, 0.0);
        t.on_round();
        t.record("recv_combine", Scope::Inter, 2, 0, 0, 8, 8, Instant::now(), 0.0, 0.0);
        let spans = t.into_spans();
        assert_eq!((spans[0].phase, spans[0].round), (0, 0));
        assert_eq!((spans[1].phase, spans[1].round), (1, 0));
        assert_eq!((spans[2].phase, spans[2].round), (1, 1));
        assert_eq!(spans[1].scope, "inter");
    }

    #[test]
    fn thread_local_install_and_teardown() {
        assert!(!is_active());
        begin(3);
        assert!(is_active());
        let taken = take().expect("installed");
        assert!(!is_active());
        restore(taken);
        let t = end().expect("restored");
        assert_eq!(t.rank(), 3);
        assert!(!is_active());
    }

    #[test]
    fn aggregate_rolls_up_per_phase() {
        let rank0 = vec![span(0, 0, 100, 0), span(1, 0, 50, 50), span(1, 1, 50, 0)];
        let rank1 = vec![span(0, 0, 100, 0), span(1, 0, 50, 0)];
        let cell = aggregate(vec![rank0, rank1]);
        assert_eq!(cell.phases.len(), 2);
        assert_eq!(cell.phases[0].ops, 1);
        assert_eq!(cell.phases[0].sent_bytes, 100);
        assert_eq!(cell.phases[0].total_sent_bytes, 200);
        assert_eq!(cell.phases[1].rounds, 2);
        assert_eq!(cell.phases[1].combine_bytes, 50);
        assert!(cell.phases[0].busy_s > 0.0);
        // Queueing-vs-service split: rank 0's per-span wait/serve sum up.
        assert!((cell.phases[1].wait_s - 2.0 * 6e-7).abs() < 1e-12);
        assert!((cell.phases[1].serve_s - 2.0 * 4e-7).abs() < 1e-12);
    }

    #[test]
    fn check_phases_accepts_a_faithful_trace_and_rejects_a_forged_one() {
        // Flat 4-rank ring all-gather: one phase, p-1 rounds, one block
        // (256 elems × 4 B) sent per round by rank 0.
        let spec = PlanSpec::flat(PlanKind::AllGather, Algo::Ring, 4, 1024, 1);
        let shapes = plan::phase_shapes(&spec).expect("shapes");
        let mut rank0 = Vec::new();
        for (ph, shape) in shapes.iter().enumerate() {
            for (r, round) in shape.rounds.iter().enumerate() {
                rank0.push(span(ph as u32, r as u32, round.sent_elems * 4, round.combine_elems * 4));
            }
        }
        let good = aggregate(vec![rank0.clone()]);
        check_phases(&good, &spec, 4).expect("faithful trace passes");

        let mut forged = rank0;
        forged[0].sent_bytes += 4;
        let bad = aggregate(vec![forged]);
        let err = check_phases(&bad, &spec, 4).expect_err("forged trace rejected");
        assert!(err.to_string().contains("verified plan schedules"));
    }

    #[test]
    fn check_phases_rejects_extra_rounds_and_phases() {
        let spec = PlanSpec::flat(PlanKind::AllGather, Algo::Ring, 2, 64, 1);
        // One bogus span in a phase the plan does not have.
        let bad = aggregate(vec![vec![span(7, 0, 4, 0)]]);
        assert!(check_phases(&bad, &spec, 4).is_err());
    }

    #[test]
    fn chrome_doc_is_valid_json_with_one_event_per_span() {
        let cell = aggregate(vec![vec![span(0, 0, 8, 0)], vec![span(0, 0, 8, 0)]]);
        let doc = chrome_trace_doc(&[("demo".to_string(), &cell)]);
        let parsed = Value::parse(&doc.to_string()).expect("valid JSON");
        let events = parsed.get("traceEvents").unwrap().as_arr().unwrap();
        // 1 process-name metadata record + 2 spans.
        assert_eq!(events.len(), 3);
        assert_eq!(events[0].get("ph").unwrap().as_str().unwrap(), "M");
        assert_eq!(events[1].get("ph").unwrap().as_str().unwrap(), "X");
        assert_eq!(events[2].get("tid").unwrap().as_usize().unwrap(), 1);
    }

    #[test]
    fn summary_table_has_one_line_per_phase() {
        let cell = aggregate(vec![vec![span(0, 0, 8, 0), span(1, 0, 8, 8)]]);
        let table = format_summary(&cell, &[1e-6]);
        assert_eq!(table.lines().count(), 3); // header + 2 phases
        assert!(table.contains("predicted"));
        assert!(table.contains("wait"));
        assert!(table.contains("serve"));
    }
}
