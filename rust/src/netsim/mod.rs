//! Network simulator — reproduces the paper's Frontier/Perlmutter-scale
//! experiments on commodity hardware (see DESIGN.md §1 for the
//! substitution argument).

pub mod counters;
pub mod libmodel;
pub mod sim;

pub use counters::NicCounters;
pub use libmodel::{predict_phase_times, simulate, LibModel};
pub use sim::{NetSim, Phase, RoundCost};
