//! Cassini (Slingshot-11 NIC) hardware-counter model.
//!
//! The paper uses three counters to diagnose library behaviour:
//! * `parbs_tarb_pi_posted_pkts` — packets *written to* the NIC (sends),
//! * `parbs_tarb_pi_non_posted_pkts` — packets *read from* the NIC (recvs),
//! * `lpe_net_match_overflow_0` — messages that missed the hardware
//!   "priority list" and were copied through the software overflow buffer
//!   (§VI-B: RCCL shows 200× higher values than PCCL).
//!
//! The simulator maintains these per NIC for a representative node (the
//! collectives are node-symmetric).

/// Bytes per network packet used when converting modeled volumes to packet
/// counts (Slingshot MTU-sized transfers).
pub const PACKET_BYTES: f64 = 2048.0;

/// Per-node NIC counters (one slot per NIC).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct NicCounters {
    /// Packets written to each NIC (posted: our sends).
    pub posted_pkts: Vec<f64>,
    /// Packets read from each NIC (non-posted: our receives).
    pub non_posted_pkts: Vec<f64>,
    /// Messages that took the overflow (software-copy) path.
    pub match_overflow: f64,
}

impl NicCounters {
    pub fn new(nics: usize) -> Self {
        Self {
            posted_pkts: vec![0.0; nics],
            non_posted_pkts: vec![0.0; nics],
            match_overflow: 0.0,
        }
    }

    /// Record `bytes` written through NIC `nic`.
    pub fn write(&mut self, nic: usize, bytes: f64) {
        self.posted_pkts[nic] += bytes / PACKET_BYTES;
    }

    /// Record `bytes` read through NIC `nic`.
    pub fn read(&mut self, nic: usize, bytes: f64) {
        self.non_posted_pkts[nic] += bytes / PACKET_BYTES;
    }

    /// Record `bytes` written spread evenly across all NICs.
    pub fn write_even(&mut self, bytes: f64) {
        let n = self.posted_pkts.len() as f64;
        for v in &mut self.posted_pkts {
            *v += bytes / n / PACKET_BYTES;
        }
    }

    /// Record `bytes` read spread evenly across all NICs.
    pub fn read_even(&mut self, bytes: f64) {
        let n = self.non_posted_pkts.len() as f64;
        for v in &mut self.non_posted_pkts {
            *v += bytes / n / PACKET_BYTES;
        }
    }

    /// Total posted packets across NICs.
    pub fn total_posted(&self) -> f64 {
        self.posted_pkts.iter().sum()
    }

    /// Total non-posted packets across NICs.
    pub fn total_non_posted(&self) -> f64 {
        self.non_posted_pkts.iter().sum()
    }

    /// Total bytes written across NICs (posted packets × packet size) —
    /// the modeled counterpart of the data plane's `Traffic::sent_bytes`.
    pub fn posted_bytes(&self) -> f64 {
        self.total_posted() * PACKET_BYTES
    }

    /// Total bytes read across NICs.
    pub fn non_posted_bytes(&self) -> f64 {
        self.total_non_posted() * PACKET_BYTES
    }

    /// Max/min posted ratio — ∞-like for single-NIC routing, ≈1 for even.
    pub fn posted_imbalance(&self) -> f64 {
        let max = self.posted_pkts.iter().cloned().fold(0.0, f64::max);
        let min = self
            .posted_pkts
            .iter()
            .cloned()
            .fold(f64::INFINITY, f64::min);
        if min <= 0.0 {
            f64::INFINITY
        } else {
            max / min
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn single_nic_routing_shows_imbalance() {
        let mut c = NicCounters::new(4);
        c.write(0, 1_000_000.0);
        c.read(3, 1_000_000.0);
        assert!(c.posted_imbalance().is_infinite());
        assert_eq!(c.posted_pkts[1], 0.0);
        assert!(c.total_posted() > 0.0);
        // Byte views reconstruct the recorded volumes on both sides.
        assert!((c.posted_bytes() - 1_000_000.0).abs() < PACKET_BYTES);
        assert!((c.non_posted_bytes() - 1_000_000.0).abs() < PACKET_BYTES);
    }

    #[test]
    fn even_routing_is_balanced() {
        let mut c = NicCounters::new(4);
        c.write_even(8192.0);
        assert!((c.posted_imbalance() - 1.0).abs() < 1e-9);
        assert!((c.total_posted() - 4.0).abs() < 1e-9);
        assert!((c.posted_bytes() - 8192.0).abs() < 1e-9);
    }
}
