//! Per-library performance models.
//!
//! Each model turns `(collective, message size, rank count)` into the round
//! schedule its algorithm executes. The PCCL models cost the **lowered
//! plan itself**: they build the same [`crate::collectives::plan::PlanSpec`]
//! the data plane executes (at a symbolic one element per block) and read
//! the per-phase, per-round element factors off
//! [`crate::collectives::plan::phase_shapes`] — so the schedule that is
//! statically verified is the schedule that is timed. The third-party
//! models (vendor, Cray-MPICH, the diagnostics) stay closed-form over the
//! step math in [`crate::collectives::schedule`]; they describe libraries
//! whose schedules this repo does not lower. The models encode the
//! behaviours the paper measures:
//!
//! * **Vendor (NCCL/RCCL)** — flat ring all-gather/reduce-scatter across all
//!   `p` ranks, channelized over all NICs (Fig. 3 shows the even NIC use);
//!   double-binary-tree all-reduce [15]. Above ~128 ranks the Cassini
//!   priority list overflows and messages take a software-copy path
//!   (`lpe_net_match_overflow_0`, §VI-B) — modeled as an eager-protocol
//!   penalty that is worst for small per-step chunks and fades once chunks
//!   are large enough for rendezvous.
//! * **Cray-MPICH** — flat single-channel ring routing every write through
//!   NIC-0 and every read through NIC-3, with reductions on the CPU
//!   (Observation 1, Figs. 3–4).
//! * **Custom** — the paper's diagnostic: MPI point-to-point ring +
//!   GPU reduction kernel (Fig. 4, blue line).
//! * **PCCL ring / PCCL rec** — the hierarchical two-level design of §IV
//!   with per-GPU NIC binding; inter-node phase ring or recursive
//!   doubling/halving.

use crate::backends::CollKind;
use crate::collectives::plan::{self, Algo, PhaseShape, PlanKind, PlanSpec, Scope};
use crate::collectives::schedule::{recursive, ring};
use crate::error::{Error, Result};
use crate::metrics::Stats;
use crate::topology::{Machine, MachineParams, Topology};

use super::counters::NicCounters;
use super::sim::{NetSim, Phase, RoundCost};

/// Eager→rendezvous protocol crossover: per-step chunks at or below this
/// size take the unexpected-message (overflow-copy) path in full.
const RENDEZVOUS_BYTES: f64 = 256.0 * 1024.0;
/// Rank count at which vendor-library match-list pressure begins.
const OVERFLOW_START_RANKS: f64 = 128.0;
/// Fraction of all-reduce volume taking the copy path at full pressure.
const TREE_COPY_FACTOR: f64 = 1.0;
/// Extra run-to-run variability of vendor all-reduce (§V-B).
const VENDOR_AR_EXTRA_SIGMA: f64 = 0.20;

/// Which library's model to simulate.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum LibModel {
    /// NCCL (Perlmutter) / RCCL (Frontier).
    Vendor,
    /// Cray-MPICH.
    CrayMpich,
    /// MPI p2p ring + GPU reduce kernel (the Fig. 4 diagnostic).
    Custom,
    /// PCCL hierarchical, ring inter-node.
    PcclRing,
    /// PCCL hierarchical, recursive doubling/halving inter-node.
    PcclRec,
    /// Ablation: NCCL's PAT algorithm [16] as if it supported multi-GPU
    /// nodes — log-latency flat all-gather/reduce-scatter.
    VendorPat,
    /// Ablation: PCCL_rec with a 4-chunk pipelined inter/intra overlap
    /// (the extension implemented in
    /// [`crate::collectives::pipelined_hier_all_gather`]).
    PcclRecPipelined,
}

impl LibModel {
    pub fn label(self, machine: Machine) -> String {
        match self {
            LibModel::Vendor => machine.vendor_name().to_lowercase(),
            LibModel::CrayMpich => "cray-mpich".into(),
            LibModel::Custom => "custom-p2p-gpu".into(),
            LibModel::PcclRing => "pccl_ring".into(),
            LibModel::PcclRec => "pccl_rec".into(),
            LibModel::VendorPat => format!("{}-pat", machine.vendor_name().to_lowercase()),
            LibModel::PcclRecPipelined => "pccl_rec_pipe4".into(),
        }
    }

    /// Mapping from the dispatchable [`crate::backends::Backend`] set.
    pub fn from_backend(b: crate::backends::Backend) -> Option<LibModel> {
        use crate::backends::Backend;
        match b {
            Backend::Vendor => Some(LibModel::Vendor),
            Backend::CrayMpich => Some(LibModel::CrayMpich),
            Backend::PcclRing => Some(LibModel::PcclRing),
            Backend::PcclRec => Some(LibModel::PcclRec),
            Backend::Auto => None,
        }
    }
}

/// Result of simulating one configuration.
#[derive(Debug, Clone)]
pub struct SimOutcome {
    /// Per-trial times (seconds).
    pub times: Vec<f64>,
    /// Trial statistics.
    pub stats: Stats,
    /// Modeled NIC counters for one representative node over one trial.
    pub counters: NicCounters,
}

/// Match-list pressure ramp: 0 below [`OVERFLOW_START_RANKS`], →1 by ~2k.
fn overflow_frac(p: usize) -> f64 {
    (((p as f64).log2() - OVERFLOW_START_RANKS.log2()) / 4.0).clamp(0.0, 1.0)
}

/// Rendezvous fade: chunks larger than the eager window avoid most copies.
fn rendezvous_decay(chunk: f64) -> f64 {
    if chunk <= RENDEZVOUS_BYTES {
        1.0
    } else {
        (RENDEZVOUS_BYTES / chunk).powf(1.5)
    }
}

/// Small-chunk multiplier: tiny unexpected messages thrash the match list
/// hardest (reduce-scatter shows the paper's largest gaps, 50–168×).
fn small_chunk_mult(chunk: f64) -> f64 {
    const KNEE: f64 = 64.0 * 1024.0;
    1.0 + 3.0 * ((KNEE - chunk) / KNEE).clamp(0.0, 1.0)
}

/// Per-step overflow-copy volume for vendor ring collectives.
fn vendor_copy_bytes(p: usize, chunk: f64, is_reduce: bool) -> f64 {
    let mult = if is_reduce { small_chunk_mult(chunk) } else { 1.0 };
    overflow_frac(p) * chunk * rendezvous_decay(chunk) * mult
}

fn ceil_log2(p: usize) -> usize {
    (usize::BITS - p.next_power_of_two().leading_zeros() - 1) as usize
}

/// Build the round schedule + NIC counters for one configuration.
///
/// `msg` is the paper's message-size convention (§III-A): all-gather =
/// output bytes per GPU, reduce-scatter = input bytes per GPU, all-reduce =
/// input/output bytes per GPU.
pub fn schedule(
    machine: Machine,
    lib: LibModel,
    kind: CollKind,
    msg: usize,
    ranks: usize,
) -> Result<(Vec<Phase>, NicCounters, f64)> {
    schedule_lanes(machine, lib, kind, msg, ranks, 1)
}

/// Cost each lowered phase of `spec` on `machine`: one predicted seconds
/// value per [`plan::phase_shapes`] phase, in phase order.
///
/// This is deliberately *not* [`schedule`]: the library models there add
/// phases the plan does not carry (e.g. the hierarchical shuffle runs as
/// an op-free local plan outside the op stream), so their phase counts
/// cannot line up with an op trace. Costing `phase_shapes` directly keeps
/// the prediction one-to-one with the tracer's observed per-phase
/// timeline — `pccl smoke` writes both side by side, so simulated-vs-
/// measured drift becomes a plottable number per phase.
pub fn predict_phase_times(
    spec: &PlanSpec,
    machine: Machine,
    elem_bytes: usize,
) -> Result<Vec<f64>> {
    let mp = machine.params();
    let shapes = plan::phase_shapes(spec)?;
    Ok(shapes
        .iter()
        .map(|ph| {
            let intra = ph.scope == Scope::Intra;
            ph.rounds
                .iter()
                .map(|r| {
                    let wire = (r.sent_elems as usize * elem_bytes) as f64;
                    let reduce = (r.combine_elems as usize * elem_bytes) as f64;
                    RoundCost {
                        label: "traced-phase",
                        alpha: if intra { mp.alpha_intra } else { mp.alpha_inter },
                        nic_bytes: if intra { 0.0 } else { wire },
                        intra_bytes: if intra { wire } else { 0.0 },
                        reduce_bytes: reduce,
                        reduce_bw: mp.gpu_reduce_bw,
                        copy_bytes: 0.0,
                        copy_bw: 0.0,
                        rails: 1.0,
                        repeat: 1,
                    }
                    .time(&mp)
                })
                .sum()
        })
        .collect())
}

/// [`schedule`] with an explicit transport-lane count. Only the PCCL
/// hierarchical models are lane-aware (their NIC-bound inter phase stripes
/// over the rails); the vendor and Cray-MPICH models ignore `lanes` —
/// single-lane routing is exactly the libraries' measured behavior.
pub fn schedule_lanes(
    machine: Machine,
    lib: LibModel,
    kind: CollKind,
    msg: usize,
    ranks: usize,
    lanes: usize,
) -> Result<(Vec<Phase>, NicCounters, f64)> {
    let mp = machine.params();
    let topo = Topology::for_machine(machine, ranks)?;
    if msg == 0 || ranks == 0 {
        return Err(Error::NetSim(format!("bad config msg={msg} ranks={ranks}")));
    }
    let mut counters = NicCounters::new(mp.nics_per_node);
    let msg = msg as f64;
    let p = ranks as f64;
    let b = msg / p; // per-step block for flat ring algorithms
    let mut extra_sigma = 0.0;
    let lanes = lanes.max(1);

    let phases = match lib {
        LibModel::Vendor => {
            vendor_phases(&mp, &topo, kind, msg, ranks, b, &mut counters, &mut extra_sigma)
        }
        LibModel::CrayMpich => craympich_phases(&mp, kind, msg, ranks, b, &mut counters),
        LibModel::Custom => custom_phases(&mp, kind, msg, ranks, b, &mut counters),
        LibModel::PcclRing | LibModel::PcclRec => pccl_phases(
            &mp,
            &topo,
            kind,
            msg,
            ranks,
            lib == LibModel::PcclRec,
            lanes,
            &mut counters,
        )?,
        LibModel::VendorPat => {
            vendor_pat_phases(&mp, kind, msg, ranks, b, &mut counters, &mut extra_sigma)
        }
        LibModel::PcclRecPipelined => {
            let phases = pccl_phases(&mp, &topo, kind, msg, ranks, true, lanes, &mut counters)?;
            pipeline_phases(&mp, phases)
        }
    };
    Ok((phases, counters, extra_sigma))
}

#[allow(clippy::too_many_arguments)]
fn vendor_phases(
    mp: &MachineParams,
    _topo: &Topology,
    kind: CollKind,
    msg: f64,
    ranks: usize,
    b: f64,
    counters: &mut NicCounters,
    extra_sigma: &mut f64,
) -> Vec<Phase> {
    let c = mp.nics_per_node as f64;
    let m_local = mp.gpus_per_node as f64;
    match kind {
        CollKind::AllGather | CollKind::ReduceScatter => {
            // Flat ring over all p ranks, channelized across all C NICs.
            let is_rs = kind == CollKind::ReduceScatter;
            let steps = ring::steps(ranks);
            let copy = vendor_copy_bytes(ranks, b, is_rs);
            counters.write_even((steps as f64) * b);
            counters.read_even((steps as f64) * b);
            counters.match_overflow += overflow_frac(ranks) * steps as f64 * m_local;
            vec![Phase {
                label: "vendor-flat-ring",
                rounds: vec![RoundCost {
                    label: "ring-step",
                    alpha: mp.alpha_vendor,
                    nic_bytes: b / c,
                    intra_bytes: b,
                    reduce_bytes: if is_rs { b } else { 0.0 },
                    reduce_bw: mp.gpu_reduce_bw,
                    copy_bytes: copy,
                    copy_bw: mp.overflow_copy_bw,
                    repeat: steps,
                }],
            }]
        }
        CollKind::AllReduce => {
            // Double binary tree [15]: log-latency, node egress ≈ 2·msg
            // spread across NICs (intra-node part of the trees rides
            // NVLink/Infinity Fabric). Pipelined chunks of msg/p keep the
            // match list under the same pressure as the ring chunks.
            *extra_sigma = VENDOR_AR_EXTRA_SIGMA;
            let depth = 2 * ceil_log2(ranks);
            // Copy-path volume: a TREE_COPY_FACTOR share of the message at
            // full pressure, weighted by how eager-protocol-sized the
            // pipeline chunks (≈ msg/p) are.
            let copy = overflow_frac(ranks)
                * msg
                * TREE_COPY_FACTOR
                * (small_chunk_mult(b) / 4.0)
                * rendezvous_decay(b).max(0.25);
            counters.write_even(2.0 * msg);
            counters.read_even(2.0 * msg);
            counters.match_overflow +=
                overflow_frac(ranks) * (depth as f64) * m_local * (msg / RENDEZVOUS_BYTES).max(1.0);
            vec![
                Phase {
                    label: "vendor-tree-latency",
                    rounds: vec![RoundCost {
                        label: "tree-hop",
                        alpha: mp.alpha_vendor,
                        repeat: depth,
                        ..Default::default()
                    }],
                },
                Phase {
                    label: "vendor-tree-stream",
                    rounds: vec![RoundCost {
                        label: "tree-stream",
                        nic_bytes: 2.0 * msg / c,
                        intra_bytes: 2.0 * msg,
                        reduce_bytes: msg,
                        reduce_bw: mp.gpu_reduce_bw,
                        copy_bytes: copy,
                        copy_bw: mp.overflow_copy_bw,
                        repeat: 1,
                        ..Default::default()
                    }],
                },
            ]
        }
    }
}

fn craympich_phases(
    mp: &MachineParams,
    kind: CollKind,
    _msg: f64,
    ranks: usize,
    b: f64,
    counters: &mut NicCounters,
) -> Vec<Phase> {
    // Single-channel ring; ALL writes via NIC-0, ALL reads via NIC-3
    // (Observation 1); reductions on the CPU.
    let steps = match kind {
        CollKind::AllGather | CollKind::ReduceScatter => ring::steps(ranks),
        CollKind::AllReduce => 2 * ring::steps(ranks), // RS ∘ AG ring pair
    };
    let needs_reduce = matches!(kind, CollKind::ReduceScatter | CollKind::AllReduce);
    let inter_bytes = steps as f64 * b;
    counters.write(0, inter_bytes);
    let read_nic = mp.nics_per_node - 1;
    counters.read(read_nic, inter_bytes);
    vec![Phase {
        label: "craympich-flat-ring",
        rounds: vec![RoundCost {
            label: "ring-step",
            alpha: mp.alpha_inter,
            nic_bytes: b, // everything through one NIC
            intra_bytes: b,
            reduce_bytes: if needs_reduce { b } else { 0.0 },
            reduce_bw: mp.cpu_reduce_bw,
            repeat: steps,
            ..Default::default()
        }],
    }]
}

fn custom_phases(
    mp: &MachineParams,
    kind: CollKind,
    _msg: f64,
    ranks: usize,
    b: f64,
    counters: &mut NicCounters,
) -> Vec<Phase> {
    // The paper's diagnostic (Fig. 4): MPI p2p ring + GPU reduce. Same
    // single-channel routing as a flat MPI ring (one boundary GPU per node,
    // hence one busy NIC), but reductions on the GPU.
    let steps = match kind {
        CollKind::AllGather | CollKind::ReduceScatter => ring::steps(ranks),
        CollKind::AllReduce => 2 * ring::steps(ranks),
    };
    let needs_reduce = matches!(kind, CollKind::ReduceScatter | CollKind::AllReduce);
    let inter_bytes = steps as f64 * b;
    counters.write(0, inter_bytes);
    counters.read(0, inter_bytes);
    vec![Phase {
        label: "custom-p2p-ring",
        rounds: vec![RoundCost {
            label: "ring-step",
            alpha: mp.alpha_inter,
            nic_bytes: b,
            intra_bytes: b,
            reduce_bytes: if needs_reduce { b } else { 0.0 },
            reduce_bw: mp.gpu_reduce_bw,
            repeat: steps,
            ..Default::default()
        }],
    }]
}

/// PCCL hierarchical phases (§IV-A), costed off the **lowered plan**:
/// the same `PlanSpec` the data plane runs is built at one symbolic
/// element per block, [`plan::phase_shapes`] reports each phase's
/// per-round element factors, and round bytes = factor × `b`. `rec`
/// selects the recursive doubling/halving inter-node backend; `lanes`
/// stripes the inter-node phase over that many transport lanes (rails).
#[allow(clippy::too_many_arguments)]
fn pccl_phases(
    mp: &MachineParams,
    topo: &Topology,
    kind: CollKind,
    msg: f64,
    ranks: usize,
    rec: bool,
    lanes: usize,
    counters: &mut NicCounters,
) -> Result<Vec<Phase>> {
    let n = topo.nodes();
    let m_local = topo.gpus_per_node();
    let gpg = (m_local / topo.nics_per_node()) as f64; // GPUs per NIC
    let p = ranks as f64;
    let b = msg / p;
    // Effective rail occupancy of the striped inter phase: one lane per
    // NIC rail at most (extra lanes share rails and buy nothing). The
    // recursive inter path runs unstriped (its exchange ranges span
    // blocks), matching the data plane's fallback.
    let rails = lanes.min(mp.nics_per_node).max(1);
    let inter_alpha = mp.alpha_inter + (rails - 1) as f64 * mp.alpha_lane;
    let use_rec = rec && n.is_power_of_two();

    // Lower rank 0's plan (SPMD-symmetric, so it is representative) with
    // block length 1, so each round's `sent_elems`/`combine_elems` is an
    // exact small-integer byte *factor*.
    let algo = if use_rec { Algo::HierRec } else { Algo::HierRing };
    let (pk, elems0) = match kind {
        CollKind::AllGather => (PlanKind::AllGather, 1),
        CollKind::ReduceScatter => (PlanKind::ReduceScatter, n * m_local),
        CollKind::AllReduce => (PlanKind::AllReduce, n * m_local),
    };
    let spec = PlanSpec::hier(pk, algo, n, m_local, elems0, 1);
    let shapes = plan::phase_shapes(&spec)?;

    // Inter-node phase (NIC-bound; per-GPU byte volumes, NIC load = gpg×).
    let cost_inter = |ph: &PhaseShape| -> Vec<RoundCost> {
        debug_assert_eq!(ph.scope, Scope::Inter);
        if ph.rounds.is_empty() {
            return vec![]; // single-node topology: phase is a no-op
        }
        if use_rec {
            // Non-uniform doubling/halving rounds, costed smallest first
            // (the halving reduce-scatter runs largest first; cost order
            // is immaterial to the round sum).
            let mut rounds: Vec<(u64, u64)> =
                ph.rounds.iter().map(|r| (r.sent_elems, r.combine_elems)).collect();
            rounds.sort_unstable();
            rounds
                .into_iter()
                .map(|(sent, combine)| RoundCost {
                    label: "inter-rec",
                    alpha: mp.alpha_inter,
                    nic_bytes: gpg * sent as f64 * b,
                    reduce_bytes: combine as f64 * b,
                    reduce_bw: mp.gpu_reduce_bw,
                    repeat: 1,
                    ..Default::default()
                })
                .collect()
        } else {
            // Ring rounds are uniform: compress to one repeated round.
            let (sent, combine) = (ph.rounds[0].sent_elems, ph.rounds[0].combine_elems);
            debug_assert!(ph
                .rounds
                .iter()
                .all(|r| (r.sent_elems, r.combine_elems) == (sent, combine)));
            vec![RoundCost {
                label: "inter-ring",
                alpha: inter_alpha,
                nic_bytes: gpg * sent as f64 * b,
                reduce_bytes: combine as f64 * b,
                reduce_bw: mp.gpu_reduce_bw,
                rails: rails as f64,
                repeat: ph.rounds.len(),
                ..Default::default()
            }]
        }
    };
    // Intra-node ring phase (vendor library, NVLink/IF only): uniform
    // rounds of n block messages each.
    let cost_intra = |ph: &PhaseShape| -> Vec<RoundCost> {
        debug_assert_eq!(ph.scope, Scope::Intra);
        if ph.rounds.is_empty() {
            return vec![]; // single GPU per node: phase is a no-op
        }
        let (sent, combine) = (ph.rounds[0].sent_elems, ph.rounds[0].combine_elems);
        debug_assert!(ph
            .rounds
            .iter()
            .all(|r| (r.sent_elems, r.combine_elems) == (sent, combine)));
        vec![RoundCost {
            label: "intra-ring",
            alpha: mp.alpha_intra,
            intra_bytes: sent as f64 * b,
            reduce_bytes: combine as f64 * b,
            reduce_bw: mp.gpu_reduce_bw,
            repeat: ph.rounds.len(),
            ..Default::default()
        }]
    };
    // Device-local shuffle of the full buffer (Step 3 / pre-shuffle).
    // Communication-free, so it has no rounds in the lowered plan — the
    // plan's output ordering *is* the unshuffle; its kernel cost is added
    // here positionally.
    let shuffle_round = || RoundCost {
        label: "shuffle",
        reduce_bytes: msg,
        reduce_bw: mp.shuffle_bw,
        repeat: 1,
        ..Default::default()
    };

    // NIC counters: each GPU moves (N-1)·b inter bytes via its bound NIC.
    let inter_per_gpu = (n.saturating_sub(1)) as f64 * b;
    for nic in 0..topo.nics_per_node() {
        counters.write(nic, gpg * inter_per_gpu);
        counters.read(nic, gpg * inter_per_gpu);
    }
    // Zero-copy priority-list path: residual overflow only.
    counters.match_overflow += 0.005 * (n as f64).log2().max(0.0) * m_local as f64;

    // Map the plan's phases positionally (builders emit AG = [inter,
    // intra], RS = [intra, inter], AR = RS phases then AG phases) and
    // splice the shuffle kernels in where the device-local permutation
    // sits in the paper's Fig. 5 description.
    let mut phases = Vec::new();
    match kind {
        CollKind::AllGather => {
            debug_assert_eq!(shapes.len(), 2);
            phases.push(Phase { label: "pccl-inter-ag", rounds: cost_inter(&shapes[0]) });
            phases.push(Phase { label: "pccl-intra-ag", rounds: cost_intra(&shapes[1]) });
            phases.push(Phase { label: "pccl-unshuffle", rounds: vec![shuffle_round()] });
        }
        CollKind::ReduceScatter => {
            debug_assert_eq!(shapes.len(), 2);
            phases.push(Phase { label: "pccl-preshuffle", rounds: vec![shuffle_round()] });
            phases.push(Phase { label: "pccl-intra-rs", rounds: cost_intra(&shapes[0]) });
            phases.push(Phase { label: "pccl-inter-rs", rounds: cost_inter(&shapes[1]) });
        }
        CollKind::AllReduce => {
            debug_assert_eq!(shapes.len(), 4);
            phases.push(Phase { label: "pccl-preshuffle", rounds: vec![shuffle_round()] });
            phases.push(Phase { label: "pccl-intra-rs", rounds: cost_intra(&shapes[0]) });
            phases.push(Phase { label: "pccl-inter-rs", rounds: cost_inter(&shapes[1]) });
            phases.push(Phase { label: "pccl-inter-ag", rounds: cost_inter(&shapes[2]) });
            phases.push(Phase { label: "pccl-intra-ag", rounds: cost_intra(&shapes[3]) });
            phases.push(Phase { label: "pccl-unshuffle", rounds: vec![shuffle_round()] });
        }
    }
    Ok(phases)
}

/// Pipeline stages used by the `pccl_rec_pipe4` ablation.
const PIPELINE_CHUNKS: f64 = 4.0;

/// Collapse a PCCL phase list into its chunk-pipelined wall time: the
/// dominant phase runs at full length while the others hide behind it,
/// except for one chunk's worth of fill/drain.
fn pipeline_phases(mp: &MachineParams, phases: Vec<Phase>) -> Vec<Phase> {
    let times: Vec<f64> = phases.iter().map(|ph| ph.time(mp)).collect();
    let sum: f64 = times.iter().sum();
    let max = times.iter().cloned().fold(0.0, f64::max);
    let t = max + (sum - max) / PIPELINE_CHUNKS;
    vec![Phase {
        label: "pccl-pipelined",
        rounds: vec![RoundCost {
            label: "pipelined-total",
            alpha: t,
            repeat: 1,
            ..Default::default()
        }],
    }]
}

/// NCCL PAT ablation: recursive-doubling-shaped flat all-gather /
/// reduce-scatter with vendor channelization. Real NCCL PAT only supports
/// one GPU per node [16]; this model assumes that restriction lifted.
fn vendor_pat_phases(
    mp: &MachineParams,
    kind: CollKind,
    msg: f64,
    ranks: usize,
    b: f64,
    counters: &mut NicCounters,
    extra_sigma: &mut f64,
) -> Vec<Phase> {
    if kind == CollKind::AllReduce {
        // PAT does not change all-reduce (already double binary tree).
        let topo = Topology::flat(ranks);
        return vendor_phases(mp, &topo, kind, msg, ranks, b, counters, extra_sigma);
    }
    let c = mp.nics_per_node as f64;
    let m_local = mp.gpus_per_node as f64;
    let is_rs = kind == CollKind::ReduceScatter;
    let steps = recursive::steps(ranks.next_power_of_two());
    counters.write_even((ranks - 1) as f64 * b);
    counters.read_even((ranks - 1) as f64 * b);
    counters.match_overflow += overflow_frac(ranks) * steps as f64 * m_local;
    let rounds = (0..steps)
        .map(|s| {
            let bytes = b * (1 << s) as f64;
            RoundCost {
                label: "pat-step",
                alpha: mp.alpha_vendor,
                // Every GPU moves `bytes`; node egress m_local·bytes over
                // all NICs.
                nic_bytes: m_local * bytes / c,
                intra_bytes: bytes,
                reduce_bytes: if is_rs { bytes } else { 0.0 },
                reduce_bw: mp.gpu_reduce_bw,
                copy_bytes: vendor_copy_bytes(ranks, bytes, is_rs),
                copy_bw: mp.overflow_copy_bw,
                repeat: 1,
                ..Default::default()
            }
        })
        .collect();
    vec![Phase {
        label: "vendor-pat",
        rounds,
    }]
}

/// Simulate `trials` runs of one configuration.
pub fn simulate(
    machine: Machine,
    lib: LibModel,
    kind: CollKind,
    msg: usize,
    ranks: usize,
    trials: usize,
    seed: u64,
) -> Result<SimOutcome> {
    simulate_lanes(machine, lib, kind, msg, ranks, 1, trials, seed)
}

/// [`simulate`] with an explicit transport-lane count (see
/// [`schedule_lanes`]).
#[allow(clippy::too_many_arguments)]
pub fn simulate_lanes(
    machine: Machine,
    lib: LibModel,
    kind: CollKind,
    msg: usize,
    ranks: usize,
    lanes: usize,
    trials: usize,
    seed: u64,
) -> Result<SimOutcome> {
    let (phases, counters, extra_sigma) = schedule_lanes(machine, lib, kind, msg, ranks, lanes)?;
    // lanes = 1 must reproduce the exact pre-lane seed stream.
    let lane_salt = (lanes.max(1) as u64 - 1) << 24;
    let mut sim = NetSim::new(machine, seed ^ ((ranks as u64) << 32) ^ lane_salt ^ msg as u64);
    let times: Vec<f64> = (0..trials.max(1))
        .map(|_| sim.trial(&phases, extra_sigma))
        .collect();
    let stats = Stats::from_iter(times.iter().copied());
    Ok(SimOutcome {
        times,
        stats,
        counters,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    const MB: usize = 1 << 20;

    fn mean(lib: LibModel, kind: CollKind, msg: usize, ranks: usize) -> f64 {
        simulate(Machine::Frontier, lib, kind, msg, ranks, 1, 7)
            .unwrap()
            .stats
            .mean()
    }

    #[test]
    fn vendor_beats_craympich_bandwidth_bound() {
        // Fig. 3: ~4× from NIC underutilization at small scale, large msgs.
        let v = mean(LibModel::Vendor, CollKind::AllGather, 512 * MB, 64);
        let c = mean(LibModel::CrayMpich, CollKind::AllGather, 512 * MB, 64);
        let ratio = c / v;
        assert!(
            (2.0..8.0).contains(&ratio),
            "Cray-MPICH/RCCL AG ratio {ratio:.2} out of band"
        );
    }

    #[test]
    fn craympich_reduce_scatter_much_worse_than_allgather_gap() {
        // Fig. 4: CPU reductions blow the gap far beyond 4×.
        let v = mean(LibModel::Vendor, CollKind::ReduceScatter, 512 * MB, 64);
        let c = mean(LibModel::CrayMpich, CollKind::ReduceScatter, 512 * MB, 64);
        assert!(c / v > 6.0, "RS gap {:.2} should exceed AG gap", c / v);
        // And the custom p2p+GPU implementation recovers most of it.
        let cu = mean(LibModel::Custom, CollKind::ReduceScatter, 512 * MB, 64);
        assert!(cu < c / 2.0, "custom {cu} should be ≫ faster than Cray {c}");
        assert!(cu > v, "custom stays behind RCCL's multi-NIC ring");
    }

    #[test]
    fn pccl_scales_flat_vendor_scales_linearly() {
        // Fig. 1 / Fig. 10: vendor AG time grows ~linearly past 128 ranks,
        // PCCL stays near-flat.
        let v_256 = mean(LibModel::Vendor, CollKind::AllGather, 64 * MB, 256);
        let v_2048 = mean(LibModel::Vendor, CollKind::AllGather, 64 * MB, 2048);
        let p_256 = mean(LibModel::PcclRec, CollKind::AllGather, 64 * MB, 256);
        let p_2048 = mean(LibModel::PcclRec, CollKind::AllGather, 64 * MB, 2048);
        assert!(v_2048 / v_256 > 4.0, "vendor should degrade with p");
        assert!(p_2048 / p_256 < 2.0, "pccl should stay near-flat");
        assert!(v_2048 / p_2048 > 10.0, "pccl should win big at scale");
    }

    #[test]
    fn rec_beats_ring_latency_bound_and_loses_bandwidth_bound() {
        // Fig. 6 structure.
        let ring_small = mean(LibModel::PcclRing, CollKind::ReduceScatter, MB, 2048);
        let rec_small = mean(LibModel::PcclRec, CollKind::ReduceScatter, MB, 2048);
        assert!(rec_small < ring_small, "rec must win latency-bound");
        let ring_big = mean(LibModel::PcclRing, CollKind::ReduceScatter, 1024 * MB, 32);
        let rec_big = mean(LibModel::PcclRec, CollKind::ReduceScatter, 1024 * MB, 32);
        assert!(rec_big <= ring_big * 1.6, "rec shouldn't be a blowout loss");
    }

    #[test]
    fn lanes_speed_up_pccl_ring_reduce_and_leave_vendor_alone() {
        // Striped inter phase: parallel per-lane combine cuts the reduce
        // term; the per-lane alpha penalty must not dominate at large
        // messages. Deterministic times (jitter would swamp the margin).
        let mp = Machine::Frontier.params();
        let det = |lanes: usize| -> f64 {
            let (ph, _, _) = schedule_lanes(
                Machine::Frontier, LibModel::PcclRing, CollKind::ReduceScatter,
                1024 * MB, 48, lanes,
            )
            .unwrap();
            ph.iter().map(|p| p.time(&mp)).sum()
        };
        let (one, four) = (det(1), det(4));
        assert!(four < one, "4-lane RS {four} should beat 1-lane {one}");
        // Vendor ignores lanes entirely (same schedule, same seed stream
        // differs only by the lane salt — compare deterministic times).
        let (v1, _, _) = schedule_lanes(
            Machine::Frontier, LibModel::Vendor, CollKind::ReduceScatter, 64 * MB, 64, 1,
        )
        .unwrap();
        let (v4, _, _) = schedule_lanes(
            Machine::Frontier, LibModel::Vendor, CollKind::ReduceScatter, 64 * MB, 64, 4,
        )
        .unwrap();
        let t1: f64 = v1.iter().map(|ph| ph.time(&mp)).sum();
        let t4: f64 = v4.iter().map(|ph| ph.time(&mp)).sum();
        assert_eq!(t1, t4, "vendor model must be lane-blind");
        // And lanes = 1 through the lane entry point is bit-identical to
        // the legacy entry point.
        let legacy = simulate(
            Machine::Frontier, LibModel::PcclRing, CollKind::ReduceScatter, 64 * MB, 48, 3, 7,
        )
        .unwrap();
        let lane1 = simulate_lanes(
            Machine::Frontier, LibModel::PcclRing, CollKind::ReduceScatter, 64 * MB, 48, 1, 3, 7,
        )
        .unwrap();
        assert_eq!(legacy.times, lane1.times);
    }

    #[test]
    fn counters_show_library_routing() {
        let (_, cray, _) =
            schedule(Machine::Frontier, LibModel::CrayMpich, CollKind::AllGather, 256 * MB, 64)
                .unwrap();
        assert!(cray.posted_pkts[0] > 0.0);
        assert_eq!(cray.posted_pkts[1], 0.0);
        assert!(cray.non_posted_pkts[3] > 0.0);
        let (_, ven, _) =
            schedule(Machine::Frontier, LibModel::Vendor, CollKind::AllGather, 256 * MB, 64)
                .unwrap();
        assert!((ven.posted_imbalance() - 1.0).abs() < 1e-6);
        let (_, pccl, _) =
            schedule(Machine::Frontier, LibModel::PcclRec, CollKind::AllGather, 256 * MB, 64)
                .unwrap();
        assert!((pccl.posted_imbalance() - 1.0).abs() < 1e-6);
    }

    #[test]
    fn vendor_overflow_counter_dwarfs_pccl() {
        // §VI-B: RCCL's lpe_net_match_overflow_0 ≈ 200× PCCL's.
        let (_, ven, _) =
            schedule(Machine::Frontier, LibModel::Vendor, CollKind::ReduceScatter, 64 * MB, 2048)
                .unwrap();
        let (_, pccl, _) =
            schedule(Machine::Frontier, LibModel::PcclRec, CollKind::ReduceScatter, 64 * MB, 2048)
                .unwrap();
        assert!(
            ven.match_overflow > 100.0 * pccl.match_overflow,
            "vendor {} vs pccl {}",
            ven.match_overflow,
            pccl.match_overflow
        );
    }
}
